"""Kernel budget linter: static SMEM/VMEM accounting vs declared budgets.

Builds headroom reports on top of the cost models in
``repro.kernels.budgets`` (the constants + validators the packers call at
cache-pack time). This module is the *analysis* face: given an ELL layout,
a flash-GAT grid, or a grouped-matmul tiling, report per-launch memory use
against the per-core budgets — and raise the same actionable
:class:`BudgetError` the producer-thread validators do.

The split keeps layering clean: kernels never import ``repro.analysis``;
the pack-time checks live next to the constants in ``kernels.budgets``,
while the reporting/linting API (and the benchmark's ``budget_headroom``
summaries) live here.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.kernels import budgets as hw
from repro.kernels.budgets import BudgetError  # noqa: F401  re-export


def _headroom(usage: Dict[str, int]) -> Dict[str, float]:
    return {
        "smem_frac": usage["smem_bytes"] / hw.SMEM_BYTES_PER_CORE,
        "vmem_frac": usage["vmem_bytes"] / hw.VMEM_BYTES_PER_CORE,
        "smem_headroom_bytes": hw.SMEM_BYTES_PER_CORE - usage["smem_bytes"],
        "vmem_headroom_bytes": hw.VMEM_BYTES_PER_CORE - usage["vmem_bytes"],
    }


def ell_layout_report(layout: Sequence[Tuple[np.ndarray, int]], *,
                      feat: int = hw.DEFAULT_BF,
                      block_rows: int = hw.DEFAULT_BR,
                      weighted: bool = False,
                      strict: bool = True) -> List[Dict[str, Any]]:
    """Per-rung launch accounting of a static ELL layout.

    With ``strict=True`` (default) an over-budget rung raises
    :class:`BudgetError`; with ``strict=False`` the rung is reported with
    ``over_budget=True`` instead (the lint-report mode).
    """
    out = []
    for rows, k in layout:
        k = int(k)
        usage = hw.ell_launch_usage(len(rows), k, feat,
                                    block_rows=block_rows, weighted=weighted)
        rec = {"rows": int(len(rows)), "k": k, "feat": feat, **usage,
               **_headroom(usage)}
        rec["over_budget"] = (usage["smem_bytes"] > hw.SMEM_BYTES_PER_CORE
                              or usage["vmem_bytes"] > hw.VMEM_BYTES_PER_CORE
                              or block_rows * k > hw.MAX_PREFETCH_ELEMS)
        if strict and rec["over_budget"]:
            hw.check_ell_rung(k, block_rows=block_rows,
                              context="ell_layout_report")
            raise BudgetError(
                f"ell_layout_report: K={k} rung over budget: "
                f"smem={usage['smem_bytes']}B vmem={usage['vmem_bytes']}B")
        out.append(rec)
    return out


def gat_grid_report(rows: int, k: int, heads: int, feat: int, *,
                    block_rows: int = hw.DEFAULT_BR,
                    weighted: bool = False) -> Dict[str, Any]:
    """One flash-GAT bucket's launch accounting (strict)."""
    hw.check_gat_bucket(rows, k, heads, feat, block_rows=block_rows,
                        weighted=weighted)
    usage = hw.gat_launch_usage(rows, k, heads, feat,
                                block_rows=block_rows, weighted=weighted)
    return {"rows": rows, "k": k, "heads": heads, "feat": feat, **usage,
            **_headroom(usage)}


def attn_grid_report(rows: int, k: int, heads: int, feat: int, *,
                     logit_dim: int = 1, block_rows: int = hw.DEFAULT_BR,
                     weighted: bool = False, carry: bool = True
                     ) -> Dict[str, Any]:
    """One typed-attention bucket's launch accounting (strict).

    Generalises :func:`gat_grid_report` to the carry-mode launch shape:
    ``logit_dim`` widens the alpha operands per head (the dot logit's head
    dim), ``carry=True`` adds the ``(1, H)`` prior row and the per-block
    ``m``/``l`` carry outputs. Raises :class:`BudgetError` when the shape
    is unservable — the same check the packer runs at pack time.
    """
    hw.check_attn_bucket(rows, k, heads, feat, logit_dim=logit_dim,
                         block_rows=block_rows, weighted=weighted,
                         carry=carry)
    usage = hw.attn_launch_usage(rows, k, heads, feat, logit_dim=logit_dim,
                                 block_rows=block_rows, weighted=weighted,
                                 carry=carry)
    return {"rows": rows, "k": k, "heads": heads, "feat": feat,
            "logit_dim": logit_dim, "carry": carry, **usage,
            **_headroom(usage)}


def gmm_tiling_report(k_dim: int, *, block: Tuple[int, int, int] = hw.GMM_BLOCK
                      ) -> Dict[str, Any]:
    """Grouped-matmul grid-step accounting (the MXU tile working set)."""
    usage = hw.gmm_launch_usage(k_dim, block=block)
    if usage["vmem_bytes"] > hw.VMEM_BYTES_PER_CORE:
        raise BudgetError(
            f"grouped-matmul tiling {block}: {usage['vmem_bytes']} VMEM "
            f"bytes per grid step exceeds the per-core budget of "
            f"{hw.VMEM_BYTES_PER_CORE}. Shrink the MXU block shape.")
    return {"block": tuple(block), **usage, **_headroom(usage)}


def budget_headroom_summary(layouts: Optional[Sequence[
        Sequence[Tuple[np.ndarray, int]]]] = None, *,
        feat: int = hw.DEFAULT_BF, heads: int = 4) -> Dict[str, float]:
    """Worst-case headroom across layouts (the benchmark cell payload).

    With no layouts given, reports the default-constant working point: one
    max-chunk SpMM launch and a matching flash-GAT launch at ``DEFAULT_BR``
    / ``DEFAULT_BF``, plus the grouped-matmul tile set.
    """
    recs: List[Dict[str, Any]] = []
    if layouts:
        for layout in layouts:
            recs.extend(ell_layout_report(layout, feat=feat))
    else:
        max_k = hw.MAX_PREFETCH_ELEMS // hw.DEFAULT_BR
        usage = hw.ell_launch_usage(hw.DEFAULT_BR, max_k, feat)
        recs.append({**usage, **_headroom(usage)})
    gat = hw.gat_launch_usage(hw.DEFAULT_BR, hw.DEFAULT_BR * 2, heads, feat)
    recs.append({**gat, **_headroom(gat)})
    # typed-attention working point: carry-mode launch with the dot logit's
    # head-dim-wide alpha operands (the HGT shape at this feat/heads)
    attn = hw.attn_launch_usage(hw.DEFAULT_BR, hw.DEFAULT_BR * 2, heads,
                                feat, logit_dim=max(feat // heads, 1),
                                carry=True)
    recs.append({**attn, **_headroom(attn)})
    gmm = hw.gmm_launch_usage(feat)
    recs.append({**gmm, **_headroom(gmm)})
    return {
        "min_smem_headroom_bytes": min(r["smem_headroom_bytes"]
                                       for r in recs),
        "min_vmem_headroom_bytes": min(r["vmem_headroom_bytes"]
                                       for r in recs),
        "max_smem_frac": max(r["smem_frac"] for r in recs),
        "max_vmem_frac": max(r["vmem_frac"] for r in recs),
        "launches_audited": len(recs),
    }
