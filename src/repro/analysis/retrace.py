"""Retrace sentinel: fail fast (and explain) when a jit entry recompiles.

Every "single trace across batches" invariant in this repo used to be a
hand-rolled ``traces.append(1)`` inside the traced body. The sentinel makes
it a reusable instrument: wrap a jit'd entry point, and every call records
its *abstract signature* — the args' pytree structure plus each leaf's
``(shape, dtype)`` (or the static value for non-array leaves). Distinct
signatures are exactly what forces a fresh jit compilation, so exceeding a
declared budget raises :class:`RetraceError` *with a leaf-level diff* of
the offending avals/static aux against the previous signature — instead of
a silent recompile (or an opaque counter assert).

Usage::

    with RetraceSentinel(budget=1) as sentinel:
        step = sentinel.wrap(jax.jit(step), name="train_step")
        for batch in batches:
            step(params, batch)        # raises on a 2nd distinct signature
    sentinel.count("train_step")       # -> 1

``watch(jitted_fn)`` is the non-wrapping variant for functions called
elsewhere: it snapshots ``_cache_size()`` on entry and verifies the delta
on exit.
"""

from __future__ import annotations

import math
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax


class RetraceError(RuntimeError):
    """A jit entry point exceeded its declared recompilation budget."""


def _leaf_sig(leaf) -> Tuple[str, ...]:
    if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
        return ("aval", str(tuple(leaf.shape)), str(leaf.dtype))
    return ("static", repr(leaf))


def _signature(args: tuple, kwargs: dict) -> Tuple[Any, ...]:
    leaves, treedef = jax.tree_util.tree_flatten((args, kwargs))
    return (str(treedef),) + tuple(_leaf_sig(l) for l in leaves)


def _diff(old: Tuple, new: Tuple) -> str:
    lines: List[str] = []
    if old[0] != new[0]:
        lines.append(f"  pytree structure changed:\n    was {old[0]}\n"
                     f"    now {new[0]}")
    for i, (a, b) in enumerate(zip(old[1:], new[1:])):
        if a != b:
            lines.append(f"  leaf[{i}]: {' '.join(a)} -> {' '.join(b)}")
    if len(old) != len(new):
        lines.append(f"  leaf count: {len(old) - 1} -> {len(new) - 1}")
    return "\n".join(lines) or "  (signatures differ only in ordering)"


def cache_size(jitted) -> Optional[int]:
    """Compiled-variant count of a ``jax.jit``-ed callable, if exposed."""
    probe = getattr(jitted, "_cache_size", None)
    try:
        return int(probe()) if callable(probe) else None
    except Exception:
        return None


class RetraceSentinel:
    """Records (fn, abstract-signature) keys; raises beyond the budget.

    ``budget`` is the number of *distinct signatures* (== compilations)
    each instrumented entry point may accumulate; ``None`` disables
    enforcement but keeps recording (the serving-path mode: never crash,
    still report).
    """

    def __init__(self, budget: Optional[int] = 1):
        self.budget = math.inf if budget is None else int(budget)
        self._signatures: Dict[str, List[Tuple]] = {}
        self._watched: List[Tuple[str, Any, int]] = []

    # ------------------------------------------------------------- wrapping
    def wrap(self, fn: Callable, name: Optional[str] = None) -> Callable:
        """Instrument ``fn``: every call records its abstract signature."""
        key = name or getattr(fn, "__name__", repr(fn))
        self._signatures.setdefault(key, [])

        def wrapped(*args, **kwargs):
            self._record(key, _signature(args, kwargs))
            return fn(*args, **kwargs)

        wrapped.__name__ = f"sentinel({key})"
        wrapped.__wrapped__ = fn
        return wrapped

    def _record(self, key: str, sig: Tuple) -> None:
        seen = self._signatures[key]
        if sig in seen:
            return
        seen.append(sig)
        if len(seen) > self.budget:
            detail = ("\n" + _diff(seen[-2], sig)) if len(seen) >= 2 else ""
            raise RetraceError(
                f"{key}: retrace budget exceeded — {len(seen)} distinct "
                f"abstract signatures (budget {self.budget})."
                + (" Offending signature diff vs the previous one:" + detail
                   if detail else ""))

    # ------------------------------------------------------------- watching
    def watch(self, jitted, name: Optional[str] = None) -> None:
        """Track an already-jitted fn's compile cache without wrapping it."""
        key = name or getattr(jitted, "__name__", repr(jitted))
        base = cache_size(jitted)
        if base is None:
            raise ValueError(f"{key}: object exposes no _cache_size(); "
                             f"use wrap() instead")
        self._watched.append((key, jitted, base))

    # ------------------------------------------------------------ reporting
    def count(self, name: str) -> int:
        """Distinct signatures recorded for one instrumented entry point."""
        return len(self._signatures.get(name, ()))

    @property
    def counts(self) -> Dict[str, int]:
        out = {k: len(v) for k, v in self._signatures.items()}
        for key, jitted, base in self._watched:
            out[key] = (cache_size(jitted) or base) - base
        return out

    @property
    def total(self) -> int:
        return sum(self.counts.values())

    def check(self) -> None:
        """Verify every instrumented/watched entry is within budget."""
        for key, n in self.counts.items():
            if n > self.budget:
                sigs = self._signatures.get(key)
                detail = ("\n" + _diff(sigs[-2], sigs[-1])) if sigs and \
                    len(sigs) >= 2 else ""
                raise RetraceError(
                    f"{key}: {n} compilations exceed the retrace budget "
                    f"of {self.budget}{detail}")

    # -------------------------------------------------------- context mgmt
    def __enter__(self) -> "RetraceSentinel":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None:
            self.check()
