"""Dispatch auditor: prove a jit'd step rides the Pallas fast path.

Walks the closed jaxpr of a step (the same eqn-walking style as
``launch/jaxpr_stats.py``, here with full sub-jaxpr coverage — ``pjit``,
``custom_vjp``/``custom_jvp`` bodies, ``scan``/``while``/``cond`` branches)
and classifies every aggregation/projection into the ROADMAP dispatch tree:

  * ``pallas_call`` eqns, keyed by kernel function name
    (``_spmm_ell_kernel``, ``_gat_ell_kernel``, ``_gmm_kernel``, ...) —
    the fused fast path;
  * eqns inside a ``repro_oracle:<tag>`` named scope (the ref oracles tag
    themselves at trace time) — the XLA fallback branch;
  * eqns inside a ``repro_kernel_vjp:<tag>`` scope — the kernels' own
    custom-VJP backwards, which are gather/scatter XLA programs *by design*
    and must never be read as fallbacks when auditing grad steps;
  * untagged gather/scatter/segment eqns — reported informationally
    (feature lookups, output scatters, packers), never a failure.

``audit_report(fn, *args)`` replaces monkey-patched kernel spies: the claim
"all N relations hit the fused kernel, zero oracle fallbacks" becomes
``audit_report(step, params, batch).assert_fused()``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Tuple

import jax

ORACLE_SCOPE = "repro_oracle:"
KERNEL_VJP_SCOPE = "repro_kernel_vjp:"

# Untagged primitives worth surfacing in the informational bucket: the
# building blocks a segment-oracle aggregation would be made of.
_GATHER_SCATTER = ("gather", "scatter", "scatter-add", "scatter_add",
                   "scatter-max", "scatter-min", "take", "segment_sum")

# Cross-device collectives (the shard_map data-parallel step's comm layer).
# Classified *before* any scope check: a psum is communication wherever it
# appears — it must never be mistaken for an oracle fallback.
_COLLECTIVES = ("psum", "all_gather", "all_to_all", "ppermute",
                "reduce_scatter", "pmax", "pmin", "axis_index")


def _scope_tag(name_stack: str, marker: str) -> str:
    """Extract ``<tag>`` from the first ``<marker><tag>`` scope in a stack.

    Name stacks render as ``"a/b/repro_oracle:spmm_csr/c"`` and transforms
    may wrap entries (``transpose(repro_oracle:spmm_csr)``) — take the tag
    up to the next separator or closing paren.
    """
    start = name_stack.index(marker) + len(marker)
    tag = name_stack[start:]
    for sep in ("/", ")"):
        if sep in tag:
            tag = tag[: tag.index(sep)]
    return tag


def _sub_jaxprs(eqn) -> Tuple[List[Tuple[Any, int]], bool]:
    """(jaxpr, multiplier) children of an eqn — full coverage variant."""
    name = eqn.primitive.name
    p = eqn.params
    if name == "scan":
        return [(p["jaxpr"], p["length"])], False
    if name == "while":
        return [(p["body_jaxpr"], 1), (p["cond_jaxpr"], 1)], True
    if name == "cond":
        # audit every branch: any of them can run
        return [(b, 1) for b in p["branches"]], False
    for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
        if key in p and p[key] is not None:
            return [(p[key], 1)], False
    return [], False


@dataclasses.dataclass
class DispatchReport:
    """Classified eqn counts of one audited jaxpr (all scan-multiplied)."""
    kernel_launches: Dict[str, int] = dataclasses.field(default_factory=dict)
    oracle_eqns: Dict[str, int] = dataclasses.field(default_factory=dict)
    kernel_vjp_eqns: Dict[str, int] = dataclasses.field(default_factory=dict)
    unattributed_gather_scatter: Dict[str, int] = dataclasses.field(
        default_factory=dict)
    collective_eqns: Dict[str, int] = dataclasses.field(default_factory=dict)
    total_eqns: int = 0
    dynamic_trip_warnings: int = 0

    @property
    def oracle_fallbacks(self) -> int:
        """Total eqns attributed to any oracle region (0 == fully fused)."""
        return sum(self.oracle_eqns.values())

    @property
    def total_kernel_launches(self) -> int:
        return sum(self.kernel_launches.values())

    @property
    def total_collectives(self) -> int:
        """Total cross-device collective eqns (psum/all_gather/...)."""
        return sum(self.collective_eqns.values())

    def assert_fused(self, *, expect_kernels: Tuple[str, ...] = (),
                     min_launches: int = 1,
                     expect_collectives: Dict[str, int] = None
                     ) -> "DispatchReport":
        """Fail unless the step is fully on the fast path.

        Asserts zero oracle-region eqns, at least ``min_launches``
        ``pallas_call`` eqns overall, and (when given) at least one launch
        of each kernel in ``expect_kernels``. ``expect_collectives`` pins
        the *exact* per-primitive collective counts (golden audit of a
        sharded step: e.g. ``{"psum": 1}`` for the single fused gradient
        all-reduce; primitives absent from the dict must not appear).
        Returns self for chaining.
        """
        if self.oracle_fallbacks:
            raise AssertionError(
                f"oracle fallback detected: {self.oracle_eqns} "
                f"(kernel launches seen: {self.kernel_launches or 'none'})")
        if self.total_kernel_launches < min_launches:
            raise AssertionError(
                f"expected >= {min_launches} pallas_call launches, saw "
                f"{self.total_kernel_launches} ({self.kernel_launches})")
        for k in expect_kernels:
            if self.kernel_launches.get(k, 0) < 1:
                raise AssertionError(
                    f"expected kernel {k!r} was never launched; saw "
                    f"{self.kernel_launches}")
        if expect_collectives is not None and \
                dict(self.collective_eqns) != dict(expect_collectives):
            raise AssertionError(
                f"collective eqns {dict(self.collective_eqns)} != expected "
                f"{dict(expect_collectives)}")
        return self

    def summary(self) -> Dict[str, Any]:
        """JSON-ready summary (the benchmark audit cell's payload)."""
        return {
            "kernel_launches": dict(self.kernel_launches),
            "oracle_fallback_eqns": dict(self.oracle_eqns),
            "oracle_fallbacks": self.oracle_fallbacks,
            "kernel_vjp_eqns": dict(self.kernel_vjp_eqns),
            "unattributed_gather_scatter":
                dict(self.unattributed_gather_scatter),
            "collective_eqns": dict(self.collective_eqns),
            "total_collectives": self.total_collectives,
            "total_eqns": self.total_eqns,
        }


def audit_jaxpr(jaxpr, mult: int = 1,
                report: DispatchReport = None) -> DispatchReport:
    """Classify every eqn of a (closed) jaxpr into the dispatch tree."""
    if report is None:
        report = DispatchReport()
    inner = jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr
    for eqn in inner.eqns:
        name = eqn.primitive.name
        if name == "pallas_call":
            info = eqn.params.get("name_and_src_info")
            kernel = getattr(info, "name", None) or eqn.params.get(
                "name", "<unnamed>")
            report.kernel_launches[kernel] = report.kernel_launches.get(
                kernel, 0) + mult
            report.total_eqns += mult
            continue
        if name in _COLLECTIVES:
            report.collective_eqns[name] = report.collective_eqns.get(
                name, 0) + mult
            report.total_eqns += mult
            continue
        subs, is_while = _sub_jaxprs(eqn)
        if subs:
            if is_while:
                report.dynamic_trip_warnings += mult
            for sub, length in subs:
                audit_jaxpr(sub, mult * length, report)
            continue
        report.total_eqns += mult
        stack = str(getattr(eqn.source_info, "name_stack", "") or "")
        if KERNEL_VJP_SCOPE in stack:
            tag = _scope_tag(stack, KERNEL_VJP_SCOPE)
            report.kernel_vjp_eqns[tag] = report.kernel_vjp_eqns.get(
                tag, 0) + mult
        elif ORACLE_SCOPE in stack:
            tag = _scope_tag(stack, ORACLE_SCOPE)
            report.oracle_eqns[tag] = report.oracle_eqns.get(tag, 0) + mult
        elif name in _GATHER_SCATTER:
            report.unattributed_gather_scatter[name] = \
                report.unattributed_gather_scatter.get(name, 0) + mult
    return report


def audit_report(fn, *args, **kwargs) -> DispatchReport:
    """Trace ``fn(*args, **kwargs)`` abstractly and audit its dispatch.

    ``fn`` may be a plain callable or an already-``jax.jit``-ed one; the
    trace is abstract (no compilation, no execution), so auditing a
    ``value_and_grad`` train step is cheap.
    """
    jaxpr = jax.make_jaxpr(lambda *a: fn(*a, **kwargs))(*args)
    return audit_jaxpr(jaxpr)
