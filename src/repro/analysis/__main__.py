"""``python -m repro.analysis [--root PATH]`` — run the static lint gate.

Runs the AST rules over every ``.py`` under ``--root`` (default: the
``src/`` tree this package was imported from) plus the dynamic pytree
round-trip checks. Prints findings one per line and exits 1 on any; exits
0 clean — the tier-1 test ``test_static_analysis.py::test_lint_clean``
enforces the clean exit.
"""

from __future__ import annotations

import argparse
import os
import sys

from repro.analysis import lint


def default_root() -> str:
    # src/repro/analysis/__main__.py -> src/
    return os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    ap.add_argument("--root", default=default_root(),
                    help="directory tree to lint (default: the src/ tree)")
    ap.add_argument("--no-pytree", action="store_true",
                    help="skip the dynamic pytree round-trip checks")
    args = ap.parse_args(argv)

    findings = lint.lint_tree(args.root)
    if not args.no_pytree:
        findings += lint.check_pytree_roundtrips()
    for f in findings:
        print(f)
    n_rules = 3 + (0 if args.no_pytree else 1)
    if findings:
        print(f"FAILED: {len(findings)} finding(s) across {n_rules} passes",
              file=sys.stderr)
        return 1
    print(f"OK: {n_rules} passes clean over {args.root}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
