"""Static verification for the Pallas GNN stack (PR 7).

Four passes, one import surface:

  * :mod:`repro.analysis.dispatch` — jaxpr dispatch auditing
    (``audit_report(fn, *args).assert_fused()``);
  * :mod:`repro.analysis.budgets` — SMEM/VMEM accounting of kernel layouts
    vs the declared per-core budgets in :mod:`repro.kernels.budgets`;
  * :mod:`repro.analysis.retrace` — recompilation sentinels with
    signature diffs (``RetraceSentinel``);
  * :mod:`repro.analysis.lint` — AST rules + pytree round-trip checks
    (``python -m repro.analysis`` runs them over ``src/``).
"""

from repro.analysis.budgets import (BudgetError, attn_grid_report,
                                    budget_headroom_summary,
                                    ell_layout_report, gat_grid_report,
                                    gmm_tiling_report)
from repro.analysis.dispatch import (DispatchReport, audit_jaxpr,
                                     audit_report)
from repro.analysis.lint import (Finding, check_pytree_roundtrips,
                                 lint_source, lint_tree, run_all)
from repro.analysis.retrace import (RetraceError, RetraceSentinel,
                                    cache_size)

__all__ = [
    "BudgetError", "attn_grid_report", "budget_headroom_summary",
    "ell_layout_report", "gat_grid_report", "gmm_tiling_report", "DispatchReport", "audit_jaxpr",
    "audit_report", "Finding", "check_pytree_roundtrips", "lint_source",
    "lint_tree", "run_all", "RetraceError", "RetraceSentinel", "cache_size",
]
