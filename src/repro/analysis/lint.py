"""AST lint rules for the Pallas GNN stack (+ pytree round-trip check).

Five rules, each encoding an invariant the stack's correctness rests on:

  * **raw-kernel-entry** — the forward-only Pallas entry points
    (``spmm_ell_pallas``, ``gat_ell_pallas``, ``attn_ell_pallas``,
    ``grouped_matmul_pallas``, ``segment_softmax_pallas``,
    ``flash_attention_pallas``) may only be called from inside their own
    kernel package (its ``ops.py`` wrapper is the differentiable,
    budget-checked public surface). A call anywhere else bypasses the
    custom VJP, the SMEM chunking, and the budget validation at once.
    The rule is also *generic*: ANY call named ``*_pallas`` that is not a
    registered entry (or the ``use_pallas``/``forward_only_pallas``
    helpers) must live inside ``repro/kernels/`` — a new raw entry is
    package-private until it is registered here with its owning package.
  * **injectable-clock-rng** — the deterministic host paths
    (``data/resilience.py`` fault handling, ``data/loader.py`` batch
    production, ``data/feature_store.py`` cache eviction,
    ``data/partition.py`` region growing) must stay deterministic and
    testable: no ``time.time()``, no stdlib ``random``, no global-state
    ``np.random.*`` calls, no zero-arg ``default_rng()`` (the injectable
    ``clock=``/``sleep=``/seeded-rng discipline).
  * **host-packing-purity** — the producer-thread packers (CSR->ELL
    packing, grouped-matmul pack plans, slot-bound computation) and the
    loader pipeline's sample/gather stages plus the hot-cache eviction
    must be pure numpy: a ``jnp.``/``jax.`` call there moves device work
    (and possibly tracing) onto the loader's producer/stage threads —
    only ``_stage_pack`` may touch jnp, on purpose.
  * **shard-step-purity** — the ``shard_map``'d train-step bodies
    (``MeshTrainer``'s ``_shard_body``/``_shard_body_compressed``) must
    stay on-device end to end: no ``jax.device_get`` and no host
    callbacks (``pure_callback``/``io_callback``/``debug_callback``/
    ``print``-style debugging). A host round-trip inside the sharded body
    serialises every device on the mesh behind one host transfer — the
    exact sync point data parallelism exists to remove.
  * **pytree-roundtrip** (dynamic, not AST) — every registered pytree
    (``Batch``, ``HeteroBatch``, ``EdgeIndex``) must flatten/unflatten to
    an equal treedef with its aux fields intact, else batches silently
    retrace or drop metadata across the jit boundary.

``python -m repro.analysis`` runs everything over ``src/`` and exits
non-zero on any finding; ``tests/test_static_analysis.py::test_lint_clean``
enforces it in tier 1.
"""

from __future__ import annotations

import ast
import dataclasses
import os
from typing import Dict, List, Optional, Set, Tuple

# kernel entry name -> kernel package directory (posix fragment) whose
# files may call it (the defining module + its ops.py wrapper).
RAW_KERNEL_ENTRIES: Dict[str, str] = {
    "spmm_ell_pallas": "repro/kernels/spmm/",
    "gat_ell_pallas": "repro/kernels/attention/",
    "attn_ell_pallas": "repro/kernels/attention/",
    "grouped_matmul_pallas": "repro/kernels/grouped_matmul/",
    "segment_softmax_pallas": "repro/kernels/segment_softmax/",
    "flash_attention_pallas": "repro/kernels/flash_attention/",
}

# ``*_pallas`` callables that are NOT raw kernel entries (dispatch helpers).
PALLAS_CALL_ALLOWLIST: Set[str] = {"use_pallas", "forward_only_pallas"}

# path suffix -> function names that must stay jnp/jax-free (producer-thread
# host packing: shape decisions and table packing, pure numpy by contract).
# The loader pipeline's sample/gather stages and the hot-row cache's
# lookup/insert/eviction run on producer/stage threads and obey the same
# contract — only _stage_pack is allowed to touch jnp (device put).
HOST_PACKING_FUNCS: Dict[str, Set[str]] = {
    "repro/kernels/spmm/ops.py": {
        "_ell_positions", "csr_to_ell", "csr_to_ell_bucketed",
        "csr_to_ell_static", "ell_layout_from_bounds"},
    "repro/kernels/grouped_matmul/ops.py": {"_pack_plan"},
    "repro/data/sampler.py": {"static_slot_bounds"},
    "repro/data/hetero_sampler.py": {
        "hetero_static_slot_bounds", "_stage_sample", "_stage_gather"},
    "repro/data/loader.py": {
        "_stage_sample", "_stage_gather", "_seed_batches", "_seed_route",
        "split_seed_shards", "_sample_one", "_gather_one"},
    "repro/data/feature_store.py": {"lookup", "insert", "_evict", "_get"},
    "repro/data/partition.py": {
        "partition_graph", "_frontier_neighbors", "_undirected_csr"},
}

# Files whose host-side control flow must be deterministic and testable:
# resilience fault paths, the loader's stage pipeline + seed batching, the
# feature-store caches' eviction, and the partitioner's region growing.
DETERMINISTIC_HOST_SUFFIXES: Tuple[str, ...] = (
    "repro/data/resilience.py",
    "repro/data/loader.py",
    "repro/data/feature_store.py",
    "repro/data/partition.py",
)

# backward-compat alias (pre-pipeline rule scope)
RESILIENCE_SUFFIX = DETERMINISTIC_HOST_SUFFIXES[0]

# path suffix -> shard_map'd step-body function names that must stay
# on-device (no host transfers / callbacks inside the mesh step).
SHARD_STEP_FUNCS: Dict[str, Set[str]] = {
    "repro/launch/train.py": {"_shard_body", "_shard_body_compressed"},
}

# Call names (matched on the final attribute) that force a host round-trip.
_HOST_SYNC_CALLS = {"device_get", "pure_callback", "io_callback",
                    "debug_callback", "debug_print"}

# numpy global-state RNG entry points (the seeded-Generator API is fine).
_NP_GLOBAL_RNG = {"seed", "random", "rand", "randn", "randint", "choice",
                  "shuffle", "permutation", "normal", "uniform"}


@dataclasses.dataclass
class Finding:
    path: str
    lineno: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.lineno}: [{self.rule}] {self.message}"


def _posix(path: str) -> str:
    return path.replace(os.sep, "/")


def _attr_chain(node: ast.AST) -> List[str]:
    """``a.b.c`` -> ["a", "b", "c"]; [] when the root is not a Name."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        parts.reverse()
        return parts
    return []


def _call_name(node: ast.Call) -> Optional[str]:
    if isinstance(node.func, ast.Name):
        return node.func.id
    if isinstance(node.func, ast.Attribute):
        return node.func.attr
    return None


def _lint_raw_kernel_entries(path: str, tree: ast.AST) -> List[Finding]:
    posix = _posix(path)
    findings = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = _call_name(node)
        allowed = RAW_KERNEL_ENTRIES.get(name or "")
        if allowed and allowed not in posix:
            findings.append(Finding(
                path, node.lineno, "raw-kernel-entry",
                f"{name} is a forward-only raw kernel entry; call the "
                f"differentiable wrapper in {allowed}ops.py instead"))
        elif (name and name.endswith("_pallas")
              and name not in RAW_KERNEL_ENTRIES
              and name not in PALLAS_CALL_ALLOWLIST
              and "repro/kernels/" not in posix):
            findings.append(Finding(
                path, node.lineno, "raw-kernel-entry",
                f"{name} looks like an unregistered raw Pallas entry; raw "
                f"entries are package-private to repro/kernels/ — expose a "
                f"differentiable ops.py wrapper and register the entry in "
                f"RAW_KERNEL_ENTRIES"))
    return findings


def _lint_resilience_clock_rng(path: str, tree: ast.AST) -> List[Finding]:
    if not _posix(path).endswith(DETERMINISTIC_HOST_SUFFIXES):
        return []
    findings = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name.split(".")[0] == "random":
                    findings.append(Finding(
                        path, node.lineno, "injectable-clock-rng",
                        "stdlib random in fault paths: use a seeded "
                        "np.random.default_rng(seed) stream"))
        elif isinstance(node, ast.ImportFrom):
            if (node.module or "").split(".")[0] == "random":
                findings.append(Finding(
                    path, node.lineno, "injectable-clock-rng",
                    "stdlib random in fault paths: use a seeded "
                    "np.random.default_rng(seed) stream"))
        elif isinstance(node, ast.Call):
            chain = _attr_chain(node.func)
            if chain == ["time", "time"]:
                findings.append(Finding(
                    path, node.lineno, "injectable-clock-rng",
                    "time.time() in fault paths: use the injectable "
                    "clock=time.monotonic default"))
            elif (len(chain) == 3 and chain[0] in ("np", "numpy")
                  and chain[1] == "random" and chain[2] in _NP_GLOBAL_RNG):
                findings.append(Finding(
                    path, node.lineno, "injectable-clock-rng",
                    f"np.random.{chain[2]} uses the global RNG state: "
                    f"use a seeded default_rng(seed) stream"))
            elif (chain and chain[-1] == "default_rng"
                  and not node.args and not node.keywords):
                findings.append(Finding(
                    path, node.lineno, "injectable-clock-rng",
                    "default_rng() without a seed is nondeterministic: "
                    "thread the component's seed through"))
    return findings


def _lint_host_packing(path: str, tree: ast.AST) -> List[Finding]:
    posix = _posix(path)
    func_names: Optional[Set[str]] = None
    for suffix, names in HOST_PACKING_FUNCS.items():
        if posix.endswith(suffix):
            func_names = names
            break
    if func_names is None:
        return []
    findings = []
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if node.name not in func_names:
            continue
        for sub in ast.walk(node):
            chain = _attr_chain(sub) if isinstance(sub, ast.Attribute) \
                else []
            if chain and chain[0] in ("jnp", "jax"):
                findings.append(Finding(
                    path, sub.lineno, "host-packing-purity",
                    f"{node.name} is producer-thread host packing and must "
                    f"stay pure numpy; found {'.'.join(chain)}"))
                break  # one finding per function is enough signal
    return findings


def _lint_shard_step_purity(path: str, tree: ast.AST) -> List[Finding]:
    posix = _posix(path)
    func_names: Optional[Set[str]] = None
    for suffix, names in SHARD_STEP_FUNCS.items():
        if posix.endswith(suffix):
            func_names = names
            break
    if func_names is None:
        return []
    findings = []
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if node.name not in func_names:
            continue
        for sub in ast.walk(node):
            if not isinstance(sub, ast.Call):
                continue
            name = _call_name(sub)
            if name in _HOST_SYNC_CALLS:
                findings.append(Finding(
                    path, sub.lineno, "shard-step-purity",
                    f"{node.name} is a shard_map'd step body and must stay "
                    f"on-device; {name} forces a host round-trip that "
                    f"serialises the whole mesh"))
    return findings


def lint_source(path: str, source: str) -> List[Finding]:
    """All AST rules over one file's source text."""
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Finding(path, e.lineno or 0, "parse-error", str(e))]
    return (_lint_raw_kernel_entries(path, tree)
            + _lint_resilience_clock_rng(path, tree)
            + _lint_host_packing(path, tree)
            + _lint_shard_step_purity(path, tree))


def lint_tree(root: str) -> List[Finding]:
    """Run the AST rules over every ``.py`` under ``root``."""
    findings: List[Finding] = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if d != "__pycache__"]
        for fn in sorted(filenames):
            if not fn.endswith(".py"):
                continue
            path = os.path.join(dirpath, fn)
            with open(path, encoding="utf-8") as fh:
                findings.extend(lint_source(path, fh.read()))
    return findings


# ------------------------------------------------------- pytree round-trip
def _roundtrip(obj, describe: str) -> List[Finding]:
    import jax

    leaves, treedef = jax.tree_util.tree_flatten(obj)
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    leaves2, treedef2 = jax.tree_util.tree_flatten(rebuilt)
    findings = []
    if treedef != treedef2:
        findings.append(Finding(
            describe, 0, "pytree-roundtrip",
            f"treedef not stable under flatten/unflatten:\n  was "
            f"{treedef}\n  now {treedef2}"))
    if len(leaves) != len(leaves2):
        findings.append(Finding(
            describe, 0, "pytree-roundtrip",
            f"leaf count changed {len(leaves)} -> {len(leaves2)}"))
    return findings


def check_pytree_roundtrips() -> List[Finding]:
    """Flatten/unflatten every registered pytree; aux must survive intact.

    Treedef equality covers the aux data (it is part of the treedef), so a
    flatten/unflatten pair that drops or reorders aux fields fails here.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.edge_index import EdgeIndex
    from repro.data.hetero_sampler import HeteroBatch
    from repro.data.loader import Batch

    rng = np.random.default_rng(0)
    src = rng.integers(0, 8, 16).astype(np.int32)
    dst = rng.integers(0, 8, 16).astype(np.int32)
    ei = EdgeIndex.from_coo(src, dst, 8, 8).sort_by("col")[0].fill_cache()
    findings = _roundtrip(ei, "EdgeIndex")

    batch = Batch(
        x=jnp.zeros((8, 4)), edge_index=ei,
        n_id=jnp.arange(8), e_id=jnp.arange(16),
        seed_slots=jnp.arange(2), num_sampled_nodes=[2, 6],
        num_sampled_edges=[16], y=jnp.zeros((2,)),
        extras={"tag": jnp.zeros(())})
    findings += _roundtrip(batch, "Batch")

    et = ("user", "buys", "item")
    hetero = HeteroBatch(
        x_dict={"user": jnp.zeros((4, 2)), "item": jnp.zeros((6, 2))},
        edge_index_dict={et: ei},
        n_id_dict={"user": jnp.arange(4), "item": jnp.arange(6)},
        e_id_dict={et: jnp.arange(16)},
        seed_slots=jnp.arange(2), seed_type="item",
        num_sampled_nodes_dict={"user": [4], "item": [2, 4]},
        num_sampled_edges_dict={et: [16]},
        y=jnp.zeros((2,)))
    findings += _roundtrip(hetero, "HeteroBatch")
    leaves, treedef = jax.tree_util.tree_flatten(hetero)
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    if (rebuilt.seed_type != hetero.seed_type
            or rebuilt.num_sampled_nodes_dict != hetero.num_sampled_nodes_dict
            or rebuilt.num_sampled_edges_dict
            != hetero.num_sampled_edges_dict):
        findings.append(Finding(
            "HeteroBatch", 0, "pytree-roundtrip",
            "aux fields (seed_type / per-hop counts) did not round-trip"))
    return findings


def run_all(root: str) -> List[Finding]:
    """AST rules over ``root`` plus the dynamic pytree round-trip checks."""
    return lint_tree(root) + check_pytree_roundtrips()
