"""repro: a JAX/TPU reproduction of *PyG 2.0: Scalable Learning on Real World Graphs*.

Layers (bottom-up):
  kernels/      Pallas TPU kernels (+ jnp oracles) for the compute hot spots
  core/         the paper's contribution: EdgeIndex, message passing,
                aggregations, hetero transforms, trimming, explainability
  data/         FeatureStore / GraphStore / samplers / loaders (paper §2.3)
  nn/           GNN zoo + LM-architecture blocks (assigned-arch support)
  train/ serve/ step factories, optimizer, schedules, KV/SSM caches
  distributed/  sharding rules, checkpointing, elastic re-meshing
  launch/       production meshes, multi-pod dry-run, drivers
  configs/      assigned architecture configs (+ reduced smoke variants)
"""

__version__ = "2.0.0"
