"""jit-able step functions: train / prefill / decode, per architecture."""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.nn.lm import model as model_lib
from repro.nn.lm.config import ModelConfig
from repro.train import optimizer as opt_lib


def make_train_step(cfg: ModelConfig, opt_cfg: opt_lib.OptConfig,
                    remat=True):
    """(state, batch) -> (state, metrics). Closed over static configs."""

    def train_step(state: opt_lib.TrainState, batch: Dict[str, jnp.ndarray]):
        def loss(params):
            return model_lib.loss_fn(params, cfg, batch, remat=remat)

        (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(
            state.params)
        new_state, opt_metrics = opt_lib.apply_updates(state, grads, opt_cfg)
        out = {"loss": l, **metrics, **opt_metrics}
        return new_state, out

    return train_step


def make_train_step_compressed(cfg: ModelConfig,
                               opt_cfg: opt_lib.OptConfig):
    """Train step with error-feedback int8 gradient compression.

    The quantise/dequantise pair models the pod-boundary (DCN) gradient
    exchange: on real hardware the int8 payload is what crosses the slow
    link (4x traffic cut vs fp32); the error-feedback residual carries the
    rounding error to the next step so long-run updates stay unbiased.
    Signature: (state, batch, residual) -> (state, metrics, residual).
    """
    from repro.distributed import compression as comp_lib

    def train_step(state, batch, residual):
        def loss(params):
            return model_lib.loss_fn(params, cfg, batch)

        (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(
            state.params)
        payload, new_residual = comp_lib.compress_grads(grads, residual)
        grads = comp_lib.decompress_grads(payload, grads)
        new_state, opt_metrics = opt_lib.apply_updates(state, grads, opt_cfg)
        return new_state, {"loss": l, **metrics, **opt_metrics}, new_residual

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch, cache):
        return model_lib.prefill(
            params, cfg, batch["tokens"], cache,
            prefix_embeds=batch.get("prefix_embeds"),
            enc_in=batch.get("enc_in"))

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, token, cache, pos):
        return model_lib.decode_step(params, cfg, token, cache, pos)

    return decode_step
