"""Synthetic token pipeline for LM training drivers (infinite iterator).

Deterministic per-step batches (seeded), host-side generation double-
buffered so the accelerator never waits on the RNG.
"""

from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import jax.numpy as jnp
import numpy as np

from repro.nn.lm.config import ModelConfig


def synthetic_batches(cfg: ModelConfig, batch: int, seq: int, *,
                      seed: int = 0, enc_len: int = 0,
                      prefetch: int = 2) -> Iterator[Dict]:
    """Markov-ish synthetic tokens (learnable structure, not pure noise)."""
    rng = np.random.default_rng(seed)

    def make(i):
        # successor sequences with 5% noise tokens: next-token is learnable
        # from the bigram table alone (CE floor ~ 0.05 * ln V), so smoke
        # trainings show a clear loss drop within tens of steps
        first = rng.integers(0, cfg.vocab_size, (batch, 1))
        toks = (first + np.arange(seq)[None, :]) % cfg.vocab_size
        noise_mask = rng.random((batch, seq)) < 0.05
        toks = np.where(noise_mask,
                        rng.integers(0, cfg.vocab_size, (batch, seq)), toks)
        out = {"tokens": jnp.asarray(toks, jnp.int32)}
        if cfg.n_prefix_embeds:
            out["prefix_embeds"] = jnp.asarray(rng.standard_normal(
                (batch, cfg.n_prefix_embeds, cfg.d_model)), cfg.jnp_dtype)
        if cfg.arch_type == "encdec":
            out["enc_in"] = jnp.asarray(rng.standard_normal(
                (batch, enc_len or seq, cfg.d_model)), cfg.jnp_dtype)
        return out

    if prefetch <= 0:
        i = 0
        while True:
            yield make(i)
            i += 1
        return

    q: "queue.Queue" = queue.Queue(maxsize=prefetch)

    def producer():
        i = 0
        while True:
            q.put(make(i))
            i += 1

    threading.Thread(target=producer, daemon=True).start()
    while True:
        yield q.get()
