"""Fault-tolerant training loop: checkpoint/restart, stragglers, compression.

``train_loop`` is the production driver skeleton: resume-from-latest,
periodic (optionally async) checkpointing, per-step host timing into the
StragglerMonitor, optional error-feedback int8 gradient compression at the
pod boundary. ``SimulatedFailure`` lets tests kill the loop at an exact step
and assert bit-exact resume. Storage-layer faults compose from below: a
loader with ``on_batch_error="skip"`` simply yields fewer batches, the loop
rides an exhausted iterator out cleanly, and ``loader=`` snapshots the
loader's ``health`` counters into logs and the returned dict.
"""

from __future__ import annotations

import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import numpy as np

from repro.analysis.retrace import RetraceSentinel
from repro.distributed import checkpoint as ckpt_lib
from repro.distributed import compression as comp_lib
from repro.distributed.elastic import StragglerMonitor
from repro.train import optimizer as opt_lib


class SimulatedFailure(RuntimeError):
    pass


def train_loop(state: opt_lib.TrainState,
               train_step: Callable,
               batches: Iterator[Any], *,
               num_steps: int,
               ckpt_dir: Optional[str] = None,
               ckpt_every: int = 50,
               async_ckpt: bool = False,
               keep: int = 3,
               monitor: Optional[StragglerMonitor] = None,
               fail_at: Optional[int] = None,
               log_every: int = 10,
               loader: Optional[Any] = None,
               retrace_budget: Optional[int] = None,
               log_fn: Callable = print) -> Dict[str, Any]:
    """Run ``num_steps`` steps (resuming from the latest checkpoint if any).

    Returns {'state': final_state, 'history': [(step, loss), ...],
    'loader_health': ..., 'trace_signatures': ...}. A loader running with
    ``on_batch_error="skip"`` yields fewer batches than seed batches under
    store faults; the loop treats an exhausted iterator as end-of-data
    (logged, not crashed) and, when ``loader`` is given, snapshots its
    ``health`` counters (retries, skipped batches, degraded rows) into the
    result and the periodic log.

    ``retrace_budget`` arms a :class:`RetraceSentinel` around
    ``train_step``: every call's abstract signature (batch pytree + leaf
    avals) is recorded, and a batch whose shapes/static aux force a fresh
    compilation beyond the budget raises :class:`RetraceError` with a
    leaf-level signature diff — loudly, instead of silently recompiling
    every step. ``None`` records without enforcing.
    """
    sentinel = RetraceSentinel(budget=retrace_budget)
    train_step = sentinel.wrap(train_step, name="train_step")
    start = 0
    if ckpt_dir is not None:
        latest = ckpt_lib.latest_step(ckpt_dir)
        if latest is not None:
            state = ckpt_lib.restore_checkpoint(ckpt_dir, latest, state)
            start = latest
            log_fn(f"[resume] from step {latest}")
    history = []
    pending = None
    for step in range(start, num_steps):
        try:
            batch = next(batches)
        except StopIteration:
            # skipped batches (loader on_batch_error="skip") can exhaust
            # the epoch early — end the run cleanly instead of crashing
            log_fn(f"[data] iterator exhausted at step {step} "
                   f"(skipped batches?) — stopping")
            break
        t0 = time.perf_counter()
        state, metrics = train_step(state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        if monitor is not None:
            monitor.record(0, dt)
        loss = float(metrics["loss"])
        history.append((step + 1, loss))
        if (step + 1) % log_every == 0:
            health = ("" if loader is None or not hasattr(loader, "health")
                      else f" health={dict(loader.health)}")
            log_fn(f"step {step + 1}: loss={loss:.4f} "
                   f"({dt * 1e3:.0f} ms){health}")
        if ckpt_dir is not None and (step + 1) % ckpt_every == 0:
            if pending is not None:
                pending.join()
            pending = ckpt_lib.save_checkpoint(
                ckpt_dir, step + 1, state, keep=keep,
                async_write=async_ckpt)
        if fail_at is not None and (step + 1) == fail_at:
            if pending is not None:
                pending.join()
            raise SimulatedFailure(f"injected failure at step {step + 1}")
    if pending is not None:
        pending.join()
    loader_health = (dict(loader.health)
                     if loader is not None and hasattr(loader, "health")
                     else None)
    return {"state": state, "history": history,
            "loader_health": loader_health,
            "trace_signatures": sentinel.count("train_step")}


# EF-int8-compressed train steps live in repro.train.steps
# (make_train_step_compressed); the loop composes with them by carrying the
# residual pytree through `state.extras`-style threading in the caller.
