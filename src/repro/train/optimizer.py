"""AdamW with sharding-friendly, dtype-configurable moments.

Pure-pytree implementation (no optax on this box). Moments inherit the
param sharding (see distributed/sharding.py) so optimizer state is fully
FSDP/TP sharded. For the >300B archs (arctic, jamba) moments default to
bf16 so a single 256-chip pod's HBM holds the train state; smaller archs
use fp32 moments (standard).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"  # 'float32' | 'bfloat16'
    warmup_steps: int = 100
    total_steps: int = 10_000


class TrainState(NamedTuple):
    step: jnp.ndarray      # scalar int32
    params: Params
    mu: Params             # first moment
    nu: Params             # second moment


def init_state(params: Params, cfg: OptConfig) -> TrainState:
    mdt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return TrainState(
        step=jnp.zeros((), jnp.int32),
        params=params,
        mu=jax.tree_util.tree_map(zeros, params),
        nu=jax.tree_util.tree_map(zeros, params),
    )


def lr_schedule(cfg: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup + cosine decay."""
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def apply_updates(state: TrainState, grads: Params, cfg: OptConfig
                  ) -> Tuple[TrainState, Dict[str, jnp.ndarray]]:
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32) * scale
        mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        vf = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = mf / bc1
        vhat = vf / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, mf.astype(m.dtype), vf.astype(v.dtype)

    flat_p, treedef = jax.tree_util.tree_flatten(state.params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state.mu)
    flat_v = jax.tree_util.tree_leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return (TrainState(step, new_p, new_m, new_v),
            {"lr": lr, "grad_norm": gnorm})
