"""Path-based sharding rules: param/cache pytrees -> PartitionSpec trees.

The 2-D scheme (DESIGN.md §5):
  * ``model`` axis: tensor parallel — attention heads, FFN hidden, MoE
    experts, vocab.
  * ``data`` axis: FSDP — every param additionally shards its largest
    remaining axis over ``data``; gradients reduce-scatter over ``data``.
  * ``pod`` axis (multi-pod): pure data parallel; params replicated across
    pods, gradient all-reduce on DCN only.

Rules are matched on the flattened param path (e.g. ``body/sub0/mixer/wq``),
with the scanned-stack leading period axis handled automatically (specs are
shifted right by one when the leaf has an extra leading dim).
"""

from __future__ import annotations

import re
from contextlib import contextmanager
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# (regex on path, spec WITHOUT the scan axis). First match wins.
_PARAM_RULES: Tuple[Tuple[str, P], ...] = (
    # embeddings / head
    (r"embed$",            P("model", "data")),      # (vocab, d)
    (r"lm_head$",          P("data", "model")),      # (d, vocab)
    (r"(final_norm|enc_norm)$", P(None)),
    # attention
    (r"mixer/wq$",         P("data", "model", None)),  # (d, H, hd)
    (r"mixer/wk$",         P("data", "model", None)),
    (r"mixer/wv$",         P("data", "model", None)),
    (r"mixer/wo$",         P("model", None, "data")),  # (H, hd, d)
    (r"cross/wq$",         P("data", "model", None)),
    (r"cross/wk$",         P("data", "model", None)),
    (r"cross/wv$",         P("data", "model", None)),
    (r"cross/wo$",         P("model", None, "data")),
    (r"mixer/b[qkv]$",     P("model", None)),
    (r"(q_norm|k_norm)$",  P(None)),
    # dense FFN
    (r"ffn/w_in$",         P("data", None, "model")),  # (d, 2, ff)
    (r"ffn/w_out$",        P("model", "data")),        # (ff, d)
    (r"(shared|dense)/w_in$",  P("data", None, "model")),
    (r"(shared|dense)/w_out$", P("model", "data")),
    # MoE
    (r"ffn/router$",       P("data", None)),           # (d, E)
    # expert stacks: experts -> model (EP), d -> data (FSDP)
    (r"ffn/w_in$",         P("model", "data", None, None)),
    (r"ffn/w_out$",        P("model", None, "data")),
    # mamba
    (r"mixer/in_proj$",    P("data", None, "model")),  # (d, 2, di)
    (r"mixer/conv_w$",     P(None, "model")),          # (k, di)
    (r"mixer/conv_b$",     P("model")),
    (r"mixer/x_proj$",     P("model", None)),          # (di, r+2s)
    (r"mixer/dt_proj_w$",  P(None, "model")),          # (r, di)
    (r"mixer/dt_proj_b$",  P("model")),
    (r"mixer/A_log$",      P("model", None)),          # (di, st)
    (r"mixer/D$",          P("model")),
    (r"mixer/out_proj$",   P("model", "data")),        # (di, d)
    # norms
    (r"norm", P(None)),
)

# MoE expert tensors share the "ffn/w_in|w_out" names with dense FFN but have
# one more dim; disambiguate by rank (see _match).
_MOE_W_IN = P("model", "data", None, None)   # (E, d, 2, f)
_MOE_W_OUT = P("model", None, "data")        # (E, f, d)
_FFN_W_IN = P("data", None, "model")         # (d, 2, ff)
_FFN_W_OUT = P("model", "data")              # (ff, d)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(p.name)
        else:
            parts.append(str(p))
    return "/".join(parts)


def _match(path: str, ndim: int) -> P:
    if re.search(r"ffn/w_in$", path):
        base = _MOE_W_IN if ndim >= 4 else _FFN_W_IN
    elif re.search(r"ffn/w_out$", path):
        base = _MOE_W_OUT if ndim >= 3 else _FFN_W_OUT
    else:
        base = None
        for pat, spec in _PARAM_RULES:
            if re.search(pat, path):
                base = spec
                break
        if base is None:
            base = P()  # replicate by default
    return base


def _axis_size(mesh: Mesh, ax) -> int:
    if isinstance(ax, (tuple, list)):
        n = 1
        for a in ax:
            n *= mesh.shape[a]
        return n
    return mesh.shape[ax]


def _fix_spec(spec, shape, mesh: Mesh, lock_dims=()) -> P:
    """Repair a spec for divisibility: explicit in_shardings must divide
    evenly (GSPMD pads only propagated intermediates, not arguments).

    For each dim whose assigned axis does not divide, the axis migrates to
    the largest free dim that divides (e.g. GQA: kv_heads=8 < model=16 ->
    the ``model`` axis moves from the head dim to head_dim — head_dim
    tensor parallelism). Dims in ``lock_dims`` (the scan axis) never
    receive a migrated axis.
    """
    spec = list(spec)
    for i, ax in enumerate(spec):
        if ax is None:
            continue
        if shape[i] % _axis_size(mesh, ax) == 0:
            continue
        spec[i] = None
        n = _axis_size(mesh, ax)
        for j in sorted(range(len(shape)), key=lambda j: -shape[j]):
            if j == i or j in lock_dims or spec[j] is not None:
                continue
            if shape[j] % n == 0 and shape[j] >= n:
                spec[j] = ax
                break
    return P(*spec)


def _fsdp_spec(shape, mesh: Mesh, lock_dims=()) -> P:
    """Pure-FSDP spec: the largest divisible dim carries all non-pod axes.

    §Perf profile: at ≥1k tokens/device, per-param compute (6·tokens/chips
    FLOPs) dwarfs per-param FSDP traffic (~4 bytes), so sharding *weights*
    across all chips and batch across all chips beats tensor parallelism —
    TP's per-layer activation all-reduces are what dominate the baseline
    collective term.
    """
    axes = ("data", "model")  # flattened within-pod FSDP axis
    n = _axis_size(mesh, axes)
    spec = [None] * len(shape)
    cands = sorted((j for j in range(len(shape)) if j not in lock_dims),
                   key=lambda j: -shape[j])
    for j in cands:
        if shape[j] % n == 0 and shape[j] >= n:
            spec[j] = axes
            return P(*spec)
    for sub in ("data", "model"):
        m = _axis_size(mesh, sub)
        for j in cands:
            if shape[j] % m == 0 and shape[j] >= m:
                spec[j] = sub
                return P(*spec)
    return P(*spec)


def param_spec(path, leaf, mesh: Mesh = None, profile: str = "2d") -> P:
    """PartitionSpec for one param leaf, accounting for the scan axis."""
    ps = _path_str(path)
    ndim = leaf.ndim
    in_body = ps.startswith("body/") or "/body/" in ps or ps.startswith(
        "encoder/")
    if profile == "fsdp" and mesh is not None:
        return _fsdp_spec(leaf.shape, mesh,
                          lock_dims=(0,) if in_body else ())
    if profile == "ep" and mesh is not None:
        # expert tensors: experts -> 'model' (EP), hidden -> 'data' (FSDP);
        # everything else: FSDP over data only (model axis reserved for EP)
        base_ndim = ndim - (1 if in_body else 0)
        # expert weights: E -> 'model' (EP), d -> 'data' (FSDP).
        # (§Perf iteration 5 tried FSDP on the expert-hidden f dim instead —
        # hypothesis: avoid gathering weights whose contraction dim is
        # sharded. REFUTED: arctic 21.5->30.0s, deepseek 9.1->22.0s — XLA's
        # chosen schedule for the d-sharded layout (one weight all-gather
        # amortised across the fused GLU pair) beats per-matmul activation
        # psums. Reverted; kept for the record.)
        if re.search(r"ffn/w_in$", ps) and base_ndim >= 4:
            spec = (None, "model", "data", None, None)[-ndim:] \
                if in_body else ("model", "data", None, None)
            return _fix_spec(spec, leaf.shape, mesh,
                             lock_dims=(0,) if in_body else ())
        if re.search(r"ffn/w_out$", ps) and base_ndim >= 3:
            spec = (None, "model", None, "data")[-ndim:] \
                if in_body else ("model", None, "data")
            return _fix_spec(spec, leaf.shape, mesh,
                             lock_dims=(0,) if in_body else ())
        spec = [None] * ndim
        cands = sorted((j for j in range(ndim)
                        if not (in_body and j == 0)),
                       key=lambda j: -leaf.shape[j])
        for j in cands:
            if leaf.shape[j] % mesh.shape["data"] == 0 and \
                    leaf.shape[j] >= mesh.shape["data"]:
                spec[j] = "data"
                break
        return P(*spec)
    base = _match(ps, ndim - (1 if in_body else 0))
    spec = tuple(base)
    if in_body:
        spec = (None,) + spec  # period-stack axis replicated
    # pad/truncate to rank
    spec = (spec + (None,) * ndim)[:ndim]
    if mesh is not None:
        return _fix_spec(spec, leaf.shape, mesh,
                         lock_dims=(0,) if in_body else ())
    return P(*spec)


def param_shardings(mesh: Mesh, params_shape, profile: str = "2d") -> Any:
    """NamedSharding tree matching ``params_shape`` (shapes or arrays)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, param_spec(path, leaf, mesh, profile)), params_shape)


# ----------------------------------------------------------------- batches
def batch_spec(mesh: Mesh, shape_len: int = 2, profile: str = "2d") -> P:
    """Token batches: batch axis over ('pod','data') when pods exist;
    the fsdp profile spreads batch over every axis."""
    if profile == "fsdp":
        axes = (("pod", "data", "model") if "pod" in mesh.axis_names
                else ("data", "model"))
    else:
        axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    return P(axes, *([None] * (shape_len - 1)))


def batch_shardings(mesh: Mesh, batch_shape, profile: str = "2d") -> Any:
    def spec(leaf):
        b = leaf.shape[0]
        for prof in ((profile, "2d") if profile != "2d" else ("2d",)):
            cand = batch_spec(mesh, len(leaf.shape), prof)
            n = _axis_size(mesh, cand[0]) if cand[0] else 1
            if b % n == 0:
                return NamedSharding(mesh, cand)
        return NamedSharding(mesh, P(*([None] * len(leaf.shape))))
    return jax.tree_util.tree_map(spec, batch_shape)


def _dp_size(mesh: Mesh) -> int:
    size = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        size *= mesh.shape["pod"]
    return size


# ------------------------------------------------------------------ caches
def cache_spec(path, leaf, mesh: Mesh, batch: int) -> P:
    """KV/SSM cache sharding.

    Batch shards over data when divisible; otherwise (long_500k batch=1)
    the sequence axis of KV caches shards over data instead.
    """
    ps = _path_str(path)
    ndim = leaf.ndim
    dp = mesh.shape["data"]
    batch_ok = batch % dp == 0
    in_body = ps.startswith("body/") or "/body/" in ps

    if re.search(r"(self|cross)/[kv]$", ps):  # (B, S, Hkv, hd)
        spec = (("data" if batch_ok else None),
                (None if batch_ok else "data"), "model", None)
    elif re.search(r"self/conv$", ps):        # (B, k-1, di)
        spec = (("data" if batch_ok else None), None, "model")
    elif re.search(r"self/ssm$", ps):         # (B, di, st)
        spec = (("data" if batch_ok else None), "model", None)
    else:
        spec = ()
    if in_body:
        spec = (None,) + tuple(spec)
    spec = (tuple(spec) + (None,) * ndim)[:ndim]
    return _fix_spec(spec, leaf.shape, mesh,
                     lock_dims=(0,) if in_body else ())


def cache_shardings(mesh: Mesh, cache_shape, batch: int) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, cache_spec(path, leaf, mesh, batch)), cache_shape)


# -------------------------------------------------- activation constraints
# GSPMD drops propagated shardings inside nested scan/while bodies (observed
# in §Perf iteration 1: fully-replicated global-batch attention logits being
# all-reduced per block). Production JAX frameworks pin every major
# activation with with_sharding_constraint; these hooks do the same. The
# context is set at trace time (dryrun/train drivers); without it the model
# is constraint-free (the paper-faithful baseline + single-device tests).

_ACT_CTX: Optional[Tuple[Mesh, str]] = None


@contextmanager
def activation_sharding(mesh: Mesh, profile: str = "2d"):
    global _ACT_CTX
    old = _ACT_CTX
    _ACT_CTX = (mesh, profile)
    try:
        yield
    finally:
        _ACT_CTX = old


def _dp_axes(mesh: Mesh, profile: str):
    if profile == "fsdp":
        return tuple(mesh.axis_names)  # batch over every axis
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _act_spec(kind: str, mesh: Mesh, profile: str) -> Optional[P]:
    dp = _dp_axes(mesh, profile)
    if profile == "ep":
        # expert-parallel: batch over data only; experts own 'model';
        # attention replicated across 'model' (heads rarely divide 16);
        # logits vocab-sharded over 'model'.
        if kind == "btd":
            return P(dp, None, None)
        if kind == "bshd":
            return P(dp, None, None, None)
        if kind == "btv":
            return P(dp, None, "model")
        if kind == "btf":
            return P(dp, None, None)
        if kind == "ecd":
            return P("model", None, None)
        if kind == "te":
            return P(dp, None)
        return None
    tp = None if profile == "fsdp" else "model"
    if kind == "btd":     # (B, S, D) hidden states
        return P(dp, None, None)
    if kind == "bshd":    # (B, S, H, Dh) attention heads
        return P(dp, None, tp, None)
    if kind == "btv":     # (B, S, V) logits
        return P(dp, None, tp)
    if kind == "btf":     # (B, S, F) ffn / mamba inner
        return P(dp, None, tp)
    if kind == "ecd":     # (E, C, D) MoE expert buffers
        return P(tp, dp if profile == "fsdp" else None, None)
    if kind == "te":      # (T, E) router logits/probs
        return P(dp, None)
    return None


def constrain(x, kind: str):
    """Pin an activation's sharding (no-op outside a sharding context)."""
    if _ACT_CTX is None:
        return x
    mesh, profile = _ACT_CTX
    spec = _act_spec(kind, mesh, profile)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec))


# ----------------------------------------------- data-parallel GNN (PR 10)
# The mesh train step's scheme is deliberately simpler than the LM rules
# above: every model/optimizer leaf replicates (P()), every batch leaf
# shards its leading shard axis over the 1-D "data" mesh. The loader's
# ``stack_batches`` produces exactly that leading axis.

def replicated_shardings(mesh: Mesh, tree: Any) -> Any:
    """NamedSharding(P()) for every leaf — params/opt state on a data mesh."""
    return jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), tree)


def data_batch_spec(leaf, axis_name: str = "data") -> P:
    """Leading-axis shard spec for one stacked-batch leaf."""
    return P(axis_name, *([None] * (jnp.ndim(leaf) - 1)))


def data_batch_shardings(mesh: Mesh, batch: Any,
                         axis_name: str = "data") -> Any:
    """Shard every stacked-batch leaf's leading shard axis over the mesh."""
    return jax.tree_util.tree_map(
        lambda leaf: NamedSharding(mesh, data_batch_spec(leaf, axis_name)),
        batch)


# ------------------------------------------------------------- train state
def state_shardings(mesh: Mesh, state_shape, profile: str = "2d") -> Any:
    """TrainState sharding: params/mu/nu share param specs; step replicated."""
    from repro.train.optimizer import TrainState
    return TrainState(
        step=NamedSharding(mesh, P()),
        params=param_shardings(mesh, state_shape.params, profile),
        mu=param_shardings(mesh, state_shape.mu, profile),
        nu=param_shardings(mesh, state_shape.nu, profile),
    )
