"""Elastic scaling + straggler mitigation (1000+-node operability).

* ``StragglerMonitor`` — per-host EMA step times with robust (median/MAD)
  outlier detection; emits mitigation decisions (re-balance the slow host's
  data shard, or evict + trigger an elastic restart).
* ``reshard_state`` — move a live TrainState onto a new mesh (the in-memory
  half of elastic restart; the on-disk half is checkpoint.restore with a new
  mesh).
* ``ElasticController`` — glue: on a detected failure, shrink the mesh,
  reshard from the last checkpoint, and continue (tested in
  tests/test_distributed.py by simulated host loss).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import numpy as np


@dataclasses.dataclass
class Mitigation:
    kind: str          # 'none' | 'rebalance' | 'evict'
    host: Optional[int] = None
    factor: float = 1.0


class StragglerMonitor:
    """Robust straggler detection over per-host step times."""

    def __init__(self, num_hosts: int, ema: float = 0.7,
                 slow_factor: float = 1.5, evict_factor: float = 3.0,
                 min_steps: int = 5):
        self.num_hosts = num_hosts
        self.ema = ema
        self.slow_factor = slow_factor
        self.evict_factor = evict_factor
        self.min_steps = min_steps
        self.times: Dict[int, float] = {}
        self.counts: Dict[int, int] = {h: 0 for h in range(num_hosts)}

    def record(self, host: int, step_time: float) -> None:
        prev = self.times.get(host)
        self.times[host] = (step_time if prev is None
                            else self.ema * prev + (1 - self.ema) * step_time)
        self.counts[host] += 1

    def check(self) -> Mitigation:
        if len(self.times) < self.num_hosts or min(
                self.counts.values()) < self.min_steps:
            return Mitigation("none")
        vals = np.array([self.times[h] for h in range(self.num_hosts)])
        med = np.median(vals)
        worst = int(np.argmax(vals))
        ratio = vals[worst] / max(med, 1e-9)
        if ratio >= self.evict_factor:
            return Mitigation("evict", host=worst, factor=float(ratio))
        if ratio >= self.slow_factor:
            return Mitigation("rebalance", host=worst, factor=float(ratio))
        return Mitigation("none")

    def rebalanced_shares(self) -> np.ndarray:
        """Data shares inversely proportional to host speed (work stealing)."""
        vals = np.array([self.times.get(h, 1.0)
                         for h in range(self.num_hosts)])
        inv = 1.0 / np.maximum(vals, 1e-9)
        return inv / inv.sum()


def reshard_state(state, new_shardings):
    """Move a live state pytree onto new shardings (new mesh)."""
    return jax.tree_util.tree_map(
        lambda a, s: jax.device_put(np.asarray(a), s), state, new_shardings)


def elastic_resize(ckpt_dir, abstract_state, mesh, *,
                   step: Optional[int] = None):
    """Restore a replicated train state onto a differently-sized mesh.

    The N->M data-parallel resize half of PR 10: params/optimizer state are
    replicated (spec ``P()``) on every mesh, so a checkpoint written on an
    N-device mesh restores *bit-identical* onto an M-device one — only the
    replica count changes. Per-device compressor residuals are NOT part of
    the checkpointed state; callers restart error feedback from zeros after
    a resize (``MeshTrainer.restore`` does). Returns ``(state, step)``.
    """
    from repro.distributed import checkpoint as ckpt
    from repro.distributed.sharding import replicated_shardings
    if step is None:
        step = ckpt.latest_step(ckpt_dir)
        if step is None:
            raise RuntimeError(f"no checkpoint to resize from in {ckpt_dir}")
    shardings = replicated_shardings(mesh, abstract_state)
    state = ckpt.restore_checkpoint(ckpt_dir, step, abstract_state,
                                    mesh=mesh, shardings=shardings)
    return state, step


class ElasticController:
    """Orchestrates evict -> shrink mesh -> restore -> continue."""

    def __init__(self, make_mesh_fn, make_shardings_fn):
        self.make_mesh = make_mesh_fn
        self.make_shardings = make_shardings_fn

    def recover(self, ckpt_dir, abstract_state, new_num_hosts: int):
        from repro.distributed import checkpoint as ckpt
        mesh = self.make_mesh(new_num_hosts)
        shardings = self.make_shardings(mesh, abstract_state)
        step = ckpt.latest_step(ckpt_dir)
        if step is None:
            raise RuntimeError("no checkpoint to recover from")
        state = ckpt.restore_checkpoint(ckpt_dir, step, abstract_state,
                                        mesh=mesh, shardings=shardings)
        return mesh, state, step
