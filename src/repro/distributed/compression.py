"""Gradient compression with error feedback for the data-parallel all-reduce.

Two compressors (1-bit-Adam / EF-SGD family, Seide et al.):

  * **int8** — error-feedback int8 quantisation: gradients quantise to int8
    with a per-tensor scale before the cross-device all-reduce; the
    quantisation residual carries to the next step so the compression is
    unbiased in the long run. On the wire this cuts gradient traffic 4x
    (fp32 -> int8).
  * **topk** — error-feedback top-k sparsification: only the ``k`` largest-
    magnitude entries per tensor (``k = ceil(ratio * size)``) travel as
    (values, indices) pairs; unsent mass accumulates in the residual. At
    ``ratio=1.0`` the compressor is lossless — the mechanism-parity tests
    pin the compressed all-reduce against the plain ``psum`` at <=1e-5.

The mesh entry point is :func:`compressed_allreduce`: called *inside* the
``shard_map``'d train step between the local gradient and the optimizer
update, it compresses the local grads, moves the compressed payload with
``jax.lax.all_gather`` over the data axis (int8 / sparse payloads cannot
``psum`` directly — summing int8 overflows and top-k indices differ per
device), decompresses and sums on every device, and returns the summed
gradients plus the new per-device residual. The collective traffic is the
*compressed* payload — ``launch/jaxpr_stats.collective_bytes`` counts the
difference, and ``benchmarks/dist_scaling.py`` records compressed vs raw
bytes per step.

Off by default; enabled via ``MeshTrainer(compression=...)`` in
``launch/train.py``. Correctness properties (round-trip bounds, telescoping
error feedback, compressed-vs-raw step parity) are tested in
``tests/test_mesh_scaleout.py`` and ``tests/test_distributed.py``.
"""

from __future__ import annotations

import math
from typing import Any, Tuple

import jax
import jax.numpy as jnp

COMPRESSION_METHODS = ("int8", "topk")


# ------------------------------------------------------------------- int8
def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


# ------------------------------------------------------------------- topk
def _topk_k(size: int, ratio: float) -> int:
    return max(1, min(size, int(-(-size * float(ratio)) // 1)))


def topk_compress(x: jnp.ndarray, k: int
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(values, indices) of the ``k`` largest-|x| entries of ``x.ravel()``."""
    flat = x.reshape(-1)
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    return flat[idx], idx.astype(jnp.int32)


def topk_decompress(values: jnp.ndarray, indices: jnp.ndarray,
                    shape, dtype=jnp.float32) -> jnp.ndarray:
    out = jnp.zeros(math.prod(shape), dtype)
    return out.at[indices].add(values).reshape(shape)


# --------------------------------------------------------- local EF payload
def compress_grads(grads: Any, residual: Any, *, method: str = "int8",
                   ratio: float = 0.01) -> Tuple[Any, Any]:
    """(grads + residual) -> compressed payload; returns (payload, residual').

    The payload is a pair of trees: ``(q, scale)`` for int8, ``(values,
    indices)`` for topk. The new residual is exactly the compression error
    ``(g + r) - decompress(payload)`` — error feedback telescopes, so the
    *cumulative* applied gradient tracks the true sum.
    """
    if method not in COMPRESSION_METHODS:
        raise ValueError(f"method must be one of {COMPRESSION_METHODS}, "
                         f"got {method!r}")
    leaves_g, treedef = jax.tree_util.tree_flatten(grads)
    leaves_r = jax.tree_util.tree_leaves(residual)
    a_leaves, b_leaves, r_leaves = [], [], []
    for g, r in zip(leaves_g, leaves_r):
        gf = g.astype(jnp.float32) + r
        if method == "int8":
            a, b = quantize_int8(gf)
            deq = dequantize_int8(a, b)
        else:
            a, b = topk_compress(gf, _topk_k(gf.size, ratio))
            deq = topk_decompress(a, b, gf.shape)
        a_leaves.append(a)
        b_leaves.append(b)
        r_leaves.append(gf - deq)
    unf = jax.tree_util.tree_unflatten
    return ((unf(treedef, a_leaves), unf(treedef, b_leaves)),
            unf(treedef, r_leaves))


def decompress_grads(payload: Any, grads_like: Any, *,
                     method: str = "int8") -> Any:
    a_tree, b_tree = payload
    if method == "int8":
        return jax.tree_util.tree_map(
            lambda q, s, g: dequantize_int8(q, s).astype(g.dtype),
            a_tree, b_tree, grads_like)
    return jax.tree_util.tree_map(
        lambda v, i, g: topk_decompress(v, i, g.shape).astype(g.dtype),
        a_tree, b_tree, grads_like)


def init_residual(grads_like: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


# ------------------------------------------------------- mesh all-reduce
def compressed_allreduce(grads: Any, residual: Any, *, axis_name: str,
                         method: str = "int8", ratio: float = 0.01
                         ) -> Tuple[Any, Any]:
    """Compressed cross-device gradient **sum** inside a ``shard_map`` body.

    Per leaf: compress the local ``grad + residual``, ``all_gather`` the
    compressed payload over ``axis_name`` (the only collective on the
    gradient path — its operands are the int8/sparse payload, so the wire
    traffic is the compressed size), then decompress-and-sum every shard's
    contribution locally. All devices hold identical sums afterwards, so
    the optimizer update stays replicated. Returns ``(summed_grads,
    new_residual)``; the residual is per-device state.
    """
    payload, new_residual = compress_grads(grads, residual, method=method,
                                           ratio=ratio)
    a_tree, b_tree = payload
    ga = jax.tree_util.tree_map(
        lambda a: jax.lax.all_gather(a, axis_name), a_tree)
    gb = jax.tree_util.tree_map(
        lambda b: jax.lax.all_gather(b, axis_name), b_tree)

    if method == "int8":
        def leaf_sum(q_all, s_all, g):
            # (D, *shape) int8 + (D,) scales -> sum of dequantised shards
            return jnp.einsum(
                "d...,d->...", q_all.astype(jnp.float32),
                s_all.reshape(-1).astype(jnp.float32)).astype(g.dtype)
    else:
        def leaf_sum(v_all, i_all, g):
            dense = jnp.zeros(g.size, jnp.float32)
            dense = dense.at[i_all.reshape(-1)].add(v_all.reshape(-1))
            return dense.reshape(g.shape).astype(g.dtype)

    summed = jax.tree_util.tree_map(leaf_sum, ga, gb, grads)
    return summed, new_residual


def payload_nbytes(grads_like: Any, *, method: str = "int8",
                   ratio: float = 0.01) -> int:
    """Host-side estimate of one device's compressed payload size."""
    total = 0
    for g in jax.tree_util.tree_leaves(grads_like):
        if method == "int8":
            total += g.size + 4                    # int8 + fp32 scale
        else:
            total += _topk_k(g.size, ratio) * 8    # fp32 value + int32 index
    return total
