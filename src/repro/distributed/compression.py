"""Gradient compression with error feedback (distributed-optimization trick).

Error-feedback int8 quantisation (1-bit-Adam family, Seide et al. / EF-SGD):
gradients are quantised to int8 with a per-tensor scale before the cross-pod
(DCN) all-reduce; the quantisation residual is carried to the next step so
the compression is unbiased in the long run. On the wire this cuts the pod-
boundary gradient traffic 4x (bf16->int8 would be 2x; fp32->int8 is 4x).

Off by default; enabled via OptConfig-style flag in the train loop. The
correctness property (training converges to the same loss neighbourhood) is
tested in tests/test_distributed.py.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_grads(grads: Any, residual: Any) -> Tuple[Any, Any]:
    """(grads + residual) -> int8 payload; returns (payload, new_residual)."""
    leaves_g, treedef = jax.tree_util.tree_flatten(grads)
    leaves_r = jax.tree_util.tree_leaves(residual)
    qs, ss, rs = [], [], []
    for g, r in zip(leaves_g, leaves_r):
        gf = g.astype(jnp.float32) + r
        q, s = quantize_int8(gf)
        qs.append(q)
        ss.append(s)
        rs.append(gf - dequantize_int8(q, s))
    payload = (jax.tree_util.tree_unflatten(treedef, qs),
               jax.tree_util.tree_unflatten(treedef, ss))
    return payload, jax.tree_util.tree_unflatten(treedef, rs)


def decompress_grads(payload: Any, grads_like: Any) -> Any:
    q_tree, s_tree = payload
    return jax.tree_util.tree_map(
        lambda q, s, g: dequantize_int8(q, s).astype(g.dtype),
        q_tree, s_tree, grads_like)


def init_residual(grads_like: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)
