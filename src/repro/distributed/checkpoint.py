"""Sharded checkpointing with atomic commits and elastic resharding.

Design (scales to 1000+ nodes):
  * one ``.npz`` shard file per host (here: one host) + a JSON manifest;
  * writes go to ``step_N.tmp/`` then an atomic ``rename`` to ``step_N/``
    — a crashed writer never corrupts the latest checkpoint;
  * ``restore(..., mesh=new_mesh)`` re-shards onto a *different* topology
    (elastic restart after node loss): arrays are loaded host-side and
    ``device_put`` with the new mesh's NamedShardings;
  * ``keep`` retention + ``latest_step`` resume discovery;
  * optional async write thread (checkpoint I/O overlaps training).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

from repro.train.optimizer import TrainState

_SEP = "::"


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(p.key) if hasattr(p, "key") else
            (p.name if hasattr(p, "name") else str(p.idx)) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(ckpt_dir, step: int, state: Any, *, keep: int = 3,
                    async_write: bool = False) -> Optional[threading.Thread]:
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    flat = _flatten(state)  # host-side copy happens before returning

    def write():
        tmp = ckpt_dir / f"step_{step}.tmp"
        final = ckpt_dir / f"step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir()
        np.savez(tmp / "shard_0.npz", **flat)
        (tmp / "manifest.json").write_text(json.dumps({
            "step": step, "num_shards": 1,
            "keys": sorted(flat.keys())}))
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic commit
        # retention
        steps = sorted(all_steps(ckpt_dir))
        for s in steps[:-keep]:
            shutil.rmtree(ckpt_dir / f"step_{s}", ignore_errors=True)

    if async_write:
        th = threading.Thread(target=write, daemon=True)
        th.start()
        return th
    write()
    return None


def all_steps(ckpt_dir) -> list:
    ckpt_dir = Path(ckpt_dir)
    out = []
    for p in ckpt_dir.glob("step_*"):
        if p.name.endswith(".tmp") or not (p / "manifest.json").exists():
            continue  # incomplete/crashed write — ignored by design
        out.append(int(p.name.split("_")[1]))
    return sorted(out)


def latest_step(ckpt_dir) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore_checkpoint(ckpt_dir, step: int, abstract_state: Any, *,
                       mesh=None, shardings=None) -> Any:
    """Load + (re)shard. ``abstract_state`` supplies the pytree structure.

    With ``mesh``/``shardings`` the arrays are placed sharded — pass a
    *different* mesh than the writer used for an elastic restart.
    """
    path = Path(ckpt_dir) / f"step_{step}"
    data = np.load(path / "shard_0.npz")
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(abstract_state)
    out = []
    for kpath, leaf in leaves_p:
        key = _SEP.join(
            str(p.key) if hasattr(p, "key") else
            (p.name if hasattr(p, "name") else str(p.idx)) for p in kpath)
        arr = data[key]
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        out.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, out)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda a, s: jax.device_put(a, s), tree, shardings)
    return tree
