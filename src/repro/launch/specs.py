"""Input / state ShapeDtypeStruct specs for every (arch x shape) cell.

Nothing here allocates device memory: batches are ShapeDtypeStructs and the
model/cache/optimizer trees come from ``jax.eval_shape`` over the real
constructors (weak-type-correct stand-ins, shardable, zero allocation).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.nn.lm import model as model_lib
from repro.nn.lm.config import ModelConfig
from repro.train import optimizer as opt_lib


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524_288, 1),
}


def cell_applicable(cfg: ModelConfig, shape: ShapeCell) -> Optional[str]:
    """None if the cell runs; else a reason string for the skip."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return ("full-attention architecture: 500k dense-attention decode "
                "has no sub-quadratic mechanism (DESIGN.md §Arch-applicability)")
    return None


def opt_config_for(cfg: ModelConfig) -> opt_lib.OptConfig:
    """>300B archs use bf16 moments so one pod's HBM holds the train state."""
    huge = cfg.param_count() > 100e9
    return opt_lib.OptConfig(moment_dtype="bfloat16" if huge else "float32")


def input_specs(arch: str, shape_name: str) -> Dict[str, Any]:
    """Batch ShapeDtypeStructs for one cell (tokens / stubs / decode token)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    dt = cfg.jnp_dtype
    batch: Dict[str, Any] = {}
    if shape.kind in ("train", "prefill"):
        s_tok = s - cfg.n_prefix_embeds
        batch["tokens"] = jax.ShapeDtypeStruct((b, s_tok), i32)
        if cfg.n_prefix_embeds:
            batch["prefix_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_prefix_embeds, cfg.d_model), dt)
        if cfg.arch_type == "encdec":
            batch["enc_in"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), dt)
    else:  # decode: one new token against a seq_len cache
        batch["tokens"] = jax.ShapeDtypeStruct((b, 1), i32)
    return batch


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(
        lambda k: model_lib.init_model(k, cfg),
        jax.ShapeDtypeStruct((2,), jnp.uint32))


def abstract_state(cfg: ModelConfig):
    params = abstract_params(cfg)
    ocfg = opt_config_for(cfg)
    return jax.eval_shape(
        functools.partial(opt_lib.init_state, cfg=ocfg), params), ocfg


def abstract_cache(cfg: ModelConfig, shape: ShapeCell):
    enc_len = shape.seq_len if cfg.arch_type == "encdec" else 0
    return jax.eval_shape(
        functools.partial(model_lib.make_cache, cfg, shape.global_batch,
                          shape.seq_len, enc_len=enc_len))


# ------------------------------------------------------------ model flops
def model_flops(cfg: ModelConfig, shape: ShapeCell) -> float:
    """MODEL_FLOPS = 6*N_active*D (+ causal attention term), PaLM-style."""
    n_active = cfg.active_param_count()
    emb = cfg.vocab_size * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    n = n_active - emb + cfg.vocab_size * cfg.d_model  # lm_head matmul counts
    b, s = shape.global_batch, shape.seq_len
    layers = ([cfg.layer_desc(0, True)] * cfg.n_head_layers
              + cfg.period_descs * cfg.n_periods)
    n_attn = sum(1 for m, _ in layers if m == "attn")
    if cfg.arch_type == "encdec":
        n_attn += cfg.n_enc_layers + cfg.n_layers  # enc self + dec cross
    hq = cfg.n_heads * cfg.head_dim
    if shape.kind == "train":
        tokens = b * s
        attn = 6 * n_attn * hq * (s / 2) * tokens  # causal avg S/2, fwd+bwd x3
        return 6.0 * n * tokens + 2 * attn
    if shape.kind == "prefill":
        tokens = b * s
        attn = 4 * n_attn * hq * (s / 2) * tokens
        return 2.0 * n * tokens + attn
    # decode: one token vs full cache
    tokens = b * 1
    attn = 4 * n_attn * hq * shape.seq_len * tokens
    return 2.0 * n * tokens + attn
