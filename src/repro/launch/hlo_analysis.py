"""Trip-count-aware collective accounting from optimized HLO text.

GSPMD hoists loop-invariant collectives (the FSDP param all-gathers of
scan-stacked weights) into ENTRY, but per-layer tensor-parallel collectives
stay inside ``while`` bodies and execute once per scan iteration. This
walker parses the HLO into computations, finds every ``while``, reads the
trip count out of its condition computation (the loop-bound constant), and
weights collective payload bytes by the product of enclosing trip counts.
"""

from __future__ import annotations

import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1, "u8": 1,
    "pred": 1, "s64": 8, "u64": 8, "f64": 8, "s16": 2, "u16": 2,
    "f8e4m3": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(
    r"\b(f32|bf16|f16|f64|s64|s32|s16|s8|u64|u32|u16|u8|pred|f8e4m3|f8e5m2|"
    r"c64|c128)\[([0-9,]*)\]")
_WHILE_RE = re.compile(
    r"while\(.*?condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_COLL_RE = re.compile(
    r"=\s+[^=]*?\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(-start)?\(")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo.splitlines():
        if not line.startswith(" ") and "{" in line and ("(" in line):
            head = line.strip()
            if head.startswith("ENTRY"):
                name = head.split()[1]
            else:
                name = head.split("(")[0].strip()
            cur = name.lstrip("%").rstrip()
            comps.setdefault(cur, [])
        elif cur is not None:
            comps[cur].append(line)
    return comps


def _entry_name(hlo: str) -> str:
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            return line.split()[1].lstrip("%").split("(")[0]
    return "main"


def _trip_count(cond_lines: List[str]) -> int:
    """Loop bound = the largest integer constant in the condition."""
    best = 1
    for l in cond_lines:
        for m in _CONST_RE.finditer(l):
            best = max(best, int(m.group(1)))
    return best


def collective_stats(hlo: str) -> Dict[str, Dict[str, float]]:
    comps = parse_computations(hlo)
    entry = _entry_name(hlo)
    out = {c: {"count": 0, "bytes": 0.0} for c in COLLECTIVES}
    seen = set()

    def walk(comp: str, mult: float):
        if comp not in comps:
            return
        key = (comp, mult)
        if key in seen:  # guard against pathological recursion
            return
        seen.add(key)
        for line in comps[comp]:
            s = line.strip()
            wm = _WHILE_RE.search(s)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                trip = _trip_count(comps.get(cond, []))
                walk(body, mult * trip)
                continue
            cm = _COLL_RE.search(s)
            if cm and not s.split("=")[1].strip().startswith("get-tuple"):
                op, start = cm.group(1), cm.group(2)
                if start == "-done":
                    continue
                shapes = _SHAPE_RE.findall(s)
                if not shapes:
                    continue
                lhs, rhs = shapes[0], shapes[1:]
                operands = rhs if rhs else [lhs]
                nbytes = sum(_shape_bytes(dt, dims) for dt, dims in operands)
                out[op]["count"] += mult
                out[op]["bytes"] += mult * nbytes

    # find matching entry computation key (suffix variations)
    entry_key = None
    for k in comps:
        if k.startswith(entry):
            entry_key = k
            break
    walk(entry_key or entry, 1.0)
    return out
