"""Production meshes for the multi-pod dry-run (and real deployments).

Functions, not module-level constants: importing this module never touches
jax device state (device count is locked at first jax init, and only
``dryrun.py`` forces 512 host devices).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — tests only."""
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants (per chip) used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # bytes/s
ICI_BW = 50e9                 # bytes/s per link
