"""Mesh construction on the modern ``jax.sharding.Mesh`` API.

Functions, not module-level constants: importing this module never touches
jax device state (device count is locked at first jax init; only
``dryrun.py`` forces 512 host devices, and CPU testing of the data-parallel
path forces a small count via ``XLA_FLAGS`` — see :func:`host_device_flag`).

The data-parallel GNN scale-out (PR 10) builds 1-D ``("data",)`` meshes via
:func:`data_parallel_mesh`; the LM dry-run keeps its 2-D/3-D production
shapes. All constructors go through :func:`make_mesh`, which builds a
``jax.sharding.Mesh`` from an explicit device array — the stale
``jax.make_mesh``-era helpers required the mesh to cover *every* visible
device, which breaks the 1/2/4/8-device scaling sweeps run inside one
forced-8-device host process.
"""

from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

HOST_DEVICE_FLAG = "--xla_force_host_platform_device_count"


def host_device_flag(n: int) -> str:
    """The ``XLA_FLAGS`` fragment that forces ``n`` host (CPU) devices.

    Must be set in the environment *before* jax initialises its backends;
    the CPU mesh tests and ``benchmarks/dist_scaling.py`` use it to emulate
    an ``n``-device data-parallel mesh on one host.
    """
    return f"{HOST_DEVICE_FLAG}={n}"


def make_mesh(shape: Sequence[int], axes: Sequence[str], *,
              devices: Optional[Sequence] = None) -> Mesh:
    """A ``jax.sharding.Mesh`` of ``shape`` over the first devices.

    Unlike the all-devices-only convenience constructor, a sub-mesh over a
    prefix of ``jax.devices()`` is allowed — the scaling benchmark builds
    1/2/4/8-device meshes inside a single forced-8-device process.
    """
    shape = tuple(int(s) for s in shape)
    axes = tuple(axes)
    if len(shape) != len(axes):
        raise ValueError(f"mesh shape {shape} and axes {axes} disagree")
    need = math.prod(shape)
    if devices is None:
        devices = jax.devices()
    if len(devices) < need:
        raise ValueError(
            f"mesh shape {shape} needs {need} devices but only "
            f"{len(devices)} are visible; on CPU, relaunch with "
            f"XLA_FLAGS={host_device_flag(need)} (set before jax "
            f"initialises) to emulate a {need}-device host platform")
    dev = np.asarray(devices[:need], dtype=object).reshape(shape)
    return Mesh(dev, axes)


def data_parallel_mesh(num_devices: Optional[int] = None,
                       axis_name: str = "data") -> Mesh:
    """1-D data-parallel mesh over ``num_devices`` (default: all) devices.

    This is the mesh the ``shard_map``'d GNN train step runs on: loader
    batches shard along the leading (shard) axis, parameters replicate,
    gradients reduce with one fused ``psum`` over ``axis_name``.
    """
    if num_devices is None:
        num_devices = len(jax.devices())
    return make_mesh((num_devices,), (axis_name,))


def make_local_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small 2-D mesh over however many (host) devices exist — tests only."""
    return make_mesh((data, model), ("data", "model"))


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape: Tuple[int, ...] = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


# TPU v5e hardware constants (per chip) used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # bytes/s
ICI_BW = 50e9                 # bytes/s per link
