import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the production mesh (16x16 single-pod or 2x16x16
two-pod), the sharded step function (train / prefill / decode per the shape
kind), lowers it against pure ShapeDtypeStruct inputs, compiles, and records:

  * ``memory_analysis()``   — per-device bytes (proves it fits)
  * ``cost_analysis()``     — HLO FLOPs / bytes for the roofline
  * collective byte counts  — parsed from the optimized HLO text
  * the three roofline terms + dominant bottleneck (§Roofline)

Usage:
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results/dryrun]
"""

import argparse
import json
import re
import time
import traceback
from contextlib import nullcontext as _nullcontext
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, canonical, get_config
from repro.distributed import sharding as shard_lib
from repro.launch import hlo_analysis, jaxpr_stats
from repro.launch import specs as specs_lib
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh)
from repro.train import steps as steps_lib

_DTYPE_BYTES = {
    "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1, "u8": 1,
    "pred": 1, "s64": 8, "u64": 8, "f64": 8, "s16": 2, "u16": 2, "f8e4m3": 1,
    "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b(f32|bf16|f16|f64|s64|s32|s16|s8|u64|u32|u16|u8|"
                       r"pred|f8e4m3|f8e5m2)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def parse_collectives(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in the optimized HLO."""
    out = {c: {"count": 0, "bytes": 0} for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.search(r"=\s+[^ ]+\s+([a-z0-9-]+)\(", stripped)
        if not m:
            continue
        op = m.group(1)
        # normalise start/done pairs (async collectives) to the base op;
        # count only the -start (or the sync form) to avoid double counting.
        base = op.replace("-start", "")
        if base not in _COLLECTIVES or op.endswith("-done"):
            continue
        shapes = _SHAPE_RE.findall(stripped)
        if not shapes:
            continue
        lhs, rhs = shapes[0], shapes[1:]
        operands = rhs if rhs else [lhs]
        nbytes = sum(_shape_bytes(dt, dims) for dt, dims in operands)
        out[base]["count"] += 1
        out[base]["bytes"] += nbytes
    return out


def build_step(arch: str, shape_name: str, mesh, profile: str = "2d",
               remat=True):
    """Returns (jit_fn, abstract_args) for the cell."""
    cfg = get_config(arch)
    shape = specs_lib.SHAPES[shape_name]
    batch = specs_lib.input_specs(arch, shape_name)
    batch_sh = shard_lib.batch_shardings(mesh, batch, profile)

    if shape.kind == "train":
        state, ocfg = specs_lib.abstract_state(cfg)
        state_sh = shard_lib.state_shardings(mesh, state, profile)
        fn = steps_lib.make_train_step(cfg, ocfg, remat=remat)
        jit_fn = jax.jit(fn, in_shardings=(state_sh, batch_sh),
                         out_shardings=(state_sh, None), donate_argnums=(0,))
        return jit_fn, (state, batch)

    params = specs_lib.abstract_params(cfg)
    params_sh = shard_lib.param_shardings(mesh, params, profile)
    cache = specs_lib.abstract_cache(cfg, shape)
    cache_sh = shard_lib.cache_shardings(mesh, cache, shape.global_batch)

    if shape.kind == "prefill":
        fn = steps_lib.make_prefill_step(cfg)
        jit_fn = jax.jit(fn, in_shardings=(params_sh, batch_sh, cache_sh),
                         out_shardings=(None, cache_sh), donate_argnums=(2,))
        return jit_fn, (params, batch, cache)

    fn = steps_lib.make_decode_step(cfg)
    tok = batch["tokens"]
    tok_sh = shard_lib.batch_shardings(mesh, {"t": tok})["t"]
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    pos_sh = NamedSharding(mesh, P())
    jit_fn = jax.jit(fn, in_shardings=(params_sh, tok_sh, cache_sh, pos_sh),
                     out_shardings=(None, cache_sh), donate_argnums=(2,))
    return jit_fn, (params, tok, cache, pos)


def roofline_terms(flops: float, bytes_acc: float, coll: dict,
                   n_chips: int) -> dict:
    """Three roofline terms in seconds.

    flops/bytes are *global* (jaxpr-level), so divide by chips. HLO
    collective payloads are *per-device* shard sizes, so the per-chip link
    time is simply sum(local_payload * ring_factor) / link_bw; we also report
    collective_bytes scaled to global so the prescribed
    ``collective_bytes / (chips * link_bw)`` formula yields the same time.
    Ring all-reduce moves ~2x its payload per link; other collectives ~1x.
    """
    ring = {"all-reduce": 2.0}
    local_link_bytes = sum(
        v["bytes"] * ring.get(name, 1.0) for name, v in coll.items())
    coll_bytes_global = local_link_bytes * n_chips
    t_compute = flops / n_chips / PEAK_FLOPS_BF16
    t_memory = bytes_acc / n_chips / HBM_BW
    t_coll = coll_bytes_global / n_chips / ICI_BW
    dom = max(("compute", t_compute), ("memory", t_memory),
              ("collective", t_coll), key=lambda kv: kv[1])[0]
    return {
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dom,
        "collective_bytes": coll_bytes_global,
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: Path, profile: str = "2d", remat=True) -> dict:
    cfg = get_config(arch)
    shape = specs_lib.SHAPES[shape_name]
    skip = specs_lib.cell_applicable(cfg, shape)
    mesh_tag = "2pod" if multi_pod else "1pod"
    rec = {"arch": cfg.name, "shape": shape_name, "mesh": mesh_tag,
           "profile": profile, "remat": str(remat),
           "status": "skipped", "reason": skip}
    if skip:
        return rec

    n_chips = 512 if multi_pod else 256
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    # activation sharding constraints are part of the optimized profiles;
    # the '2d' baseline stays constraint-free (paper-faithful naive SPMD)
    act_ctx = (shard_lib.activation_sharding(mesh, profile)
               if profile != "2d" else _nullcontext())
    with mesh, act_ctx:
        jit_fn, args = build_step(arch, shape_name, mesh, profile, remat)
        # exact global FLOPs/bytes from the jaxpr (scan-aware; XLA:CPU
        # cost_analysis counts while bodies once — see jaxpr_stats docstring)
        stats = jaxpr_stats.step_stats(jit_fn, *args)
        lowered = jit_fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
    coll = hlo_analysis.collective_stats(hlo)
    flops = float(stats["total_flops"])
    bytes_acc = float(stats["major_bytes"])
    mflops = specs_lib.model_flops(cfg, shape)
    terms = roofline_terms(flops, bytes_acc, coll, n_chips)

    mem_rec = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes", "peak_memory_in_bytes"):
        try:
            mem_rec[attr] = int(getattr(mem, attr))
        except Exception:
            pass

    rec.update(
        status="ok", n_chips=n_chips,
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
        hlo_flops=flops, hlo_bytes=bytes_acc,
        dot_flops=float(stats["dot_flops"]),
        elementwise_flops=float(stats["elementwise_flops"]),
        xla_cost_flops=float(cost.get("flops", 0.0)),
        xla_cost_bytes=float(cost.get("bytes accessed", 0.0)),
        model_flops=mflops,
        useful_ratio=(mflops / flops if flops else None),
        collectives=coll, memory=mem_rec,
        params=cfg.param_count(), active_params=cfg.active_param_count(),
        bytes_per_device=(
            (mem_rec.get("argument_size_in_bytes", 0)
             + mem_rec.get("temp_size_in_bytes", 0)
             - mem_rec.get("alias_size_in_bytes", 0)) / n_chips
            if mem_rec else None),
        **terms,
    )
    return rec


def cells(multi_pod: bool):
    for arch in ARCH_IDS:
        for shape_name in specs_lib.SHAPES:
            yield arch, shape_name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--profile", default="2d", choices=["2d", "fsdp", "ep"])
    ap.add_argument("--remat", default="true",
                    choices=["true", "false", "dots"])
    ap.add_argument("--moe-impl", default="scatter",
                    choices=["scatter", "gather"])
    ap.add_argument("--attn-impl", default="rect", choices=["rect", "tri"])
    args = ap.parse_args()
    remat = {"true": True, "false": False, "dots": "dots"}[args.remat]
    from repro.nn.lm import moe as moe_mod
    moe_mod.set_moe_impl(args.moe_impl)
    from repro.kernels.flash_attention import ops as attn_ops
    attn_ops.set_attention_impl(args.attn_impl)

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    todo = (list(cells(args.multi_pod)) if args.all
            else [(canonical(args.arch), args.shape)])
    for arch, shape_name in todo:
        tag = "2pod" if args.multi_pod else "1pod"
        variant = ""
        if args.profile != "2d":
            variant += f"__{args.profile}"
        if args.remat != "true":
            variant += f"__remat-{args.remat}"
        if args.moe_impl != "scatter":
            variant += f"__moe-{args.moe_impl}"
        if args.attn_impl != "rect":
            variant += f"__attn-{args.attn_impl}"
        path = out_dir / (f"{canonical(arch)}__{shape_name}__{tag}"
                          f"{variant}.json")
        if path.exists() and not args.force:
            print(f"[skip cached] {path.name}")
            continue
        print(f"[run] {arch} x {shape_name} x {tag}{variant}", flush=True)
        try:
            rec = run_cell(arch, shape_name, args.multi_pod, out_dir,
                           profile=args.profile, remat=remat)
        except Exception as e:  # record failures — they are bugs to fix
            rec = {"arch": arch, "shape": shape_name, "mesh": tag,
                   "status": "error", "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
        path.write_text(json.dumps(rec, indent=2))
        status = rec["status"]
        extra = ""
        if status == "ok":
            extra = (f" compile={rec['compile_s']}s dom={rec['dominant']}"
                     f" tc={rec['t_compute_s']:.4f} tm={rec['t_memory_s']:.4f}"
                     f" tl={rec['t_collective_s']:.4f}")
        print(f"[done] {path.name}: {status}{extra}", flush=True)


if __name__ == "__main__":
    main()
