"""Serving driver: prefill + batched decode with a fixed-slot scheduler.

``python -m repro.launch.serve --arch <id> --batch 4 --prompt-len 16
--max-new 32`` runs continuous-batching-lite: a fixed decode batch where
finished sequences (EOS or length) immediately free their slot for the next
queued request — the serving pattern the decode_32k dry-run cells lower.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.nn.lm import model as model_lib
from repro.train import steps


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--num-requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--eos", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=True)
    rng = np.random.default_rng(0)
    params = model_lib.init_model(jax.random.PRNGKey(0), cfg)
    max_len = args.prompt_len + args.max_new + cfg.n_prefix_embeds

    prefill = jax.jit(steps.make_prefill_step(cfg))
    decode = jax.jit(steps.make_decode_step(cfg), donate_argnums=(2,))

    # request queue
    queue = [rng.integers(2, cfg.vocab_size, (args.prompt_len,))
             for _ in range(args.num_requests)]
    done, active = [], []

    t0 = time.perf_counter()
    generated = 0
    while queue or active:
        # (re)fill the batch: prefill a fresh wave of requests
        wave = [queue.pop() for _ in range(min(args.batch, len(queue)))]
        if wave:
            toks = jnp.asarray(np.stack(wave), jnp.int32)
            batch = {"tokens": toks}
            if cfg.arch_type == "encdec":
                batch["enc_in"] = jnp.asarray(rng.standard_normal(
                    (len(wave), args.prompt_len, cfg.d_model)),
                    cfg.jnp_dtype)
            if cfg.n_prefix_embeds:
                batch["prefix_embeds"] = jnp.asarray(rng.standard_normal(
                    (len(wave), cfg.n_prefix_embeds, cfg.d_model)),
                    cfg.jnp_dtype)
            cache = model_lib.make_cache(cfg, len(wave), max_len,
                                         enc_len=args.prompt_len)
            logits, cache = prefill(params, batch, cache)
            cur = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
            pos = args.prompt_len + cfg.n_prefix_embeds
            seqs = [list(w) for w in wave]
            alive = np.ones(len(wave), bool)
            for step in range(args.max_new):
                tok = cur[:, None]
                logits, cache = decode(params, tok, cache,
                                       jnp.asarray(pos, jnp.int32))
                for i in range(len(wave)):
                    if alive[i]:
                        seqs[i].append(int(cur[i]))
                        generated += 1
                        if int(cur[i]) == args.eos or len(
                                seqs[i]) >= args.prompt_len + args.max_new:
                            alive[i] = False  # slot freed for next wave
                if not alive.any():
                    break
                cur = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
                pos += 1
            done.extend(seqs)
    dt = time.perf_counter() - t0
    print(f"served {len(done)} requests, {generated} tokens in {dt:.2f}s "
          f"({generated / max(dt, 1e-9):.1f} tok/s)")
    return done


if __name__ == "__main__":
    main()
