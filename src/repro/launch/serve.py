"""Serving driver: prefill + batched decode with a fixed-slot scheduler.

``python -m repro.launch.serve --arch <id> --batch 4 --prompt-len 16
--max-new 32`` runs continuous-batching-lite: a fixed decode batch where
finished sequences (EOS or length) immediately free their slot for the next
queued request — the serving pattern the decode_32k dry-run cells lower.

``GraphServer`` is the graph-side counterpart (paper §3's production
workloads): a deadline-bounded node-inference endpoint over a
(Feature/Graph)Store pair. Each request samples the seeds' neighborhood,
fetches features under a per-request deadline, and runs one jit'd forward
(one trace across requests — static shapes). When the store is impaired the
answer degrades instead of stalling: features for rows on a tripped
partition come from the resilient store's stale cache (or zeros), the
response is flagged ``degraded``, and latency stays bounded by the deadline
rather than the outage. ``python -m repro.launch.serve --graph-smoke`` runs
a chaos-impaired demo.
"""

from __future__ import annotations

import argparse
import time
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.nn.lm import model as model_lib
from repro.train import steps


class GraphServer:
    """Batched, deadline-bounded GNN inference over store backends.

    ``apply_fn(x, edge_index, seed_slots) -> (B, ...) predictions`` is
    jit-compiled once; requests are padded to ``batch_size`` seeds so every
    call shares the trace — a :class:`RetraceSentinel` instruments the
    entry point (``trace_count`` reads it; ``retrace_budget`` makes an
    unexpected recompile raise with a signature diff instead of silently
    re-tracing per request). ``answer`` never raises on storage faults: it
    returns ``{pred, degraded, latency_s, deadline_s}`` where ``degraded``
    counts feature rows served stale/zero (0 = fully fresh).
    """

    def __init__(self, feature_store, graph_store, apply_fn: Callable, *,
                 num_neighbors: Sequence[int], batch_size: int,
                 deadline_s: Optional[float] = None, seed: int = 0,
                 retrace_budget: Optional[int] = None):
        from repro.analysis.retrace import RetraceSentinel
        from repro.core.edge_index import EdgeIndex
        from repro.data.sampler import NeighborSampler

        self.fs = feature_store
        self.sampler = NeighborSampler(graph_store, num_neighbors, seed=seed)
        self.batch_size = batch_size
        self.deadline_s = deadline_s
        self._edge_index_cls = EdgeIndex

        def traced(x, edge_data, seed_slots, num_nodes):
            ei = EdgeIndex(edge_data, int(num_nodes), int(num_nodes))
            return apply_fn(x, ei, seed_slots)

        self._sentinel = RetraceSentinel(budget=retrace_budget)
        self._apply = self._sentinel.wrap(
            jax.jit(traced, static_argnums=(3,)), name="graph_server.apply")

    @property
    def trace_count(self) -> int:
        """Distinct abstract signatures seen by the jit'd apply (== traces,
        since every padded request shares one signature)."""
        return self._sentinel.count("graph_server.apply")

    def answer(self, seeds: np.ndarray,
               deadline_s: Optional[float] = None) -> dict:
        from repro.data.resilience import StoreError

        t0 = time.perf_counter()
        deadline = self.deadline_s if deadline_s is None else deadline_s
        seeds = np.asarray(seeds, np.int64)
        k = len(seeds)
        if k > self.batch_size:
            raise ValueError(f"request of {k} seeds exceeds batch_size="
                             f"{self.batch_size}")
        padded = np.concatenate(
            [seeds, np.full(self.batch_size - k, seeds[0], np.int64)])
        out = self.sampler.sample(padded)
        fetch = getattr(self.fs, "get_padded_resilient", None)
        degraded = 0
        try:
            if fetch is not None:
                x, dmask = fetch(out.node, group="node", attr="x",
                                 deadline=deadline)
                degraded = int(np.asarray(dmask).sum())
            else:
                x = self.fs.get_padded(out.node, group="node", attr="x")
        except StoreError:
            # nothing fetchable at all: answer fast with zero features
            feat = self.fs.get_tensor_size(group="node", attr="x")[1:]
            x = np.zeros((len(out.node),) + tuple(feat), np.float32)
            degraded = len(out.node)
        pred = self._apply(jnp.asarray(x),
                           jnp.asarray(np.stack([out.row, out.col])),
                           jnp.asarray(out.seed_slots.astype(np.int32)),
                           len(out.node))
        pred = np.asarray(jax.block_until_ready(pred))[:k]
        return {"pred": pred, "degraded": degraded,
                "latency_s": time.perf_counter() - t0,
                "deadline_s": deadline}


def graph_smoke() -> dict:
    """Tiny end-to-end demo: chaos-impaired store, degraded-but-fast answers."""
    from repro.data.partition import build_partitioned_stores
    from repro.data.resilience import (ChaosFeatureStore, FailureSchedule,
                                       ResilientFeatureStore, RetryPolicy)

    rng = np.random.default_rng(0)
    n, feat = 2000, 32
    ei = np.stack([rng.integers(0, n, 8000), rng.integers(0, n, 8000)])
    x = rng.standard_normal((n, feat)).astype(np.float32)
    fs0, gs, _ = build_partitioned_stores(x, ei, 4)
    schedule = FailureSchedule(seed=1, error_rate=0.3,
                               blackout={2: [(10, 40)]})
    fs = ResilientFeatureStore(
        ChaosFeatureStore(fs0, schedule),
        retry=RetryPolicy(max_attempts=3, base_delay=1e-4),
        recovery_time=0.0, deadline=0.25)
    w = jnp.asarray(rng.standard_normal((feat, 4)) * 0.1, jnp.float32)
    server = GraphServer(
        fs, gs, lambda x_, ei_, s: (ei_.matmul(x_) @ w)[s],
        num_neighbors=[5, 5], batch_size=8, deadline_s=0.25)
    stats = {"requests": 0, "degraded": 0, "max_latency_s": 0.0}
    for i in range(24):
        r = server.answer(rng.integers(0, n, 8))
        stats["requests"] += 1
        stats["degraded"] += int(r["degraded"] > 0)
        stats["max_latency_s"] = max(stats["max_latency_s"], r["latency_s"])
    stats["trace_count"] = server.trace_count
    stats["store_health"] = dict(fs.health)
    print(f"graph-smoke: {stats['requests']} requests, "
          f"{stats['degraded']} degraded, trace_count="
          f"{stats['trace_count']}, max_latency="
          f"{stats['max_latency_s'] * 1e3:.1f} ms")
    return stats


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--num-requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--eos", type=int, default=1)
    ap.add_argument("--graph-smoke", action="store_true",
                    help="run the GraphServer degraded-serving demo instead")
    args = ap.parse_args(argv)

    if args.graph_smoke:
        return graph_smoke()

    cfg = get_config(args.arch, smoke=True)
    rng = np.random.default_rng(0)
    params = model_lib.init_model(jax.random.PRNGKey(0), cfg)
    max_len = args.prompt_len + args.max_new + cfg.n_prefix_embeds

    prefill = jax.jit(steps.make_prefill_step(cfg))
    decode = jax.jit(steps.make_decode_step(cfg), donate_argnums=(2,))

    # request queue
    queue = [rng.integers(2, cfg.vocab_size, (args.prompt_len,))
             for _ in range(args.num_requests)]
    done, active = [], []

    t0 = time.perf_counter()
    generated = 0
    while queue or active:
        # (re)fill the batch: prefill a fresh wave of requests
        wave = [queue.pop() for _ in range(min(args.batch, len(queue)))]
        if wave:
            toks = jnp.asarray(np.stack(wave), jnp.int32)
            batch = {"tokens": toks}
            if cfg.arch_type == "encdec":
                batch["enc_in"] = jnp.asarray(rng.standard_normal(
                    (len(wave), args.prompt_len, cfg.d_model)),
                    cfg.jnp_dtype)
            if cfg.n_prefix_embeds:
                batch["prefix_embeds"] = jnp.asarray(rng.standard_normal(
                    (len(wave), cfg.n_prefix_embeds, cfg.d_model)),
                    cfg.jnp_dtype)
            cache = model_lib.make_cache(cfg, len(wave), max_len,
                                         enc_len=args.prompt_len)
            logits, cache = prefill(params, batch, cache)
            cur = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
            pos = args.prompt_len + cfg.n_prefix_embeds
            seqs = [list(w) for w in wave]
            alive = np.ones(len(wave), bool)
            for step in range(args.max_new):
                tok = cur[:, None]
                logits, cache = decode(params, tok, cache,
                                       jnp.asarray(pos, jnp.int32))
                for i in range(len(wave)):
                    if alive[i]:
                        seqs[i].append(int(cur[i]))
                        generated += 1
                        if int(cur[i]) == args.eos or len(
                                seqs[i]) >= args.prompt_len + args.max_new:
                            alive[i] = False  # slot freed for next wave
                if not alive.any():
                    break
                cur = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
                pos += 1
            done.extend(seqs)
    dt = time.perf_counter() - t0
    print(f"served {len(done)} requests, {generated} tokens in {dt:.2f}s "
          f"({generated / max(dt, 1e-9):.1f} tok/s)")
    return done


if __name__ == "__main__":
    main()
