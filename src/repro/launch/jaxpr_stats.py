"""Exact, scan-aware FLOP / HBM-byte accounting from the step jaxpr.

XLA:CPU's ``compiled.cost_analysis()`` counts ``while``/scan bodies once, so
on this container it under-reports the 40-layer models by orders of
magnitude. The jaxpr is the pre-partitioning *global* program with explicit
scan lengths, so walking it yields exact global FLOPs — the numerator the
roofline needs (differentiation is a trace-time transform, so the walked
jaxpr already includes backward + remat recompute).

Byte accounting uses a fusion-aware HBM-traffic model: only ops whose
operands/results must transit HBM on TPU are charged — dots/convs
(operands+outputs), gathers/scatters (output+updates), reduces (operands) —
while elementwise chains are treated as fused into their producers. This is
the standard postfusion traffic approximation (cf. roofline practice in
MaxText/JAX-toolbox perf notes).
"""

from __future__ import annotations

import math
from typing import Any, Dict

import jax
import numpy as np
from jax import core

FLOP_REPORT_KEYS = ("dot_flops", "conv_flops", "elementwise_flops",
                    "pallas_flops", "total_flops", "major_bytes",
                    "collective_bytes", "while_warning")

# Cross-device collectives: per-device wire-traffic model (ring/bidirectional
# approximations — what the compressed-vs-raw all-reduce comparison needs,
# not a topology simulator). psum moves ~2x its operand bytes on a ring;
# all_gather receives (out - in) bytes; reduce_scatter/all_to_all/ppermute
# move their operand bytes once.
_COLLECTIVE_BYTES = {
    "psum": lambda inb, outb: 2 * inb,
    "all_gather": lambda inb, outb: max(outb - inb, 0),
    "reduce_scatter": lambda inb, outb: inb,
    "all_to_all": lambda inb, outb: inb,
    "ppermute": lambda inb, outb: inb,
    "axis_index": lambda inb, outb: 0,
    "pmax": lambda inb, outb: 2 * inb,
    "pmin": lambda inb, outb: 2 * inb,
}


def _nbytes(aval) -> int:
    try:
        return int(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:
        return 0


def _dot_flops(eqn) -> int:
    dn = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dn
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    batch = 1
    for d in lb:
        batch *= lhs.shape[d]
    k = 1
    for d in lc:
        k *= lhs.shape[d]
    m = 1
    for i, s in enumerate(lhs.shape):
        if i not in lc and i not in lb:
            m *= s
    n = 1
    for i, s in enumerate(rhs.shape):
        if i not in rc and i not in rb:
            n *= s
    return 2 * batch * m * n * k


def _conv_flops(eqn) -> int:
    # flops = 2 * out_elements * kernel_spatial * (C_in / groups); the rhs
    # already carries C_in/groups on its input-feature dim, so it's simply
    # 2 * out_elems * prod(rhs_nonoutput_dims).
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    dn = eqn.params["dimension_numbers"]
    out_feat_dim = dn.rhs_spec[0] if hasattr(dn, "rhs_spec") else 0
    kernel_elems = int(np.prod(rhs.shape)) // rhs.shape[out_feat_dim]
    return 2 * int(np.prod(out.shape)) * kernel_elems


def _sub_jaxprs(eqn):
    """(jaxpr, multiplier) children of an eqn, handling scan/cond/etc."""
    name = eqn.primitive.name
    p = eqn.params
    if name == "scan":
        return [(p["jaxpr"], p["length"])], False
    if name == "while":
        return [(p["body_jaxpr"], 1), (p["cond_jaxpr"], 1)], True
    if name == "cond":
        return [(b, 1) for b in p["branches"][:1]], False  # branch max ~ first
    # "fun_jaxpr" is the custom_vjp body: without it the kernel wrappers'
    # forward work would be invisible to the accounting.
    for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
        if key in p and p[key] is not None:
            return [(p[key], 1)], False
    return [], False


# ------------------------------------------------- pallas_call cost models
# The kernel body is opaque to XLA (and to the generic eqn walk), so each
# kernel gets an analytic cost keyed off its function name — the same names
# analysis.dispatch audits. Costs are (flops, hbm_bytes) per launch, read
# off the eqn's operand/result avals.


def _avals(eqn):
    ins = [v.aval for v in eqn.invars if hasattr(v.aval, "shape")]
    outs = [v.aval for v in eqn.outvars if hasattr(v.aval, "shape")]
    return ins, outs


def _ell_table(ins):
    """The (R, K) int32 neighbor table aval (first 2D integer operand)."""
    for a in ins:
        if len(a.shape) == 2 and np.issubdtype(a.dtype, np.integer):
            return a
    return None


def _spmm_ell_cost(eqn):
    ins, outs = _avals(eqn)
    table, out = _ell_table(ins), outs[0]
    r, k = table.shape
    feat = out.shape[-1]
    weighted = any(len(a.shape) == 2 and a.shape == (r, k)
                   and not np.issubdtype(a.dtype, np.integer) for a in ins)
    flops = (3 if weighted else 2) * r * k * feat  # 2*nnz*F (+w mul)
    nbytes = (r * k * 4  # prefetched table
              + r * k * feat * out.dtype.itemsize  # neighbor-row gather DMAs
              + _nbytes(out))
    return flops, nbytes


def _gat_ell_cost(eqn):
    ins, outs = _avals(eqn)
    table, out = _ell_table(ins), outs[0]
    r, k = table.shape
    # operands: adst (R, H) identifies H; out is (R, H*F)
    heads = next((a.shape[1] for a in ins
                  if len(a.shape) == 2 and a.shape[0] == r
                  and not np.issubdtype(a.dtype, np.integer)), 1)
    hf = out.shape[-1]
    # softmax (exp/max/sum ~ 8 ops per (row, slot, head)) + accumulate
    flops = r * k * (2 * hf + 8 * heads)
    nbytes = (r * k * 4 + r * k * hf * out.dtype.itemsize
              + r * k * heads * 4 + _nbytes(out))
    return flops, nbytes


def _attn_ell_cost(eqn):
    """Carry-mode typed-attention launch: outs = (acc (R,H*F), m, l (R,H))."""
    ins, outs = _avals(eqn)
    table = _ell_table(ins)
    r, k = table.shape
    acc, m = outs[0], outs[1]
    heads = m.shape[1]
    hf = acc.shape[-1]
    # adst is the (R, H*LD) float operand row-aligned with the table
    adst = next((a for a in ins
                 if len(a.shape) == 2 and a.shape[0] == r and a.shape[1] != k
                 and not np.issubdtype(a.dtype, np.integer)), None)
    ld = (adst.shape[1] // max(heads, 1)) if adst is not None else 1
    # per (row, slot): LD-wide dot per head + online softmax + accumulate
    flops = r * k * (2 * heads * ld + 8 * heads + 2 * hf)
    nbytes = (r * k * 4 + r * k * hf * acc.dtype.itemsize
              + r * k * heads * ld * 4 + sum(_nbytes(o) for o in outs))
    return flops, nbytes


def _gmm_cost(eqn):
    ins, outs = _avals(eqn)
    x = next(a for a in ins if len(a.shape) == 2
             and not np.issubdtype(a.dtype, np.integer))
    w = next(a for a in ins if len(a.shape) == 3)
    m, k = x.shape
    n = w.shape[2]
    flops = 2 * m * k * n  # sum over groups of 2*m_g*k*n; m = sum m_g
    nbytes = _nbytes(x) + _nbytes(w) + sum(_nbytes(o) for o in outs)
    return flops, nbytes


def _segment_softmax_cost(eqn):
    ins, outs = _avals(eqn)
    elems = max((int(np.prod(a.shape)) for a in ins), default=0)
    nbytes = sum(_nbytes(a) for a in ins) + sum(_nbytes(o) for o in outs)
    return 5 * elems, nbytes


def _flash_cost(eqn):
    ins, outs = _avals(eqn)
    floats = [a for a in ins if not np.issubdtype(a.dtype, np.integer)
              and len(a.shape) >= 3]
    q, kv = floats[0], floats[1]
    lq, d = q.shape[-2], q.shape[-1]
    lkv = kv.shape[-2]
    batch = int(np.prod(q.shape[:-2]))
    flops = 4 * batch * lq * lkv * d  # qk^T + softmax*V
    nbytes = sum(_nbytes(a) for a in floats) + sum(_nbytes(o) for o in outs)
    return flops, nbytes


_PALLAS_COSTS = {
    "_spmm_ell_kernel": _spmm_ell_cost,
    "_gat_ell_kernel": _gat_ell_cost,
    "_attn_ell_kernel": _attn_ell_cost,
    "_gmm_kernel": _gmm_cost,
    "_segment_softmax_kernel": _segment_softmax_cost,
    "_flash_kernel": _flash_cost,
}


def _pallas_cost(eqn):
    """(flops, bytes) of one pallas_call eqn, keyed off the kernel name."""
    info = eqn.params.get("name_and_src_info")
    kernel = getattr(info, "name", None) or eqn.params.get("name", "")
    fn = _PALLAS_COSTS.get(kernel)
    if fn is not None:
        try:
            return fn(eqn)
        except (StopIteration, IndexError, AttributeError):
            pass  # shape layout drifted: fall through to the generic model
    ins, outs = _avals(eqn)
    elems = sum(int(np.prod(a.shape)) for a in outs)
    nbytes = sum(_nbytes(a) for a in ins) + sum(_nbytes(o) for o in outs)
    return elems, nbytes


def analyze_jaxpr(jaxpr, mult: int = 1, acc: Dict[str, float] = None
                  ) -> Dict[str, float]:
    if acc is None:
        acc = {k: 0 for k in FLOP_REPORT_KEYS}
    inner = jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr
    for eqn in inner.eqns:
        name = eqn.primitive.name
        if name == "pallas_call":
            f, nb = _pallas_cost(eqn)
            acc["pallas_flops"] += mult * f
            acc["total_flops"] += mult * f
            acc["major_bytes"] += mult * nb
            continue
        if name in _COLLECTIVE_BYTES:
            inb = sum(_nbytes(v.aval) for v in eqn.invars
                      if hasattr(v.aval, "shape"))
            outb = sum(_nbytes(v.aval) for v in eqn.outvars
                       if hasattr(v.aval, "shape"))
            acc["collective_bytes"] += mult * _COLLECTIVE_BYTES[name](
                inb, outb)
            continue
        subs, is_while = _sub_jaxprs(eqn)
        if subs:
            if is_while:
                acc["while_warning"] += mult  # dynamic trip: counted once
            for sub, length in subs:
                analyze_jaxpr(sub, mult * length, acc)
            continue
        if name == "dot_general":
            f = _dot_flops(eqn)
            acc["dot_flops"] += mult * f
            acc["total_flops"] += mult * f
            acc["major_bytes"] += mult * (
                sum(_nbytes(v.aval) for v in eqn.invars)
                + sum(_nbytes(v.aval) for v in eqn.outvars))
        elif name == "conv_general_dilated":
            f = _conv_flops(eqn)
            acc["conv_flops"] += mult * f
            acc["total_flops"] += mult * f
            acc["major_bytes"] += mult * (
                sum(_nbytes(v.aval) for v in eqn.invars)
                + sum(_nbytes(v.aval) for v in eqn.outvars))
        elif name in ("gather", "scatter", "scatter-add", "scatter_add",
                      "dynamic_slice", "dynamic_update_slice", "take",
                      "argsort", "sort"):
            nb = sum(_nbytes(v.aval) for v in eqn.outvars)
            if name in ("sort", "argsort"):
                n = max(int(np.prod(eqn.invars[0].aval.shape)), 1)
                acc["elementwise_flops"] += mult * n * max(
                    int(math.log2(n)), 1)
                acc["total_flops"] += mult * n * max(int(math.log2(n)), 1)
            acc["major_bytes"] += mult * nb
        elif name.startswith("reduce_") or name == "reduce":
            nb = sum(_nbytes(v.aval) for v in eqn.invars)
            f = sum(int(np.prod(v.aval.shape)) for v in eqn.invars)
            acc["elementwise_flops"] += mult * f
            acc["total_flops"] += mult * f
            acc["major_bytes"] += mult * nb
        else:
            f = sum(int(np.prod(v.aval.shape)) for v in eqn.outvars
                    if hasattr(v.aval, "shape"))
            acc["elementwise_flops"] += mult * f
            acc["total_flops"] += mult * f
    return acc


def step_stats(fn, *abstract_args) -> Dict[str, float]:
    """Trace ``fn`` abstractly and return global FLOP/byte stats."""
    jaxpr = jax.make_jaxpr(fn)(*abstract_args)
    return analyze_jaxpr(jaxpr)
