"""Training driver: ``python -m repro.launch.train --arch <id> [--smoke]``.

On the CPU container this trains the reduced (smoke) configs end-to-end —
the same code path a TPU deployment uses with the full configs + production
mesh (sharding applied when the mesh has >1 device). Fault tolerance is
live: interrupt and re-run with the same --ckpt-dir to resume.
"""

from __future__ import annotations

import argparse

import jax

from repro.configs import get_config
from repro.distributed.elastic import StragglerMonitor
from repro.launch.mesh import make_local_mesh
from repro.nn.lm import model as model_lib
from repro.train import data_pipeline, optimizer as opt_lib, steps
from repro.train.loop import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compressed-grads", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    opt_cfg = opt_lib.OptConfig(lr=args.lr, warmup_steps=10,
                                total_steps=args.steps)
    params = model_lib.init_model(jax.random.PRNGKey(0), cfg)
    state = opt_lib.init_state(params, opt_cfg)
    n_params = sum(l.size for l in jax.tree_util.tree_leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.2f}M "
          f"steps={args.steps} batch={args.batch}x{args.seq}")

    batches = data_pipeline.synthetic_batches(
        cfg, args.batch, args.seq, enc_len=args.seq)
    monitor = StragglerMonitor(num_hosts=1)
    if args.compressed_grads:
        from repro.distributed import compression as comp_lib
        step_c = jax.jit(steps.make_train_step_compressed(cfg, opt_cfg))
        residual = comp_lib.init_residual(params)

        def train_step(state, batch):
            nonlocal residual
            state, metrics, residual = step_c(state, batch, residual)
            return state, metrics
    else:
        train_step = jax.jit(steps.make_train_step(cfg, opt_cfg),
                             donate_argnums=(0,))

    out = train_loop(state, train_step, batches, num_steps=args.steps,
                     ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                     monitor=monitor)
    first = out["history"][0][1] if out["history"] else float("nan")
    last = out["history"][-1][1] if out["history"] else float("nan")
    print(f"done: loss {first:.4f} -> {last:.4f}")
    return out


if __name__ == "__main__":
    main()
