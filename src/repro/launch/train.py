"""Training driver: ``python -m repro.launch.train --arch <id> [--smoke]``.

On the CPU container this trains the reduced (smoke) configs end-to-end —
the same code path a TPU deployment uses with the full configs + production
mesh (sharding applied when the mesh has >1 device). Fault tolerance is
live: interrupt and re-run with the same --ckpt-dir to resume.

This module also hosts :class:`MeshTrainer`, the data-parallel mesh wrapper
for the GNN stack (PR 10): give it any ``loss_fn(params, batch) ->
(loss_sum, weight)`` and it builds the jit'd ``shard_map`` train step over
a 1-D ``("data",)`` mesh — no model changes, redco-style ergonomics.
"""

from __future__ import annotations

import argparse
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.analysis.retrace import RetraceSentinel
from repro.configs import get_config
from repro.distributed import compression as comp_lib
from repro.distributed.elastic import StragglerMonitor, elastic_resize
from repro.distributed.sharding import (data_batch_shardings,
                                        replicated_shardings)
from repro.launch.mesh import data_parallel_mesh, make_local_mesh
from repro.nn.lm import model as model_lib
from repro.train import data_pipeline, optimizer as opt_lib, steps
from repro.train.loop import train_loop


class MeshTrainer:
    """Data-parallel ``shard_map`` train step over a 1-D device mesh.

    Wraps an existing per-shard loss function and the loader's stacked
    batches (``NeighborLoader(shards=D)``) into a train step with
    ``train_loop``-compatible shape ``step(state, batch) -> (state,
    metrics)``:

      * **batch** shards: every leaf of the stacked batch pytree splits its
        leading shard axis over the ``data`` mesh axis (one loader shard
        per device);
      * **params replicate**: the TrainState enters and leaves with spec
        ``P()`` on every leaf;
      * **gradients reduce once**: the whole local-grad pytree goes through
        a single fused ``psum`` over ``data`` (or, with ``compression=``,
        through :func:`repro.distributed.compression.compressed_allreduce`
        — per-device error-feedback residuals live on the trainer, stacked
        along the shard axis, never in the checkpoint).

    The loss contract makes the sharded step *numerically identical* to
    single-device gradient accumulation over the same shards:
    ``loss_fn(params, shard_batch)`` returns ``(loss_sum, weight)`` — an
    unnormalised loss total and its weight (e.g. real-seed count, so -1
    pad seeds drop out via ``batch.seed_mask``). The step computes
    ``psum(grads)/psum(weight)`` and ``psum(loss_sum)/psum(weight)``:
    sums commute with the device split, so parity holds to float
    round-off (the tier-1 tests pin <=1e-5; observed exact).

    One trace serves every batch: the step is jit'd once, batches keep
    static shapes (the loader pads non-dividing seed tails), and the
    built-in :class:`RetraceSentinel` counts compilations —
    ``trainer.trace_count`` must stay 1 across an epoch.

    ``save``/``restore`` checkpoint the replicated state; ``restore`` goes
    through :func:`repro.distributed.elastic.elastic_resize`, so a run
    checkpointed on an N-device mesh continues bit-identically on this
    trainer's M-device mesh (error feedback restarts from zero residuals).
    """

    def __init__(self, loss_fn: Callable[[Any, Any], Tuple[jnp.ndarray,
                                                           jnp.ndarray]],
                 opt_cfg: "opt_lib.OptConfig", *,
                 mesh: Optional[Mesh] = None,
                 compression: Optional[str] = None,
                 compression_ratio: float = 0.01,
                 retrace_budget: Optional[int] = 1):
        self.loss_fn = loss_fn
        self.opt_cfg = opt_cfg
        self.mesh = mesh if mesh is not None else data_parallel_mesh()
        if len(self.mesh.axis_names) != 1:
            raise ValueError(f"MeshTrainer needs a 1-D data mesh, got axes "
                             f"{self.mesh.axis_names}")
        self.axis_name = self.mesh.axis_names[0]
        self.num_devices = self.mesh.devices.size
        if compression is not None and \
                compression not in comp_lib.COMPRESSION_METHODS:
            raise ValueError(
                f"compression must be None or one of "
                f"{comp_lib.COMPRESSION_METHODS}, got {compression!r}")
        self.compression = compression
        self.compression_ratio = float(compression_ratio)
        self._residual = None  # lazily built stacked (D, ...) zeros
        self._sentinel = RetraceSentinel(budget=retrace_budget)
        self._step = self._sentinel.wrap(jax.jit(self._build()),
                                         name="mesh_step")

    # ---- step construction ----
    def _build(self):
        axis = self.axis_name
        loss_fn = self.loss_fn
        opt_cfg = self.opt_cfg
        compression = self.compression
        ratio = self.compression_ratio

        def _local_grads(params, shard_batch):
            def local_loss(p):
                loss_sum, weight = loss_fn(p, shard_batch)
                return loss_sum, weight
            grad_fn = jax.value_and_grad(local_loss, has_aux=True)
            (loss_sum, weight), grads = grad_fn(params)
            return grads, loss_sum, weight

        def _finish(state, grads_sum, loss_sum, weight):
            weight = jnp.maximum(weight, 1e-12)
            grads = jax.tree_util.tree_map(lambda g: g / weight, grads_sum)
            state, metrics = opt_lib.apply_updates(state, grads, opt_cfg)
            metrics = dict(metrics)
            metrics["loss"] = loss_sum / weight
            return state, metrics

        if compression is None:
            def _shard_body(state, stacked):
                shard = jax.tree_util.tree_map(lambda l: l[0], stacked)
                grads, loss_sum, weight = _local_grads(state.params, shard)
                # one fused all-reduce: the grad pytree + the two loss
                # scalars reduce in a single psum
                grads, loss_sum, weight = jax.lax.psum(
                    (grads, loss_sum, weight), axis)
                return _finish(state, grads, loss_sum, weight)

            return shard_map(_shard_body, self.mesh,
                             in_specs=(P(), P(axis)),
                             out_specs=(P(), P()),
                             check_rep=False)

        def _shard_body_compressed(state, stacked, residual):
            shard = jax.tree_util.tree_map(lambda l: l[0], stacked)
            local_res = jax.tree_util.tree_map(lambda l: l[0], residual)
            grads, loss_sum, weight = _local_grads(state.params, shard)
            loss_sum, weight = jax.lax.psum((loss_sum, weight), axis)
            grads, new_res = comp_lib.compressed_allreduce(
                grads, local_res, axis_name=axis, method=compression,
                ratio=ratio)
            state, metrics = _finish(state, grads, loss_sum, weight)
            residual = jax.tree_util.tree_map(lambda l: l[None], new_res)
            return state, metrics, residual

        return shard_map(_shard_body_compressed, self.mesh,
                         in_specs=(P(), P(axis), P(axis)),
                         out_specs=(P(), P(), P(axis)),
                         check_rep=False)

    # ---- data/state placement ----
    def _check_stacked(self, batch):
        leaves = jax.tree_util.tree_leaves(batch)
        bad = [l.shape for l in leaves
               if l.ndim == 0 or l.shape[0] != self.num_devices]
        if bad:
            raise ValueError(
                f"stacked batch leading dim must equal the mesh size "
                f"{self.num_devices} (loader shards=); got leaf shapes "
                f"{bad[:3]} — build the loader with "
                f"shards={self.num_devices}")

    def shard_batch(self, batch):
        """Place a stacked batch: leading shard axis over the mesh."""
        self._check_stacked(batch)
        return jax.device_put(batch, data_batch_shardings(
            self.mesh, batch, self.axis_name))

    def replicate_state(self, state):
        """Place a TrainState replicated (spec P()) on the mesh."""
        return jax.device_put(state, replicated_shardings(self.mesh, state))

    def _init_residual(self, params):
        d = self.num_devices
        res = jax.tree_util.tree_map(
            lambda p: jnp.zeros((d,) + p.shape, jnp.float32), params)
        return jax.device_put(res, data_batch_shardings(
            self.mesh, res, self.axis_name))

    # ---- the train_loop-compatible step ----
    def step(self, state, batch):
        self._check_stacked(batch)
        if self.compression is None:
            return self._step(state, batch)
        if self._residual is None:
            self._residual = self._init_residual(state.params)
        state, metrics, self._residual = self._step(
            state, batch, self._residual)
        return state, metrics

    __call__ = step

    # ---- introspection (dispatch audits / retrace accounting) ----
    @property
    def trace_count(self) -> int:
        return self._sentinel.count("mesh_step")

    def step_jaxpr(self, state, batch):
        """The step's closed jaxpr (for audit_jaxpr / jaxpr_stats)."""
        if self.compression is None:
            return jax.make_jaxpr(self._build())(state, batch)
        residual = (self._residual if self._residual is not None
                    else self._init_residual(state.params))
        return jax.make_jaxpr(self._build())(state, batch, residual)

    # ---- checkpoint / elastic resize ----
    def save(self, ckpt_dir, step: int, state, **kw):
        from repro.distributed import checkpoint as ckpt_lib
        return ckpt_lib.save_checkpoint(ckpt_dir, step, state, **kw)

    def restore(self, ckpt_dir, abstract_state, *, step=None):
        """Restore onto *this* trainer's mesh (any saved mesh size).

        Params/opt state come back bit-identical and replicated; the
        compressor residual — per-device state, deliberately outside the
        checkpoint — restarts from zeros (elastic resize contract).
        """
        state, step = elastic_resize(ckpt_dir, abstract_state, self.mesh,
                                     step=step)
        self._residual = None
        return state, step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compressed-grads", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    opt_cfg = opt_lib.OptConfig(lr=args.lr, warmup_steps=10,
                                total_steps=args.steps)
    params = model_lib.init_model(jax.random.PRNGKey(0), cfg)
    state = opt_lib.init_state(params, opt_cfg)
    n_params = sum(l.size for l in jax.tree_util.tree_leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.2f}M "
          f"steps={args.steps} batch={args.batch}x{args.seq}")

    batches = data_pipeline.synthetic_batches(
        cfg, args.batch, args.seq, enc_len=args.seq)
    monitor = StragglerMonitor(num_hosts=1)
    if args.compressed_grads:
        from repro.distributed import compression as comp_lib
        step_c = jax.jit(steps.make_train_step_compressed(cfg, opt_cfg))
        residual = comp_lib.init_residual(params)

        def train_step(state, batch):
            nonlocal residual
            state, metrics, residual = step_c(state, batch, residual)
            return state, metrics
    else:
        train_step = jax.jit(steps.make_train_step(cfg, opt_cfg),
                             donate_argnums=(0,))

    out = train_loop(state, train_step, batches, num_steps=args.steps,
                     ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                     monitor=monitor)
    first = out["history"][0][1] if out["history"] else float("nan")
    last = out["history"][-1][1] if out["history"] else float("nan")
    print(f"done: loss {first:.4f} -> {last:.4f}")
    return out


if __name__ == "__main__":
    main()
