"""Hardware budget constants + static per-launch memory accounting.

The single source of truth for the SMEM/VMEM assumptions the Pallas kernels
bake into their grids (previously duplicated across ``kernels/spmm/ops.py``
and ``kernels/attention/ops.py``). Two layers:

  * constants — prefetch-table cap, default block shapes, declared per-core
    SMEM/VMEM budgets, double-buffer depth;
  * accounting — pure-Python cost models of one kernel launch
    (``ell_launch_usage`` / ``gat_launch_usage`` / ``gmm_launch_usage``) and
    the pack-time validators (``check_ell_rung`` / ``check_ell_layout`` /
    ``check_gat_bucket``) that raise :class:`BudgetError` *before* a layout
    that cannot launch reaches a kernel — on the loader's producer thread,
    not inside a trace.

``analysis.budgets`` builds its headroom reports on top of these models;
keeping them here (below the kernels) avoids a kernels -> analysis import
cycle. Everything is host-side numpy/ints: safe to call from packers.
"""

from __future__ import annotations

from typing import Dict, Sequence, Tuple

import numpy as np

# --------------------------------------------------------------- constants
# The neighbor table rides scalar prefetch into SMEM on real TPUs, which is
# KB-scale: bound the per-launch table and chunk the row dimension above it.
# 64k int32 = 256 KB per launch; shapes are host-known so the chunk loop is
# a static Python loop (one pallas_call per chunk, shared compiled kernel
# across equal-shaped chunks).
MAX_PREFETCH_ELEMS = 64 * 1024

# Declared per-core budgets (TPU v4-class; conservative so CPU interpret
# runs enforce the same discipline the hardware would).
SMEM_BYTES_PER_CORE = 512 * 1024
VMEM_BYTES_PER_CORE = 16 * 1024 * 1024

# Default kernel block shapes: BR rows per grid step, BF feature lanes.
DEFAULT_BR = 8
DEFAULT_BF = 128
# Grouped-matmul MXU tiles (M, N, K).
GMM_BLOCK = (128, 128, 128)
# Gather scratch double-buffering depth (DMA slot count) in the ELL kernels.
DOUBLE_BUFFER_SLOTS = 2

_I32 = 4  # prefetch tables are int32


class BudgetError(ValueError):
    """A static layout/tiling exceeds a declared per-core memory budget.

    Subclasses ``ValueError`` so existing "bad layout" handling keeps
    working; raised at pack time (host side) with an actionable message —
    which rung/grid is over, by how much, and what to shrink.
    """


# -------------------------------------------------------------- accounting
def ell_chunk_rows(k: int, block_rows: int = DEFAULT_BR,
                   max_prefetch: int = MAX_PREFETCH_ELEMS) -> int:
    """Rows per launch after SMEM chunking (the ops-layer chunk rule)."""
    chunk = max(max_prefetch // max(k, 1), block_rows)
    return chunk - chunk % block_rows


def ell_launch_usage(rows: int, k: int, feat: int, *,
                     block_rows: int = DEFAULT_BR,
                     block_feat: int = DEFAULT_BF,
                     dtype_bytes: int = 4,
                     weighted: bool = False) -> Dict[str, int]:
    """Static SMEM/VMEM bytes of one (chunked) SpMM ELL launch."""
    launch_rows = min(rows, ell_chunk_rows(k, block_rows))
    bf = block_feat if feat % block_feat == 0 else feat
    smem = launch_rows * k * _I32                      # prefetched table
    vmem = (DOUBLE_BUFFER_SLOTS * block_rows * bf * dtype_bytes  # gather buf
            + block_rows * bf * dtype_bytes)                     # out block
    if weighted:
        vmem += block_rows * k * dtype_bytes                     # weights
    return {"smem_bytes": smem, "vmem_bytes": vmem,
            "launch_rows": launch_rows, "block_feat": bf}


def attn_launch_usage(rows: int, k: int, heads: int, feat: int, *,
                      logit_dim: int = 1,
                      block_rows: int = DEFAULT_BR,
                      block_feat: int = DEFAULT_BF,
                      dtype_bytes: int = 4,
                      weighted: bool = False,
                      carry: bool = False) -> Dict[str, int]:
    """Static SMEM/VMEM bytes of one (chunked) typed-attention launch.

    ``logit_dim`` is the per-head width of the attention operands: 1 for
    additive GAT logits, the head dim D for HGT's dot-product K/Q.  A typed
    launch additionally stages a ``(1, heads)`` prior row in VMEM, and a
    carry launch (``return_carry=True``) keeps the running ``(m, l)``
    softmax statistics as extra per-head output blocks.
    """
    launch_rows = min(rows, ell_chunk_rows(k, block_rows))
    bf = block_feat if feat % block_feat == 0 else feat
    al = heads * logit_dim
    smem = launch_rows * k * _I32
    vmem = (DOUBLE_BUFFER_SLOTS * block_rows * bf * dtype_bytes   # z gather
            + DOUBLE_BUFFER_SLOTS * block_rows * al * dtype_bytes  # alpha
            + block_rows * bf * dtype_bytes                       # out block
            + block_rows * al * dtype_bytes)                      # adst block
    if weighted:
        vmem += block_rows * k * dtype_bytes
    typed = logit_dim > 1 or carry
    if typed:
        vmem += heads * dtype_bytes                    # (1, H) prior row
    if carry:
        vmem += 2 * block_rows * dtype_bytes           # (BR, 1) m + l blocks
    return {"smem_bytes": smem, "vmem_bytes": vmem,
            "launch_rows": launch_rows, "block_feat": bf}


def gat_launch_usage(rows: int, k: int, heads: int, feat: int, *,
                     block_rows: int = DEFAULT_BR,
                     block_feat: int = DEFAULT_BF,
                     dtype_bytes: int = 4,
                     weighted: bool = False) -> Dict[str, int]:
    """Static SMEM/VMEM bytes of one (chunked) flash-GAT launch."""
    return attn_launch_usage(rows, k, heads, feat, logit_dim=1,
                             block_rows=block_rows, block_feat=block_feat,
                             dtype_bytes=dtype_bytes, weighted=weighted,
                             carry=False)


def gmm_launch_usage(k_dim: int, *, block: Tuple[int, int, int] = GMM_BLOCK,
                     dtype_bytes: int = 4) -> Dict[str, int]:
    """Static VMEM bytes of one grouped-matmul grid step (x/w/acc tiles)."""
    bm, bn, bk = block
    vmem = (bm * bk + bk * bn) * dtype_bytes + bm * bn * 4  # acc is f32
    return {"smem_bytes": 0, "vmem_bytes": vmem, "k_dim": k_dim}


# --------------------------------------------------------------- validators
def check_ell_rung(k: int, *, block_rows: int = DEFAULT_BR,
                   context: str = "ELL layout") -> None:
    """Reject a K rung whose *minimum* launch cannot fit the budgets.

    The chunker floors at one ``block_rows`` row block per launch, so a rung
    with ``block_rows * K`` table elements above ``MAX_PREFETCH_ELEMS`` (or
    its bytes above SMEM) can never be split small enough — fail at pack
    time instead of OOMing a launch.
    """
    min_table = block_rows * k
    if min_table > MAX_PREFETCH_ELEMS:
        raise BudgetError(
            f"{context}: K={k} rung needs a {min_table}-element prefetch "
            f"table even at one {block_rows}-row block per launch, over the "
            f"MAX_PREFETCH_ELEMS={MAX_PREFETCH_ELEMS} SMEM cap "
            f"(max K at block_rows={block_rows} is "
            f"{MAX_PREFETCH_ELEMS // block_rows}). Lower the degree bound "
            f"(sampler fanout) or split the range across buckets.")
    if min_table * _I32 > SMEM_BYTES_PER_CORE:
        raise BudgetError(
            f"{context}: K={k} rung's minimum prefetch table is "
            f"{min_table * _I32} bytes, over the per-core SMEM budget of "
            f"{SMEM_BYTES_PER_CORE} bytes. Lower the degree bound or "
            f"shrink block_rows.")


def check_ell_layout(layout: Sequence[Tuple[np.ndarray, int]], *,
                     block_rows: int = DEFAULT_BR,
                     feat: int = DEFAULT_BF,
                     context: str = "ELL layout") -> None:
    """Validate every rung of a static bucket layout against the budgets."""
    for rows, k in layout:
        check_ell_rung(int(k), block_rows=block_rows,
                       context=f"{context} (bucket of {len(rows)} rows)")
        usage = ell_launch_usage(len(rows), int(k), feat,
                                 block_rows=block_rows)
        if usage["vmem_bytes"] > VMEM_BYTES_PER_CORE:
            raise BudgetError(
                f"{context}: K={k} bucket needs {usage['vmem_bytes']} VMEM "
                f"bytes per launch, over the per-core budget of "
                f"{VMEM_BYTES_PER_CORE}. Shrink block_feat or block_rows.")


def check_attn_bucket(rows: int, k: int, heads: int, feat: int, *,
                      logit_dim: int = 1,
                      block_rows: int = DEFAULT_BR,
                      weighted: bool = False,
                      carry: bool = False) -> None:
    """Validate one typed-attention bucket's grid against the budgets.

    Covers the full typed launch shape — ``logit_dim``-wide alpha gathers,
    the ``(1, heads)`` prior row, and the ``(m, l)`` carry output buffers —
    so an unservable rung fails here (pack/trace time, host side), not when
    a launch finally OOMs.
    """
    context = ("typed-attention bucket" if (logit_dim > 1 or carry)
               else "flash-GAT bucket")
    check_ell_rung(k, block_rows=block_rows, context=context)
    usage = attn_launch_usage(rows, k, heads, feat, logit_dim=logit_dim,
                              block_rows=block_rows, weighted=weighted,
                              carry=carry)
    if usage["vmem_bytes"] > VMEM_BYTES_PER_CORE:
        raise BudgetError(
            f"{context} (rows={rows}, K={k}, heads={heads}, feat={feat}, "
            f"logit_dim={logit_dim}, carry={carry}): "
            f"{usage['vmem_bytes']} VMEM bytes per launch exceeds the "
            f"per-core budget of {VMEM_BYTES_PER_CORE}. Shrink the feature "
            f"block, head count, or per-head logit width per launch.")


def check_gat_bucket(rows: int, k: int, heads: int, feat: int, *,
                     block_rows: int = DEFAULT_BR,
                     weighted: bool = False) -> None:
    """Validate one flash-GAT bucket's grid against the budgets."""
    check_attn_bucket(rows, k, heads, feat, logit_dim=1,
                      block_rows=block_rows, weighted=weighted, carry=False)


def check_attn_layout(layout: Sequence[Tuple[np.ndarray, int]], *,
                      heads: int, feat: int, logit_dim: int,
                      block_rows: int = DEFAULT_BR,
                      weighted: bool = False,
                      carry: bool = True,
                      context: str = "typed-attention layout") -> None:
    """Pack-time validation of a static bucket layout for typed attention.

    Like :func:`check_ell_layout` but accounting the attention launch shape
    (prior row + carry buffers) per rung, so a layout that would only die
    inside an HGT launch is rejected when it is packed.
    """
    for rows, k in layout:
        try:
            check_attn_bucket(len(rows), int(k), heads, feat,
                              logit_dim=logit_dim, block_rows=block_rows,
                              weighted=weighted, carry=carry)
        except BudgetError as exc:
            raise BudgetError(f"{context}: {exc}") from None
