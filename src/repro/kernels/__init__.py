"""Pallas TPU kernels for the compute hot spots of the PyG 2.0 reproduction.

Each kernel lives in its own subpackage with three files:

  <name>.py  — the ``pl.pallas_call`` kernel with explicit BlockSpec tiling
  ops.py     — the jit'd public wrapper (dispatches kernel on TPU, oracle on CPU)
  ref.py     — the pure-jnp oracle used for validation and as the XLA fallback

Kernels:
  spmm             blocked-ELL sparse @ dense (message-passing fast path, C2)
  grouped_matmul   per-group GEMM {H_T W_T} (hetero projections C4 + MoE experts)
  attention        fused flash-GAT aggregation (gather -> leaky-relu ->
                   online masked softmax -> weighted accumulate) over the
                   same blocked-ELL buckets as spmm
  segment_softmax  softmax over variable-length segments (GAT oracle path,
                   explainer masks)
  flash_attention  online-softmax attention (LM prefill/train path)
"""

USE_PALLAS_ENV = "REPRO_USE_PALLAS"


def forward_only_pallas(impl, num_static: int, message: str):
    """Wrap a raw ``pallas_call`` entry point so differentiation fails fast.

    ``pallas_call`` carries no autodiff rule, so naked ``jax.grad`` through
    a raw kernel dies with an opaque trace error. The *supported* backward
    for a kernel lives in its ops-level wrapper (a hand-written
    ``jax.custom_vjp``); this helper gives the raw entry point a VJP whose
    backward raises ``NotImplementedError(message)`` instead — the message
    should name the differentiable ops-level wrapper and the
    ``REPRO_USE_PALLAS`` fallback env var.

    The first ``num_static`` arguments of ``impl`` are static/hashable
    (``nondiff_argnums``); the rest are array operands.
    """
    import functools

    import jax

    statics = tuple(range(num_static))

    @functools.partial(jax.custom_vjp, nondiff_argnums=statics)
    def wrapped(*args):
        return impl(*args)

    def fwd(*args):
        return wrapped(*args), None

    def bwd(*args):  # (*statics, residuals, cotangent)
        raise NotImplementedError(message)

    wrapped.defvjp(fwd, bwd)
    return wrapped


def use_pallas() -> bool:
    """Whether to dispatch Pallas kernels (TPU) or the jnp oracle (CPU/XLA).

    On this CPU container Pallas kernels run only in ``interpret=True`` mode,
    which we exercise in tests; production entry points leave this off so the
    XLA oracle path (itself fused by jit) is used.
    """
    import os

    import jax

    val = os.environ.get(USE_PALLAS_ENV)
    if val is not None:
        return val not in ("0", "false", "False")
    return jax.default_backend() == "tpu"
