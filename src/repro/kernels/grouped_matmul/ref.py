"""Pure-jnp oracle for grouped (segmented) matmul: {H_T @ W_T}_{T in types}.

The paper (§2.2) implements per-type projections of heterogeneous node sets
with CUTLASS grouped GEMM; the same primitive is MoE expert compute
(MegaBlocks-style). Rows of ``x`` are sorted by group; ``group_sizes[g]``
rows belong to group ``g`` and are multiplied by ``w[g]``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def group_ids_from_sizes(group_sizes: jnp.ndarray, num_rows: int) -> jnp.ndarray:
    """Per-row group id from group sizes (rows sorted by group)."""
    offsets = jnp.cumsum(group_sizes)
    return jnp.searchsorted(offsets, jnp.arange(num_rows, dtype=jnp.int32),
                            side="right").astype(jnp.int32)


def grouped_matmul(x: jnp.ndarray, w: jnp.ndarray,
                   group_sizes: jnp.ndarray) -> jnp.ndarray:
    """out[m] = x[m] @ w[g(m)].

    Args:
      x: (M, K) rows sorted by group.
      w: (G, K, N) per-group weights.
      group_sizes: (G,) int32, sums to M.
    """
    m = x.shape[0]
    gids = group_ids_from_sizes(group_sizes, m)
    # Oracle: gather per-row weight matrices. O(M*K*N) memory — fine for tests.
    return jnp.einsum("mk,mkn->mn", x, w[gids]).astype(x.dtype)


def grouped_matmul_dense(x: jnp.ndarray, w: jnp.ndarray,
                         group_sizes: jnp.ndarray) -> jnp.ndarray:
    """Alternative oracle via masked dense matmuls (checks the first one)."""
    m = x.shape[0]
    gids = group_ids_from_sizes(group_sizes, m)
    outs = jnp.stack([x @ w[g] for g in range(w.shape[0])])  # (G, M, N)
    return jnp.take_along_axis(outs, gids[None, :, None], axis=0)[0].astype(x.dtype)
