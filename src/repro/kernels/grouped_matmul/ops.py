"""Public grouped-matmul entry points: packing + kernel/oracle dispatch."""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import use_pallas
from repro.kernels.grouped_matmul import ref
from repro.kernels.grouped_matmul.grouped_matmul import grouped_matmul_pallas


def grouped_matmul(x: jnp.ndarray, w: jnp.ndarray, group_sizes: jnp.ndarray,
                   *, force_pallas: Optional[bool] = None,
                   interpret: bool = False) -> jnp.ndarray:
    """Grouped GEMM over group-sorted rows. Dispatches kernel or XLA oracle.

    The XLA path uses ``jax.lax.ragged_dot`` when available (native grouped
    matmul lowering) and falls back to the gather-einsum oracle otherwise.
    """
    take_pallas = use_pallas() if force_pallas is None else force_pallas
    if take_pallas:
        xp, tile_group, row_map, m_orig = pack_rows(x, group_sizes)
        # pad K / N up to MXU tile multiples
        k, n = x.shape[1], w.shape[2]
        kp, np_ = -(-k // 128) * 128, -(-n // 128) * 128
        if kp != k:
            xp = jnp.pad(xp, ((0, 0), (0, kp - k)))
            w = jnp.pad(w, ((0, 0), (0, kp - k), (0, 0)))
        if np_ != n:
            w = jnp.pad(w, ((0, 0), (0, 0), (0, np_ - n)))
        out = grouped_matmul_pallas(xp, w, tile_group, interpret=interpret)
        return out[row_map, :n]
    try:
        return jax.lax.ragged_dot(x, w, group_sizes.astype(jnp.int32))
    except Exception:  # pragma: no cover - older jax
        return ref.grouped_matmul(x, w, group_sizes)


def pack_rows(x: jnp.ndarray, group_sizes: jnp.ndarray, block_m: int = 128
              ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, int]:
    """Pad each group's rows to a multiple of ``block_m`` (host-side shapes).

    Returns (x_packed, tile_group, row_map) where ``row_map`` scatters packed
    rows back to original positions: ``out_orig = out_packed[row_map]``.
    NOTE: requires concrete ``group_sizes`` (host), as padding changes shapes.
    """
    sizes = np.asarray(group_sizes)
    g = len(sizes)
    padded = -(-sizes // block_m) * block_m  # per-group padded row counts
    padded = np.maximum(padded, block_m)  # empty groups still occupy one tile
    total = int(padded.sum())
    src_rows = np.zeros(total, np.int64)  # packed slot -> original row
    row_map = np.zeros(int(sizes.sum()), np.int64)  # original row -> packed slot
    tile_group = np.zeros(total // block_m, np.int32)
    off_orig, off_pack, off_tile = 0, 0, 0
    for gi in range(g):
        s, p = int(sizes[gi]), int(padded[gi])
        src_rows[off_pack:off_pack + s] = np.arange(off_orig, off_orig + s)
        # padding slots re-read row 0 (masked out by row_map on the way back)
        row_map[off_orig:off_orig + s] = np.arange(off_pack, off_pack + s)
        tile_group[off_tile:off_tile + p // block_m] = gi
        off_orig += s
        off_pack += p
        off_tile += p // block_m
    xp = jnp.take(x, jnp.asarray(src_rows), axis=0)
    return xp, jnp.asarray(tile_group), jnp.asarray(row_map), int(sizes.sum())
