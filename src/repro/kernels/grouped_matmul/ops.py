"""Public grouped-matmul entry points: packing + kernel/oracle dispatch.

The Pallas branch is differentiable: a custom VJP runs the backward as two
grouped GEMMs that reuse the forward's tile->group table (the MegaBlocks
recipe — ``dx = dy @ w[g]^T`` through the same packed layout, ``dw[g]``
accumulated tile-wise and segment-summed per group), so hetero projection
stacks and MoE experts can train on the kernel path.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import use_pallas
from repro.kernels.grouped_matmul import ref
from repro.kernels.grouped_matmul.grouped_matmul import grouped_matmul_pallas


def grouped_matmul(x: jnp.ndarray, w: jnp.ndarray, group_sizes: jnp.ndarray,
                   *, force_pallas: Optional[bool] = None,
                   interpret: bool = False) -> jnp.ndarray:
    """Grouped GEMM over group-sorted rows. Dispatches kernel or XLA oracle.

    The XLA path uses ``jax.lax.ragged_dot`` when available (native grouped
    matmul lowering) and falls back to the gather-einsum oracle otherwise.
    The Pallas path needs *concrete* ``group_sizes`` (row packing is a host
    shape decision); traced sizes fall back to the XLA path — same
    convention as the SpMM dispatch under tracing. The Pallas branch carries
    a custom VJP (two grouped GEMMs over the same tile->group table), so
    ``jax.grad`` through it works.
    """
    take_pallas = use_pallas() if force_pallas is None else force_pallas
    if take_pallas and isinstance(group_sizes, jax.core.Tracer):
        take_pallas = False  # packing needs host shapes
    if take_pallas:
        sizes = tuple(int(s) for s in np.asarray(group_sizes))
        return _grouped_matmul_diff(sizes, bool(interpret), x, w)
    # The named scope tags the XLA fallback for the dispatch auditor.
    with jax.named_scope("repro_oracle:grouped_matmul"):
        try:
            return jax.lax.ragged_dot(x, w, group_sizes.astype(jnp.int32))
        except Exception:  # pragma: no cover - older jax
            return ref.grouped_matmul(x, w, group_sizes)


def _pack_plan(sizes: Tuple[int, ...], block_m: int = 128
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """Host-side packing plan: (src_rows, row_map, tile_group, total).

    Each group's rows pad to a ``block_m`` multiple so every M-tile belongs
    to exactly one group; ``src_rows`` maps packed slot -> original row
    (padding slots re-read row 0), ``row_map`` original row -> packed slot,
    ``tile_group`` M-tile -> group id.
    """
    sizes_a = np.asarray(sizes, np.int64)
    padded = -(-sizes_a // block_m) * block_m
    padded = np.maximum(padded, block_m)  # empty groups still occupy a tile
    total = int(padded.sum())
    src_rows = np.zeros(total, np.int64)
    row_map = np.zeros(int(sizes_a.sum()), np.int64)
    tile_group = np.zeros(total // block_m, np.int32)
    off_orig, off_pack, off_tile = 0, 0, 0
    for gi, (s, p) in enumerate(zip(sizes_a, padded)):
        s, p = int(s), int(p)
        src_rows[off_pack:off_pack + s] = np.arange(off_orig, off_orig + s)
        row_map[off_orig:off_orig + s] = np.arange(off_pack, off_pack + s)
        tile_group[off_tile:off_tile + p // block_m] = gi
        off_orig += s
        off_pack += p
        off_tile += p // block_m
    return src_rows, row_map, tile_group, total


def pack_rows(x: jnp.ndarray, group_sizes: jnp.ndarray, block_m: int = 128
              ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, int]:
    """Pad each group's rows to a multiple of ``block_m`` (host-side shapes).

    Returns (x_packed, tile_group, row_map) where ``row_map`` scatters packed
    rows back to original positions: ``out_orig = out_packed[row_map]``.
    NOTE: requires concrete ``group_sizes`` (host), as padding changes shapes.
    """
    sizes = tuple(int(s) for s in np.asarray(group_sizes))
    src_rows, row_map, tile_group, _ = _pack_plan(sizes, block_m)
    xp = jnp.take(x, jnp.asarray(src_rows), axis=0)
    return xp, jnp.asarray(tile_group), jnp.asarray(row_map), int(sum(sizes))


def _gmm_pallas_forward(sizes: Tuple[int, ...], interpret: bool,
                        x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Pack -> pad K/N to MXU tiles -> kernel -> unpack (the Pallas path).

    ``sizes`` is a static tuple (host shapes), so the plan is pure numpy —
    inside a trace the operands are tracers but the packing never is.
    """
    src_rows, row_map, tile_group, _ = _pack_plan(sizes)
    xp = jnp.take(x, jnp.asarray(src_rows), axis=0)
    row_map, tile_group = jnp.asarray(row_map), jnp.asarray(tile_group)
    k, n = x.shape[1], w.shape[2]
    kp, np_ = -(-k // 128) * 128, -(-n // 128) * 128
    if kp != k:
        xp = jnp.pad(xp, ((0, 0), (0, kp - k)))
        w = jnp.pad(w, ((0, 0), (0, kp - k), (0, 0)))
    if np_ != n:
        w = jnp.pad(w, ((0, 0), (0, 0), (0, np_ - n)))
    out = grouped_matmul_pallas(xp, w, tile_group, interpret=interpret)
    return out[row_map, :n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _grouped_matmul_diff(sizes: Tuple[int, ...], interpret: bool, x, w):
    """Differentiable Pallas grouped matmul: forward on the MXU kernel, the
    backward as two grouped GEMMs reusing the forward tile->group table."""
    return _gmm_pallas_forward(sizes, interpret, x, w)


def _grouped_matmul_diff_fwd(sizes, interpret, x, w):
    return _gmm_pallas_forward(sizes, interpret, x, w), (x, w)


def _grouped_matmul_diff_bwd(sizes, interpret, residuals, dy):
    x, w = residuals
    # Scoped as the kernel's own backward so the dispatch auditor never
    # reads its scatters/segment-sums as an oracle fallback in grad steps.
    with jax.named_scope("repro_kernel_vjp:grouped_matmul"):
        # dx[m] = dy[m] @ w[g(m)]^T — the same grouped GEMM with w
        # transposed, over the identical tile->group table (shapes depend
        # only on `sizes`).
        dx = _gmm_pallas_forward(sizes, interpret, dy,
                                 jnp.swapaxes(w, 1, 2)).astype(x.dtype)
        # dw[g] = sum_{m in g} x[m]^T dy[m] — pack both operands into the
        # tiled layout with *zeros* in padding slots, contract per M-tile,
        # and segment-sum tiles into their groups (the second grouped GEMM).
        _, row_map, tile_group, total = _pack_plan(sizes)
        block_m = 128  # _pack_plan's tile height
        k, n = x.shape[1], dy.shape[1]
        xp = jnp.zeros((total, k), jnp.float32).at[jnp.asarray(row_map)].set(
            x.astype(jnp.float32))
        dyp = jnp.zeros((total, n), jnp.float32).at[jnp.asarray(row_map)].set(
            dy.astype(jnp.float32))
        per_tile = jnp.einsum("tmk,tmn->tkn",
                              xp.reshape(-1, block_m, k),
                              dyp.reshape(-1, block_m, n))
        dw = jax.ops.segment_sum(per_tile, jnp.asarray(tile_group),
                                 num_segments=w.shape[0]).astype(w.dtype)
    return dx, dw


_grouped_matmul_diff.defvjp(_grouped_matmul_diff_fwd,
                            _grouped_matmul_diff_bwd)
