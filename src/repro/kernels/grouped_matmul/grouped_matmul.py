"""Grouped-GEMM Pallas TPU kernel (paper C4; CUTLASS grouped GEMM -> MXU).

TPU adaptation:
* Every group's row count is padded (host side, in ops.py) to a multiple of
  the 128-row MXU tile, so each M-tile belongs to exactly one group — the
  MegaBlocks trick, which turns the ragged problem into a dense grid plus a
  tiny ``tile -> group`` table.
* The table rides in as a *scalar-prefetch* operand, so BlockSpec index maps
  can route each M-tile to its group's weight block while the MXU runs dense
  128x128x128 tiles.

Grid: ``(num_m_tiles, num_n_tiles, num_k_tiles)`` — K innermost so a VMEM
fp32 accumulator carries partial sums across K steps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _gmm_kernel(tile_group_ref, x_ref, w_ref, out_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[0].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == n_k - 1)
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret"))
def _grouped_matmul_pallas_impl(x: jnp.ndarray, w: jnp.ndarray,
                                tile_group: jnp.ndarray, *,
                                block_m: int = DEFAULT_BM,
                                block_n: int = DEFAULT_BN,
                                block_k: int = DEFAULT_BK,
                                interpret: bool = False) -> jnp.ndarray:
    """out[tile t] = x[tile t] @ w[tile_group[t]].

    Args:
      x: (M, K) with M % block_m == 0; rows pre-packed so that every M-tile
         belongs to a single group.
      w: (G, K, N) per-group weights; K % block_k == 0, N % block_n == 0.
      tile_group: (M // block_m,) int32 group id per M-tile.
    """
    m, kdim = x.shape
    g, _, n = w.shape
    assert m % block_m == 0 and kdim % block_k == 0 and n % block_n == 0
    n_m, n_n, n_k = m // block_m, n // block_n, kdim // block_k

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_m, n_n, n_k),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k, tg: (i, k)),
            # Route the weight block through the prefetched tile->group table.
            pl.BlockSpec((1, block_k, block_n),
                         lambda i, j, k, tg: (tg[i], k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k, tg: (i, j)),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
    )

    kernel = functools.partial(_gmm_kernel, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )(tile_group, x, w)


from repro.kernels import forward_only_pallas

_grouped_matmul_pallas_cv = forward_only_pallas(
    lambda block_m, block_n, block_k, interpret, x, w, tile_group:
        _grouped_matmul_pallas_impl(x, w, tile_group, block_m=block_m,
                                    block_n=block_n, block_k=block_k,
                                    interpret=interpret),
    num_static=4,
    message=(
        "grouped_matmul_pallas is the raw pre-packed Pallas kernel and has "
        "no backward rule. Differentiate through "
        "repro.kernels.grouped_matmul.ops.grouped_matmul, whose custom VJP "
        "runs the backward as two grouped GEMMs over the same tile->group "
        "table, or set REPRO_USE_PALLAS=0 to dispatch the differentiable "
        "XLA path."))


def grouped_matmul_pallas(x: jnp.ndarray, w: jnp.ndarray,
                          tile_group: jnp.ndarray, *,
                          block_m: int = DEFAULT_BM,
                          block_n: int = DEFAULT_BN,
                          block_k: int = DEFAULT_BK,
                          interpret: bool = False) -> jnp.ndarray:
    """Grouped-GEMM Pallas kernel (see :func:`_grouped_matmul_pallas_impl`).

    Forward-only: differentiating this raw entry point raises a clear
    ``NotImplementedError`` naming the differentiable ops-level wrapper and
    the ``REPRO_USE_PALLAS`` fallback env var.
    """
    return _grouped_matmul_pallas_cv(block_m, block_n, block_k, interpret,
                                     x, w, tile_group)
