"""Public attention entry point: Pallas on TPU, chunked-jnp on XLA."""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels import use_pallas
from repro.kernels.flash_attention import ref
from repro.kernels.flash_attention.flash_attention import flash_attention_pallas

# Below this KV length the naive path is cheaper than blocking overhead.
CHUNKED_THRESHOLD = 2048

# XLA-path blockwise schedule: 'rect' (rectangular + masking, baseline) or
# 'tri' (diagonal-banded lower-triangle scan — half the attention FLOPs;
# §Perf beyond-paper iteration, switchable at trace time like the MoE impl).
_ATTN_IMPL = "rect"


def set_attention_impl(impl: str):
    global _ATTN_IMPL
    assert impl in ("rect", "tri")
    _ATTN_IMPL = impl


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
              causal: bool = True, q_offset: int = 0,
              force_pallas: Optional[bool] = None,
              interpret: bool = False) -> jnp.ndarray:
    """GQA attention. q: (B, Sq, H, D); k/v: (B, Sk, Hkv, D)."""
    take_pallas = use_pallas() if force_pallas is None else force_pallas
    if take_pallas:
        h, hkv = q.shape[2], k.shape[2]
        if hkv != h:
            k = jnp.repeat(k, h // hkv, axis=2)
            v = jnp.repeat(v, h // hkv, axis=2)
        return flash_attention_pallas(q, k, v, causal=causal,
                                      q_offset=q_offset, interpret=interpret)
    if k.shape[1] <= CHUNKED_THRESHOLD:
        return ref.mha_reference(q, k, v, causal=causal, q_offset=q_offset)
    if (_ATTN_IMPL == "tri" and causal and q_offset == 0
            and q.shape[1] == k.shape[1]):
        return ref.mha_chunked_causal(q, k, v)
    return ref.mha_chunked(q, k, v, causal=causal, q_offset=q_offset)
