"""FlashAttention Pallas TPU kernel (online softmax, causal block skipping).

Tiling: grid ``(batch*heads, num_q_blocks, num_kv_blocks)`` with the KV axis
innermost; fp32 accumulator / running-max / running-sum live in VMEM scratch
and persist across the sequential KV steps of one (bh, q) tile — the TPU
rendition of FlashAttention's SRAM accumulators. Causal tiles strictly above
the diagonal are skipped via ``pl.when`` (no MXU work issued).

Block sizes default to (128, 128): MXU-native, and a (128 q x 128 kv) logits
tile + two (128, d) operand tiles fit comfortably in ~16 MB VMEM for d<=256.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BKV = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, out_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, n_kv: int, block_q: int,
                  block_kv: int, seq_kv: int, q_offset: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # Causal skip: the whole KV tile is in the future of the whole Q tile.
    first_q = qi * block_q + q_offset
    run = True
    if causal:
        run = ki * block_kv <= first_q + block_q - 1

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (BQ, D)
        k = k_ref[0].astype(jnp.float32)                  # (BKV, D)
        v = v_ref[0].astype(jnp.float32)                  # (BKV, D)
        logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
        kpos = ki * block_kv + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 1)
        qpos = q_offset + qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 0)
        mask = kpos < seq_kv
        if causal:
            mask = mask & (qpos >= kpos)
        logits = jnp.where(mask, logits, NEG_INF)

        m_prev = m_ref[...]
        blk_max = jnp.max(logits, axis=-1, keepdims=True)  # (BQ, 1)
        m_new = jnp.maximum(m_prev, blk_max)
        p = jnp.exp(logits - m_new)
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _flush():
        out_ref[0] = (acc_ref[...] /
                      jnp.maximum(l_ref[...], 1e-20)).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "block_q", "block_kv", "q_offset", "interpret"))
def _flash_attention_pallas_impl(q: jnp.ndarray, k: jnp.ndarray,
                                 v: jnp.ndarray, *, causal: bool = True,
                                 block_q: int = DEFAULT_BQ,
                                 block_kv: int = DEFAULT_BKV,
                                 q_offset: int = 0,
                                 interpret: bool = False) -> jnp.ndarray:
    """q: (B, Sq, H, D), k/v: (B, Sk, H, D) (pre-broadcast GQA upstream).

    Sq % block_q == 0 and Sk % block_kv == 0 (pad upstream; padded KV masked
    via ``seq_kv``).
    """
    b, sq, h, d = q.shape
    sk = k.shape[1]
    assert sq % block_q == 0 and sk % block_kv == 0
    n_q, n_kv = sq // block_q, sk // block_kv
    scale = 1.0 / (d ** 0.5)

    # Fold batch & heads into the leading grid dim; move seq to dim 1.
    qr = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kr = k.transpose(0, 2, 1, 3).reshape(b * h, sk, d)
    vr = v.transpose(0, 2, 1, 3).reshape(b * h, sk, d)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, n_kv=n_kv,
        block_q=block_q, block_kv=block_kv, seq_kv=sk, q_offset=q_offset)

    out = pl.pallas_call(
        kernel,
        grid=(b * h, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_kv, d), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, block_kv, d), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)


from repro.kernels import forward_only_pallas

_flash_attention_pallas_cv = forward_only_pallas(
    lambda causal, block_q, block_kv, q_offset, interpret, q, k, v:
        _flash_attention_pallas_impl(q, k, v, causal=causal,
                                     block_q=block_q, block_kv=block_kv,
                                     q_offset=q_offset, interpret=interpret),
    num_static=5,
    message=(
        "flash_attention_pallas is the raw Pallas kernel and has no "
        "backward rule. Differentiate through "
        "repro.kernels.flash_attention.ops.attention with "
        "REPRO_USE_PALLAS=0 (the chunked XLA path is differentiable); the "
        "LM train path keeps XLA attention."))


def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                           causal: bool = True, block_q: int = DEFAULT_BQ,
                           block_kv: int = DEFAULT_BKV, q_offset: int = 0,
                           interpret: bool = False) -> jnp.ndarray:
    """FlashAttention Pallas kernel (see :func:`_flash_attention_pallas_impl`).

    Forward-only: differentiating this raw entry point raises a clear
    ``NotImplementedError`` naming the differentiable XLA path and the
    ``REPRO_USE_PALLAS`` fallback env var, instead of an opaque
    ``pallas_call`` trace error.
    """
    return _flash_attention_pallas_cv(causal, block_q, block_kv, q_offset,
                                      interpret, q, k, v)
