"""Attention oracles: naive full-materialisation + chunked (memory-efficient).

``mha_reference`` is the quadratic-memory oracle used for kernel validation.
``mha_chunked`` is a pure-jnp online-softmax implementation (lax.scan over KV
blocks) that the LM stack uses at long sequence lengths on the XLA path — it
keeps the attention working set O(block) instead of O(seq^2), which is what
makes the 32k prefill dry-run cells compile with sane memory.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp


def mha_reference(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, scale: Optional[float] = None,
                  q_offset: int = 0) -> jnp.ndarray:
    """Naive attention. q: (B, Sq, H, D); k/v: (B, Sk, Hkv, D). GQA by head tiling.

    ``q_offset``: absolute position of q[0] relative to k[0] (decode: Sk-1).
    """
    b, sq, h, d = q.shape
    hkv = k.shape[2]
    rep = h // hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if causal:
        qpos = jnp.arange(sq) + q_offset
        kpos = jnp.arange(k.shape[1])
        mask = qpos[:, None] >= kpos[None, :]
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
    return out.astype(q.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_kv"))
def mha_chunked(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                causal: bool = True, block_q: int = 1024,
                block_kv: int = 1024, q_offset: int = 0) -> jnp.ndarray:
    """Double-blocked online-softmax attention (flash-style, pure jnp).

    Outer ``lax.map`` over Q blocks x inner ``lax.scan`` over KV blocks keeps
    the working set O(block_q * block_kv) — this is what lets 32k-seq cells
    compile with sane memory on the XLA path. Baseline is *rectangular*
    (every KV block visited per Q block, causal handled by masking); the
    diagonal-banded variant is a §Perf iteration.
    """
    b, sq, h, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    rep = h // hkv
    scale = 1.0 / (d ** 0.5)
    nkv = -(-sk // block_kv)
    pad_kv = nkv * block_kv - sk
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    nq = -(-sq // block_q)
    pad_q = nq * block_q - sq
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else q
    kb = jnp.moveaxis(k.reshape(b, nkv, block_kv, hkv, d), 1, 0)
    vb = jnp.moveaxis(v.reshape(b, nkv, block_kv, hkv, d), 1, 0)
    qb = jnp.moveaxis(qp.reshape(b, nq, block_q, h, d), 1, 0)

    def q_block(args):
        qblk, qi = args  # (b, block_q, h, d)
        qf = qblk.astype(jnp.float32) * scale
        qpos = q_offset + qi * block_q + jnp.arange(block_q)

        def kv_step(carry, blk):
            acc, m, l = carry
            kblk, vblk, ki = blk
            if rep > 1:
                kblk = jnp.repeat(kblk, rep, axis=2)
                vblk = jnp.repeat(vblk, rep, axis=2)
            logits = jnp.einsum("bqhd,bkhd->bhqk", qf,
                                kblk.astype(jnp.float32))
            kpos = ki * block_kv + jnp.arange(block_kv)
            valid = kpos[None, :] < sk
            if causal:
                valid = valid & (qpos[:, None] >= kpos[None, :])
            logits = jnp.where(valid[None, None], logits, -jnp.inf)
            blk_max = jnp.max(logits, axis=-1)
            new_m = jnp.maximum(m, blk_max)
            corr = jnp.exp(m - new_m)
            p = jnp.exp(logits - new_m[..., None])
            p = jnp.where(jnp.isfinite(logits), p, 0.0)
            new_l = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bhqk,bkhd->bhqd", p, vblk.astype(jnp.float32))
            return (acc * corr[..., None] + pv, new_m, new_l), None

        init = (jnp.zeros((b, h, block_q, d), jnp.float32),
                jnp.full((b, h, block_q), -jnp.inf, jnp.float32),
                jnp.zeros((b, h, block_q), jnp.float32))
        (acc, m, l), _ = jax.lax.scan(
            kv_step, init, (kb, vb, jnp.arange(nkv)))
        return acc / jnp.maximum(l, 1e-20)[..., None]  # (b, h, block_q, d)

    outs = jax.lax.map(q_block, (qb, jnp.arange(nq)))  # (nq, b, h, bq, d)
    out = jnp.moveaxis(outs, 0, 2).reshape(b, h, nq * block_q, d)
    return jnp.moveaxis(out[:, :, :sq], 1, 2).astype(q.dtype)


@functools.partial(jax.jit, static_argnames=("block",))
def mha_chunked_causal(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                       block: int = 1024) -> jnp.ndarray:
    """Diagonal-banded causal attention: scan over the *lower-triangular*
    (q_block, kv_block) pairs only — exactly half the rectangular variant's
    attention FLOPs/bytes (§Perf beyond-paper iteration). Requires
    Sq == Sk (self-attention training/prefill); pads S to a block multiple.
    """
    b, s, h, d = q.shape
    hkv = k.shape[2]
    rep = h // hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    scale = 1.0 / (d ** 0.5)
    n = -(-s // block)
    pad = n * block - s
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qb = jnp.moveaxis(q.reshape(b, n, block, h, d), 1, 0).astype(
        jnp.float32) * scale
    kb = jnp.moveaxis(k.reshape(b, n, block, h, d), 1, 0).astype(jnp.float32)
    vb = jnp.moveaxis(v.reshape(b, n, block, h, d), 1, 0).astype(jnp.float32)

    # static lower-triangle pair list: n(n+1)/2 steps instead of n^2
    qis = jnp.asarray([qi for qi in range(n) for _ in range(qi + 1)])
    kis = jnp.asarray([ki for qi in range(n) for ki in range(qi + 1)])

    def step(carry, pair):
        acc, m, l = carry  # (n, b, h, block, d), (n, b, h, block), ...
        qi, ki = pair
        qblk = jax.lax.dynamic_index_in_dim(qb, qi, 0, keepdims=False)
        kblk = jax.lax.dynamic_index_in_dim(kb, ki, 0, keepdims=False)
        vblk = jax.lax.dynamic_index_in_dim(vb, ki, 0, keepdims=False)
        logits = jnp.einsum("bqhd,bkhd->bhqk", qblk, kblk)
        qpos = qi * block + jnp.arange(block)
        kpos = ki * block + jnp.arange(block)
        valid = (qpos[:, None] >= kpos[None, :]) & (kpos[None, :] < s)
        logits = jnp.where(valid[None, None], logits, -jnp.inf)
        m_q = jax.lax.dynamic_index_in_dim(m, qi, 0, keepdims=False)
        l_q = jax.lax.dynamic_index_in_dim(l, qi, 0, keepdims=False)
        a_q = jax.lax.dynamic_index_in_dim(acc, qi, 0, keepdims=False)
        blk_max = jnp.max(logits, axis=-1)
        new_m = jnp.maximum(m_q, blk_max)
        corr = jnp.exp(m_q - new_m)
        p = jnp.where(jnp.isfinite(logits),
                      jnp.exp(logits - new_m[..., None]), 0.0)
        new_l = l_q * corr + p.sum(axis=-1)
        pv = jnp.einsum("bhqk,bkhd->bhqd", p, vblk)
        new_a = a_q * corr[..., None] + pv
        acc = jax.lax.dynamic_update_index_in_dim(acc, new_a, qi, 0)
        m = jax.lax.dynamic_update_index_in_dim(m, new_m, qi, 0)
        l = jax.lax.dynamic_update_index_in_dim(l, new_l, qi, 0)
        return (acc, m, l), None

    init = (jnp.zeros((n, b, h, block, d), jnp.float32),
            jnp.full((n, b, h, block), -jnp.inf, jnp.float32),
            jnp.zeros((n, b, h, block), jnp.float32))
    (acc, m, l), _ = jax.lax.scan(step, init, (qis, kis))
    out = acc / jnp.maximum(l, 1e-20)[..., None]  # (n, b, h, block, d)
    out = jnp.moveaxis(out, 0, 2).reshape(b, h, n * block, d)
    return jnp.moveaxis(out[:, :, :s], 1, 2).astype(q.dtype)
