"""Public fused-attention entry points with kernel/oracle dispatch.

The GAT aggregation analogue of ``kernels.spmm.ops``: the same bucketed
blocked-ELL layout (``EllBucket`` triples from the SpMM packers, ``ell_pos``
keyed to COO edge order) drives a *fused* attention aggregation

    out[r, h] = sum_k softmax_k(leaky_relu(a_src[nbr] + a_dst[r]))_k
                * w[r, k] * z[nbr, h]

per bucket: the Pallas flash-GAT kernel on TPU (or when forced), the panel
oracle elsewhere. The Pallas branch is differentiable at this level — an
ops-level ``jax.custom_vjp`` recomputes the softmax over the same panels and
runs its backward (softmax VJP + leaky-relu VJP + masked scatter-adds into
``alpha_src``/``z``) in XLA, exactly the PR-4 pattern for SpMM. The raw
kernel entry point stays forward-only behind the shared
``forward_only_pallas`` guard.
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import budgets as hw_budgets, use_pallas
from repro.kernels.attention import ref
from repro.kernels.attention.gat_attention import DEFAULT_BR, gat_ell_pallas
# MAX_PREFETCH_ELEMS comes from the shared budget source of truth (a
# module-level name here so tests can monkeypatch this ops module's chunk
# rule independently of the SpMM one).
from repro.kernels.budgets import MAX_PREFETCH_ELEMS
from repro.kernels.spmm.ops import EllBucket


def _gat_ell_pallas_chunked(ell_idx: jnp.ndarray, adst: jnp.ndarray,
                            ell_w: Optional[jnp.ndarray],
                            alpha_src: jnp.ndarray, z: jnp.ndarray,
                            negative_slope: float,
                            interpret: bool) -> jnp.ndarray:
    """The raw Pallas forward, row-chunked to the SMEM prefetch budget.

    Calls the module-global ``gat_ell_pallas`` (not a captured reference) so
    test spies that monkeypatch the ops attribute still observe every
    launch. Returns (R, H, F).
    """
    rows, k = ell_idx.shape
    heads, feat = z.shape[1], z.shape[2]
    z2d = z.reshape(z.shape[0], heads * feat)
    bf = 128 if feat % 128 == 0 else feat
    # Launch-time backstop against the *declared* hardware budgets (the
    # pack-time check covers loader layouts; ad-hoc buckets land here).
    hw_budgets.check_gat_bucket(rows, k, heads, feat,
                                weighted=ell_w is not None)
    chunk = max(MAX_PREFETCH_ELEMS // max(k, 1), DEFAULT_BR)
    chunk -= chunk % DEFAULT_BR
    if rows <= chunk:
        out = gat_ell_pallas(ell_idx, adst, ell_w, alpha_src, z2d,
                             negative_slope=negative_slope, block_feat=bf,
                             interpret=interpret)
        return out.reshape(rows, heads, feat)
    outs = []
    for lo in range(0, rows, chunk):
        hi = min(lo + chunk, rows)
        outs.append(gat_ell_pallas(
            ell_idx[lo:hi], adst[lo:hi],
            None if ell_w is None else ell_w[lo:hi], alpha_src, z2d,
            negative_slope=negative_slope, block_feat=bf,
            interpret=interpret))
    return jnp.concatenate(outs, axis=0).reshape(rows, heads, feat)


def _gat_panels_backward(ell_idx, adst, ell_w, alpha_src, z, dy,
                         negative_slope: float):
    """VJP of the fused attention w.r.t. (adst, ell_w, alpha_src, z).

    Recomputes the masked softmax over the *same* panels the forward
    consumed (cheap — (R, K, H)), then chains the softmax backward, the
    leaky-relu backward, and two masked scatter-adds back into the dense
    per-node operands. ``dy`` is (R, H, F).
    """
    mask = ell_idx >= 0
    safe = jnp.maximum(ell_idx, 0)
    a32 = alpha_src.astype(jnp.float32)
    raw = a32[safe] + adst.astype(jnp.float32)[:, None, :]    # (R, K, H)
    p = ref.gat_softmax_panels(ell_idx, adst, alpha_src,
                               negative_slope=negative_slope)
    p = p.astype(jnp.float32)
    dy32 = dy.astype(jnp.float32)
    zg = z[safe].astype(jnp.float32)                          # (R, K, H, F)
    dyz = jnp.einsum("rhf,rkhf->rkh", dy32, zg)               # dL/d(p*w)
    w32 = None if ell_w is None else ell_w.astype(jnp.float32)
    dp = dyz if w32 is None else dyz * w32[..., None]
    # masked-softmax backward over the K axis
    ds = p * (dp - (p * dp).sum(axis=1, keepdims=True))
    dlogit = ds * jnp.where(raw >= 0, 1.0, negative_slope)
    dlogit = jnp.where(mask[..., None], dlogit, 0.0)
    d_adst = dlogit.sum(axis=1).astype(adst.dtype)            # (R, H)
    n = alpha_src.shape[0]
    scatter_rows = jnp.where(mask, ell_idx, n).reshape(-1)
    d_asrc = jnp.zeros(alpha_src.shape, jnp.float32).at[scatter_rows].add(
        dlogit.reshape(-1, dlogit.shape[-1]), mode="drop").astype(
        alpha_src.dtype)
    pw = p if w32 is None else p * w32[..., None]
    contrib = jnp.einsum("rkh,rhf->rkhf", pw, dy32)
    d_z = jnp.zeros(z.shape, jnp.float32).at[scatter_rows].add(
        contrib.reshape(-1, z.shape[1], z.shape[2]), mode="drop").astype(
        z.dtype)
    d_w = None
    if ell_w is not None:
        d_w = jnp.where(mask, (p * dyz).sum(-1), 0.0).astype(ell_w.dtype)
    return d_adst, d_w, d_asrc, d_z


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _gat_ell_pallas_diff(negative_slope, interpret, ell_idx, adst, ell_w,
                         alpha_src, z):
    """Differentiable wrapper over the Pallas flash-GAT forward: Pallas runs
    the fused forward, the backward is the panel softmax VJP of
    :func:`_gat_panels_backward` over the same table."""
    return _gat_ell_pallas_chunked(ell_idx, adst, ell_w, alpha_src, z,
                                   negative_slope, interpret)


def _gat_ell_diff_fwd(negative_slope, interpret, ell_idx, adst, ell_w,
                      alpha_src, z):
    out = _gat_ell_pallas_chunked(ell_idx, adst, ell_w, alpha_src, z,
                                  negative_slope, interpret)
    return out, (ell_idx, adst, ell_w, alpha_src, z)


def _gat_ell_diff_bwd(negative_slope, interpret, residuals, dy):
    ell_idx, adst, ell_w, alpha_src, z = residuals
    # Tag the recompute + scatter-adds as the kernel's own backward so the
    # dispatch auditor never reads them as an oracle fallback in grad steps.
    with jax.named_scope("repro_kernel_vjp:gat_ell"):
        d_adst, d_w, d_asrc, d_z = _gat_panels_backward(
            ell_idx, adst, ell_w, alpha_src, z, dy, negative_slope)
    d_idx = np.zeros(ell_idx.shape, jax.dtypes.float0)  # int operand: no ct
    return d_idx, d_adst, d_w, d_asrc, d_z


_gat_ell_pallas_diff.defvjp(_gat_ell_diff_fwd, _gat_ell_diff_bwd)


def _bucket_adst(row_ids: jnp.ndarray, alpha_dst: jnp.ndarray,
                 rows_pad: int) -> jnp.ndarray:
    """Gather the receiver term per bucket row; padding rows get zeros
    (their slots are all-invalid, so the value never contributes)."""
    ids = jnp.asarray(row_ids)
    adst = jnp.where((ids >= 0)[:, None],
                     alpha_dst[jnp.maximum(ids, 0)], 0.0)
    if rows_pad > adst.shape[0]:
        adst = jnp.concatenate(
            [adst, jnp.zeros((rows_pad - adst.shape[0], adst.shape[1]),
                             adst.dtype)], axis=0)
    return adst


def gat_attend_ell(buckets: Sequence[EllBucket], alpha_src: jnp.ndarray,
                   alpha_dst: jnp.ndarray, z: jnp.ndarray,
                   edge_weight: Optional[jnp.ndarray] = None, *,
                   num_rows: int, negative_slope: float = 0.2,
                   force_pallas: Optional[bool] = None,
                   interpret: Optional[bool] = None) -> jnp.ndarray:
    """Bucketed fused GAT aggregation: one kernel launch per bucket.

    ``z`` is (N, H, F) per-head projected features, ``alpha_src`` /
    ``alpha_dst`` the dense (N_src, H) / (N_dst, H) logit halves.
    ``edge_weight`` (the folded explainer mask / per-edge weight) is per
    edge in COO order — each bucket gathers its slots' weights through
    ``ell_pos`` and applies them *after* the softmax (no renormalisation,
    matching the materialised path). Differentiable end to end: the
    per-bucket kernel carries a custom VJP and the gathers/scatters are
    plain XLA ops, so gradients flow to ``alpha_src``, ``alpha_dst``,
    ``z`` and ``edge_weight``. Rows absent from every bucket (degree 0)
    keep the 0 fill; ``-1`` row ids (capacity padding) are masked out of
    the scatter, so bucket arrays may be tracers (jit-argument batches).
    Returns (num_rows, H, F).
    """
    take_pallas = use_pallas() if force_pallas is None else force_pallas
    heads, feat = z.shape[1], z.shape[2]
    out = jnp.zeros((num_rows, heads, feat), z.dtype)
    for row_ids, ell_idx, ell_pos in buckets:
        ell_idx = jnp.asarray(ell_idx)
        adst = _bucket_adst(row_ids, alpha_dst, ell_idx.shape[0])
        w_b = None
        if edge_weight is not None:
            pos = jnp.asarray(ell_pos)
            w_b = jnp.where(pos >= 0,
                            jnp.asarray(edge_weight)[jnp.maximum(pos, 0)],
                            0.0).astype(jnp.float32)
        if take_pallas:
            itp = (jax.default_backend() != "tpu") if interpret is None \
                else interpret
            res = _gat_ell_pallas_diff(float(negative_slope), bool(itp),
                                       ell_idx, adst, w_b, alpha_src, z)
        else:
            res = ref.gat_attend_panels(ell_idx, adst, w_b, alpha_src, z,
                                        negative_slope=negative_slope)
        ids = jnp.asarray(row_ids)
        # Padding ids scatter out of bounds and are dropped.
        ids = jnp.where(ids >= 0, ids, num_rows)
        out = out.at[ids].set(res[: ids.shape[0]].astype(z.dtype),
                              mode="drop")
    return out


def gat_alpha_ell(buckets: Sequence[EllBucket], alpha_src: jnp.ndarray,
                  alpha_dst: jnp.ndarray, *, num_edges: int,
                  negative_slope: float = 0.2) -> jnp.ndarray:
    """Recover per-edge attention coefficients (E, H) from the ELL panels.

    The panels' softmax probabilities are scattered back to COO edge order
    through the COO-keyed ``ell_pos`` — the ``return_attention`` round trip.
    Pure XLA (the (E, H) result is inherently edge-level); padding slots
    scatter out of bounds and drop.
    """
    heads = alpha_src.shape[1]
    alpha = jnp.zeros((num_edges, heads), jnp.float32)
    for row_ids, ell_idx, ell_pos in buckets:
        ell_idx = jnp.asarray(ell_idx)
        adst = _bucket_adst(row_ids, alpha_dst, ell_idx.shape[0])
        p = ref.gat_softmax_panels(ell_idx, adst, alpha_src,
                                   negative_slope=negative_slope)
        pos = jnp.asarray(ell_pos)
        pos = jnp.where(pos >= 0, pos, num_edges).reshape(-1)
        alpha = alpha.at[pos].set(
            p.reshape(-1, heads).astype(jnp.float32), mode="drop")
    return alpha
