"""Public fused-attention entry points with kernel/oracle dispatch.

The attention analogue of ``kernels.spmm.ops``: the same bucketed
blocked-ELL layout (``EllBucket`` triples from the SpMM packers, ``ell_pos``
keyed to COO edge order) drives a *fused* attention aggregation

    out[r, h] = sum_k softmax_k(logit(nbr, r))_k * w[r, k] * z[nbr, h]

per bucket: the Pallas flash kernel on TPU (or when forced), the panel
oracle elsewhere. Two families share the kernel body:

  * ``gat_attend_ell`` / ``gat_alpha_ell`` — GAT's additive leaky-relu
    logit, normalised per relation (unchanged public contract);
  * ``attn_carry_ell`` + ``merge_carries`` + ``finalize_carry`` — the typed
    path: a per-relation logit spec (:class:`AdditiveLogit` /
    :class:`DotLogit` with a per-head ``prior``) and an *unfinalised*
    :class:`SoftmaxCarry` ``(m, l, acc)`` out, so several relation launches
    into the same destination rows merge into ONE cross-type softmax.

The Pallas branches are differentiable at this level — ops-level
``jax.custom_vjp``s recompute the softmax over the same panels and run
their backward in XLA, exactly the PR-4 pattern for SpMM. The raw kernel
entry points stay forward-only behind the shared ``forward_only_pallas``
guard. Stabilizer convention: ``m`` (and the merged max) are
``stop_gradient`` constants — the finalized output is shift-invariant in
them, so the gradient is exact.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import budgets as hw_budgets, use_pallas
from repro.kernels.attention import ref
from repro.kernels.attention.gat_attention import (DEFAULT_BR,
                                                   attn_ell_pallas,
                                                   gat_ell_pallas)
# MAX_PREFETCH_ELEMS comes from the shared budget source of truth (a
# module-level name here so tests can monkeypatch this ops module's chunk
# rule independently of the SpMM one).
from repro.kernels.budgets import MAX_PREFETCH_ELEMS
from repro.kernels.spmm.ops import EllBucket


# ------------------------------------------------------------- logit specs
class AdditiveLogit(NamedTuple):
    """GAT's additive logit: ``leaky_relu(a_src[nbr] + a_dst[row])``.

    Per-head logit width ``LD == 1``; no prior.
    """
    negative_slope: float = 0.2


class DotLogit(NamedTuple):
    """HGT's dot logit: ``<k[nbr, h], q[row, h]> * scale * prior[h]``.

    Per-head logit width ``LD == head_dim``; ``scale`` (typically
    ``1/sqrt(D)``) is folded into the per-head ``prior`` row at launch.
    """
    scale: float = 1.0


LogitSpec = Union[AdditiveLogit, DotLogit]


class SoftmaxCarry(NamedTuple):
    """Running online-softmax state of one (or several merged) launches.

    ``m`` (R, H) masked logit max (-inf on rows with no valid neighbor;
    treated as a stop-gradient constant), ``l`` (R, H) exp-sum against
    ``m``, ``acc`` (R, H, F) the unnormalised weighted accumulator.
    ``finalize_carry`` turns it into the attention output; carries of
    different relations over the same rows combine via ``merge_carries``
    into one cross-type softmax.
    """
    m: jnp.ndarray
    l: jnp.ndarray
    acc: jnp.ndarray


def _logit_kind(logit: LogitSpec) -> str:
    return "add" if isinstance(logit, AdditiveLogit) else "dot"


def _logit_slope(logit: LogitSpec) -> float:
    return logit.negative_slope if isinstance(logit, AdditiveLogit) else 0.0


def _effective_prior(logit: LogitSpec, prior: Optional[jnp.ndarray],
                     heads: int) -> jnp.ndarray:
    """Fold the dot-logit scale into one (H,) f32 prior row.

    The additive logit has no prior semantics — the kernel carries (and
    ignores) a row of ones so the carry launch signature stays static.
    """
    base = (jnp.ones((heads,), jnp.float32) if prior is None
            else jnp.asarray(prior, jnp.float32))
    if isinstance(logit, DotLogit) and logit.scale != 1.0:
        base = base * jnp.float32(logit.scale)
    return base


def _gat_ell_pallas_chunked(ell_idx: jnp.ndarray, adst: jnp.ndarray,
                            ell_w: Optional[jnp.ndarray],
                            alpha_src: jnp.ndarray, z: jnp.ndarray,
                            negative_slope: float,
                            interpret: bool) -> jnp.ndarray:
    """The raw Pallas forward, row-chunked to the SMEM prefetch budget.

    Calls the module-global ``gat_ell_pallas`` (not a captured reference) so
    test spies that monkeypatch the ops attribute still observe every
    launch. Returns (R, H, F).
    """
    rows, k = ell_idx.shape
    heads, feat = z.shape[1], z.shape[2]
    z2d = z.reshape(z.shape[0], heads * feat)
    bf = 128 if feat % 128 == 0 else feat
    # Launch-time backstop against the *declared* hardware budgets (the
    # pack-time check covers loader layouts; ad-hoc buckets land here).
    hw_budgets.check_gat_bucket(rows, k, heads, feat,
                                weighted=ell_w is not None)
    chunk = max(MAX_PREFETCH_ELEMS // max(k, 1), DEFAULT_BR)
    chunk -= chunk % DEFAULT_BR
    if rows <= chunk:
        out = gat_ell_pallas(ell_idx, adst, ell_w, alpha_src, z2d,
                             negative_slope=negative_slope, block_feat=bf,
                             interpret=interpret)
        return out.reshape(rows, heads, feat)
    outs = []
    for lo in range(0, rows, chunk):
        hi = min(lo + chunk, rows)
        outs.append(gat_ell_pallas(
            ell_idx[lo:hi], adst[lo:hi],
            None if ell_w is None else ell_w[lo:hi], alpha_src, z2d,
            negative_slope=negative_slope, block_feat=bf,
            interpret=interpret))
    return jnp.concatenate(outs, axis=0).reshape(rows, heads, feat)


def _gat_panels_backward(ell_idx, adst, ell_w, alpha_src, z, dy,
                         negative_slope: float):
    """VJP of the fused attention w.r.t. (adst, ell_w, alpha_src, z).

    Recomputes the masked softmax over the *same* panels the forward
    consumed (cheap — (R, K, H)), then chains the softmax backward, the
    leaky-relu backward, and two masked scatter-adds back into the dense
    per-node operands. ``dy`` is (R, H, F).
    """
    mask = ell_idx >= 0
    safe = jnp.maximum(ell_idx, 0)
    a32 = alpha_src.astype(jnp.float32)
    raw = a32[safe] + adst.astype(jnp.float32)[:, None, :]    # (R, K, H)
    p = ref.gat_softmax_panels(ell_idx, adst, alpha_src,
                               negative_slope=negative_slope)
    p = p.astype(jnp.float32)
    dy32 = dy.astype(jnp.float32)
    zg = z[safe].astype(jnp.float32)                          # (R, K, H, F)
    dyz = jnp.einsum("rhf,rkhf->rkh", dy32, zg)               # dL/d(p*w)
    w32 = None if ell_w is None else ell_w.astype(jnp.float32)
    dp = dyz if w32 is None else dyz * w32[..., None]
    # masked-softmax backward over the K axis
    ds = p * (dp - (p * dp).sum(axis=1, keepdims=True))
    dlogit = ds * jnp.where(raw >= 0, 1.0, negative_slope)
    dlogit = jnp.where(mask[..., None], dlogit, 0.0)
    d_adst = dlogit.sum(axis=1).astype(adst.dtype)            # (R, H)
    n = alpha_src.shape[0]
    scatter_rows = jnp.where(mask, ell_idx, n).reshape(-1)
    d_asrc = jnp.zeros(alpha_src.shape, jnp.float32).at[scatter_rows].add(
        dlogit.reshape(-1, dlogit.shape[-1]), mode="drop").astype(
        alpha_src.dtype)
    pw = p if w32 is None else p * w32[..., None]
    contrib = jnp.einsum("rkh,rhf->rkhf", pw, dy32)
    d_z = jnp.zeros(z.shape, jnp.float32).at[scatter_rows].add(
        contrib.reshape(-1, z.shape[1], z.shape[2]), mode="drop").astype(
        z.dtype)
    d_w = None
    if ell_w is not None:
        d_w = jnp.where(mask, (p * dyz).sum(-1), 0.0).astype(ell_w.dtype)
    return d_adst, d_w, d_asrc, d_z


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _gat_ell_pallas_diff(negative_slope, interpret, ell_idx, adst, ell_w,
                         alpha_src, z):
    """Differentiable wrapper over the Pallas flash-GAT forward: Pallas runs
    the fused forward, the backward is the panel softmax VJP of
    :func:`_gat_panels_backward` over the same table."""
    return _gat_ell_pallas_chunked(ell_idx, adst, ell_w, alpha_src, z,
                                   negative_slope, interpret)


def _gat_ell_diff_fwd(negative_slope, interpret, ell_idx, adst, ell_w,
                      alpha_src, z):
    out = _gat_ell_pallas_chunked(ell_idx, adst, ell_w, alpha_src, z,
                                  negative_slope, interpret)
    return out, (ell_idx, adst, ell_w, alpha_src, z)


def _gat_ell_diff_bwd(negative_slope, interpret, residuals, dy):
    ell_idx, adst, ell_w, alpha_src, z = residuals
    # Tag the recompute + scatter-adds as the kernel's own backward so the
    # dispatch auditor never reads them as an oracle fallback in grad steps.
    with jax.named_scope("repro_kernel_vjp:gat_ell"):
        d_adst, d_w, d_asrc, d_z = _gat_panels_backward(
            ell_idx, adst, ell_w, alpha_src, z, dy, negative_slope)
    d_idx = np.zeros(ell_idx.shape, jax.dtypes.float0)  # int operand: no ct
    return d_idx, d_adst, d_w, d_asrc, d_z


_gat_ell_pallas_diff.defvjp(_gat_ell_diff_fwd, _gat_ell_diff_bwd)


def _bucket_gather(row_ids: jnp.ndarray, table: jnp.ndarray,
                   rows_pad: int) -> jnp.ndarray:
    """Gather a per-row table (any trailing shape) per bucket row; padding
    rows (-1 ids, capacity fill) get zeros — their slots are all-invalid,
    so the value never contributes."""
    ids = jnp.asarray(row_ids)
    vals = table[jnp.maximum(ids, 0)]
    mask = (ids >= 0).reshape((-1,) + (1,) * (vals.ndim - 1))
    vals = jnp.where(mask, vals, 0.0)
    if rows_pad > vals.shape[0]:
        pad = jnp.zeros((rows_pad - vals.shape[0],) + vals.shape[1:],
                        vals.dtype)
        vals = jnp.concatenate([vals, pad], axis=0)
    return vals


def _bucket_adst(row_ids: jnp.ndarray, alpha_dst: jnp.ndarray,
                 rows_pad: int) -> jnp.ndarray:
    """Gather the (R, H) receiver term per bucket row (GAT layout)."""
    return _bucket_gather(row_ids, alpha_dst, rows_pad)


def _bucket_ell_w(ell_pos, edge_weight) -> Optional[jnp.ndarray]:
    """Per-slot post-softmax weights gathered through COO-keyed ell_pos."""
    if edge_weight is None:
        return None
    pos = jnp.asarray(ell_pos)
    return jnp.where(pos >= 0,
                     jnp.asarray(edge_weight)[jnp.maximum(pos, 0)],
                     0.0).astype(jnp.float32)


# ------------------------------------------------------ typed carry launch
def _attn_ell_pallas_chunked(ell_idx, adst, ell_w, prior, alpha_src, z,
                             logit_kind: str, negative_slope: float,
                             interpret: bool):
    """The raw typed-carry Pallas forward, row-chunked to the SMEM budget.

    ``adst``/``alpha_src`` arrive natural-shaped — (R, H, LD) / (N, H, LD)
    — and are head-flattened here for the kernel. Calls the module-global
    ``attn_ell_pallas`` so test spies observe every launch. Returns the
    ``(m, l, acc)`` triple with ``acc`` reshaped to (R, H, F).
    """
    rows, k = ell_idx.shape
    heads, ld = alpha_src.shape[1], alpha_src.shape[2]
    feat = z.shape[2]
    a2d = alpha_src.reshape(alpha_src.shape[0], heads * ld)
    adst2d = adst.reshape(adst.shape[0], heads * ld)
    z2d = z.reshape(z.shape[0], heads * feat)
    prior2d = jnp.asarray(prior, jnp.float32).reshape(1, heads)
    bf = 128 if feat % 128 == 0 else feat
    # Launch-time backstop against the *declared* hardware budgets, over
    # the full typed shape (prior row + carry buffers included).
    hw_budgets.check_attn_bucket(rows, k, heads, feat, logit_dim=ld,
                                 weighted=ell_w is not None, carry=True)
    chunk = max(MAX_PREFETCH_ELEMS // max(k, 1), DEFAULT_BR)
    chunk -= chunk % DEFAULT_BR
    if rows <= chunk:
        acc, m, l = attn_ell_pallas(ell_idx, adst2d, ell_w, prior2d, a2d,
                                    z2d, logit_kind=logit_kind,
                                    negative_slope=negative_slope,
                                    block_feat=bf, interpret=interpret)
        return m, l, acc.reshape(rows, heads, feat)
    ms, ls, accs = [], [], []
    for lo in range(0, rows, chunk):
        hi = min(lo + chunk, rows)
        acc, m, l = attn_ell_pallas(
            ell_idx[lo:hi], adst2d[lo:hi],
            None if ell_w is None else ell_w[lo:hi], prior2d, a2d, z2d,
            logit_kind=logit_kind, negative_slope=negative_slope,
            block_feat=bf, interpret=interpret)
        ms.append(m)
        ls.append(l)
        accs.append(acc)
    return (jnp.concatenate(ms, axis=0), jnp.concatenate(ls, axis=0),
            jnp.concatenate(accs, axis=0).reshape(rows, heads, feat))


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _attn_ell_carry_diff(logit_kind, negative_slope, interpret, ell_idx,
                         adst, ell_w, prior, alpha_src, z):
    """Differentiable wrapper over the typed-carry Pallas forward: Pallas
    runs the fused forward, the backward is ``jax.vjp`` of the panel carry
    reference over the same table (the merged-carry form: cotangents arrive
    for ``(m, l, acc)``, with the stop-gradded ``m`` contributing zero)."""
    return _attn_ell_pallas_chunked(ell_idx, adst, ell_w, prior, alpha_src,
                                    z, logit_kind, negative_slope, interpret)


def _attn_ell_carry_fwd(logit_kind, negative_slope, interpret, ell_idx,
                        adst, ell_w, prior, alpha_src, z):
    out = _attn_ell_pallas_chunked(ell_idx, adst, ell_w, prior, alpha_src,
                                   z, logit_kind, negative_slope, interpret)
    return out, (ell_idx, adst, ell_w, prior, alpha_src, z)


def _attn_ell_carry_bwd(logit_kind, negative_slope, interpret, residuals,
                        cts):
    ell_idx, adst, ell_w, prior, alpha_src, z = residuals
    have_w = ell_w is not None
    # Tag the recompute as the kernel's own backward so the dispatch
    # auditor never reads it as an oracle fallback in grad steps.
    with jax.named_scope("repro_kernel_vjp:attn_ell"):
        if have_w:
            def f(adst_, w_, prior_, asrc_, z_):
                return ref.attn_carry_panels(
                    ell_idx, adst_, w_, asrc_, z_, logit_kind=logit_kind,
                    negative_slope=negative_slope, prior=prior_)
            _, vjp = jax.vjp(f, adst, ell_w, prior, alpha_src, z)
            d_adst, d_w, d_prior, d_asrc, d_z = vjp(cts)
        else:
            def f(adst_, prior_, asrc_, z_):
                return ref.attn_carry_panels(
                    ell_idx, adst_, None, asrc_, z_, logit_kind=logit_kind,
                    negative_slope=negative_slope, prior=prior_)
            _, vjp = jax.vjp(f, adst, prior, alpha_src, z)
            d_adst, d_prior, d_asrc, d_z = vjp(cts)
            d_w = None
    d_idx = np.zeros(ell_idx.shape, jax.dtypes.float0)  # int operand: no ct
    return d_idx, d_adst, d_w, d_prior, d_asrc, d_z


_attn_ell_carry_diff.defvjp(_attn_ell_carry_fwd, _attn_ell_carry_bwd)


def attn_carry_ell(buckets: Sequence[EllBucket], alpha_src: jnp.ndarray,
                   alpha_dst: jnp.ndarray, z: jnp.ndarray,
                   edge_weight: Optional[jnp.ndarray] = None, *,
                   num_rows: int, logit: LogitSpec,
                   prior: Optional[jnp.ndarray] = None,
                   force_pallas: Optional[bool] = None,
                   interpret: Optional[bool] = None) -> SoftmaxCarry:
    """Bucketed typed-attention carry: one kernel launch per bucket.

    The typed generalisation of :func:`gat_attend_ell` that stops *before*
    the softmax divide: ``z`` is (N, H, F), ``alpha_src`` / ``alpha_dst``
    the (N_src, H, LD) / (N_dst, H, LD) logit operands (2-D inputs get an
    implicit LD=1 axis), ``logit`` the per-relation transform and ``prior``
    its optional per-head scale (``mu[rel]``; ``DotLogit.scale`` is folded
    in). Returns the dense-row :class:`SoftmaxCarry` — merge carries of
    other relations into the same rows with :func:`merge_carries`, then
    :func:`finalize_carry`. Differentiable end to end (the per-bucket
    kernel carries a custom VJP in the merged-carry form).
    """
    take_pallas = use_pallas() if force_pallas is None else force_pallas
    if alpha_src.ndim == 2:
        alpha_src = alpha_src[..., None]
    if alpha_dst.ndim == 2:
        alpha_dst = alpha_dst[..., None]
    heads, feat = z.shape[1], z.shape[2]
    kind = _logit_kind(logit)
    slope = _logit_slope(logit)
    prior_eff = _effective_prior(logit, prior, heads)
    m = jnp.full((num_rows, heads), -jnp.inf, jnp.float32)
    l = jnp.zeros((num_rows, heads), jnp.float32)
    acc = jnp.zeros((num_rows, heads, feat), jnp.float32)
    for row_ids, ell_idx, ell_pos in buckets:
        ell_idx = jnp.asarray(ell_idx)
        adst = _bucket_gather(row_ids, alpha_dst, ell_idx.shape[0])
        w_b = _bucket_ell_w(ell_pos, edge_weight)
        if take_pallas:
            itp = (jax.default_backend() != "tpu") if interpret is None \
                else interpret
            mb, lb, accb = _attn_ell_carry_diff(
                kind, float(slope), bool(itp), ell_idx, adst, w_b,
                prior_eff, alpha_src, z)
        else:
            mb, lb, accb = ref.attn_carry_panels(
                ell_idx, adst, w_b, alpha_src, z, logit_kind=kind,
                negative_slope=slope,
                prior=prior_eff if kind == "dot" else None)
        ids = jnp.asarray(row_ids)
        # Padding ids scatter out of bounds and are dropped.
        ids = jnp.where(ids >= 0, ids, num_rows)
        n_ids = ids.shape[0]
        m = m.at[ids].set(mb[:n_ids], mode="drop")
        l = l.at[ids].set(lb[:n_ids], mode="drop")
        acc = acc.at[ids].set(accb[:n_ids], mode="drop")
    return SoftmaxCarry(m, l, acc)


def merge_carries(carries: Sequence[SoftmaxCarry]) -> SoftmaxCarry:
    """Combine per-relation carries over the same rows into one softmax.

    ``M = max_r m_r``; ``l = sum_r l_r * exp(m_r - M)``; ``acc = sum_r
    acc_r * exp(m_r - M)`` — after ``finalize_carry`` this equals the
    softmax over the union of all relations' edges (the cross-type
    softmax). All stabilizers are stop-gradient constants: the finalized
    output is shift-invariant in them, so the merged custom-VJP gradient
    stays exact.
    """
    carries = list(carries)
    if len(carries) == 1:
        return carries[0]
    stabs = [jax.lax.stop_gradient(c.m) for c in carries]
    m = functools.reduce(jnp.maximum, stabs)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    l = jnp.zeros_like(carries[0].l)
    acc = jnp.zeros_like(carries[0].acc)
    for c, mr in zip(carries, stabs):
        scale = jnp.exp(mr - m_safe)  # exp(-inf) = 0: empty relation rows
        l = l + c.l * scale
        acc = acc + c.acc * scale[..., None]
    return SoftmaxCarry(m, l, acc)


def finalize_carry(carry: SoftmaxCarry,
                   dtype: Optional[jnp.dtype] = None) -> jnp.ndarray:
    """Normalise a (merged) carry: ``acc / max(l, 1e-16)`` — rows that saw
    no valid neighbor in any relation keep the 0 fill (the kernel's
    empty-segment convention)."""
    out = carry.acc / jnp.maximum(carry.l, 1e-16)[..., None]
    return out if dtype is None else out.astype(dtype)


def gat_attend_ell(buckets: Sequence[EllBucket], alpha_src: jnp.ndarray,
                   alpha_dst: jnp.ndarray, z: jnp.ndarray,
                   edge_weight: Optional[jnp.ndarray] = None, *,
                   num_rows: int, negative_slope: float = 0.2,
                   force_pallas: Optional[bool] = None,
                   interpret: Optional[bool] = None) -> jnp.ndarray:
    """Bucketed fused GAT aggregation: one kernel launch per bucket.

    ``z`` is (N, H, F) per-head projected features, ``alpha_src`` /
    ``alpha_dst`` the dense (N_src, H) / (N_dst, H) logit halves.
    ``edge_weight`` (the folded explainer mask / per-edge weight) is per
    edge in COO order — each bucket gathers its slots' weights through
    ``ell_pos`` and applies them *after* the softmax (no renormalisation,
    matching the materialised path). Differentiable end to end: the
    per-bucket kernel carries a custom VJP and the gathers/scatters are
    plain XLA ops, so gradients flow to ``alpha_src``, ``alpha_dst``,
    ``z`` and ``edge_weight``. Rows absent from every bucket (degree 0)
    keep the 0 fill; ``-1`` row ids (capacity padding) are masked out of
    the scatter, so bucket arrays may be tracers (jit-argument batches).
    Returns (num_rows, H, F).
    """
    take_pallas = use_pallas() if force_pallas is None else force_pallas
    heads, feat = z.shape[1], z.shape[2]
    out = jnp.zeros((num_rows, heads, feat), z.dtype)
    for row_ids, ell_idx, ell_pos in buckets:
        ell_idx = jnp.asarray(ell_idx)
        adst = _bucket_adst(row_ids, alpha_dst, ell_idx.shape[0])
        w_b = None
        if edge_weight is not None:
            pos = jnp.asarray(ell_pos)
            w_b = jnp.where(pos >= 0,
                            jnp.asarray(edge_weight)[jnp.maximum(pos, 0)],
                            0.0).astype(jnp.float32)
        if take_pallas:
            itp = (jax.default_backend() != "tpu") if interpret is None \
                else interpret
            res = _gat_ell_pallas_diff(float(negative_slope), bool(itp),
                                       ell_idx, adst, w_b, alpha_src, z)
        else:
            res = ref.gat_attend_panels(ell_idx, adst, w_b, alpha_src, z,
                                        negative_slope=negative_slope)
        ids = jnp.asarray(row_ids)
        # Padding ids scatter out of bounds and are dropped.
        ids = jnp.where(ids >= 0, ids, num_rows)
        out = out.at[ids].set(res[: ids.shape[0]].astype(z.dtype),
                              mode="drop")
    return out


def gat_alpha_ell(buckets: Sequence[EllBucket], alpha_src: jnp.ndarray,
                  alpha_dst: jnp.ndarray, *, num_edges: int,
                  negative_slope: float = 0.2) -> jnp.ndarray:
    """Recover per-edge attention coefficients (E, H) from the ELL panels.

    The panels' softmax probabilities are scattered back to COO edge order
    through the COO-keyed ``ell_pos`` — the ``return_attention`` round trip.
    Pure XLA (the (E, H) result is inherently edge-level); padding slots
    scatter out of bounds and drop.
    """
    heads = alpha_src.shape[1]
    alpha = jnp.zeros((num_edges, heads), jnp.float32)
    for row_ids, ell_idx, ell_pos in buckets:
        ell_idx = jnp.asarray(ell_idx)
        adst = _bucket_adst(row_ids, alpha_dst, ell_idx.shape[0])
        p = ref.gat_softmax_panels(ell_idx, adst, alpha_src,
                                   negative_slope=negative_slope)
        pos = jnp.asarray(ell_pos)
        pos = jnp.where(pos >= 0, pos, num_edges).reshape(-1)
        alpha = alpha.at[pos].set(
            p.reshape(-1, heads).astype(jnp.float32), mode="drop")
    return alpha


def attn_alpha_ell(buckets: Sequence[EllBucket], alpha_src: jnp.ndarray,
                   alpha_dst: jnp.ndarray, *, num_edges: int,
                   logit: LogitSpec, prior: Optional[jnp.ndarray] = None,
                   m: jnp.ndarray, l: jnp.ndarray) -> jnp.ndarray:
    """Per-edge attention (E, H) against *merged* softmax statistics.

    The typed ``return_attention`` round trip: ``m`` / ``l`` are the
    (num_rows, H) carry stats after :func:`merge_carries`, so the returned
    coefficients of every relation into a destination row jointly sum to 1
    (cross-type softmax). Per-slot probabilities are scattered back to COO
    edge order through the COO-keyed ``ell_pos``; pure XLA.
    """
    if alpha_src.ndim == 2:
        alpha_src = alpha_src[..., None]
    if alpha_dst.ndim == 2:
        alpha_dst = alpha_dst[..., None]
    heads = m.shape[1]
    kind = _logit_kind(logit)
    slope = _logit_slope(logit)
    prior_eff = _effective_prior(logit, prior, heads) if kind == "dot" \
        else None
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    l_safe = jnp.maximum(l, 1e-16)
    alpha = jnp.zeros((num_edges, heads), jnp.float32)
    for row_ids, ell_idx, ell_pos in buckets:
        ell_idx = jnp.asarray(ell_idx)
        rows_pad = ell_idx.shape[0]
        adst = _bucket_gather(row_ids, alpha_dst, rows_pad)
        logits, mask = ref.attn_logit_panels(
            ell_idx, adst, alpha_src, logit_kind=kind,
            negative_slope=slope, prior=prior_eff)
        mrow = _bucket_gather(row_ids, m_safe, rows_pad)    # (R, H)
        lrow = jnp.maximum(_bucket_gather(row_ids, l_safe, rows_pad), 1e-16)
        p = jnp.where(mask[..., None],
                      jnp.exp(logits - mrow[:, None, :]) / lrow[:, None, :],
                      0.0)
        pos = jnp.asarray(ell_pos)
        pos = jnp.where(pos >= 0, pos, num_edges).reshape(-1)
        alpha = alpha.at[pos].set(
            p.reshape(-1, heads).astype(jnp.float32), mode="drop")
    return alpha
