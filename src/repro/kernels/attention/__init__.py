"""Fused GAT attention aggregation over the bucketed blocked-ELL layout.

``gat_attention.py`` holds the Pallas flash-GAT kernel (online masked
softmax + pipelined DMA gathers), ``ops.py`` the differentiable dispatching
wrappers (``gat_attend_ell`` / ``gat_alpha_ell``), ``ref.py`` the panel
oracle.
"""
