"""Fused typed attention over the bucketed blocked-ELL layout.

``gat_attention.py`` holds the Pallas flash kernel (online masked softmax +
pipelined DMA gathers) — one kernel body, two logit transforms and two
output modes:

* **logit transform** — ``"add"`` is GAT's additive leaky-relu over scalar
  per-head halves; ``"dot"`` is the scaled dot product over head-dim-wide
  halves times a per-head typed prior (HGT's ``mu[rel]``). The additive
  launches still stamp the historical ``_gat_ell_kernel`` name into the
  jaxpr (a thin delegator), so existing dispatch audits are unaffected;
  typed carry launches audit as ``_attn_ell_kernel``.
* **output mode** — normalised output (GAT), or the raw softmax carry.

``ops.py`` is the differentiable public surface (``gat_attend_ell`` /
``gat_alpha_ell`` / ``attn_carry_ell`` / ``merge_carries`` /
``finalize_carry`` / ``attn_alpha_ell``), ``ref.py`` the panel/COO oracles.

Carry-merge cross-type softmax convention
-----------------------------------------
A carry is the online-softmax state ``SoftmaxCarry(m, l, acc)`` per
destination row and head: ``m`` the running masked logit max (``-inf`` on
rows the relation never touches), ``l`` the *unweighted* exp-sum
``sum_j exp(logit_j - m)``, ``acc`` the *weighted* unnormalised accumulator
``sum_j exp(logit_j - m) * w_j * z_j`` (edge weights hit the numerator
only — no renormalisation, matching the materialised path). Merging R
relations targeting the same rows::

    M      = max_r m_r                      # stop_gradient'd stabilizer
    M_safe = where(isfinite(M), M, 0)       # all-empty rows stay defined
    l      = sum_r l_r  * exp(m_r - M_safe)
    acc    = sum_r acc_r * exp(m_r - M_safe)
    out    = acc / max(l, 1e-16)            # finalize_carry

``exp(-inf - M_safe) = 0`` makes empty relation rows vanish from the sums,
so the merged result equals one softmax over the UNION of all relations'
incoming edges — the HGT cross-type softmax — without ever materialising
cross-relation logits. All stabilizers (``m`` inside kernels/refs, ``M`` at
merge time) are ``jax.lax.stop_gradient`` constants: the finalized output
is shift-invariant in them, so gradients are exact.
"""
