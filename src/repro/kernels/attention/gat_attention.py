"""Fused typed-attention aggregation — blocked-ELL Pallas TPU kernel.

ONE kernel body (``_attn_ell_kernel``) fuses the whole attention
aggregation of a graph-attention layer over the same bucketed blocked-ELL
layout the SpMM kernel consumes:

    gather sender term[nbr] -> per-relation logit -> masked row softmax
      -> weighted accumulate of z[nbr]

in a single VMEM pass per row block (flash style): the softmax runs
*online* — a running max / running sum rescale the feature accumulator as
neighbor columns stream in — so the ``(E, H, F)`` edge-message tensor of the
materialised path is never built. Per neighbor column the kernel issues two
batches of async HBM->VMEM copies (the ``(1, F)`` feature row and the
``(1, H*LD)`` sender-term row of each neighbor), double-buffered exactly
like the SpMM kernel's pipelined gather, with the scalar-prefetched neighbor
table as the DMA address stream.

The logit transform is a static template parameter (``logit_kind``):

  * ``"add"`` — GAT's additive leaky-relu logit, ``LD = 1``
    (``leaky(alpha_src[nbr] + alpha_dst[row])``);
  * ``"dot"`` — HGT's scaled dot product, ``LD = head_dim``
    (``sum_d k[nbr, h, d] * q[row, h, d] * prior[h]`` — the relation prior
    ``mu[rel]/sqrt(D)`` enters as a ``(1, H)`` VMEM row).

``return_carry=True`` additionally emits the running softmax carry
``(m, l)`` next to the *unnormalised* accumulator, so several per-relation
launches targeting the same destination rows can be merged ops-side into
one cross-relation softmax (see ``kernels/attention/__init__.py`` for the
merge convention).

Layout: ``z`` arrives flattened to ``(N, H*F)`` so the head axis rides the
feature grid dimension (the per-head feature slice starts at ``h * F``) and
the DMA indexing stays 2-D. The receiver term is pre-gathered per bucket
row host/XLA-side (it is keyed by *row ids*, not by the neighbor table) and
enters as a dense ``(R, H*LD)`` VMEM panel.

Grid: ``(num_row_blocks, heads, num_feat_blocks)``; each (row, head, feat)
tile recomputes the cheap ``(BR, K)`` online softmax and is written once
(the tiny ``(BR, 1)`` carry blocks are revisited across feat tiles with
identical values).

``_gat_ell_kernel`` is a named delegator to the same body: Pallas reports
the kernel *function name* in the jaxpr, and the dispatch auditor / cost
table key on it — additive launches keep auditing as ``_gat_ell_kernel``,
typed carry launches as ``_attn_ell_kernel``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.budgets import DEFAULT_BF, DEFAULT_BR
_NUM_SLOTS = 2  # double buffering


def _attn_ell_kernel(idx_sref, idx_ref, adst_ref, w_ref, *rest,
                     block_rows: int, block_feat: int, k: int, heads: int,
                     feat: int, negative_slope: float, has_weight: bool,
                     logit_kind: str = "add", logit_dim: int = 1,
                     return_carry: bool = False):
    """One (row_block, head, feat_block) tile: online-softmax accumulate.

    ``idx_sref``   full (R, K) neighbor table, scalar-prefetched (SMEM) — the
                   DMA address stream.
    ``idx_ref``    (BR, K) VMEM panel of the same table — vectorized masking.
    ``adst_ref``   (BR, H*LD) VMEM panel: receiver term per bucket row
                   (alpha_dst for additive logits, q for dot logits).
    ``rest``       [prior_ref] asrc_hbm z_hbm out_ref [m_ref l_ref]
                   zgather agather sems — the prior operand and the carry
                   outputs exist only on ``return_carry`` launches.
    ``zgather``    (2, BR, BF) VMEM scratch — feature-row landing zone.
    ``agather``    (2, BR, H*LD) VMEM scratch — sender-row landing zone.
    ``sems``       (2, 2, BR) DMA semaphores: [0] features, [1] alphas.
    """
    if return_carry:
        (prior_ref, asrc_hbm, z_hbm, out_ref, m_ref, l_ref, zgather,
         agather, sems) = rest
    else:
        asrc_hbm, z_hbm, out_ref, zgather, agather, sems = rest
    r_blk = pl.program_id(0)
    h = pl.program_id(1)
    f_blk = pl.program_id(2)
    row_base = r_blk * block_rows
    # z is (N, H*F): head h's feature block starts at h*F + f_blk*BF.
    f_start = h * feat + f_blk * block_feat

    def z_dma(slot, kk, r):
        nid = jnp.maximum(idx_sref[row_base + r, kk], 0)
        return pltpu.make_async_copy(
            z_hbm.at[pl.dslice(nid, 1), pl.dslice(f_start, block_feat)],
            zgather.at[slot, pl.dslice(r, 1), :],
            sems.at[0, slot, r],
        )

    def a_dma(slot, kk, r):
        nid = jnp.maximum(idx_sref[row_base + r, kk], 0)
        return pltpu.make_async_copy(
            asrc_hbm.at[pl.dslice(nid, 1), :],
            agather.at[slot, pl.dslice(r, 1), :],
            sems.at[1, slot, r],
        )

    def start_column(slot, kk):
        def body_r(r, carry):
            z_dma(slot, kk, r).start()
            a_dma(slot, kk, r).start()
            return carry
        jax.lax.fori_loop(0, block_rows, body_r, 0)

    def wait_column(slot, kk):
        def body_r(r, carry):
            z_dma(slot, kk, r).wait()
            a_dma(slot, kk, r).wait()
            return carry
        jax.lax.fori_loop(0, block_rows, body_r, 0)

    idx_panel = idx_ref[...]  # (BR, K)
    if logit_kind == "add":
        adst_col = jax.lax.dynamic_slice_in_dim(
            adst_ref[...].astype(jnp.float32), h, 1, 1)  # (BR, 1): this head
    else:  # dot: this head's (BR, LD) query slice + scalar prior
        q_col = jax.lax.dynamic_slice_in_dim(
            adst_ref[...].astype(jnp.float32), h * logit_dim, logit_dim, 1)
        prior_col = jax.lax.dynamic_slice_in_dim(
            prior_ref[...].astype(jnp.float32), h, 1, 1)  # (1, 1)
    if has_weight:
        w_panel = w_ref[...].astype(jnp.float32)

    # Warm-up: put column 0 in flight before entering the steady state.
    start_column(0, 0)

    m0 = jnp.full((block_rows, 1), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((block_rows, 1), jnp.float32)
    acc0 = jnp.zeros((block_rows, block_feat), jnp.float32)

    def body_k(kk, carry):
        m, l, acc = carry
        slot = jax.lax.rem(kk, _NUM_SLOTS)

        # Prefetch column kk+1 into the other slot while kk lands/computes.
        @pl.when(kk + 1 < k)
        def _():
            start_column(1 - slot, kk + 1)

        wait_column(slot, kk)
        ztile = zgather[slot].astype(jnp.float32)   # (BR, BF)
        arows = agather[slot].astype(jnp.float32)   # (BR, H*LD)

        col_idx = jax.lax.dynamic_slice_in_dim(idx_panel, kk, 1, 1)  # (BR, 1)
        valid = col_idx >= 0
        if logit_kind == "add":
            a_col = jax.lax.dynamic_slice_in_dim(arows, h, 1, 1)  # (BR, 1)
            logit = a_col + adst_col
            logit = jnp.where(logit >= 0, logit, negative_slope * logit)
        else:  # dot: <k[nbr], q[row]> over this head's LD lanes, scaled
            a_sl = jax.lax.dynamic_slice_in_dim(
                arows, h * logit_dim, logit_dim, 1)  # (BR, LD)
            logit = jnp.sum(a_sl * q_col, axis=1, keepdims=True) * prior_col
        logit = jnp.where(valid, logit, -jnp.inf)

        # Online softmax: rescale the accumulator by exp(m - m_new). While a
        # row has seen no valid neighbor m is -inf and every term is 0.
        m_new = jnp.maximum(m, logit)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.where(valid, jnp.exp(logit - m_safe), 0.0)    # (BR, 1)
        corr = jnp.exp(m - m_safe)  # exp(-inf) = 0 zeroes the empty prefix
        num = p if not has_weight else p * jax.lax.dynamic_slice_in_dim(
            w_panel, kk, 1, 1)
        return m_new, l * corr + p, acc * corr + num * ztile

    m, l, acc = jax.lax.fori_loop(0, k, body_k, (m0, l0, acc0))
    if return_carry:
        # Unnormalised carry out: the ops layer merges (m, l, acc) triples
        # across relation launches before the single finalize divide. The
        # (BR, 1) carry blocks are revisited per feat tile — same values.
        out_ref[...] = acc.astype(out_ref.dtype)
        m_ref[...] = m.astype(m_ref.dtype)
        l_ref[...] = l.astype(l_ref.dtype)
    else:
        # acc/l = sum_k softmax_k(logits) * w_k * z_k; empty rows stay 0.
        out_ref[...] = (acc / jnp.maximum(l, 1e-16)).astype(out_ref.dtype)


def _gat_ell_kernel(*args, **kwargs):
    """Additive-logit launch face of :func:`_attn_ell_kernel`.

    Exists for its ``__name__``: Pallas stamps the kernel function name into
    the jaxpr, and the dispatch auditor / FLOP cost table key on it.
    """
    return _attn_ell_kernel(*args, **kwargs)


@functools.partial(
    jax.jit,
    static_argnames=("negative_slope", "block_rows", "block_feat",
                     "interpret"),
)
def _gat_ell_pallas_impl(ell_idx: jnp.ndarray, adst: jnp.ndarray,
                         ell_w: Optional[jnp.ndarray], alpha_src: jnp.ndarray,
                         z2d: jnp.ndarray, *, negative_slope: float = 0.2,
                         block_rows: int = DEFAULT_BR,
                         block_feat: Optional[int] = None,
                         interpret: bool = False) -> jnp.ndarray:
    """Fused GAT aggregation over one blocked-ELL bucket.

    Args:
      ell_idx:   (R, K) int32 neighbor table, -1 = padding. R % BR == 0.
      adst:      (R, H) alpha_dst values of each bucket row (receiver term).
      ell_w:     optional (R, K) per-slot post-softmax weights (edge_mask /
                 edge_weight gathered through ``ell_pos``).
      alpha_src: (N, H) dense per-node sender term (gathered in-kernel).
      z2d:       (N, H*F) head-flattened features (gathered in-kernel).

    Returns ``(R, H*F)``: per row, head h's slice is the attention-weighted
    neighbor sum for that head.
    """
    rows, k = ell_idx.shape
    heads = adst.shape[1]
    hf = z2d.shape[1]
    assert hf % heads == 0, (hf, heads)
    feat = hf // heads
    if block_feat is None:  # lane-width tile when it divides, else whole F
        block_feat = DEFAULT_BF if feat % DEFAULT_BF == 0 else feat
    assert rows % block_rows == 0, (rows, block_rows)
    assert feat % block_feat == 0, (feat, block_feat)
    assert k >= 1, "ELL table must have at least one neighbor column"
    nfb = feat // block_feat
    grid = (rows // block_rows, heads, nfb)

    has_weight = ell_w is not None
    if ell_w is None:  # dummy operand keeps the signature static
        ell_w = jnp.zeros((block_rows, k), jnp.float32)

    kernel = functools.partial(
        _gat_ell_kernel, block_rows=block_rows, block_feat=block_feat, k=k,
        heads=heads, feat=feat, negative_slope=float(negative_slope),
        has_weight=has_weight)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,  # the neighbor table: DMA address stream
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, k), lambda i, h, j, idx: (i, 0)),
            pl.BlockSpec((block_rows, heads), lambda i, h, j, idx: (i, 0)),
            pl.BlockSpec((block_rows, k), lambda i, h, j, idx: (i, 0))
            if has_weight else
            pl.BlockSpec((block_rows, k), lambda i, h, j, idx: (0, 0)),
            # alpha_src and z stay in HBM; the kernel DMA-gathers rows out.
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=pl.BlockSpec((block_rows, block_feat),
                               lambda i, h, j, idx: (i, h * nfb + j)),
        scratch_shapes=[
            pltpu.VMEM((_NUM_SLOTS, block_rows, block_feat), z2d.dtype),
            pltpu.VMEM((_NUM_SLOTS, block_rows, heads), alpha_src.dtype),
            pltpu.SemaphoreType.DMA((2, _NUM_SLOTS, block_rows)),
        ],
    )

    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((rows, hf), z2d.dtype),
        interpret=interpret,
    )(ell_idx, ell_idx, adst, ell_w, alpha_src, z2d)


from repro.kernels import forward_only_pallas

_gat_ell_pallas_cv = forward_only_pallas(
    lambda negative_slope, block_rows, block_feat, interpret, ell_idx, adst,
    ell_w, alpha_src, z2d:
        _gat_ell_pallas_impl(ell_idx, adst, ell_w, alpha_src, z2d,
                             negative_slope=negative_slope,
                             block_rows=block_rows, block_feat=block_feat,
                             interpret=interpret),
    num_static=4,
    message=(
        "gat_ell_pallas is the raw Pallas kernel and has no backward rule. "
        "Differentiate through the ops-level entry points instead "
        "(repro.kernels.attention.ops.gat_attend_ell carries a custom VJP "
        "— the softmax backward over the same ELL panels), or set "
        "REPRO_USE_PALLAS=0 to dispatch the differentiable XLA oracle."))


def gat_ell_pallas(ell_idx: jnp.ndarray, adst: jnp.ndarray,
                   ell_w: Optional[jnp.ndarray], alpha_src: jnp.ndarray,
                   z2d: jnp.ndarray, *, negative_slope: float = 0.2,
                   block_rows: int = DEFAULT_BR,
                   block_feat: Optional[int] = None,
                   interpret: bool = False) -> jnp.ndarray:
    """Fused GAT attention kernel (see :func:`_gat_ell_pallas_impl`).

    Forward-only: differentiating this raw entry point raises a clear
    ``NotImplementedError`` pointing at the ops-level wrapper (which carries
    the custom VJP) and the ``REPRO_USE_PALLAS`` fallback env var.
    """
    return _gat_ell_pallas_cv(float(negative_slope), block_rows, block_feat,
                              interpret, ell_idx, adst, ell_w, alpha_src,
                              z2d)


@functools.partial(
    jax.jit,
    static_argnames=("logit_kind", "negative_slope", "block_rows",
                     "block_feat", "interpret"),
)
def _attn_ell_pallas_impl(ell_idx: jnp.ndarray, adst: jnp.ndarray,
                          ell_w: Optional[jnp.ndarray], prior: jnp.ndarray,
                          alpha_src: jnp.ndarray, z2d: jnp.ndarray, *,
                          logit_kind: str, negative_slope: float = 0.2,
                          block_rows: int = DEFAULT_BR,
                          block_feat: Optional[int] = None,
                          interpret: bool = False):
    """Typed-attention carry launch over one blocked-ELL bucket.

    Args:
      ell_idx:   (R, K) int32 neighbor table, -1 = padding. R % BR == 0.
      adst:      (R, H*LD) receiver term per bucket row (alpha_dst / q).
      ell_w:     optional (R, K) per-slot post-softmax weights.
      prior:     (1, H) per-head logit scale (mu[rel]/sqrt(D); used by the
                 dot logit, carried-but-ignored by the additive one).
      alpha_src: (N, H*LD) dense per-node sender term (gathered in-kernel).
      z2d:       (N, H*F) head-flattened features (gathered in-kernel).

    Returns ``(acc, m, l)`` float32: the *unnormalised* accumulator
    ``(R, H*F)`` plus the per-(row, head) running softmax max/denominator —
    mergeable across relation launches, finalized ops-side.
    """
    rows, k = ell_idx.shape
    heads = prior.shape[1]
    hl = adst.shape[1]
    hf = z2d.shape[1]
    assert hl % heads == 0, (hl, heads)
    assert hf % heads == 0, (hf, heads)
    logit_dim = hl // heads
    feat = hf // heads
    if block_feat is None:
        block_feat = DEFAULT_BF if feat % DEFAULT_BF == 0 else feat
    assert rows % block_rows == 0, (rows, block_rows)
    assert feat % block_feat == 0, (feat, block_feat)
    assert k >= 1, "ELL table must have at least one neighbor column"
    nfb = feat // block_feat
    grid = (rows // block_rows, heads, nfb)

    has_weight = ell_w is not None
    if ell_w is None:  # dummy operand keeps the signature static
        ell_w = jnp.zeros((block_rows, k), jnp.float32)

    kernel = functools.partial(
        _attn_ell_kernel, block_rows=block_rows, block_feat=block_feat, k=k,
        heads=heads, feat=feat, negative_slope=float(negative_slope),
        has_weight=has_weight, logit_kind=logit_kind, logit_dim=logit_dim,
        return_carry=True)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, k), lambda i, h, j, idx: (i, 0)),
            pl.BlockSpec((block_rows, hl), lambda i, h, j, idx: (i, 0)),
            pl.BlockSpec((block_rows, k), lambda i, h, j, idx: (i, 0))
            if has_weight else
            pl.BlockSpec((block_rows, k), lambda i, h, j, idx: (0, 0)),
            pl.BlockSpec((1, heads), lambda i, h, j, idx: (0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, block_feat),
                         lambda i, h, j, idx: (i, h * nfb + j)),
            pl.BlockSpec((block_rows, 1), lambda i, h, j, idx: (i, h)),
            pl.BlockSpec((block_rows, 1), lambda i, h, j, idx: (i, h)),
        ],
        scratch_shapes=[
            pltpu.VMEM((_NUM_SLOTS, block_rows, block_feat), z2d.dtype),
            pltpu.VMEM((_NUM_SLOTS, block_rows, hl), alpha_src.dtype),
            pltpu.SemaphoreType.DMA((2, _NUM_SLOTS, block_rows)),
        ],
    )

    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((rows, hf), jnp.float32),
            jax.ShapeDtypeStruct((rows, heads), jnp.float32),
            jax.ShapeDtypeStruct((rows, heads), jnp.float32),
        ],
        interpret=interpret,
    )(ell_idx, ell_idx, adst, ell_w, prior, alpha_src, z2d)


_attn_ell_pallas_cv = forward_only_pallas(
    lambda logit_kind, negative_slope, block_rows, block_feat, interpret,
    ell_idx, adst, ell_w, prior, alpha_src, z2d:
        _attn_ell_pallas_impl(ell_idx, adst, ell_w, prior, alpha_src, z2d,
                              logit_kind=logit_kind,
                              negative_slope=negative_slope,
                              block_rows=block_rows, block_feat=block_feat,
                              interpret=interpret),
    num_static=5,
    message=(
        "attn_ell_pallas is the raw Pallas kernel and has no backward rule. "
        "Differentiate through the ops-level entry points instead "
        "(repro.kernels.attention.ops.attn_carry_ell carries a custom VJP "
        "over the merged-carry form), or set REPRO_USE_PALLAS=0 to dispatch "
        "the differentiable XLA oracle."))


def attn_ell_pallas(ell_idx: jnp.ndarray, adst: jnp.ndarray,
                    ell_w: Optional[jnp.ndarray], prior: jnp.ndarray,
                    alpha_src: jnp.ndarray, z2d: jnp.ndarray, *,
                    logit_kind: str = "dot", negative_slope: float = 0.2,
                    block_rows: int = DEFAULT_BR,
                    block_feat: Optional[int] = None,
                    interpret: bool = False):
    """Typed-attention carry kernel (see :func:`_attn_ell_pallas_impl`).

    Forward-only: differentiating this raw entry point raises a clear
    ``NotImplementedError`` pointing at the ops-level wrapper (which carries
    the custom VJP) and the ``REPRO_USE_PALLAS`` fallback env var.
    """
    return _attn_ell_pallas_cv(str(logit_kind), float(negative_slope),
                               block_rows, block_feat, interpret, ell_idx,
                               adst, ell_w, prior, alpha_src, z2d)
