"""Pure-jnp oracle for the fused typed-attention aggregation.

Same math as the Pallas kernel — per-relation logits (additive leaky-relu
or scaled dot product + typed prior), masked row softmax, weighted
accumulate — over the ``(R, K)`` blocked-ELL panels and the COO edge list,
written as plain XLA ops. Used for validation, as the CPU/GPU dispatch
target, and as the recompute inside the ops-level custom VJPs.

Convention for the carry references: the softmax stabilizers (the running
max ``m``, and the merged max at carry-merge time) are ``stop_gradient``
constants. The normalised output is shift-invariant in them, so this is the
exact gradient — minus the float cancellation noise of differentiating
through a max.
"""

from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.segment_softmax import ref as softmax_ref


def gat_attend_coo(send: jnp.ndarray, recv: jnp.ndarray,
                   a_send: jnp.ndarray, a_recv: jnp.ndarray,
                   z_send: jnp.ndarray, *, num_rows: int,
                   negative_slope: float = 0.2,
                   edge_weight: Optional[jnp.ndarray] = None,
                   message_callback: Optional[Callable] = None
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """COO-level attention aggregation oracle: ``(out, alpha)``.

    The single source of truth for the edge-materialising fallback (both
    ``EdgeIndex.attend`` and ``MessagePassing._propagate_attention`` call
    it), so fused-vs-fallback numerics can never drift between entry
    points. ``edge_weight`` multiplies messages *after* the softmax (no
    renormalisation); ``message_callback`` observes the flattened
    ``(E, H*F)`` messages (the explainer's c(.) hook).
    """
    with jax.named_scope("repro_oracle:gat_attend_coo"):
        logits = a_send[send] + a_recv[recv]                # (E, H)
        logits = jax.nn.leaky_relu(logits, negative_slope)
        alpha = softmax_ref.segment_softmax(logits, recv, num_rows)
        msg = z_send[send] * alpha[..., None]               # (E, H, F)
        if edge_weight is not None:
            msg = msg * edge_weight[:, None, None].astype(msg.dtype)
        if message_callback is not None:
            msg = message_callback(msg.reshape(msg.shape[0], -1)).reshape(
                msg.shape)
        out = jax.ops.segment_sum(msg, recv, num_segments=num_rows)
    return out, alpha


def gat_softmax_panels(ell_idx: jnp.ndarray, adst: jnp.ndarray,
                       alpha_src: jnp.ndarray, *,
                       negative_slope: float = 0.2) -> jnp.ndarray:
    """Per-slot attention probabilities ``p`` of shape (R, K, H).

    ``ell_idx`` (R, K) neighbor table (-1 = padding), ``adst`` (R, H) the
    receiver term per row, ``alpha_src`` (N, H) the sender term per node.
    Padding slots get p = 0; all-padding rows a 0 row (the kernel's empty-
    segment convention).
    """
    mask = ell_idx >= 0
    safe = jnp.maximum(ell_idx, 0)
    raw = alpha_src[safe] + adst[:, None, :]            # (R, K, H)
    logits = jnp.where(raw >= 0, raw, negative_slope * raw)
    neg = jnp.where(mask[..., None], logits, -jnp.inf)
    mx = jnp.max(neg, axis=1, keepdims=True)
    mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
    ex = jnp.where(mask[..., None], jnp.exp(logits - mx), 0.0)
    den = jnp.maximum(ex.sum(axis=1, keepdims=True), 1e-16)
    return ex / den


def gat_attend_panels(ell_idx: jnp.ndarray, adst: jnp.ndarray,
                      ell_w: Optional[jnp.ndarray], alpha_src: jnp.ndarray,
                      z: jnp.ndarray, *,
                      negative_slope: float = 0.2) -> jnp.ndarray:
    """Oracle fused attention over one bucket: (R, H, F).

    ``z`` is (N, H, F); ``ell_w`` optional (R, K) post-softmax per-slot
    weights (the explainer mask / edge weight — applied to the numerator
    only, no renormalisation, matching the materialised path).

    Scoped ``repro_oracle`` for the dispatch auditor: this is the panel
    fallback of ``gat_attend_ell``. (The kernel's own backward recomputes
    the softmax via ``gat_softmax_panels`` directly — inside a
    ``repro_kernel_vjp`` scope, which takes classification precedence.)
    """
    with jax.named_scope("repro_oracle:gat_attend_panels"):
        p = gat_softmax_panels(ell_idx, adst, alpha_src,
                               negative_slope=negative_slope)
        if ell_w is not None:
            p = p * ell_w[..., None]
        zg = z[jnp.maximum(ell_idx, 0)]                 # (R, K, H, F)
        return jnp.einsum("rkh,rkhf->rhf", p.astype(jnp.float32),
                          zg.astype(jnp.float32)).astype(z.dtype)


# ----------------------------------------------------------- typed logits
def attn_logit_panels(ell_idx: jnp.ndarray, adst: jnp.ndarray,
                      alpha_src: jnp.ndarray, *, logit_kind: str = "add",
                      negative_slope: float = 0.2,
                      prior: Optional[jnp.ndarray] = None
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Raw per-slot logits ``(R, K, H)`` + validity mask ``(R, K)``.

    ``adst`` (R, H, LD) receiver term per row, ``alpha_src`` (N, H, LD)
    sender term per node — ``LD == 1`` for the additive GAT logit, the head
    dim for the dot logit. ``prior`` (H,) is the per-head scale of the dot
    logit (``mu[rel] / sqrt(D)``).
    """
    mask = ell_idx >= 0
    safe = jnp.maximum(ell_idx, 0)
    ag = alpha_src[safe].astype(jnp.float32)            # (R, K, H, LD)
    ad = adst[:, None].astype(jnp.float32)              # (R, 1, H, LD)
    if logit_kind == "add":
        raw = (ag + ad).sum(axis=-1)                    # LD == 1
        logits = jnp.where(raw >= 0, raw, negative_slope * raw)
    else:
        logits = (ag * ad).sum(axis=-1)
        if prior is not None:
            logits = logits * prior.astype(jnp.float32)[None, None, :]
    return logits, mask


def attn_carry_panels(ell_idx: jnp.ndarray, adst: jnp.ndarray,
                      ell_w: Optional[jnp.ndarray], alpha_src: jnp.ndarray,
                      z: jnp.ndarray, *, logit_kind: str = "add",
                      negative_slope: float = 0.2,
                      prior: Optional[jnp.ndarray] = None
                      ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Oracle softmax carry over one bucket: ``(m, l, acc)``.

    Mirrors the carry-mode kernel exactly: ``m`` (R, H) is the masked logit
    max (-inf on empty rows, stop-gradded), ``l`` (R, H) the unweighted
    exp-sum, ``acc`` (R, H, F) the *weighted*, unnormalised accumulator
    (``ell_w`` multiplies the numerator only). ``z`` is (N, H, F).

    Scoped ``repro_oracle`` for the dispatch auditor: this is the panel
    fallback of ``attn_carry_ell``. (The kernel's custom VJP re-enters it
    inside a ``repro_kernel_vjp`` scope, which takes precedence.)
    """
    with jax.named_scope("repro_oracle:attn_carry_panels"):
        logits, mask = attn_logit_panels(
            ell_idx, adst, alpha_src, logit_kind=logit_kind,
            negative_slope=negative_slope, prior=prior)
        neg = jnp.where(mask[..., None], logits, -jnp.inf)
        m = jax.lax.stop_gradient(jnp.max(neg, axis=1))     # (R, H)
        m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
        p = jnp.where(mask[..., None],
                      jnp.exp(logits - m_safe[:, None, :]), 0.0)
        l = p.sum(axis=1)                                   # (R, H)
        num = p if ell_w is None else p * ell_w[..., None]
        zg = z[jnp.maximum(ell_idx, 0)].astype(jnp.float32)
        acc = jnp.einsum("rkh,rkhf->rhf", num, zg)          # (R, H, F)
    return m, l, acc


def attn_carry_coo(send: jnp.ndarray, recv: jnp.ndarray,
                   a_send: jnp.ndarray, a_recv: jnp.ndarray,
                   z_send: jnp.ndarray, *, num_rows: int,
                   logit_kind: str = "add", negative_slope: float = 0.2,
                   prior: Optional[jnp.ndarray] = None,
                   edge_weight: Optional[jnp.ndarray] = None
                   ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """COO-level softmax carry oracle: ``(m, l, acc)`` per destination row.

    The edge-materialising fallback of the typed-attention carry path;
    same stabilizer/weight conventions as :func:`attn_carry_panels`.
    ``a_send``/``a_recv`` are (N, H, LD), ``z_send`` (N, H, F).
    """
    with jax.named_scope("repro_oracle:attn_carry_coo"):
        a = a_send[send].astype(jnp.float32)
        b = a_recv[recv].astype(jnp.float32)
        if logit_kind == "add":
            raw = (a + b).sum(axis=-1)                       # (E, H)
            logits = jnp.where(raw >= 0, raw, negative_slope * raw)
        else:
            logits = (a * b).sum(axis=-1)
            if prior is not None:
                logits = logits * prior.astype(jnp.float32)[None, :]
        m = jax.lax.stop_gradient(
            jax.ops.segment_max(logits, recv, num_segments=num_rows))
        m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
        p = jnp.exp(logits - m_safe[recv])                   # (E, H)
        l = jax.ops.segment_sum(p, recv, num_segments=num_rows)
        msg = z_send[send].astype(jnp.float32) * p[..., None]
        if edge_weight is not None:
            msg = msg * edge_weight[:, None, None].astype(jnp.float32)
        acc = jax.ops.segment_sum(msg, recv, num_segments=num_rows)
    return m, l, acc


def attn_alpha_coo(send: jnp.ndarray, recv: jnp.ndarray,
                   a_send: jnp.ndarray, a_recv: jnp.ndarray, *,
                   m: jnp.ndarray, l: jnp.ndarray, logit_kind: str = "add",
                   negative_slope: float = 0.2,
                   prior: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Per-edge attention ``(E, H)`` against *merged* softmax stats.

    ``m``/``l`` are the (num_rows, H) carry statistics after cross-relation
    merging, so the returned alphas of all relations into a destination
    node sum to 1 jointly (the cross-type softmax the explainers see).
    """
    with jax.named_scope("repro_oracle:attn_alpha_coo"):
        a = a_send[send].astype(jnp.float32)
        b = a_recv[recv].astype(jnp.float32)
        if logit_kind == "add":
            raw = (a + b).sum(axis=-1)
            logits = jnp.where(raw >= 0, raw, negative_slope * raw)
        else:
            logits = (a * b).sum(axis=-1)
            if prior is not None:
                logits = logits * prior.astype(jnp.float32)[None, :]
        m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
        return (jnp.exp(logits - m_safe[recv])
                / jnp.maximum(l[recv], 1e-16))
