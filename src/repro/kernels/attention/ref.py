"""Pure-jnp oracle for the fused GAT attention aggregation (panel layout).

Same math as the Pallas kernel — leaky-relu logits, masked row softmax,
weighted accumulate — over the ``(R, K)`` blocked-ELL panels, written as
plain XLA ops. Used for validation, as the CPU/GPU dispatch target, and as
the recompute inside the ops-level custom VJP.
"""

from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.segment_softmax import ref as softmax_ref


def gat_attend_coo(send: jnp.ndarray, recv: jnp.ndarray,
                   a_send: jnp.ndarray, a_recv: jnp.ndarray,
                   z_send: jnp.ndarray, *, num_rows: int,
                   negative_slope: float = 0.2,
                   edge_weight: Optional[jnp.ndarray] = None,
                   message_callback: Optional[Callable] = None
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """COO-level attention aggregation oracle: ``(out, alpha)``.

    The single source of truth for the edge-materialising fallback (both
    ``EdgeIndex.attend`` and ``MessagePassing._propagate_attention`` call
    it), so fused-vs-fallback numerics can never drift between entry
    points. ``edge_weight`` multiplies messages *after* the softmax (no
    renormalisation); ``message_callback`` observes the flattened
    ``(E, H*F)`` messages (the explainer's c(.) hook).
    """
    with jax.named_scope("repro_oracle:gat_attend_coo"):
        logits = a_send[send] + a_recv[recv]                # (E, H)
        logits = jax.nn.leaky_relu(logits, negative_slope)
        alpha = softmax_ref.segment_softmax(logits, recv, num_rows)
        msg = z_send[send] * alpha[..., None]               # (E, H, F)
        if edge_weight is not None:
            msg = msg * edge_weight[:, None, None].astype(msg.dtype)
        if message_callback is not None:
            msg = message_callback(msg.reshape(msg.shape[0], -1)).reshape(
                msg.shape)
        out = jax.ops.segment_sum(msg, recv, num_segments=num_rows)
    return out, alpha


def gat_softmax_panels(ell_idx: jnp.ndarray, adst: jnp.ndarray,
                       alpha_src: jnp.ndarray, *,
                       negative_slope: float = 0.2) -> jnp.ndarray:
    """Per-slot attention probabilities ``p`` of shape (R, K, H).

    ``ell_idx`` (R, K) neighbor table (-1 = padding), ``adst`` (R, H) the
    receiver term per row, ``alpha_src`` (N, H) the sender term per node.
    Padding slots get p = 0; all-padding rows a 0 row (the kernel's empty-
    segment convention).
    """
    mask = ell_idx >= 0
    safe = jnp.maximum(ell_idx, 0)
    raw = alpha_src[safe] + adst[:, None, :]            # (R, K, H)
    logits = jnp.where(raw >= 0, raw, negative_slope * raw)
    neg = jnp.where(mask[..., None], logits, -jnp.inf)
    mx = jnp.max(neg, axis=1, keepdims=True)
    mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
    ex = jnp.where(mask[..., None], jnp.exp(logits - mx), 0.0)
    den = jnp.maximum(ex.sum(axis=1, keepdims=True), 1e-16)
    return ex / den


def gat_attend_panels(ell_idx: jnp.ndarray, adst: jnp.ndarray,
                      ell_w: Optional[jnp.ndarray], alpha_src: jnp.ndarray,
                      z: jnp.ndarray, *,
                      negative_slope: float = 0.2) -> jnp.ndarray:
    """Oracle fused attention over one bucket: (R, H, F).

    ``z`` is (N, H, F); ``ell_w`` optional (R, K) post-softmax per-slot
    weights (the explainer mask / edge weight — applied to the numerator
    only, no renormalisation, matching the materialised path).

    Scoped ``repro_oracle`` for the dispatch auditor: this is the panel
    fallback of ``gat_attend_ell``. (The kernel's own backward recomputes
    the softmax via ``gat_softmax_panels`` directly — inside a
    ``repro_kernel_vjp`` scope, which takes classification precedence.)
    """
    with jax.named_scope("repro_oracle:gat_attend_panels"):
        p = gat_softmax_panels(ell_idx, adst, alpha_src,
                               negative_slope=negative_slope)
        if ell_w is not None:
            p = p * ell_w[..., None]
        zg = z[jnp.maximum(ell_idx, 0)]                 # (R, K, H, F)
        return jnp.einsum("rkh,rkhf->rhf", p.astype(jnp.float32),
                          zg.astype(jnp.float32)).astype(z.dtype)
