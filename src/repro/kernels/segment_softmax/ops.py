"""Public segment-softmax entry point with kernel/oracle dispatch.

The padded-panel entry point is differentiable on the Pallas branch: an
ops-level ``jax.custom_vjp`` runs the standard softmax backward
``ds = p * (dy - sum_k p * dy)`` over the same panels in XLA (the PR-4
pattern), so only the *raw* kernel entry point remains forward-only (it
raises a clear error via the shared ``forward_only_pallas`` guard).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import use_pallas
from repro.kernels.segment_softmax import ref
from repro.kernels.segment_softmax.segment_softmax import segment_softmax_pallas


def segment_softmax(values: jnp.ndarray, segment_ids: jnp.ndarray,
                    num_segments: int) -> jnp.ndarray:
    """Softmax over segments (jit-friendly CSR-style API; XLA path)."""
    return ref.segment_softmax(values, segment_ids, num_segments)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _segment_softmax_ell_diff(interpret, values, mask):
    return segment_softmax_pallas(values, mask, interpret=interpret)


def _segment_softmax_ell_fwd(interpret, values, mask):
    p = segment_softmax_pallas(values, mask, interpret=interpret)
    return p, (p, mask)


def _segment_softmax_ell_bwd(interpret, residuals, dy):
    p, mask = residuals
    ds = p * (dy - (p * dy).sum(axis=1, keepdims=True))
    ds = jnp.where(mask != 0, ds, 0.0).astype(p.dtype)
    d_mask = np.zeros(mask.shape, jax.dtypes.float0)  # int operand: no ct
    return ds, d_mask


_segment_softmax_ell_diff.defvjp(_segment_softmax_ell_fwd,
                                 _segment_softmax_ell_bwd)


def segment_softmax_ell(values: jnp.ndarray, mask: jnp.ndarray, *,
                        force_pallas: Optional[bool] = None,
                        interpret: bool = False) -> jnp.ndarray:
    """Padded-panel segment softmax; Pallas on TPU, oracle elsewhere.

    Both branches differentiate: the Pallas branch carries the ops-level
    custom VJP above, the oracle is plain XLA.
    """
    take_pallas = use_pallas() if force_pallas is None else force_pallas
    if take_pallas:
        return _segment_softmax_ell_diff(interpret, values,
                                         mask.astype(jnp.int32))
    return ref.segment_softmax_ell(values, mask)
