"""Public segment-softmax entry point with kernel/oracle dispatch."""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels import use_pallas
from repro.kernels.segment_softmax import ref
from repro.kernels.segment_softmax.segment_softmax import segment_softmax_pallas


def segment_softmax(values: jnp.ndarray, segment_ids: jnp.ndarray,
                    num_segments: int) -> jnp.ndarray:
    """Softmax over segments (jit-friendly CSR-style API; XLA path)."""
    return ref.segment_softmax(values, segment_ids, num_segments)


def segment_softmax_ell(values: jnp.ndarray, mask: jnp.ndarray, *,
                        force_pallas: Optional[bool] = None,
                        interpret: bool = False) -> jnp.ndarray:
    """Padded-panel segment softmax; Pallas on TPU, oracle elsewhere."""
    take_pallas = use_pallas() if force_pallas is None else force_pallas
    if take_pallas:
        return segment_softmax_pallas(values, mask, interpret=interpret)
    return ref.segment_softmax_ell(values, mask)
