"""Pure-jnp oracle for segment softmax (GAT edge-attention, explainer masks).

Softmax over variable-length segments of a value vector — in GNN terms:
normalise attention logits over the incoming edges of each destination node.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_softmax(values: jnp.ndarray, segment_ids: jnp.ndarray,
                    num_segments: int) -> jnp.ndarray:
    """Numerically-stable softmax within each segment.

    Args:
      values: (E,) or (E, H) logits.
      segment_ids: (E,) int32 segment of each entry (need not be sorted).
    """
    seg_max = jax.ops.segment_max(values, segment_ids, num_segments=num_segments)
    seg_max = jnp.where(jnp.isfinite(seg_max), seg_max, 0.0)
    shifted = values - seg_max[segment_ids]
    exp = jnp.exp(shifted)
    seg_sum = jax.ops.segment_sum(exp, segment_ids, num_segments=num_segments)
    return exp / jnp.maximum(seg_sum[segment_ids], 1e-16)


def segment_softmax_ell(values: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Oracle for the padded-panel layout: softmax along axis 1 where mask."""
    neg = jnp.where(mask, values, -jnp.inf)
    mx = jnp.max(neg, axis=1, keepdims=True)
    mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
    ex = jnp.where(mask, jnp.exp(values - mx), 0.0)
    den = jnp.maximum(ex.sum(axis=1, keepdims=True), 1e-16)
    return ex / den
