"""Segment-softmax Pallas TPU kernel over padded edge panels.

TPU adaptation of the CUDA segment softmax used for GAT edge attention:
edges sorted by destination are packed into (row, K) panels (same blocked-ELL
layout as the SpMM kernel), turning the ragged per-destination softmax into a
dense masked row softmax that vectorises over 128 lanes. Row blocks are tiled
into VMEM; max/sum reductions run on the VPU within a tile.

Grid: ``(num_row_blocks,)`` with the full K panel per block in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BR = 8


def _segment_softmax_kernel(val_ref, mask_ref, out_ref):
    vals = val_ref[...].astype(jnp.float32)
    mask = mask_ref[...] != 0
    neg = jnp.where(mask, vals, -jnp.inf)
    mx = jnp.max(neg, axis=1, keepdims=True)
    mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
    ex = jnp.where(mask, jnp.exp(vals - mx), 0.0)
    den = jnp.maximum(jnp.sum(ex, axis=1, keepdims=True), 1e-16)
    out_ref[...] = (ex / den).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def _segment_softmax_pallas_impl(values: jnp.ndarray, mask: jnp.ndarray, *,
                                 block_rows: int = DEFAULT_BR,
                                 interpret: bool = False) -> jnp.ndarray:
    """Masked row softmax over (R, K) panels.

    Odd panel heights are padded (masked) up to the ``block_rows`` multiple
    — the same capacity-padding convention as the SpMM kernel — instead of
    asserting; padded rows are all-masked and come out as 0 rows, and the
    result is sliced back to the caller's R.
    """
    rows, k = values.shape
    pad = -rows % block_rows
    mask = mask.astype(jnp.int32)
    if pad:
        values = jnp.concatenate(
            [values, jnp.zeros((pad, k), values.dtype)], axis=0)
        mask = jnp.concatenate([mask, jnp.zeros((pad, k), mask.dtype)],
                               axis=0)
    grid = ((rows + pad) // block_rows,)
    out = pl.pallas_call(
        _segment_softmax_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, k), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows + pad, k), values.dtype),
        interpret=interpret,
    )(values, mask)
    return out[:rows] if pad else out


from repro.kernels import forward_only_pallas

_segment_softmax_pallas_cv = forward_only_pallas(
    lambda block_rows, interpret, values, mask:
        _segment_softmax_pallas_impl(values, mask, block_rows=block_rows,
                                     interpret=interpret),
    num_static=2,
    message=(
        "segment_softmax_pallas is the raw Pallas kernel and has no "
        "backward rule. Differentiate through the ops-level entry points "
        "instead (repro.kernels.segment_softmax.ops.segment_softmax_ell "
        "carries a custom VJP over the same panels, and the fused GAT path "
        "repro.kernels.attention.ops.gat_attend_ell differentiates end to "
        "end), or set REPRO_USE_PALLAS=0 to dispatch the differentiable "
        "XLA oracle."))


def segment_softmax_pallas(values: jnp.ndarray, mask: jnp.ndarray, *,
                           block_rows: int = DEFAULT_BR,
                           interpret: bool = False) -> jnp.ndarray:
    """Masked row softmax over (R, K) panels (rows padded to the block).

    Forward-only: differentiating this raw entry point raises a clear
    ``NotImplementedError`` pointing at the ops-level wrappers (which carry
    the custom VJP) and the ``REPRO_USE_PALLAS`` fallback env var.
    """
    return _segment_softmax_pallas_cv(block_rows, interpret, values, mask)
