"""Segment-softmax Pallas TPU kernel over padded edge panels.

TPU adaptation of the CUDA segment softmax used for GAT edge attention:
edges sorted by destination are packed into (row, K) panels (same blocked-ELL
layout as the SpMM kernel), turning the ragged per-destination softmax into a
dense masked row softmax that vectorises over 128 lanes. Row blocks are tiled
into VMEM; max/sum reductions run on the VPU within a tile.

Grid: ``(num_row_blocks,)`` with the full K panel per block in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BR = 8


def _segment_softmax_kernel(val_ref, mask_ref, out_ref):
    vals = val_ref[...].astype(jnp.float32)
    mask = mask_ref[...] != 0
    neg = jnp.where(mask, vals, -jnp.inf)
    mx = jnp.max(neg, axis=1, keepdims=True)
    mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
    ex = jnp.where(mask, jnp.exp(vals - mx), 0.0)
    den = jnp.maximum(jnp.sum(ex, axis=1, keepdims=True), 1e-16)
    out_ref[...] = (ex / den).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def segment_softmax_pallas(values: jnp.ndarray, mask: jnp.ndarray, *,
                           block_rows: int = DEFAULT_BR,
                           interpret: bool = False) -> jnp.ndarray:
    """Masked row softmax over (R, K) panels. R % block_rows == 0."""
    rows, k = values.shape
    assert rows % block_rows == 0, (rows, block_rows)
    grid = (rows // block_rows,)
    return pl.pallas_call(
        _segment_softmax_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, k), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, k), values.dtype),
        interpret=interpret,
    )(values, mask.astype(jnp.int32))
