"""Blocked-ELL SpMM Pallas TPU kernel — the message-passing fast path (C2).

TPU adaptation of PyG's CUDA scatter/SpMM message passing:

* TPUs have no atomics, so the CUDA scatter-add design does not port. Instead
  we exploit exactly the property the paper's `EdgeIndex` tracks — *sortedness*
  — to turn aggregation into a dense, maskable, per-row-block reduction.
* Layout: rows (destination nodes) are padded to a fixed neighbor budget `K`
  (blocked-ELL). Feature dim is tiled to the 128-lane VPU/MXU width; row
  blocks of `BR` live in VMEM together with a (BR, BF) fp32 accumulator.
* The neighbor gather is a dynamic-slice load from the feature matrix held in
  HBM (`memory_space=ANY`); sorted `EdgeIndex` gives consecutive rows highly
  overlapping neighborhoods, which is the same data-locality argument the
  paper makes for its sorted-CSR path.

Grid: ``(num_row_blocks, num_feat_blocks)``; the `K` loop runs inside the
kernel so each (row, feat) tile is written exactly once.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# TPU-friendly defaults: 8-row sublanes x 128-lane features.
DEFAULT_BR = 8
DEFAULT_BF = 128


def _spmm_ell_kernel(idx_ref, w_ref, x_ref, out_ref, *, block_rows: int,
                     block_feat: int, k: int, has_weight: bool, reduce: str):
    """One (row_block, feat_block) tile: gather-accumulate K neighbors."""
    f_blk = pl.program_id(1)
    f_start = f_blk * block_feat

    if reduce in ("sum", "mean"):
        init = jnp.zeros((block_rows, block_feat), jnp.float32)
    elif reduce == "max":
        init = jnp.full((block_rows, block_feat), -jnp.inf, jnp.float32)
    else:  # min
        init = jnp.full((block_rows, block_feat), jnp.inf, jnp.float32)

    def body_k(kk, acc):
        def body_r(r, acc):
            nid = idx_ref[r, kk]
            valid = nid >= 0
            safe = jnp.maximum(nid, 0)
            # Dynamic-slice a single neighbor row's feature tile out of HBM.
            row = pl.load(
                x_ref, (pl.dslice(safe, 1), pl.dslice(f_start, block_feat))
            ).astype(jnp.float32)  # (1, BF)
            if has_weight:
                row = row * w_ref[r, kk].astype(jnp.float32)
            if reduce in ("sum", "mean"):
                contrib = jnp.where(valid, row[0], 0.0)
                return acc.at[r].add(contrib)
            if reduce == "max":
                contrib = jnp.where(valid, row[0], -jnp.inf)
                return acc.at[r].set(jnp.maximum(acc[r], contrib))
            contrib = jnp.where(valid, row[0], jnp.inf)
            return acc.at[r].set(jnp.minimum(acc[r], contrib))

        return jax.lax.fori_loop(0, block_rows, body_r, acc)

    acc = jax.lax.fori_loop(0, k, body_k, init)

    if reduce == "mean":
        cnt = jnp.sum((idx_ref[...] >= 0).astype(jnp.float32), axis=1)
        acc = acc / jnp.maximum(cnt, 1.0)[:, None]
    elif reduce in ("max", "min"):
        acc = jnp.where(jnp.isfinite(acc), acc, 0.0)

    out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_rows", "block_feat", "reduce", "interpret"),
)
def spmm_ell_pallas(ell_idx: jnp.ndarray, ell_w: Optional[jnp.ndarray],
                    x: jnp.ndarray, *, block_rows: int = DEFAULT_BR,
                    block_feat: int = DEFAULT_BF, reduce: str = "sum",
                    interpret: bool = False) -> jnp.ndarray:
    """Blocked-ELL SpMM: out[r] = reduce_k w[r,k] * x[ell_idx[r,k]].

    Args:
      ell_idx: (R, K) int32 neighbor table, -1 = padding. R % block_rows == 0.
      ell_w:   optional (R, K) weights.
      x:       (N, F) features. F % block_feat == 0.
    """
    rows, k = ell_idx.shape
    feat = x.shape[1]
    assert rows % block_rows == 0, (rows, block_rows)
    assert feat % block_feat == 0, (feat, block_feat)
    grid = (rows // block_rows, feat // block_feat)

    has_weight = ell_w is not None
    if ell_w is None:  # dummy operand keeps the signature static
        ell_w = jnp.zeros((1, 1), x.dtype)

    kernel = functools.partial(
        _spmm_ell_kernel, block_rows=block_rows, block_feat=block_feat, k=k,
        has_weight=has_weight, reduce=reduce)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # Neighbor ids for this row block; full K panel in VMEM.
            pl.BlockSpec((block_rows, k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_rows, k), lambda i, j: (i, 0))
            if has_weight else
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            # Features stay in HBM; the kernel dynamic-slices rows out.
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((block_rows, block_feat), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows, feat), x.dtype),
        interpret=interpret,
    )(ell_idx, ell_w, x)
