"""Blocked-ELL SpMM Pallas TPU kernel — the message-passing fast path (C2).

TPU adaptation of PyG's CUDA scatter/SpMM message passing:

* TPUs have no atomics, so the CUDA scatter-add design does not port. Instead
  we exploit exactly the property the paper's `EdgeIndex` tracks — *sortedness*
  — to turn aggregation into a dense, maskable, per-row-block reduction.
* Layout: rows (destination nodes) are padded to a fixed neighbor budget `K`
  (blocked-ELL). Feature dim is tiled to the 128-lane VPU/MXU width; row
  blocks of `BR` live in VMEM together with a (BR, BF) fp32 accumulator.
* The neighbor gather is *pipelined*: the neighbor ids arrive via scalar
  prefetch (SMEM), and the kernel issues `BR` async HBM->VMEM copies per
  neighbor column into a double-buffered VMEM scratch — the copies for
  column ``k+1`` are in flight while column ``k`` is being accumulated.
  This replaces the previous design (one *synchronous* scalar dynamic-slice
  load per (row, neighbor), i.e. BR*K serialized HBM round trips per tile)
  with BR-wide batches of overlapped DMAs and a single vectorized
  (BR, BF) accumulation step per column.
* Skewed degree distributions do not pay max-degree padding: the host packs
  rows into power-of-two-K *degree buckets* (see ``ops.csr_to_ell_bucketed``)
  and launches this kernel once per bucket.

Grid: ``(num_row_blocks, num_feat_blocks)``; the `K` loop runs inside the
kernel so each (row, feat) tile is written exactly once.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# TPU-friendly defaults (declared in kernels.budgets, the budget source
# of truth): 8-row sublanes x 128-lane features, double-buffered DMA.
from repro.kernels.budgets import (DEFAULT_BF, DEFAULT_BR,
                                   DOUBLE_BUFFER_SLOTS as _NUM_SLOTS)


def _spmm_ell_kernel(idx_sref, idx_ref, w_ref, x_hbm, out_ref, gather, sems,
                     *, block_rows: int, block_feat: int, k: int,
                     has_weight: bool, reduce: str):
    """One (row_block, feat_block) tile: pipelined gather-accumulate.

    ``idx_sref``  full (R, K) neighbor table, scalar-prefetched (SMEM) — the
                  DMA address stream.
    ``idx_ref``   (BR, K) VMEM panel of the same table — vectorized masking.
    ``gather``    (2, BR, BF) VMEM scratch — double-buffered landing zone.
    ``sems``      (2, BR) DMA semaphores — one per in-flight neighbor row.
    """
    r_blk = pl.program_id(0)
    f_blk = pl.program_id(1)
    row_base = r_blk * block_rows
    f_start = f_blk * block_feat

    def column_dma(slot, kk, r):
        nid = jnp.maximum(idx_sref[row_base + r, kk], 0)
        return pltpu.make_async_copy(
            x_hbm.at[pl.dslice(nid, 1), pl.dslice(f_start, block_feat)],
            gather.at[slot, pl.dslice(r, 1), :],
            sems.at[slot, r],
        )

    def start_column(slot, kk):
        def body_r(r, carry):
            column_dma(slot, kk, r).start()
            return carry
        jax.lax.fori_loop(0, block_rows, body_r, 0)

    def wait_column(slot, kk):
        def body_r(r, carry):
            column_dma(slot, kk, r).wait()
            return carry
        jax.lax.fori_loop(0, block_rows, body_r, 0)

    idx_panel = idx_ref[...]  # (BR, K) — in VMEM; drives masks and counts
    if has_weight:
        w_panel = w_ref[...].astype(jnp.float32)

    if reduce in ("sum", "mean"):
        init = jnp.zeros((block_rows, block_feat), jnp.float32)
    elif reduce == "max":
        init = jnp.full((block_rows, block_feat), -jnp.inf, jnp.float32)
    else:  # min
        init = jnp.full((block_rows, block_feat), jnp.inf, jnp.float32)

    # Warm-up: put column 0 in flight before entering the steady state.
    start_column(0, 0)

    def body_k(kk, acc):
        slot = jax.lax.rem(kk, _NUM_SLOTS)

        # Prefetch column kk+1 into the other slot while kk lands/computes.
        @pl.when(kk + 1 < k)
        def _():
            start_column(1 - slot, kk + 1)

        wait_column(slot, kk)
        tile = gather[slot].astype(jnp.float32)  # (BR, BF)

        col_idx = jax.lax.dynamic_slice_in_dim(idx_panel, kk, 1, 1)  # (BR, 1)
        valid = col_idx >= 0
        if has_weight:
            w_col = jax.lax.dynamic_slice_in_dim(w_panel, kk, 1, 1)
            tile = tile * w_col
        if reduce in ("sum", "mean"):
            return acc + jnp.where(valid, tile, 0.0)
        if reduce == "max":
            return jnp.maximum(acc, jnp.where(valid, tile, -jnp.inf))
        return jnp.minimum(acc, jnp.where(valid, tile, jnp.inf))

    acc = jax.lax.fori_loop(0, k, body_k, init)

    if reduce == "mean":
        cnt = jnp.sum((idx_panel >= 0).astype(jnp.float32), axis=1)
        acc = acc / jnp.maximum(cnt, 1.0)[:, None]
    elif reduce in ("max", "min"):
        acc = jnp.where(jnp.isfinite(acc), acc, 0.0)

    out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_rows", "block_feat", "reduce", "interpret"),
)
def _spmm_ell_pallas_impl(ell_idx: jnp.ndarray, ell_w: Optional[jnp.ndarray],
                          x: jnp.ndarray, *, block_rows: int = DEFAULT_BR,
                          block_feat: int = DEFAULT_BF, reduce: str = "sum",
                          interpret: bool = False) -> jnp.ndarray:
    """Blocked-ELL SpMM: out[r] = reduce_k w[r,k] * x[ell_idx[r,k]].

    Args:
      ell_idx: (R, K) int32 neighbor table, -1 = padding. R % block_rows == 0.
      ell_w:   optional (R, K) weights.
      x:       (N, F) features. F % block_feat == 0.
    """
    rows, k = ell_idx.shape
    feat = x.shape[1]
    assert rows % block_rows == 0, (rows, block_rows)
    assert feat % block_feat == 0, (feat, block_feat)
    assert k >= 1, "ELL table must have at least one neighbor column"
    grid = (rows // block_rows, feat // block_feat)

    has_weight = ell_w is not None
    if ell_w is None:  # dummy operand keeps the signature static
        ell_w = jnp.zeros((block_rows, k), x.dtype)

    kernel = functools.partial(
        _spmm_ell_kernel, block_rows=block_rows, block_feat=block_feat, k=k,
        has_weight=has_weight, reduce=reduce)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,  # the neighbor table: DMA address stream
        grid=grid,
        in_specs=[
            # Neighbor ids for this row block; full K panel in VMEM.
            pl.BlockSpec((block_rows, k), lambda i, j, idx: (i, 0)),
            pl.BlockSpec((block_rows, k), lambda i, j, idx: (i, 0))
            if has_weight else
            pl.BlockSpec((block_rows, k), lambda i, j, idx: (0, 0)),
            # Features stay in HBM; the kernel DMA-gathers rows out.
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=pl.BlockSpec((block_rows, block_feat),
                               lambda i, j, idx: (i, j)),
        scratch_shapes=[
            pltpu.VMEM((_NUM_SLOTS, block_rows, block_feat), x.dtype),
            pltpu.SemaphoreType.DMA((_NUM_SLOTS, block_rows)),
        ],
    )

    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((rows, feat), x.dtype),
        interpret=interpret,
    )(ell_idx, ell_idx, ell_w, x)


from repro.kernels import forward_only_pallas

_spmm_ell_pallas_cv = forward_only_pallas(
    lambda block_rows, block_feat, reduce, interpret, ell_idx, ell_w, x:
        _spmm_ell_pallas_impl(ell_idx, ell_w, x, block_rows=block_rows,
                              block_feat=block_feat, reduce=reduce,
                              interpret=interpret),
    num_static=4,
    message=(
        "spmm_ell_pallas is the raw Pallas kernel and has no backward rule "
        "for this configuration. Differentiate through the ops-level entry "
        "points instead (repro.kernels.spmm.ops.spmm_ell / "
        "spmm_ell_bucketed carry a custom VJP over the same ELL buckets), "
        "or set REPRO_USE_PALLAS=0 to dispatch the differentiable XLA "
        "oracle."))


def spmm_ell_pallas(ell_idx: jnp.ndarray, ell_w: Optional[jnp.ndarray],
                    x: jnp.ndarray, *, block_rows: int = DEFAULT_BR,
                    block_feat: int = DEFAULT_BF, reduce: str = "sum",
                    interpret: bool = False) -> jnp.ndarray:
    """Blocked-ELL SpMM Pallas kernel (see :func:`_spmm_ell_pallas_impl`).

    Forward-only: differentiating this raw entry point raises a clear
    ``NotImplementedError`` pointing at the ops-level wrappers (which carry
    the custom VJP) and the ``REPRO_USE_PALLAS`` fallback env var.
    """
    return _spmm_ell_pallas_cv(block_rows, block_feat, reduce, interpret,
                               ell_idx, ell_w, x)
