"""Pure-jnp oracle for CSR SpMM (sparse adjacency @ dense features).

``out[r] = reduce_{e in [indptr[r], indptr[r+1])} w[e] * x[indices[e]]``

This is the message-passing fast path of PyG 2.0 §2.2 ("if the EdgeIndex is
sorted by row or column, we can efficiently leverage SpMMs and segmented
aggregations"). XLA fuses the gather + segment reduction well on CPU/GPU;
the Pallas kernel in ``spmm.py`` is the TPU-native version.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def _row_ids(indptr: jnp.ndarray, num_edges: int) -> jnp.ndarray:
    """Expand a compressed pointer into per-edge row ids."""
    return (jnp.searchsorted(indptr, jnp.arange(num_edges, dtype=jnp.int32),
                             side="right") - 1).astype(jnp.int32)


def spmm_csr(indptr: jnp.ndarray, indices: jnp.ndarray, x: jnp.ndarray,
             weight: Optional[jnp.ndarray] = None, *, num_rows: int,
             reduce: str = "sum") -> jnp.ndarray:
    """Reference CSR SpMM with sum/mean/max/min reduction.

    The ``repro_oracle`` named scope rides the jaxpr name stack so the
    dispatch auditor (``analysis.dispatch``) can attribute every eqn traced
    here to the oracle fallback branch.
    """
    with jax.named_scope("repro_oracle:spmm_csr"):
        num_edges = indices.shape[0]
        if num_edges == 0:
            fill = 0.0
            return jnp.full((num_rows,) + x.shape[1:], fill, dtype=x.dtype)
        rows = _row_ids(indptr, num_edges)
        gathered = jnp.take(x, indices, axis=0)
        if weight is not None:
            gathered = gathered * weight.reshape(
                (-1,) + (1,) * (x.ndim - 1)).astype(x.dtype)
        if reduce == "sum":
            return jax.ops.segment_sum(gathered, rows, num_segments=num_rows)
        if reduce == "mean":
            s = jax.ops.segment_sum(gathered, rows, num_segments=num_rows)
            cnt = (indptr[1:] - indptr[:-1]).astype(x.dtype)
            return s / jnp.maximum(cnt, 1).reshape(
                (-1,) + (1,) * (x.ndim - 1))
        if reduce == "max":
            out = jax.ops.segment_max(gathered, rows, num_segments=num_rows)
            return jnp.where(jnp.isfinite(out), out, 0.0).astype(x.dtype)
        if reduce == "min":
            out = jax.ops.segment_min(gathered, rows, num_segments=num_rows)
            return jnp.where(jnp.isfinite(out), out, 0.0).astype(x.dtype)
    raise ValueError(f"unknown reduce: {reduce}")


def spmm_ell(ell_idx: jnp.ndarray, ell_w: Optional[jnp.ndarray],
             x: jnp.ndarray, *, reduce: str = "sum") -> jnp.ndarray:
    """Reference for the blocked-ELL layout the Pallas kernel consumes.

    ``ell_idx``: (R, K) int32 neighbor ids, ``-1`` marks padding.
    ``ell_w``:   (R, K) optional weights.

    Scoped ``repro_oracle`` for the dispatch auditor (see ``spmm_csr``).
    """
    with jax.named_scope("repro_oracle:spmm_ell"):
        mask = ell_idx >= 0
        safe = jnp.maximum(ell_idx, 0)
        gathered = x[safe]  # (R, K, F)
        if ell_w is not None:
            gathered = gathered * ell_w[..., None].astype(x.dtype)
        if reduce == "sum" or reduce == "mean":
            out = jnp.where(mask[..., None], gathered, 0).sum(axis=1)
            if reduce == "mean":
                cnt = jnp.maximum(mask.sum(axis=1), 1).astype(x.dtype)
                out = out / cnt[:, None]
            return out.astype(x.dtype)
        if reduce == "max":
            out = jnp.where(mask[..., None], gathered, -jnp.inf).max(axis=1)
            return jnp.where(jnp.isfinite(out), out, 0.0).astype(x.dtype)
        if reduce == "min":
            out = jnp.where(mask[..., None], gathered, jnp.inf).min(axis=1)
            return jnp.where(jnp.isfinite(out), out, 0.0).astype(x.dtype)
    raise ValueError(f"unknown reduce: {reduce}")
