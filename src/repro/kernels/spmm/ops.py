"""Public SpMM entry points with kernel/oracle dispatch + format packing.

Dispatch decision tree (see also ROADMAP.md):

    spmm over a sorted adjacency
    ├── CSR given directly (`spmm_csr`)          -> XLA segment oracle
    └── blocked-ELL given (`spmm_ell[_bucketed]`)
        ├── TPU backend, or `force_pallas=True`  -> Pallas pipelined kernel
        │     └── non-TPU backend               -> interpret mode (tests)
        └── otherwise                            -> jnp ELL oracle (XLA fuses)

Packing is host-side (shape decisions cannot trace): ``csr_to_ell`` pads
every row to one fixed K; ``csr_to_ell_bucketed`` instead groups rows into
power-of-two-K degree buckets so skewed real-world degree distributions do
not pay max-degree padding — one kernel launch per bucket, disjoint row
sets scattered back into a single output.
"""

from __future__ import annotations

import functools
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import budgets as hw_budgets, use_pallas
from repro.kernels.budgets import MAX_PREFETCH_ELEMS  # noqa: F401  re-export
from repro.kernels.spmm import ref
from repro.kernels.spmm.spmm import spmm_ell_pallas

# A degree bucket: (row_ids, ell_idx, ell_pos).
#   row_ids: (R_b,)      original row ids covered by this bucket
#   ell_idx: (R_pad, K)  int32 neighbor table, -1 = padding, R_pad % BR == 0
#   ell_pos: (R_pad, K)  int32 position of each slot in the CSR edge order
#                        (-1 = padding) — lets callers gather per-call edge
#                        weights without re-packing.
EllBucket = Tuple[np.ndarray, np.ndarray, np.ndarray]


def spmm_csr(indptr: jnp.ndarray, indices: jnp.ndarray, x: jnp.ndarray,
             weight: Optional[jnp.ndarray] = None, *, num_rows: int,
             reduce: str = "sum") -> jnp.ndarray:
    """CSR SpMM — jit-friendly; XLA path everywhere, Pallas on TPU via ELL.

    The CSR->ELL conversion requires host-side shape decisions, so the Pallas
    path is taken only when the caller pre-packs via :func:`csr_to_ell` /
    :func:`csr_to_ell_bucketed` (``EdgeIndex`` does this in its demand-filled
    ELL cache); direct CSR calls use the fused XLA oracle (itself the paper's
    "sorted segment reduction" fast path).
    """
    return ref.spmm_csr(indptr, indices, x, weight, num_rows=num_rows,
                        reduce=reduce)


def _ell_positions(starts: np.ndarray, deg: np.ndarray, k: int,
                   block_rows: int) -> np.ndarray:
    """Vectorised CSR -> ELL slot map: (R_pad, k) edge positions, -1 = pad.

    ``starts[i]`` is row i's first edge position, ``deg[i]`` its length —
    callers pass either the full CSR (``indptr[:-1], diff(indptr)``) or a
    row subset (one degree bucket). Rows longer than ``k`` truncate; the row
    count pads up to a ``block_rows`` multiple.
    """
    num_rows = len(deg)
    rows_pad = -(-max(num_rows, 1) // block_rows) * block_rows
    cols = np.arange(k)
    mask = cols[None, :] < np.minimum(deg, k)[:, None]
    pos = np.where(mask, starts[:, None] + cols[None, :], -1)
    if rows_pad > num_rows:
        pos = np.concatenate(
            [pos, np.full((rows_pad - num_rows, k), -1, pos.dtype)], axis=0)
    return pos.astype(np.int32)


def csr_to_ell(indptr: np.ndarray, indices: np.ndarray,
               weight: Optional[np.ndarray] = None, *, block_rows: int = 8,
               k: Optional[int] = None
               ) -> Tuple[np.ndarray, Optional[np.ndarray]]:
    """Host-side CSR -> blocked-ELL packing (rows padded to `k` neighbors).

    Fully vectorised (no per-row Python loop); rows longer than ``k`` are
    truncated, shorter rows padded with ``-1``.
    """
    indptr = np.asarray(indptr)
    indices = np.asarray(indices)
    deg = np.diff(indptr)
    if k is None:
        k = max(int(deg.max()) if deg.size else 1, 1)
    hw_budgets.check_ell_rung(k, block_rows=block_rows,
                              context="csr_to_ell")
    pos = _ell_positions(indptr[:-1], deg, k, block_rows)
    mask = pos >= 0
    safe = np.where(mask, pos, 0)
    ell_idx = np.where(mask, indices[safe], -1).astype(np.int32)
    ell_w = None
    if weight is not None:
        ell_w = np.where(mask, np.asarray(weight)[safe], 0.0).astype(
            np.float32)
    return ell_idx, ell_w


def csr_to_ell_bucketed(indptr: np.ndarray, indices: np.ndarray, *,
                        block_rows: int = 8,
                        min_k: int = 4) -> List[EllBucket]:
    """CSR -> degree-bucketed blocked-ELL (power-of-two K ladder).

    Bucket ``j`` holds the rows with degree in ``(K_j/2, K_j]`` where
    ``K_j = min_k * 2**j`` (the first bucket takes degrees ``1..min_k``), so
    per-row padding waste is bounded by 2x instead of max-degree. Zero-degree
    rows appear in no bucket (their output is the reduce identity / 0 fill).
    Every edge appears in exactly one bucket and every row in at most one.
    """
    indptr = np.asarray(indptr)
    indices = np.asarray(indices)
    deg = np.diff(indptr)
    buckets: List[EllBucket] = []
    if deg.size == 0 or int(deg.max()) == 0:
        return buckets
    max_deg = int(deg.max())
    lower, k = 0, min_k
    while lower < max_deg:
        sel = np.nonzero((deg > lower) & (deg <= k))[0]
        if sel.size:
            hw_budgets.check_ell_rung(k, block_rows=block_rows,
                                      context="csr_to_ell_bucketed")
            pos = _ell_positions(indptr[sel], deg[sel], k, block_rows)
            safe = np.where(pos >= 0, pos, 0)
            ell_idx = np.where(pos >= 0, indices[safe], -1).astype(np.int32)
            buckets.append((sel.astype(np.int32), ell_idx, pos))
        lower, k = k, k * 2
    return buckets


def ell_layout_from_bounds(bounds: Sequence[Tuple[int, int, int]], *,
                           min_k: int = 4, block_rows: int = 8
                           ) -> List[Tuple[np.ndarray, int]]:
    """Static row ranges + degree bounds -> a fixed power-of-two K ladder.

    ``bounds`` is ``[(start, stop, max_degree), ...]`` (e.g. the sampler's
    static per-hop in-degree bounds). Each range is assigned the smallest
    ladder rung ``K = min_k * 2**j >= max_degree``; ranges sharing a rung
    merge into one bucket, and every bucket's row list is capacity-padded to
    a ``block_rows`` multiple with ``-1`` row ids. The result depends only
    on the *bounds* — never on realised degrees — so every packing against
    it has identical shapes (the jit-ready layout). Every rung is validated
    against the declared SMEM/VMEM budgets at layout time
    (:func:`repro.kernels.budgets.check_ell_layout`): an unservable K ladder
    raises :class:`repro.kernels.budgets.BudgetError` here, on the host,
    instead of OOMing a launch later.
    """
    by_k: dict = {}
    for lo, hi, bound in bounds:
        if hi <= lo or bound <= 0:
            continue
        k = min_k
        while k < bound:
            k *= 2
        by_k.setdefault(k, []).append(np.arange(lo, hi))
    layout = []
    for k in sorted(by_k):
        rows = np.concatenate(by_k[k]).astype(np.int32)
        pad = -(-len(rows) // block_rows) * block_rows - len(rows)
        if pad:
            rows = np.concatenate([rows, np.full(pad, -1, np.int32)])
        layout.append((rows, k))
    hw_budgets.check_ell_layout(layout, block_rows=block_rows,
                                context="ell_layout_from_bounds")
    return layout


def csr_to_ell_static(indptr: np.ndarray, indices: np.ndarray,
                      layout: Sequence[Tuple[np.ndarray, int]], *,
                      block_rows: int = 8) -> List[EllBucket]:
    """Pack a CSR/CSC into a *fixed* bucket layout (capacity-padded).

    The shape-stable variant of :func:`csr_to_ell_bucketed`: bucket row sets
    and K widths come from ``layout`` (see :func:`ell_layout_from_bounds`)
    instead of the realised degree distribution, so every call returns
    buckets of identical shapes — batches packed this way share one jit
    trace. ``-1`` row ids are capacity padding (all-invalid slots; the
    consumer masks them out of the scatter). A realised degree above its
    bucket's K means the static bound was violated and raises.
    """
    indptr = np.asarray(indptr)
    indices = np.asarray(indices)
    deg_all = np.diff(indptr)
    # layouts may be hand-built (not via ell_layout_from_bounds): validate
    # against the declared budgets here too — pack time is the last host-
    # side moment before these shapes hit a launch.
    hw_budgets.check_ell_layout(layout, block_rows=block_rows,
                                context="csr_to_ell_static")
    buckets: List[EllBucket] = []
    for row_ids, k in layout:
        row_ids = np.asarray(row_ids, np.int32)
        valid = row_ids >= 0
        safe = np.where(valid, row_ids, 0)
        deg = np.where(valid, deg_all[safe], 0)
        over = int(deg.max(initial=0))
        if over > k:
            raise ValueError(
                f"static ELL layout violated: realised degree {over} exceeds "
                f"bucket capacity K={k}")
        starts = np.where(valid, indptr[safe], 0)
        pos = _ell_positions(starts, deg, k, block_rows)
        if len(pos) > len(row_ids):  # layout not block-padded: pad ids too
            row_ids = np.concatenate([row_ids, np.full(
                len(pos) - len(row_ids), -1, np.int32)])
        safe_pos = np.where(pos >= 0, pos, 0)
        ell_idx = np.where(pos >= 0, indices[safe_pos], -1).astype(np.int32)
        buckets.append((row_ids, ell_idx, pos))
    return buckets


# MAX_PREFETCH_ELEMS (re-exported above from kernels.budgets, the single
# source of truth) bounds the scalar-prefetched neighbor table per launch;
# rows chunk above it. It stays a module-level name here so tests can
# monkeypatch the chunk rule per ops module without touching the declared
# hardware budgets.


def _spmm_ell_pallas_chunked(ell_idx: jnp.ndarray,
                             ell_w: Optional[jnp.ndarray], x: jnp.ndarray,
                             reduce: str, interpret: bool) -> jnp.ndarray:
    """The raw Pallas forward, row-chunked to the SMEM prefetch budget.

    Calls the module-global ``spmm_ell_pallas`` (not a captured reference) so
    test spies that monkeypatch the ops attribute still observe every launch.
    """
    feat = x.shape[1]
    bf = 128 if feat % 128 == 0 else feat
    rows, k = ell_idx.shape
    from repro.kernels.spmm.spmm import DEFAULT_BR
    # Launch-time backstop against the *declared* hardware budgets (the
    # pack-time check covers loader layouts; ad-hoc tables land here).
    hw_budgets.check_ell_rung(k, block_rows=DEFAULT_BR,
                              context="spmm_ell launch")
    chunk = max(MAX_PREFETCH_ELEMS // max(k, 1), DEFAULT_BR)
    chunk -= chunk % DEFAULT_BR
    if rows <= chunk:
        return spmm_ell_pallas(ell_idx, ell_w, x, reduce=reduce,
                               block_feat=bf, interpret=interpret)
    outs = []
    for lo in range(0, rows, chunk):
        hi = min(lo + chunk, rows)
        outs.append(spmm_ell_pallas(
            ell_idx[lo:hi], None if ell_w is None else ell_w[lo:hi], x,
            reduce=reduce, block_feat=bf, interpret=interpret))
    return jnp.concatenate(outs, axis=0)


def _spmm_ell_backward(ell_idx: jnp.ndarray, ell_w: Optional[jnp.ndarray],
                       x: jnp.ndarray, out: Optional[jnp.ndarray],
                       dy: jnp.ndarray, reduce: str
                       ) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """VJP of the blocked-ELL SpMM w.r.t. (features, weights).

    The feature cotangent is a masked scatter-add over the *same* ELL table
    the forward consumed: gather ``dy`` by row, accumulate each slot's
    contribution into its neighbor column (``-1`` capacity/padding slots are
    dropped out of the scatter). The weight cotangent is the per-slot
    ``dy[row] . x[col]`` reduction. ``mean`` pre-scales ``dy`` by the
    per-row valid count; ``max``/``min`` route ``dy`` to the arg-extreme
    slots (ties split evenly — the same convention as ``lax.reduce_max``'s
    gradient, so kernel and oracle gradients agree).
    """
    mask = ell_idx >= 0
    n = x.shape[0]
    dy32 = dy.astype(jnp.float32)
    xg = x[jnp.maximum(ell_idx, 0)].astype(jnp.float32)  # (R, K, F)
    if reduce in ("sum", "mean"):
        if reduce == "mean":
            cnt = jnp.maximum(mask.sum(axis=1), 1).astype(jnp.float32)
            dy32 = dy32 / cnt[:, None]
        g = jnp.where(mask[..., None], dy32[:, None, :], 0.0)  # (R, K, F)
    else:  # max / min: dy flows only to the slots that achieved the output
        contrib = xg if ell_w is None else xg * ell_w[..., None].astype(
            jnp.float32)
        hit = mask[..., None] & (contrib == out.astype(jnp.float32)[:, None])
        ties = jnp.maximum(hit.sum(axis=1, keepdims=True), 1).astype(
            jnp.float32)
        g = jnp.where(hit, dy32[:, None, :] / ties, 0.0)
    gx = g if ell_w is None else g * ell_w[..., None].astype(jnp.float32)
    scatter_rows = jnp.where(mask, ell_idx, n).reshape(-1)
    dx = jnp.zeros((n, x.shape[1]), jnp.float32).at[scatter_rows].add(
        gx.reshape(-1, x.shape[1]), mode="drop").astype(x.dtype)
    dw = None
    if ell_w is not None:
        dw = jnp.where(mask, (g * xg).sum(-1), 0.0).astype(ell_w.dtype)
    return dx, dw


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _spmm_ell_pallas_diff(reduce: str, interpret: bool, ell_idx, ell_w, x):
    """Differentiable wrapper over the Pallas ELL forward (the custom VJP
    the ROADMAP promised): Pallas runs the forward, the backward is the
    masked scatter-add of :func:`_spmm_ell_backward` over the same table."""
    return _spmm_ell_pallas_chunked(ell_idx, ell_w, x, reduce, interpret)


def _spmm_ell_diff_fwd(reduce, interpret, ell_idx, ell_w, x):
    out = _spmm_ell_pallas_chunked(ell_idx, ell_w, x, reduce, interpret)
    keep_out = out if reduce in ("max", "min") else None
    return out, (ell_idx, ell_w, x, keep_out)


def _spmm_ell_diff_bwd(reduce, interpret, residuals, dy):
    ell_idx, ell_w, x, out = residuals
    # The named scope tags these gather/scatter eqns as the *kernel's own
    # backward* so the dispatch auditor (analysis.dispatch) never mistakes
    # them for an oracle fallback when walking a grad step.
    with jax.named_scope("repro_kernel_vjp:spmm_ell"):
        dx, dw = _spmm_ell_backward(ell_idx, ell_w, x, out, dy, reduce)
    d_idx = np.zeros(ell_idx.shape, jax.dtypes.float0)  # int operand: no ct
    return d_idx, dw, dx


_spmm_ell_pallas_diff.defvjp(_spmm_ell_diff_fwd, _spmm_ell_diff_bwd)


def spmm_ell(ell_idx: jnp.ndarray, ell_w: Optional[jnp.ndarray],
             x: jnp.ndarray, *, reduce: str = "sum",
             force_pallas: Optional[bool] = None,
             interpret: Optional[bool] = None) -> jnp.ndarray:
    """Blocked-ELL SpMM: Pallas kernel on TPU (or when forced), oracle else.

    ``interpret=None`` auto-selects interpret mode off-TPU so a forced Pallas
    path stays runnable (and testable) on CPU containers. Tables larger than
    ``MAX_PREFETCH_ELEMS`` are split along rows into multiple launches so the
    scalar-prefetched neighbor table always fits SMEM. The Pallas branch is
    differentiable: a custom VJP computes the feature cotangent as a masked
    scatter-add over the same ELL table and the weight cotangent as per-slot
    ``dy[row] . x[col]``, so ``jax.grad`` through a kernel-dispatched step
    works (training and explainers ride the fast path).
    """
    take_pallas = use_pallas() if force_pallas is None else force_pallas
    if not take_pallas:
        return ref.spmm_ell(ell_idx, ell_w, x, reduce=reduce)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _spmm_ell_pallas_diff(reduce, bool(interpret), ell_idx, ell_w, x)


def spmm_ell_bucketed(buckets: Sequence[EllBucket], x: jnp.ndarray,
                      weight: Optional[jnp.ndarray] = None, *,
                      num_rows: int, reduce: str = "sum",
                      force_pallas: Optional[bool] = None,
                      interpret: Optional[bool] = None) -> jnp.ndarray:
    """Degree-bucketed blocked-ELL SpMM: one kernel launch per bucket.

    ``weight`` is per-edge in whatever order ``ell_pos`` is keyed to (the
    packers emit packed/CSR order; ``EdgeIndex`` re-keys its caches to COO
    order); each bucket gathers its slots' weights through ``ell_pos``.
    Differentiable end to end: the per-bucket kernel carries a custom VJP
    and the weight gather / output scatter are plain XLA ops, so gradients
    flow to both ``x`` and ``weight``.
    Rows absent from every bucket (degree 0) keep the 0 fill — identical to
    the oracle's empty-segment convention for every reduce mode. ``-1`` row
    ids (capacity padding from :func:`csr_to_ell_static`) are masked out of
    the scatter, so bucket arrays may be tracers (jit-argument batches).
    """
    out = jnp.zeros((num_rows,) + x.shape[1:], x.dtype)
    for row_ids, ell_idx, ell_pos in buckets:
        w_b = None
        if weight is not None:
            mask = ell_pos >= 0
            w_b = jnp.where(mask,
                            jnp.asarray(weight)[jnp.maximum(ell_pos, 0)],
                            0.0).astype(jnp.float32)
        res = spmm_ell(jnp.asarray(ell_idx), w_b, x, reduce=reduce,
                       force_pallas=force_pallas, interpret=interpret)
        ids = jnp.asarray(row_ids)
        # Padding ids scatter out of bounds and are dropped.
        ids = jnp.where(ids >= 0, ids, num_rows)
        out = out.at[ids].set(res[: ids.shape[0]].astype(x.dtype),
                              mode="drop")
    return out
