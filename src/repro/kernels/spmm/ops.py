"""Public SpMM entry points with kernel/oracle dispatch + format packing."""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import use_pallas
from repro.kernels.spmm import ref
from repro.kernels.spmm.spmm import spmm_ell_pallas


def spmm_csr(indptr: jnp.ndarray, indices: jnp.ndarray, x: jnp.ndarray,
             weight: Optional[jnp.ndarray] = None, *, num_rows: int,
             reduce: str = "sum") -> jnp.ndarray:
    """CSR SpMM — jit-friendly; XLA path everywhere, Pallas on TPU via ELL.

    The CSR->ELL conversion requires host-side shape decisions, so the Pallas
    path is taken only when the caller pre-packs via :func:`csr_to_ell`;
    direct CSR calls use the fused XLA oracle (itself the paper's "sorted
    segment reduction" fast path).
    """
    return ref.spmm_csr(indptr, indices, x, weight, num_rows=num_rows,
                        reduce=reduce)


def csr_to_ell(indptr: np.ndarray, indices: np.ndarray,
               weight: Optional[np.ndarray] = None, *, block_rows: int = 8,
               k: Optional[int] = None
               ) -> Tuple[np.ndarray, Optional[np.ndarray]]:
    """Host-side CSR -> blocked-ELL packing (rows padded to `k` neighbors)."""
    indptr = np.asarray(indptr)
    indices = np.asarray(indices)
    num_rows = len(indptr) - 1
    deg = np.diff(indptr)
    if k is None:
        k = max(int(deg.max()) if num_rows else 1, 1)
    rows_pad = -(-num_rows // block_rows) * block_rows
    ell_idx = np.full((rows_pad, k), -1, np.int32)
    ell_w = None if weight is None else np.zeros((rows_pad, k), np.float32)
    for r in range(num_rows):
        lo, hi = int(indptr[r]), int(indptr[r + 1])
        take = min(hi - lo, k)
        ell_idx[r, :take] = indices[lo:lo + take]
        if weight is not None:
            ell_w[r, :take] = weight[lo:lo + take]
    return ell_idx, ell_w


def spmm_ell(ell_idx: jnp.ndarray, ell_w: Optional[jnp.ndarray],
             x: jnp.ndarray, *, reduce: str = "sum",
             force_pallas: Optional[bool] = None,
             interpret: bool = False) -> jnp.ndarray:
    """Blocked-ELL SpMM: Pallas kernel on TPU (or when forced), oracle else."""
    take_pallas = use_pallas() if force_pallas is None else force_pallas
    if take_pallas:
        feat = x.shape[1]
        bf = 128 if feat % 128 == 0 else feat
        return spmm_ell_pallas(ell_idx, ell_w, x, reduce=reduce,
                               block_feat=bf, interpret=interpret)
    return ref.spmm_ell(ell_idx, ell_w, x, reduce=reduce)
