"""Fault tolerance for store-backed loading (the robustness spine).

Remote ``FeatureStore``/``GraphStore`` backends fail, stall, and black out;
the loading pipeline must ride through instead of killing the producer
thread. This module is the whole story in one place:

  * a structured error taxonomy — ``StoreError`` (base), retryable
    ``TransientStoreError``, ``PartitionUnavailableError`` (carries the
    partition id), ``FetchTimeoutError`` (deadline exceeded);
  * ``RetryPolicy`` — bounded attempts, exponential backoff with *seeded*
    deterministic jitter, retryable-class filtering, injectable sleep/abort
    hooks so tests never assert on wall time;
  * ``CircuitBreaker`` — per-partition closed -> open (after N consecutive
    failures) -> half-open probe -> closed, with an injectable clock;
  * ``ResilientFeatureStore`` / ``ResilientGraphStore`` — decorate any
    backend with retry + deadline + breaker, per-partition fan-out on a
    small thread pool (one slow partition overlaps the others), and
    graceful degradation: a bounded last-known-good row cache serves stale
    features for rows homed on a tripped partition instead of crashing the
    step (health counters record every degraded row);
  * ``ChaosFeatureStore`` / ``ChaosGraphStore`` + ``FailureSchedule`` —
    deterministic fault injection (error rate, latency spikes, per-partition
    blackout windows in call counts) from seeded per-partition rng streams,
    so every retry/breaker/degradation path is exercised reproducibly.

Fetch dispatch: fetch -> retry (transient) -> breaker (consecutive
failures) -> stale-cache degrade (rows homed on the tripped partition) ->
loader-level skip/raise (``_PrefetchLoader.on_batch_error``). See
ROADMAP.md "Store failure handling".

The last-known-good ``_RowCache`` here is a *failure* cache: it is
consulted only when a partition is down, and a row served from it is
flagged degraded. The cross-batch hot-feature cache
(``feature_store.CachedFeatureStore``) is the *traffic* twin: it serves on
every hit and never changes failure semantics. They compose — wrap the hot
cache inside the resilient store and healthy hits skip the remote fetch
while failures still degrade gracefully.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from concurrent.futures import TimeoutError as _FutureTimeout
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.data.feature_store import FeatureStore, Key
from repro.data.graph_store import EdgeType, GraphStore


# --------------------------------------------------------------------------
# Error taxonomy
# --------------------------------------------------------------------------

class StoreError(RuntimeError):
    """Base class of storage-layer failures (the loader's policy boundary)."""


class TransientStoreError(StoreError):
    """A failure worth retrying (flaky RPC, lost packet, overloaded shard)."""


class PartitionUnavailableError(TransientStoreError):
    """A whole partition is unreachable (blackout / shard restart)."""

    def __init__(self, partition: int, msg: str = ""):
        super().__init__(msg or f"partition {partition} unavailable")
        self.partition = partition


class FetchTimeoutError(TransientStoreError):
    """A fetch exceeded its deadline."""

    def __init__(self, deadline_s: float, msg: str = ""):
        super().__init__(msg or f"fetch exceeded deadline of {deadline_s}s")
        self.deadline_s = deadline_s


# --------------------------------------------------------------------------
# Retry policy
# --------------------------------------------------------------------------

@dataclasses.dataclass
class RetryPolicy:
    """Bounded retries with exponential backoff + seeded deterministic jitter.

    ``call`` runs ``fn`` up to ``max_attempts`` times, sleeping
    ``base_delay * backoff**attempt * (1 + jitter*u)`` between attempts
    (``u`` drawn from a seeded rng, so delay sequences are reproducible).
    Only ``retryable`` classes are retried; everything else propagates on
    first raise. ``sleep`` is injectable so tests never block, and an
    optional ``abort`` callable (checked before every retry) lets an
    abandoned producer thread bail out of a backoff loop promptly.
    """

    max_attempts: int = 3
    base_delay: float = 0.005
    max_delay: float = 0.5
    backoff: float = 2.0
    jitter: float = 0.5
    retryable: tuple = (TransientStoreError,)
    seed: int = 0
    sleep: Callable[[float], None] = time.sleep

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        self._lock = threading.Lock()

    def delay(self, attempt: int) -> float:
        with self._lock:
            u = float(self._rng.random())
        d = self.base_delay * (self.backoff ** attempt) * (1.0
                                                           + self.jitter * u)
        return min(d, self.max_delay)

    def is_retryable(self, exc: BaseException) -> bool:
        return isinstance(exc, self.retryable)

    def call(self, fn: Callable, *, abort: Optional[Callable[[], bool]] = None,
             on_retry: Optional[Callable[[BaseException], None]] = None):
        last: Optional[BaseException] = None
        for attempt in range(self.max_attempts):
            if abort is not None and abort():
                break
            try:
                return fn()
            except BaseException as exc:  # noqa: BLE001 - filtered below
                if not self.is_retryable(exc):
                    raise
                last = exc
                if on_retry is not None:
                    on_retry(exc)
                if attempt + 1 < self.max_attempts:
                    self.sleep(self.delay(attempt))
        if last is None:  # aborted before the first attempt
            raise TransientStoreError("fetch aborted (consumer gone)")
        raise last


# --------------------------------------------------------------------------
# Circuit breaker
# --------------------------------------------------------------------------

class CircuitBreaker:
    """Closed -> open (N consecutive failures) -> half-open probe -> closed.

    ``allow()`` gates a call: True while closed, False while open and inside
    the cooldown, and True exactly once per cooldown expiry (the half-open
    probe — a success closes the breaker, a failure re-opens it and restarts
    the cooldown). The clock is injectable for deterministic tests; with
    ``recovery_time=0`` every post-trip call is a probe, which keeps chaos
    schedules (counted in calls) deterministic.
    """

    CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"

    def __init__(self, failure_threshold: int = 3, recovery_time: float = 0.05,
                 clock: Callable[[], float] = time.monotonic):
        self.failure_threshold = failure_threshold
        self.recovery_time = recovery_time
        self.clock = clock
        self._state = self.CLOSED
        self._consecutive = 0
        self._opened_at = 0.0
        self.trips = 0          # closed/half-open -> open transitions
        self.recoveries = 0     # half-open -> closed transitions
        self._lock = threading.Lock()

    @property
    def state(self) -> str:
        with self._lock:
            return self._state

    def allow(self) -> bool:
        with self._lock:
            if self._state == self.CLOSED:
                return True
            if self._state == self.OPEN and (
                    self.clock() - self._opened_at >= self.recovery_time):
                self._state = self.HALF_OPEN
                return True
            return False  # open (cooling down) or a probe already in flight

    def record_success(self):
        with self._lock:
            if self._state == self.HALF_OPEN:
                self.recoveries += 1
            self._state = self.CLOSED
            self._consecutive = 0

    def record_failure(self):
        with self._lock:
            self._consecutive += 1
            if self._state == self.HALF_OPEN or (
                    self._state == self.CLOSED
                    and self._consecutive >= self.failure_threshold):
                self._state = self.OPEN
                self._opened_at = self.clock()
                self.trips += 1


def _fresh_health() -> Dict[str, int]:
    return {"requests": 0, "retries": 0, "failures": 0, "timeouts": 0,
            "breaker_trips": 0, "breaker_recoveries": 0, "degraded_rows": 0,
            "stale_rows": 0}


def find_routed(store):
    """Walk the ``.inner`` chain to the partition-routing backend, if any.

    The wrapper chain is compositional (``Resilient(Cached(Chaos(
    Partitioned)))`` and friends): the resilient fan-out, the chaos
    injector's per-partition streams, and the loader's partition-aware
    seed ordering all discover the routing table through this one walk.
    """
    s = store
    while s is not None:
        if hasattr(s, "_route") and hasattr(s, "num_parts"):
            return s
        s = getattr(s, "inner", None)
    return None


_find_routed = find_routed  # backwards-compatible private alias


class _RowCache:
    """Bounded last-known-good row cache: a vectorised FIFO ring.

    ``slot_of`` maps global row -> ring slot (-1 = not cached); ``owner``
    maps slot -> global row so a wrapping head evicts in insertion order.
    put/get are pure NumPy gathers/scatters — no per-row Python — which is
    what keeps the zero-fault resilience overhead in the noise.
    """

    def __init__(self, num_rows: int, capacity: int):
        self.capacity = max(int(capacity), 1)
        self.slot_of = np.full(num_rows, -1, np.int64)
        self.owner = np.full(self.capacity, -1, np.int64)
        self.vals: Optional[np.ndarray] = None
        self.head = 0

    def put(self, rows: np.ndarray, values: np.ndarray):
        rows = np.asarray(rows, np.int64)
        if len(rows) > self.capacity:  # keep the newest `capacity` rows
            rows, values = rows[-self.capacity:], values[-self.capacity:]
        if self.vals is None:
            self.vals = np.zeros((self.capacity,) + values.shape[1:],
                                 values.dtype)
        slot = self.slot_of[rows]
        new = slot < 0
        k = int(new.sum())
        if k:
            idx = (self.head + np.arange(k)) % self.capacity
            prev = self.owner[idx]
            self.slot_of[prev[prev >= 0]] = -1
            self.owner[idx] = rows[new]
            self.slot_of[rows[new]] = idx
            self.head = (self.head + k) % self.capacity
            slot = self.slot_of[rows]
        self.vals[slot] = values

    def get(self, rows: np.ndarray) -> Tuple[Optional[np.ndarray],
                                             np.ndarray]:
        """-> (values for the cached subset, have-mask over ``rows``)."""
        rows = np.asarray(rows, np.int64)
        slot = self.slot_of[rows]
        have = slot >= 0
        if self.vals is None:
            return None, np.zeros(len(rows), bool)
        return self.vals[slot[have]], have


# --------------------------------------------------------------------------
# Resilient feature store
# --------------------------------------------------------------------------

class ResilientFeatureStore(FeatureStore):
    """Retry + deadline + per-partition breaker + stale-cache degradation.

    Wraps any ``FeatureStore``. Fetches fan out per home partition (when the
    wrapped chain exposes a routing table) on a small shared thread pool, so
    one slow or retrying partition overlaps the others; each partition task
    runs its bounded retries behind that partition's circuit breaker, and a
    per-fetch ``deadline`` bounds the whole gather. A partition that stays
    down degrades instead of raising: its rows are served from a bounded
    last-known-good row cache (missing rows become fill rows), the mask of
    degraded rows is surfaced through ``get_padded_resilient`` and every
    degradation is counted in ``health``.
    """

    def __init__(self, inner: FeatureStore, *,
                 retry: Optional[RetryPolicy] = None,
                 deadline: Optional[float] = None,
                 failure_threshold: int = 3,
                 recovery_time: float = 0.05,
                 clock: Callable[[], float] = time.monotonic,
                 max_cache_rows: int = 65536,
                 max_workers: int = 4):
        self.inner = inner
        self.retry = retry if retry is not None else RetryPolicy()
        self.deadline = deadline
        self.health = _fresh_health()
        self._routed = _find_routed(inner)
        self._breakers: Dict[int, CircuitBreaker] = {}
        self._breaker_cfg = (failure_threshold, recovery_time, clock)
        self._caches: Dict[Key, _RowCache] = {}
        self.max_cache_rows = max_cache_rows
        self._meta: Dict[Key, Tuple[tuple, np.dtype]] = {}
        self._pool = ThreadPoolExecutor(max_workers=max_workers,
                                        thread_name_prefix="store-fetch")
        self._lock = threading.Lock()

    # ---- breaker / cache plumbing ----
    def breaker(self, partition: int) -> CircuitBreaker:
        with self._lock:
            if partition not in self._breakers:
                th, rt, clk = self._breaker_cfg
                self._breakers[partition] = CircuitBreaker(
                    failure_threshold=th, recovery_time=rt, clock=clk)
            return self._breakers[partition]

    def breaker_states(self) -> Dict[int, str]:
        with self._lock:
            return {p: b.state for p, b in self._breakers.items()}

    def _row_cache(self, key: Key) -> _RowCache:
        with self._lock:
            if key not in self._caches:
                n = int(self.inner._size(key)[0])
                self._caches[key] = _RowCache(n, self.max_cache_rows)
            return self._caches[key]

    # ---- the fetch engine ----
    def _routed_partition(self, key: Key, index: np.ndarray):
        if self._routed is None:
            return None
        route = getattr(self._routed, "_route", {}).get(key)
        if route is None:
            return None
        return np.asarray(route)[index]

    def _fetch_partition(self, key: Key, rows: np.ndarray, partition: int
                         ) -> np.ndarray:
        """One partition's gather: breaker gate + bounded retries."""
        brk = self.breaker(partition)
        if not brk.allow():
            raise PartitionUnavailableError(
                partition, f"breaker open for partition {partition}")

        def once():
            return self.inner._get(key, rows)

        def on_retry(exc):
            with self._lock:
                self.health["retries"] += 1
            brk.record_failure()

        try:
            out = self.retry.call(once, on_retry=on_retry)
        except StoreError:
            brk.record_failure()
            raise
        brk.record_success()
        return np.asarray(out)

    def _fetch(self, key: Key, index: np.ndarray,
               deadline: Optional[float] = None
               ) -> Tuple[np.ndarray, np.ndarray]:
        """Gather ``index`` rows -> (values, degraded_mask).

        Partition tasks run concurrently; a partition whose task fails (or
        misses the deadline) is *degraded* — served from the last-known-good
        cache / fill rows — rather than raised, unless nothing has ever been
        fetched successfully (no dtype/shape to degrade to).
        """
        index = np.asarray(index)
        deadline = self.deadline if deadline is None else deadline
        with self._lock:
            self.health["requests"] += 1
        part = self._routed_partition(key, index)
        if part is None:
            groups = [(0, np.arange(len(index)))]
        else:
            groups = [(int(p), np.where(part == p)[0])
                      for p in np.unique(part)]
        futures = [(p, pos, self._pool.submit(
            self._fetch_partition, key, index[pos], p))
            for p, pos in groups if len(pos)]
        t0 = time.monotonic()
        results: List[Tuple[int, np.ndarray, Optional[np.ndarray]]] = []
        for p, pos, fut in futures:
            budget = (None if deadline is None
                      else max(deadline - (time.monotonic() - t0), 0.0))
            try:
                vals = fut.result(timeout=budget)
            except _FutureTimeout:
                fut.cancel()
                with self._lock:
                    self.health["timeouts"] += 1
                    self.health["failures"] += 1
                self.breaker(p).record_failure()
                vals = None
            except StoreError:
                with self._lock:
                    self.health["failures"] += 1
                vals = None
            results.append((p, pos, vals))
        good = next((v for _, _, v in results if v is not None), None)
        if good is not None:
            self._meta[key] = (good.shape[1:], good.dtype)
        meta = self._meta.get(key)
        if meta is None:
            raise TransientStoreError(
                f"fetch of {key} failed with no last-known-good data to "
                f"degrade to")
        feat_shape, dtype = meta
        out = np.zeros((len(index),) + tuple(feat_shape), dtype=dtype)
        degraded = np.zeros(len(index), dtype=bool)
        cache = self._row_cache(key)
        for p, pos, vals in results:
            if vals is not None:
                out[pos] = vals
                with self._lock:
                    cache.put(index[pos], vals)
                continue
            degraded[pos] = True
            with self._lock:
                hits, have = cache.get(index[pos])
            if hits is not None and have.any():
                out[pos[have]] = hits
            with self._lock:
                self.health["degraded_rows"] += len(pos)
                self.health["stale_rows"] += int(have.sum())
        self._sync_breaker_health()
        return out, degraded

    def _sync_breaker_health(self):
        with self._lock:
            self.health["breaker_trips"] = sum(
                b.trips for b in self._breakers.values())
            self.health["breaker_recoveries"] = sum(
                b.recoveries for b in self._breakers.values())

    # ---- FeatureStore interface ----
    def _put(self, key: Key, tensor: np.ndarray):
        self.inner._put(key, tensor)

    def _get(self, key: Key, index):
        if index is None:
            n = self._size_with_retry(key)[0]
            index = np.arange(n)
        out, _ = self._fetch(key, np.asarray(index))
        return out

    def _size(self, key: Key):
        return self._size_with_retry(key)

    def _size_with_retry(self, key: Key):
        return self.retry.call(lambda: self.inner._size(key))

    def get_padded_resilient(self, index: np.ndarray, *, group: str = "node",
                             attr: str = "x", fill: float = 0.0,
                             deadline: Optional[float] = None
                             ) -> Tuple[np.ndarray, np.ndarray]:
        """``get_padded`` + the degraded-row mask (the loader's fetch op).

        Pads (-1 ids) never generate storage traffic; degraded rows are
        rows whose home partition failed this fetch (served stale or fill).
        """
        index = np.asarray(index)
        valid = index >= 0
        key = (group, attr)
        if not valid.any():
            if key not in self._meta:
                probe = self.retry.call(
                    lambda: self.inner._get(key, np.zeros(0, np.int64)))
                self._meta[key] = (np.asarray(probe).shape[1:],
                                   np.asarray(probe).dtype)
            feat_shape, dtype = self._meta[key]
            return (np.full((len(index),) + tuple(feat_shape), fill, dtype),
                    np.zeros(len(index), dtype=bool))
        rows, dmask = self._fetch(key, index[valid], deadline=deadline)
        out = np.full((len(index),) + rows.shape[1:], fill, dtype=rows.dtype)
        out[valid] = rows
        degraded = np.zeros(len(index), dtype=bool)
        degraded[valid] = dmask
        return out, degraded


# --------------------------------------------------------------------------
# Resilient graph store
# --------------------------------------------------------------------------

class ResilientGraphStore(GraphStore):
    """Retry + deadline + breaker + stale-topology degradation for graphs.

    Topology fetches (`_get`, consumed by ``get_csr``/``get_rev_csr``) are
    retried under a single breaker; after the first success the COO is kept
    as last-known-good, so a later backend outage serves the stale topology
    (counted in ``health['stale_topology']``) instead of failing the
    sampler. CSR/CSC caches live in the wrapper, independent of the inner
    store's.
    """

    def __init__(self, inner: GraphStore, *,
                 retry: Optional[RetryPolicy] = None,
                 deadline: Optional[float] = None,
                 failure_threshold: int = 3,
                 recovery_time: float = 0.05,
                 clock: Callable[[], float] = time.monotonic,
                 max_workers: int = 2):
        self.inner = inner
        self.retry = retry if retry is not None else RetryPolicy()
        self.deadline = deadline
        self.breaker = CircuitBreaker(failure_threshold=failure_threshold,
                                      recovery_time=recovery_time,
                                      clock=clock)
        self.health = _fresh_health()
        self.health["stale_topology"] = 0
        self._last_good: Dict[EdgeType, tuple] = {}
        self._caches: Dict[Tuple[EdgeType, str], object] = {}
        self._pool = ThreadPoolExecutor(max_workers=max_workers,
                                        thread_name_prefix="graph-fetch")
        self._lock = threading.Lock()

    def _put(self, etype: EdgeType, coo: tuple):
        self.inner._put(etype, coo)
        with self._lock:
            self._caches = {k: v for k, v in self._caches.items()
                            if k[0] != etype}

    def _get(self, etype: EdgeType):
        with self._lock:
            self.health["requests"] += 1
        if not self.breaker.allow():
            return self._degrade(etype, PartitionUnavailableError(
                0, "graph store breaker open"))

        def once():
            fut = self._pool.submit(self.inner._get, etype)
            try:
                return fut.result(timeout=self.deadline)
            except _FutureTimeout:
                fut.cancel()
                with self._lock:
                    self.health["timeouts"] += 1
                raise FetchTimeoutError(self.deadline or 0.0)

        def on_retry(exc):
            with self._lock:
                self.health["retries"] += 1
            self.breaker.record_failure()

        try:
            coo = self.retry.call(once, on_retry=on_retry)
        except StoreError as exc:
            self.breaker.record_failure()
            self._sync_breaker_health()
            return self._degrade(etype, exc)
        self.breaker.record_success()
        self._sync_breaker_health()
        with self._lock:
            self._last_good[etype] = coo
        return coo

    def _degrade(self, etype: EdgeType, exc: StoreError):
        with self._lock:
            self.health["failures"] += 1
            stale = self._last_good.get(etype)
            if stale is not None:
                self.health["stale_topology"] += 1
                return stale
        raise exc

    def _sync_breaker_health(self):
        with self._lock:
            self.health["breaker_trips"] = self.breaker.trips
            self.health["breaker_recoveries"] = self.breaker.recoveries

    def _cache(self, etype: EdgeType, key: str):
        with self._lock:
            return self._caches.get((etype, key))

    def _set_cache(self, etype: EdgeType, key: str, csr):
        with self._lock:
            self._caches[(etype, key)] = csr

    def edge_types(self):
        return self.inner.edge_types()


# --------------------------------------------------------------------------
# Deterministic chaos injection
# --------------------------------------------------------------------------

@dataclasses.dataclass
class FailureSchedule:
    """Seeded, reproducible fault plan for the chaos wrappers.

    Decisions are drawn from *per-partition* rng streams keyed by
    ``(seed, partition)`` and indexed by that partition's own call counter,
    so the fault sequence each partition sees is independent of how calls
    to other partitions interleave (the resilient fan-out runs partitions
    concurrently). ``blackout`` maps partition -> [(start, stop)] windows in
    that partition's call counts: calls ``start <= c < stop`` raise
    ``PartitionUnavailableError``. Unrouted calls use stream -1.
    """

    seed: int = 0
    error_rate: float = 0.0
    latency_rate: float = 0.0
    latency_s: float = 0.0
    blackout: Dict[int, List[Tuple[int, int]]] = dataclasses.field(
        default_factory=dict)
    sleep: Callable[[float], None] = time.sleep

    def __post_init__(self):
        self._rng: Dict[int, np.random.Generator] = {}
        self._count: Dict[int, int] = {}
        self._lock = threading.Lock()
        self.injected = {"errors": 0, "latency": 0, "blackout": 0,
                         "calls": 0}

    def reset(self):
        """Rewind every stream (identical schedule for a fresh run)."""
        with self._lock:
            self._rng.clear()
            self._count.clear()
            self.injected = {"errors": 0, "latency": 0, "blackout": 0,
                             "calls": 0}

    def _stream(self, partition: int) -> np.random.Generator:
        if partition not in self._rng:
            self._rng[partition] = np.random.default_rng(
                [self.seed, partition & 0xFFFFFFFF])
        return self._rng[partition]

    def check(self, partition: int):
        """Advance partition's stream one call; raise/sleep per the plan."""
        with self._lock:
            c = self._count.get(partition, 0)
            self._count[partition] = c + 1
            self.injected["calls"] += 1
            u = float(self._stream(partition).random())
            for lo, hi in self.blackout.get(partition, ()):
                if lo <= c < hi:
                    self.injected["blackout"] += 1
                    raise PartitionUnavailableError(
                        partition, f"injected blackout (call {c})")
            if u < self.error_rate:
                self.injected["errors"] += 1
                raise TransientStoreError(
                    f"injected transient fault (partition {partition}, "
                    f"call {c})")
            do_latency = u < self.error_rate + self.latency_rate
        if do_latency:
            with self._lock:
                self.injected["latency"] += 1
            self.sleep(self.latency_s)

    def calls(self, partition: int) -> int:
        with self._lock:
            return self._count.get(partition, 0)


class ChaosFeatureStore(FeatureStore):
    """Deterministic fault-injecting decorator for any ``FeatureStore``.

    Each ``_get`` consults the ``FailureSchedule`` before delegating; the
    partition key is the (single) home partition of the requested rows when
    the wrapped chain routes (the resilient fan-out sends one partition per
    call), else -1. ``_put``/``_size`` pass through untouched.
    """

    def __init__(self, inner: FeatureStore, schedule: FailureSchedule):
        self.inner = inner
        self.schedule = schedule
        self._routed = _find_routed(inner)

    def _partition_of(self, key: Key, index) -> int:
        if self._routed is None or index is None:
            return -1
        route = getattr(self._routed, "_route", {}).get(key)
        if route is None:
            return -1
        index = np.asarray(index)
        if index.size == 0:
            return -1
        parts = np.unique(np.asarray(route)[index])
        return int(parts[0]) if len(parts) == 1 else -1

    def _put(self, key, tensor):
        self.inner._put(key, tensor)

    def _get(self, key, index):
        self.schedule.check(self._partition_of(key, index))
        return self.inner._get(key, index)

    def _size(self, key):
        return self.inner._size(key)


class ChaosGraphStore(GraphStore):
    """Deterministic fault-injecting decorator for any ``GraphStore``.

    Injects on topology fetches (`_get`) from stream -1 of the schedule;
    caches are NOT delegated to the inner store, so every ``get_csr`` of a
    fresh wrapper exercises the fetch path.
    """

    def __init__(self, inner: GraphStore, schedule: FailureSchedule):
        self.inner = inner
        self.schedule = schedule
        self._caches: Dict[Tuple[EdgeType, str], object] = {}

    def _put(self, etype, coo):
        self.inner._put(etype, coo)
        self._caches = {k: v for k, v in self._caches.items()
                        if k[0] != etype}

    def _get(self, etype):
        self.schedule.check(-1)
        return self.inner._get(etype)

    def _cache(self, etype, key):
        return self._caches.get((etype, key))

    def _set_cache(self, etype, key, csr):
        self._caches[(etype, key)] = csr

    def edge_types(self):
        return self.inner.edge_types()
