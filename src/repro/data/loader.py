"""NeighborLoader: seeds -> sampler(graph store) -> features(feature store)
-> jit-ready mini-batch — the paper's three-component loading loop (C6).

The loader is oblivious to the storage backends (swap InMemory for
Partitioned without touching this file — the paper's plug-and-play claim)
and emits **static-shape** batches so the jit'd step never recompiles.
Supports externally-seeded iteration (training tables with per-seed
timestamps + attached labels, the RDL workflow of §3.1) via ``transform``.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Callable, Iterator, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.edge_index import EdgeIndex
from repro.data.feature_store import FeatureStore
from repro.data.graph_store import DEFAULT_ETYPE, GraphStore
from repro.data.sampler import NeighborSampler, SamplerOutput


@dataclasses.dataclass
class Batch:
    """A sampled subgraph with fetched features (all jnp, static shapes)."""
    x: jnp.ndarray                    # (N_slots, F) zero rows for padding
    edge_index: EdgeIndex             # local slots; pads are (0, 0) self-loops
    n_id: jnp.ndarray                 # (N_slots,) global node ids (-1 pad)
    e_id: jnp.ndarray                 # (E_slots,) global edge ids (-1 pad)
    seed_slots: jnp.ndarray           # (B,)
    num_sampled_nodes: List[int]
    num_sampled_edges: List[int]
    y: Optional[jnp.ndarray] = None
    edge_mask: Optional[jnp.ndarray] = None
    extras: dict = dataclasses.field(default_factory=dict)

    @property
    def num_nodes(self) -> int:
        return int(self.x.shape[0])

    def seed_output(self, out: jnp.ndarray) -> jnp.ndarray:
        return out[self.seed_slots]


class NeighborLoader:
    def __init__(self, feature_store: FeatureStore, graph_store: GraphStore,
                 *, num_neighbors: Sequence[int], batch_size: int,
                 input_nodes: Optional[np.ndarray] = None,
                 input_time: Optional[np.ndarray] = None,
                 labels_attr: Optional[str] = "y",
                 edge_type=DEFAULT_ETYPE, disjoint: bool = False,
                 temporal_strategy: str = "uniform",
                 transform: Optional[Callable[[Batch], Batch]] = None,
                 shuffle: bool = False, drop_last: bool = True,
                 prefetch: int = 0, seed: int = 0):
        self.fs = feature_store
        self.sampler = NeighborSampler(
            graph_store, num_neighbors, edge_type=edge_type,
            disjoint=disjoint, temporal_strategy=temporal_strategy, seed=seed)
        if input_nodes is None:
            n = feature_store.get_tensor_size(group="node", attr="x")[0]
            input_nodes = np.arange(n)
        self.input_nodes = np.asarray(input_nodes)
        self.input_time = None if input_time is None else np.asarray(
            input_time)
        self.batch_size = batch_size
        self.labels_attr = labels_attr
        self.transform = transform
        self.shuffle = shuffle
        self.drop_last = drop_last
        self.prefetch = prefetch
        self.rng = np.random.default_rng(seed)

    def _make_batch(self, seeds: np.ndarray,
                    seed_time: Optional[np.ndarray]) -> Batch:
        out: SamplerOutput = self.sampler.sample(seeds, seed_time)
        x = self.fs.get_padded(out.node, group="node", attr="x")
        y = None
        if self.labels_attr is not None:
            try:
                y = jnp.asarray(self.fs.get_tensor(
                    group="node", attr=self.labels_attr, index=seeds))
            except KeyError:
                y = None
        n_slots = len(out.node)
        ei = EdgeIndex(jnp.asarray(np.stack([out.row, out.col])).astype(
            jnp.int32), n_slots, n_slots)
        batch = Batch(
            x=jnp.asarray(x), edge_index=ei,
            n_id=jnp.asarray(out.node), e_id=jnp.asarray(out.edge),
            seed_slots=jnp.asarray(out.seed_slots.astype(np.int32)),
            num_sampled_nodes=out.num_sampled_nodes,
            num_sampled_edges=out.num_sampled_edges,
            y=y, edge_mask=jnp.asarray((out.edge >= 0)))
        if self.transform is not None:
            batch = self.transform(batch)
        return batch

    def _seed_batches(self):
        order = np.arange(len(self.input_nodes))
        if self.shuffle:
            self.rng.shuffle(order)
        bs = self.batch_size
        for i in range(0, len(order) - (bs - 1 if self.drop_last else 0), bs):
            idx = order[i:i + bs]
            if len(idx) < bs and self.drop_last:
                break
            yield (self.input_nodes[idx],
                   None if self.input_time is None else self.input_time[idx])

    def __iter__(self) -> Iterator[Batch]:
        if self.prefetch <= 0:
            for seeds, t in self._seed_batches():
                yield self._make_batch(seeds, t)
            return
        # double-buffered host prefetch (the paper's multi-worker loading,
        # adapted: vectorised sampling + a producer thread)
        q: "queue.Queue" = queue.Queue(maxsize=self.prefetch)
        stop = object()

        def producer():
            for seeds, t in self._seed_batches():
                q.put(self._make_batch(seeds, t))
            q.put(stop)

        th = threading.Thread(target=producer, daemon=True)
        th.start()
        while True:
            item = q.get()
            if item is stop:
                break
            yield item
        th.join()

    def __len__(self):
        n = len(self.input_nodes)
        return n // self.batch_size if self.drop_last else -(-n // self.batch_size)
