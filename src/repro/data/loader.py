"""NeighborLoader: seeds -> sampler(graph store) -> features(feature store)
-> jit-ready mini-batch — the paper's three-component loading loop (C6).

The loader is oblivious to the storage backends (swap InMemory for
Partitioned, Cached, Mmap or Resilient without touching this file — the
paper's plug-and-play claim) and emits **static-shape** batches so the
jit'd step never recompiles. Batches are *jit-ready*: the producer path
sorts the sampled COO by destination and pre-fills the ``EdgeIndex``
CSR/CSC caches host-side — plus, when Pallas dispatch is on, a
static-layout blocked-ELL packing whose bucket shapes derive from the
sampler's budgets, so per-batch edge indices passed as jit arguments take
the Pallas SpMM path with a single compilation across batches. ``Batch``
is a registered pytree for exactly this reason. Supports externally-seeded
iteration (training tables with per-seed timestamps + attached labels, the
RDL workflow of §3.1) via ``transform``.

Out-of-core overlap: batch production decomposes into three stages —
**sample** (graph-store walk, sequential so the sampler's seeded RNG draws
in batch order), **gather** (feature-store fetch, the dominant latency
against partitioned/remote/disk backends) and **pack** (host CSR/CSC/ELL
packing + device put). With ``pipeline_depth > 1`` the producer keeps that
many batches in flight on a small worker pool with *ordered reassembly*:
batch ``i``'s gather hides behind the sampling and packing of batches
``i+1..i+depth``, while consumers still see batches in exactly the
sequential order (bit-identical in the fault-free case — the equivalence
tests pin this down). ``partition_order=True`` additionally groups shuffled
seeds by their home partition (discovered through the store chain's routing
table) so each batch's gather touches fewer remote partitions.

Fault tolerance: when the feature store is a
``repro.data.resilience.ResilientFeatureStore`` the gathers fan out per
partition on its thread pool (retries + deadlines + circuit breakers behind
the scenes) and each batch carries an ``extras['degraded']`` row mask for
features served from the stale cache; ``on_batch_error="raise"|"retry"|
"skip"`` decides what a batch-level store failure does — identically in the
sequential and pipelined producers (a failed pipelined chain re-runs the
remaining policy attempts in order at reassembly) — with every
retry/skip/degraded row counted in the loader's ``health`` dict. See the
ROADMAP "Store-backed loading pipeline" subsection.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.edge_index import EdgeIndex
from repro.data.feature_store import FeatureStore
from repro.data.graph_store import DEFAULT_ETYPE, GraphStore
from repro.data.resilience import StoreError, find_routed
from repro.data.sampler import NeighborSampler, SamplerOutput
from repro.kernels import use_pallas
from repro.kernels.spmm.ops import ell_layout_from_bounds


@dataclasses.dataclass
class Batch:
    """A sampled subgraph with fetched features (all jnp, static shapes)."""
    x: jnp.ndarray                    # (N_slots, F) zero rows for padding
    edge_index: EdgeIndex             # local slots; pads are (0, 0) self-loops
    n_id: jnp.ndarray                 # (N_slots,) global node ids (-1 pad)
    e_id: jnp.ndarray                 # (E_slots,) global edge ids (-1 pad)
    seed_slots: jnp.ndarray           # (B,)
    num_sampled_nodes: List[int]
    num_sampled_edges: List[int]
    y: Optional[jnp.ndarray] = None
    edge_mask: Optional[jnp.ndarray] = None
    extras: dict = dataclasses.field(default_factory=dict)

    @property
    def num_nodes(self) -> int:
        return int(self.x.shape[0])

    @property
    def seed_mask(self) -> jnp.ndarray:
        """(B,) True for real seeds, False for -1 shard-padding seeds.

        Per-shard batches only (inside a shard_map body, or shards=1): a
        stacked multi-shard batch must be sliced to one shard first.
        """
        return self.n_id[self.seed_slots] >= 0

    def seed_output(self, out: jnp.ndarray) -> jnp.ndarray:
        return out[self.seed_slots]


def _batch_flatten(b: Batch):
    children = (b.x, b.edge_index, b.n_id, b.e_id, b.seed_slots, b.y,
                b.edge_mask, b.extras)
    aux = (tuple(b.num_sampled_nodes), tuple(b.num_sampled_edges))
    return children, aux


def _batch_unflatten(aux, children):
    x, ei, n_id, e_id, seed_slots, y, edge_mask, extras = children
    nn, ne = aux
    return Batch(x=x, edge_index=ei, n_id=n_id, e_id=e_id,
                 seed_slots=seed_slots, num_sampled_nodes=list(nn),
                 num_sampled_edges=list(ne), y=y, edge_mask=edge_mask,
                 extras=extras)


# Batch flows through jit boundaries whole (the per-hop counts are static
# aux data); identical budgets -> identical treedef -> no recompiles.
jax.tree_util.register_pytree_node(Batch, _batch_flatten, _batch_unflatten)


def split_seed_shards(seeds: np.ndarray,
                      seed_time: Optional[np.ndarray],
                      shards: int):
    """Split one global seed batch into ``shards`` equal-size parts.

    Pure numpy (producer-thread stage). When the batch doesn't divide, the
    tail pads with -1 seeds (seed time 0) up to ``ceil(B/shards)`` per shard
    — the masked-seed convention the sampler keeps out of its dedup table
    and ``Batch.seed_mask`` exposes to the loss. Returns a list of
    ``(seeds, seed_time)`` pairs, one per shard.
    """
    shards = int(shards)
    if shards < 1:
        raise ValueError(f"shards must be >= 1, got {shards}")
    seeds = np.asarray(seeds, np.int64)
    per = -(-len(seeds) // shards)
    pad = per * shards - len(seeds)
    if pad:
        seeds = np.concatenate([seeds, np.full(pad, -1, seeds.dtype)])
        if seed_time is not None:
            seed_time = np.concatenate(
                [seed_time, np.zeros(pad, seed_time.dtype)])
    return [(seeds[i * per:(i + 1) * per],
             None if seed_time is None
             else seed_time[i * per:(i + 1) * per])
            for i in range(shards)]


def stack_batches(batches: List[Batch]) -> Batch:
    """Stack per-shard batches leaf-wise into one leading-``D``-axis pytree.

    The stacked batch is what the mesh trainer shards over the ``data``
    axis: every leaf gains a leading shard dimension, the static aux data
    (per-hop counts) is shared. Requires identical treedefs — i.e. equal
    per-shard seed counts, which ``split_seed_shards`` guarantees.
    """
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *batches)


_SKIP = object()  # sentinel: a batch dropped by on_batch_error="skip"

_BATCH_ERROR_MODES = ("raise", "retry", "skip")


class _PrefetchLoader:
    """Seed-batching + pipelined/prefetch production shared by both loaders.

    Subclasses set ``input_nodes``, ``input_time``, ``batch_size``,
    ``shuffle``, ``drop_last``, ``prefetch``, ``pipeline_depth``,
    ``partition_order`` and ``rng`` in ``__init__`` and implement the three
    production stages:

      * ``_stage_sample(seeds, seed_time)`` — graph-store sampling + any
        shared shape/layout decisions. Always called sequentially in batch
        order (the sampler's seeded RNG must draw deterministically), pure
        numpy.
      * ``_stage_gather(sample)`` — feature-store fetch for the sampled
        nodes. The dominant latency against partitioned/remote/disk
        stores; safe to run concurrently across batches, pure numpy.
      * ``_stage_pack(sample, gather)`` — host ELL/CSR packing + device
        put, assembling the final batch.

    ``_make_batch`` composes the three, so the sequential path and the
    policy retry loop re-run one chain. Iteration (the producer thread,
    the stage pipeline with ordered reassembly, exception propagation
    through the queue, and reaping of abandoned producers/workers) lives
    here once — the homogeneous and heterogeneous loaders differ only in
    what a batch *is*.

    Store failures (``repro.data.resilience.StoreError``) are policy, not
    fate: ``on_batch_error`` picks what a failed batch chain does —
    ``"raise"`` propagates immediately, ``"retry"`` re-samples/re-fetches
    the same seeds up to ``batch_retries`` times then raises, ``"skip"``
    retries then drops the batch and keeps the epoch going. Every decision
    lands in the ``health`` counter dict ({batches, batch_retries,
    skipped_batches, degraded_rows}); degraded rows are read off the
    batch's ``extras['degraded']`` mask (filled by the resilient feature
    store). Non-store exceptions always propagate — a bug is not a fault.
    The pipelined producer applies the *same* policy with the same
    counters: a chain that failed in flight consumed attempt 0, and the
    remaining attempts re-run sequentially at its reassembly slot.
    """

    input_nodes: np.ndarray
    input_time: Optional[np.ndarray]
    batch_size: int
    shuffle: bool
    drop_last: bool
    prefetch: int
    pipeline_depth: int = 1
    partition_order: bool = False
    rng: np.random.Generator
    on_batch_error: str = "raise"
    batch_retries: int = 2

    # ---- the three production stages (subclass contract) ----
    def _stage_sample(self, seeds: np.ndarray,
                      seed_time: Optional[np.ndarray]):
        raise NotImplementedError

    def _stage_gather(self, sample):
        raise NotImplementedError

    def _stage_pack(self, sample, gather):
        raise NotImplementedError

    def _make_batch(self, seeds: np.ndarray,
                    seed_time: Optional[np.ndarray]):
        sample = self._stage_sample(seeds, seed_time)
        return self._stage_pack(sample, self._stage_gather(sample))

    def _init_policy(self, on_batch_error: str, batch_retries: int):
        if on_batch_error not in _BATCH_ERROR_MODES:
            raise ValueError(f"on_batch_error must be one of "
                             f"{_BATCH_ERROR_MODES}, got {on_batch_error!r}")
        self.on_batch_error = on_batch_error
        self.batch_retries = int(batch_retries)
        self.health = {"batches": 0, "batch_retries": 0,
                       "skipped_batches": 0, "degraded_rows": 0}

    def _init_pipeline(self, pipeline_depth: int, partition_order: bool):
        self.pipeline_depth = int(pipeline_depth)
        if self.pipeline_depth < 0:
            raise ValueError(
                f"pipeline_depth must be >= 0, got {pipeline_depth}")
        self.partition_order = bool(partition_order)

    @staticmethod
    def _degraded_count(batch) -> int:
        extras = getattr(batch, "extras", None)
        if not extras or "degraded" not in extras:
            return 0
        d = extras["degraded"]
        leaves = d.values() if isinstance(d, dict) else [d]
        return int(sum(int(np.asarray(m).sum()) for m in leaves))

    def _count_success(self, batch) -> None:
        self.health["batches"] += 1
        self.health["degraded_rows"] += self._degraded_count(batch)

    def _make_batch_guarded(self, seeds, seed_time, abort=None):
        """Apply ``on_batch_error`` around the full batch chain.

        Returns the batch, or ``_SKIP`` when the policy drops it. ``abort``
        (the producer's abandonment flag) bounds how long a retry loop can
        hold the producer thread after the consumer is gone.
        """
        if not hasattr(self, "health"):
            self._init_policy(self.on_batch_error, self.batch_retries)
        try:
            batch = self._make_batch(seeds, seed_time)
        except StoreError as exc:
            return self._finish_policy(seeds, seed_time, exc, abort)
        self._count_success(batch)
        return batch

    def _finish_policy(self, seeds, seed_time, first_exc, abort):
        """Policy attempts 1..N after attempt 0 raised ``first_exc``.

        Shared by the sequential path and the pipelined reassembly (where
        attempt 0 ran — and failed — in flight on the worker pool). Health
        accounting is identical either way.
        """
        attempts = (1 if self.on_batch_error == "raise"
                    else 1 + self.batch_retries)
        last = first_exc
        if attempts > 1:
            self.health["batch_retries"] += 1
        for attempt in range(1, attempts):
            if abort is not None and abort():
                break
            try:
                batch = self._make_batch(seeds, seed_time)
            except StoreError as exc:
                last = exc
                if attempt + 1 < attempts:
                    self.health["batch_retries"] += 1
                continue
            self._count_success(batch)
            return batch
        if self.on_batch_error == "skip":
            self.health["skipped_batches"] += 1
            return _SKIP
        raise last

    # ---- seed batching ----
    def _seed_route(self) -> Optional[np.ndarray]:
        """Home partition of every *input node*, via the feature-store
        chain's routing table (None when the chain doesn't route)."""
        routed = find_routed(getattr(self, "fs", None))
        if routed is None:
            return None
        route = getattr(routed, "_route", {}).get(self._seed_feature_key())
        if route is None:
            return None
        return np.asarray(route)[self.input_nodes]

    def _seed_feature_key(self):
        """(group, attr) of the seed features (hetero overrides group)."""
        return ("node", "x")

    def _seed_batches(self):
        order = np.arange(len(self.input_nodes))
        if self.shuffle:
            self.rng.shuffle(order)
        if self.partition_order:
            # group (shuffled) seeds by home partition: each batch's gather
            # then touches one — or few — partitions, cutting the remote-row
            # fraction. A stable sort keeps the shuffled order within each
            # partition, so epochs stay randomised *inside* locality groups.
            part = self._seed_route()
            if part is not None:
                order = order[np.argsort(part[order], kind="stable")]
        bs = self.batch_size
        for i in range(0, len(order) - (bs - 1 if self.drop_last else 0), bs):
            idx = order[i:i + bs]
            if len(idx) < bs and self.drop_last:
                break
            yield (self.input_nodes[idx],
                   None if self.input_time is None else self.input_time[idx])

    # ---- batch production (sequential or stage-pipelined) ----
    def _produce(self, abort=None):
        """Yield policy-guarded batches in seed-batch order."""
        if not hasattr(self, "health"):
            self._init_policy(self.on_batch_error, self.batch_retries)
        if self.pipeline_depth > 1:
            yield from self._produce_pipelined(abort)
            return
        for seeds, t in self._seed_batches():
            if abort is not None and abort():
                return
            batch = self._make_batch_guarded(seeds, t, abort=abort)
            if batch is not _SKIP:
                yield batch

    def _produce_pipelined(self, abort=None):
        """Stage-pipelined production with ordered reassembly.

        Sampling stays sequential on this thread (deterministic RNG draw
        order); each sampled batch's *gather* is submitted to a bounded
        worker pool, up to ``pipeline_depth`` gathers in flight. Gather is
        the stage that blocks on the store (remote/disk I/O releases the
        GIL), so batch ``i``'s fetch latency hides behind the sampling and
        packing of its successors; packing stays on this thread at
        reassembly time — host packing is CPU-bound and would only fight
        the coordinator for the GIL on a worker, and coordinator packing
        keeps device puts single-threaded and the in-memory fast path
        overhead-free. Batches are yielded strictly in submission order,
        so consumers see exactly the sequential sequence. A chain that
        raises a ``StoreError`` re-enters the policy loop at its
        reassembly slot (the in-flight run was attempt 0); non-store
        errors propagate from the head slot in order. The pool is torn
        down (and every worker joined) when the generator closes, however
        early — abandonment cannot leak stage workers.
        """
        depth = self.pipeline_depth
        pool = ThreadPoolExecutor(max_workers=depth,
                                  thread_name_prefix="loader-stage")
        inflight: deque = deque()  # (seeds, t, sample, Future | StoreError)
        seed_iter = self._seed_batches()
        exhausted = False
        try:
            while True:
                while not exhausted and len(inflight) < depth:
                    try:
                        seeds, t = next(seed_iter)
                    except StopIteration:
                        exhausted = True
                        break
                    try:
                        sample = self._stage_sample(seeds, t)
                    except StoreError as exc:  # sampling itself can fetch
                        inflight.append((seeds, t, None, exc))
                    else:
                        inflight.append((seeds, t, sample, pool.submit(
                            self._stage_gather, sample)))
                if not inflight:
                    return
                seeds, t, sample, head = inflight.popleft()
                try:
                    if isinstance(head, StoreError):
                        raise head
                    batch = self._stage_pack(sample, head.result())
                except StoreError as exc:
                    batch = self._finish_policy(seeds, t, exc, abort)
                else:
                    self._count_success(batch)
                if batch is not _SKIP:
                    yield batch
                if abort is not None and abort():
                    return
        finally:
            pool.shutdown(wait=True, cancel_futures=True)

    def __iter__(self):
        if self.prefetch <= 0:
            # inline production on the consumer thread; with
            # pipeline_depth > 1 gathers still overlap on the worker pool
            gen = self._produce()
            try:
                yield from gen
            finally:
                gen.close()  # deterministic worker-pool teardown
            return
        # bounded host prefetch: a producer thread runs the (sequential or
        # pipelined) generator and feeds the consumer through a queue
        q: "queue.Queue" = queue.Queue(maxsize=self.prefetch)
        stop = object()
        abandoned = threading.Event()

        def producer():
            # A raised exception must reach the consumer: swallowing it here
            # would never enqueue the sentinel and deadlock `q.get()`.
            gen = self._produce(abort=abandoned.is_set)
            try:
                for batch in gen:
                    if abandoned.is_set():
                        return
                    q.put(batch)
            except BaseException as exc:  # noqa: BLE001 - re-raised below
                q.put(exc)
                return
            finally:
                gen.close()  # reap stage workers even on abandonment
            q.put(stop)

        th = threading.Thread(target=producer, daemon=True,
                              name="loader-producer")
        th.start()
        try:
            while True:
                item = q.get()
                if item is stop:
                    break
                if isinstance(item, BaseException):
                    raise item
                yield item
        finally:
            # Reap the producer even when the consumer abandons the iterator
            # early (GeneratorExit): drain the bounded queue so a blocked
            # q.put unblocks, then join.
            abandoned.set()
            while th.is_alive():
                try:
                    q.get_nowait()
                except queue.Empty:
                    pass
                th.join(timeout=0.01)

    def __len__(self):
        n = len(self.input_nodes)
        return n // self.batch_size if self.drop_last else -(-n // self.batch_size)


class NeighborLoader(_PrefetchLoader):
    def __init__(self, feature_store: FeatureStore, graph_store: GraphStore,
                 *, num_neighbors: Sequence[int], batch_size: int,
                 input_nodes: Optional[np.ndarray] = None,
                 input_time: Optional[np.ndarray] = None,
                 labels_attr: Optional[str] = "y",
                 edge_type=DEFAULT_ETYPE, disjoint: bool = False,
                 temporal_strategy: str = "uniform",
                 transform: Optional[Callable[[Batch], Batch]] = None,
                 shuffle: bool = False, drop_last: bool = True,
                 prefetch: int = 0, pipeline_depth: int = 1,
                 partition_order: bool = False,
                 prefill_ell: Optional[bool] = None,
                 on_batch_error: str = "raise", batch_retries: int = 2,
                 shards: int = 1, seed: int = 0):
        self.fs = feature_store
        self.shards = int(shards)
        if self.shards < 1:
            raise ValueError(f"shards must be >= 1, got {shards}")
        self._init_policy(on_batch_error, batch_retries)
        self._init_pipeline(pipeline_depth, partition_order)
        self.sampler = NeighborSampler(
            graph_store, num_neighbors, edge_type=edge_type,
            disjoint=disjoint, temporal_strategy=temporal_strategy, seed=seed)
        if input_nodes is None:
            n = feature_store.get_tensor_size(group="node", attr="x")[0]
            input_nodes = np.arange(n)
        self.input_nodes = np.asarray(input_nodes)
        self.input_time = None if input_time is None else np.asarray(
            input_time)
        self.batch_size = batch_size
        self.labels_attr = labels_attr
        self.transform = transform
        self.shuffle = shuffle
        self.drop_last = drop_last
        self.prefetch = prefetch
        # Static-layout ELL packing plan: depends only on the sampler's
        # budgets and the seed count, shared by every batch of that size
        # (a drop_last=False tail batch gets its own, smaller layout).
        self.prefill_ell = prefill_ell
        self._ell_layouts: dict = {}
        self.rng = np.random.default_rng(seed)

    def _ell_layout_for(self, num_seeds: int):
        if num_seeds not in self._ell_layouts:
            self._ell_layouts[num_seeds] = ell_layout_from_bounds(
                self.sampler.slot_degree_bounds(num_seeds))
        return self._ell_layouts[num_seeds]

    # ---- stages ----
    # With shards > 1 each stage runs its single-shard body once per shard
    # (sampling stays in shard order for deterministic RNG draws) and
    # ``_stage_pack`` stacks the per-shard batches leaf-wise; health
    # counters keep counting *global* batches either way.
    def _stage_sample(self, seeds: np.ndarray,
                      seed_time: Optional[np.ndarray]):
        """Sequential: sampler RNG draws + the (cached) shared ELL layout
        decision both happen in batch order on one thread."""
        if self.shards == 1:
            return self._sample_one(seeds, seed_time)
        return {"parts": [self._sample_one(s, t) for s, t in
                          split_seed_shards(seeds, seed_time, self.shards)]}

    def _stage_gather(self, sample):
        """Feature (+ label) fetch — the latency this pipeline hides."""
        if "parts" not in sample:
            return self._gather_one(sample)
        return {"parts": [self._gather_one(p) for p in sample["parts"]]}

    def _stage_pack(self, sample, gather) -> Batch:
        """Host ELL/CSR packing + device put -> the jit-ready batch."""
        if "parts" not in sample:
            return self._pack_one(sample, gather)
        return stack_batches([
            self._pack_one(s, g)
            for s, g in zip(sample["parts"], gather["parts"])])

    def _sample_one(self, seeds: np.ndarray,
                    seed_time: Optional[np.ndarray]):
        out: SamplerOutput = self.sampler.sample(seeds, seed_time)
        fill_ell = (use_pallas() if self.prefill_ell is None
                    else self.prefill_ell)
        layout = self._ell_layout_for(len(seeds)) if fill_ell else None
        return {"seeds": seeds, "out": out, "layout": layout,
                "fill_ell": fill_ell}

    def _gather_one(self, sample):
        out: SamplerOutput = sample["out"]
        fetch = getattr(self.fs, "get_padded_resilient", None)
        degraded = None
        if fetch is not None:  # resilient store: degraded-row mask surfaced
            x, degraded = fetch(out.node, group="node", attr="x")
        else:
            x = self.fs.get_padded(out.node, group="node", attr="x")
        y = None
        if self.labels_attr is not None:
            seeds = np.asarray(sample["seeds"])
            # -1 shard-padding seeds must not wrap to the last row: gather
            # through a safe index, then zero the padded label rows.
            safe = np.where(seeds >= 0, seeds, 0)
            try:
                y = self.fs.get_tensor(
                    group="node", attr=self.labels_attr, index=safe)
            except KeyError:
                y = None
            if y is not None and (seeds < 0).any():
                y = np.asarray(y)
                mask = (seeds >= 0).reshape(
                    (-1,) + (1,) * (y.ndim - 1))
                y = np.where(mask, y, np.zeros((), y.dtype))
        return {"x": x, "y": y, "degraded": degraded}

    def _pack_one(self, sample, gather) -> Batch:
        out: SamplerOutput = sample["out"]
        n_slots = len(out.node)
        ei = EdgeIndex.from_coo_prefilled(
            out.row, out.col, n_slots, n_slots,
            ell_layout=sample["layout"] if sample["fill_ell"] else None)
        batch = Batch(
            x=jnp.asarray(gather["x"]), edge_index=ei,
            n_id=jnp.asarray(out.node), e_id=jnp.asarray(out.edge),
            seed_slots=jnp.asarray(out.seed_slots.astype(np.int32)),
            num_sampled_nodes=out.num_sampled_nodes,
            num_sampled_edges=out.num_sampled_edges,
            y=None if gather["y"] is None else jnp.asarray(gather["y"]),
            edge_mask=jnp.asarray((out.edge >= 0)))
        if gather["degraded"] is not None:
            batch.extras["degraded"] = jnp.asarray(gather["degraded"])
        if self.transform is not None:
            batch = self.transform(batch)
        return batch
