"""FeatureStore — the paper's remote-backend interface (C6, §2.3).

"Users that define custom feature handling are only required to specify the
implementation of the get operation on their feature backend" — the abstract
interface below is exactly that: ``_get`` / ``_put`` on (group, attr) keyed
tensors, with the loader oblivious to where features live.

Implementations here:
  * InMemoryFeatureStore — plain dict-of-arrays.
  * PartitionedFeatureStore — features sharded into partitions with a
    routing table; ``get`` fans indices out per partition and re-assembles
    (the JAX-land stand-in for WholeGraph/remote KV stores). Fetch counters
    expose the remote-traffic behaviour that the paper's distributed
    benchmarks measure (``stats`` is lock-guarded: the resilient fan-out
    issues concurrent per-partition gets from a thread pool).

Fault tolerance lives one layer up, in ``repro.data.resilience``:
``ResilientFeatureStore`` decorates any backend here with bounded retries,
per-fetch deadlines, per-partition circuit breakers, and a last-known-good
row cache that serves stale features (recorded in its ``health`` counters
and the batch's ``extras['degraded']`` mask) when a partition is down;
``ChaosFeatureStore`` injects deterministic faults for tests/benchmarks.
"""

from __future__ import annotations

import abc
import threading
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

Key = Tuple[str, str]  # (group e.g. node type, attr e.g. 'x')


class FeatureStore(abc.ABC):
    @abc.abstractmethod
    def _put(self, key: Key, tensor: np.ndarray) -> None: ...

    @abc.abstractmethod
    def _get(self, key: Key, index: Optional[np.ndarray]) -> np.ndarray: ...

    @abc.abstractmethod
    def _size(self, key: Key) -> Tuple[int, ...]: ...

    # ---- public API (PyG-style) ----
    def put_tensor(self, tensor, *, group: str = "node", attr: str = "x"):
        self._put((group, attr), np.asarray(tensor))
        return self

    def get_tensor(self, *, group: str = "node", attr: str = "x",
                   index: Optional[np.ndarray] = None) -> np.ndarray:
        return self._get((group, attr), index)

    def get_tensor_size(self, *, group: str = "node", attr: str = "x"):
        return self._size((group, attr))

    def get_padded(self, index: np.ndarray, *, group: str = "node",
                   attr: str = "x", fill: float = 0.0) -> np.ndarray:
        """Gather with -1 = padding -> fill rows (the loader's fetch op).

        Exactly ONE backend fetch: the valid rows are fetched once and
        dtype/feature shape derive from that same result (an all-pad index
        issues an *empty* fetch, which also works on an empty store) — pads
        never generate storage traffic and the fetch isn't double-counted
        in backend stats.
        """
        index = np.asarray(index)
        valid = index >= 0
        rows = self.get_tensor(group=group, attr=attr,
                               index=index[valid].astype(np.int64))
        out = np.full((len(index),) + rows.shape[1:], fill, dtype=rows.dtype)
        out[valid] = rows
        return out


class InMemoryFeatureStore(FeatureStore):
    def __init__(self):
        self._data: Dict[Key, np.ndarray] = {}

    def _put(self, key, tensor):
        self._data[key] = tensor

    def _get(self, key, index):
        t = self._data[key]
        return t if index is None else t[np.asarray(index)]

    def _size(self, key):
        return tuple(self._data[key].shape)

    def keys(self):
        return list(self._data)


class PartitionedFeatureStore(FeatureStore):
    """Row-partitioned store with a routing table (distributed stand-in).

    ``get`` groups requested rows by home partition, "fetches" from each
    (counted as remote traffic for partitions != local_rank), and
    scatter-assembles — the access pattern of a real sharded KV/embedding
    service, with the training loop fully oblivious (paper C6/C10).
    """

    def __init__(self, num_parts: int, local_rank: int = 0):
        self.num_parts = num_parts
        self.local_rank = local_rank
        self._parts: Dict[Key, List[np.ndarray]] = {}
        self._route: Dict[Key, np.ndarray] = {}     # global row -> partition
        self._local_idx: Dict[Key, np.ndarray] = {}  # global row -> row-in-part
        self.stats = {"local_rows": 0, "remote_rows": 0, "requests": 0}
        self._lock = threading.Lock()

    def _put(self, key, tensor):
        n = tensor.shape[0]
        route = np.arange(n) % self.num_parts  # block-cyclic by default
        self.put_partitioned(key, tensor, route)

    def put_partitioned(self, key: Key, tensor: np.ndarray,
                        route: np.ndarray):
        parts, local_idx = [], np.zeros(len(route), np.int64)
        for p in range(self.num_parts):
            rows = np.where(route == p)[0]
            local_idx[rows] = np.arange(len(rows))
            parts.append(tensor[rows])
        self._parts[key] = parts
        self._route[key] = np.asarray(route)
        self._local_idx[key] = local_idx

    def _feat_meta(self, key) -> Tuple[tuple, np.dtype]:
        """(feature shape, dtype) from any non-empty partition.

        Partition 0 may be empty (``num_parts > num_rows`` or a skewed
        custom route); any partition slice carries the trailing shape, but
        prefer a populated one so subclasses with lazily-materialised parts
        stay correct.
        """
        parts = self._parts[key]
        ref = next((p for p in parts if len(p)), parts[0])
        return tuple(ref.shape[1:]), ref.dtype

    def _get(self, key, index):
        route = self._route[key]
        if index is None:
            index = np.arange(len(route))
        index = np.asarray(index)
        local = self._local_idx[key][index]
        part = route[index]
        feat_dim, dtype = self._feat_meta(key)
        out = np.zeros((len(index),) + feat_dim, dtype=dtype)
        with self._lock:
            self.stats["requests"] += 1
            for p in range(self.num_parts):
                m = part == p
                cnt = int(m.sum())
                if not cnt:
                    continue
                out[m] = self._parts[key][p][local[m]]
                if p == self.local_rank:
                    self.stats["local_rows"] += cnt
                else:
                    self.stats["remote_rows"] += cnt
        return out

    def _size(self, key):
        n = len(self._route[key])
        return (n,) + self._feat_meta(key)[0]
