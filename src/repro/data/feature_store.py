"""FeatureStore — the paper's remote-backend interface (C6, §2.3).

"Users that define custom feature handling are only required to specify the
implementation of the get operation on their feature backend" — the abstract
interface below is exactly that: ``_get`` / ``_put`` on (group, attr) keyed
tensors, with the loader oblivious to where features live.

Implementations here:
  * InMemoryFeatureStore — plain dict-of-arrays.
  * PartitionedFeatureStore — features sharded into partitions with a
    routing table; ``get`` fans indices out per partition and re-assembles
    (the JAX-land stand-in for WholeGraph/remote KV stores). Fetch counters
    expose the remote-traffic behaviour that the paper's distributed
    benchmarks measure (``stats`` is lock-guarded: the resilient fan-out
    and the pipelined loader issue concurrent gets from thread pools).
  * CachedFeatureStore — a bounded cross-batch **hot-feature cache** over
    any backend: power-law graphs refetch the same hub rows every batch,
    and this wrapper short-circuits those rows out of the traffic entirely
    (seeded sampled-LFU eviction, pure numpy, hit/miss counters). Distinct
    from resilience's last-known-good cache, which serves *only on
    failure* — this one serves on every hit and changes traffic, never
    failure semantics.
  * MmapFeatureStore — **out-of-core** features: tensors live in on-disk
    ``np.memmap`` files and gathers touch only the requested rows' pages,
    so a feature matrix far larger than the configured host-memory budget
    streams through the unchanged loader -> jit'd step (the paper's
    disk-backed-store claim); full-tensor materialisation above the budget
    is refused with ``MemoryBudgetError``.

Every store exposes ``reset_stats()``, which zeroes the ``stats``/``health``
counter dicts down the whole ``.inner`` wrapper chain (benchmarks reset
between cells instead of poking ``fs.stats`` by hand).

Fault tolerance lives one layer up, in ``repro.data.resilience``:
``ResilientFeatureStore`` decorates any backend here with bounded retries,
per-fetch deadlines, per-partition circuit breakers, and a last-known-good
row cache that serves stale features (recorded in its ``health`` counters
and the batch's ``extras['degraded']`` mask) when a partition is down;
``ChaosFeatureStore`` injects deterministic faults for tests/benchmarks.
The wrappers compose through ``.inner`` — e.g.
``ResilientFeatureStore(CachedFeatureStore(PartitionedFeatureStore(...)))``
keeps routing discovery, the hot cache, and degradation all working.
"""

from __future__ import annotations

import abc
import os
import tempfile
import threading
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

Key = Tuple[str, str]  # (group e.g. node type, attr e.g. 'x')


class MemoryBudgetError(RuntimeError):
    """A fetch would materialise more bytes than the configured budget.

    Deliberately NOT a ``TransientStoreError``: exceeding the host-memory
    budget is a programming/sizing bug, not a fault to retry or degrade."""


class FeatureStore(abc.ABC):
    @abc.abstractmethod
    def _put(self, key: Key, tensor: np.ndarray) -> None: ...

    @abc.abstractmethod
    def _get(self, key: Key, index: Optional[np.ndarray]) -> np.ndarray: ...

    @abc.abstractmethod
    def _size(self, key: Key) -> Tuple[int, ...]: ...

    # ---- public API (PyG-style) ----
    def put_tensor(self, tensor, *, group: str = "node", attr: str = "x"):
        self._put((group, attr), np.asarray(tensor))
        return self

    def get_tensor(self, *, group: str = "node", attr: str = "x",
                   index: Optional[np.ndarray] = None) -> np.ndarray:
        return self._get((group, attr), index)

    def get_tensor_size(self, *, group: str = "node", attr: str = "x"):
        return self._size((group, attr))

    def get_padded(self, index: np.ndarray, *, group: str = "node",
                   attr: str = "x", fill: float = 0.0) -> np.ndarray:
        """Gather with -1 = padding -> fill rows (the loader's fetch op).

        Exactly ONE backend fetch: the valid rows are fetched once and
        dtype/feature shape derive from that same result (an all-pad index
        issues an *empty* fetch, which also works on an empty store) — pads
        never generate storage traffic and the fetch isn't double-counted
        in backend stats.
        """
        index = np.asarray(index)
        valid = index >= 0
        rows = self.get_tensor(group=group, attr=attr,
                               index=index[valid].astype(np.int64))
        out = np.full((len(index),) + rows.shape[1:], fill, dtype=rows.dtype)
        out[valid] = rows
        return out

    def reset_stats(self):
        """Zero every counter dict (``stats``/``health``) down the wrapper
        chain, in place (shared references stay live). Returns ``self`` so
        benchmarks can chain it."""
        s = self
        while s is not None:
            for name in ("stats", "health"):
                d = getattr(s, name, None)
                if isinstance(d, dict):
                    for k in d:
                        if isinstance(d[k], (int, float)):
                            d[k] = 0
            s = getattr(s, "inner", None)
        return self


class InMemoryFeatureStore(FeatureStore):
    def __init__(self):
        self._data: Dict[Key, np.ndarray] = {}

    def _put(self, key, tensor):
        self._data[key] = tensor

    def _get(self, key, index):
        t = self._data[key]
        return t if index is None else t[np.asarray(index)]

    def _size(self, key):
        return tuple(self._data[key].shape)

    def keys(self):
        return list(self._data)


class PartitionedFeatureStore(FeatureStore):
    """Row-partitioned store with a routing table (distributed stand-in).

    ``get`` groups requested rows by home partition, "fetches" from each
    (counted as remote traffic for partitions != local_rank), and
    scatter-assembles — the access pattern of a real sharded KV/embedding
    service, with the training loop fully oblivious (paper C6/C10).
    """

    def __init__(self, num_parts: int, local_rank: int = 0):
        self.num_parts = num_parts
        self.local_rank = local_rank
        self._parts: Dict[Key, List[np.ndarray]] = {}
        self._route: Dict[Key, np.ndarray] = {}     # global row -> partition
        self._local_idx: Dict[Key, np.ndarray] = {}  # global row -> row-in-part
        self.stats = {"local_rows": 0, "remote_rows": 0, "requests": 0}
        self._lock = threading.Lock()

    def _put(self, key, tensor):
        n = tensor.shape[0]
        route = np.arange(n) % self.num_parts  # block-cyclic by default
        self.put_partitioned(key, tensor, route)

    def put_partitioned(self, key: Key, tensor: np.ndarray,
                        route: np.ndarray):
        parts, local_idx = [], np.zeros(len(route), np.int64)
        for p in range(self.num_parts):
            rows = np.where(route == p)[0]
            local_idx[rows] = np.arange(len(rows))
            parts.append(tensor[rows])
        self._parts[key] = parts
        self._route[key] = np.asarray(route)
        self._local_idx[key] = local_idx

    def _feat_meta(self, key) -> Tuple[tuple, np.dtype]:
        """(feature shape, dtype) from any non-empty partition.

        Partition 0 may be empty (``num_parts > num_rows`` or a skewed
        custom route); any partition slice carries the trailing shape, but
        prefer a populated one so subclasses with lazily-materialised parts
        stay correct.
        """
        parts = self._parts[key]
        ref = next((p for p in parts if len(p)), parts[0])
        return tuple(ref.shape[1:]), ref.dtype

    def _get(self, key, index):
        route = self._route[key]
        if index is None:
            index = np.arange(len(route))
        index = np.asarray(index)
        local = self._local_idx[key][index]
        part = route[index]
        feat_dim, dtype = self._feat_meta(key)
        out = np.zeros((len(index),) + feat_dim, dtype=dtype)
        local_rows = remote_rows = 0
        # gathers run lock-free so pipelined batches overlap; only the
        # counter update is guarded
        for p in range(self.num_parts):
            m = part == p
            cnt = int(m.sum())
            if not cnt:
                continue
            out[m] = self._parts[key][p][local[m]]
            if p == self.local_rank:
                local_rows += cnt
            else:
                remote_rows += cnt
        with self._lock:
            self.stats["requests"] += 1
            self.stats["local_rows"] += local_rows
            self.stats["remote_rows"] += remote_rows
        return out

    def _size(self, key):
        n = len(self._route[key])
        return (n,) + self._feat_meta(key)[0]


# --------------------------------------------------------------------------
# Cross-batch hot-feature cache
# --------------------------------------------------------------------------

class HotRowCache:
    """Bounded hot-row cache: pure-numpy lookup/insert, seeded eviction.

    ``slot_of`` maps global row -> slot (-1 = not cached), ``owner`` maps
    slot -> global row, ``hits`` counts per-slot lookups since insertion.
    Eviction is **sampled-LFU with a seeded rng**: when slots run out, a
    seeded random candidate window is drawn and its least-hit slots (slot
    index as the deterministic tiebreak) are reclaimed — hubs with high hit
    counts survive, and the whole decision sequence is reproducible from
    the seed. All operations are vectorised gathers/scatters (no per-row
    Python), so the zero-miss overhead stays in the noise on the loader's
    gather path.
    """

    # evict from a candidate window this many times the needed slot count
    # (power-of-k-choices: wider windows approximate true LFU more closely)
    CANDIDATE_FACTOR = 4

    def __init__(self, num_rows: int, capacity: int, seed: int = 0):
        self.capacity = max(int(capacity), 1)
        self.slot_of = np.full(num_rows, -1, np.int64)
        self.owner = np.full(self.capacity, -1, np.int64)
        self.hits = np.zeros(self.capacity, np.int64)
        self.vals: Optional[np.ndarray] = None
        self.evictions = 0
        self._rng = np.random.default_rng(seed)

    def lookup(self, rows: np.ndarray
               ) -> Tuple[Optional[np.ndarray], np.ndarray]:
        """-> (values for the cached subset, have-mask over ``rows``)."""
        rows = np.asarray(rows, np.int64)
        slot = self.slot_of[rows]
        have = slot >= 0
        if self.vals is None or not have.any():
            return None, np.zeros(len(rows), bool)
        np.add.at(self.hits, slot[have], 1)
        return self.vals[slot[have]], have

    def insert(self, rows: np.ndarray, values: np.ndarray) -> None:
        rows = np.asarray(rows, np.int64)
        if rows.size == 0:
            return
        # first-occurrence dedup (concurrent fetches may overlap rows)
        _, first = np.unique(rows, return_index=True)
        keep = np.sort(first)
        rows, values = rows[keep], values[keep]
        if self.vals is None:
            self.vals = np.zeros((self.capacity,) + values.shape[1:],
                                 values.dtype)
        slot = self.slot_of[rows]
        cached = slot >= 0
        self.vals[slot[cached]] = values[cached]  # refresh in place
        new_rows, new_vals = rows[~cached], values[~cached]
        if new_rows.size == 0:
            return
        # slots holding rows refreshed this call must not be reclaimed
        protected = np.zeros(self.capacity, bool)
        protected[slot[cached]] = True
        avail = self.capacity - int(protected.sum())
        if len(new_rows) > avail:  # keep the first `avail` (deterministic)
            new_rows, new_vals = new_rows[:avail], new_vals[:avail]
        free = np.where(self.owner < 0)[0][:len(new_rows)]
        sel = free
        need = len(new_rows) - len(free)
        if need > 0:
            sel = np.concatenate([free, self._evict(need, protected)])
        prev = self.owner[sel]
        live = prev >= 0
        self.evictions += int(live.sum())
        self.slot_of[prev[live]] = -1
        self.owner[sel] = new_rows
        self.slot_of[new_rows] = sel
        self.hits[sel] = 0
        self.vals[sel] = new_vals

    def _evict(self, need: int, protected: np.ndarray) -> np.ndarray:
        """Reclaim ``need`` occupied slots: seeded sampled-LFU."""
        window = self._rng.permutation(self.capacity)
        window = window[~protected[window] & (self.owner[window] >= 0)]
        window = window[:max(need * self.CANDIDATE_FACTOR, need)]
        ranked = window[np.lexsort((window, self.hits[window]))]
        return ranked[:need]


class CachedFeatureStore(FeatureStore):
    """Cross-batch hot-feature cache over any backend.

    Power-law graphs resample the same hub nodes in nearly every batch; in
    a store-backed pipeline those rows are refetched from remote partitions
    again and again. This wrapper keeps a bounded ``HotRowCache`` per
    (group, attr) key and serves cache hits locally, fetching only the
    missing rows from ``inner`` — cutting remote-row traffic without
    touching loader or step code. ``stats`` counts hits/misses/evictions;
    ``hit_rate()`` is the headline number ``benchmarks/store_scaling.py``
    reports. Lookup/insert run under a lock; the miss fetch does NOT, so
    concurrent pipeline gathers still overlap (two threads missing the same
    row both fetch it and the second insert refreshes — consistent, just
    briefly duplicated traffic).

    Unlike ``resilience._RowCache`` (last-known-good, consulted only when a
    partition is down) this cache serves on every hit: it changes traffic,
    never failure semantics — a fault in ``inner`` still propagates for the
    uncached rows.
    """

    def __init__(self, inner: FeatureStore, *, capacity: int = 4096,
                 seed: int = 0):
        self.inner = inner
        self.capacity = int(capacity)
        self._seed = seed
        self._caches: Dict[Key, HotRowCache] = {}
        self.stats = {"requests": 0, "hits": 0, "misses": 0, "evictions": 0}
        self._lock = threading.Lock()

    def hit_rate(self) -> float:
        total = self.stats["hits"] + self.stats["misses"]
        return self.stats["hits"] / total if total else 0.0

    def _cache_for(self, key: Key) -> HotRowCache:
        with self._lock:
            if key not in self._caches:
                n = int(self.inner._size(key)[0])
                self._caches[key] = HotRowCache(n, self.capacity,
                                                seed=self._seed)
            return self._caches[key]

    def _put(self, key, tensor):
        self.inner._put(key, tensor)
        with self._lock:  # stale rows must not outlive the backing tensor
            self._caches.pop(key, None)

    def _get(self, key, index):
        if index is None:  # full-tensor reads bypass the row cache
            return self.inner._get(key, None)
        index = np.asarray(index, np.int64)
        cache = self._cache_for(key)
        with self._lock:
            self.stats["requests"] += 1
            vals, have = cache.lookup(index)
            self.stats["hits"] += int(have.sum())
            self.stats["misses"] += int(len(index) - have.sum())
        if have.all():
            return vals
        fetched = np.asarray(self.inner._get(key, index[~have]))
        out = np.zeros((len(index),) + fetched.shape[1:], fetched.dtype)
        out[~have] = fetched
        if vals is not None:
            out[have] = vals
        with self._lock:
            cache.insert(index[~have], fetched)
            self.stats["evictions"] = sum(c.evictions
                                          for c in self._caches.values())
        return out

    def _size(self, key):
        return self.inner._size(key)


# --------------------------------------------------------------------------
# Out-of-core (memory-mapped) feature store
# --------------------------------------------------------------------------

class MmapFeatureStore(FeatureStore):
    """Disk-backed features through ``np.memmap`` under a host-memory budget.

    Tensors live in ``.npy`` files on disk (``np.lib.format.open_memmap``);
    a gather copies only the requested rows into host memory, so a feature
    matrix many times the configured ``memory_budget_bytes`` streams through
    the unchanged loader -> jit'd train step — the paper's out-of-core
    claim, proven end-to-end by the ``store/out_of_core`` benchmark cell.

    The budget gates *materialisation*, not storage: any single fetch whose
    result would exceed ``memory_budget_bytes`` (including ``index=None``
    full reads of an over-budget tensor) raises ``MemoryBudgetError``
    instead of silently paging the host into the ground. ``put_tensor``
    spills an in-memory array to disk; for matrices that never fit in
    memory at all, ``create_tensor`` returns the writable memmap to be
    filled in chunks.
    """

    def __init__(self, root: Optional[str] = None, *,
                 memory_budget_bytes: int = 1 << 30):
        self.root = root or tempfile.mkdtemp(prefix="repro-mmap-")
        os.makedirs(self.root, exist_ok=True)
        self.memory_budget_bytes = int(memory_budget_bytes)
        self._maps: Dict[Key, np.memmap] = {}
        self.stats = {"requests": 0, "rows_read": 0, "bytes_read": 0}
        self._lock = threading.Lock()

    def _path(self, key: Key) -> str:
        group, attr = key
        return os.path.join(self.root, f"{group}__{attr}.npy")

    def create_tensor(self, shape: Sequence[int], dtype, *,
                      group: str = "node", attr: str = "x") -> np.memmap:
        """Allocate an on-disk tensor and return the writable memmap.

        The caller fills it in chunks (never holding the full matrix in
        host memory); the store serves gathers from the same file.
        """
        mm = np.lib.format.open_memmap(
            self._path((group, attr)), mode="w+", dtype=np.dtype(dtype),
            shape=tuple(int(s) for s in shape))
        self._maps[(group, attr)] = mm
        return mm

    def _put(self, key, tensor):
        mm = self.create_tensor(tensor.shape, tensor.dtype,
                                group=key[0], attr=key[1])
        mm[...] = tensor
        mm.flush()

    def _row_nbytes(self, mm: np.memmap) -> int:
        return int(np.prod(mm.shape[1:], dtype=np.int64)) * mm.dtype.itemsize

    def _map_for(self, key: Key) -> np.memmap:
        """The key's memmap, reattaching to an existing file on disk (a
        fresh store over a previously-written ``root``)."""
        if key not in self._maps:
            path = self._path(key)
            if not os.path.exists(path):
                raise KeyError(key)
            self._maps[key] = np.lib.format.open_memmap(path, mode="r+")
        return self._maps[key]

    def _get(self, key, index):
        mm = self._map_for(key)
        if index is None:
            need = mm.nbytes
            if need > self.memory_budget_bytes:
                raise MemoryBudgetError(
                    f"full read of {key} would materialise {need} bytes "
                    f"(> budget {self.memory_budget_bytes}); gather rows "
                    f"instead")
            with self._lock:
                self.stats["requests"] += 1
                self.stats["rows_read"] += int(mm.shape[0])
                self.stats["bytes_read"] += int(need)
            return np.array(mm)
        index = np.asarray(index, np.int64)
        need = len(index) * self._row_nbytes(mm)
        if need > self.memory_budget_bytes:
            raise MemoryBudgetError(
                f"gather of {len(index)} rows of {key} would materialise "
                f"{need} bytes (> budget {self.memory_budget_bytes})")
        out = np.asarray(mm[index])  # copies only the touched pages
        with self._lock:
            self.stats["requests"] += 1
            self.stats["rows_read"] += int(len(index))
            self.stats["bytes_read"] += int(need)
        return out

    def _size(self, key):
        return tuple(self._map_for(key).shape)
