"""Graph partitioning for distributed feature/graph stores (paper C10).

Two partitioners:
  * 'hash'  — block-cyclic (the WholeGraph default layout),
  * 'bfs'   — locality-aware BFS growing (METIS-lite): grows parts from
    random roots along edges, which concentrates neighborhoods within a
    partition and cuts remote feature fetches for neighbor sampling.

``build_partitioned_stores`` wires a PartitionedFeatureStore so the
NeighborLoader runs *unchanged* on top of partitioned storage — the paper's
separation-of-concerns claim, measured by ``benchmarks/store_scaling.py``.
"""

from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.data.feature_store import PartitionedFeatureStore
from repro.data.graph_store import InMemoryGraphStore


def partition_graph(num_nodes: int, edge_index: np.ndarray, num_parts: int,
                    method: str = "bfs", seed: int = 0) -> np.ndarray:
    """node -> partition id."""
    if method == "hash":
        return np.arange(num_nodes) % num_parts
    rng = np.random.default_rng(seed)
    src, dst = np.asarray(edge_index[0]), np.asarray(edge_index[1])
    # undirected adjacency for region growing
    s2 = np.concatenate([src, dst])
    d2 = np.concatenate([dst, src])
    order = np.argsort(s2, kind="stable")
    src_s, dst_s = s2[order], d2[order]
    indptr = np.searchsorted(src_s, np.arange(num_nodes + 1))
    part = np.full(num_nodes, -1, np.int64)
    target = -(-num_nodes // num_parts)
    perm = rng.permutation(num_nodes)
    root_iter = iter(perm)
    from collections import deque
    for p in range(num_parts):
        # grow one contiguous BFS region until it reaches the target size
        count = 0
        queue: deque = deque()
        while count < target:
            if not queue:
                root = next((r for r in root_iter if part[r] < 0), None)
                if root is None:
                    break
                queue.append(int(root))
            v = queue.popleft()
            if part[v] >= 0:
                continue
            part[v] = p
            count += 1
            for u in dst_s[indptr[v]:indptr[v + 1]]:
                if part[u] < 0:
                    queue.append(int(u))
    part[part < 0] = num_parts - 1
    return part


def build_partitioned_stores(
        x: np.ndarray, edge_index: np.ndarray, num_parts: int,
        method: str = "bfs", local_rank: int = 0,
        y: Optional[np.ndarray] = None,
        time: Optional[np.ndarray] = None
) -> Tuple[PartitionedFeatureStore, InMemoryGraphStore, np.ndarray]:
    """Partitioned feature store + (shared) graph store + part table."""
    n = len(x)
    part = partition_graph(n, edge_index, num_parts, method=method)
    fs = PartitionedFeatureStore(num_parts, local_rank=local_rank)
    fs.put_partitioned(("node", "x"), np.asarray(x), part)
    if y is not None:
        fs.put_partitioned(("node", "y"), np.asarray(y), part)
    gs = InMemoryGraphStore()
    gs.put_edge_index(edge_index, num_nodes=n, time=time)
    return fs, gs, part
