"""Graph partitioning for distributed feature/graph stores (paper C10).

Two partitioners:
  * 'hash'  — block-cyclic (the WholeGraph default layout),
  * 'bfs'   — locality-aware BFS growing (METIS-lite): grows parts from
    random roots along edges, which concentrates neighborhoods within a
    partition and cuts remote feature fetches for neighbor sampling.

The BFS grower is fully vectorised: each region expands a whole frontier at
a time with numpy gathers (degree-repeat + first-occurrence dedup), and root
selection advances a single pointer over the seeded permutation — no
per-node Python queue, no root rescans. The assignment is bit-identical to
the original FIFO/deque formulation for a given seed (the frontier order
*is* the queue's first-occurrence pop order), which the parity test in
``tests/test_store_pipeline.py`` pins down.

``build_partitioned_stores`` wires a PartitionedFeatureStore so the
NeighborLoader runs *unchanged* on top of partitioned storage — the paper's
separation-of-concerns claim, measured by ``benchmarks/store_scaling.py``.
"""

from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.data.feature_store import PartitionedFeatureStore
from repro.data.graph_store import InMemoryGraphStore


def _undirected_csr(num_nodes: int, edge_index: np.ndarray
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """Symmetrised adjacency as (indptr, neighbors) for region growing."""
    src, dst = np.asarray(edge_index[0]), np.asarray(edge_index[1])
    s2 = np.concatenate([src, dst])
    d2 = np.concatenate([dst, src])
    order = np.argsort(s2, kind="stable")
    src_s, dst_s = s2[order], d2[order]
    indptr = np.searchsorted(src_s, np.arange(num_nodes + 1))
    return indptr, dst_s


def _frontier_neighbors(indptr: np.ndarray, nbrs: np.ndarray,
                        frontier: np.ndarray) -> np.ndarray:
    """All neighbors of ``frontier`` concatenated in adjacency order.

    Vectorised ragged gather: each frontier node contributes its CSR
    segment, in frontier order — exactly the order a FIFO queue would pop
    them in.
    """
    deg = indptr[frontier + 1] - indptr[frontier]
    total = int(deg.sum())
    if total == 0:
        return np.empty(0, np.int64)
    starts = np.repeat(indptr[frontier], deg)
    prefix = np.repeat(np.cumsum(deg) - deg, deg)
    return nbrs[starts + np.arange(total) - prefix]


def partition_graph(num_nodes: int, edge_index: np.ndarray, num_parts: int,
                    method: str = "bfs", seed: int = 0) -> np.ndarray:
    """node -> partition id."""
    if method == "hash":
        return np.arange(num_nodes) % num_parts
    if method != "bfs":
        raise ValueError(f"unknown partition method {method!r}")
    rng = np.random.default_rng(seed)
    indptr, nbrs = _undirected_csr(num_nodes, edge_index)
    part = np.full(num_nodes, -1, np.int64)
    target = -(-num_nodes // num_parts)
    perm = rng.permutation(num_nodes)
    ptr = 0  # next unconsumed root candidate in the seeded permutation
    for p in range(num_parts):
        count = 0
        frontier = np.empty(0, np.int64)
        while count < target:
            if frontier.size == 0:
                while ptr < num_nodes and part[perm[ptr]] >= 0:
                    ptr += 1
                if ptr == num_nodes:
                    break
                frontier = perm[ptr:ptr + 1]
                ptr += 1
            # assign up to the region's remaining capacity in frontier
            # (= FIFO pop) order; a mid-frontier cutoff drops the tail,
            # matching the queue being discarded at target size
            take = min(target - count, len(frontier))
            part[frontier[:take]] = p
            count += take
            if count >= target:
                break
            grown = _frontier_neighbors(indptr, nbrs, frontier)
            grown = grown[part[grown] < 0]
            # first-occurrence dedup keeps FIFO discovery order
            _, first = np.unique(grown, return_index=True)
            frontier = grown[np.sort(first)]
    part[part < 0] = num_parts - 1
    return part


def build_partitioned_stores(
        x: np.ndarray, edge_index: np.ndarray, num_parts: int,
        method: str = "bfs", local_rank: int = 0,
        y: Optional[np.ndarray] = None,
        time: Optional[np.ndarray] = None
) -> Tuple[PartitionedFeatureStore, InMemoryGraphStore, np.ndarray]:
    """Partitioned feature store + (shared) graph store + part table."""
    n = len(x)
    part = partition_graph(n, edge_index, num_parts, method=method)
    fs = PartitionedFeatureStore(num_parts, local_rank=local_rank)
    fs.put_partitioned(("node", "x"), np.asarray(x), part)
    if y is not None:
        fs.put_partitioned(("node", "y"), np.asarray(y), part)
    gs = InMemoryGraphStore()
    gs.put_edge_index(edge_index, num_nodes=n, time=time)
    return fs, gs, part
