"""GraphStore — the paper's graph-backend interface (C6, §2.3).

Stores edge indices per (src_type, rel_type, dst_type) in COO/CSR/CSC
layouts with demand-filled conversions (the storage-level counterpart of the
EdgeIndex caches). Samplers consume CSR (+ per-row time sorting for temporal
sampling); users with custom graph backends "specify how sampling is
performed against their graph representation" by implementing ``_get``.
"""

from __future__ import annotations

import abc
from typing import Dict, Optional, Tuple

import numpy as np

EdgeType = Tuple[str, str, str]
DEFAULT_ETYPE: EdgeType = ("node", "to", "node")


class CSRGraph:
    """Host-side CSR adjacency (+ optional per-edge time, sorted per row)."""

    def __init__(self, indptr: np.ndarray, indices: np.ndarray,
                 edge_id: np.ndarray, time: Optional[np.ndarray] = None):
        self.indptr = indptr
        self.indices = indices
        self.edge_id = edge_id
        self.time = time

    @property
    def num_rows(self) -> int:
        return len(self.indptr) - 1

    @property
    def num_edges(self) -> int:
        return len(self.indices)

    @classmethod
    def from_coo(cls, src: np.ndarray, dst: np.ndarray, num_nodes: int,
                 time: Optional[np.ndarray] = None) -> "CSRGraph":
        """CSR over *source* rows: row v lists v's outgoing neighbors.

        For temporal graphs, each row's neighbors are sub-sorted by edge
        time so a binary search bounds the ``<= t`` prefix (paper C9).
        """
        order = np.lexsort((time, src)) if time is not None else np.argsort(
            src, kind="stable")
        src_s, dst_s = src[order], dst[order]
        indptr = np.searchsorted(src_s, np.arange(num_nodes + 1)).astype(
            np.int64)
        t = time[order] if time is not None else None
        return cls(indptr, dst_s.astype(np.int64), order.astype(np.int64), t)


class GraphStore(abc.ABC):
    """Demand-filled CSR/CSC caches at the storage layer (paper C1 at rest).

    ``get_csr``   — rows = source nodes (outgoing adjacency)
    ``get_rev_csr`` — rows = destination nodes (incoming adjacency; what a
    source_to_target neighbor sampler walks backwards over).
    """

    @abc.abstractmethod
    def _put(self, etype: EdgeType, coo: tuple) -> None: ...

    @abc.abstractmethod
    def _get(self, etype: EdgeType) -> tuple: ...

    @abc.abstractmethod
    def _cache(self, etype: EdgeType, key: str) -> Optional[CSRGraph]: ...

    @abc.abstractmethod
    def _set_cache(self, etype: EdgeType, key: str, csr: CSRGraph): ...

    def put_edge_index(self, edge_index, *, edge_type: EdgeType = DEFAULT_ETYPE,
                       num_nodes: Optional[int] = None,
                       time: Optional[np.ndarray] = None):
        src, dst = np.asarray(edge_index[0]), np.asarray(edge_index[1])
        if num_nodes is None:
            # infer from the edges; an explicit num_nodes=0 (empty graph)
            # must NOT fall through to src.max() on empty arrays
            num_nodes = int(max(src.max(), dst.max())) + 1 if len(src) else 0
        n = num_nodes
        self._put(edge_type,
                  (src, dst, None if time is None else np.asarray(time), n))
        return self

    def get_csr(self, edge_type: EdgeType = DEFAULT_ETYPE) -> CSRGraph:
        hit = self._cache(edge_type, "csr")
        if hit is None:
            src, dst, time, n = self._get(edge_type)
            hit = CSRGraph.from_coo(src, dst, n, time)
            self._set_cache(edge_type, "csr", hit)
        return hit

    def get_rev_csr(self, edge_type: EdgeType = DEFAULT_ETYPE) -> CSRGraph:
        hit = self._cache(edge_type, "rev_csr")
        if hit is None:
            src, dst, time, n = self._get(edge_type)
            hit = CSRGraph.from_coo(dst, src, n, time)
            self._set_cache(edge_type, "rev_csr", hit)
        return hit

    def edge_types(self):
        raise NotImplementedError


class InMemoryGraphStore(GraphStore):
    def __init__(self):
        self._coo: Dict[EdgeType, tuple] = {}
        self._caches: Dict[Tuple[EdgeType, str], CSRGraph] = {}

    def _put(self, etype, coo):
        self._coo[etype] = coo
        self._caches = {k: v for k, v in self._caches.items()
                        if k[0] != etype}

    def _get(self, etype):
        return self._coo[etype]

    def _cache(self, etype, key):
        return self._caches.get((etype, key))

    def _set_cache(self, etype, key, csr):
        self._caches[(etype, key)] = csr

    def edge_types(self):
        return list(self._coo)
