"""Data / HeteroData containers implementing BOTH store interfaces.

Mirrors the paper's key unification: "both Data and HeteroData classes in
PyG inherit from the FeatureStore and GraphStore interfaces, providing a
unified mechanism for retrieving mini-batches from any type of data storage".
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

from repro.data.feature_store import FeatureStore, InMemoryFeatureStore, Key
from repro.data.graph_store import (CSRGraph, DEFAULT_ETYPE, EdgeType,
                                    GraphStore, InMemoryGraphStore)


class Data(FeatureStore, GraphStore):
    """Homogeneous in-memory graph = feature store + graph store in one."""

    def __init__(self, x: Optional[np.ndarray] = None,
                 edge_index: Optional[np.ndarray] = None,
                 y: Optional[np.ndarray] = None,
                 num_nodes: Optional[int] = None,
                 time: Optional[np.ndarray] = None,
                 edge_attr: Optional[np.ndarray] = None):
        self._fs = InMemoryFeatureStore()
        self._gs = InMemoryGraphStore()
        if x is not None:
            self.put_tensor(x, group="node", attr="x")
            num_nodes = num_nodes or len(x)
        if y is not None:
            self.put_tensor(y, group="node", attr="y")
        if edge_attr is not None:
            self.put_tensor(edge_attr, group="edge", attr="edge_attr")
        if edge_index is not None:
            self.put_edge_index(edge_index, num_nodes=num_nodes, time=time)
        self.num_nodes = num_nodes or 0

    # FeatureStore plumbing
    def _put(self, key, value):
        if isinstance(key, tuple) and len(key) == 2 and isinstance(
                key[0], str):
            return self._fs._put(key, value)
        return self._gs._put(key, value)

    def _get(self, key, index=None):
        if isinstance(key, tuple) and len(key) == 2 and isinstance(
                key[0], str):
            return self._fs._get(key, index)
        return self._gs._get(key)

    def _size(self, key):
        return self._fs._size(key)

    # GraphStore plumbing
    def _cache(self, etype, key):
        return self._gs._cache(etype, key)

    def _set_cache(self, etype, key, csr):
        return self._gs._set_cache(etype, key, csr)

    def edge_types(self):
        return self._gs.edge_types()

    @property
    def x(self):
        return self.get_tensor(group="node", attr="x")

    @property
    def y(self):
        return self.get_tensor(group="node", attr="y")


class HeteroData(FeatureStore, GraphStore):
    """Typed graph (V, E, phi, psi): per-type features + per-type edges."""

    def __init__(self):
        self._fs = InMemoryFeatureStore()
        self._gs = InMemoryGraphStore()
        self.num_nodes_dict: Dict[str, int] = {}

    def add_nodes(self, node_type: str, x: np.ndarray,
                  time: Optional[np.ndarray] = None, **extra):
        self.put_tensor(x, group=node_type, attr="x")
        if time is not None:
            self.put_tensor(time, group=node_type, attr="time")
        for k, v in extra.items():
            self.put_tensor(v, group=node_type, attr=k)
        self.num_nodes_dict[node_type] = len(x)
        return self

    def add_edges(self, edge_type: EdgeType, edge_index,
                  time: Optional[np.ndarray] = None):
        n = max(self.num_nodes_dict.get(edge_type[0], 0),
                self.num_nodes_dict.get(edge_type[2], 0),
                int(np.asarray(edge_index).max()) + 1 if np.asarray(
                    edge_index).size else 0)
        self.put_edge_index(edge_index, edge_type=edge_type, num_nodes=n,
                            time=time)
        return self

    def _put(self, key, value):
        if isinstance(key, tuple) and len(key) == 2:
            return self._fs._put(key, value)
        return self._gs._put(key, value)

    def _get(self, key, index=None):
        if isinstance(key, tuple) and len(key) == 2:
            return self._fs._get(key, index)
        return self._gs._get(key)

    def _size(self, key):
        return self._fs._size(key)

    def _cache(self, etype, key):
        return self._gs._cache(etype, key)

    def _set_cache(self, etype, key, csr):
        return self._gs._set_cache(etype, key, csr)

    def edge_types(self):
        return self._gs.edge_types()

    def node_types(self):
        return list(self.num_nodes_dict)

    def metadata(self) -> Tuple[list, list]:
        return (self.node_types(), self.edge_types())
