"""Budgeted multi-hop neighbor sampling — paper C7 + C9 (§2.3).

PyG's sampler is multi-threaded C++; the TPU adaptation (DESIGN.md §2) is a
*vectorised* NumPy sampler that emits **static padded shapes**: every hop has
a fixed node/edge budget, so the jit'd training step never recompiles and
layer-wise trimming (C8) becomes static slicing. Matches PyG semantics:

  * single multi-hop subgraph (not layer-wise 1-hop graphs),
  * intersecting (deduplicated) or disjoint per-seed subgraphs,
  * directional sampling over the CSR rows,
  * temporal constraints: only edges with ``time <= seed_time`` are
    sampled, with 'uniform' / 'recent' / 'anneal' strategies (C9).

Output layout (local slot space):
  slot 0              = null sink (zero features; padded edges self-loop here)
  slots 1..B          = seeds
  then one block per hop, each padded to its budget with -1 global ids.
Edges are grouped by the hop that discovered them (BFS order), padded with
(0, 0) — i.e. null->null. ``num_sampled_nodes/edges`` feed ``trim_to_layer``.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.data.graph_store import CSRGraph, DEFAULT_ETYPE, GraphStore


@dataclasses.dataclass
class SamplerOutput:
    node: np.ndarray                 # (N_slots,) global node ids, -1 = pad
    row: np.ndarray                  # (E_slots,) local src slots
    col: np.ndarray                  # (E_slots,) local dst slots
    edge: np.ndarray                 # (E_slots,) global edge ids, -1 = pad
    num_sampled_nodes: List[int]     # per hop (incl. [null+seeds] first)
    num_sampled_edges: List[int]     # per hop
    seed_slots: np.ndarray           # (B,) local slots of the seeds
    metadata: dict = dataclasses.field(default_factory=dict)


def _temporal_prefix(time: np.ndarray, lo: np.ndarray, hi: np.ndarray,
                     bound: np.ndarray) -> np.ndarray:
    """Vectorised per-row ``searchsorted(time[lo:hi], bound, side='right')``.

    Each row's edge segment ``[lo_i, hi_i)`` is time-sorted; this runs one
    *simultaneous* binary search across all rows (O(log max_deg) vectorised
    steps) instead of a per-row Python loop, so temporal sampling no longer
    scales with frontier size in Python. Returns the absolute end position of
    each row's ``time <= bound`` prefix.
    """
    lo = lo.astype(np.int64)
    hi = hi.astype(np.int64)
    if time.size == 0:
        return lo
    cap = time.size - 1
    while True:
        active = lo < hi
        if not active.any():
            return lo
        mid = (lo + hi) >> 1
        go_right = time[np.minimum(mid, cap)] <= bound
        lo = np.where(active & go_right, mid + 1, lo)
        hi = np.where(active & ~go_right, mid, hi)


def _pick_neighbors(csr: CSRGraph, frontier: np.ndarray, fanout: int,
                    rng: np.random.Generator,
                    seed_time: Optional[np.ndarray] = None,
                    strategy: str = "uniform"):
    """Vectorised neighbor choice (with replacement) for a frontier.

    Returns (src_global, eid_global, parent_idx) arrays of len F*fanout with
    -1 where the parent has no (valid) neighbors. ``seed_time`` is the
    per-frontier-node time bound for temporal sampling.
    """
    f = len(frontier)
    valid_parent = frontier >= 0
    safe = np.where(valid_parent, frontier, 0)
    lo = csr.indptr[safe]
    hi = csr.indptr[safe + 1]
    if seed_time is not None and csr.time is not None:
        # rows are time-sorted: one vectorised binary search over all
        # parents finds each <= t prefix (no per-frontier-node Python)
        hi = _temporal_prefix(csr.time, lo, hi, seed_time)
    deg = np.maximum(hi - lo, 0)
    u = rng.random((f, fanout))
    if strategy == "recent" and seed_time is not None:
        # most-recent k: take the last `fanout` of the allowed prefix
        pick = (deg[:, None] - 1 - np.arange(fanout)[None, :])
    elif strategy == "anneal" and seed_time is not None:
        # bias toward recent: sample rank ~ (1 - u^2) * deg (denser near end)
        pick = np.floor((1.0 - u * u) * deg[:, None]).astype(np.int64)
        pick = np.minimum(pick, deg[:, None] - 1)
    else:
        pick = np.floor(u * deg[:, None]).astype(np.int64)
    ok = (pick >= 0) & (deg[:, None] > 0) & valid_parent[:, None]
    pick = np.clip(pick, 0, None)
    eidx = lo[:, None] + np.minimum(pick, np.maximum(deg[:, None] - 1, 0))
    eidx = np.clip(eidx, 0, max(len(csr.indices) - 1, 0))  # empty tail rows
    src = np.where(ok, csr.indices[eidx], -1)
    eid = np.where(ok, csr.edge_id[eidx], -1)
    parent = np.broadcast_to(np.arange(f)[:, None], (f, fanout))
    return src.ravel(), eid.ravel(), parent.ravel()


def static_slot_bounds(batch_size: int,
                       num_neighbors: Sequence[int]) -> List[tuple]:
    """Static per-slot in-degree bounds of a sampled batch subgraph.

    The sampler's slot layout is fixed by its budgets: slot 0 is the null
    sink (receives only padding edges), slots ``[1, 1+B)`` are the seeds,
    then one block per hop of size ``B * prod(fanouts[:h])``. Edges produced
    while expanding hop ``h`` always point *into* the hop-``h`` frontier
    block, at most ``fanout[h]`` per frontier slot — so every slot's
    in-degree is bounded by the fanout of the hop that expands it, and the
    last hop's block (never expanded) receives none. These bounds hold for
    shared (deduplicated) and disjoint batches alike, which is what lets the
    loader pre-pack a *static-layout* blocked-ELL cache host-side.

    Returns ``[(start, stop, max_in_degree), ...]`` row ranges in slot
    space, covering exactly the slots that can receive edges.
    """
    fan = list(num_neighbors)
    blocks = [(1, 1 + batch_size)]  # seeds
    start, size = 1 + batch_size, batch_size
    for f in fan:
        size *= f
        blocks.append((start, start + size))
        start += size
    return [(lo, hi, fan[i]) for i, (lo, hi) in enumerate(blocks)
            if i < len(fan) and fan[i] > 0 and hi > lo]


class NeighborSampler:
    """k-hop budgeted sampler over a GraphStore (homogeneous)."""

    def __init__(self, graph_store: GraphStore,
                 num_neighbors: Sequence[int], *,
                 edge_type=DEFAULT_ETYPE, disjoint: bool = False,
                 temporal_strategy: str = "uniform", seed: int = 0):
        # source_to_target flow: walk the *incoming* adjacency backwards
        self.csr = graph_store.get_rev_csr(edge_type)
        self.num_neighbors = list(num_neighbors)
        self.disjoint = disjoint
        self.temporal_strategy = temporal_strategy
        self.rng = np.random.default_rng(seed)

    def slot_degree_bounds(self, batch_size: int) -> List[tuple]:
        """Static in-degree bounds per slot range (see static_slot_bounds)."""
        return static_slot_bounds(batch_size, self.num_neighbors)

    def sample(self, seeds: np.ndarray,
               seed_time: Optional[np.ndarray] = None) -> SamplerOutput:
        seeds = np.asarray(seeds, np.int64)
        if self.disjoint:
            return self._sample_disjoint(seeds, seed_time)
        return self._sample_shared(seeds, seed_time)

    # -- intersecting subgraphs: global dedup across the batch ---------------
    def _sample_shared(self, seeds, seed_time):
        """Fully vectorised hop expansion (no per-edge Python).

        Dedup uses a persistent global->slot lookup array (reset via the
        touched list after each call) — the vectorised replacement for the
        paper's C++ hash map.

        Seeds may contain -1 pads (the loader's shard tail padding): a -1
        seed keeps its slot in ``[1, 1+B)`` so the batch layout stays
        static, but never enters the slot lookup and never expands — a
        plain ``slot_of[seeds] = ...`` would alias ``slot_of[-1]`` onto the
        last global node and corrupt dedup.
        """
        b = len(seeds)
        n_glob = self.csr.num_rows
        if not hasattr(self, "_slot_of") or len(self._slot_of) != n_glob:
            self._slot_of = np.full(n_glob, -1, np.int64)
        slot_of = self._slot_of
        valid_seed = seeds >= 0
        vseeds = seeds[valid_seed]
        touched = [vseeds]
        slot_of[vseeds] = np.arange(1, b + 1)[valid_seed]
        nodes = [np.array([-1], np.int64), seeds]  # null sink + seeds
        num_nodes = [1 + b]
        rows, cols, eids, num_edges = [], [], [], []
        frontier = seeds
        frontier_slots = np.arange(1, b + 1)
        frontier_time = seed_time
        for fanout in self.num_neighbors:
            budget = len(frontier) * fanout
            src, eid, parent = _pick_neighbors(
                self.csr, frontier, fanout, self.rng,
                seed_time=frontier_time, strategy=self.temporal_strategy)
            valid = src >= 0
            vsrc = src[valid]
            base = sum(num_nodes)
            # vectorised dedup: first occurrence of each unseen global id,
            # slotted in BFS discovery order
            unseen = slot_of[vsrc] < 0
            uniq, first = np.unique(vsrc[unseen], return_index=True)
            disc = uniq[np.argsort(first, kind="stable")]
            slot_of[disc] = base + np.arange(len(disc))
            touched.append(disc)
            hop_nodes = np.full(budget, -1, np.int64)
            hop_nodes[:len(disc)] = disc
            # edge assembly: valid edges compacted to the front
            w = int(valid.sum())
            row = np.zeros(budget, np.int64)
            col = np.zeros(budget, np.int64)
            evalid = np.full(budget, -1, np.int64)
            row[:w] = slot_of[vsrc]
            col[:w] = frontier_slots[parent[valid]]
            evalid[:w] = eid[valid]
            nodes.append(hop_nodes)
            num_nodes.append(budget)
            rows.append(row)
            cols.append(col)
            eids.append(evalid)
            num_edges.append(budget)
            frontier = hop_nodes
            frontier_slots = np.where(hop_nodes >= 0, slot_of[
                np.maximum(hop_nodes, 0)], 0)
            if frontier_time is not None:
                ft = np.zeros(budget, dtype=seed_time.dtype)
                pt = frontier_time[parent[valid]]
                # time bound of a discovered node = its discovering parent's
                nd = len(disc)
                first_slot = slot_of[vsrc] - base
                keep = (first_slot >= 0) & (first_slot < nd)
                ft_new = np.zeros(nd, dtype=seed_time.dtype)
                ft_new[first_slot[keep]] = pt[keep]
                ft[:nd] = ft_new
                frontier_time = ft
        out = SamplerOutput(
            node=np.concatenate(nodes),
            row=np.concatenate(rows) if rows else np.zeros(0, np.int64),
            col=np.concatenate(cols) if cols else np.zeros(0, np.int64),
            edge=np.concatenate(eids) if eids else np.zeros(0, np.int64),
            num_sampled_nodes=num_nodes, num_sampled_edges=num_edges,
            seed_slots=np.arange(1, b + 1))
        for t in touched:  # reset the lookup for the next call
            slot_of[t] = -1
        return out

    # -- disjoint per-seed subgraphs (temporal mini-batches, paper C9) -------
    def _sample_disjoint(self, seeds, seed_time):
        outs = [self._sample_shared(
            seeds[i:i + 1],
            None if seed_time is None else seed_time[i:i + 1])
            for i in range(len(seeds))]
        return merge_disjoint(outs)


def merge_disjoint(outs: List[SamplerOutput]) -> SamplerOutput:
    """Concatenate per-seed subgraphs into one disjoint batch graph.

    Keeps a single shared null sink at slot 0; per-sample slots are offset.
    """
    seed_slots: List[int] = []
    n_hops = len(outs[0].num_sampled_nodes) - 1
    num_nodes = [1 + sum(o.num_sampled_nodes[0] - 1 for o in outs)]
    num_edges = [0] * n_hops
    # interleave per hop to preserve BFS ordering across the batch
    per_hop_nodes = [[] for _ in range(n_hops + 1)]
    per_hop_edges = [[] for _ in range(n_hops)]
    slot_maps = []
    for o in outs:
        m = np.zeros(len(o.node), np.int64)
        slot_maps.append(m)
    # assign new slots hop-block by hop-block
    cursor = num_nodes[0]
    starts = [np.cumsum([0] + o.num_sampled_nodes) for o in outs]
    for h in range(n_hops + 1):
        for oi, o in enumerate(outs):
            lo, hi = starts[oi][h], starts[oi][h + 1]
            blk = o.node[lo:hi]
            if h == 0:
                blk = blk[1:]  # drop per-sample null; slots 1..B map later
                idx = np.arange(lo + 1, hi)
            else:
                idx = np.arange(lo, hi)
            if h == 0:
                new = np.arange(len(seed_slots) + 1,
                                len(seed_slots) + 1 + len(blk))
                seed_slots.extend(new)
            else:
                new = np.arange(cursor, cursor + len(blk))
                cursor += len(blk)
            slot_maps[oi][idx] = new
            per_hop_nodes[h].append(blk)
        if h > 0:
            num_nodes.append(sum(len(b) for b in per_hop_nodes[h][-len(outs):]))
    estarts = [np.cumsum([0] + o.num_sampled_edges) for o in outs]
    for h in range(n_hops):
        for oi, o in enumerate(outs):
            lo, hi = estarts[oi][h], estarts[oi][h + 1]
            r, c, e = o.row[lo:hi], o.col[lo:hi], o.edge[lo:hi]
            pad = e < 0
            rr = np.where(pad, 0, slot_maps[oi][r])
            cc = np.where(pad, 0, slot_maps[oi][c])
            per_hop_edges[h].append((rr, cc, e))
        num_edges[h] = sum(len(t[0]) for t in per_hop_edges[h][-len(outs):])
    node = np.concatenate([np.array([-1], np.int64)]
                          + [b for h in per_hop_nodes for b in h])
    row = np.concatenate([t[0] for h in per_hop_edges for t in h])
    col = np.concatenate([t[1] for h in per_hop_edges for t in h])
    eid = np.concatenate([t[2] for h in per_hop_edges for t in h])
    return SamplerOutput(node=node, row=row, col=col, edge=eid,
                         num_sampled_nodes=num_nodes,
                         num_sampled_edges=num_edges,
                         seed_slots=np.asarray(seed_slots, np.int64),
                         metadata={"disjoint": True})
