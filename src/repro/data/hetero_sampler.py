"""Heterogeneous budgeted neighbor sampling — paper C7's hetero pipeline.

PyG's C++ sampler multi-threads *across edge types* per hop; the vectorised
analogue processes every edge type's frontier expansion as one NumPy pass
per (hop, edge type). Budgets are static per (hop, edge type), so batches
are shape-stable per node/edge type — the hetero mini-batch feeds a jit'd
HeteroGNN without recompiles.

Output layout per node type mirrors the homogeneous sampler: slot 0 is a
typed null sink, then seed slots (for seed types), then one block per
(hop, contributing edge type). Temporal constraints apply per edge type when
that type's store carries timestamps; types without timestamps sample
unconstrained — exactly the paper's "node and edge types lacking timestamps
... sampling is performed without applying temporal constraints".
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.data.graph_store import EdgeType, GraphStore
from repro.data.sampler import _pick_neighbors


@dataclasses.dataclass
class HeteroSamplerOutput:
    node: Dict[str, np.ndarray]                  # per node type, -1 = pad
    row: Dict[EdgeType, np.ndarray]              # local src slots (src type)
    col: Dict[EdgeType, np.ndarray]              # local dst slots (dst type)
    edge: Dict[EdgeType, np.ndarray]             # global edge ids, -1 = pad
    num_sampled_nodes: Dict[str, List[int]]
    num_sampled_edges: Dict[EdgeType, List[int]]
    seed_slots: np.ndarray
    seed_type: str


class HeteroNeighborSampler:
    """k-hop sampling over typed graphs with per-edge-type fanouts."""

    def __init__(self, graph_store: GraphStore,
                 num_neighbors: Dict[EdgeType, Sequence[int]], *,
                 temporal_strategy: str = "uniform", seed: int = 0):
        self.graph_store = graph_store
        self.edge_types = list(num_neighbors.keys())
        self.num_neighbors = {et: list(f) for et, f in num_neighbors.items()}
        depths = {len(f) for f in self.num_neighbors.values()}
        assert len(depths) == 1, "all edge types need equal depth"
        self.depth = depths.pop()
        self.temporal_strategy = temporal_strategy
        self.rng = np.random.default_rng(seed)
        # incoming adjacency per edge type: sample edges pointing INTO the
        # frontier of the edge type's dst type
        self.rev = {et: graph_store.get_rev_csr(et) for et in self.edge_types}

    def sample(self, seed_type: str, seeds: np.ndarray,
               seed_time: Optional[np.ndarray] = None) -> HeteroSamplerOutput:
        seeds = np.asarray(seeds, np.int64)
        b = len(seeds)
        node_types = {t for et in self.edge_types for t in (et[0], et[2])}
        node_types.add(seed_type)

        nodes: Dict[str, List[np.ndarray]] = {
            t: [np.array([-1], np.int64)] for t in node_types}
        slot_of: Dict[str, Dict[int, int]] = {t: {} for t in node_types}
        num_nodes: Dict[str, List[int]] = {t: [1] for t in node_types}
        rows: Dict[EdgeType, List[np.ndarray]] = {et: [] for et in
                                                  self.edge_types}
        cols: Dict[EdgeType, List[np.ndarray]] = {et: [] for et in
                                                  self.edge_types}
        eids: Dict[EdgeType, List[np.ndarray]] = {et: [] for et in
                                                  self.edge_types}
        num_edges: Dict[EdgeType, List[int]] = {et: [] for et in
                                                self.edge_types}

        for i, g in enumerate(seeds):
            slot_of[seed_type][int(g)] = 1 + i
        nodes[seed_type].append(seeds)
        num_nodes[seed_type][0] += b

        frontier: Dict[str, np.ndarray] = {
            t: (seeds if t == seed_type else np.zeros(0, np.int64))
            for t in node_types}
        frontier_slots: Dict[str, np.ndarray] = {
            t: (np.arange(1, b + 1) if t == seed_type
                else np.zeros(0, np.int64)) for t in node_types}
        frontier_time = {t: (seed_time if t == seed_type else None)
                         for t in node_types}

        for hop in range(self.depth):
            new_nodes: Dict[str, List[int]] = {t: [] for t in node_types}
            new_times: Dict[str, List] = {t: [] for t in node_types}
            for et in self.edge_types:
                src_t, _, dst_t = et
                fanout = self.num_neighbors[et][hop]
                front = frontier[dst_t]
                budget = len(front) * fanout
                num_edges[et].append(budget)
                if budget == 0:
                    for coll in (rows, cols, eids):
                        coll[et].append(np.zeros(0, np.int64))
                    continue
                csr = self.rev[et]
                st = (frontier_time[dst_t]
                      if csr.time is not None else None)
                src, eid, parent = _pick_neighbors(
                    csr, front, fanout, self.rng, seed_time=st,
                    strategy=self.temporal_strategy)
                row = np.zeros(budget, np.int64)
                col = np.zeros(budget, np.int64)
                ev = np.full(budget, -1, np.int64)
                w = 0
                base = num_nodes[src_t]
                for j in range(budget):
                    g = int(src[j])
                    if g < 0:
                        continue
                    s = slot_of[src_t].get(g)
                    if s is None:
                        s = sum(num_nodes[src_t]) + len(new_nodes[src_t])
                        slot_of[src_t][g] = s
                        new_nodes[src_t].append(g)
                        if frontier_time[dst_t] is not None:
                            new_times[src_t].append(
                                frontier_time[dst_t][parent[j]])
                    row[w] = s
                    col[w] = frontier_slots[dst_t][parent[j]]
                    ev[w] = eid[j]
                    w += 1
                rows[et].append(row)
                cols[et].append(col)
                eids[et].append(ev)
            # close the hop: pad each node type's block to its budget
            for t in node_types:
                budget_t = sum(len(frontier[et2[2]]) * self.num_neighbors[
                    et2][hop] for et2 in self.edge_types if et2[0] == t)
                blk = np.full(budget_t, -1, np.int64)
                blk[:len(new_nodes[t])] = new_nodes[t]
                nodes[t].append(blk)
                num_nodes[t].append(budget_t)
            for t in node_types:
                blk = nodes[t][-1]
                frontier[t] = blk
                fs = np.zeros(len(blk), np.int64)
                valid = blk >= 0
                fs[valid] = [slot_of[t][int(g)] for g in blk[valid]]
                frontier_slots[t] = fs
                if any(new_times[t]):
                    ft = np.zeros(len(blk),
                                  dtype=np.asarray(new_times[t]).dtype)
                    ft[:len(new_times[t])] = new_times[t]
                    frontier_time[t] = ft

        return HeteroSamplerOutput(
            node={t: np.concatenate(v) for t, v in nodes.items()},
            row={et: np.concatenate(v) if v else np.zeros(0, np.int64)
                 for et, v in rows.items()},
            col={et: np.concatenate(v) if v else np.zeros(0, np.int64)
                 for et, v in cols.items()},
            edge={et: np.concatenate(v) if v else np.zeros(0, np.int64)
                  for et, v in eids.items()},
            num_sampled_nodes=num_nodes, num_sampled_edges=num_edges,
            seed_slots=np.arange(1, b + 1), seed_type=seed_type)


class HeteroNeighborLoader:
    """Typed mini-batches: sampler + per-type feature fetch (paper C6+C7)."""

    def __init__(self, feature_store, graph_store, *,
                 num_neighbors: Dict[EdgeType, Sequence[int]],
                 input_type: str, input_nodes: np.ndarray, batch_size: int,
                 input_time: Optional[np.ndarray] = None,
                 temporal_strategy: str = "uniform",
                 shuffle: bool = False, seed: int = 0):
        import jax.numpy as jnp
        self.jnp = jnp
        self.fs = feature_store
        self.sampler = HeteroNeighborSampler(
            graph_store, num_neighbors,
            temporal_strategy=temporal_strategy, seed=seed)
        self.input_type = input_type
        self.input_nodes = np.asarray(input_nodes)
        self.input_time = (None if input_time is None
                           else np.asarray(input_time))
        self.batch_size = batch_size
        self.shuffle = shuffle
        self.rng = np.random.default_rng(seed)

    def __iter__(self):
        jnp = self.jnp
        order = np.arange(len(self.input_nodes))
        if self.shuffle:
            self.rng.shuffle(order)
        bs = self.batch_size
        for i in range(0, len(order) - bs + 1, bs):
            idx = order[i:i + bs]
            out = self.sampler.sample(
                self.input_type, self.input_nodes[idx],
                None if self.input_time is None else self.input_time[idx])
            x_dict = {t: jnp.asarray(self.fs.get_padded(
                n, group=t, attr="x")) for t, n in out.node.items()}
            ei_dict = {et: jnp.asarray(
                np.stack([out.row[et], out.col[et]])).astype(jnp.int32)
                for et in out.row}
            yield out, x_dict, ei_dict
