"""Heterogeneous budgeted neighbor sampling — paper C7's hetero pipeline.

PyG's C++ sampler multi-threads *across edge types* per hop; the vectorised
analogue processes every edge type's frontier expansion as one NumPy pass
per (hop, edge type) — the same persistent global->slot lookup dedup as the
homogeneous sampler, no per-edge Python. Budgets are static per (hop, edge
type), so batches are shape-stable per node/edge type and every slot's
in-degree is statically bounded by the fanout of the (hop, edge type) that
expands it (``hetero_static_slot_bounds``) — which is what lets the loader
pre-pack a *static-layout* blocked-ELL cache per relation and feed a jit'd
HeteroGNN without recompiles.

Output layout per node type mirrors the homogeneous sampler: slot 0 is a
typed null sink, then seed slots (for seed types), then one block per
(hop, contributing edge type). Temporal constraints apply per edge type when
that type's store carries timestamps; types without timestamps sample
unconstrained — exactly the paper's "node and edge types lacking timestamps
... sampling is performed without applying temporal constraints".

``HeteroNeighborLoader`` rides the shared producer-thread/prefetch and
stage-pipeline machinery of ``repro.data.loader`` (sequential sample on
the coordinator, per-type feature gathers overlapped on the worker pool
with ``pipeline_depth`` batches in flight, pack at ordered reassembly;
``partition_order`` groups seed batches by the input type's home
partition) and emits registered-pytree
``HeteroBatch``es whose per-edge-type graphs carry host-built CSR/CSC (and,
when Pallas dispatch is on, static-layout bucketed ELL) caches — one jit
trace across batches, every relation's aggregation on the Pallas SpMM path.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.edge_index import EdgeIndex
from repro.data.graph_store import EdgeType, GraphStore
from repro.data.loader import _PrefetchLoader
from repro.data.sampler import _pick_neighbors
from repro.kernels import use_pallas
from repro.kernels.spmm.ops import ell_layout_from_bounds


@dataclasses.dataclass
class HeteroSamplerOutput:
    node: Dict[str, np.ndarray]                  # per node type, -1 = pad
    row: Dict[EdgeType, np.ndarray]              # local src slots (src type)
    col: Dict[EdgeType, np.ndarray]              # local dst slots (dst type)
    edge: Dict[EdgeType, np.ndarray]             # global edge ids, -1 = pad
    num_sampled_nodes: Dict[str, List[int]]
    num_sampled_edges: Dict[EdgeType, List[int]]
    seed_slots: np.ndarray
    seed_type: str


def hetero_static_slot_bounds(
        batch_size: int, num_neighbors: Dict[EdgeType, Sequence[int]],
        seed_type: str) -> Dict[EdgeType, List[Tuple[int, int, int]]]:
    """Static per-edge-type dst-slot in-degree bounds of a typed batch.

    The hetero slot layout is fixed by the budgets: per node type, slot 0 is
    the null sink, seeds occupy ``[1, 1+B)`` (seed type only), then one block
    per hop sized by the sum of that type's incoming expansion budgets. Hop
    ``h`` edges of type ``et`` always point *into* the dst type's current
    frontier block, at most ``fanout[et][h]`` per slot — the heterogeneous
    counterpart of ``repro.data.sampler.static_slot_bounds``.

    Returns, per edge type, ``[(start, stop, max_in_degree), ...]`` row
    ranges in the *destination type's* slot space (disjoint across hops),
    ready for ``ell_layout_from_bounds``.
    """
    edge_types = list(num_neighbors)
    node_types = sorted({t for et in edge_types for t in (et[0], et[2])}
                        | {seed_type})
    depth = len(next(iter(num_neighbors.values())))
    num_nodes = {t: [1 + (batch_size if t == seed_type else 0)]
                 for t in node_types}
    front = {t: ((1, 1 + batch_size) if t == seed_type else (1, 1))
             for t in node_types}
    bounds: Dict[EdgeType, List[Tuple[int, int, int]]] = {
        et: [] for et in edge_types}
    for hop in range(depth):
        budget = {t: 0 for t in node_types}
        for et in edge_types:
            fanout = num_neighbors[et][hop]
            lo, hi = front[et[2]]
            if fanout > 0 and hi > lo:
                bounds[et].append((lo, hi, fanout))
            budget[et[0]] += (hi - lo) * fanout
        for t in node_types:
            start = sum(num_nodes[t])
            num_nodes[t].append(budget[t])
            front[t] = (start, start + budget[t])
    return bounds


class HeteroNeighborSampler:
    """k-hop sampling over typed graphs with per-edge-type fanouts."""

    def __init__(self, graph_store: GraphStore,
                 num_neighbors: Dict[EdgeType, Sequence[int]], *,
                 temporal_strategy: str = "uniform", seed: int = 0):
        self.graph_store = graph_store
        self.edge_types = list(num_neighbors.keys())
        self.num_neighbors = {et: list(f) for et, f in num_neighbors.items()}
        depths = {len(f) for f in self.num_neighbors.values()}
        assert len(depths) == 1, "all edge types need equal depth"
        self.depth = depths.pop()
        self.temporal_strategy = temporal_strategy
        self.rng = np.random.default_rng(seed)
        # incoming adjacency per edge type: sample edges pointing INTO the
        # frontier of the edge type's dst type
        self.rev = {et: graph_store.get_rev_csr(et) for et in self.edge_types}
        self.node_types = sorted(
            {t for et in self.edge_types for t in (et[0], et[2])})
        self._slot_of: Dict[str, np.ndarray] = {}

    def slot_degree_bounds(self, seed_type: str, batch_size: int
                           ) -> Dict[EdgeType, List[Tuple[int, int, int]]]:
        """Static dst-slot in-degree bounds per edge type (loader ELL plan)."""
        return hetero_static_slot_bounds(batch_size, self.num_neighbors,
                                         seed_type)

    def _slot_lookup(self, node_type: str, min_cap: int) -> np.ndarray:
        """Persistent global->slot array per type (vectorised hash map)."""
        cap = max([min_cap] + [self.rev[et].num_rows for et in self.edge_types
                               if node_type in (et[0], et[2])])
        cur = self._slot_of.get(node_type)
        if cur is None or len(cur) < cap:
            self._slot_of[node_type] = np.full(cap, -1, np.int64)
        return self._slot_of[node_type]

    def sample(self, seed_type: str, seeds: np.ndarray,
               seed_time: Optional[np.ndarray] = None) -> HeteroSamplerOutput:
        seeds = np.asarray(seeds, np.int64)
        b = len(seeds)
        node_types = sorted(set(self.node_types) | {seed_type})

        slot_of = {t: self._slot_lookup(
            t, int(seeds.max()) + 1 if t == seed_type and b else 1)
            for t in node_types}
        touched: Dict[str, List[np.ndarray]] = {t: [] for t in node_types}
        nodes: Dict[str, List[np.ndarray]] = {
            t: [np.array([-1], np.int64)] for t in node_types}
        num_nodes: Dict[str, List[int]] = {t: [1] for t in node_types}
        rows: Dict[EdgeType, List[np.ndarray]] = {et: [] for et in
                                                  self.edge_types}
        cols: Dict[EdgeType, List[np.ndarray]] = {et: [] for et in
                                                  self.edge_types}
        eids: Dict[EdgeType, List[np.ndarray]] = {et: [] for et in
                                                  self.edge_types}
        num_edges: Dict[EdgeType, List[int]] = {et: [] for et in
                                                self.edge_types}

        try:
            return self._sample(seed_type, seeds, seed_time, slot_of,
                                touched, nodes, num_nodes, rows, cols, eids,
                                num_edges, node_types)
        finally:
            # the lookups must come back clean even when sampling raises
            # mid-hop (bad seed id, fanout mismatch): stale slots would
            # silently corrupt every later batch from this sampler
            for t in node_types:
                for arr in touched[t]:
                    slot_of[t][arr] = -1

    def _sample(self, seed_type, seeds, seed_time, slot_of, touched, nodes,
                num_nodes, rows, cols, eids, num_edges,
                node_types) -> HeteroSamplerOutput:
        b = len(seeds)
        slot_of[seed_type][seeds] = np.arange(1, b + 1)
        touched[seed_type].append(seeds)
        nodes[seed_type].append(seeds)
        num_nodes[seed_type][0] += b

        frontier: Dict[str, np.ndarray] = {
            t: (seeds if t == seed_type else np.zeros(0, np.int64))
            for t in node_types}
        frontier_slots: Dict[str, np.ndarray] = {
            t: (np.arange(1, b + 1) if t == seed_type
                else np.zeros(0, np.int64)) for t in node_types}
        frontier_time = {t: (seed_time if t == seed_type else None)
                         for t in node_types}

        for hop in range(self.depth):
            # discoveries this hop, per src type: (array, times|None) per pass
            new_nodes: Dict[str, List[np.ndarray]] = {t: [] for t in
                                                      node_types}
            new_times: Dict[str, List] = {t: [] for t in node_types}
            next_slot = {t: sum(num_nodes[t]) for t in node_types}
            for et in self.edge_types:
                src_t, _, dst_t = et
                fanout = self.num_neighbors[et][hop]
                front = frontier[dst_t]
                budget = len(front) * fanout
                num_edges[et].append(budget)
                if budget == 0:
                    for coll in (rows, cols, eids):
                        coll[et].append(np.zeros(0, np.int64))
                    continue
                csr = self.rev[et]
                st = (frontier_time[dst_t]
                      if csr.time is not None else None)
                src, eid, parent = _pick_neighbors(
                    csr, front, fanout, self.rng, seed_time=st,
                    strategy=self.temporal_strategy)
                # vectorised dedup: first occurrence of each unseen global
                # id, slotted in BFS discovery order (shared slot map across
                # edge types within the hop)
                valid = src >= 0
                vsrc = src[valid]
                lut = slot_of[src_t]
                base = next_slot[src_t]
                unseen = lut[vsrc] < 0
                uniq, first = np.unique(vsrc[unseen], return_index=True)
                disc = uniq[np.argsort(first, kind="stable")]
                lut[disc] = base + np.arange(len(disc))
                next_slot[src_t] += len(disc)
                touched[src_t].append(disc)
                new_nodes[src_t].append(disc)
                nt = None
                if frontier_time[dst_t] is not None:
                    # time bound of a discovered node = its discovering
                    # parent's (first writer in slot order wins up to numpy
                    # fancy-assignment semantics, matching the homogeneous
                    # sampler)
                    pt = frontier_time[dst_t][parent[valid]]
                    first_slot = lut[vsrc] - base
                    keep = (first_slot >= 0) & (first_slot < len(disc))
                    nt = np.zeros(len(disc), dtype=np.asarray(pt).dtype)
                    nt[first_slot[keep]] = pt[keep]
                new_times[src_t].append(nt)
                # edge assembly: valid edges compacted to the front, pads
                # are (0, 0) null->null self-loops
                w = int(valid.sum())
                row = np.zeros(budget, np.int64)
                col = np.zeros(budget, np.int64)
                ev = np.full(budget, -1, np.int64)
                row[:w] = lut[vsrc]
                col[:w] = frontier_slots[dst_t][parent[valid]]
                ev[:w] = eid[valid]
                rows[et].append(row)
                cols[et].append(col)
                eids[et].append(ev)
            # close the hop: pad each node type's block to its budget
            for t in node_types:
                budget_t = sum(len(frontier[et2[2]]) * self.num_neighbors[
                    et2][hop] for et2 in self.edge_types if et2[0] == t)
                disc_t = (np.concatenate(new_nodes[t]) if new_nodes[t]
                          else np.zeros(0, np.int64))
                blk = np.full(budget_t, -1, np.int64)
                blk[:len(disc_t)] = disc_t
                nodes[t].append(blk)
                num_nodes[t].append(budget_t)
            for t in node_types:
                blk = nodes[t][-1]
                frontier[t] = blk
                fs = np.zeros(len(blk), np.int64)
                valid = blk >= 0
                fs[valid] = slot_of[t][blk[valid]]
                frontier_slots[t] = fs
                if any(a is not None for a in new_times[t]):
                    dtype = next(a.dtype for a in new_times[t]
                                 if a is not None)
                    segs = [a if a is not None else np.zeros(len(n), dtype)
                            for a, n in zip(new_times[t], new_nodes[t])]
                    new = (np.concatenate(segs) if segs
                           else np.zeros(0, dtype))
                    ft = np.zeros(len(blk), dtype=dtype)
                    ft[:len(new)] = new
                    frontier_time[t] = ft
                elif frontier_time[t] is not None:
                    frontier_time[t] = np.zeros(
                        len(blk), dtype=frontier_time[t].dtype)

        return HeteroSamplerOutput(
            node={t: np.concatenate(v) for t, v in nodes.items()},
            row={et: np.concatenate(v) if v else np.zeros(0, np.int64)
                 for et, v in rows.items()},
            col={et: np.concatenate(v) if v else np.zeros(0, np.int64)
                 for et, v in cols.items()},
            edge={et: np.concatenate(v) if v else np.zeros(0, np.int64)
                  for et, v in eids.items()},
            num_sampled_nodes=num_nodes, num_sampled_edges=num_edges,
            seed_slots=np.arange(1, b + 1), seed_type=seed_type)


@dataclasses.dataclass
class HeteroBatch:
    """A typed sampled subgraph with fetched features (jit-ready pytree).

    Per-edge-type ``EdgeIndex`` objects carry host-prefilled CSR/CSC (and,
    with Pallas dispatch on, static-layout ELL) caches; the static aux data
    (seed type + per-hop budgets) is identical for every batch of the same
    seed count, so batches share a single jit trace.
    """
    x_dict: Dict[str, jnp.ndarray]
    edge_index_dict: Dict[EdgeType, EdgeIndex]
    n_id_dict: Dict[str, jnp.ndarray]            # global ids, -1 = pad
    e_id_dict: Dict[EdgeType, jnp.ndarray]       # global edge ids, -1 = pad
    seed_slots: jnp.ndarray                      # (B,) slots in seed type
    seed_type: str
    num_sampled_nodes_dict: Dict[str, List[int]]
    num_sampled_edges_dict: Dict[EdgeType, List[int]]
    y: Optional[jnp.ndarray] = None
    extras: dict = dataclasses.field(default_factory=dict)

    @property
    def num_nodes_dict(self) -> Dict[str, int]:
        return {t: int(x.shape[0]) for t, x in self.x_dict.items()}

    def seed_output(self, out_dict: Dict[str, jnp.ndarray]) -> jnp.ndarray:
        return out_dict[self.seed_type][self.seed_slots]


def _hetero_batch_flatten(b: HeteroBatch):
    children = (b.x_dict, b.edge_index_dict, b.n_id_dict, b.e_id_dict,
                b.seed_slots, b.y, b.extras)
    aux = (b.seed_type,
           tuple(sorted((t, tuple(v))
                        for t, v in b.num_sampled_nodes_dict.items())),
           tuple(sorted((et, tuple(v))
                        for et, v in b.num_sampled_edges_dict.items())))
    return children, aux


def _hetero_batch_unflatten(aux, children):
    x, ei, n_id, e_id, seed_slots, y, extras = children
    seed_type, nn, ne = aux
    return HeteroBatch(
        x_dict=x, edge_index_dict=ei, n_id_dict=n_id, e_id_dict=e_id,
        seed_slots=seed_slots, seed_type=seed_type,
        num_sampled_nodes_dict={t: list(v) for t, v in nn},
        num_sampled_edges_dict={et: list(v) for et, v in ne},
        y=y, extras=extras)


# HeteroBatch flows through jit boundaries whole (per-hop budgets and the
# seed type are static aux data); identical budgets -> identical treedef ->
# no recompiles across batches.
jax.tree_util.register_pytree_node(HeteroBatch, _hetero_batch_flatten,
                                   _hetero_batch_unflatten)


class HeteroNeighborLoader(_PrefetchLoader):
    """Typed mini-batches: sampler + per-type feature fetch (paper C6+C7).

    Built on the same producer-thread/prefetch machinery as
    ``NeighborLoader``; the producer pre-fills every relation's CSR/CSC
    host-side and — when Pallas dispatch is on (``prefill_ell=None`` follows
    ``use_pallas()``) — packs a static-layout bucketed ELL per edge type
    against the sampler's budgets, so whole ``HeteroBatch``es flow through
    jit with one trace and every relation's ``propagate`` reaches the
    Pallas SpMM kernel. A ``drop_last=False`` tail batch gets its own
    (cached-by-size) static layouts instead of being silently dropped.
    """

    def __init__(self, feature_store, graph_store, *,
                 num_neighbors: Dict[EdgeType, Sequence[int]],
                 input_type: str, input_nodes: np.ndarray, batch_size: int,
                 input_time: Optional[np.ndarray] = None,
                 labels_attr: Optional[str] = "y",
                 temporal_strategy: str = "uniform",
                 transform=None, shuffle: bool = False,
                 drop_last: bool = True, prefetch: int = 0,
                 pipeline_depth: int = 1, partition_order: bool = False,
                 prefill_ell: Optional[bool] = None,
                 on_batch_error: str = "raise", batch_retries: int = 2,
                 seed: int = 0):
        self.fs = feature_store
        self._init_policy(on_batch_error, batch_retries)
        self._init_pipeline(pipeline_depth, partition_order)
        self.sampler = HeteroNeighborSampler(
            graph_store, num_neighbors,
            temporal_strategy=temporal_strategy, seed=seed)
        self.input_type = input_type
        self.input_nodes = np.asarray(input_nodes)
        self.input_time = (None if input_time is None
                           else np.asarray(input_time))
        self.batch_size = batch_size
        self.labels_attr = labels_attr
        self.transform = transform
        self.shuffle = shuffle
        self.drop_last = drop_last
        self.prefetch = prefetch
        self.prefill_ell = prefill_ell
        self._ell_layouts: dict = {}  # num_seeds -> {edge_type: layout}
        self.rng = np.random.default_rng(seed)

    def _ell_layouts_for(self, num_seeds: int) -> dict:
        if num_seeds not in self._ell_layouts:
            bounds = self.sampler.slot_degree_bounds(self.input_type,
                                                     num_seeds)
            self._ell_layouts[num_seeds] = {
                et: ell_layout_from_bounds(b) for et, b in bounds.items()}
        return self._ell_layouts[num_seeds]

    def _seed_feature_key(self):
        return (self.input_type, "x")

    # ---- stages (see _PrefetchLoader: sample is sequential, gather+pack
    # run on the stage pool when pipeline_depth > 1) ----
    def _stage_sample(self, seeds: np.ndarray,
                      seed_time: Optional[np.ndarray]):
        out = self.sampler.sample(self.input_type, seeds, seed_time)
        fill_ell = (use_pallas() if self.prefill_ell is None
                    else self.prefill_ell)
        layouts = self._ell_layouts_for(len(seeds)) if fill_ell else {}
        return {"seeds": seeds, "out": out, "layouts": layouts,
                "fill_ell": fill_ell}

    def _stage_gather(self, sample):
        out = sample["out"]
        fetch = getattr(self.fs, "get_padded_resilient", None)
        degraded = None
        if fetch is not None:  # resilient store: per-type degraded masks
            fetched = {t: fetch(n, group=t, attr="x")
                       for t, n in out.node.items()}
            x_dict = {t: v[0] for t, v in fetched.items()}
            degraded = {t: v[1] for t, v in fetched.items()}
        else:
            x_dict = {t: self.fs.get_padded(n, group=t, attr="x")
                      for t, n in out.node.items()}
        y = None
        if self.labels_attr is not None:
            try:
                y = self.fs.get_tensor(
                    group=self.input_type, attr=self.labels_attr,
                    index=sample["seeds"])
            except KeyError:
                y = None
        return {"x_dict": x_dict, "y": y, "degraded": degraded}

    def _stage_pack(self, sample, gather) -> HeteroBatch:
        out = sample["out"]
        layouts, fill_ell = sample["layouts"], sample["fill_ell"]
        ei_dict = {}
        for et in self.sampler.edge_types:
            ei_dict[et] = EdgeIndex.from_coo_prefilled(
                out.row[et], out.col[et],
                len(out.node[et[0]]), len(out.node[et[2]]),
                ell_layout=layouts.get(et, []) if fill_ell else None)
        batch = HeteroBatch(
            x_dict={t: jnp.asarray(v) for t, v in gather["x_dict"].items()},
            edge_index_dict=ei_dict,
            n_id_dict={t: jnp.asarray(n) for t, n in out.node.items()},
            e_id_dict={et: jnp.asarray(e) for et, e in out.edge.items()},
            seed_slots=jnp.asarray(out.seed_slots.astype(np.int32)),
            seed_type=out.seed_type,
            num_sampled_nodes_dict=out.num_sampled_nodes,
            num_sampled_edges_dict=out.num_sampled_edges,
            y=None if gather["y"] is None else jnp.asarray(gather["y"]))
        if gather["degraded"] is not None:
            batch.extras["degraded"] = {
                t: jnp.asarray(m) for t, m in gather["degraded"].items()}
        if self.transform is not None:
            batch = self.transform(batch)
        return batch
