"""Qwen3-4B [dense]: 36L d_model=2560 32H (GQA kv=8) d_ff=9728
vocab=151936 — qk_norm, GQA; q_dim (32*128=4096) != d_model.
[hf:Qwen/Qwen3-8B; hf]"""

from repro.nn.lm.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b", family="dense",
    n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=9728, vocab_size=151936, act="silu", qk_norm=True,
    rope_theta=1_000_000.0, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="qwen3-4b-smoke", family="dense",
    n_layers=2, d_model=48, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=96, vocab_size=256, act="silu", qk_norm=True,
    tie_embeddings=True, dtype="float32",
)
