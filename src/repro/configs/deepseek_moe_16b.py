"""DeepSeekMoE-16B [moe]: 28L d_model=2048 16H (MHA kv=16) d_ff=1408
vocab=102400, MoE 64 routed experts top-6 + 2 shared experts, fine-grained;
layer 0 uses a dense FFN (hidden 10944). [arXiv:2401.06066; hf]"""

from repro.nn.lm.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408, vocab_size=102400, act="silu", rope_theta=10_000.0,
    moe=MoEConfig(num_experts=64, top_k=6, d_expert=1408, num_shared=2,
                  first_dense_ff=10944),
)

SMOKE = ModelConfig(
    name="deepseek-moe-16b-smoke", family="moe",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=64, vocab_size=256, act="silu", dtype="float32",
    moe=MoEConfig(num_experts=8, top_k=3, d_expert=64, num_shared=2,
                  first_dense_ff=128, capacity_factor=8.0),
)
