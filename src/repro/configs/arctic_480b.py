"""Snowflake Arctic-480B [moe]: 35L d_model=7168 56H (GQA kv=8) d_ff=4864
vocab=32000, MoE 128 experts top-2 + dense residual (dense-MoE hybrid:
a dense FFN runs in parallel with the routed experts on every layer).
[hf:Snowflake/snowflake-arctic-base; hf]"""

from repro.nn.lm.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=4864, vocab_size=32000, act="silu",
    moe=MoEConfig(num_experts=128, top_k=2, d_expert=4864,
                  dense_residual=True),
)

SMOKE = ModelConfig(
    name="arctic-480b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=96, vocab_size=256, act="silu", dtype="float32",
    moe=MoEConfig(num_experts=8, top_k=2, d_expert=96, dense_residual=True,
                  capacity_factor=8.0),  # non-dropping at smoke scale
)
