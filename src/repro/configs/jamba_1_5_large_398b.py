"""Jamba-1.5-Large-398B [hybrid]: 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, MoE 16e top-2 — Mamba+attention 1:7 interleave
(1 attention layer per 8-layer period), MoE every other layer.
[arXiv:2403.19887; hf]"""

from repro.nn.lm.config import MambaConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid", subquadratic=True,
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab_size=65536, act="silu",
    attn_every=8, attn_offset=4,  # attn at index 4 of each 8-layer period
    moe=MoEConfig(num_experts=16, top_k=2, d_expert=24576, moe_every=2),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
)

SMOKE = ModelConfig(
    name="jamba-smoke", family="hybrid", subquadratic=True,
    n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=96, vocab_size=256, act="silu", dtype="float32",
    attn_every=8, attn_offset=4,
    moe=MoEConfig(num_experts=4, top_k=2, d_expert=96, moe_every=2,
                  capacity_factor=4.0),  # non-dropping at smoke scale
    mamba=MambaConfig(d_state=8, d_conv=4, expand=2),
)
