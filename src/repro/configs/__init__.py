"""Assigned-architecture registry: ``get_config(arch_id)`` / ``--arch <id>``.

Each ``<id>.py`` module defines ``CONFIG`` (the exact published config) and
``SMOKE`` (a reduced same-family config for CPU smoke tests). Shapes live in
``repro.configs.shapes``.
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.nn.lm.config import ModelConfig

ARCH_IDS: List[str] = [
    "qwen3_14b", "qwen2_7b", "gemma_2b", "qwen3_4b", "arctic_480b",
    "deepseek_moe_16b", "jamba_1_5_large_398b", "seamless_m4t_large_v2",
    "internvl2_76b", "falcon_mamba_7b",
]

# CLI aliases with dashes/dots as given in the assignment
ALIASES: Dict[str, str] = {
    "qwen3-14b": "qwen3_14b", "qwen2-7b": "qwen2_7b", "gemma-2b": "gemma_2b",
    "qwen3-4b": "qwen3_4b", "arctic-480b": "arctic_480b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "internvl2-76b": "internvl2_76b", "falcon-mamba-7b": "falcon_mamba_7b",
}


def canonical(arch: str) -> str:
    return ALIASES.get(arch, arch)


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(arch)}")
    return mod.SMOKE if smoke else mod.CONFIG
