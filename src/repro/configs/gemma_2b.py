"""Gemma-2B [dense]: 18L d_model=2048 8H (GQA kv=1, i.e. MQA) d_ff=16384
vocab=256000 — GeGLU, head_dim=256, tied embeddings, sqrt(d) embedding
scaling, (1+w) RMSNorm. [arXiv:2403.08295; hf]"""

from repro.nn.lm.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b", family="dense",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab_size=256000, act="gelu", tie_embeddings=True,
    emb_scale=True, rms_scale_plus_one=True, rope_theta=10_000.0,
)

SMOKE = ModelConfig(
    name="gemma-2b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, head_dim=32,
    d_ff=128, vocab_size=256, act="gelu", tie_embeddings=True,
    emb_scale=True, rms_scale_plus_one=True, dtype="float32",
)
