"""Falcon-Mamba-7B [ssm]: 64L d_model=4096, attention-free (pure Mamba-1),
d_ff=0 (the Mamba block IS the layer), vocab=65024, ssm_state=16.
[arXiv:2410.05355; unverified]"""

from repro.nn.lm.config import MambaConfig, ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm", subquadratic=True,
    n_layers=64, d_model=4096, n_heads=0, n_kv_heads=0, head_dim=0,
    d_ff=0, vocab_size=65024, act="silu",
    attn_every=0,  # attention-free
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
)

SMOKE = ModelConfig(
    name="falcon-mamba-smoke", family="ssm", subquadratic=True,
    n_layers=2, d_model=64, n_heads=0, n_kv_heads=0, head_dim=0,
    d_ff=0, vocab_size=256, act="silu", dtype="float32",
    attn_every=0, mamba=MambaConfig(d_state=8, d_conv=4, expand=2),
)
