"""InternVL2-76B [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256 — Llama-3-70B language backbone; InternViT vision frontend
STUBBED: ``input_specs`` provides precomputed patch embeddings (256 tokens).
[arXiv:2404.16821; unverified]"""

from repro.nn.lm.config import ModelConfig

N_PATCHES = 256

CONFIG = ModelConfig(
    name="internvl2-76b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=28672, vocab_size=128256, act="silu", rope_theta=500_000.0,
    n_prefix_embeds=N_PATCHES,
)

SMOKE = ModelConfig(
    name="internvl2-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, act="silu", dtype="float32",
    n_prefix_embeds=8,
)
