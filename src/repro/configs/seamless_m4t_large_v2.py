"""SeamlessM4T-large-v2 [audio]: enc-dec, 24L encoder + 24L decoder,
d_model=1024 16H (MHA kv=16) d_ff=8192 vocab=256206 — multimodal frontend
STUBBED: ``input_specs`` provides precomputed audio frame embeddings.
[arXiv:2308.11596; hf]"""

from repro.nn.lm.config import ModelConfig

# vocab padded 256206 -> 256256 (multiple of 256) for tensor-parallel
# divisibility — standard practice when sharding embedding/vocab dims.
# The logical vocabulary remains 256206; ids >= 256206 are never emitted.
CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="audio",
    arch_type="encdec", n_enc_layers=24,
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=8192, vocab_size=256256, act="gelu", rope_theta=10_000.0,
)

SMOKE = ModelConfig(
    name="seamless-smoke", family="audio",
    arch_type="encdec", n_enc_layers=2,
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256, act="gelu", dtype="float32",
)
