"""Minimal pure-functional module system (no flax on this box).

Modules are *stateless descriptors*: ``init(key) -> params`` builds a param
pytree, ``apply(params, ...)`` is a pure function. This keeps every training
/serving step a closed jit-able function of ``(params, batch)`` — the JAX
rendition of PyG's tensor-centric API ("exclusively operates on tensor-like
data").
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Sequence

import jax
import jax.numpy as jnp

Params = Any


class Module:
    """Base class: subclasses define ``init`` and ``apply``."""

    def init(self, key: jax.Array) -> Params:
        return {}

    def apply(self, params: Params, *args, **kwargs):
        raise NotImplementedError

    def __call__(self, params: Params, *args, **kwargs):
        return self.apply(params, *args, **kwargs)


def split_keys(key: jax.Array, names: Sequence[str]) -> Dict[str, jax.Array]:
    keys = jax.random.split(key, len(names))
    return dict(zip(names, keys))


# --------------------------------------------------------------------- inits
def lecun_normal(key, shape, dtype=jnp.float32, in_axis: int = 0):
    fan_in = shape[in_axis]
    return jax.random.normal(key, shape, dtype) * (1.0 / fan_in) ** 0.5


def glorot_uniform(key, shape, dtype=jnp.float32):
    fan_in, fan_out = shape[0], shape[-1]
    lim = (6.0 / (fan_in + fan_out)) ** 0.5
    return jax.random.uniform(key, shape, dtype, -lim, lim)


def normal_init(key, shape, dtype=jnp.float32, stddev=0.02):
    return jax.random.normal(key, shape, dtype) * stddev
