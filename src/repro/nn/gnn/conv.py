"""GNN operator zoo — the five architectures benchmarked in paper Tables 1-2
(GCN, GraphSAGE, GIN, GAT, EdgeCNN) built on the MessagePassing framework.

GCN/SAGE/GIN use the *fused* SpMM path (default message + sum/mean/max/min
— all four reduce modes lower to the blocked-ELL Pallas kernel on TPU);
GAT rides the *fused attention* path (``EdgeIndex.attend`` — the flash-GAT
Pallas kernel over the same ELL buckets, segment-softmax oracle fallback);
EdgeCNN exercises the edge-level materialisation path (custom messages) —
together they cover all three compute paths of C2. GCNConv wraps a raw
``(2, E)`` edge array into an ``EdgeIndex`` once so the fused path (and its
demand-filled CSC/ELL caches) is reachable even when callers don't
construct one themselves.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.edge_index import EdgeIndex
from repro.core.message_passing import MessagePassing
from repro.nn.layers import MLP, Linear
from repro.nn.module import glorot_uniform


def gcn_norm(edge_index, num_nodes: int, add_self_loops: bool = True):
    """Symmetric GCN weights WITHOUT materialising self-loop edges.

    Returns (edge_weight, self_weight): the self-loop contribution
    ``D^-1/2 I D^-1/2 x`` is applied as ``self_weight[:, None] * x`` instead
    of appending edges — keeps the BFS edge ordering intact so layer-wise
    trimming (C8) can slice precomputed weights exactly.
    """
    src = edge_index.src if isinstance(edge_index, EdgeIndex) else edge_index[0]
    dst = edge_index.dst if isinstance(edge_index, EdgeIndex) else edge_index[1]
    deg = jax.ops.segment_sum(jnp.ones_like(dst, dtype=jnp.float32), dst,
                              num_segments=num_nodes)
    if add_self_loops:
        deg = deg + 1.0
    dinv = jnp.where(deg > 0, jax.lax.rsqrt(jnp.maximum(deg, 1e-12)), 0.0)
    w = dinv[src] * dinv[dst]
    self_w = dinv * dinv if add_self_loops else jnp.zeros_like(dinv)
    return w, self_w


class GCNConv(MessagePassing):
    def __init__(self, in_features: int, out_features: int,
                 add_self_loops: bool = True, bias: bool = True):
        super().__init__(aggr="sum")
        self.lin = Linear(in_features, out_features, bias=bias)
        self.add_self_loops = add_self_loops

    def init(self, key):
        return {"lin": self.lin.init(key)}

    def apply(self, params, x, edge_index, num_nodes: Optional[int] = None,
              edge_weight: Optional[jnp.ndarray] = None,
              self_weight: Optional[jnp.ndarray] = None, **kw):
        n = num_nodes if num_nodes is not None else x.shape[0]
        if not isinstance(edge_index, EdgeIndex):
            edge_index = EdgeIndex(edge_index, n, n)
        if edge_weight is None:
            edge_weight, self_weight = gcn_norm(edge_index, n,
                                                self.add_self_loops)
        x = self.lin.apply(params["lin"], x)
        out = self.propagate(params, edge_index, x,
                             edge_weight=edge_weight, num_nodes=n, **kw)
        if self_weight is not None:
            out = out + self_weight[:, None].astype(x.dtype) * x
        return out


class SAGEConv(MessagePassing):
    def __init__(self, in_features: int, out_features: int,
                 aggr: str = "mean", bias: bool = True):
        super().__init__(aggr=aggr)
        self.lin_l = Linear(in_features, out_features, bias=bias)  # neighbor
        self.lin_r = Linear(in_features, out_features, bias=False)  # root

    def init(self, key):
        k1, k2 = jax.random.split(key)
        return {"lin_l": self.lin_l.init(k1), "lin_r": self.lin_r.init(k2)}

    def apply(self, params, x, edge_index, num_nodes: Optional[int] = None,
              **kw):
        n = num_nodes if num_nodes is not None else (
            x[1].shape[0] if isinstance(x, tuple) else x.shape[0])
        agg = self.propagate(params, edge_index, x, num_nodes=n, **kw)
        x_dst = x[1] if isinstance(x, tuple) else x
        return (self.lin_l.apply(params["lin_l"], agg)
                + self.lin_r.apply(params["lin_r"], x_dst))

    def fused_projections(self, params):
        """(w_neigh, b_neigh, w_root, b_root) — the grouped-GEMM contract.

        SAGE aggregates *raw* source features and only then projects, so a
        hetero wrapper may hoist both linears out of the conv and batch
        them with every other relation's into one grouped matmul
        (``HeteroConv``'s single-MXU-launch projection path) without
        changing the math.
        """
        return (params["lin_l"]["w"], params["lin_l"].get("b"),
                params["lin_r"]["w"], params["lin_r"].get("b"))


class GINConv(MessagePassing):
    def __init__(self, in_features: int, out_features: int,
                 hidden: Optional[int] = None, train_eps: bool = True):
        super().__init__(aggr="sum")
        hidden = hidden or out_features
        self.mlp = MLP([in_features, hidden, out_features])
        self.train_eps = train_eps

    def init(self, key):
        return {"mlp": self.mlp.init(key),
                "eps": jnp.asarray(0.0, jnp.float32)}

    def apply(self, params, x, edge_index, num_nodes: Optional[int] = None,
              **kw):
        n = num_nodes if num_nodes is not None else x.shape[0]
        agg = self.propagate(params, edge_index, x, num_nodes=n, **kw)
        x_dst = x[1] if isinstance(x, tuple) else x
        return self.mlp.apply(params["mlp"], (1.0 + params["eps"]) * x_dst + agg)


class GATConv(MessagePassing):
    """Graph attention (GAT) on the fused attention fast path.

    The aggregation rides :meth:`MessagePassing._propagate_attention`:
    with an ``EdgeIndex`` (and no explainer ``message_callback``) the step
    lowers to ``EdgeIndex.attend`` — the fused flash-GAT Pallas kernel over
    the blocked-ELL buckets when a cache is packed (loader-prefilled
    batches / ``fill_cache()``), the COO segment-softmax oracle otherwise.
    Explainer soft masks fold into the post-softmax per-edge weight and
    stay fused. Bipartite ``(x_src, x_dst)`` inputs (the hetero per-relation
    call) share one projection; ``flow="target_to_source"`` dispatches the
    transpose table with sender/receiver roles (and attention vectors)
    swapped.
    """

    def __init__(self, in_features: int, out_features: int, heads: int = 1,
                 negative_slope: float = 0.2, concat: bool = True,
                 flow: str = "source_to_target"):
        super().__init__(aggr="sum", flow=flow)
        self.heads = heads
        self.out_per_head = out_features // heads if concat else out_features
        self.concat = concat
        self.lin = Linear(in_features, heads * self.out_per_head, bias=False)
        self.negative_slope = negative_slope

    def init(self, key):
        k1, k2, k3, k4 = jax.random.split(key, 4)
        h, f = self.heads, self.out_per_head
        return {
            "lin": self.lin.init(k1),
            "att_src": glorot_uniform(k2, (h, f)),
            "att_dst": glorot_uniform(k3, (h, f)),
            "bias": jnp.zeros((h * f if self.concat else f,), jnp.float32),
        }

    def apply(self, params, x, edge_index, num_nodes: Optional[int] = None,
              message_callback=None, return_attention: bool = False,
              edge_mask: Optional[jnp.ndarray] = None,
              edge_weight: Optional[jnp.ndarray] = None, **kw):
        h, f = self.heads, self.out_per_head
        x_src, x_dst = x if isinstance(x, tuple) else (x, x)
        z_src = self.lin.apply(params["lin"], x_src).reshape(-1, h, f)
        z_dst = (z_src if x_dst is x_src
                 else self.lin.apply(params["lin"], x_dst).reshape(-1, h, f))
        # att_src weighs the *message sender*, att_dst the receiver — under
        # target_to_source flow the dst side sends, so the vectors swap.
        if self.flow == "source_to_target":
            a_src = (z_src * params["att_src"]).sum(-1)  # (N_src, H)
            a_dst = (z_dst * params["att_dst"]).sum(-1)  # (N_dst, H)
        else:
            a_src = (z_src * params["att_dst"]).sum(-1)
            a_dst = (z_dst * params["att_src"]).sum(-1)
        # Explicit logit spec: GAT is the additive instance of the typed-
        # attention stack (numerically identical to the implicit default —
        # the additive non-carry path is byte-for-byte the pre-typed code).
        # An explainer message_callback needs edge-level materialisation,
        # which the typed entry point doesn't serve, so it keeps the
        # implicit route.
        from repro.kernels.attention.ops import AdditiveLogit
        logit = (None if message_callback is not None
                 else AdditiveLogit(self.negative_slope))
        res = self.propagate(params, edge_index, (z_src, z_dst),
                             alpha=(a_src, a_dst), edge_mask=edge_mask,
                             edge_weight=edge_weight, num_nodes=num_nodes,
                             message_callback=message_callback,
                             negative_slope=self.negative_slope,
                             logit=logit,
                             return_attention=return_attention)
        out, alpha = res if return_attention else (res, None)
        n = out.shape[0]
        out = out.reshape(n, h * f) if self.concat else out.mean(1)
        out = out + params["bias"]
        if return_attention:
            return out, alpha
        return out


class EdgeConv(MessagePassing):
    """EdgeCNN (DGCNN edge convolution): max_j MLP([x_i, x_j - x_i])."""

    def __init__(self, in_features: int, out_features: int,
                 hidden: Optional[int] = None):
        super().__init__(aggr="max")
        hidden = hidden or out_features
        self.mlp = MLP([2 * in_features, hidden, out_features])

    def init(self, key):
        return {"mlp": self.mlp.init(key)}

    def message(self, params, x_j, x_i, edge_attr):
        return self.mlp.apply(params["mlp"],
                              jnp.concatenate([x_i, x_j - x_i], axis=-1))

    def apply(self, params, x, edge_index, num_nodes: Optional[int] = None,
              **kw):
        n = num_nodes if num_nodes is not None else x.shape[0]
        return self.propagate(params, edge_index, x, num_nodes=n, **kw)
