"""Stacked GNN models (the paper's Table 1-2 benchmark subjects).

``BasicGNN`` stacks one conv type with ReLU between layers and supports the
paper's two execution-mode axes:

* ``jit`` on/off — paper's eager vs ``torch.compile`` (Table 1);
* ``trim`` on/off — layer-wise trimming of BFS subgraphs (Table 2).
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.trim import trim_to_layer
from repro.nn.gnn.conv import EdgeConv, GATConv, GCNConv, GINConv, SAGEConv
from repro.nn.module import Module

_CONVS = {"gcn": GCNConv, "sage": SAGEConv, "gin": GINConv, "gat": GATConv,
          "edgecnn": EdgeConv}


class BasicGNN(Module):
    # Explainers may pass their soft edge mask as `edge_mask` (fused-path
    # reweighting through the kernels' custom VJPs) instead of a
    # message_callback (which forces edge-level materialisation).
    supports_edge_mask = True

    def __init__(self, conv: str, in_features: int, hidden: int,
                 out_features: int, num_layers: int, **conv_kwargs):
        self.conv_name = conv
        cls = _CONVS[conv]
        dims = ([in_features] + [hidden] * (num_layers - 1) + [out_features])
        self.convs = []
        for i in range(num_layers):
            kw = dict(conv_kwargs)
            if conv == "gat" and i == num_layers - 1:
                kw["concat"] = False  # head-average final layer (PyG default)
            self.convs.append(cls(dims[i], dims[i + 1], **kw))
        self.num_layers = num_layers

    def init(self, key):
        keys = jax.random.split(key, len(self.convs))
        return {f"conv{i}": c.init(k)
                for i, (c, k) in enumerate(zip(self.convs, keys))}

    def apply(self, params, x, edge_index,
              num_nodes: Optional[int] = None,
              num_sampled_nodes_per_hop: Optional[Sequence[int]] = None,
              num_sampled_edges_per_hop: Optional[Sequence[int]] = None,
              trim: bool = False, message_callback=None, edge_mask=None):
        """Forward. With ``trim=True`` the per-hop sampler budgets drive
        progressive static slicing (paper C8).

        For degree-normalised convs (GCN) the normalisation is computed ONCE
        on the full batch graph and *sliced* alongside edges/nodes, so
        trimming preserves seed outputs exactly (the paper's invariant).
        ``edge_mask`` (explainer soft mask) reweighs every edge's message
        multiplicatively *without* leaving the fused path — per layer it is
        sliced to the surviving (prefix) edge set, exactly like the GCN
        normalisation weights.
        """
        edge_weight = self_weight = None
        if self.conv_name == "gcn":
            from repro.nn.gnn.conv import gcn_norm
            n0 = num_nodes if num_nodes is not None else x.shape[0]
            edge_weight, self_weight = gcn_norm(edge_index, n0)
        for i, conv in enumerate(self.convs):
            extra = {}
            # layer 0 sees the untrimmed graph: skipping its no-op trim
            # keeps any loader-prefilled EdgeIndex caches intact there
            if trim and num_sampled_nodes_per_hop is not None and i > 0:
                x, edge_index, edge_weight = trim_to_layer(
                    i, num_sampled_nodes_per_hop, num_sampled_edges_per_hop,
                    x, edge_index, edge_attr=edge_weight)
                n = x.shape[0]
                if self_weight is not None:
                    self_weight = self_weight[:n]
            else:
                n = num_nodes if num_nodes is not None else x.shape[0]
            if self.conv_name == "gcn":
                extra = {"edge_weight": edge_weight,
                         "self_weight": self_weight}
            if edge_mask is not None:
                n_e = (edge_index.num_edges if hasattr(edge_index,
                                                       "num_edges")
                       else edge_index.shape[1])
                extra["edge_mask"] = edge_mask[:n_e]
            x = conv.apply(params[f"conv{i}"], x, edge_index, num_nodes=n,
                           message_callback=message_callback, **extra)
            if i < len(self.convs) - 1:
                x = jax.nn.relu(x)
        return x


def make_model(name: str, in_features: int, hidden: int, out_features: int,
               num_layers: int) -> BasicGNN:
    """The five paper-benchmark models with their conventional settings."""
    if name == "gat":
        return BasicGNN("gat", in_features, hidden, out_features, num_layers,
                        heads=4)
    return BasicGNN(name, in_features, hidden, out_features, num_layers)


def make_hgt(metadata, in_features: int, hidden: int, out_features: int,
             num_layers: int, heads: int = 2):
    """HGT graph-transformer stack with the BasicGNN dims convention.

    Each layer is an ``HGTConv`` (typed dot-product attention with a
    cross-relation merged softmax, carried by the same fused kernel as
    GAT); the stack shares one packed per-relation ELL layout across
    layers via the hetero trimming path.
    """
    from repro.core.hetero import hgt
    dims = [in_features] + [hidden] * (num_layers - 1) + [out_features]
    return hgt(metadata, dims, heads=heads)
