"""Core neural layers shared by the GNN zoo and the LM stack (pure JAX)."""

from __future__ import annotations

from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.nn.module import Module, glorot_uniform, lecun_normal, normal_init, split_keys


class Linear(Module):
    def __init__(self, in_features: int, out_features: int, bias: bool = True,
                 dtype=jnp.float32, init: Callable = glorot_uniform):
        self.in_features = in_features
        self.out_features = out_features
        self.bias = bias
        self.dtype = dtype
        self._init = init

    def init(self, key):
        kw, kb = jax.random.split(key)
        p = {"w": self._init(kw, (self.in_features, self.out_features),
                             self.dtype)}
        if self.bias:
            p["b"] = jnp.zeros((self.out_features,), self.dtype)
        return p

    def apply(self, params, x):
        y = x @ params["w"]
        if self.bias:
            y = y + params["b"]
        return y


class MLP(Module):
    """Plain MLP with activation between layers (used by GIN/EdgeCNN)."""

    def __init__(self, dims: Sequence[int], act: Callable = jax.nn.relu,
                 bias: bool = True, dtype=jnp.float32):
        self.dims = tuple(dims)
        self.act = act
        self.layers = [Linear(dims[i], dims[i + 1], bias=bias, dtype=dtype)
                       for i in range(len(dims) - 1)]

    def init(self, key):
        keys = jax.random.split(key, len(self.layers))
        return {f"lin{i}": l.init(k) for i, (l, k) in
                enumerate(zip(self.layers, keys))}

    def apply(self, params, x):
        for i, l in enumerate(self.layers):
            x = l.apply(params[f"lin{i}"], x)
            if i < len(self.layers) - 1:
                x = self.act(x)
        return x


class Embedding(Module):
    def __init__(self, num_embeddings: int, features: int, dtype=jnp.float32):
        self.num_embeddings = num_embeddings
        self.features = features
        self.dtype = dtype

    def init(self, key):
        return {"embedding": normal_init(
            key, (self.num_embeddings, self.features), self.dtype)}

    def apply(self, params, ids):
        return jnp.take(params["embedding"], ids, axis=0)

    def attend(self, params, x):
        """Tied-embedding logits: x @ E^T."""
        return x @ params["embedding"].T


class LayerNorm(Module):
    def __init__(self, features: int, eps: float = 1e-5, dtype=jnp.float32):
        self.features = features
        self.eps = eps
        self.dtype = dtype

    def init(self, key):
        return {"scale": jnp.ones((self.features,), self.dtype),
                "bias": jnp.zeros((self.features,), self.dtype)}

    def apply(self, params, x):
        xf = x.astype(jnp.float32)
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + self.eps)
        return (y * params["scale"] + params["bias"]).astype(x.dtype)


class RMSNorm(Module):
    def __init__(self, features: int, eps: float = 1e-6, dtype=jnp.float32,
                 scale_plus_one: bool = False):
        self.features = features
        self.eps = eps
        self.dtype = dtype
        # gemma parameterises the scale as (1 + w) with w zero-init.
        self.scale_plus_one = scale_plus_one

    def init(self, key):
        init = jnp.zeros if self.scale_plus_one else jnp.ones
        return {"scale": init((self.features,), self.dtype)}

    def apply(self, params, x):
        xf = x.astype(jnp.float32)
        var = (xf * xf).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + self.eps)
        scale = params["scale"].astype(jnp.float32)
        if self.scale_plus_one:
            scale = 1.0 + scale
        return (y * scale).astype(x.dtype)
