"""Shared typed-projection machinery: {H_T W_T} as ONE grouped matmul.

Every hetero layer in the repo projects per-type row chunks through
per-type weight matrices — ``GroupedLinear`` over node types,
``HeteroConv``'s grouped path over 2·|edge types| neighbor/root groups,
``HGTConv``'s K/Q/V over 3·|node types| groups and its per-type output
heads. They all reduce to the same pack -> grouped GEMM -> unpack
sequence (the CUTLASS grouped-GEMM pattern on the MXU via
``kernels/grouped_matmul``). This module is the single implementation;
the callers contribute only their grouping semantics.

Group sizes are static shape facts (``chunk.shape[0]``) and stay
host-side (``np.int32``) so the packer can make shape decisions under
tracing.
"""

from __future__ import annotations

from typing import List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.grouped_matmul import ops as gmm_ops


def grouped_apply(chunks: Sequence[jnp.ndarray],
                  weights: Union[jnp.ndarray, Sequence[jnp.ndarray]],
                  biases: Optional[Sequence[Optional[jnp.ndarray]]] = None,
                  *, force_pallas: Optional[bool] = None,
                  interpret: Optional[bool] = None) -> List[jnp.ndarray]:
    """Project ``chunks[g] @ weights[g] (+ biases[g])`` in ONE grouped GEMM.

    ``chunks`` is a list of (n_g, F_in) row blocks, ``weights`` a stacked
    (G, F_in, F_out) tensor (or a list to be stacked — all groups must
    share in/out dims, the grouped-GEMM contract). ``biases`` is an
    optional per-group list; ``None`` entries skip the add. Returns the
    per-group output blocks, unpacked in input order.

    ``interpret=None`` auto-selects interpret mode off-TPU, so callers on
    CPU/GPU exercise the same packed code path the TPU kernel runs.
    """
    sizes = np.asarray([c.shape[0] for c in chunks], np.int32)
    if not isinstance(weights, jnp.ndarray):
        weights = jnp.stack(list(weights))
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    out = gmm_ops.grouped_matmul(
        jnp.concatenate(list(chunks), axis=0), weights, sizes,
        force_pallas=force_pallas, interpret=interpret)
    parts: List[jnp.ndarray] = []
    off = 0
    for s in sizes.tolist():
        parts.append(out[off:off + s])
        off += s
    if biases is not None:
        parts = [p if b is None else p + b for p, b in zip(parts, biases)]
    return parts
