"""LM model assembly: scanned period-blocks covering all assigned families.

Layers are grouped into *periods* (the LCM of the attention-interleave and
MoE-interleave patterns: 1 for homogeneous stacks, 8 for Jamba) and the
period stack is driven by ``lax.scan`` over period-stacked params. This keeps
the HLO size O(period) instead of O(n_layers) — essential for compiling the
40-cell dry-run sweep — and gives remat a natural per-period boundary.

Supported families:
  dense decoders (qwen*, gemma)      MoE decoders (arctic, deepseek-moe)
  hybrid mamba+attn MoE (jamba)      pure SSM (falcon-mamba)
  enc-dec (seamless-m4t)             VLM backbone w/ stub patches (internvl2)
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.nn.lm import attention as attn
from repro.nn.lm import ffn as ffn_mod
from repro.nn.lm import mamba as mamba_mod
from repro.nn.lm import moe as moe_mod
from repro.nn.lm.config import ModelConfig
from repro.nn.module import normal_init

Params = Any


# ---------------------------------------------------------------- sublayers
def _init_sublayer(key, cfg: ModelConfig, desc, cross: bool = False):
    mixer, ffn = desc
    ks = jax.random.split(key, 6)
    dt = cfg.jnp_dtype
    p: Dict[str, Any] = {"norm1": jnp.ones((cfg.d_model,), dt)}
    if mixer == "attn":
        p["mixer"] = attn.init_attention(ks[0], cfg)
    else:
        p["mixer"] = mamba_mod.init_mamba(ks[0], cfg)
    if cross:
        p["norm_x"] = jnp.ones((cfg.d_model,), dt)
        p["cross"] = attn.init_attention(ks[1], cfg, cross=True)
    if ffn == "dense":
        p["norm2"] = jnp.ones((cfg.d_model,), dt)
        p["ffn"] = ffn_mod.init_ffn(ks[2], cfg)
    elif ffn == "dense_first":
        p["norm2"] = jnp.ones((cfg.d_model,), dt)
        p["ffn"] = ffn_mod.init_ffn(ks[2], cfg, d_ff=cfg.moe.first_dense_ff)
    elif ffn == "moe":
        p["norm2"] = jnp.ones((cfg.d_model,), dt)
        p["ffn"] = moe_mod.init_moe(ks[2], cfg)
    return p


def _rmsnorm(x, scale, cfg: ModelConfig):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt((xf * xf).mean(-1, keepdims=True) + 1e-6)
    s = scale.astype(jnp.float32)
    if cfg.rms_scale_plus_one:
        s = 1.0 + s
    return (y * s).astype(x.dtype)


def _apply_sublayer(p, cfg: ModelConfig, desc, x, *, positions, causal,
                    cache=None, cache_pos=None, enc_out=None):
    mixer, ffn = desc
    new_cache: Dict[str, Any] = {}
    h = _rmsnorm(x, p["norm1"], cfg)
    if mixer == "attn":
        a, nc = attn.attention_apply(
            p["mixer"], cfg, h, positions=positions, causal=causal,
            cache=None if cache is None else cache.get("self"),
            cache_pos=cache_pos)
        if nc is not None:
            new_cache["self"] = nc
    else:
        a, nc = mamba_mod.mamba_apply(
            p["mixer"], cfg, h,
            cache=None if cache is None else cache.get("self"),
            cache_pos=cache_pos)
        if nc is not None:
            new_cache["self"] = nc
    x = constrain(x + a, "btd")
    if "cross" in p:
        h = _rmsnorm(x, p["norm_x"], cfg)
        if enc_out is not None:  # (re)compute K/V from encoder output
            c, nc = attn.attention_apply(
                p["cross"], cfg, h, kv_source=enc_out, causal=False,
                cross=True, cache={} if cache is not None else None)
        else:  # decode: use precomputed cross K/V
            c, nc = attn.attention_apply(
                p["cross"], cfg, h, causal=False, cross=True,
                cache=cache.get("cross"))
        if nc is not None:
            new_cache["cross"] = nc
        x = x + c
    aux = jnp.zeros((), jnp.float32)
    if ffn in ("dense", "dense_first"):
        x = x + ffn_mod.ffn_apply(p["ffn"], cfg, _rmsnorm(x, p["norm2"], cfg))
    elif ffn == "moe":
        y, aux = moe_mod.moe_apply(p["ffn"], cfg, _rmsnorm(x, p["norm2"], cfg))
        x = x + y
    if ffn != "none":
        x = constrain(x, "btd")
    return x, (new_cache if new_cache else None), aux


# ------------------------------------------------------------------- model
def init_model(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 8)
    dt = cfg.jnp_dtype
    p: Dict[str, Any] = {
        "embed": normal_init(ks[0], (cfg.vocab_size, cfg.d_model), dt, 0.02),
        "final_norm": jnp.ones((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = normal_init(ks[1], (cfg.d_model, cfg.vocab_size), dt,
                                   cfg.d_model ** -0.5)
    # unscanned head layers (deepseek dense first layer)
    for i in range(cfg.n_head_layers):
        p[f"head{i}"] = _init_sublayer(
            jax.random.fold_in(ks[2], i), cfg,
            cfg.layer_desc(0, is_head_layer=True))
    # scanned body: per-period param stacks
    descs = cfg.period_descs
    cross = cfg.arch_type == "encdec"

    def init_period(pkey):
        kk = jax.random.split(pkey, len(descs))
        return {f"sub{i}": _init_sublayer(kk[i], cfg, d, cross=cross)
                for i, d in enumerate(descs)}

    period_keys = jax.random.split(ks[3], cfg.n_periods)
    p["body"] = jax.vmap(init_period)(period_keys)

    if cfg.arch_type == "encdec":
        enc_cfg = cfg  # same dims for encoder

        def init_enc_layer(lkey):
            return _init_sublayer(lkey, enc_cfg, ("attn", "dense"))

        p["encoder"] = jax.vmap(init_enc_layer)(
            jax.random.split(ks[4], cfg.n_enc_layers))
        p["enc_norm"] = jnp.ones((cfg.d_model,), dt)
    return p


def _embed(params, cfg: ModelConfig, tokens, prefix_embeds=None):
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.emb_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    return constrain(x, "btd")


def _encoder_apply(params, cfg: ModelConfig, enc_in):
    """Bidirectional encoder over stub frame embeddings (seamless)."""

    def body(x, lp):
        x, _, _ = _apply_sublayer(lp, cfg, ("attn", "dense"), x,
                                  positions=None, causal=False)
        return x, None

    x, _ = jax.lax.scan(jax.checkpoint(body), enc_in.astype(cfg.jnp_dtype),
                        params["encoder"])
    return _rmsnorm(x, params["enc_norm"], cfg)


def _remat_wrap(body, remat):
    """remat: True (full), False/None (off), or a named policy string.

    'dots' keeps matmul outputs resident (recompute only elementwise ops in
    the backward pass) — trades HBM for a ~25% cut in backward recompute
    FLOPs; a §Perf iteration knob.
    """
    if remat is True:
        return jax.checkpoint(body)
    if remat == "dots":
        return jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return body


def _run_body(params, cfg: ModelConfig, x, *, positions, causal=True,
              cache=None, cache_pos=None, enc_out=None, remat=True):
    """Head layers + scanned periods. Returns (x, new_cache, aux_sum)."""
    descs = cfg.period_descs
    aux_total = jnp.zeros((), jnp.float32)
    new_head_caches = {}
    for i in range(cfg.n_head_layers):
        hc = None if cache is None else cache.get(f"head{i}")
        x, nc, aux = _apply_sublayer(
            params[f"head{i}"], cfg, cfg.layer_desc(0, is_head_layer=True), x,
            positions=positions, causal=causal, cache=hc, cache_pos=cache_pos,
            enc_out=enc_out)
        aux_total = aux_total + aux
        if nc is not None:
            new_head_caches[f"head{i}"] = nc

    def body(carry, inputs):
        x, aux_acc = carry
        if cache is None:
            lp, lc = inputs, None
        else:
            lp, lc = inputs
        ncs = {}
        for i, d in enumerate(descs):
            sub_cache = None if lc is None else lc[f"sub{i}"]
            x, nc, aux = _apply_sublayer(
                lp[f"sub{i}"], cfg, d, x, positions=positions, causal=causal,
                cache=sub_cache, cache_pos=cache_pos, enc_out=enc_out)
            aux_acc = aux_acc + aux
            if nc is not None:
                ncs[f"sub{i}"] = nc
        return (x, aux_acc), (ncs if ncs else None)

    body_fn = _remat_wrap(body, remat)
    xs = params["body"] if cache is None else (params["body"], cache["body"])
    (x, aux_total), body_caches = jax.lax.scan(body_fn, (x, aux_total), xs)

    new_cache = None
    if cache is not None:
        new_cache = dict(cache)
        new_cache.update(new_head_caches)
        if body_caches is not None:
            new_cache["body"] = body_caches
    return x, new_cache, aux_total


def _logits(params, cfg: ModelConfig, x):
    x = _rmsnorm(x, params["final_norm"], cfg)
    if cfg.tie_embeddings:
        out = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        out = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    return constrain(out, "btv")


def forward_train(params, cfg: ModelConfig, tokens, *,
                  prefix_embeds=None, enc_in=None, remat=True):
    """Teacher-forced forward. Returns (logits, aux_loss)."""
    x = _embed(params, cfg, tokens, prefix_embeds)
    b, s = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    enc_out = None
    if cfg.arch_type == "encdec":
        enc_out = _encoder_apply(params, cfg, enc_in)
    x, _, aux = _run_body(params, cfg, x, positions=positions, causal=True,
                          enc_out=enc_out, remat=remat)
    if prefix_embeds is not None:  # logits only over the token suffix
        x = x[:, prefix_embeds.shape[1]:]
    return _logits(params, cfg, x), aux


def loss_fn(params, cfg: ModelConfig, batch, *, remat=True):
    """Next-token CE (+ MoE aux). batch: tokens (B,S) [+ prefix/enc stubs]."""
    tokens = batch["tokens"]
    logits, aux = forward_train(
        params, cfg, tokens, prefix_embeds=batch.get("prefix_embeds"),
        enc_in=batch.get("enc_in"), remat=remat)
    logits = logits[:, :-1].astype(jnp.float32)
    labels = tokens[:, 1:]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = (logz - gold).mean()
    return ce + 0.01 * aux, {"ce": ce, "aux": aux}


# ----------------------------------------------------------------- serving
def make_cache(cfg: ModelConfig, batch: int, max_len: int,
               enc_len: int = 0) -> dict:
    """Full decode cache pytree (period-stacked for the scanned body)."""
    descs = cfg.period_descs
    cross = cfg.arch_type == "encdec"

    def one_sub(desc):
        mixer, _ = desc
        c = {}
        if mixer == "attn":
            c["self"] = attn.make_kv_cache(cfg, batch, max_len)
        else:
            c["self"] = mamba_mod.make_mamba_cache(cfg, batch)
        if cross:
            src_len = enc_len or max_len
            c["cross"] = {"k": jnp.zeros(
                (batch, src_len, cfg.n_kv_heads, cfg.head_dim),
                cfg.jnp_dtype), "v": jnp.zeros(
                (batch, src_len, cfg.n_kv_heads, cfg.head_dim),
                cfg.jnp_dtype)}
        return c

    period_cache = {f"sub{i}": one_sub(d) for i, d in enumerate(descs)}
    body = jax.tree_util.tree_map(
        lambda a: (jnp.broadcast_to(a, (cfg.n_periods,) + a.shape)
                   if isinstance(a, jnp.ndarray) else a), period_cache)
    cache = {"body": body}
    for i in range(cfg.n_head_layers):
        cache[f"head{i}"] = one_sub(cfg.layer_desc(0, is_head_layer=True))
    return cache


def prefill(params, cfg: ModelConfig, tokens, cache, *,
            prefix_embeds=None, enc_in=None):
    """Run the prompt through the model, filling the cache.

    Returns (last_token_logits, cache). ``cache`` KV length == prompt length
    (the dry-run prefill cells size it so).
    """
    x = _embed(params, cfg, tokens, prefix_embeds)
    b, s = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    enc_out = None
    if cfg.arch_type == "encdec":
        enc_out = _encoder_apply(params, cfg, enc_in)
    x, new_cache, _ = _run_body(params, cfg, x, positions=positions,
                                causal=True, cache=cache, cache_pos=0,
                                enc_out=enc_out)
    return _logits(params, cfg, x[:, -1:]), new_cache


def decode_step(params, cfg: ModelConfig, token, cache, pos):
    """One decode step. token: (B, 1) int32; pos: scalar int32 position."""
    x = _embed(params, cfg, token)
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    x, new_cache, _ = _run_body(params, cfg, x, positions=positions,
                                causal=True, cache=cache, cache_pos=pos,
                                remat=False)
    return _logits(params, cfg, x), new_cache
