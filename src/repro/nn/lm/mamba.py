"""Mamba-1 selective SSM block (falcon-mamba, jamba hybrid layers).

Train/prefill: chunked selective scan — ``lax.scan`` over sequence chunks
carrying the (B, d_inner, d_state) SSM state, with a parallel associative
scan *inside* each chunk. This bounds the activation working set to
O(chunk) while keeping log-depth parallelism within chunks (the TPU-friendly
middle ground between a pure sequential scan and a full-sequence associative
scan whose O(S) blowup would sink the 500k cells).

Decode: O(1) single-step recurrence on (conv_state, ssm_state) — this is why
``long_500k`` is trivially cheap for SSM archs (the "KV cache" is the state).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.nn.lm.config import ModelConfig
from repro.nn.module import normal_init

CHUNK = 256


def init_mamba(key, cfg: ModelConfig):
    d, di = cfg.d_model, cfg.d_inner
    st, dc, dr = cfg.mamba.d_state, cfg.mamba.d_conv, cfg.dt_rank
    dt = cfg.jnp_dtype
    ks = jax.random.split(key, 6)
    # S4D-real initialisation for A
    a_init = jnp.tile(jnp.arange(1, st + 1, dtype=jnp.float32)[None], (di, 1))
    return {
        "in_proj": normal_init(ks[0], (d, 2, di), dt, d ** -0.5),
        "conv_w": normal_init(ks[1], (dc, di), dt, dc ** -0.5),
        "conv_b": jnp.zeros((di,), dt),
        "x_proj": normal_init(ks[2], (di, dr + 2 * st), dt, di ** -0.5),
        "dt_proj_w": normal_init(ks[3], (dr, di), dt, dr ** -0.5),
        "dt_proj_b": jnp.asarray(
            jnp.log(jnp.expm1(jnp.exp(jax.random.uniform(
                ks[4], (di,), jnp.float32, jnp.log(1e-3), jnp.log(1e-1))))),
            dt),
        "A_log": jnp.log(a_init),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": normal_init(ks[5], (di, d), dt, di ** -0.5),
    }


def _ssm_chunked(u, delta, B, C, A, D, init_state):
    """Selective scan. u/delta: (b, s, di); B/C: (b, s, st); A: (di, st)."""
    b, s, di = u.shape
    st = B.shape[-1]
    nchunks = s // CHUNK if s % CHUNK == 0 and s > CHUNK else 1
    chunk = s // nchunks

    da = jnp.exp(delta[..., None] * (-jnp.exp(A))[None, None])  # (b,s,di,st)
    dbu = (delta * u)[..., None] * B[:, :, None, :]              # (b,s,di,st)

    def chunk_step(h0, blk):
        da_c, dbu_c = blk  # (chunk, b, di, st)

        def combine(x, y):
            a1, b1 = x
            a2, b2 = y
            return a1 * a2, b1 * a2 + b2

        aa, bb = jax.lax.associative_scan(combine, (da_c, dbu_c), axis=0)
        h = aa * h0[None] + bb  # (chunk, b, di, st)
        return h[-1], h

    da_t = jnp.moveaxis(da, 1, 0).reshape(nchunks, chunk, b, di, st)
    dbu_t = jnp.moveaxis(dbu, 1, 0).reshape(nchunks, chunk, b, di, st)
    last, hs = jax.lax.scan(chunk_step, init_state, (da_t, dbu_t))
    hs = jnp.moveaxis(hs.reshape(s, b, di, st), 0, 1)  # (b, s, di, st)
    y = jnp.einsum("bsdn,bsn->bsd", hs, C) + u * D[None, None]
    return y, last


def mamba_apply(params, cfg: ModelConfig, x: jnp.ndarray, *,
                cache: Optional[dict] = None,
                cache_pos: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, Optional[dict]]:
    b, s, d = x.shape
    di, st = cfg.d_inner, cfg.mamba.d_state
    dc, dr = cfg.mamba.d_conv, cfg.dt_rank

    zu = jnp.einsum("bsd,dgi->bsgi", x, params["in_proj"])
    u, z = zu[:, :, 0, :], zu[:, :, 1, :]  # (b, s, di)
    u, z = constrain(u, "btf"), constrain(z, "btf")

    if cache is not None and s == 1:
        # ---- decode: O(1) state update
        conv_state = cache["conv"]  # (b, dc-1, di)
        window = jnp.concatenate([conv_state, u], axis=1)  # (b, dc, di)
        uc = jnp.einsum("bkd,kd->bd", window, params["conv_w"]) + params["conv_b"]
        uc = jax.nn.silu(uc)[:, None]  # (b, 1, di)
        new_conv = window[:, 1:]
        xdbc = jnp.einsum("bsi,ij->bsj", uc, params["x_proj"])
        dt_r, B, C = jnp.split(xdbc, [dr, dr + st], axis=-1)
        delta = jax.nn.softplus(
            jnp.einsum("bsr,ri->bsi", dt_r, params["dt_proj_w"])
            + params["dt_proj_b"])
        A = params["A_log"]
        da = jnp.exp(delta[..., None] * (-jnp.exp(A))[None, None])[:, 0]  # (b, di, st)
        dbu = ((delta * uc)[..., None] * B[:, :, None, :])[:, 0]
        h = cache["ssm"] * da.astype(jnp.float32) + dbu.astype(jnp.float32)
        y = (jnp.einsum("bdn,bn->bd", h, C[:, 0].astype(jnp.float32))
             + uc[:, 0].astype(jnp.float32) * params["D"][None])
        y = y[:, None].astype(x.dtype)
        new_cache = dict(cache)
        new_cache.update(conv=new_conv.astype(cache["conv"].dtype), ssm=h)
    else:
        # ---- train/prefill: causal depthwise conv + chunked scan
        upad = jnp.pad(u, ((0, 0), (dc - 1, 0), (0, 0)))
        uc = jax.lax.conv_general_dilated(
            upad.astype(jnp.float32),
            params["conv_w"].astype(jnp.float32)[:, None, :],  # (k, 1, di)
            window_strides=(1,), padding="VALID",
            dimension_numbers=("NWC", "WIO", "NWC"),
            feature_group_count=di) + params["conv_b"].astype(jnp.float32)
        uc = jax.nn.silu(uc).astype(x.dtype)
        xdbc = jnp.einsum("bsi,ij->bsj", uc, params["x_proj"])
        dt_r, B, C = jnp.split(xdbc, [dr, dr + st], axis=-1)
        delta = jax.nn.softplus(
            jnp.einsum("bsr,ri->bsi", dt_r, params["dt_proj_w"]).astype(jnp.float32)
            + params["dt_proj_b"].astype(jnp.float32))
        init_state = (cache["ssm"] if cache is not None
                      else jnp.zeros((b, di, st), jnp.float32))
        y, last = _ssm_chunked(
            uc.astype(jnp.float32), delta,
            B.astype(jnp.float32), C.astype(jnp.float32),
            params["A_log"], params["D"], init_state)
        y = y.astype(x.dtype)
        if cache is not None:
            # the conv window holds *raw* (pre-conv) activations
            new_cache = dict(cache)
            new_cache.update(conv=u[:, s - (dc - 1):, :] if s >= dc - 1
                             else cache["conv"], ssm=last)
        else:
            new_cache = None

    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, params["out_proj"]).astype(x.dtype)
    return out, new_cache


def make_mamba_cache(cfg: ModelConfig, batch: int, dtype=None) -> dict:
    dt = dtype or cfg.jnp_dtype
    return {
        "conv": jnp.zeros((batch, cfg.mamba.d_conv - 1, cfg.d_inner), dt),
        "ssm": jnp.zeros((batch, cfg.d_inner, cfg.mamba.d_state), jnp.float32),
    }
