"""Model configuration dataclasses for the assigned LM-family architectures."""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                 # hidden dim of each routed expert
    num_shared: int = 0           # DeepSeekMoE shared experts
    dense_residual: bool = False  # Arctic: dense FFN in parallel with MoE
    moe_every: int = 1            # MoE FFN every k-th layer (Jamba: 2)
    first_dense_ff: int = 0       # DeepSeekMoE: layer 0 uses a dense FFN
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    act: str = "silu"             # 'silu' -> SwiGLU, 'gelu' -> GeGLU
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1_000_000.0
    tie_embeddings: bool = False
    emb_scale: bool = False       # gemma: embeddings scaled by sqrt(d_model)
    rms_scale_plus_one: bool = False  # gemma RMSNorm (1 + w)
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    # attention layout: None -> every layer is attention; 0 -> attention-free
    # (pure SSM); k>1 -> one attention layer per k layers (hybrid).
    attn_every: Optional[int] = None
    attn_offset: int = 0          # index of the attn layer within the period
    arch_type: str = "decoder"    # 'decoder' | 'encdec'
    n_enc_layers: int = 0
    n_prefix_embeds: int = 0      # VLM patch / audio frame stub inputs
    max_seq_len: int = 8192
    dtype: str = "bfloat16"
    # families for shape handling
    family: str = "dense"         # dense | moe | hybrid | ssm | audio | vlm
    subquadratic: bool = False    # True -> long_500k applicable

    # ---------------------------------------------------------------- derived
    @property
    def jnp_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        return (self.mamba.expand * self.d_model) if self.mamba else 0

    @property
    def dt_rank(self) -> int:
        if not self.mamba:
            return 0
        return self.mamba.dt_rank or math.ceil(self.d_model / 16)

    @property
    def period_len(self) -> int:
        a = self.attn_every if (self.attn_every or 0) > 1 else 1
        m = self.moe.moe_every if (self.moe and self.moe.moe_every > 1) else 1
        return math.lcm(a, m)

    @property
    def n_head_layers(self) -> int:
        """Unscanned prefix layers (DeepSeekMoE dense first layer)."""
        return 1 if (self.moe and self.moe.first_dense_ff) else 0

    @property
    def n_periods(self) -> int:
        body = self.n_layers - self.n_head_layers
        assert body % self.period_len == 0, (self.name, body, self.period_len)
        return body // self.period_len

    def layer_desc(self, idx_in_period: int, is_head_layer: bool = False
                   ) -> Tuple[str, str]:
        """(mixer, ffn) descriptor for a layer position."""
        if is_head_layer:  # DeepSeekMoE layer 0: dense FFN
            return ("attn", "dense_first")
        if self.attn_every == 0:
            mixer = "mamba"
        elif self.attn_every is None or self.attn_every == 1:
            mixer = "attn"
        else:
            mixer = "attn" if idx_in_period % self.attn_every == self.attn_offset else "mamba"
        if self.d_ff == 0:
            ffn = "none"
        elif self.moe is None:
            ffn = "dense"
        else:
            ffn = "moe" if idx_in_period % self.moe.moe_every == (
                self.moe.moe_every - 1 if self.moe.moe_every > 1 else 0) else "dense"
        return (mixer, ffn)

    @property
    def period_descs(self) -> List[Tuple[str, str]]:
        return [self.layer_desc(i) for i in range(self.period_len)]

    def param_count(self) -> int:
        """Analytic parameter count (cross-checked against the real pytree)."""
        d, v = self.d_model, self.vocab_size
        total = v * d  # embed
        if not self.tie_embeddings:
            total += v * d
        total += d  # final norm

        def ffn_params(ff):
            return d * ff * 2 + ff * d  # gated: w_in(gate+up) + w_out

        def attn_params():
            p = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            if self.qkv_bias:
                p += self.q_dim + 2 * self.kv_dim
            if self.qk_norm:
                p += 2 * self.head_dim
            return p + d  # pre-norm

        def mamba_params():
            di, st, dr = self.d_inner, self.mamba.d_state, self.dt_rank
            return (d * 2 * di + di * self.mamba.d_conv + di
                    + di * (dr + 2 * st) + dr * di + di
                    + di * st + di + di * d + d)

        def moe_params():
            m = self.moe
            p = d * m.num_experts  # router
            p += m.num_experts * ffn_params(m.d_expert)
            p += m.num_shared * ffn_params(m.d_expert)
            if m.dense_residual:
                p += ffn_params(self.d_ff)
            return p

        layers = []
        if self.n_head_layers:
            layers.append(("attn", "dense_first"))
        layers += self.period_descs * self.n_periods
        for mixer, ffn in layers:
            total += attn_params() if mixer == "attn" else mamba_params()
            if ffn == "dense":
                total += ffn_params(self.d_ff) + d
            elif ffn == "dense_first":
                total += ffn_params(self.moe.first_dense_ff) + d
            elif ffn == "moe":
                total += moe_params() + d
        if self.arch_type == "encdec":
            # encoder layers: self-attn + dense ffn; decoder adds cross-attn
            total += self.n_enc_layers * (attn_params() + ffn_params(self.d_ff) + d)
            total += d  # encoder final norm
            # cross-attn blocks: attn weights + norm_x (the +d inside
            # attn_params covers it)
            total += self.n_layers * attn_params()
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared + dense residual)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        m = self.moe

        def ffn_params(ff):
            return d * ff * 3

        full = self.param_count()
        inactive_per_moe_layer = (m.num_experts - m.top_k) * ffn_params(m.d_expert)
        n_moe_layers = sum(1 for desc in self.period_descs * self.n_periods
                           if desc[1] == "moe")
        return full - n_moe_layers * inactive_per_moe_layer
