"""GQA attention with qk-norm / QKV-bias / RoPE and KV-cache decode.

Sharding: q/k/v projections keep an explicit (heads, head_dim) split so the
head axis can be tensor-parallel over the mesh ``model`` axis; GSPMD pads
uneven head counts. The full/prefill path dispatches to the chunked
(flash-style) attention for long KV so 32k cells compile with O(block)
working sets; decode attends one token against the cache with absolute-
position causal masking (garbage slots beyond ``cache_pos`` are masked as
"future").
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.kernels.flash_attention import ops as attn_ops
from repro.nn.lm.config import ModelConfig
from repro.nn.lm.rope import apply_rope
from repro.nn.module import normal_init


def init_attention(key, cfg: ModelConfig, cross: bool = False):
    d, q_dim, kv_dim, hd = cfg.d_model, cfg.q_dim, cfg.kv_dim, cfg.head_dim
    dt = cfg.jnp_dtype
    ks = jax.random.split(key, 4)
    p = {
        "wq": normal_init(ks[0], (d, cfg.n_heads, hd), dt, d ** -0.5),
        "wk": normal_init(ks[1], (d, cfg.n_kv_heads, hd), dt, d ** -0.5),
        "wv": normal_init(ks[2], (d, cfg.n_kv_heads, hd), dt, d ** -0.5),
        "wo": normal_init(ks[3], (cfg.n_heads, hd, d), dt, q_dim ** -0.5),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((cfg.n_heads, hd), dt)
        p["bk"] = jnp.zeros((cfg.n_kv_heads, hd), dt)
        p["bv"] = jnp.zeros((cfg.n_kv_heads, hd), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dt)
        p["k_norm"] = jnp.ones((hd,), dt)
    return p


def _rms(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt((xf * xf).mean(-1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def attention_apply(
    params, cfg: ModelConfig, x: jnp.ndarray, *,
    positions: Optional[jnp.ndarray] = None,
    causal: bool = True,
    cache: Optional[dict] = None,
    cache_pos: Optional[jnp.ndarray] = None,
    kv_source: Optional[jnp.ndarray] = None,
    use_rope: bool = True,
    cross: bool = False,
) -> Tuple[jnp.ndarray, Optional[dict]]:
    """Self- or cross-attention.

    Modes:
      * train/full:   cache=None                     -> (out, None)
      * prefill:      cache=zeros, cache_pos=0       -> (out, filled cache)
      * decode:       cache=state, cache_pos=t       -> (out, updated cache)
      * cross decode: kv_source=None + cache holds precomputed enc K/V
    """
    b, s, d = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    if "bq" in params:
        q = q + params["bq"]
    if cfg.qk_norm:
        q = _rms(q, params["q_norm"])
    q = constrain(q, "bshd")

    def project_kv(src):
        k = jnp.einsum("bsd,dhk->bshk", src, params["wk"])
        v = jnp.einsum("bsd,dhk->bshk", src, params["wv"])
        if "bk" in params:
            k, v = k + params["bk"], v + params["bv"]
        if cfg.qk_norm:
            k = _rms(k, params["k_norm"])
        return constrain(k, "bshd"), constrain(v, "bshd")

    is_cross = cross or kv_source is not None

    if is_cross and kv_source is None:
        # decode-time cross attention: K/V precomputed at prefill
        k, v = cache["k"], cache["v"]
        out = attn_ops.attention(q, k, v, causal=False)
        new_cache = cache
    else:
        src = kv_source if is_cross else x
        k, v = project_kv(src)
        if use_rope and not is_cross:
            if positions is None:
                positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
        if cache is None:
            q_off = 0
            out = attn_ops.attention(q, k, v, causal=causal and not is_cross,
                                     q_offset=q_off)
            new_cache = None
        elif is_cross or s == cache["k"].shape[1]:
            # prefill: write-through; attention over the fresh K/V directly
            new_cache = dict(cache)
            if is_cross:
                new_cache.update(k=k, v=v)
                out = attn_ops.attention(q, k, v, causal=False)
            else:
                kc = jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, 0, 0))
                vc = jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, 0, 0))
                new_cache.update(k=kc, v=vc)
                out = attn_ops.attention(q, k, v, causal=causal)
        else:
            # decode: insert at cache_pos, attend over the whole cache with
            # absolute-position masking
            t = cache_pos
            kc = jax.lax.dynamic_update_slice(
                cache["k"], k, (0, t, 0, 0))
            vc = jax.lax.dynamic_update_slice(
                cache["v"], v, (0, t, 0, 0))
            new_cache = dict(cache)
            new_cache.update(k=kc, v=vc)
            out = _decode_attention(q, kc, vc, t)
    o = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return o, new_cache


def _decode_attention(q, k, v, cache_pos):
    """One-token attention against a (B, Smax, Hkv, D) cache.

    Explicit masked einsum (not the chunked path): with Sq == 1 the logits
    tensor is (B, H, 1, Smax) — linear in Smax, no need for blocking.
    """
    b, sq, h, hd = q.shape
    hkv = k.shape[2]
    rep = h // hkv
    scale = hd ** -0.5
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    kpos = jnp.arange(k.shape[1])
    mask = kpos[None, None, None, :] <= (cache_pos + jnp.arange(sq))[None, None, :, None]
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
    return out.astype(q.dtype)


def make_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  dtype=None) -> dict:
    dt = dtype or cfg.jnp_dtype
    return {
        "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.head_dim), dt),
        "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.head_dim), dt),
    }
