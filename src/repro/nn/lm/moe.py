"""Mixture-of-Experts FFN: sort-based dispatch + batched expert GEMMs.

This is the paper-technique crossover point (DESIGN.md §4): expert compute is
the *grouped matmul* of PyG's heterogeneous projections (C4) and token
dispatch is the *sort + segment* machinery of accelerated message passing
(C2) — tokens scatter to experts exactly as messages scatter to destination
nodes, with the paper's sort-order insight providing contiguity.

Dispatch (per jit-global batch):
  1. router logits -> top-k (gates, expert ids)
  2. flatten to (T*k) assignments, sort by expert id (stable)
  3. position-in-expert via exclusive-cumsum offsets; drop beyond capacity C
  4. scatter tokens into an (E, C, d) buffer     [GSPMD: all-to-all when the
     token axis is data-sharded and E is model-sharded]
  5. batched expert GLU-FFN: (E,C,d) x (E,d,2,f) -> (E,C,d)   [MXU-dense]
  6. gather back, weight by gates, sum over k

Variants: DeepSeekMoE shared experts (always-on), Arctic dense residual.
Aux output: switch-style load-balance loss.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.nn.lm.config import ModelConfig, MoEConfig
from repro.nn.lm.ffn import _ACTS, ffn_apply, init_ffn
from repro.nn.module import normal_init


def init_moe(key, cfg: ModelConfig):
    m = cfg.moe
    d, dt = cfg.d_model, cfg.jnp_dtype
    ks = jax.random.split(key, 5)
    p = {
        "router": normal_init(ks[0], (d, m.num_experts), jnp.float32, d ** -0.5),
        "w_in": normal_init(ks[1], (m.num_experts, d, 2, m.d_expert), dt,
                            d ** -0.5),
        "w_out": normal_init(ks[2], (m.num_experts, m.d_expert, d), dt,
                             m.d_expert ** -0.5),
    }
    if m.num_shared:
        p["shared"] = init_ffn(ks[3], cfg, d_ff=m.num_shared * m.d_expert)
    if m.dense_residual:
        p["dense"] = init_ffn(ks[4], cfg, d_ff=cfg.d_ff)
    return p


def _capacity(tokens: int, m: MoEConfig) -> int:
    c = int(tokens * m.top_k / m.num_experts * m.capacity_factor)
    # MXU-align large (training/prefill) capacities; decode-sized batches
    # use 8-row sublane alignment instead — the 128 floor was wasting up to
    # 16x expert FLOPs at decode (§Roofline: MoE decode useful_ratio 0.06)
    if tokens >= 16_384:
        return max(((c + 127) // 128) * 128, 128)
    return max(((c + 7) // 8) * 8, 8)


# Dispatch implementation, switchable at trace time (§Perf iteration knob):
# 'scatter' — buf.at[slot].add / out.at[token].add (baseline)
# 'gather'  — argsort-inverse index tables; both directions become gathers,
#             which GSPMD reshards with all-to-all instead of replicating
#             scatter operands.
_MOE_IMPL = "scatter"


def set_moe_impl(impl: str):
    global _MOE_IMPL
    assert impl in ("scatter", "gather")
    _MOE_IMPL = impl


def moe_apply(params, cfg: ModelConfig, x: jnp.ndarray
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (out, aux_loss)."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    e, k = m.num_experts, m.top_k
    xf = x.reshape(t, d)

    logits = constrain(xf.astype(jnp.float32) @ params["router"], "te")
    probs = constrain(jax.nn.softmax(logits, axis=-1), "te")
    gates, ids = jax.lax.top_k(probs, k)  # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # --- sort-based dispatch (C2 machinery)
    flat_ids = ids.reshape(-1)                      # (T*k,)
    flat_gates = gates.reshape(-1)
    order = jnp.argsort(flat_ids, stable=True)      # sort by expert
    sorted_experts = flat_ids[order]
    token_of = order // k                           # source token per slot
    # scatter-free per-expert histogram: binary search over the sorted ids
    # (§Perf: the .at[].add scatter forced a replicated all-reduce per layer)
    starts = jnp.searchsorted(sorted_experts,
                              jnp.arange(e + 1, dtype=flat_ids.dtype),
                              side="left").astype(jnp.int32)
    counts = starts[1:] - starts[:-1]
    offsets = starts[:-1]                           # exclusive cumsum

    # --- load-balance aux (Switch): E * sum_e f_e * p_e
    me = probs.mean(0)
    ce = counts.astype(jnp.float32) / (t * k)
    aux = e * jnp.sum(me * ce)
    pos_in_e = jnp.arange(t * k, dtype=jnp.int32) - offsets[sorted_experts]
    cap = _capacity(t, m)
    keep = pos_in_e < cap
    slot = sorted_experts * cap + jnp.where(keep, pos_in_e, 0)

    if _MOE_IMPL == "gather":
        # slot -> assignment table built arithmetically (scatter-free):
        # slot (e_i, c) holds the assignment at sorted position
        # offsets[e_i] + c, valid iff c < counts[e_i]. All data movement is
        # gathers, which GSPMD reshards with all-to-alls instead of the
        # replicated-scatter fallback.
        e_idx = jnp.arange(e * cap, dtype=jnp.int32) // cap
        c_idx = jnp.arange(e * cap, dtype=jnp.int32) % cap
        sorted_pos = offsets[e_idx] + c_idx
        slot_valid = (c_idx < counts[e_idx]) & (sorted_pos < t * k)
        assignment = jnp.take(order, jnp.minimum(sorted_pos, t * k - 1))
        buf = jnp.where(
            slot_valid[:, None],
            jnp.take(xf, assignment // k, axis=0), 0).astype(x.dtype)
    else:
        buf = jnp.zeros((e * cap, d), x.dtype)
        buf = buf.at[slot].add(
            jnp.where(keep[:, None], xf[token_of], 0).astype(x.dtype))
    buf = constrain(buf.reshape(e, cap, d), "ecd")

    # --- batched expert GLU (grouped matmul, MXU-dense per expert)
    act = _ACTS[cfg.act]
    gu = jnp.einsum("ecd,edgf->ecgf", buf, params["w_in"])
    h = act(gu[:, :, 0, :]) * gu[:, :, 1, :]
    out_e = constrain(
        jnp.einsum("ecf,efd->ecd", h, params["w_out"]), "ecd"
    ).reshape(e * cap, d)

    if _MOE_IMPL == "gather":
        # combine: token t's k expert outputs live at slots slot[inv[t,k]]
        inv = jnp.argsort(order, stable=True)       # (T*k,) assignment->sorted
        tok_slots = slot[inv].reshape(t, k)
        tok_keep = keep[inv].reshape(t, k)
        picked = jnp.take(out_e, tok_slots.reshape(-1), axis=0).reshape(
            t, k, d)
        y = (picked * (gates * tok_keep).astype(x.dtype)[..., None]).sum(1)
    else:
        back = out_e[slot] * (flat_gates[order] * keep)[:, None].astype(
            x.dtype)
        y = jnp.zeros((t, d), x.dtype).at[token_of].add(back)

    if "shared" in params:
        y = y + ffn_apply(params["shared"], cfg, xf)
    if "dense" in params:
        y = y + ffn_apply(params["dense"], cfg, xf)
    return y.reshape(b, s, d), aux
