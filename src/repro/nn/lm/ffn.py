"""Gated FFN (SwiGLU / GeGLU) — the dense MLP used by every assigned arch."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.lm.config import ModelConfig
from repro.nn.module import normal_init

_ACTS = {"silu": jax.nn.silu, "gelu": lambda x: jax.nn.gelu(x, approximate=True)}


def init_ffn(key, cfg: ModelConfig, d_ff: int = 0):
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    dt = cfg.jnp_dtype
    k1, k2 = jax.random.split(key)
    return {
        # gate & up fused along the last axis -> one matmul
        "w_in": normal_init(k1, (d, 2, ff), dt, d ** -0.5),
        "w_out": normal_init(k2, (ff, d), dt, ff ** -0.5),
    }


def ffn_apply(params, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    act = _ACTS[cfg.act]
    gu = jnp.einsum("...d,dgf->...gf", x, params["w_in"])
    gate, up = gu[..., 0, :], gu[..., 1, :]
    return jnp.einsum("...f,fd->...d", act(gate) * up, params["w_out"])
