"""Heterogeneous message passing — paper C4 (§2.2).

A heterogeneous graph (V, E, phi, psi) gets a *nested* version of Eq. (1):
per-edge-type bipartite message passing, then an aggregation across incoming
edge types per destination node type. ``to_hetero`` replicates any
homogeneous GNN per edge type (the torch.fx transform of the paper, done
here by functional replication — parameters are duplicated per relation and
the computation graph rewired to bipartite propagate + group aggregation).

``GroupedLinear`` exposes the paper's {H_T W_T} grouped projection backed by
the grouped-matmul Pallas kernel (kernels/grouped_matmul) — the same
primitive the MoE experts use (DESIGN.md §4).
"""

from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.edge_index import EdgeIndex
from repro.core.message_passing import MessagePassing
from repro.kernels.grouped_matmul import ops as gmm_ops
from repro.nn.module import Module, glorot_uniform

EdgeType = Tuple[str, str, str]


def _et_key(et: EdgeType) -> str:
    return "__".join(et)


class HeteroConv(Module):
    """One hetero layer: a conv per edge type + cross-type aggregation."""

    def __init__(self, convs: Dict[EdgeType, MessagePassing],
                 aggr: str = "sum"):
        self.convs = convs
        self.aggr = aggr

    def init(self, key):
        keys = jax.random.split(key, len(self.convs))
        return {_et_key(et): conv.init(k)
                for (et, conv), k in zip(self.convs.items(), keys)}

    def apply(self, params, x_dict: Dict[str, jnp.ndarray],
              edge_index_dict: Dict[EdgeType, jnp.ndarray],
              num_nodes_dict: Optional[Dict[str, int]] = None,
              **kwargs) -> Dict[str, jnp.ndarray]:
        if num_nodes_dict is None:
            num_nodes_dict = {t: x.shape[0] for t, x in x_dict.items()}
        grouped: Dict[str, List[jnp.ndarray]] = {}
        for et, conv in self.convs.items():
            if et not in edge_index_dict:
                continue
            src_t, _, dst_t = et
            out = conv.apply(
                params[_et_key(et)],
                (x_dict[src_t], x_dict[dst_t]),
                edge_index_dict[et],
                num_nodes=num_nodes_dict[dst_t], **kwargs)
            grouped.setdefault(dst_t, []).append(out)
        out_dict = {}
        for dst_t, outs in grouped.items():
            stacked = jnp.stack(outs)
            if self.aggr == "sum":
                out_dict[dst_t] = stacked.sum(0)
            elif self.aggr == "mean":
                out_dict[dst_t] = stacked.mean(0)
            elif self.aggr == "max":
                out_dict[dst_t] = stacked.max(0)
            else:
                out_dict[dst_t] = jnp.concatenate(outs, axis=-1)
        # node types with no incoming edges keep their features (valid only
        # when dims already match — otherwise the caller needs reverse edge
        # types, the PyG ToUndirected idiom)
        for t, x in x_dict.items():
            if t not in out_dict:
                dims = {o.shape[-1] for o in out_dict.values()}
                if dims and x.shape[-1] not in dims:
                    raise ValueError(
                        f"node type '{t}' receives no messages and its "
                        f"feature dim {x.shape[-1]} != layer output dims "
                        f"{dims}; add a reverse edge type for '{t}'")
                out_dict[t] = x
        return out_dict


class HeteroGNN(Module):
    """``to_hetero``'d stack: every layer replicated over all edge types."""

    def __init__(self, make_conv: Callable[[int, int], MessagePassing],
                 metadata: Tuple[Sequence[str], Sequence[EdgeType]],
                 dims: Sequence[int], aggr: str = "sum",
                 act=jax.nn.relu):
        node_types, edge_types = metadata
        self.node_types = list(node_types)
        self.edge_types = list(edge_types)
        self.layers = [
            HeteroConv({et: make_conv(dims[i], dims[i + 1])
                        for et in self.edge_types}, aggr=aggr)
            for i in range(len(dims) - 1)]
        self.act = act

    def init(self, key):
        keys = jax.random.split(key, len(self.layers))
        return {f"layer{i}": l.init(k)
                for i, (l, k) in enumerate(zip(self.layers, keys))}

    def apply(self, params, x_dict, edge_index_dict,
              num_nodes_dict=None, **kwargs):
        for i, layer in enumerate(self.layers):
            x_dict = layer.apply(params[f"layer{i}"], x_dict,
                                 edge_index_dict, num_nodes_dict, **kwargs)
            if i < len(self.layers) - 1:
                x_dict = {t: self.act(x) for t, x in x_dict.items()}
        return x_dict


def to_hetero(make_conv: Callable[[int, int], MessagePassing],
              metadata, dims: Sequence[int], aggr: str = "sum") -> HeteroGNN:
    """Replicate a homogeneous conv constructor across all edge types."""
    return HeteroGNN(make_conv, metadata, dims, aggr=aggr)


class GroupedLinear(Module):
    """{H_T W_T}: per-type projection via grouped GEMM (paper C4).

    Takes a dict of per-type features, packs rows type-sorted, runs one
    grouped matmul, and unpacks — O(1) kernel launches for |T| projections
    (the CUTLASS grouped-GEMM pattern, on the MXU via Pallas).
    """

    def __init__(self, types: Sequence[str], in_features: int,
                 out_features: int):
        self.types = list(types)
        self.in_features = in_features
        self.out_features = out_features

    def init(self, key):
        return {"w": glorot_uniform(
            key, (len(self.types), self.in_features, self.out_features))}

    def apply(self, params, x_dict: Dict[str, jnp.ndarray],
              force_pallas: Optional[bool] = None,
              interpret: bool = False) -> Dict[str, jnp.ndarray]:
        sizes = [x_dict[t].shape[0] for t in self.types]
        packed = jnp.concatenate([x_dict[t] for t in self.types], axis=0)
        out = gmm_ops.grouped_matmul(
            packed, params["w"], jnp.asarray(sizes, jnp.int32),
            force_pallas=force_pallas, interpret=interpret)
        outs = {}
        off = 0
        for t, s in zip(self.types, sizes):
            outs[t] = out[off:off + s]
            off += s
        return outs
