"""Heterogeneous message passing — paper C4 (§2.2).

A heterogeneous graph (V, E, phi, psi) gets a *nested* version of Eq. (1):
per-edge-type bipartite message passing, then an aggregation across incoming
edge types per destination node type. ``to_hetero`` replicates any
homogeneous GNN per edge type (the torch.fx transform of the paper, done
here by functional replication — parameters are duplicated per relation and
the computation graph rewired to bipartite propagate + group aggregation).

The serving path is *grouped*: when every replicated conv decomposes into
aggregate-then-project (``fused_projections``, e.g. ``SAGEConv``),
``HeteroConv`` runs each relation's aggregation as one SpMM (the blocked-ELL
Pallas fast path when the ``EdgeIndex`` carries a prefilled cache) and then
batches ALL per-relation projections — neighbor and root weights of every
edge type — into a single grouped matmul (one MXU launch instead of
2·|edge types| GEMMs), the same {H_T W_T} primitive the MoE experts use
(``kernels/grouped_matmul``, DESIGN.md §4). Cross-type aggregation
accumulates in place instead of materialising a stacked tensor.

Attention convs (``GATConv``) don't decompose into aggregate-then-project,
so they skip the grouped-projection path — but each relation's bipartite
``propagate`` still lowers to the *fused attention* kernel
(``EdgeIndex.attend`` over the loader-prefilled per-relation ELL caches),
so a hetero GAT keeps every relation on the Pallas fast path.

``GroupedLinear`` exposes the raw {H_T W_T} grouped projection for callers
that manage their own per-type features.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.edge_index import EdgeIndex
from repro.core.message_passing import MessagePassing
from repro.core.trim import trim_to_layer_hetero
from repro.kernels import use_pallas
from repro.kernels.grouped_matmul import ops as gmm_ops
from repro.nn.module import Module, glorot_uniform

EdgeType = Tuple[str, str, str]

_CROSS_TYPE_AGGRS = ("sum", "mean", "max", "min", "cat")


def _et_key(et: EdgeType) -> str:
    return "__".join(et)


class HeteroConv(Module):
    """One hetero layer: a conv per edge type + cross-type aggregation.

    ``aggr`` must be one of ``sum | mean | max | min | cat`` (``"cat"`` is
    the explicit concatenation mode; unknown strings raise instead of
    silently concatenating). ``grouped=None`` auto-selects the grouped
    projection path when Pallas dispatch is on (TPU backend or
    ``REPRO_USE_PALLAS=1`` — on CPU/GPU, |T| separate XLA GEMMs beat a
    ragged grouped dot) and every participating conv exposes
    ``fused_projections`` with uniform weight shapes over ``EdgeIndex``
    inputs; ``True``/``False`` force it on/off.
    """

    def __init__(self, convs: Dict[EdgeType, MessagePassing],
                 aggr: str = "sum", grouped: Optional[bool] = None):
        if aggr not in _CROSS_TYPE_AGGRS:
            raise ValueError(
                f"HeteroConv: unknown cross-type aggr '{aggr}'; expected one "
                f"of {_CROSS_TYPE_AGGRS} (use 'cat' for concatenation)")
        self.convs = convs
        self.aggr = aggr
        self.grouped = grouped

    def init(self, key):
        keys = jax.random.split(key, len(self.convs))
        return {_et_key(et): conv.init(k)
                for (et, conv), k in zip(self.convs.items(), keys)}

    # ---------------------------------------------------------------- grouped
    def _grouped_projections(self, params, ets, edge_index_dict, kwargs):
        """Per-edge-type (w_neigh, b_neigh, w_root, b_root), or ``None``
        when the grouped path does not apply (custom messages / raw edge
        arrays / non-uniform weight shapes / extra propagate kwargs)."""
        if kwargs or not ets or (self.grouped is False):
            return None
        if self.grouped is None and not use_pallas():
            return None
        proj = {}
        for et in ets:
            conv = self.convs[et]
            if not (hasattr(conv, "fused_projections")
                    and conv._message_is_default()
                    and getattr(conv.aggr, "name", None)
                    in ("sum", "mean", "max", "min")
                    and isinstance(edge_index_dict[et], EdgeIndex)):
                return None
            proj[et] = conv.fused_projections(params[_et_key(et)])
        if len({(p[0].shape, p[2].shape) for p in proj.values()}) != 1:
            return None
        return proj

    def _apply_grouped(self, params, proj, ets, x_dict, edge_index_dict
                       ) -> Dict[str, List[jnp.ndarray]]:
        """Aggregate per relation (SpMM fast path), then project every
        relation's neighbor AND root features in ONE grouped matmul."""
        # 1. per-edge-type aggregation of *raw* source features — each call
        #    dispatches through EdgeIndex.matmul (Pallas ELL when cached)
        aggs = [self.convs[et].propagate(
            {}, edge_index_dict[et], (x_dict[et[0]], x_dict[et[2]]))
            for et in ets]
        roots = [x_dict[et[2]] for et in ets]
        # 2. one grouped GEMM over 2·|E| groups: [agg_et...] + [x_dst_et...]
        chunks = aggs + roots
        sizes = [c.shape[0] for c in chunks]
        w = jnp.stack([proj[et][0] for et in ets]
                      + [proj[et][2] for et in ets])
        # group sizes are static shape facts — keep them host-side so the
        # packer can make shape decisions under tracing
        out = gmm_ops.grouped_matmul(
            jnp.concatenate(chunks, axis=0), w,
            np.asarray(sizes, np.int32),
            interpret=jax.default_backend() != "tpu")
        parts, off = [], 0
        for s in sizes:
            parts.append(out[off:off + s])
            off += s
        # 3. per-relation output = projected neighbors + projected root
        grouped: Dict[str, List[jnp.ndarray]] = {}
        for i, et in enumerate(ets):
            o = parts[i] + parts[len(ets) + i]
            for b in (proj[et][1], proj[et][3]):
                if b is not None:
                    o = o + b
            grouped.setdefault(et[2], []).append(o)
        return grouped

    # ------------------------------------------------------------ aggregation
    def _cross_type_reduce(self, outs: List[jnp.ndarray]) -> jnp.ndarray:
        """Accumulate-in-place across edge types (no stacked temporary)."""
        if self.aggr == "cat":
            return jnp.concatenate(outs, axis=-1)
        acc = outs[0]
        for o in outs[1:]:
            if self.aggr == "max":
                acc = jnp.maximum(acc, o)
            elif self.aggr == "min":
                acc = jnp.minimum(acc, o)
            else:
                acc = acc + o
        if self.aggr == "mean":
            acc = acc / len(outs)
        return acc

    def apply(self, params, x_dict: Dict[str, jnp.ndarray],
              edge_index_dict: Dict[EdgeType, jnp.ndarray],
              num_nodes_dict: Optional[Dict[str, int]] = None,
              **kwargs) -> Dict[str, jnp.ndarray]:
        if num_nodes_dict is None:
            num_nodes_dict = {t: x.shape[0] for t, x in x_dict.items()}
        ets = [et for et in self.convs if et in edge_index_dict]
        proj = self._grouped_projections(params, ets, edge_index_dict,
                                         kwargs)
        if proj is not None:
            grouped = self._apply_grouped(params, proj, ets, x_dict,
                                          edge_index_dict)
        else:
            grouped = {}
            for et in ets:
                src_t, _, dst_t = et
                out = self.convs[et].apply(
                    params[_et_key(et)],
                    (x_dict[src_t], x_dict[dst_t]),
                    edge_index_dict[et],
                    num_nodes=num_nodes_dict[dst_t], **kwargs)
                grouped.setdefault(dst_t, []).append(out)
        out_dict = {dst_t: self._cross_type_reduce(outs)
                    for dst_t, outs in grouped.items()}
        # node types with no incoming edges keep their features (valid only
        # when dims already match — otherwise the caller needs reverse edge
        # types, the PyG ToUndirected idiom)
        for t, x in x_dict.items():
            if t not in out_dict:
                dims = {o.shape[-1] for o in out_dict.values()}
                if dims and x.shape[-1] not in dims:
                    raise ValueError(
                        f"node type '{t}' receives no messages and its "
                        f"feature dim {x.shape[-1]} != layer output dims "
                        f"{dims}; add a reverse edge type for '{t}'")
                out_dict[t] = x
        return out_dict


class HeteroGNN(Module):
    """``to_hetero``'d stack: every layer replicated over all edge types.

    Supports layer-wise trimming of hetero BFS subgraphs (paper C8): with
    ``trim=True`` and the sampler's per-type/per-relation budgets, each
    layer statically slices nodes, edges and the per-relation static-layout
    ELL caches (``trim_to_layer_hetero``), keeping the Pallas fast path on
    inner hops.
    """

    def __init__(self, make_conv: Callable[[int, int], MessagePassing],
                 metadata: Tuple[Sequence[str], Sequence[EdgeType]],
                 dims: Sequence[int], aggr: str = "sum",
                 act=jax.nn.relu, grouped: Optional[bool] = None):
        node_types, edge_types = metadata
        self.node_types = list(node_types)
        self.edge_types = list(edge_types)
        self.layers = [
            HeteroConv({et: make_conv(dims[i], dims[i + 1])
                        for et in self.edge_types}, aggr=aggr,
                       grouped=grouped)
            for i in range(len(dims) - 1)]
        self.act = act

    def init(self, key):
        keys = jax.random.split(key, len(self.layers))
        return {f"layer{i}": l.init(k)
                for i, (l, k) in enumerate(zip(self.layers, keys))}

    def apply(self, params, x_dict, edge_index_dict,
              num_nodes_dict=None,
              num_sampled_nodes_dict=None, num_sampled_edges_dict=None,
              trim: bool = False, **kwargs):
        do_trim = trim and num_sampled_nodes_dict is not None
        if do_trim and num_sampled_edges_dict is None:
            raise ValueError(
                "HeteroGNN.apply(trim=True) needs num_sampled_edges_dict "
                "alongside num_sampled_nodes_dict (the sampler's per-hop "
                "edge budgets drive the per-relation slicing)")
        for i, layer in enumerate(self.layers):
            # layer 0 sees the untrimmed graph by construction — skipping
            # its no-op trim keeps the loader-prefilled CSR/CSC/ELL caches
            # (and the weighted fast path) on the outermost, largest layer
            if do_trim and i > 0:
                x_dict, edge_index_dict = trim_to_layer_hetero(
                    i, num_sampled_nodes_dict, num_sampled_edges_dict,
                    x_dict, edge_index_dict)
                num_nodes_dict = {t: x.shape[0] for t, x in x_dict.items()}
            x_dict = layer.apply(params[f"layer{i}"], x_dict,
                                 edge_index_dict, num_nodes_dict, **kwargs)
            if i < len(self.layers) - 1:
                x_dict = {t: self.act(x) for t, x in x_dict.items()}
        return x_dict


def to_hetero(make_conv: Callable[[int, int], MessagePassing],
              metadata, dims: Sequence[int], aggr: str = "sum",
              grouped: Optional[bool] = None) -> HeteroGNN:
    """Replicate a homogeneous conv constructor across all edge types."""
    return HeteroGNN(make_conv, metadata, dims, aggr=aggr, grouped=grouped)


class GroupedLinear(Module):
    """{H_T W_T}: per-type projection via grouped GEMM (paper C4).

    Takes a dict of per-type features, packs rows type-sorted, runs one
    grouped matmul, and unpacks — O(1) kernel launches for |T| projections
    (the CUTLASS grouped-GEMM pattern, on the MXU via Pallas).
    """

    def __init__(self, types: Sequence[str], in_features: int,
                 out_features: int):
        self.types = list(types)
        self.in_features = in_features
        self.out_features = out_features

    def init(self, key):
        return {"w": glorot_uniform(
            key, (len(self.types), self.in_features, self.out_features))}

    def apply(self, params, x_dict: Dict[str, jnp.ndarray],
              force_pallas: Optional[bool] = None,
              interpret: bool = False) -> Dict[str, jnp.ndarray]:
        sizes = [x_dict[t].shape[0] for t in self.types]
        packed = jnp.concatenate([x_dict[t] for t in self.types], axis=0)
        out = gmm_ops.grouped_matmul(
            packed, params["w"], jnp.asarray(sizes, jnp.int32),
            force_pallas=force_pallas, interpret=interpret)
        outs = {}
        off = 0
        for t, s in zip(self.types, sizes):
            outs[t] = out[off:off + s]
            off += s
        return outs
