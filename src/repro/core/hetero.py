"""Heterogeneous message passing — paper C4 (§2.2).

A heterogeneous graph (V, E, phi, psi) gets a *nested* version of Eq. (1):
per-edge-type bipartite message passing, then an aggregation across incoming
edge types per destination node type. ``to_hetero`` replicates any
homogeneous GNN per edge type (the torch.fx transform of the paper, done
here by functional replication — parameters are duplicated per relation and
the computation graph rewired to bipartite propagate + group aggregation).

The serving path is *grouped*: when every replicated conv decomposes into
aggregate-then-project (``fused_projections``, e.g. ``SAGEConv``),
``HeteroConv`` runs each relation's aggregation as one SpMM (the blocked-ELL
Pallas fast path when the ``EdgeIndex`` carries a prefilled cache) and then
batches ALL per-relation projections — neighbor and root weights of every
edge type — into a single grouped matmul (one MXU launch instead of
2·|edge types| GEMMs), the same {H_T W_T} primitive the MoE experts use
(``kernels/grouped_matmul``, DESIGN.md §4). Cross-type aggregation
accumulates in place instead of materialising a stacked tensor.

Attention convs (``GATConv``) don't decompose into aggregate-then-project,
so they skip the grouped-projection path — but each relation's bipartite
``propagate`` still lowers to the *fused attention* kernel
(``EdgeIndex.attend`` over the loader-prefilled per-relation ELL caches),
so a hetero GAT keeps every relation on the Pallas fast path.

``HGTConv`` is the typed-attention composition of the same primitives: one
grouped matmul for every type's K/Q/V, one carry-mode fused attention
launch per relation, and a ``merge_carries`` cross-type softmax per
destination type — the Heterogeneous Graph Transformer with zero new
kernels. ``GroupedLinear`` exposes the raw {H_T W_T} grouped projection
for callers that manage their own per-type features; all grouped packing
lives in ``nn.typed_linear.grouped_apply``.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.edge_index import EdgeIndex
from repro.core.message_passing import MessagePassing
from repro.core.trim import trim_to_layer_hetero
from repro.kernels import use_pallas
from repro.nn.module import Module, glorot_uniform
from repro.nn.typed_linear import grouped_apply

EdgeType = Tuple[str, str, str]

_CROSS_TYPE_AGGRS = ("sum", "mean", "max", "min", "cat")


def _et_key(et: EdgeType) -> str:
    return "__".join(et)


class HeteroConv(Module):
    """One hetero layer: a conv per edge type + cross-type aggregation.

    ``aggr`` must be one of ``sum | mean | max | min | cat`` (``"cat"`` is
    the explicit concatenation mode; unknown strings raise instead of
    silently concatenating). ``grouped=None`` auto-selects the grouped
    projection path when Pallas dispatch is on (TPU backend or
    ``REPRO_USE_PALLAS=1`` — on CPU/GPU, |T| separate XLA GEMMs beat a
    ragged grouped dot) and every participating conv exposes
    ``fused_projections`` with uniform weight shapes over ``EdgeIndex``
    inputs; ``True``/``False`` force it on/off.
    """

    def __init__(self, convs: Dict[EdgeType, MessagePassing],
                 aggr: str = "sum", grouped: Optional[bool] = None):
        if aggr not in _CROSS_TYPE_AGGRS:
            raise ValueError(
                f"HeteroConv: unknown cross-type aggr '{aggr}'; expected one "
                f"of {_CROSS_TYPE_AGGRS} (use 'cat' for concatenation)")
        self.convs = convs
        self.aggr = aggr
        self.grouped = grouped

    def init(self, key):
        keys = jax.random.split(key, len(self.convs))
        return {_et_key(et): conv.init(k)
                for (et, conv), k in zip(self.convs.items(), keys)}

    # ---------------------------------------------------------------- grouped
    def _grouped_projections(self, params, ets, edge_index_dict, kwargs):
        """Per-edge-type (w_neigh, b_neigh, w_root, b_root), or ``None``
        when the grouped path does not apply (custom messages / raw edge
        arrays / non-uniform weight shapes / extra propagate kwargs)."""
        if kwargs or not ets or (self.grouped is False):
            return None
        if self.grouped is None and not use_pallas():
            return None
        proj = {}
        for et in ets:
            conv = self.convs[et]
            if not (hasattr(conv, "fused_projections")
                    and conv._message_is_default()
                    and getattr(conv.aggr, "name", None)
                    in ("sum", "mean", "max", "min")
                    and isinstance(edge_index_dict[et], EdgeIndex)):
                return None
            proj[et] = conv.fused_projections(params[_et_key(et)])
        if len({(p[0].shape, p[2].shape) for p in proj.values()}) != 1:
            return None
        return proj

    def _apply_grouped(self, params, proj, ets, x_dict, edge_index_dict
                       ) -> Dict[str, List[jnp.ndarray]]:
        """Aggregate per relation (SpMM fast path), then project every
        relation's neighbor AND root features in ONE grouped matmul."""
        # 1. per-edge-type aggregation of *raw* source features — each call
        #    dispatches through EdgeIndex.matmul (Pallas ELL when cached)
        aggs = [self.convs[et].propagate(
            {}, edge_index_dict[et], (x_dict[et[0]], x_dict[et[2]]))
            for et in ets]
        roots = [x_dict[et[2]] for et in ets]
        # 2. one grouped GEMM over 2·|E| groups: [agg_et...] + [x_dst_et...]
        parts = grouped_apply(
            aggs + roots,
            [proj[et][0] for et in ets] + [proj[et][2] for et in ets])
        # 3. per-relation output = projected neighbors + projected root
        grouped: Dict[str, List[jnp.ndarray]] = {}
        for i, et in enumerate(ets):
            o = parts[i] + parts[len(ets) + i]
            for b in (proj[et][1], proj[et][3]):
                if b is not None:
                    o = o + b
            grouped.setdefault(et[2], []).append(o)
        return grouped

    # ------------------------------------------------------------ aggregation
    def _cross_type_reduce(self, outs: List[jnp.ndarray]) -> jnp.ndarray:
        """Accumulate-in-place across edge types (no stacked temporary)."""
        if self.aggr == "cat":
            return jnp.concatenate(outs, axis=-1)
        acc = outs[0]
        for o in outs[1:]:
            if self.aggr == "max":
                acc = jnp.maximum(acc, o)
            elif self.aggr == "min":
                acc = jnp.minimum(acc, o)
            else:
                acc = acc + o
        if self.aggr == "mean":
            acc = acc / len(outs)
        return acc

    def apply(self, params, x_dict: Dict[str, jnp.ndarray],
              edge_index_dict: Dict[EdgeType, jnp.ndarray],
              num_nodes_dict: Optional[Dict[str, int]] = None,
              return_attention: bool = False,
              **kwargs) -> Dict[str, jnp.ndarray]:
        if num_nodes_dict is None:
            num_nodes_dict = {t: x.shape[0] for t, x in x_dict.items()}
        ets = [et for et in self.convs if et in edge_index_dict]
        # return_attention needs each conv's per-edge alphas, so it forces
        # the per-relation (ungrouped) path — grouped convs (SAGE family)
        # have no attention coefficients to surface anyway.
        proj = None if return_attention else self._grouped_projections(
            params, ets, edge_index_dict, kwargs)
        alpha_dict: Dict[EdgeType, jnp.ndarray] = {}
        if proj is not None:
            grouped = self._apply_grouped(params, proj, ets, x_dict,
                                          edge_index_dict)
        else:
            grouped = {}
            for et in ets:
                src_t, _, dst_t = et
                out = self.convs[et].apply(
                    params[_et_key(et)],
                    (x_dict[src_t], x_dict[dst_t]),
                    edge_index_dict[et],
                    num_nodes=num_nodes_dict[dst_t],
                    **(dict(kwargs, return_attention=True)
                       if return_attention else kwargs))
                if return_attention:
                    out, alpha_dict[et] = out
                grouped.setdefault(dst_t, []).append(out)
        out_dict = {dst_t: self._cross_type_reduce(outs)
                    for dst_t, outs in grouped.items()}
        # node types with no incoming edges keep their features (valid only
        # when dims already match — otherwise the caller needs reverse edge
        # types, the PyG ToUndirected idiom)
        for t, x in x_dict.items():
            if t not in out_dict:
                dims = {o.shape[-1] for o in out_dict.values()}
                if dims and x.shape[-1] not in dims:
                    raise ValueError(
                        f"node type '{t}' receives no messages and its "
                        f"feature dim {x.shape[-1]} != layer output dims "
                        f"{dims}; add a reverse edge type for '{t}'")
                out_dict[t] = x
        if return_attention:
            return out_dict, alpha_dict
        return out_dict


class HGTConv(Module):
    """Heterogeneous Graph Transformer layer (Hu et al. 2020) on the fused
    typed-attention stack — ZERO new kernels.

    Per node type: K/Q/V projections, batched with the output heads'
    pattern into ONE grouped matmul over 3·|T| groups
    (``nn.typed_linear.grouped_apply``). Per edge type r: relation
    transforms ``k W^ATT_r`` / ``v W^MSG_r``, scaled-dot logits with the
    learned per-head prior ``mu[r]`` (``DotLogit`` + ``prior``), and ONE
    carry-mode attention launch (``MessagePassing.propagate(...,
    return_carry=True)`` -> the generalised flash kernel over the
    relation's blocked-ELL buckets). The per-relation ``SoftmaxCarry``s
    targeting a destination type then combine via ``merge_carries`` — the
    *cross-type* softmax over ALL incoming edges of a node, computed
    without ever materialising cross-relation logits — and finalize into
    gelu -> per-type output projection (one more grouped matmul) ->
    ``sigmoid(skip[t])``-gated residual (when in/out dims match).

    ``return_attention=True`` additionally returns the per-edge-type
    ``(E_r, H)`` alpha dict, each relation's coefficients normalised
    against the *merged* softmax statistics (they sum to 1 jointly across
    relations into a node).
    """

    def __init__(self, in_features: int, out_features: int,
                 metadata: Tuple[Sequence[str], Sequence[EdgeType]],
                 heads: int = 2):
        node_types, edge_types = metadata
        if out_features % heads:
            raise ValueError(
                f"HGTConv: out_features={out_features} not divisible by "
                f"heads={heads}")
        self.node_types = list(node_types)
        self.edge_types = [tuple(et) for et in edge_types]
        self.in_features = in_features
        self.out_features = out_features
        self.heads = heads
        self.head_dim = out_features // heads
        self._mp = MessagePassing(aggr="sum")

    def init(self, key):
        T, R = len(self.node_types), len(self.edge_types)
        H, D = self.heads, self.head_dim
        ks = jax.random.split(key, 4)
        return {
            # K-groups (T), then Q-groups (T), then V-groups (T) — one
            # grouped GEMM projects all three roles for every type.
            "w_kqv": glorot_uniform(ks[0], (3 * T, self.in_features, H * D)),
            "b_kqv": jnp.zeros((3 * T, H * D), jnp.float32),
            "a_rel": glorot_uniform(ks[1], (R, H, D, D)),  # W^ATT per rel
            "m_rel": glorot_uniform(ks[2], (R, H, D, D)),  # W^MSG per rel
            "mu": jnp.ones((R, H), jnp.float32),           # typed prior
            "w_out": glorot_uniform(ks[3], (T, H * D, self.out_features)),
            "b_out": jnp.zeros((T, self.out_features), jnp.float32),
            "skip": jnp.ones((T,), jnp.float32),
        }

    def apply(self, params, x_dict: Dict[str, jnp.ndarray],
              edge_index_dict: Dict[EdgeType, jnp.ndarray],
              num_nodes_dict: Optional[Dict[str, int]] = None,
              return_attention: bool = False,
              edge_mask_dict: Optional[Dict[EdgeType, jnp.ndarray]] = None,
              **kwargs):
        from repro.kernels.attention.ops import (DotLogit, finalize_carry,
                                                 merge_carries)
        if num_nodes_dict is None:
            num_nodes_dict = {t: x.shape[0] for t, x in x_dict.items()}
        H, D, T = self.heads, self.head_dim, len(self.node_types)
        types = [t for t in self.node_types if t in x_dict]
        ti = {t: i for i, t in enumerate(self.node_types)}
        # 1. K/Q/V for every node type in ONE grouped matmul (3·|T| groups)
        sel = ([ti[t] for t in types] + [T + ti[t] for t in types]
               + [2 * T + ti[t] for t in types])
        parts = grouped_apply([x_dict[t] for t in types] * 3,
                              params["w_kqv"][jnp.asarray(sel)],
                              [params["b_kqv"][i] for i in sel])
        nt = len(types)
        k = {t: parts[i].reshape(-1, H, D) for i, t in enumerate(types)}
        q = {t: parts[nt + i].reshape(-1, H, D) for i, t in enumerate(types)}
        v = {t: parts[2 * nt + i].reshape(-1, H, D)
             for i, t in enumerate(types)}
        scale = float(D) ** -0.5
        # 2. one carry-mode attention launch per relation; carries of the
        #    relations into each destination type merge into one softmax
        carries: Dict[str, list] = {}
        alpha_ctx = []
        for r, et in enumerate(self.edge_types):
            if et not in edge_index_dict:
                continue
            src_t, _, dst_t = et
            k_rel = jnp.einsum("nhd,hde->nhe", k[src_t], params["a_rel"][r])
            v_rel = jnp.einsum("nhd,hde->nhe", v[src_t], params["m_rel"][r])
            carry = self._mp.propagate(
                {}, edge_index_dict[et], (v_rel, None),
                alpha=(k_rel, q[dst_t]), logit=DotLogit(scale=scale),
                prior=params["mu"][r],
                edge_mask=(None if edge_mask_dict is None
                           else edge_mask_dict.get(et)),
                num_nodes=num_nodes_dict[dst_t], return_carry=True)
            carries.setdefault(dst_t, []).append(carry)
            if return_attention:
                alpha_ctx.append((et, k_rel, r))
        merged = {t: merge_carries(cs) for t, cs in carries.items()}
        # 3. finalize -> gelu -> per-type output heads (one grouped matmul)
        dst_types = [t for t in types if t in merged]
        hidden = [jax.nn.gelu(finalize_carry(merged[t]).reshape(-1, H * D))
                  for t in dst_types]
        outs = grouped_apply(
            hidden, params["w_out"][jnp.asarray([ti[t] for t in dst_types])],
            [params["b_out"][ti[t]] for t in dst_types])
        out_dict: Dict[str, jnp.ndarray] = {}
        for t, o in zip(dst_types, outs):
            x = x_dict[t]
            if self.in_features == self.out_features:
                gate = jax.nn.sigmoid(params["skip"][ti[t]])
                o = gate * o.astype(x.dtype) + (1.0 - gate) * x
            out_dict[t] = o
        # node types with no incoming edges keep their features (the
        # HeteroConv passthrough convention, same dim guard)
        for t in types:
            if t not in out_dict:
                if x_dict[t].shape[-1] != self.out_features:
                    raise ValueError(
                        f"node type '{t}' receives no messages and its "
                        f"feature dim {x_dict[t].shape[-1]} != out_features "
                        f"{self.out_features}; add a reverse edge type")
                out_dict[t] = x_dict[t]
        if not return_attention:
            return out_dict
        alpha_dict: Dict[EdgeType, jnp.ndarray] = {}
        for et, k_rel, r in alpha_ctx:
            dst_t = et[2]
            ei = edge_index_dict[et]
            if not isinstance(ei, EdgeIndex):
                ei = EdgeIndex(jnp.stack([ei[0], ei[1]]), k_rel.shape[0],
                               num_nodes_dict[dst_t])
            alpha_dict[et] = ei.attend_alpha(
                k_rel, q[dst_t], logit=DotLogit(scale=scale),
                prior=params["mu"][r], m=merged[dst_t].m, l=merged[dst_t].l)
        return out_dict, alpha_dict


class HeteroGNN(Module):
    """``to_hetero``'d stack: every layer replicated over all edge types.

    Supports layer-wise trimming of hetero BFS subgraphs (paper C8): with
    ``trim=True`` and the sampler's per-type/per-relation budgets, each
    layer statically slices nodes, edges and the per-relation static-layout
    ELL caches (``trim_to_layer_hetero``), keeping the Pallas fast path on
    inner hops.
    """

    def __init__(self, make_conv: Optional[Callable[[int, int],
                                                    MessagePassing]],
                 metadata: Tuple[Sequence[str], Sequence[EdgeType]],
                 dims: Sequence[int], aggr: str = "sum",
                 act=jax.nn.relu, grouped: Optional[bool] = None,
                 make_layer: Optional[Callable[[int, int], Module]] = None):
        node_types, edge_types = metadata
        self.node_types = list(node_types)
        self.edge_types = list(edge_types)
        if make_layer is not None:
            # whole-hetero-layer modules (HGTConv): the module itself owns
            # the per-type/per-relation structure — no per-et replication
            self.layers = [make_layer(dims[i], dims[i + 1])
                           for i in range(len(dims) - 1)]
        else:
            self.layers = [
                HeteroConv({et: make_conv(dims[i], dims[i + 1])
                            for et in self.edge_types}, aggr=aggr,
                           grouped=grouped)
                for i in range(len(dims) - 1)]
        self.act = act

    def init(self, key):
        keys = jax.random.split(key, len(self.layers))
        return {f"layer{i}": l.init(k)
                for i, (l, k) in enumerate(zip(self.layers, keys))}

    def apply(self, params, x_dict, edge_index_dict,
              num_nodes_dict=None,
              num_sampled_nodes_dict=None, num_sampled_edges_dict=None,
              trim: bool = False, return_attention: bool = False,
              **kwargs):
        do_trim = trim and num_sampled_nodes_dict is not None
        if do_trim and num_sampled_edges_dict is None:
            raise ValueError(
                "HeteroGNN.apply(trim=True) needs num_sampled_edges_dict "
                "alongside num_sampled_nodes_dict (the sampler's per-hop "
                "edge budgets drive the per-relation slicing)")
        alphas = []
        for i, layer in enumerate(self.layers):
            # layer 0 sees the untrimmed graph by construction — skipping
            # its no-op trim keeps the loader-prefilled CSR/CSC/ELL caches
            # (and the weighted fast path) on the outermost, largest layer
            if do_trim and i > 0:
                x_dict, edge_index_dict = trim_to_layer_hetero(
                    i, num_sampled_nodes_dict, num_sampled_edges_dict,
                    x_dict, edge_index_dict)
                num_nodes_dict = {t: x.shape[0] for t, x in x_dict.items()}
            res = layer.apply(params[f"layer{i}"], x_dict,
                              edge_index_dict, num_nodes_dict,
                              **(dict(kwargs, return_attention=True)
                                 if return_attention else kwargs))
            if return_attention:
                x_dict, layer_alpha = res
                alphas.append(layer_alpha)
            else:
                x_dict = res
            if i < len(self.layers) - 1:
                x_dict = {t: self.act(x) for t, x in x_dict.items()}
        if return_attention:
            return x_dict, alphas
        return x_dict


def to_hetero(make_conv: Callable[[int, int], MessagePassing],
              metadata, dims: Sequence[int], aggr: str = "sum",
              grouped: Optional[bool] = None) -> HeteroGNN:
    """Replicate a homogeneous conv constructor across all edge types."""
    return HeteroGNN(make_conv, metadata, dims, aggr=aggr, grouped=grouped)


def hgt(metadata, dims: Sequence[int], heads: int = 2) -> HeteroGNN:
    """Multi-layer HGT graph-transformer block.

    One :class:`HGTConv` per layer via ``make_layer`` — every layer shares
    the SAME packed per-relation ELL layouts through the hetero trimming
    path (``trim_to_layer_hetero`` slices rungs, it never re-packs), so a
    loader-prefilled batch keeps all layers' attention launches on the
    fused kernel. No inter-layer activation: HGTConv already applies
    gelu + the gated residual internally (the transformer convention).
    """
    return HeteroGNN(None, metadata, dims,
                     make_layer=lambda i, o: HGTConv(i, o, metadata,
                                                     heads=heads),
                     act=lambda x: x)


class GroupedLinear(Module):
    """{H_T W_T}: per-type projection via grouped GEMM (paper C4).

    Takes a dict of per-type features, packs rows type-sorted, runs one
    grouped matmul, and unpacks — O(1) kernel launches for |T| projections
    (the CUTLASS grouped-GEMM pattern, on the MXU via Pallas).
    """

    def __init__(self, types: Sequence[str], in_features: int,
                 out_features: int):
        self.types = list(types)
        self.in_features = in_features
        self.out_features = out_features

    def init(self, key):
        return {"w": glorot_uniform(
            key, (len(self.types), self.in_features, self.out_features))}

    def apply(self, params, x_dict: Dict[str, jnp.ndarray],
              force_pallas: Optional[bool] = None,
              interpret: bool = False) -> Dict[str, jnp.ndarray]:
        parts = grouped_apply([x_dict[t] for t in self.types], params["w"],
                              force_pallas=force_pallas, interpret=interpret)
        return dict(zip(self.types, parts))
