"""Aggregations as a first-class principle — paper C3 (PyG 2.0 §2.2).

Every aggregation is an object with a uniform signature

    aggr(params, values, index, num_segments, ptr=None) -> (num_segments, F)

so they plug into message passing *and* global readouts interchangeably, and
can be stacked via :class:`MultiAggregation` — the paper's "seamlessly
stacked together" (PNA-style). Learnable aggregations (softmax temperature,
power-mean exponent) carry params; the rest use an empty pytree.

``ptr`` (a CSR-style segment pointer) is accepted by sort-aware aggregations
(median/quantile) which need contiguous segments — exactly the case the
paper's sorted ``EdgeIndex`` guarantees.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.nn.module import Module


def _seg_sum(v, idx, n):
    return jax.ops.segment_sum(v, idx, num_segments=n)


def _counts(idx, n, dtype):
    return jax.ops.segment_sum(jnp.ones(idx.shape[0], dtype), idx,
                               num_segments=n)


class Aggregation(Module):
    name = "base"

    def apply(self, params, values, index, num_segments, ptr=None):
        raise NotImplementedError


class SumAggregation(Aggregation):
    name = "sum"

    def apply(self, params, values, index, num_segments, ptr=None):
        return _seg_sum(values, index, num_segments)


class MeanAggregation(Aggregation):
    name = "mean"

    def apply(self, params, values, index, num_segments, ptr=None):
        s = _seg_sum(values, index, num_segments)
        c = _counts(index, num_segments, values.dtype)
        return s / jnp.maximum(c, 1)[:, None]


class MaxAggregation(Aggregation):
    name = "max"

    def apply(self, params, values, index, num_segments, ptr=None):
        out = jax.ops.segment_max(values, index, num_segments=num_segments)
        return jnp.where(jnp.isfinite(out), out, 0.0).astype(values.dtype)


class MinAggregation(Aggregation):
    name = "min"

    def apply(self, params, values, index, num_segments, ptr=None):
        out = jax.ops.segment_min(values, index, num_segments=num_segments)
        return jnp.where(jnp.isfinite(out), out, 0.0).astype(values.dtype)


class VarAggregation(Aggregation):
    name = "var"

    def apply(self, params, values, index, num_segments, ptr=None):
        c = jnp.maximum(_counts(index, num_segments, values.dtype), 1)[:, None]
        mean = _seg_sum(values, index, num_segments) / c
        mean2 = _seg_sum(values * values, index, num_segments) / c
        return jnp.maximum(mean2 - mean * mean, 0.0)


class StdAggregation(Aggregation):
    name = "std"

    def __init__(self, eps: float = 1e-5):
        self.eps = eps
        self._var = VarAggregation()

    def apply(self, params, values, index, num_segments, ptr=None):
        return jnp.sqrt(self._var.apply({}, values, index, num_segments)
                        + self.eps)


class MedianAggregation(Aggregation):
    """Per-segment median via contiguous-segment sorting (needs ``ptr``).

    The 'advanced' aggregation from the paper. Values must be grouped by
    segment (sorted EdgeIndex); we sort within segments feature-wise and
    gather the middle element of each segment.
    """

    name = "median"

    def apply(self, params, values, index, num_segments, ptr=None):
        assert ptr is not None, "median aggregation requires a segment ptr"
        e, f = values.shape
        # Rank of each slot inside its segment.
        pos = jnp.arange(e, dtype=jnp.int32) - ptr[index]
        count = (ptr[1:] - ptr[:-1]).astype(jnp.int32)
        # Sort each feature column *within* segments: key = (segment, value).
        # A stable argsort over segment-major composite keys does this.
        order = jnp.argsort(values, axis=0, stable=True)  # (E, F) per-column
        seg_of = index[order]  # (E, F) segment of each sorted slot
        inner = jnp.argsort(seg_of, axis=0, stable=True)  # group by segment
        sorted_slots = jnp.take_along_axis(order, inner, axis=0)
        sorted_vals = jnp.take_along_axis(values, sorted_slots, axis=0)
        # After the two sorts, slots of segment s occupy rows
        # [ptr[s], ptr[s+1]) per column, ascending in value.
        med_idx = ptr[:-1][:, None] + jnp.maximum((count[:, None] - 1) // 2, 0)
        med = jnp.take_along_axis(
            sorted_vals, med_idx.astype(jnp.int32), axis=0)
        empty = (count == 0)[:, None]
        return jnp.where(empty, 0.0, med).astype(values.dtype)


class SoftmaxAggregation(Aggregation):
    """Learnable softmax-weighted aggregation (DeeperGCN): params = temp t."""

    name = "softmax"

    def __init__(self, learn: bool = True, t: float = 1.0):
        self.learn = learn
        self.t0 = t

    def init(self, key):
        return {"t": jnp.asarray(self.t0, jnp.float32)} if self.learn else {}

    def apply(self, params, values, index, num_segments, ptr=None):
        t = params.get("t", self.t0) if isinstance(params, dict) else self.t0
        logits = values * t
        seg_max = jax.ops.segment_max(logits, index, num_segments=num_segments)
        seg_max = jnp.where(jnp.isfinite(seg_max), seg_max, 0.0)
        ex = jnp.exp(logits - seg_max[index])
        den = jnp.maximum(_seg_sum(ex, index, num_segments)[index], 1e-16)
        return _seg_sum(values * ex / den, index, num_segments)


class PowerMeanAggregation(Aggregation):
    """Learnable power-mean (DeeperGCN): ((1/n) sum x^p)^(1/p)."""

    name = "powermean"

    def __init__(self, learn: bool = True, p: float = 1.0, eps: float = 1e-7):
        self.learn = learn
        self.p0 = p
        self.eps = eps

    def init(self, key):
        return {"p": jnp.asarray(self.p0, jnp.float32)} if self.learn else {}

    def apply(self, params, values, index, num_segments, ptr=None):
        p = params.get("p", self.p0) if isinstance(params, dict) else self.p0
        vp = jnp.power(jnp.clip(values, self.eps, None), p)
        c = jnp.maximum(_counts(index, num_segments, values.dtype), 1)[:, None]
        mean = _seg_sum(vp, index, num_segments) / c
        return jnp.power(jnp.clip(mean, self.eps, None), 1.0 / p)


class MultiAggregation(Aggregation):
    """Stack several aggregations (PNA-style): mode in {'cat', 'sum', 'mean'}."""

    name = "multi"

    def __init__(self, aggrs: Sequence[Aggregation], mode: str = "cat"):
        self.aggrs = list(aggrs)
        self.mode = mode

    def init(self, key):
        keys = jax.random.split(key, len(self.aggrs))
        return {a.name + f"_{i}": a.init(k)
                for i, (a, k) in enumerate(zip(self.aggrs, keys))}

    def apply(self, params, values, index, num_segments, ptr=None):
        outs = [a.apply(params.get(a.name + f"_{i}", {}), values, index,
                        num_segments, ptr)
                for i, a in enumerate(self.aggrs)]
        if self.mode == "cat":
            return jnp.concatenate(outs, axis=-1)
        stacked = jnp.stack(outs)
        return stacked.sum(0) if self.mode == "sum" else stacked.mean(0)


_REGISTRY = {
    "sum": SumAggregation, "add": SumAggregation, "mean": MeanAggregation,
    "max": MaxAggregation, "min": MinAggregation, "var": VarAggregation,
    "std": StdAggregation, "median": MedianAggregation,
    "softmax": SoftmaxAggregation, "powermean": PowerMeanAggregation,
}


def resolve(aggr) -> Aggregation:
    """'sum' | 'mean' | ... | ['mean','max'] | Aggregation -> Aggregation."""
    if isinstance(aggr, Aggregation):
        return aggr
    if isinstance(aggr, (list, tuple)):
        return MultiAggregation([resolve(a) for a in aggr])
    return _REGISTRY[aggr]()
