"""EdgeIndex — the paper's C1 contribution (PyG 2.0 §2.2).

A COO edge tensor of shape ``(2, E)`` that carries *metadata* (sort order,
undirectedness, node counts) and demand-filled *caches* (CSR / CSC
conversions, i.e. the adjacency and its transpose). Message passing inspects
this metadata to pick the optimal compute path:

* sorted-by-row  -> fused CSR segment/SpMM forward path
* sorted-by-col  -> fused CSC path (transposed flow)
* cached CSC     -> cheap backward (no re-derivation of ``A^T`` per step)
* undirected     -> ``A == A^T``; a single cache serves both directions
* cached ELL     -> degree-bucketed blocked-ELL packing feeding the Pallas
  pipelined SpMM kernel on TPU (the demand-filled TPU fast path); the same
  buckets serve the fused flash-GAT attention aggregation (:meth:`attend`)

This mirrors ``torch_geometric.EdgeIndex`` semantics adapted to JAX: the
object is a registered pytree (arrays are leaves, metadata is static), so it
can flow through ``jit`` boundaries; caches are jnp arrays computed once and
reused across layers/steps — exactly the paper's "filled based on demand, and
maintained and adjusted over its lifespan".
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

SortOrder = Optional[str]  # None | "row" | "col"


def _count_sorted(index: jnp.ndarray, n: int) -> jnp.ndarray:
    """ptr[i] = number of entries < i, for a sorted index vector (CSR rowptr)."""
    # searchsorted over the sorted index gives the compressed pointer directly.
    return jnp.searchsorted(index, jnp.arange(n + 1), side="left").astype(jnp.int32)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class EdgeIndex:
    """COO edge index with metadata + CSR/CSC caches.

    Attributes:
      data:          int32 array of shape (2, E): row 0 = source, row 1 = dest.
      num_src_nodes: number of source nodes (rows of A).
      num_dst_nodes: number of destination nodes (cols of A).
      sort_order:    None | "row" | "col" — which coordinate `data` is sorted by.
      is_undirected: if True, A == A^T and one cache serves both directions.
      _csr / _csc:   optional cached (indptr, indices, perm) triples.
      _ell / _ell_t: optional cached degree-bucketed blocked-ELL packings of
                     the CSC (forward) / CSR (transpose) adjacency — tuples of
                     (row_ids, ell_idx, ell_pos) buckets feeding the Pallas
                     pipelined SpMM kernel. ``ell_pos`` slots index the
                     *original COO edge order* (the order callers pass
                     ``edge_weight`` in), so weighted matmuls gather per-call
                     weights directly — and a layer-trimmed cache keeps
                     serving them, because kept slots reference kept (prefix)
                     edges only.
    """

    data: jnp.ndarray
    num_src_nodes: int
    num_dst_nodes: int
    sort_order: SortOrder = None
    is_undirected: bool = False
    _csr: Optional[Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]] = None
    _csc: Optional[Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]] = None
    _ell: Optional[Tuple] = None
    _ell_t: Optional[Tuple] = None

    # ------------------------------------------------------------------ pytree
    def tree_flatten(self):
        children = (self.data, self._csr, self._csc, self._ell, self._ell_t)
        aux = (self.num_src_nodes, self.num_dst_nodes, self.sort_order,
               self.is_undirected)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        data, csr, csc, ell, ell_t = children
        ns, nd, so, undirected = aux
        return cls(data, ns, nd, so, undirected, csr, csc, ell, ell_t)

    # ------------------------------------------------------------- constructors
    @classmethod
    def from_coo(cls, src, dst, num_src_nodes=None, num_dst_nodes=None,
                 sort_order: SortOrder = None, is_undirected: bool = False):
        src = jnp.asarray(src, jnp.int32)
        dst = jnp.asarray(dst, jnp.int32)
        if (num_src_nodes is None or num_dst_nodes is None) and (
                isinstance(src, jax.core.Tracer)
                or isinstance(dst, jax.core.Tracer)):
            raise ValueError(
                "EdgeIndex.from_coo: num_src_nodes/num_dst_nodes must be "
                "passed explicitly when the edge arrays are traced (inside "
                "jit/vmap/grad). Node counts are static shape metadata and "
                "cannot be derived from a tracer's values.")
        if num_src_nodes is None:
            num_src_nodes = int(src.max()) + 1 if src.size else 0
        if num_dst_nodes is None:
            num_dst_nodes = int(dst.max()) + 1 if dst.size else 0
        return cls(jnp.stack([src, dst]), int(num_src_nodes), int(num_dst_nodes),
                   sort_order, is_undirected)

    @classmethod
    def from_coo_prefilled(cls, src, dst, num_src_nodes: int,
                           num_dst_nodes: int, *, ell_layout=None,
                           block_rows: int = 8) -> "EdgeIndex":
        """Host-side construct-with-caches: the jit-ready producer path.

        Sorts the COO by destination (and by source) in NumPy, building the
        CSC/CSR caches *before* the object ever reaches a jit boundary —
        so a per-batch ``EdgeIndex`` passed as a jit argument carries its
        conversions as pytree leaves instead of re-deriving them in-trace.
        With ``ell_layout`` (see ``kernels.spmm.ops.ell_layout_from_bounds``)
        it additionally packs the static-layout blocked-ELL cache, whose
        shapes depend only on the layout: batches built against the same
        layout share one jit trace and dispatch to the Pallas kernel.

        ``data`` keeps the caller's edge order (the sampler's BFS hop
        grouping, which layer-wise trimming slices); the destination-sorted
        layout lives in the caches, each carrying its own permutation.
        """
        from repro.kernels.spmm import ops as spmm_ops  # local import: no cycle
        src = np.asarray(src, np.int32)
        dst = np.asarray(dst, np.int32)
        perm_c = np.argsort(dst, kind="stable").astype(np.int32)
        colptr = np.searchsorted(dst[perm_c], np.arange(
            num_dst_nodes + 1)).astype(np.int32)
        csc_idx = src[perm_c]
        perm_r = np.argsort(src, kind="stable").astype(np.int32)
        rowptr = np.searchsorted(src[perm_r], np.arange(
            num_src_nodes + 1)).astype(np.int32)
        csr_idx = dst[perm_r]
        ell = None
        if ell_layout is not None:
            ell = cls._ell_pos_to_coo(
                spmm_ops.csr_to_ell_static(colptr, csc_idx, ell_layout,
                                           block_rows=block_rows), perm_c)
        return cls(
            jnp.asarray(np.stack([src, dst])), int(num_src_nodes),
            int(num_dst_nodes), None, False,
            _csr=(jnp.asarray(rowptr), jnp.asarray(csr_idx),
                  jnp.asarray(perm_r)),
            _csc=(jnp.asarray(colptr), jnp.asarray(csc_idx),
                  jnp.asarray(perm_c)),
            _ell=ell)

    # ----------------------------------------------------------------- accessors
    @property
    def src(self) -> jnp.ndarray:
        return self.data[0]

    @property
    def dst(self) -> jnp.ndarray:
        return self.data[1]

    @property
    def num_edges(self) -> int:
        return int(self.data.shape[1])

    def sparse_size(self) -> Tuple[int, int]:
        return (self.num_src_nodes, self.num_dst_nodes)

    # ------------------------------------------------------------------- sorting
    def sort_by(self, order: str) -> Tuple["EdgeIndex", jnp.ndarray]:
        """Return a copy sorted by 'row' (src) or 'col' (dst) + the permutation."""
        assert order in ("row", "col")
        if self.sort_order == order:
            return self, jnp.arange(self.num_edges, dtype=jnp.int32)
        key = self.src if order == "row" else self.dst
        # Stable sort keeps deterministic tie order (matches numpy/PyG).
        perm = jnp.argsort(key, stable=True).astype(jnp.int32)
        out = EdgeIndex(self.data[:, perm], self.num_src_nodes,
                        self.num_dst_nodes, order, self.is_undirected)
        return out, perm

    # -------------------------------------------------------------------- caches
    @staticmethod
    def _memoizable(triple) -> bool:
        """Never memoise tracers: a cache filled inside a jit trace would
        leak the tracer into later traces (the mutable-cache + jit hazard).
        Inside jit the conversion is recomputed — XLA CSE's it anyway."""
        return not any(isinstance(a, jax.core.Tracer) for a in triple)

    def get_csr(self) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """(rowptr, col, perm): perm maps CSR edge slots -> original COO slots.

        Fills and memoises the cache on first call (the paper's demand-filled
        cache). For undirected graphs a CSC cache doubles as CSR.
        """
        if self._csr is not None:
            return self._csr
        if self.is_undirected and self._csc is not None:
            colptr, row, perm = self._csc
            self._csr = (colptr, row, perm)
            return self._csr
        if self.sort_order == "row":
            rowptr = _count_sorted(self.src, self.num_src_nodes)
            perm = jnp.arange(self.num_edges, dtype=jnp.int32)
            out = (rowptr, self.dst, perm)
        else:
            sorted_ei, perm = self.sort_by("row")
            rowptr = _count_sorted(sorted_ei.src, self.num_src_nodes)
            out = (rowptr, sorted_ei.dst, perm)
        if self._memoizable(out):
            self._csr = out
        return out

    def get_csc(self) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """(colptr, row, perm): the transposed adjacency — the backward cache."""
        if self._csc is not None:
            return self._csc
        if self.is_undirected and self._csr is not None:
            rowptr, col, perm = self._csr
            self._csc = (rowptr, col, perm)
            return self._csc
        if self.sort_order == "col":
            colptr = _count_sorted(self.dst, self.num_dst_nodes)
            perm = jnp.arange(self.num_edges, dtype=jnp.int32)
            out = (colptr, self.src, perm)
        else:
            sorted_ei, perm = self.sort_by("col")
            colptr = _count_sorted(sorted_ei.dst, self.num_dst_nodes)
            out = (colptr, sorted_ei.src, perm)
        if self._memoizable(out):
            self._csc = out
        return out

    @staticmethod
    def _ell_pos_to_coo(buckets, perm) -> Tuple:
        """Re-key bucket ``ell_pos`` slots from packed (CSR/CSC) order to the
        original COO edge order via the cache permutation, so per-call
        ``edge_weight`` vectors can be gathered without an extra perm gather
        — and so the positions stay valid after a layer trim (kept slots
        reference only kept, prefix edges)."""
        perm = np.asarray(perm)
        out = []
        for r, i, p in buckets:
            p = np.asarray(p)
            p_coo = np.where(p >= 0, perm[np.maximum(p, 0)],
                             -1).astype(np.int32)
            out.append((jnp.asarray(r), jnp.asarray(i), jnp.asarray(p_coo)))
        return tuple(out)

    def get_ell(self, transpose: bool = False) -> Optional[Tuple]:
        """Degree-bucketed blocked-ELL packing of A (or A^T) for the Pallas
        SpMM kernel: a tuple of ``(row_ids, ell_idx, ell_pos)`` buckets
        (see ``kernels.spmm.ops.csr_to_ell_bucketed``); ``ell_pos`` is
        re-keyed to COO edge order (see :meth:`_ell_pos_to_coo`).

        The packing needs concrete (host) arrays — called with tracers it
        returns ``None`` and the caller falls back to the XLA oracle; filled
        eagerly once, the cached buckets become jit constants afterwards.
        """
        from repro.kernels.spmm import ops as spmm_ops  # local import: no cycle
        if self.is_undirected and transpose:  # A == A^T: one packing serves
            transpose = False
        cache = self._ell_t if transpose else self._ell
        if cache is not None:
            return cache
        indptr, indices, perm = (self.get_csr() if transpose
                                 else self.get_csc())
        if not self._memoizable((indptr, indices, perm)):
            return None
        buckets = self._ell_pos_to_coo(
            spmm_ops.csr_to_ell_bucketed(np.asarray(indptr),
                                         np.asarray(indices)), perm)
        if transpose:
            self._ell_t = buckets
        else:
            self._ell = buckets
        return buckets

    def fill_cache(self, ell: Optional[bool] = None) -> "EdgeIndex":
        """Eagerly fill the caches (used before entering a jit'd loop).

        ``ell`` additionally packs the blocked-ELL buckets for the Pallas
        fast path; the default (``None``) packs them exactly when dispatch
        would select that path (TPU backend or ``REPRO_USE_PALLAS=1``), so
        the documented "fill_cache() before jit" pattern reaches the kernel
        without an extra opt-in.
        """
        from repro.kernels import use_pallas
        self.get_csr()
        if not self.is_undirected:
            self.get_csc()
        if use_pallas() if ell is None else ell:
            self.get_ell()
            self.get_ell(transpose=True)
        return self

    # --------------------------------------------------------------------- spmm
    def matmul(self, x: jnp.ndarray, edge_weight: Optional[jnp.ndarray] = None,
               transpose: bool = False, reduce: str = "sum",
               force_pallas: Optional[bool] = None,
               interpret: Optional[bool] = None) -> jnp.ndarray:
        """Sparse(A or A^T) @ dense(x) using the best available path.

        ``A[dst, src] = w`` convention: forward message passing aggregates
        source features into destinations, i.e. ``out = A @ x`` with A of
        shape (num_dst, num_src).

        Dispatch: on TPU (or ``force_pallas=True``) the degree-bucketed
        blocked-ELL packing feeds the pipelined Pallas kernel; otherwise —
        or when packing is impossible (tracing without a filled ELL cache) —
        the fused XLA segment oracle runs. Both branches are differentiable:
        the Pallas branch carries a custom VJP (backward = masked scatter-add
        over the same buckets, with a per-slot ``dy[row] . x[col]`` cotangent
        scattered back into ``edge_weight`` in slot order), so jit'd
        ``jax.grad`` train steps ride the fast path too.
        """
        from repro.kernels.spmm import ops as spmm_ops  # local import: no cycle
        from repro.kernels import use_pallas
        num_rows = self.num_src_nodes if transpose else self.num_dst_nodes
        take_pallas = use_pallas() if force_pallas is None else force_pallas
        if take_pallas:
            ell = self.get_ell(transpose=transpose)
            if ell is not None:
                # ``ell_pos`` is keyed to COO edge order — the caller's
                # ``edge_weight`` order — so the buckets gather it directly
                # (valid on layer-trimmed caches too: kept slots only
                # reference kept, prefix edges).
                return spmm_ops.spmm_ell_bucketed(
                    ell, x, edge_weight, num_rows=num_rows, reduce=reduce,
                    force_pallas=take_pallas, interpret=interpret)
        if not transpose:
            colptr, row, perm = self.get_csc()
            w = None if edge_weight is None else edge_weight[perm]
            return spmm_ops.spmm_csr(colptr, row, x, w,
                                     num_rows=self.num_dst_nodes, reduce=reduce)
        rowptr, col, perm = self.get_csr()
        w = None if edge_weight is None else edge_weight[perm]
        return spmm_ops.spmm_csr(rowptr, col, x, w,
                                 num_rows=self.num_src_nodes, reduce=reduce)

    # ------------------------------------------------------------------ attend
    def attend(self, z: jnp.ndarray, alpha_src: jnp.ndarray,
               alpha_dst: jnp.ndarray, *, negative_slope: float = 0.2,
               logit=None, prior: Optional[jnp.ndarray] = None,
               edge_weight: Optional[jnp.ndarray] = None,
               transpose: bool = False, return_attention: bool = False,
               return_carry: bool = False,
               force_pallas: Optional[bool] = None,
               interpret: Optional[bool] = None):
        """Attention-weighted aggregation over A (or A^T), typed logits.

        ``out[i] = sum_j softmax_j(logit(j, i)) * w_ij * z[j]`` with ``z``
        of shape (N, H, F) and the logit operands dense per-node arrays —
        ``alpha_src`` keyed by the *message sender* nodes (gathered through
        the neighbor table), ``alpha_dst`` by the receivers (the table's
        rows). For ``transpose=True`` the roles ride the CSR-derived
        transpose table, so the caller passes the halves already swapped
        into sender/receiver position.

        ``logit`` selects the per-relation transform: ``None`` (the default)
        or :class:`~repro.kernels.attention.ops.AdditiveLogit` is GAT's
        additive leaky-relu over (N, H) halves (``negative_slope`` only
        applies here, back-compat); :class:`DotLogit` is the scaled dot
        product over (N, H, D) halves with an optional per-head ``prior``
        (HGT's ``mu[rel]``). ``return_carry=True`` skips the softmax divide
        and returns the :class:`SoftmaxCarry` ``(m, l, acc)`` instead, so
        several relations' carries merge into one cross-type softmax
        (``merge_carries`` + ``finalize_carry``).

        Mirrors :meth:`matmul`'s dispatch tree: with a (loader-prefilled or
        demand-filled) ELL cache and Pallas dispatch on, the fused flash
        kernel runs one launch per bucket (differentiable via the ops-level
        custom VJP — no ``(E, H, F)`` edge-message materialisation);
        otherwise — CPU/GPU, or tracing without a packed cache — the COO
        segment oracle runs. ``edge_weight`` (COO order — the folded
        explainer mask) multiplies messages *after* the softmax, no
        renormalisation. ``return_attention`` additionally returns the
        per-edge (E, H) coefficients, recovered on the fused path by
        scattering the panel softmax through the COO-keyed ``ell_pos``.
        """
        from repro.kernels import use_pallas
        from repro.kernels.attention import ops as attn_ops
        from repro.kernels.attention import ref as attn_ref
        num_rows = self.num_src_nodes if transpose else self.num_dst_nodes
        take_pallas = use_pallas() if force_pallas is None else force_pallas
        additive = logit is None or isinstance(logit, attn_ops.AdditiveLogit)
        if additive and not return_carry:
            # GAT fast path — byte-identical to the pre-typed-logit code.
            if logit is not None:
                negative_slope = logit.negative_slope
            if take_pallas:
                ell = self.get_ell(transpose=transpose)
                if ell is not None:
                    out = attn_ops.gat_attend_ell(
                        ell, alpha_src, alpha_dst, z, edge_weight,
                        num_rows=num_rows, negative_slope=negative_slope,
                        force_pallas=take_pallas, interpret=interpret)
                    if not return_attention:
                        return out
                    alpha = attn_ops.gat_alpha_ell(
                        ell, alpha_src, alpha_dst,
                        num_edges=self.num_edges,
                        negative_slope=negative_slope)
                    return out, alpha
            # COO oracle: CPU/GPU dispatch, or tracing w/o a packed cache.
            send, recv = (self.dst, self.src) if transpose else (self.src,
                                                                 self.dst)
            out, alpha = attn_ref.gat_attend_coo(
                send, recv, alpha_src, alpha_dst, z, num_rows=num_rows,
                negative_slope=negative_slope, edge_weight=edge_weight)
            return (out, alpha) if return_attention else out
        # Typed / carry path.
        spec = attn_ops.AdditiveLogit(negative_slope) if logit is None \
            else logit
        carry = None
        if take_pallas:
            ell = self.get_ell(transpose=transpose)
            if ell is not None:
                carry = attn_ops.attn_carry_ell(
                    ell, alpha_src, alpha_dst, z, edge_weight,
                    num_rows=num_rows, logit=spec, prior=prior,
                    force_pallas=take_pallas, interpret=interpret)
        if carry is None:
            send, recv = (self.dst, self.src) if transpose else (self.src,
                                                                 self.dst)
            a_s = alpha_src[..., None] if alpha_src.ndim == 2 else alpha_src
            a_d = alpha_dst[..., None] if alpha_dst.ndim == 2 else alpha_dst
            m, lsum, acc = attn_ref.attn_carry_coo(
                send, recv, a_s, a_d, z, num_rows=num_rows,
                logit_kind=attn_ops._logit_kind(spec),
                negative_slope=attn_ops._logit_slope(spec),
                prior=attn_ops._effective_prior(spec, prior, z.shape[1])
                if attn_ops._logit_kind(spec) == "dot" else None,
                edge_weight=edge_weight)
            carry = attn_ops.SoftmaxCarry(m, lsum, acc)
        if return_carry:
            return carry
        out = attn_ops.finalize_carry(carry, z.dtype)
        if return_attention:
            alpha = self.attend_alpha(
                alpha_src, alpha_dst, logit=spec, prior=prior,
                m=carry.m, l=carry.l, transpose=transpose,
                force_pallas=force_pallas)
            return out, alpha
        return out

    def attend_alpha(self, alpha_src: jnp.ndarray, alpha_dst: jnp.ndarray,
                     *, logit, prior: Optional[jnp.ndarray] = None,
                     m: jnp.ndarray, l: jnp.ndarray,
                     transpose: bool = False,
                     force_pallas: Optional[bool] = None) -> jnp.ndarray:
        """Per-edge attention (E, H) of this relation against *merged*
        softmax statistics ``(m, l)`` (from :meth:`attend`'s carry /
        ``merge_carries``) — the typed ``return_attention`` round trip.
        With a packed ELL cache the panels scatter through the COO-keyed
        ``ell_pos``; otherwise the COO fallback materialises the logits.
        """
        from repro.kernels import use_pallas
        from repro.kernels.attention import ops as attn_ops
        from repro.kernels.attention import ref as attn_ref
        take_pallas = use_pallas() if force_pallas is None else force_pallas
        ell = self.get_ell(transpose=transpose) if take_pallas else None
        if ell is not None:
            return attn_ops.attn_alpha_ell(
                ell, alpha_src, alpha_dst, num_edges=self.num_edges,
                logit=logit, prior=prior, m=m, l=l)
        send, recv = (self.dst, self.src) if transpose else (self.src,
                                                             self.dst)
        a_s = alpha_src[..., None] if alpha_src.ndim == 2 else alpha_src
        a_d = alpha_dst[..., None] if alpha_dst.ndim == 2 else alpha_dst
        kind = attn_ops._logit_kind(logit)
        heads = m.shape[1]
        return attn_ref.attn_alpha_coo(
            send, recv, a_s, a_d, m=m, l=l, logit_kind=kind,
            negative_slope=attn_ops._logit_slope(logit),
            prior=attn_ops._effective_prior(logit, prior, heads)
            if kind == "dot" else None)

    # ------------------------------------------------------------------ utility
    def to_undirected(self) -> "EdgeIndex":
        src = jnp.concatenate([self.src, self.dst])
        dst = jnp.concatenate([self.dst, self.src])
        n = max(self.num_src_nodes, self.num_dst_nodes)
        return EdgeIndex(jnp.stack([src, dst]), n, n, None, True)

    def validate(self) -> "EdgeIndex":
        """Host-side sanity check (not for use inside jit)."""
        d = np.asarray(self.data)
        if d.size:
            assert d.min() >= 0, "negative node index"
            assert d[0].max() < self.num_src_nodes, "src index out of range"
            assert d[1].max() < self.num_dst_nodes, "dst index out of range"
        if self.sort_order == "row":
            assert bool(np.all(np.diff(d[0]) >= 0)), "not sorted by row"
        if self.sort_order == "col":
            assert bool(np.all(np.diff(d[1]) >= 0)), "not sorted by col"
        return self


def coalesce(edge_index: EdgeIndex) -> EdgeIndex:
    """Remove duplicate edges (host-side helper, mirrors PyG coalesce)."""
    d = np.asarray(edge_index.data)
    key = d[0].astype(np.int64) * edge_index.num_dst_nodes + d[1]
    _, idx = np.unique(key, return_index=True)
    idx.sort()
    return EdgeIndex(jnp.asarray(d[:, idx]), edge_index.num_src_nodes,
                     edge_index.num_dst_nodes, None, edge_index.is_undirected)
