"""Unified message passing with metadata-driven path selection — paper C2.

Implements Eq. (1) of the paper: ``h_v' = f(h_v, {{ g(h_w, e_wv, h_v) }})``
with overridable ``message`` (g), first-class ``aggr`` ({{.}}) and ``update``
(f). The dispatcher mirrors PyG 2.0's accelerated message passing:

* **Fused path** — if the ``EdgeIndex`` is sorted / carries CSR-CSC caches,
  the default message (identity over source features, optionally edge-
  weighted) lowers to a single SpMM (`EdgeIndex.matmul`) with the cached
  transposed adjacency reused in the backward pass (via ``jax.grad`` the
  CSC gather/segment ops transpose to CSR ones, so the cache serves both
  directions — the paper's "caching CSR/CSC significantly reduces overhead
  during the backward pass").
* **Fused attention path** — attention-semantics steps (``alpha=...``, the
  GAT family) lower to ``EdgeIndex.attend``: the fused flash-GAT Pallas
  kernel over the same blocked-ELL buckets as the SpMM fast path (one VMEM
  pass: gather -> leaky-relu -> online masked softmax -> weighted
  accumulate), with the COO segment-softmax oracle as the CPU/GPU and
  traced-without-cache fallback.
* **Edge-level materialisation path** — custom messages, edge attributes, or
  an explainability callback ``c`` (paper §2.4) force gather->message->
  aggregate. This is the paper's fallback path, and the one the Explainer
  deliberately uses to inject masks uniformly across edges.
"""

from __future__ import annotations

from typing import Callable, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import aggr as aggr_lib
from repro.core.edge_index import EdgeIndex
from repro.nn.module import Module

ArrayOrPair = Union[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]


class MessagePassing(Module):
    """Base class. Subclasses override ``message`` / ``update`` (+ params)."""

    def __init__(self, aggr="sum", flow: str = "source_to_target"):
        assert flow in ("source_to_target", "target_to_source")
        self.aggr = aggr_lib.resolve(aggr)
        self.flow = flow

    # -- overridables --------------------------------------------------------
    def message(self, params, x_j: jnp.ndarray, x_i: Optional[jnp.ndarray],
                edge_attr: Optional[jnp.ndarray]) -> jnp.ndarray:
        """g(h_w, e_wv, h_v): default = copy source features."""
        return x_j

    def update(self, params, out: jnp.ndarray,
               x: Optional[jnp.ndarray]) -> jnp.ndarray:
        """f(h_v, aggregated): default = identity."""
        return out

    # -- dispatch -------------------------------------------------------------
    def _message_is_default(self) -> bool:
        return type(self).message is MessagePassing.message

    def _update_is_default(self) -> bool:
        return type(self).update is MessagePassing.update

    def propagate(self, params, edge_index, x: ArrayOrPair,
                  edge_attr: Optional[jnp.ndarray] = None,
                  edge_weight: Optional[jnp.ndarray] = None,
                  num_nodes: Optional[int] = None,
                  message_callback: Optional[Callable] = None,
                  edge_mask: Optional[jnp.ndarray] = None,
                  alpha: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
                  negative_slope: float = 0.2,
                  logit=None, prior: Optional[jnp.ndarray] = None,
                  return_carry: bool = False,
                  return_attention: bool = False) -> jnp.ndarray:
        """Run one message-passing step, choosing the optimal compute path.

        ``edge_mask`` is a per-edge multiplicative reweighting (the
        explainer's soft mask, paper §2.4) folded into ``edge_weight`` — so
        unlike ``message_callback`` it does NOT force edge-level
        materialisation: default-message convs keep the fused SpMM path, and
        gradients w.r.t. the mask flow through the kernel's custom VJP.

        ``alpha`` switches the step to *attention semantics* (GAT): a pair
        of dense per-node logit halves ``(alpha_src, alpha_dst)`` keyed to
        the graph's (src, dst) node sides; messages become softmax-weighted
        source features. The fused predicate extends to this mode — see
        :meth:`_propagate_attention`. ``logit``/``prior`` select the typed
        logit transform (``AdditiveLogit``/``DotLogit``), and
        ``return_carry=True`` returns the unfinalised ``SoftmaxCarry`` for
        cross-relation merging (HGT) instead of the aggregated output.
        """
        if edge_mask is not None:
            edge_weight = (edge_mask if edge_weight is None
                           else edge_weight * edge_mask)
        if alpha is not None:
            return self._propagate_attention(
                params, edge_index, x, alpha, edge_weight=edge_weight,
                num_nodes=num_nodes, message_callback=message_callback,
                negative_slope=negative_slope, logit=logit, prior=prior,
                return_carry=return_carry,
                return_attention=return_attention)
        if isinstance(x, tuple):
            x_src, x_dst = x
        else:
            x_src = x_dst = x

        if isinstance(edge_index, EdgeIndex):
            src, dst = edge_index.src, edge_index.dst
            n_dst = edge_index.num_dst_nodes
        else:
            src, dst = edge_index[0], edge_index[1]
            n_dst = num_nodes if num_nodes is not None else (
                x_dst.shape[0] if x_dst is not None else int(dst.max()) + 1)

        if self.flow == "target_to_source":
            src, dst = dst, src
            if isinstance(edge_index, EdgeIndex):
                n_dst = edge_index.num_src_nodes
            x_src, x_dst = x_dst, x_src

        # ---- fused SpMM path (paper: sorted EdgeIndex -> SpMM + segments)
        # All four dense-reducible modes lower to the SpMM kernel: the
        # blocked-ELL Pallas kernel (and the XLA oracle) implement max/min
        # masking natively, so the dispatcher no longer restricts to
        # sum/mean. target_to_source flow is the same SpMM against A^T —
        # `matmul(transpose=True)` reuses the CSR cache instead of falling
        # back to edge-level materialisation.
        fused_ok = (
            self._message_is_default()
            and message_callback is None
            and edge_attr is None
            and isinstance(edge_index, EdgeIndex)
            and self.aggr.name in ("sum", "mean", "max", "min")
        )
        if fused_ok:
            out = edge_index.matmul(
                x_src, edge_weight=edge_weight, reduce=self.aggr.name,
                transpose=(self.flow == "target_to_source"))
            return out if self._update_is_default() else self.update(
                params, out, x_dst)

        # ---- edge-level materialisation path
        x_j = jnp.take(x_src, src, axis=0)
        x_i = None if x_dst is None else jnp.take(x_dst, dst, axis=0)
        msg = self.message(params, x_j, x_i, edge_attr)
        if edge_weight is not None:
            msg = msg * edge_weight[:, None].astype(msg.dtype)
        if message_callback is not None:  # explainability hook c(.)
            msg = message_callback(msg)

        # Sorted EdgeIndex -> hand the aggregation its segment ptr (lets
        # ptr-needing aggregations like median run, and marks contiguity).
        ptr = None
        if (isinstance(edge_index, EdgeIndex)
                and edge_index.sort_order == "col"
                and self.flow == "source_to_target"):
            ptr = edge_index.get_csc()[0]
        out = self.aggr.apply(params.get("aggr", {}) if isinstance(params, dict)
                              else {}, msg, dst, n_dst, ptr=ptr)
        return out if self._update_is_default() else self.update(
            params, out, x_dst)

    # -- attention semantics ---------------------------------------------------
    def _propagate_attention(self, params, edge_index, z: ArrayOrPair,
                             alpha, *, edge_weight: Optional[jnp.ndarray],
                             num_nodes: Optional[int],
                             message_callback: Optional[Callable],
                             negative_slope: float,
                             logit=None, prior=None,
                             return_carry: bool = False,
                             return_attention: bool):
        """Attention-weighted aggregation (the GAT step), fused when it can.

        ``z`` is (N, H, F) per-head features (or a bipartite (src, dst)
        pair), ``alpha`` the per-node logit halves keyed to the graph's
        (src, dst) sides — the conv computes them with the attention vector
        matching each side's *role* under its flow. The widened fused
        predicate: a default attention message (no ``message_callback``)
        over an ``EdgeIndex`` lowers to :meth:`EdgeIndex.attend`, which
        dispatches the fused flash-GAT Pallas kernel when an ELL cache is
        packed (loader-prefilled batches, ``fill_cache()``, or eager demand
        fill) and the COO segment oracle otherwise — no ``(E, H, F)``
        edge-message tensor on the kernel path, and the explainer's
        ``edge_mask`` (already folded into ``edge_weight`` by
        :meth:`propagate`) stays fused as a post-softmax per-slot weight.
        ``target_to_source`` flow rides the transpose (CSR-derived) table
        with the sender/receiver roles swapped.

        The aggregation is the attention-weighted sum *by definition* —
        ``self.aggr`` is not consulted in this mode. An overridden
        ``update`` hook still runs (on the per-head aggregate, with the
        receiver-side projected features as its ``x`` argument) — except in
        ``return_carry`` mode, where the unfinalised ``SoftmaxCarry`` is
        returned as-is for the caller to merge/finalize (HGT).
        """
        z_src, z_dst = z if isinstance(z, tuple) else (z, z)
        a_src, a_dst = alpha
        transpose = self.flow == "target_to_source"
        if transpose:
            z_send, z_recv, a_send, a_recv = z_dst, z_src, a_dst, a_src
        else:
            z_send, z_recv, a_send, a_recv = z_src, z_dst, a_src, a_dst

        typed = logit is not None or return_carry
        if typed and message_callback is not None:
            raise NotImplementedError(
                "message_callback (edge-level materialisation) is not "
                "supported with typed logits / carry-mode attention")
        if typed and not isinstance(edge_index, EdgeIndex):
            # Raw edge arrays: wrap them so the COO carry oracle inside
            # EdgeIndex.attend serves this branch too (no cache -> oracle).
            send, recv = edge_index[0], edge_index[1]
            n_out = (num_nodes if num_nodes is not None
                     else z_recv.shape[0])
            if transpose:
                send, recv = recv, send
            n_send = z_send.shape[0]
            edge_index = EdgeIndex(jnp.stack([send, recv]), n_send, n_out)
            transpose = False

        if typed:
            res = edge_index.attend(
                z_send, a_send, a_recv, negative_slope=negative_slope,
                logit=logit, prior=prior, edge_weight=edge_weight,
                transpose=transpose, return_carry=return_carry,
                return_attention=return_attention)
            if return_carry:
                return res
        elif message_callback is None and isinstance(edge_index, EdgeIndex):
            res = edge_index.attend(
                z_send, a_send, a_recv, negative_slope=negative_slope,
                edge_weight=edge_weight, transpose=transpose,
                return_attention=return_attention)
        else:
            # edge-level materialisation: raw edge arrays, or an explainer
            # callback that must observe every (E, H*F) message — the same
            # COO oracle EdgeIndex.attend falls back to (shared helper, so
            # fused-vs-fallback numerics cannot drift between entry points)
            from repro.kernels.attention import ref as attn_ref
            if isinstance(edge_index, EdgeIndex):
                send, recv = edge_index.src, edge_index.dst
                n_out = (edge_index.num_src_nodes if transpose
                         else edge_index.num_dst_nodes)
            else:
                send, recv = edge_index[0], edge_index[1]
                n_out = (num_nodes if num_nodes is not None
                         else z_recv.shape[0])
            if transpose:
                send, recv = recv, send
            out, alpha_e = attn_ref.gat_attend_coo(
                send, recv, a_send, a_recv, z_send, num_rows=n_out,
                negative_slope=negative_slope, edge_weight=edge_weight,
                message_callback=message_callback)
            res = (out, alpha_e) if return_attention else out
        if self._update_is_default():
            return res
        if return_attention:
            out, alpha_e = res
            return self.update(params, out, z_recv), alpha_e
        return self.update(params, res, z_recv)
