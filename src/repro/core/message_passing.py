"""Unified message passing with metadata-driven path selection — paper C2.

Implements Eq. (1) of the paper: ``h_v' = f(h_v, {{ g(h_w, e_wv, h_v) }})``
with overridable ``message`` (g), first-class ``aggr`` ({{.}}) and ``update``
(f). The dispatcher mirrors PyG 2.0's accelerated message passing:

* **Fused path** — if the ``EdgeIndex`` is sorted / carries CSR-CSC caches,
  the default message (identity over source features, optionally edge-
  weighted) lowers to a single SpMM (`EdgeIndex.matmul`) with the cached
  transposed adjacency reused in the backward pass (via ``jax.grad`` the
  CSC gather/segment ops transpose to CSR ones, so the cache serves both
  directions — the paper's "caching CSR/CSC significantly reduces overhead
  during the backward pass").
* **Edge-level materialisation path** — custom messages, edge attributes, or
  an explainability callback ``c`` (paper §2.4) force gather->message->
  aggregate. This is the paper's fallback path, and the one the Explainer
  deliberately uses to inject masks uniformly across edges.
"""

from __future__ import annotations

from typing import Callable, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import aggr as aggr_lib
from repro.core.edge_index import EdgeIndex
from repro.nn.module import Module

ArrayOrPair = Union[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]


class MessagePassing(Module):
    """Base class. Subclasses override ``message`` / ``update`` (+ params)."""

    def __init__(self, aggr="sum", flow: str = "source_to_target"):
        assert flow in ("source_to_target", "target_to_source")
        self.aggr = aggr_lib.resolve(aggr)
        self.flow = flow

    # -- overridables --------------------------------------------------------
    def message(self, params, x_j: jnp.ndarray, x_i: Optional[jnp.ndarray],
                edge_attr: Optional[jnp.ndarray]) -> jnp.ndarray:
        """g(h_w, e_wv, h_v): default = copy source features."""
        return x_j

    def update(self, params, out: jnp.ndarray,
               x: Optional[jnp.ndarray]) -> jnp.ndarray:
        """f(h_v, aggregated): default = identity."""
        return out

    # -- dispatch -------------------------------------------------------------
    def _message_is_default(self) -> bool:
        return type(self).message is MessagePassing.message

    def _update_is_default(self) -> bool:
        return type(self).update is MessagePassing.update

    def propagate(self, params, edge_index, x: ArrayOrPair,
                  edge_attr: Optional[jnp.ndarray] = None,
                  edge_weight: Optional[jnp.ndarray] = None,
                  num_nodes: Optional[int] = None,
                  message_callback: Optional[Callable] = None,
                  edge_mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
        """Run one message-passing step, choosing the optimal compute path.

        ``edge_mask`` is a per-edge multiplicative reweighting (the
        explainer's soft mask, paper §2.4) folded into ``edge_weight`` — so
        unlike ``message_callback`` it does NOT force edge-level
        materialisation: default-message convs keep the fused SpMM path, and
        gradients w.r.t. the mask flow through the kernel's custom VJP.
        """
        if edge_mask is not None:
            edge_weight = (edge_mask if edge_weight is None
                           else edge_weight * edge_mask)
        if isinstance(x, tuple):
            x_src, x_dst = x
        else:
            x_src = x_dst = x

        if isinstance(edge_index, EdgeIndex):
            src, dst = edge_index.src, edge_index.dst
            n_dst = edge_index.num_dst_nodes
        else:
            src, dst = edge_index[0], edge_index[1]
            n_dst = num_nodes if num_nodes is not None else (
                x_dst.shape[0] if x_dst is not None else int(dst.max()) + 1)

        if self.flow == "target_to_source":
            src, dst = dst, src
            if isinstance(edge_index, EdgeIndex):
                n_dst = edge_index.num_src_nodes
            x_src, x_dst = x_dst, x_src

        # ---- fused SpMM path (paper: sorted EdgeIndex -> SpMM + segments)
        # All four dense-reducible modes lower to the SpMM kernel: the
        # blocked-ELL Pallas kernel (and the XLA oracle) implement max/min
        # masking natively, so the dispatcher no longer restricts to
        # sum/mean. target_to_source flow is the same SpMM against A^T —
        # `matmul(transpose=True)` reuses the CSR cache instead of falling
        # back to edge-level materialisation.
        fused_ok = (
            self._message_is_default()
            and message_callback is None
            and edge_attr is None
            and isinstance(edge_index, EdgeIndex)
            and self.aggr.name in ("sum", "mean", "max", "min")
        )
        if fused_ok:
            out = edge_index.matmul(
                x_src, edge_weight=edge_weight, reduce=self.aggr.name,
                transpose=(self.flow == "target_to_source"))
            return out if self._update_is_default() else self.update(
                params, out, x_dst)

        # ---- edge-level materialisation path
        x_j = jnp.take(x_src, src, axis=0)
        x_i = None if x_dst is None else jnp.take(x_dst, dst, axis=0)
        msg = self.message(params, x_j, x_i, edge_attr)
        if edge_weight is not None:
            msg = msg * edge_weight[:, None].astype(msg.dtype)
        if message_callback is not None:  # explainability hook c(.)
            msg = message_callback(msg)

        # Sorted EdgeIndex -> hand the aggregation its segment ptr (lets
        # ptr-needing aggregations like median run, and marks contiguity).
        ptr = None
        if (isinstance(edge_index, EdgeIndex)
                and edge_index.sort_order == "col"
                and self.flow == "source_to_target"):
            ptr = edge_index.get_csc()[0]
        out = self.aggr.apply(params.get("aggr", {}) if isinstance(params, dict)
                              else {}, msg, dst, n_dst, ptr=ptr)
        return out if self._update_is_default() else self.update(
            params, out, x_dst)
