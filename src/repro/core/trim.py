"""Layer-wise trimming of BFS-ordered subgraphs — paper C8 (§2.3, Table 2).

A GNN on a k-hop sampled subgraph only needs hop-``h`` nodes during the first
``k - h`` layers: nodes sampled in later hops stop contributing to the seed
representations. PyG trims by slicing adjacency/features along the BFS
ordering on the fly ("zero-copy"). Here the sampler emits *budgeted, padded*
hops (static per-hop sizes), so trimming is a **static** ``lax.slice`` — free
at trace time, fused by XLA, and crucially shape-stable so the jit cache
never misses. This is the TPU/XLA rendition of the paper's zero-copy narrow.

Trimming no longer drops a loader-prefilled static-layout ELL cache: every
slot's in-edges come from exactly one hop (a block is the frontier exactly
once), so the trimmed graph's ELL is the parent's with the rows of
dropped-hop slots masked to capacity padding — a shape-stable elementwise
``where`` that works on tracers, keeping the Pallas SpMM fast path on inner
layers (see ``_trim_ell``). Because ``EdgeIndex`` keys ``ell_pos`` to COO
edge order and kept slots reference only kept (prefix) edges, the masked
cache serves *weighted* matmuls too — per-layer ``edge_weight`` slices
gather straight through the inherited positions, no oracle detour. The
masked cache equally serves the fused *attention* path
(``EdgeIndex.attend``): kept rows keep their neighbor slots, dropped rows
become capacity padding the kernel softmax masks out, so deep GATs keep
the flash-GAT kernel on inner hops. A demand-filled *transpose* ELL
survives too (``_trim_ell_transpose`` — per-slot masking, since transpose
rows' out-edges don't form a hop prefix), keeping reversed-flow
(``target_to_source``) attends and transpose matmuls on the kernel.
``trim_to_layer_hetero`` applies the same per-(node type, edge type) —
deep hetero GNNs keep every relation on the fast path as they trim.
"""

from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax.numpy as jnp

from repro.core.edge_index import EdgeIndex


def trim_sizes(num_nodes_per_hop: Sequence[int],
               num_edges_per_hop: Sequence[int],
               layer: int) -> Tuple[int, int]:
    """(nodes, edges) still needed when entering GNN layer ``layer`` (0-based).

    With L = len(hops) - 1 total layers, at layer l we keep hops 0..L-l of
    nodes and hops 1..L-l of edges (edge hop h connects hop h-1/h nodes).
    """
    depth = len(num_edges_per_hop)
    keep_hops = depth - layer
    n_nodes = int(sum(num_nodes_per_hop[:keep_hops + 1]))
    n_edges = int(sum(num_edges_per_hop[:keep_hops]))
    return n_nodes, n_edges


def _trim_ell(ell, boundary: int):
    """Mask a static-layout bucketed ELL down to slots that keep edges.

    ``boundary`` is the first slot whose in-edges are dropped (hop-``h``
    edges always point into the hop ``h-1`` block, so kept slots form a
    prefix). Rows at/past the boundary become capacity padding (``-1`` row
    ids, all-invalid neighbor slots) — shapes are unchanged, so this is
    jit-stable and valid on tracer leaves. ``ell_pos`` is masked too; the
    surviving slots' positions index the COO (BFS) edge order and point only
    at kept prefix edges, so the trimmed cache serves weighted matmuls
    against per-layer-sliced ``edge_weight`` vectors directly.
    """
    if ell is None:
        return None
    trimmed = []
    for row_ids, ell_idx, ell_pos in ell:
        keep = (row_ids >= 0) & (row_ids < boundary)
        trimmed.append((jnp.where(keep, row_ids, -1),
                        jnp.where(keep[:, None], ell_idx, -1),
                        jnp.where(keep[:, None], ell_pos, -1)))
    return tuple(trimmed)


def _trim_ell_transpose(ell, n_edges: int):
    """Mask a *transpose* (CSR-derived) bucketed ELL down to kept edges.

    Unlike the forward table, a transpose row's (source node's) out-edges
    span arbitrary hops, so kept slots do NOT form a row prefix — instead
    each slot is kept iff its COO-keyed ``ell_pos`` references a surviving
    (prefix) edge. Shape-stable elementwise ``where``, valid on tracers;
    rows whose slots all drop become empty rows (0 output, the oracle's
    empty-segment convention). Keeps reversed-flow (``transpose=True``)
    SpMM and fused-attention dispatch on the kernel for inner layers.
    """
    if ell is None:
        return None
    trimmed = []
    for row_ids, ell_idx, ell_pos in ell:
        keep = (ell_pos >= 0) & (ell_pos < n_edges)
        trimmed.append((row_ids,
                        jnp.where(keep, ell_idx, -1),
                        jnp.where(keep, ell_pos, -1)))
    return tuple(trimmed)


def _trim_edge_index(edge_index: EdgeIndex, n_src: int, n_dst: int,
                     n_edges: int, recv_boundary: int) -> EdgeIndex:
    """Static COO slice + ELL masks; CSR/CSC caches are dropped (their edge
    dimension is data-dependent after a trim) and re-derived on demand."""
    return EdgeIndex(
        edge_index.data[:, :n_edges], n_src, n_dst,
        edge_index.sort_order, edge_index.is_undirected,
        _ell=_trim_ell(edge_index._ell, recv_boundary),
        _ell_t=_trim_ell_transpose(edge_index._ell_t, n_edges))


def trim_to_layer(layer: int, num_nodes_per_hop: Sequence[int],
                  num_edges_per_hop: Sequence[int], x: jnp.ndarray,
                  edge_index, edge_attr: Optional[jnp.ndarray] = None):
    """Slice (x, edge_index[, edge_attr]) to what layer ``layer`` needs.

    Requires BFS ordering: node slots grouped by hop (seeds first), edge
    slots grouped by the hop that discovered them — exactly what
    ``repro.data.sampler`` produces. All sizes static -> jit-stable. A
    prefilled static-layout ELL cache survives the trim (masked, see
    ``_trim_ell``), so trimmed inner layers still hit the Pallas kernel.
    """
    n_nodes, n_edges = trim_sizes(num_nodes_per_hop, num_edges_per_hop, layer)
    x_t = x[:n_nodes]
    if isinstance(edge_index, EdgeIndex):
        keep_hops = len(num_edges_per_hop) - layer
        recv = int(sum(num_nodes_per_hop[:keep_hops]))
        ei_t = _trim_edge_index(edge_index, n_nodes, n_nodes, n_edges, recv)
    else:
        ei_t = edge_index[:, :n_edges]
    if edge_attr is not None:
        return x_t, ei_t, edge_attr[:n_edges]
    return x_t, ei_t, None


def trim_to_layer_hetero(
        layer: int,
        num_nodes_dict: Dict[str, Sequence[int]],
        num_edges_dict: Dict[Tuple[str, str, str], Sequence[int]],
        x_dict: Dict[str, jnp.ndarray],
        edge_index_dict: Dict[Tuple[str, str, str], jnp.ndarray],
        edge_attr_dict: Optional[Dict] = None):
    """Heterogeneous layer-wise trim: per node type and per edge type.

    ``num_nodes_dict``/``num_edges_dict`` are the hetero sampler's per-hop
    budgets. Each relation's edges are sliced by its own hop counts; the
    node/ELL boundaries come from its endpoint types. Per-relation
    static-layout ELL caches survive as masked caches (the hetero fast
    path on inner layers).
    """
    depth = len(next(iter(num_edges_dict.values())))
    keep = depth - layer
    n_nodes = {t: int(sum(v[:keep + 1])) for t, v in num_nodes_dict.items()}
    recv = {t: int(sum(v[:keep])) for t, v in num_nodes_dict.items()}
    x_t = {t: x[:n_nodes[t]] for t, x in x_dict.items()}
    ei_t = {}
    for et, ei in edge_index_dict.items():
        n_e = int(sum(num_edges_dict[et][:keep]))
        if isinstance(ei, EdgeIndex):
            ei_t[et] = _trim_edge_index(ei, n_nodes[et[0]], n_nodes[et[2]],
                                        n_e, recv[et[2]])
        else:
            ei_t[et] = ei[:, :n_e]
    if edge_attr_dict is not None:
        attr_t = {et: (None if a is None
                       else a[:int(sum(num_edges_dict[et][:keep]))])
                  for et, a in edge_attr_dict.items()}
        return x_t, ei_t, attr_t
    return x_t, ei_t
