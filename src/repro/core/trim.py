"""Layer-wise trimming of BFS-ordered subgraphs — paper C8 (§2.3, Table 2).

A GNN on a k-hop sampled subgraph only needs hop-``h`` nodes during the first
``k - h`` layers: nodes sampled in later hops stop contributing to the seed
representations. PyG trims by slicing adjacency/features along the BFS
ordering on the fly ("zero-copy"). Here the sampler emits *budgeted, padded*
hops (static per-hop sizes), so trimming is a **static** ``lax.slice`` — free
at trace time, fused by XLA, and crucially shape-stable so the jit cache
never misses. This is the TPU/XLA rendition of the paper's zero-copy narrow.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax.numpy as jnp

from repro.core.edge_index import EdgeIndex


def trim_sizes(num_nodes_per_hop: Sequence[int],
               num_edges_per_hop: Sequence[int],
               layer: int) -> Tuple[int, int]:
    """(nodes, edges) still needed when entering GNN layer ``layer`` (0-based).

    With L = len(hops) - 1 total layers, at layer l we keep hops 0..L-l of
    nodes and hops 1..L-l of edges (edge hop h connects hop h-1/h nodes).
    """
    depth = len(num_edges_per_hop)
    keep_hops = depth - layer
    n_nodes = int(sum(num_nodes_per_hop[:keep_hops + 1]))
    n_edges = int(sum(num_edges_per_hop[:keep_hops]))
    return n_nodes, n_edges


def trim_to_layer(layer: int, num_nodes_per_hop: Sequence[int],
                  num_edges_per_hop: Sequence[int], x: jnp.ndarray,
                  edge_index, edge_attr: Optional[jnp.ndarray] = None):
    """Slice (x, edge_index[, edge_attr]) to what layer ``layer`` needs.

    Requires BFS ordering: node slots grouped by hop (seeds first), edge
    slots grouped by the hop that discovered them — exactly what
    ``repro.data.sampler`` produces. All sizes static -> jit-stable.
    """
    n_nodes, n_edges = trim_sizes(num_nodes_per_hop, num_edges_per_hop, layer)
    x_t = x[:n_nodes]
    if isinstance(edge_index, EdgeIndex):
        ei_t = EdgeIndex(edge_index.data[:, :n_edges], n_nodes, n_nodes,
                         edge_index.sort_order, edge_index.is_undirected)
    else:
        ei_t = edge_index[:, :n_edges]
    if edge_attr is not None:
        return x_t, ei_t, edge_attr[:n_edges]
    return x_t, ei_t, None
