"""Explainability — paper C11 (§2.4).

The ``Explainer`` bridges user GNNs, explanation algorithms, and graph data
to produce node-feature attributions A_V in R^{|V| x F} and edge
attributions a_E in R^{|E|}. Structural explanations inject an edge-level
soft mask that reweighs every message — the paper's c(.) mechanism, which
makes the non-differentiable edge set E differentiable for gradient-based
(Captum-style) algorithms. For mask-aware models (``BasicGNN``) the mask
rides the *fused* path as a multiplicative ``edge_weight``, so explanations
stay on the Pallas ELL kernel (whose custom VJP supplies the mask
gradients) even under ``REPRO_USE_PALLAS=1``; models without that support
fall back to the message-callback mechanism, which forces edge-level
materialisation (MessagePassing's fallback path).

Algorithms: 'gnn_explainer' (mask optimisation, Ying et al.), 'saliency',
'integrated_gradients' (the CaptumExplainer analogues), 'attention' (GAT
coefficient capture). Metrics: fidelity+/- and unfaithfulness.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Explanation:
    node_mask: Optional[jnp.ndarray]   # (N, F) feature attributions
    edge_mask: Optional[jnp.ndarray]   # (E,) edge attributions
    target: Optional[jnp.ndarray] = None
    metrics: dict = dataclasses.field(default_factory=dict)

    def top_edges(self, k: int) -> np.ndarray:
        return np.argsort(-np.asarray(self.edge_mask))[:k]


def _masked_forward(model, params, x, edge_index, edge_logits, feat_mask,
                    **kw):
    """Run the model with the soft edge mask injected.

    Models that advertise ``supports_edge_mask`` (``BasicGNN``) take the
    mask as a per-edge multiplicative ``edge_mask`` — it folds into the
    fused SpMM's ``edge_weight``, so explanation forward *and* backward
    passes ride the Pallas ELL kernel (its custom VJP supplies the
    ``dy[row] . x[col]`` mask cotangent) instead of forcing edge-level
    materialisation. Other models keep the message-callback mechanism c(.)
    (paper §2.4), which materialises messages per edge.
    """
    edge_w = jax.nn.sigmoid(edge_logits)
    xm = x if feat_mask is None else x * jax.nn.sigmoid(feat_mask)[None, :]
    if getattr(model, "supports_edge_mask", False):
        return model.apply(params, xm, edge_index, edge_mask=edge_w, **kw)

    def callback(msg):
        # convs may append self-loops beyond the original edge set; those
        # extra messages pass through unmasked (mask = 1)
        e = msg.shape[0]
        w = edge_w
        if e > w.shape[0]:
            w = jnp.concatenate([w, jnp.ones((e - w.shape[0],), w.dtype)])
        return msg * w[:e, None].astype(msg.dtype)

    return model.apply(params, xm, edge_index, message_callback=callback,
                       **kw)


class Explainer:
    def __init__(self, model, params, algorithm: str = "gnn_explainer",
                 epochs: int = 100, lr: float = 0.05,
                 edge_reg: float = 0.005, ent_reg: float = 0.1,
                 ig_steps: int = 16):
        self.model = model
        self.params = params
        self.algorithm = algorithm
        self.epochs = epochs
        self.lr = lr
        self.edge_reg = edge_reg
        self.ent_reg = ent_reg
        self.ig_steps = ig_steps

    def __call__(self, x, edge_index, node_idx: int,
                 target: Optional[int] = None, **kw) -> Explanation:
        logits = self.model.apply(self.params, x, edge_index, **kw)
        if target is None:
            target = int(jnp.argmax(logits[node_idx]))
        algo = getattr(self, f"_{self.algorithm}")
        expl = algo(x, edge_index, node_idx, target, **kw)
        expl.target = jnp.asarray(target)
        expl.metrics = self.evaluate(x, edge_index, node_idx, target, expl,
                                     **kw)
        return expl

    # ------------------------------------------------------------ algorithms
    def _gnn_explainer(self, x, edge_index, node_idx, target, **kw):
        e = edge_index.num_edges if hasattr(edge_index, "num_edges") else \
            edge_index.shape[1]
        f = x.shape[1]

        def loss_fn(masks):
            el, fl = masks
            out = _masked_forward(self.model, self.params, x, edge_index,
                                  el, fl, **kw)
            logp = jax.nn.log_softmax(out[node_idx])[target]
            ew = jax.nn.sigmoid(el)
            ent = -(ew * jnp.log(ew + 1e-9)
                    + (1 - ew) * jnp.log(1 - ew + 1e-9)).mean()
            return -logp + self.edge_reg * ew.sum() + self.ent_reg * ent

        masks = (jnp.full((e,), 1.0), jnp.full((f,), 1.0))
        # simple adam on the mask params
        m = jax.tree_util.tree_map(jnp.zeros_like, masks)
        v = jax.tree_util.tree_map(jnp.zeros_like, masks)
        grad_fn = jax.jit(jax.grad(loss_fn))
        for t in range(1, self.epochs + 1):
            g = grad_fn(masks)
            m = jax.tree_util.tree_map(
                lambda a, b: 0.9 * a + 0.1 * b, m, g)
            v = jax.tree_util.tree_map(
                lambda a, b: 0.999 * a + 0.001 * b * b, v, g)
            mh = jax.tree_util.tree_map(lambda a: a / (1 - 0.9 ** t), m)
            vh = jax.tree_util.tree_map(lambda a: a / (1 - 0.999 ** t), v)
            masks = jax.tree_util.tree_map(
                lambda p, a, b: p - self.lr * a / (jnp.sqrt(b) + 1e-8),
                masks, mh, vh)
        el, fl = masks
        return Explanation(node_mask=x * jax.nn.sigmoid(fl)[None, :],
                           edge_mask=jax.nn.sigmoid(el))

    def _saliency(self, x, edge_index, node_idx, target, **kw):
        e = edge_index.num_edges if hasattr(edge_index, "num_edges") else \
            edge_index.shape[1]

        def score(xin, el):
            out = _masked_forward(self.model, self.params, xin, edge_index,
                                  el, None, **kw)
            return out[node_idx, target]

        gx, ge = jax.grad(score, argnums=(0, 1))(
            x, jnp.full((e,), 20.0))  # sigmoid(20) ~ 1: mask-at-ones gradient
        return Explanation(node_mask=jnp.abs(gx), edge_mask=jnp.abs(ge))

    def _integrated_gradients(self, x, edge_index, node_idx, target, **kw):
        e = edge_index.num_edges if hasattr(edge_index, "num_edges") else \
            edge_index.shape[1]

        def score(xin, el):
            out = _masked_forward(self.model, self.params, xin, edge_index,
                                  el, None, **kw)
            return out[node_idx, target]

        grad_fn = jax.jit(jax.grad(score, argnums=(0, 1)))
        gx_acc = jnp.zeros_like(x)
        ge_acc = jnp.zeros((e,))
        ones = jnp.full((e,), 20.0)
        for alpha in np.linspace(1.0 / self.ig_steps, 1.0, self.ig_steps):
            gx, ge = grad_fn(x * alpha, ones * alpha)
            gx_acc = gx_acc + gx
            ge_acc = ge_acc + ge
        return Explanation(node_mask=jnp.abs(gx_acc * x) / self.ig_steps,
                           edge_mask=jnp.abs(ge_acc) / self.ig_steps)

    def _attention(self, x, edge_index, node_idx, target, **kw):
        """Capture attention coefficients from GAT-style layers."""
        conv0 = self.model.convs[0]
        p0 = self.params["conv0"]
        _, alpha = conv0.apply(p0, x, edge_index, return_attention=True, **kw)
        return Explanation(node_mask=None, edge_mask=alpha.mean(-1))

    # --------------------------------------------------------------- metrics
    def evaluate(self, x, edge_index, node_idx, target, expl: Explanation,
                 topk: int = 10, **kw) -> dict:
        """fidelity+ (necessity), fidelity- (sufficiency), unfaithfulness."""
        if expl.edge_mask is None:
            return {}
        full = jax.nn.softmax(
            self.model.apply(self.params, x, edge_index, **kw)[node_idx])
        keep = jnp.asarray(np.isin(
            np.arange(expl.edge_mask.shape[0]), expl.top_edges(topk)))
        hard_drop = jnp.where(keep, -20.0, 20.0)   # drop important edges
        hard_keep = jnp.where(keep, 20.0, -20.0)   # keep only important
        p_drop = jax.nn.softmax(_masked_forward(
            self.model, self.params, x, edge_index, hard_drop, None,
            **kw)[node_idx])
        p_keep = jax.nn.softmax(_masked_forward(
            self.model, self.params, x, edge_index, hard_keep, None,
            **kw)[node_idx])
        soft = jax.nn.softmax(_masked_forward(
            self.model, self.params, x, edge_index,
            jnp.log(expl.edge_mask + 1e-9) - jnp.log(1 - expl.edge_mask + 1e-9),
            None, **kw)[node_idx])
        kl = jnp.sum(full * (jnp.log(full + 1e-9) - jnp.log(soft + 1e-9)))
        return {
            "fidelity_plus": float(full[target] - p_drop[target]),
            "fidelity_minus": float(full[target] - p_keep[target]),
            "unfaithfulness": float(1 - jnp.exp(-kl)),
        }
