"""Benchmark aggregator: one function per paper table/claim.

Prints ``name,us_per_call,derived`` CSV rows (see per-module docstrings for
protocols). Heavy dry-run cells are *not* recompiled here — the roofline
table reads the cached ``results/dryrun`` JSONs (regenerate via
``python -m repro.launch.dryrun --all``).
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (chaos_recovery, dist_scaling,
                            explainer_fidelity, fastpath_audit,
                            grouped_matmul_bench, sampler_throughput,
                            spmm_bench, store_scaling,
                            table12_compile_trim)

    suites = [
        ("table12_compile_trim", table12_compile_trim.run),
        ("sampler_throughput", sampler_throughput.run),
        ("store_scaling", store_scaling.run),
        ("grouped_matmul", grouped_matmul_bench.run),
        ("spmm", spmm_bench.run),
        ("spmm_loader_step", spmm_bench.run_loader_step),
        ("spmm_train_step", spmm_bench.run_train_step),
        ("spmm_hetero_step", spmm_bench.run_hetero_step),
        ("spmm_gat_step", spmm_bench.run_gat_step),
        ("spmm_hgt_step", spmm_bench.run_hgt_step),
        ("dist_scaling", dist_scaling.run),
        ("fastpath_audit", fastpath_audit.run),
        ("explainer_fidelity", explainer_fidelity.run),
        ("chaos_recovery", chaos_recovery.run),
    ]
    failed = []
    for name, fn in suites:
        print(f"# ---- {name} ----", flush=True)
        try:
            fn()
        except Exception:
            failed.append(name)
            traceback.print_exc()
    print("# ---- roofline (cached dry-run) ----")
    try:
        import benchmarks.roofline as roofline
        for rec in roofline.load("results/dryrun", "1pod"):
            if rec["status"] == "ok":
                print(f"roofline/{rec['arch']}/{rec['shape']},"
                      f"{max(rec['t_compute_s'], rec['t_memory_s'], rec['t_collective_s']) * 1e6:.1f},"
                      f"dom={rec['dominant']} frac={roofline.fraction(rec):.4f}")
    except Exception:
        traceback.print_exc()
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
