"""Explainer quality (paper §2.4): fidelity+/-, unfaithfulness per algorithm.

Planted-motif protocol: a graph where a node's label is determined by a
specific set of 'ground-truth' edges; a good explainer should (a) rank those
edges highly and (b) show high fidelity+ (removing its top edges changes the
prediction). We report metrics per algorithm on a trained 2-layer GCN.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.edge_index import EdgeIndex
from repro.core.explain import Explainer
from repro.nn.gnn.models import make_model


def _planted_graph(rng, n=60, feat=8):
    """Label of node i = 1 iff it points to the 'hub' clique."""
    src, dst = [], []
    hub = list(range(4))
    for a in hub:
        for b in hub:
            if a != b:
                src.append(a), dst.append(b)
    labels = np.zeros(n, np.int64)
    for v in range(4, n):
        if rng.random() < 0.5:  # motif edge
            src.append(rng.choice(hub)), dst.append(v)
            labels[v] = 1
        for _ in range(3):  # noise edges
            src.append(int(rng.integers(4, n))), dst.append(v)
    x = rng.standard_normal((n, feat)).astype(np.float32)
    x[hub] += 3.0  # hub signature
    return np.array(src), np.array(dst), x, labels


def run():
    rng = np.random.default_rng(5)
    src, dst, x, y = _planted_graph(rng)
    n = len(x)
    ei = EdgeIndex.from_coo(src, dst, n, n)
    model = make_model("gcn", x.shape[1], 32, 2, 2)
    params = model.init(jax.random.PRNGKey(0))

    # quick training so explanations are about a real decision boundary
    xj, yj = jnp.asarray(x), jnp.asarray(y)

    def loss(p):
        out = model.apply(p, xj, ei)
        lp = jax.nn.log_softmax(out)
        return -jnp.take_along_axis(lp, yj[:, None], 1).mean()

    g = jax.jit(jax.grad(loss))
    for _ in range(60):
        grads = g(params)
        params = jax.tree_util.tree_map(lambda p, d: p - 0.1 * d, params,
                                        grads)
    acc = float((model.apply(params, xj, ei).argmax(-1) == yj).mean())
    emit("explainer/train_acc", acc * 100)

    motif_nodes = np.where(y == 1)[0][:5]
    for algo in ("saliency", "integrated_gradients", "gnn_explainer"):
        fps, fms, unf = [], [], []
        for v in motif_nodes:
            ex = Explainer(model, params, algorithm=algo, epochs=50)
            e = ex(xj, ei, node_idx=int(v))
            fps.append(e.metrics["fidelity_plus"])
            fms.append(e.metrics["fidelity_minus"])
            unf.append(e.metrics["unfaithfulness"])
        emit(f"explainer/{algo}/fidelity_plus", float(np.mean(fps)) * 1e3,
             "x1e-3")
        emit(f"explainer/{algo}/fidelity_minus", float(np.mean(fms)) * 1e3,
             "x1e-3")
        emit(f"explainer/{algo}/unfaithfulness", float(np.mean(unf)) * 1e3,
             "x1e-3")


if __name__ == "__main__":
    run()
