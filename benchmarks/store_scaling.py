"""Partitioned feature-store scaling (paper §2.3 cuGraph/WholeGraph claim).

Measures feature-fetch behaviour as partitions scale: remote-row fraction
under hash vs BFS (locality-aware) partitioning — the quantity that
determines loading scalability on real clusters — plus fetch latency.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, synthetic_graph
from repro.data.loader import NeighborLoader
from repro.data.partition import build_partitioned_stores


def run():
    ei, x, y = synthetic_graph(50_000, 16, 128, seed=3)
    for method in ("hash", "bfs"):
        for parts in (2, 4, 8):
            fs, gs, part = build_partitioned_stores(
                x, ei, parts, method=method)
            loader = NeighborLoader(fs, gs, num_neighbors=[10, 10],
                                    batch_size=256,
                                    input_nodes=np.where(part == 0)[0][:2048],
                                    labels_attr=None)
            fs.stats.update(local_rows=0, remote_rows=0, requests=0)
            t0 = time.perf_counter()
            nb = 0
            for b in loader:
                nb += 1
            dt = (time.perf_counter() - t0) / max(nb, 1) * 1e6
            s = fs.stats
            frac = s["remote_rows"] / max(s["remote_rows"] + s["local_rows"],
                                          1)
            emit(f"store/{method}/parts{parts}_batch_us", dt,
                 f"remote_frac={frac:.3f}")


if __name__ == "__main__":
    run()
