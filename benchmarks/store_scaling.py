"""Store-backed loading trajectory (paper §2.3 cuGraph/WholeGraph claim).

Five cells, written to ``BENCH_store.json`` via ``append_cell`` (the same
per-PR perf-trajectory convention as ``BENCH_spmm.json``):

  * ``store_locality``     — remote-row fraction + batch latency under hash
                             vs BFS partitioning as partitions scale, and
                             how ``partition_order=True`` seed grouping cuts
                             the partitions each batch's gather touches.
  * ``store_overlap``      — the tentpole: per-batch latency against a
                             latency-injected partitioned store, sequential
                             vs stage-pipelined producer (gather latency
                             hides behind neighboring batches' sample/pack).
  * ``store_hot_cache``    — cross-batch hot-row cache hit rate on the
                             power-law synthetic graph (hub features are
                             refetched every batch without it).
  * ``store_out_of_core``  — a feature matrix larger than the configured
                             host-memory budget streams out of a
                             ``MmapFeatureStore`` through the unchanged
                             jit'd train step with a single trace.
  * ``store_inmem_overhead`` — the in-memory fast path with the pipeline
                             enabled vs disabled (must stay within 5%).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import append_cell, emit, synthetic_graph
from repro.analysis.retrace import RetraceSentinel
from repro.data.data import Data
from repro.data.feature_store import CachedFeatureStore, MmapFeatureStore
from repro.data.graph_store import InMemoryGraphStore
from repro.data.loader import NeighborLoader
from repro.data.partition import build_partitioned_stores
from repro.data.resilience import ChaosFeatureStore, FailureSchedule


def _epoch_us(loader, max_batches: int = 10 ** 9) -> float:
    """Mean wall time per produced batch over (up to) one epoch."""
    t0 = time.perf_counter()
    nb = 0
    for _ in loader:
        nb += 1
        if nb >= max_batches:
            break
    return (time.perf_counter() - t0) / max(nb, 1) * 1e6


def run_locality(out_path: str = "BENCH_store.json") -> None:
    ei, x, y = synthetic_graph(50_000, 16, 128, seed=3)
    rows = []
    for method in ("hash", "bfs"):
        for parts in (2, 4, 8):
            fs, gs, part = build_partitioned_stores(x, ei, parts,
                                                    method=method)
            seeds = np.random.default_rng(0).permutation(50_000)[:2048]

            def make(po):
                return NeighborLoader(fs, gs, num_neighbors=[10, 10],
                                      batch_size=256, input_nodes=seeds,
                                      labels_attr=None, shuffle=True,
                                      partition_order=po, seed=0)

            fs.reset_stats()
            loader = make(False)
            # partitions each batch's gather touches, ordered vs shuffled
            touched = [len(np.unique(part[np.asarray(b.n_id)]))
                       for b in loader]
            s = fs.stats
            remote_frac = s["remote_rows"] / max(
                s["remote_rows"] + s["local_rows"], 1)
            batch_us = _epoch_us(make(False))
            touched_po = [len(np.unique(part[np.asarray(
                b.n_id)[np.asarray(b.seed_slots)]])) for b in make(True)]
            touched_seed = [len(np.unique(part[np.asarray(
                b.n_id)[np.asarray(b.seed_slots)]])) for b in make(False)]
            rows.append({
                "method": method, "parts": parts,
                "remote_frac": round(float(remote_frac), 4),
                "batch_us": round(batch_us, 1),
                "gather_parts_per_batch": round(float(np.mean(touched)), 2),
                "seed_parts_per_batch": round(
                    float(np.mean(touched_seed)), 2),
                "seed_parts_per_batch_ordered": round(
                    float(np.mean(touched_po)), 2),
            })
            emit(f"store/{method}/parts{parts}_batch_us", batch_us,
                 f"remote_frac={remote_frac:.3f} "
                 f"seed_parts={np.mean(touched_seed):.2f}->"
                 f"{np.mean(touched_po):.2f}")
    append_cell(out_path, {"cell": "store_locality",
                           "backend": jax.default_backend(), "rows": rows})


def run_overlap(out_path: str = "BENCH_store.json") -> None:
    """Sequential vs stage-pipelined producer against an injected-latency
    partitioned store — the remote-fetch stall the pipeline exists to
    hide. The injected wait models RPC/disk time (it releases the GIL, as
    real store I/O does), so gather latency of batch ``i`` overlaps the
    sampling and packing of batches ``i+1..i+depth``."""
    ei, x, y = synthetic_graph(20_000, 16, 64, seed=5)
    fs, gs, part = build_partitioned_stores(x, ei, 4, method="bfs")
    latency_s = 10e-3  # per feature fetch, on every call

    def make(depth):
        sched = FailureSchedule(seed=0, latency_rate=1.0,
                                latency_s=latency_s)
        chaos = ChaosFeatureStore(fs, sched)
        return NeighborLoader(
            chaos, gs, num_neighbors=[10, 5], batch_size=128,
            input_nodes=np.arange(4096), labels_attr=None, shuffle=True,
            pipeline_depth=depth, prefetch=depth if depth > 1 else 0,
            seed=0)

    seq_us = _epoch_us(make(1))
    pipe_us = _epoch_us(make(4))
    speedup = seq_us / pipe_us
    emit("store/overlap/seq_batch_us", seq_us)
    emit("store/overlap/pipe_batch_us", pipe_us, f"speedup={speedup:.2f}x")
    append_cell(out_path, {
        "cell": "store_overlap", "backend": jax.default_backend(),
        "fetch_latency_ms": latency_s * 1e3, "pipeline_depth": 4,
        "seq_batch_us": round(seq_us, 1),
        "pipe_batch_us": round(pipe_us, 1),
        "overlap_speedup": round(speedup, 2)})


def run_hot_cache(out_path: str = "BENCH_store.json") -> None:
    """Hot-row cache hit rate across batches of the power-law graph: hub
    nodes recur in nearly every sampled neighborhood, so a small bounded
    cache absorbs a large share of the fetch traffic."""
    ei, x, y = synthetic_graph(50_000, 16, 128, seed=3)
    fs, gs, part = build_partitioned_stores(x, ei, 4, method="bfs")
    cached = CachedFeatureStore(fs, capacity=16384, seed=0)
    loader = NeighborLoader(cached, gs, num_neighbors=[10, 10],
                            batch_size=256, input_nodes=np.arange(4096),
                            labels_attr=None, shuffle=True, seed=0)
    cached.reset_stats()
    batch_us = _epoch_us(loader)
    hit = cached.hit_rate()
    s = dict(cached.stats)
    emit("store/hot_cache/batch_us", batch_us,
         f"hit_rate={hit:.3f} evictions={s['evictions']}")
    append_cell(out_path, {
        "cell": "store_hot_cache", "backend": jax.default_backend(),
        "capacity": 16384, "batch_us": round(batch_us, 1),
        "hit_rate": round(hit, 4), "requests": s["requests"],
        "hits": s["hits"], "evictions": s["evictions"]})


def run_out_of_core(out_path: str = "BENCH_store.json") -> None:
    """A feature matrix over the host budget streams from disk through the
    one-trace jit'd step: MmapFeatureStore refuses full materialisation
    (budget) but serves per-batch gathers; the loader/step never notice."""
    n, feat, hidden = 30_000, 256, 64
    full_bytes = n * feat * 4
    budget = full_bytes // 4  # the matrix is 4x the in-memory budget
    rng = np.random.default_rng(9)
    ei, _, _ = synthetic_graph(n, 12, 8, seed=7)

    mfs = MmapFeatureStore(memory_budget_bytes=budget)
    mm = mfs.create_tensor((n, feat), np.float32, group="node", attr="x")
    for lo in range(0, n, 4096):  # chunked out-of-core fill
        hi = min(lo + 4096, n)
        mm[lo:hi] = rng.standard_normal((hi - lo, feat)).astype(np.float32)
    mm.flush()
    mfs.put_tensor(rng.integers(0, 8, n), group="node", attr="y")
    gs = InMemoryGraphStore()
    gs.put_edge_index(ei, num_nodes=n)

    loader = NeighborLoader(mfs, gs, num_neighbors=[10, 5], batch_size=256,
                            input_nodes=np.arange(4096), shuffle=True,
                            pipeline_depth=4, prefetch=4, seed=0)
    params = {"w1": jnp.asarray(
        rng.standard_normal((feat, hidden)) * 0.1, jnp.float32),
        "w2": jnp.asarray(rng.standard_normal((hidden, 8)) * 0.1,
                          jnp.float32)}
    sentinel = RetraceSentinel(budget=1)

    @jax.jit
    def step(params, batch):
        def loss_fn(p):
            h = jax.nn.relu(batch.edge_index.matmul(batch.x @ p["w1"]))
            out = batch.edge_index.matmul(h @ p["w2"])
            return (out[batch.seed_slots] ** 2).mean()
        return jax.value_and_grad(loss_fn)(params)

    step = sentinel.wrap(step, name="out_of_core_step")
    t0 = time.perf_counter()
    nb = 0
    for batch in loader:  # full epoch, features streamed from disk
        step(params, batch)[0].block_until_ready()
        nb += 1
    epoch_s = time.perf_counter() - t0
    sentinel.check()
    batch_us = epoch_s / nb * 1e6
    emit("store/out_of_core/batch_us", batch_us,
         f"trace_count={sentinel.count('out_of_core_step')} "
         f"feat_mb={full_bytes / 2 ** 20:.0f} "
         f"budget_mb={budget / 2 ** 20:.0f}")
    append_cell(out_path, {
        "cell": "store_out_of_core", "backend": jax.default_backend(),
        "nodes": n, "feat": feat, "feature_bytes": full_bytes,
        "memory_budget_bytes": budget, "batches": nb,
        "epoch_s": round(epoch_s, 3),
        "batch_us": round(batch_us, 1),
        "rows_read": mfs.stats["rows_read"],
        "trace_count": sentinel.count("out_of_core_step")})


def run_inmem_overhead(out_path: str = "BENCH_store.json") -> None:
    """The pipeline must not tax the in-memory fast path (<5%).

    Measured as users hit it: a loader feeding the jit'd train step,
    pipeline on vs off. Paired interleaved epochs (min-of-3 per side,
    median of the per-pair ratios) cancel machine load drift — in-memory
    gathers are GIL-bound numpy, so what this measures is the pipeline's
    residual thread/cache overhead, not a latency win."""
    rng = np.random.default_rng(11)
    n, e, feat, hidden = 20_000, 160_000, 256, 256
    data = Data(x=rng.standard_normal((n, feat)).astype(np.float32),
                edge_index=np.stack([rng.integers(0, n, e),
                                     rng.integers(0, n, e)]),
                y=rng.integers(0, 4, n))
    params = {"w1": jnp.asarray(
        rng.standard_normal((feat, hidden)) * 0.1, jnp.float32),
        "w2": jnp.asarray(rng.standard_normal((hidden, 4)) * 0.1,
                          jnp.float32)}

    @jax.jit
    def step(params, batch):
        def loss_fn(p):
            h = jax.nn.relu(batch.edge_index.matmul(batch.x @ p["w1"]))
            out = batch.edge_index.matmul(h @ p["w2"])
            return (out[batch.seed_slots] ** 2).mean()
        return jax.value_and_grad(loss_fn)(params)

    def epoch_s(depth):
        loader = NeighborLoader(data, data, num_neighbors=[10, 5],
                                batch_size=256, input_nodes=np.arange(4096),
                                shuffle=True, pipeline_depth=depth,
                                prefetch=2, seed=0)
        t0 = time.perf_counter()
        nb = 0
        for b in loader:
            step(params, b)[0].block_until_ready()
            nb += 1
        return (time.perf_counter() - t0) / nb

    epoch_s(1), epoch_s(4)  # warm jit + both producer modes
    ratios, base, pipe = [], [], []
    for _ in range(5):
        a = min(epoch_s(1) for _ in range(3))
        b = min(epoch_s(4) for _ in range(3))
        base.append(a)
        pipe.append(b)
        ratios.append(b / a)
    overhead = float(np.median(ratios)) - 1.0
    base_us, pipe_us = min(base) * 1e6, min(pipe) * 1e6
    emit("store/inmem/seq_batch_us", base_us)
    emit("store/inmem/pipe_batch_us", pipe_us,
         f"overhead={overhead * 100:.1f}%")
    append_cell(out_path, {
        "cell": "store_inmem_overhead", "backend": jax.default_backend(),
        "seq_batch_us": round(base_us, 1),
        "pipe_batch_us": round(pipe_us, 1),
        "pair_ratios": [round(r, 4) for r in ratios],
        "overhead_frac": round(overhead, 4)})


def run(out_path: str = "BENCH_store.json") -> None:
    run_locality(out_path)
    run_overlap(out_path)
    run_hot_cache(out_path)
    run_out_of_core(out_path)
    run_inmem_overhead(out_path)


if __name__ == "__main__":
    run()
