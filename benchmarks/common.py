"""Shared benchmark utilities: timing, synthetic graphs, CSV emission."""

from __future__ import annotations

import json
import os
import time
from typing import Callable, Tuple

import jax
import numpy as np


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5,
            **kwargs) -> float:
    """Median wall time per call in microseconds (block_until_ready)."""
    for _ in range(warmup):
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)


def synthetic_graph(num_nodes: int, avg_degree: int, feat: int,
                    seed: int = 0, num_classes: int = 16
                    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Random graph (power-law-ish out-degrees) + features + labels."""
    rng = np.random.default_rng(seed)
    num_edges = num_nodes * avg_degree
    # power-law-ish source selection concentrates hubs (real-world-like)
    src = (num_nodes * rng.power(3, num_edges)).astype(np.int64) % num_nodes
    dst = rng.integers(0, num_nodes, num_edges)
    x = rng.standard_normal((num_nodes, feat)).astype(np.float32)
    y = rng.integers(0, num_classes, num_nodes)
    return np.stack([src, dst]), x, y


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def append_cell(out_path: str, rec: dict) -> None:
    """Replace ``rec['cell']``'s record in a JSON trajectory file, keeping
    every other record (the per-PR perf-trajectory convention of
    ``BENCH_spmm.json``)."""
    records = []
    if os.path.exists(out_path):
        with open(out_path) as fh:
            records = [r for r in json.load(fh)
                       if r.get("cell") != rec["cell"]]
    records.append(rec)
    with open(out_path, "w") as fh:
        json.dump(records, fh, indent=2)
    print(f"# wrote {os.path.abspath(out_path)} (+ {rec['cell']} cell)")
