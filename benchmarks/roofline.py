"""Roofline reporting: aggregate dry-run JSONs into the §Roofline table.

Usage:
  python -m benchmarks.roofline [--dir results/dryrun] [--markdown]
"""

from __future__ import annotations

import argparse
import glob
import json
from pathlib import Path


def load(dir_: str, mesh: str = "1pod"):
    recs = []
    for f in sorted(glob.glob(f"{dir_}/*__{mesh}*.json")):
        recs.append(json.load(open(f)))
    return recs


def fraction(rec) -> float:
    """Roofline fraction: useful compute time / achievable step time.

    achievable step = max(compute, memory, collective) assuming perfect
    overlap of the three engines; useful = MODEL_FLOPS at peak.
    """
    t_step = max(rec["t_compute_s"], rec["t_memory_s"],
                 rec["t_collective_s"])
    t_useful = rec["model_flops"] / rec["n_chips"] / 197e12
    return t_useful / t_step if t_step else 0.0


def row(rec):
    if rec["status"] != "ok":
        return (f"| {rec['arch']} | {rec['shape']} | skipped | "
                f"{rec.get('reason', '')[:60]}… | | | | | |")
    return ("| {arch} | {shape} | {dom} | {tc:.4f} | {tm:.4f} | {tl:.4f} | "
            "{fr:.4f} | {ur:.3f} | {gb:.2f} |").format(
        arch=rec["arch"], shape=rec["shape"], dom=rec["dominant"],
        tc=rec["t_compute_s"], tm=rec["t_memory_s"],
        tl=rec["t_collective_s"], fr=fraction(rec),
        ur=rec.get("useful_ratio") or 0,
        gb=(rec.get("bytes_per_device") or 0) / 1e9)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="1pod")
    args = ap.parse_args()
    recs = load(args.dir, args.mesh)
    print("| arch | shape | dominant | t_compute | t_memory | t_collective "
          "| roofline_frac | useful_ratio | GB/dev |")
    print("|---|---|---|---|---|---|---|---|---|")
    for rec in recs:
        print(row(rec))


if __name__ == "__main__":
    main()
