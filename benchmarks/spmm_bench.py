"""SpMM perf sweep: blocked-ELL vs XLA segment oracle, JSON trajectory.

Sweeps (rows, K, F) cells over three implementations of the same sorted-
adjacency SpMM (paper §2.2 — the message-passing hot loop):

  * ``oracle``        — CSR gather + ``segment_sum`` (XLA-fused reference)
  * ``ell_xla``       — blocked-ELL dense-masked reduction lowered by XLA
  * ``ell_pallas``    — the pipelined Pallas kernel; compiled on TPU,
                        interpret mode elsewhere (timing then measures the
                        interpreter, so off-TPU it is recorded under
                        ``ell_pallas_interpret_us`` and skipped for the
                        larger cells)

Writes ``BENCH_spmm.json`` next to the repo root so the perf trajectory of
the kernel is recorded PR-over-PR. Also prints the usual CSV rows.
"""

from __future__ import annotations

import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import append_cell, emit, time_fn
from repro.analysis.retrace import RetraceSentinel
from repro.kernels.spmm import ops as spmm_ops, ref as spmm_ref

# (rows, K, F) cells. K is the padded neighbor budget per row.
CELLS = [
    (256, 4, 128),
    (256, 16, 128),
    (256, 16, 256),
    (1024, 4, 128),
    (1024, 16, 128),
    (1024, 16, 256),
    (4096, 8, 128),
    (4096, 32, 256),
]

# Interpret-mode Pallas is a correctness vehicle, not a perf one; only the
# small cells are worth the interpreter's while off-TPU.
INTERPRET_MAX_WORK = 256 * 16 * 256


def _make_cell(rng, rows: int, k: int, feat: int):
    """Random ELL table (~15% padding) + its exact CSR equivalent."""
    n = rows  # square-ish adjacency
    ell = rng.integers(0, n, (rows, k)).astype(np.int32)
    pad = rng.random((rows, k)) < 0.15
    ell[pad] = -1
    ell.sort(axis=1)  # -1s first ...
    ell = ell[:, ::-1].copy()  # ... then flipped: valid-prefix layout
    deg = (ell >= 0).sum(1)
    indptr = np.concatenate([[0], np.cumsum(deg)]).astype(np.int64)
    indices = ell[ell >= 0].astype(np.int32)
    x = rng.standard_normal((n, feat)).astype(np.float32)
    return ell, indptr, indices, x


def run(out_path: str = "BENCH_spmm.json") -> None:
    on_tpu = jax.default_backend() == "tpu"
    rng = np.random.default_rng(7)
    records = []
    for rows, k, feat in CELLS:
        ell, indptr, indices, x = _make_cell(rng, rows, k, feat)
        ell_j, x_j = jnp.asarray(ell), jnp.asarray(x)
        indptr_j, indices_j = jnp.asarray(indptr), jnp.asarray(indices)

        oracle = jax.jit(lambda p, i, x: spmm_ref.spmm_csr(
            p, i, x, num_rows=rows, reduce="sum"))
        ell_xla = jax.jit(lambda e, x: spmm_ref.spmm_ell(e, None, x))

        a = oracle(indptr_j, indices_j, x_j)
        b = ell_xla(ell_j, x_j)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)

        rec = {
            "rows": rows, "k": k, "feat": feat,
            "backend": jax.default_backend(),
            "oracle_us": time_fn(oracle, indptr_j, indices_j, x_j),
            "ell_xla_us": time_fn(ell_xla, ell_j, x_j),
        }
        run_pallas = on_tpu or rows * k * feat <= INTERPRET_MAX_WORK
        if run_pallas:
            interpret = not on_tpu
            pallas = jax.jit(lambda e, x: spmm_ops.spmm_ell(
                e, None, x, force_pallas=True, interpret=interpret))
            c = pallas(ell_j, x_j)
            np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                       rtol=1e-4, atol=1e-4)
            key = "ell_pallas_us" if on_tpu else "ell_pallas_interpret_us"
            rec[key] = time_fn(pallas, ell_j, x_j, warmup=1, iters=3)
        records.append(rec)
        tag = f"spmm/r{rows}k{k}f{feat}"
        emit(f"{tag}/oracle_us", rec["oracle_us"])
        emit(f"{tag}/ell_xla_us", rec["ell_xla_us"],
             f"vs_oracle={rec['oracle_us'] / rec['ell_xla_us']:.2f}x")

    # keep non-sweep cells (e.g. loader_step) from a previous run
    if os.path.exists(out_path):
        with open(out_path) as fh:
            records += [r for r in json.load(fh) if "cell" in r]
    with open(out_path, "w") as fh:
        json.dump(records, fh, indent=2)
    print(f"# wrote {os.path.abspath(out_path)} ({len(records)} cells)")


def run_loader_step(out_path: str = "BENCH_spmm.json") -> None:
    """End-to-end loader -> jit'd train-step cell (the PR-2 serving path).

    Measures what the jit-ready producer buys: a NeighborLoader batch with
    host-prefilled CSR/CSC (+ static ELL) caches flows through a jit'd
    2-layer GNN step as one pytree with a SINGLE compilation across
    batches, versus re-deriving the CSC sort inside the trace every step
    from the raw COO. Also proves the Pallas ELL dispatch from a
    loader-emitted batch on a small forced-interpret cell. Appends a
    ``loader_step`` record to ``BENCH_spmm.json``.
    """
    import time

    from repro.data.data import Data
    from repro.data.loader import NeighborLoader
    from repro.core.edge_index import EdgeIndex

    rng = np.random.default_rng(11)
    n, e, feat, hidden = 4096, 32768, 128, 64
    batch_size, fanouts = 64, [10, 5]
    data = Data(x=rng.standard_normal((n, feat)).astype(np.float32),
                edge_index=np.stack([rng.integers(0, n, e),
                                     rng.integers(0, n, e)]),
                y=rng.integers(0, 4, n))
    loader = NeighborLoader(data, data, num_neighbors=fanouts,
                            batch_size=batch_size, shuffle=True,
                            pipeline_depth=2, prefetch=2, prefill_ell=True,
                            seed=0)
    params = {
        "w1": jnp.asarray(rng.standard_normal((feat, hidden)) * 0.1,
                          jnp.float32),
        "w2": jnp.asarray(rng.standard_normal((hidden, 4)) * 0.1,
                          jnp.float32),
    }
    # The sentinel replaces the hand-rolled in-trace counter: a batch whose
    # shapes force a second compilation raises with a signature diff.
    sentinel = RetraceSentinel(budget=1)

    @jax.jit
    def step_cached(params, batch):
        def loss_fn(p):
            h = jax.nn.relu(batch.edge_index.matmul(batch.x @ p["w1"]))
            out = batch.edge_index.matmul(h @ p["w2"])
            return (out[batch.seed_slots] ** 2).mean()

        return jax.value_and_grad(loss_fn)(params)

    step_cached = sentinel.wrap(step_cached, name="loader_step")

    @functools.partial(jax.jit, static_argnums=(4,))
    def step_raw(params, x, edge_data, seed_slots, num_nodes):
        # identical math, but the CSC sort happens inside the trace
        ei = EdgeIndex(edge_data, int(num_nodes), int(num_nodes))

        def loss_fn(p):
            h = jax.nn.relu(ei.matmul(x @ p["w1"]))
            out = ei.matmul(h @ p["w2"])
            return (out[seed_slots] ** 2).mean()

        return jax.value_and_grad(loss_fn)(params)

    t0 = time.perf_counter()
    batches = []
    it = iter(loader)
    for _ in range(4):
        batches.append(next(it))
    make_batch_us = (time.perf_counter() - t0) / 4 * 1e6

    # warm up both variants, then time across distinct batches
    step_cached(params, batches[0])[0].block_until_ready()
    b0 = batches[0]
    step_raw(params, b0.x, b0.edge_index.data, b0.seed_slots,
             b0.num_nodes)[0].block_until_ready()

    def time_over_batches(fn, rounds: int = 3):
        t0 = time.perf_counter()
        for _ in range(rounds):
            for b in batches:
                fn(b)[0].block_until_ready()
        return (time.perf_counter() - t0) / (rounds * len(batches)) * 1e6

    cached_us = time_over_batches(lambda b: step_cached(params, b))
    raw_us = time_over_batches(
        lambda b: step_raw(params, b.x, b.edge_index.data, b.seed_slots,
                           b.num_nodes))
    sentinel.check()  # 1 signature across all batches, or raise with a diff

    # loader -> Pallas dispatch proof on a tiny forced-interpret cell
    small = NeighborLoader(data, data, num_neighbors=[4, 2], batch_size=8,
                           prefill_ell=True, seed=0)
    sb = next(iter(small))
    on_tpu = jax.default_backend() == "tpu"
    pallas_step = jax.jit(lambda b: b.edge_index.matmul(
        b.x, force_pallas=True))
    got = pallas_step(sb)
    ref = EdgeIndex(sb.edge_index.data, sb.num_nodes, sb.num_nodes).matmul(
        sb.x, force_pallas=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-4,
                               atol=1e-4)
    key = ("loader_pallas_us" if on_tpu else "loader_pallas_interpret_us")
    rec = {
        "cell": "loader_step",
        "backend": jax.default_backend(),
        "nodes": n, "edges": e, "feat": feat,
        "batch_size": batch_size, "fanouts": fanouts,
        "make_batch_us": make_batch_us,
        "step_cached_us": cached_us,
        "step_raw_us": raw_us,
        "trace_count": sentinel.count("loader_step"),
        key: time_fn(pallas_step, sb, warmup=1, iters=3),
    }
    emit("spmm/loader_step/cached_us", cached_us,
         f"vs_raw={raw_us / cached_us:.2f}x")
    emit("spmm/loader_step/make_batch_us", make_batch_us)
    append_cell(out_path, rec)


def run_train_step(out_path: str = "BENCH_spmm.json") -> None:
    """Oracle-grad vs kernel-grad train step (the custom-VJP PR path).

    A NeighborLoader batch with host-prefilled static ELL caches drives a
    jit'd ``value_and_grad`` GCN-style step twice: once dispatching the XLA
    segment oracle and once forced onto the Pallas ELL kernel, whose custom
    VJP runs the backward as a masked scatter-add over the same buckets
    (with an ``edge_weight`` cotangent — the step is GCN-normalised, so the
    weighted path differentiates too). Verifies gradient parity and ONE
    trace per variant across batches, then times both. Off-TPU the kernel
    runs in interpret mode, so its timing lands under
    ``step_grad_kernel_interpret_us`` and uses a deliberately small cell.
    Appends a ``train_step`` record to ``BENCH_spmm.json``.
    """
    import time

    from repro.data.data import Data
    from repro.data.loader import NeighborLoader
    from repro.nn.gnn.conv import gcn_norm

    on_tpu = jax.default_backend() == "tpu"
    rng = np.random.default_rng(17)
    n, e, feat, hidden = 2048, 16384, 128, 32
    batch_size, fanouts = (64, [10, 5]) if on_tpu else (8, [4, 2])
    data = Data(x=rng.standard_normal((n, feat)).astype(np.float32),
                edge_index=np.stack([rng.integers(0, n, e),
                                     rng.integers(0, n, e)]),
                y=rng.integers(0, 4, n))
    loader = NeighborLoader(data, data, num_neighbors=fanouts,
                            batch_size=batch_size, shuffle=True,
                            pipeline_depth=2, prefetch=2, prefill_ell=True,
                            seed=0)
    params = {
        "w1": jnp.asarray(rng.standard_normal((feat, hidden)) * 0.1,
                          jnp.float32),
        "w2": jnp.asarray(rng.standard_normal((hidden, 4)) * 0.1,
                          jnp.float32),
    }
    sentinel = RetraceSentinel(budget=1)

    def make_step(force_pallas: bool, tag: str):
        interpret = None if not force_pallas else (not on_tpu)

        @jax.jit
        def step(params, batch):
            def loss_fn(p):
                ew, _ = gcn_norm(batch.edge_index, batch.num_nodes,
                                 add_self_loops=False)
                h = jax.nn.relu(batch.edge_index.matmul(
                    batch.x @ p["w1"], edge_weight=ew,
                    force_pallas=force_pallas, interpret=interpret))
                out = batch.edge_index.matmul(
                    h @ p["w2"], edge_weight=ew,
                    force_pallas=force_pallas, interpret=interpret)
                return (out[batch.seed_slots] ** 2).mean()

            return jax.value_and_grad(loss_fn)(params)

        return sentinel.wrap(step, name=tag)

    step_oracle = make_step(False, "oracle")
    step_kernel = make_step(True, "kernel")

    it = iter(loader)
    batches = [next(it) for _ in range(4)]

    lo, go = step_oracle(params, batches[0])
    lk, gk = step_kernel(params, batches[0])
    lo.block_until_ready(), lk.block_until_ready()
    diffs = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a - b).max()), go, gk)
    max_diff = max(jax.tree_util.tree_leaves(diffs))
    assert max_diff < 1e-3, f"kernel-grad != oracle-grad: {max_diff}"

    def time_over_batches(fn, rounds: int = 3):
        t0 = time.perf_counter()
        for _ in range(rounds):
            for b in batches:
                fn(params, b)[0].block_until_ready()
        return (time.perf_counter() - t0) / (rounds * len(batches)) * 1e6

    oracle_us = time_over_batches(step_oracle)
    kernel_us = time_over_batches(step_kernel)
    sentinel.check()  # 1 signature per step fn, or raise with a diff

    key = "step_grad_kernel_us" if on_tpu else "step_grad_kernel_interpret_us"
    rec = {
        "cell": "train_step",
        "backend": jax.default_backend(),
        "nodes": n, "edges": e, "feat": feat,
        "batch_size": batch_size, "fanouts": fanouts,
        "step_grad_oracle_us": oracle_us,
        key: kernel_us,
        "trace_count_oracle": sentinel.count("oracle"),
        "trace_count_kernel": sentinel.count("kernel"),
        "grad_max_abs_diff": max_diff,
    }
    emit("spmm/train_step/grad_oracle_us", oracle_us)
    emit(f"spmm/train_step/{key.removeprefix('step_')}", kernel_us,
         f"grad_max_abs_diff={max_diff:.2e}")
    append_cell(out_path, rec)


def run_hetero_step(out_path: str = "BENCH_spmm.json") -> None:
    """Typed loader -> jit'd HeteroGNN train-step cell (the PR-3 path).

    Measures the heterogeneous serving chain at parity with the
    homogeneous one: a ``HeteroNeighborLoader`` batch (per-relation
    host-prefilled CSR/CSC + static ELL caches) flows through a jit'd
    2-layer ``HeteroGNN`` as ONE pytree with a single compilation across
    batches, per-relation SpMM aggregations and a single grouped matmul for
    all per-type projections per layer — timed against the ungrouped
    (|edge types| separate convs) variant. Also proves every relation's
    Pallas ELL dispatch on a small forced-interpret cell. Appends a
    ``hetero_step`` record to ``BENCH_spmm.json``.
    """
    import time

    from repro.core.edge_index import EdgeIndex
    from repro.core.hetero import to_hetero
    from repro.data.data import HeteroData
    from repro.data.hetero_sampler import HeteroNeighborLoader
    from repro.nn.gnn.conv import SAGEConv

    rng = np.random.default_rng(13)
    n_user, n_item, e, feat, hidden = 2048, 4096, 32768, 64, 32
    batch_size = 32
    fan = {("user", "buys", "item"): [8, 4],
           ("item", "rev_buys", "user"): [8, 4]}
    hd = HeteroData()
    hd.add_nodes("user", rng.standard_normal((n_user, feat)).astype(
        np.float32))
    hd.add_nodes("item", rng.standard_normal((n_item, feat)).astype(
        np.float32))
    ub = np.stack([rng.integers(0, n_user, e), rng.integers(0, n_item, e)])
    hd.add_edges(("user", "buys", "item"), ub)
    hd.add_edges(("item", "rev_buys", "user"), ub[::-1])
    metadata = (["user", "item"], list(fan))

    def make_loader(**kw):
        return HeteroNeighborLoader(
            hd, hd, num_neighbors=fan, input_type="item",
            input_nodes=np.arange(n_item), batch_size=batch_size,
            shuffle=True, prefill_ell=True, seed=0, **kw)

    net = to_hetero(lambda i, o: SAGEConv(i, o), metadata,
                    [feat, hidden, 4], grouped=True)
    net_sep = to_hetero(lambda i, o: SAGEConv(i, o), metadata,
                        [feat, hidden, 4], grouped=False)
    params = net.init(jax.random.PRNGKey(0))
    sentinel = RetraceSentinel(budget=1)

    def make_step(model, name=None):
        @jax.jit
        def step(params, batch):
            def loss_fn(p):
                out = model.apply(p, batch.x_dict, batch.edge_index_dict,
                                  batch.num_nodes_dict)
                return (batch.seed_output(out) ** 2).mean()

            return jax.value_and_grad(loss_fn)(params)

        return step if name is None else sentinel.wrap(step, name=name)

    step_grouped = make_step(net, "hetero_step")
    step_sep = make_step(net_sep)

    t0 = time.perf_counter()
    it = iter(make_loader(prefetch=2))
    batches = [next(it) for _ in range(4)]
    make_batch_us = (time.perf_counter() - t0) / 4 * 1e6

    step_grouped(params, batches[0])[0].block_until_ready()
    step_sep(params, batches[0])[0].block_until_ready()

    def time_over_batches(fn, rounds: int = 3):
        t0 = time.perf_counter()
        for _ in range(rounds):
            for b in batches:
                fn(params, b)[0].block_until_ready()
        return (time.perf_counter() - t0) / (rounds * len(batches)) * 1e6

    grouped_us = time_over_batches(step_grouped)
    sep_us = time_over_batches(step_sep)
    sentinel.check()  # 1 signature across all batches, or raise with a diff

    # every relation's aggregation -> Pallas ELL kernel, proven on a tiny
    # forced-interpret cell (compiled on real TPUs)
    on_tpu = jax.default_backend() == "tpu"
    small = next(iter(HeteroNeighborLoader(
        hd, hd, num_neighbors={et: [3, 2] for et in fan}, input_type="item",
        input_nodes=np.arange(8), batch_size=8, prefill_ell=True, seed=0)))
    key = "hetero_pallas_us" if on_tpu else "hetero_pallas_interpret_us"
    pallas_us = {}
    for et, ei in small.edge_index_dict.items():
        spmm = jax.jit(lambda b, e=et: b.edge_index_dict[e].matmul(
            b.x_dict[e[0]], force_pallas=True))
        got = spmm(small)
        ref = EdgeIndex(ei.data, ei.num_src_nodes, ei.num_dst_nodes).matmul(
            small.x_dict[et[0]], force_pallas=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)
        pallas_us["__".join(et)] = time_fn(spmm, small, warmup=1, iters=3)

    rec = {
        "cell": "hetero_step",
        "backend": jax.default_backend(),
        "n_user": n_user, "n_item": n_item, "edges_per_type": e,
        "feat": feat, "batch_size": batch_size,
        "fanouts": {"__".join(et): f for et, f in fan.items()},
        "make_batch_us": make_batch_us,
        "step_grouped_us": grouped_us,
        "step_separate_us": sep_us,
        "trace_count": sentinel.count("hetero_step"),
        key: pallas_us,
    }
    emit("spmm/hetero_step/grouped_us", grouped_us,
         f"vs_separate={sep_us / grouped_us:.2f}x")
    emit("spmm/hetero_step/make_batch_us", make_batch_us)
    append_cell(out_path, rec)


def run_gat_step(out_path: str = "BENCH_spmm.json") -> None:
    """Materialised-oracle vs fused-kernel jit'd GAT train step (this PR).

    A NeighborLoader batch with host-prefilled static ELL caches drives a
    jit'd ``value_and_grad`` GATConv step twice: once on the materialised
    oracle path (``(E, H, F)`` edge messages + XLA segment softmax) and
    once on the fused flash-GAT attention kernel, whose ops-level custom
    VJP runs the softmax backward over the same ELL panels. Verifies
    gradient parity and ONE trace per variant across batches, then times
    both. Off-TPU the kernel runs in interpret mode, so its timing lands
    under ``step_grad_kernel_interpret_us`` and uses a deliberately small
    cell. Appends a ``gat_step`` record to ``BENCH_spmm.json``.
    """
    import time

    from repro.core.edge_index import EdgeIndex
    from repro.data.data import Data
    from repro.data.loader import NeighborLoader
    from repro.nn.gnn.conv import GATConv

    on_tpu = jax.default_backend() == "tpu"
    rng = np.random.default_rng(19)
    n, e, feat, hidden, heads = 2048, 16384, 64, 32, 4
    batch_size, fanouts = (64, [10, 5]) if on_tpu else (8, [4, 2])
    data = Data(x=rng.standard_normal((n, feat)).astype(np.float32),
                edge_index=np.stack([rng.integers(0, n, e),
                                     rng.integers(0, n, e)]),
                y=rng.integers(0, 4, n))
    loader = NeighborLoader(data, data, num_neighbors=fanouts,
                            batch_size=batch_size, shuffle=True,
                            pipeline_depth=2, prefetch=2, prefill_ell=True,
                            seed=0)
    conv = GATConv(feat, hidden, heads=heads)
    params = conv.init(jax.random.PRNGKey(0))
    sentinel = RetraceSentinel(budget=1)

    # GATConv dispatches through use_pallas(); flip the env var around each
    # variant's trace — the compiled artifacts keep their path afterwards.
    def make_step(use_pallas_env: str, tag: str):
        @jax.jit
        def step(params, batch):
            def loss_fn(p):
                ei = (batch.edge_index if use_pallas_env == "1" else
                      EdgeIndex(batch.edge_index.data, batch.num_nodes,
                                batch.num_nodes))
                out = conv.apply(p, batch.x, ei)
                return (out[batch.seed_slots] ** 2).mean()

            return jax.value_and_grad(loss_fn)(params)

        return sentinel.wrap(step, name=tag)

    it = iter(loader)
    batches = [next(it) for _ in range(4)]

    prev = os.environ.get("REPRO_USE_PALLAS")
    try:
        os.environ["REPRO_USE_PALLAS"] = "0"
        step_oracle = make_step("0", "oracle")
        lo, go = step_oracle(params, batches[0])
        os.environ["REPRO_USE_PALLAS"] = "1"
        step_kernel = make_step("1", "kernel")
        lk, gk = step_kernel(params, batches[0])
    finally:
        if prev is None:
            os.environ.pop("REPRO_USE_PALLAS", None)
        else:
            os.environ["REPRO_USE_PALLAS"] = prev
    lo.block_until_ready(), lk.block_until_ready()
    diffs = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a - b).max()), go, gk)
    max_diff = max(jax.tree_util.tree_leaves(diffs))
    assert max_diff < 1e-5, f"fused GAT grad != oracle grad: {max_diff}"

    def time_over_batches(fn, rounds: int = 3):
        t0 = time.perf_counter()
        for _ in range(rounds):
            for b in batches:
                fn(params, b)[0].block_until_ready()
        return (time.perf_counter() - t0) / (rounds * len(batches)) * 1e6

    oracle_us = time_over_batches(step_oracle)
    kernel_us = time_over_batches(step_kernel)
    sentinel.check()  # 1 signature per step fn, or raise with a diff

    key = "step_grad_kernel_us" if on_tpu else "step_grad_kernel_interpret_us"
    rec = {
        "cell": "gat_step",
        "backend": jax.default_backend(),
        "nodes": n, "edges": e, "feat": feat, "heads": heads,
        "batch_size": batch_size, "fanouts": fanouts,
        "step_grad_oracle_us": oracle_us,
        key: kernel_us,
        "trace_count_oracle": sentinel.count("oracle"),
        "trace_count_kernel": sentinel.count("kernel"),
        "grad_max_abs_diff": max_diff,
    }
    emit("spmm/gat_step/grad_oracle_us", oracle_us)
    emit(f"spmm/gat_step/{key.removeprefix('step_')}", kernel_us,
         f"grad_max_abs_diff={max_diff:.2e}")
    append_cell(out_path, rec)


def run_hgt_step(out_path: str = "BENCH_spmm.json") -> None:
    """Typed-attention (HGT) loader-fed jit'd train-step cell (this PR).

    A ``HeteroNeighborLoader`` batch (per-relation host-prefilled static
    ELL caches) drives a jit'd ``value_and_grad`` step of a 2-layer
    ``hgt()`` graph-transformer stack twice: once on the COO carry oracle
    (cache-less EdgeIndexes, ``REPRO_USE_PALLAS=0`` at trace) and once on
    the fused typed-attention kernel path — one carry-mode launch per
    relation per layer, per-destination-type ``merge_carries`` cross-type
    softmax, grouped-matmul K/Q/V. Verifies gradient parity and ONE trace
    per variant across batches, then times both. Off-TPU the kernel runs
    in interpret mode (``step_grad_kernel_interpret_us``, small cell).
    Appends an ``hgt_step`` record to ``BENCH_spmm.json``.
    """
    import time

    from repro.core.edge_index import EdgeIndex
    from repro.core.hetero import hgt
    from repro.data.data import HeteroData
    from repro.data.hetero_sampler import HeteroNeighborLoader

    on_tpu = jax.default_backend() == "tpu"
    rng = np.random.default_rng(23)
    feat, hidden, heads = 32, 32, 4
    if on_tpu:
        n_user, n_item, e = 2048, 4096, 32768
        batch_size, fan_depth = 32, [8, 4]
    else:
        n_user, n_item, e = 256, 512, 2048
        batch_size, fan_depth = 8, [4, 2]
    fan = {("user", "buys", "item"): fan_depth,
           ("item", "rev_buys", "user"): fan_depth}
    hd = HeteroData()
    hd.add_nodes("user", rng.standard_normal((n_user, feat)).astype(
        np.float32))
    hd.add_nodes("item", rng.standard_normal((n_item, feat)).astype(
        np.float32))
    ub = np.stack([rng.integers(0, n_user, e), rng.integers(0, n_item, e)])
    hd.add_edges(("user", "buys", "item"), ub)
    hd.add_edges(("item", "rev_buys", "user"), ub[::-1])
    metadata = (["user", "item"], list(fan))

    loader = HeteroNeighborLoader(
        hd, hd, num_neighbors=fan, input_type="item",
        input_nodes=np.arange(n_item), batch_size=batch_size, shuffle=True,
        prefill_ell=True, pipeline_depth=2, prefetch=2, seed=0)
    net = hgt(metadata, [feat, hidden, hidden], heads=heads)
    params = net.init(jax.random.PRNGKey(0))
    sentinel = RetraceSentinel(budget=1)

    # hgt dispatches through use_pallas(); flip the env var around each
    # variant's trace — the compiled artifacts keep their path afterwards.
    def make_step(use_pallas_env: str, tag: str):
        @jax.jit
        def step(params, batch):
            def loss_fn(p):
                eid = batch.edge_index_dict
                if use_pallas_env != "1":  # cache-less -> COO carry oracle
                    eid = {et: EdgeIndex(ei.data, ei.num_src_nodes,
                                         ei.num_dst_nodes)
                           for et, ei in eid.items()}
                out = net.apply(p, batch.x_dict, eid, batch.num_nodes_dict)
                return (batch.seed_output(out) ** 2).mean()

            return jax.value_and_grad(loss_fn)(params)

        return sentinel.wrap(step, name=tag)

    it = iter(loader)
    batches = [next(it) for _ in range(4)]

    prev = os.environ.get("REPRO_USE_PALLAS")
    try:
        os.environ["REPRO_USE_PALLAS"] = "0"
        step_oracle = make_step("0", "oracle")
        lo, go = step_oracle(params, batches[0])
        os.environ["REPRO_USE_PALLAS"] = "1"
        step_kernel = make_step("1", "kernel")
        lk, gk = step_kernel(params, batches[0])
    finally:
        if prev is None:
            os.environ.pop("REPRO_USE_PALLAS", None)
        else:
            os.environ["REPRO_USE_PALLAS"] = prev
    lo.block_until_ready(), lk.block_until_ready()
    diffs = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a - b).max()), go, gk)
    max_diff = max(jax.tree_util.tree_leaves(diffs))
    assert max_diff < 1e-5, f"fused HGT grad != oracle grad: {max_diff}"

    def time_over_batches(fn, rounds: int = 3):
        t0 = time.perf_counter()
        for _ in range(rounds):
            for b in batches:
                fn(params, b)[0].block_until_ready()
        return (time.perf_counter() - t0) / (rounds * len(batches)) * 1e6

    oracle_us = time_over_batches(step_oracle)
    kernel_us = time_over_batches(step_kernel)
    sentinel.check()  # 1 signature per step fn, or raise with a diff

    key = "step_grad_kernel_us" if on_tpu else "step_grad_kernel_interpret_us"
    rec = {
        "cell": "hgt_step",
        "backend": jax.default_backend(),
        "n_user": n_user, "n_item": n_item, "edges_per_type": e,
        "feat": feat, "heads": heads, "batch_size": batch_size,
        "fanouts": fan_depth,
        "step_grad_oracle_us": oracle_us,
        key: kernel_us,
        "trace_count_oracle": sentinel.count("oracle"),
        "trace_count_kernel": sentinel.count("kernel"),
        "grad_max_abs_diff": max_diff,
    }
    emit("spmm/hgt_step/grad_oracle_us", oracle_us)
    emit(f"spmm/hgt_step/{key.removeprefix('step_')}", kernel_us,
         f"grad_max_abs_diff={max_diff:.2e}")
    append_cell(out_path, rec)


if __name__ == "__main__":
    run()
    run_loader_step()
    run_train_step()
    run_hetero_step()
    run_gat_step()
    run_hgt_step()
