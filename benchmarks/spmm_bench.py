"""SpMM perf sweep: blocked-ELL vs XLA segment oracle, JSON trajectory.

Sweeps (rows, K, F) cells over three implementations of the same sorted-
adjacency SpMM (paper §2.2 — the message-passing hot loop):

  * ``oracle``        — CSR gather + ``segment_sum`` (XLA-fused reference)
  * ``ell_xla``       — blocked-ELL dense-masked reduction lowered by XLA
  * ``ell_pallas``    — the pipelined Pallas kernel; compiled on TPU,
                        interpret mode elsewhere (timing then measures the
                        interpreter, so off-TPU it is recorded under
                        ``ell_pallas_interpret_us`` and skipped for the
                        larger cells)

Writes ``BENCH_spmm.json`` next to the repo root so the perf trajectory of
the kernel is recorded PR-over-PR. Also prints the usual CSV rows.
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.kernels.spmm import ops as spmm_ops, ref as spmm_ref

# (rows, K, F) cells. K is the padded neighbor budget per row.
CELLS = [
    (256, 4, 128),
    (256, 16, 128),
    (256, 16, 256),
    (1024, 4, 128),
    (1024, 16, 128),
    (1024, 16, 256),
    (4096, 8, 128),
    (4096, 32, 256),
]

# Interpret-mode Pallas is a correctness vehicle, not a perf one; only the
# small cells are worth the interpreter's while off-TPU.
INTERPRET_MAX_WORK = 256 * 16 * 256


def _make_cell(rng, rows: int, k: int, feat: int):
    """Random ELL table (~15% padding) + its exact CSR equivalent."""
    n = rows  # square-ish adjacency
    ell = rng.integers(0, n, (rows, k)).astype(np.int32)
    pad = rng.random((rows, k)) < 0.15
    ell[pad] = -1
    ell.sort(axis=1)  # -1s first ...
    ell = ell[:, ::-1].copy()  # ... then flipped: valid-prefix layout
    deg = (ell >= 0).sum(1)
    indptr = np.concatenate([[0], np.cumsum(deg)]).astype(np.int64)
    indices = ell[ell >= 0].astype(np.int32)
    x = rng.standard_normal((n, feat)).astype(np.float32)
    return ell, indptr, indices, x


def run(out_path: str = "BENCH_spmm.json") -> None:
    on_tpu = jax.default_backend() == "tpu"
    rng = np.random.default_rng(7)
    records = []
    for rows, k, feat in CELLS:
        ell, indptr, indices, x = _make_cell(rng, rows, k, feat)
        ell_j, x_j = jnp.asarray(ell), jnp.asarray(x)
        indptr_j, indices_j = jnp.asarray(indptr), jnp.asarray(indices)

        oracle = jax.jit(lambda p, i, x: spmm_ref.spmm_csr(
            p, i, x, num_rows=rows, reduce="sum"))
        ell_xla = jax.jit(lambda e, x: spmm_ref.spmm_ell(e, None, x))

        a = oracle(indptr_j, indices_j, x_j)
        b = ell_xla(ell_j, x_j)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)

        rec = {
            "rows": rows, "k": k, "feat": feat,
            "backend": jax.default_backend(),
            "oracle_us": time_fn(oracle, indptr_j, indices_j, x_j),
            "ell_xla_us": time_fn(ell_xla, ell_j, x_j),
        }
        run_pallas = on_tpu or rows * k * feat <= INTERPRET_MAX_WORK
        if run_pallas:
            interpret = not on_tpu
            pallas = jax.jit(lambda e, x: spmm_ops.spmm_ell(
                e, None, x, force_pallas=True, interpret=interpret))
            c = pallas(ell_j, x_j)
            np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                       rtol=1e-4, atol=1e-4)
            key = "ell_pallas_us" if on_tpu else "ell_pallas_interpret_us"
            rec[key] = time_fn(pallas, ell_j, x_j, warmup=1, iters=3)
        records.append(rec)
        tag = f"spmm/r{rows}k{k}f{feat}"
        emit(f"{tag}/oracle_us", rec["oracle_us"])
        emit(f"{tag}/ell_xla_us", rec["ell_xla_us"],
             f"vs_oracle={rec['oracle_us'] / rec['ell_xla_us']:.2f}x")

    with open(out_path, "w") as fh:
        json.dump(records, fh, indent=2)
    print(f"# wrote {os.path.abspath(out_path)} ({len(records)} cells)")


if __name__ == "__main__":
    run()
