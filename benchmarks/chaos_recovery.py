"""Chaos recovery: training throughput and loss continuity under store
faults (the proof behind the resilience layer in repro.data.resilience).

Protocol: a 4-partition feature store behind ChaosFeatureStore +
ResilientFeatureStore feeds a jit'd 2-layer GNN train step through
NeighborLoader(on_batch_error="skip"). For each injected fault rate we
record batches/sec, the fraction of seed batches that survived, loss
continuity (all finite), and the loader/store health counters; a dedicated
single-partition blackout measures breaker trip latency (first failure ->
open) and recovery latency (blackout end -> closed). The zero-fault row
doubles as the overhead gate: resilient-wrapped vs bare store on the same
epoch must stay within a few percent (the `loader_step` guarantee).

Writes/updates the ``chaos_recovery`` cell of ``BENCH_chaos.json``.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import append_cell, emit

FAULT_RATES = (0.0, 0.05, 0.1, 0.25)


def _build(n=4096, e=32768, feat=64, parts=4, seed=3):
    from repro.data.partition import build_partitioned_stores

    rng = np.random.default_rng(seed)
    ei = np.stack([rng.integers(0, n, e), rng.integers(0, n, e)])
    x = rng.standard_normal((n, feat)).astype(np.float32)
    y = rng.integers(0, 4, n)
    fs, gs, part = build_partitioned_stores(x, ei, parts, y=y)
    return fs, gs, part, feat


def _make_step(feat, hidden=32, classes=4, seed=0):
    rng = np.random.default_rng(seed)
    params = {
        "w1": jnp.asarray(rng.standard_normal((feat, hidden)) * 0.1,
                          jnp.float32),
        "w2": jnp.asarray(rng.standard_normal((hidden, classes)) * 0.1,
                          jnp.float32),
    }
    traces = []

    @jax.jit
    def step(params, batch):
        traces.append(1)

        def loss_fn(p):
            h = jax.nn.relu(batch.edge_index.matmul(batch.x @ p["w1"]))
            out = batch.edge_index.matmul(h @ p["w2"])
            logits = out[batch.seed_slots]
            onehot = jax.nn.one_hot(batch.y, logits.shape[-1])
            return -(jax.nn.log_softmax(logits) * onehot).sum(-1).mean()

        loss, grads = jax.value_and_grad(loss_fn)(params)
        new = jax.tree_util.tree_map(lambda p, g: p - 1e-2 * g, params,
                                     grads)
        return new, loss

    return params, step, traces


def _epoch(loader, params, step):
    losses, t0, nb = [], time.perf_counter(), 0
    for b in loader:
        params, loss = step(params, b)
        losses.append(float(jax.block_until_ready(loss)))
        nb += 1
    return nb, losses, time.perf_counter() - t0, params


def _wrap(fs, fault, seed=11, blackout=None):
    from repro.data.resilience import (ChaosFeatureStore, FailureSchedule,
                                       ResilientFeatureStore, RetryPolicy)

    schedule = FailureSchedule(seed=seed, error_rate=fault,
                               blackout=blackout or {})
    chaos = ChaosFeatureStore(fs, schedule)
    res = ResilientFeatureStore(
        chaos, retry=RetryPolicy(max_attempts=3, base_delay=1e-4, seed=seed),
        failure_threshold=3, recovery_time=0.0)
    return res, schedule


def run(out_path: str = "BENCH_chaos.json") -> None:
    from repro.data.loader import NeighborLoader
    from repro.data.resilience import ResilientFeatureStore, RetryPolicy

    fs, gs, part, feat = _build()
    input_nodes = np.arange(2048)
    mk_loader = lambda store: NeighborLoader(
        store, gs, num_neighbors=[8, 4], batch_size=128,
        input_nodes=input_nodes, shuffle=True, prefetch=2,
        on_batch_error="skip", batch_retries=2, seed=0)

    rows = []
    for fault in FAULT_RATES:
        # window in partition-1 CALL counts; one epoch generates ~32+ calls
        # (16 batches x {x, y} fetches), so (8, 30) is fully exercised
        blackout = {1: [(8, 30)]} if fault >= 0.1 else None
        store, schedule = _wrap(fs, fault, blackout=blackout)
        loader = mk_loader(store)
        params, step, traces = _make_step(feat)
        nb, losses, dt, _ = _epoch(loader, params, step)
        assert all(np.isfinite(losses)), f"loss diverged at fault={fault}"
        row = {
            "fault_rate": fault,
            "batches_per_s": nb / max(dt, 1e-9),
            "batches": nb,
            "seed_batches": len(loader),
            "skipped": loader.health["skipped_batches"],
            "batch_retries": loader.health["batch_retries"],
            "degraded_rows": loader.health["degraded_rows"],
            "store_retries": store.health["retries"],
            "breaker_trips": store.health["breaker_trips"],
            "breaker_recoveries": store.health["breaker_recoveries"],
            "loss_first": losses[0] if losses else None,
            "loss_last": losses[-1] if losses else None,
            "trace_count": len(traces),
            "injected": dict(schedule.injected),
        }
        rows.append(row)
        emit(f"chaos/fault{fault:g}_batches_per_s", 1e6 / max(
            row["batches_per_s"], 1e-9),
            f"skipped={row['skipped']} degraded={row['degraded_rows']} "
            f"trips={row['breaker_trips']} trace={row['trace_count']}")

    # ---- overhead of the resilience wrappers at fault rate 0 -------------
    def time_epoch(store):
        loader = mk_loader(store)
        params, step, _ = _make_step(feat)
        nb, _, dt, _ = _epoch(loader, params, step)
        return dt / max(nb, 1)

    time_epoch(fs)  # warm compile both paths before timing
    bare = min(time_epoch(fs) for _ in range(3))
    res_store = ResilientFeatureStore(
        fs, retry=RetryPolicy(max_attempts=3, base_delay=1e-4))
    wrapped = min(time_epoch(res_store) for _ in range(3))
    overhead = (wrapped - bare) / bare
    emit("chaos/resilience_overhead_pct", bare * 1e6,
         f"wrapped_us={wrapped * 1e6:.1f} overhead={overhead * 100:.2f}%")

    # ---- breaker trip / recovery latency on a controlled blackout --------
    store, schedule = _wrap(fs, 0.0, seed=5, blackout={0: [(5, 25)]})
    store._breaker_cfg = (3, 0.002, time.monotonic)  # real cooldown
    rows_p0 = np.where(part == 0)[0][:64]
    t_first_fail = t_open = t_closed = None
    for _ in range(400):  # ~7 cooldown-gated probes needed to ride the window
        _, dmask = store.get_padded_resilient(rows_p0)
        now = time.perf_counter()
        state = store.breaker_states().get(0, "closed")
        if dmask.any() and t_first_fail is None:
            t_first_fail = now
        if state == "open" and t_open is None:
            t_open = now
        if t_open is not None and state == "closed" and t_closed is None:
            t_closed = now
            break
    trip_ms = ((t_open - t_first_fail) * 1e3
               if t_open and t_first_fail else None)
    recover_ms = (t_closed - t_open) * 1e3 if t_closed and t_open else None
    emit("chaos/breaker_trip_ms", (trip_ms or 0) * 1e3,
         f"recover_ms={recover_ms}")

    append_cell(out_path, {
        "cell": "chaos_recovery",
        "protocol": "4-part store, chaos-injected transient faults + "
                    "partition-1 blackout (calls 8-30), NeighborLoader "
                    "prefetch=2 on_batch_error=skip, jit'd 2-layer GNN "
                    "step, one epoch per fault rate",
        "fault_sweep": rows,
        "overhead": {"bare_batch_s": bare, "resilient_batch_s": wrapped,
                     "overhead_frac": overhead},
        "breaker": {"trip_ms": trip_ms, "recover_ms": recover_ms,
                    "failure_threshold": 3, "recovery_time_s": 0.002},
    })


if __name__ == "__main__":
    run()
