"""Paper Tables 1 & 2: fwd+bwd runtime across GNN architectures,
eager vs compiled (jit), trim off/on.

Protocol (mirrors the open-sourced PyG benchmark): a sampled 3-hop subgraph
(NeighborLoader budgets [10, 10, 10], batch of seeds), five architectures
(GIN, GraphSAGE, EdgeCNN, GCN, GAT), median of forward+backward wall time.
The paper reports 2-3x for compile (Table 1) and 4-5x for compile+trim
(Table 2) on an A100; on this CPU container the *ratios* are the
reproduction target, absolute times differ.

'Eager' means op-by-op dispatch with no jit — the analogue of PyTorch eager:
every jnp op round-trips through the dispatcher, nothing fuses.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, synthetic_graph, time_fn
from repro.data.data import Data
from repro.data.loader import NeighborLoader
from repro.nn.gnn.models import make_model

MODELS = ["gin", "sage", "edgecnn", "gcn", "gat"]
HIDDEN = 64
CLASSES = 16
FANOUTS = [10, 10, 10]
BATCH = 64


def _get_batch(feat: int = 64):
    ei, x, y = synthetic_graph(20_000, 16, feat, seed=1)
    data = Data(x=x, edge_index=ei, y=y)
    loader = NeighborLoader(data, data, num_neighbors=FANOUTS,
                            batch_size=BATCH, shuffle=False)
    return next(iter(loader))


def run(iters: int = 5):
    batch = _get_batch()
    feat = batch.x.shape[1]
    results = {}
    for name in MODELS:
        model = make_model(name, feat, HIDDEN, CLASSES, len(FANOUTS))
        params = model.init(jax.random.PRNGKey(0))

        def loss(params, x, ei, trim):
            out = model.apply(
                params, x, ei,
                num_sampled_nodes_per_hop=batch.num_sampled_nodes,
                num_sampled_edges_per_hop=batch.num_sampled_edges,
                trim=trim)
            return (out[batch.seed_slots] ** 2).mean()

        grad = jax.grad(loss)

        def eager(trim):
            with jax.disable_jit():
                return grad(params, batch.x, batch.edge_index.data, trim)

        jitted = {t: jax.jit(lambda p, x, e, t=t: grad(p, x, e, t))
                  for t in (False, True)}

        row = {}
        row["eager"] = time_fn(lambda: eager(False), iters=iters, warmup=1)
        row["eager_trim"] = time_fn(lambda: eager(True), iters=iters,
                                    warmup=1)
        row["compile"] = time_fn(
            lambda: jitted[False](params, batch.x, batch.edge_index.data),
            iters=iters)
        row["compile_trim"] = time_fn(
            lambda: jitted[True](params, batch.x, batch.edge_index.data),
            iters=iters)
        results[name] = row
        emit(f"table1/{name}/eager_ms", row["eager"] / 1e3)
        emit(f"table1/{name}/compile_ms", row["compile"] / 1e3,
             f"speedup={row['eager'] / row['compile']:.2f}x")
        emit(f"table2/{name}/eager_trim_ms", row["eager_trim"] / 1e3,
             f"speedup={row['eager'] / row['eager_trim']:.2f}x")
        emit(f"table2/{name}/compile_trim_ms", row["compile_trim"] / 1e3,
             f"speedup={row['eager'] / row['compile_trim']:.2f}x")
    return results


if __name__ == "__main__":
    run()
