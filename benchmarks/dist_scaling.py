"""Data-parallel mesh scaling trajectory (PR 10) -> ``BENCH_dist.json``.

One cell, four mesh sizes: the ``MeshTrainer`` shard_map train step runs a
loader-fed GCN step on 1/2/4/8 forced host-platform devices with a fixed
*global* batch, recording per-mesh step time, seed throughput and scaling
efficiency (vs. the 1-device step), plus:

  * grad/loss parity of the 4-device step against the single-device
    gradient-accumulation oracle over the same shards (max |delta| across
    updated params);
  * trace_count per mesh size (must be 1 — one compilation serves every
    batch, tail included);
  * per-step collective traffic of the raw ``psum`` all-reduce vs the
    int8 / top-k compressed all-reduce, read off the step jaxpr by
    ``launch/jaxpr_stats.analyze_jaxpr`` (``collective_bytes``).

Honesty note: the container exposes ``host_cpu_count`` CPU cores (typically
1), and forced host-platform devices *timeshare* those cores — wall-clock
scaling efficiency on this box therefore measures shard_map dispatch
overhead, not parallel speedup, and is recorded as-is with the core count
beside it. On real multi-chip hardware the same cell measures true scaling.

The benchmark needs the device count forced *before* jax initialises, so
``run()`` re-execs itself in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` when the current
process sees fewer than 8 devices.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

MAX_DEVICES = 8
GLOBAL_BATCH = 32
FANOUTS = [4, 2]
STEPS_PER_MESH = 4


def _build_problem():
    import jax
    import jax.numpy as jnp
    import numpy as np

    from benchmarks.common import synthetic_graph
    from repro.data.data import Data
    from repro.data.loader import NeighborLoader
    from repro.nn.gnn.conv import gcn_norm

    edge_index, x, y = synthetic_graph(4096, 8, 64, seed=11)
    data = Data(x=x, edge_index=edge_index,
                y=y.astype(np.float32))

    def make_loader(shards):
        return NeighborLoader(
            data, data, num_neighbors=FANOUTS, batch_size=GLOBAL_BATCH,
            input_nodes=np.arange(GLOBAL_BATCH * STEPS_PER_MESH),
            prefill_ell=False, drop_last=False, shards=shards, seed=0)

    def loss_fn(params, batch):
        ew, _ = gcn_norm(batch.edge_index, batch.num_nodes,
                         add_self_loops=False)
        h = jax.nn.relu(batch.edge_index.matmul(
            batch.x @ params["w1"], edge_weight=ew))
        out = batch.edge_index.matmul(h @ params["w2"], edge_weight=ew)
        err = ((out[batch.seed_slots] - batch.y[:, None]) ** 2).sum(axis=-1)
        mask = batch.seed_mask.astype(jnp.float32)
        return (err * mask).sum(), mask.sum()

    rng = np.random.default_rng(3)
    params = {
        "w1": jnp.asarray(rng.standard_normal((64, 32)) * 0.1, jnp.float32),
        "w2": jnp.asarray(rng.standard_normal((32, 1)) * 0.1, jnp.float32)}
    return make_loader, loss_fn, params


def _inner(out_path: str = "BENCH_dist.json") -> None:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from benchmarks.common import append_cell, emit, time_fn
    from repro.data.loader import stack_batches
    from repro.launch import jaxpr_stats
    from repro.launch.mesh import data_parallel_mesh
    from repro.launch.train import MeshTrainer
    from repro.train import optimizer as opt_lib

    assert len(jax.devices()) >= MAX_DEVICES, \
        f"needs {MAX_DEVICES} forced host devices, run() handles the re-exec"
    make_loader, loss_fn, params = _build_problem()
    cfg = opt_lib.OptConfig(lr=1e-2, warmup_steps=1, total_steps=100)
    state0 = opt_lib.init_state(params, cfg)

    meshes, batches_by_d, trainers = {}, {}, {}
    per_mesh = {}
    base_us = None
    for d in (1, 2, 4, 8):
        mesh = data_parallel_mesh(d)
        trainer = MeshTrainer(loss_fn, cfg, mesh=mesh)
        batches = list(make_loader(shards=d))
        if d == 1:  # shards=1 keeps the plain unstacked batch (back-compat)
            batches = [stack_batches([b]) for b in batches]
        state = state0
        for b in batches:  # one epoch: every signature seen, still 1 trace
            state, _ = trainer.step(state, b)
        us = time_fn(trainer.step, state, batches[0], warmup=1, iters=3)
        if base_us is None:
            base_us = us
        thru = GLOBAL_BATCH / (us / 1e6)
        eff = base_us / (us * d)
        per_mesh[str(d)] = {
            "step_us": us, "seeds_per_s": thru,
            "scaling_efficiency": eff,
            "speedup_vs_1dev": base_us / us,
            "trace_count": trainer.trace_count,
        }
        emit(f"dist/step_{d}dev_us", us,
             f"eff={eff:.2f} traces={trainer.trace_count}")
        meshes[d], trainers[d], batches_by_d[d] = mesh, trainer, batches

    # ---- 4-device grad/loss parity vs single-device accumulation ----
    d = 4

    def oracle_step(state, stacked):
        def total(p):
            ls = ws = 0.0
            for i in range(d):
                shard = jax.tree_util.tree_map(lambda l, i=i: l[i], stacked)
                l, w = loss_fn(p, shard)
                ls, ws = ls + l, ws + w
            return ls, ws
        (loss_sum, weight), grads = jax.value_and_grad(
            total, has_aux=True)(state.params)
        w = jnp.maximum(weight, 1e-12)
        grads = jax.tree_util.tree_map(lambda g: g / w, grads)
        state, metrics = opt_lib.apply_updates(state, grads, cfg)
        metrics["loss"] = loss_sum / w
        return state, metrics

    oracle = jax.jit(oracle_step)
    s_mesh = s_orc = state0
    loss_diff = 0.0
    for b in batches_by_d[d]:
        s_mesh, m_mesh = trainers[d].step(s_mesh, b)
        s_orc, m_orc = oracle(s_orc, b)
        loss_diff = max(loss_diff,
                        abs(float(m_mesh["loss"]) - float(m_orc["loss"])))
    param_diff = max(
        float(jnp.max(jnp.abs(a - b))) for a, b in zip(
            jax.tree_util.tree_leaves(s_mesh.params),
            jax.tree_util.tree_leaves(s_orc.params)))
    emit("dist/parity_param_maxdiff", param_diff * 1e6,
         f"loss_diff={loss_diff:.2e}")

    # ---- compressed vs raw all-reduce traffic (per step, from jaxpr) ----
    b0 = batches_by_d[d][0]
    comm = {}
    for method, tr in (
            ("raw", trainers[d]),
            ("int8", MeshTrainer(loss_fn, cfg, mesh=meshes[d],
                                 compression="int8")),
            ("topk_1pct", MeshTrainer(loss_fn, cfg, mesh=meshes[d],
                                      compression="topk",
                                      compression_ratio=0.01))):
        stats = jaxpr_stats.analyze_jaxpr(tr.step_jaxpr(state0, b0))
        comm[method] = int(stats["collective_bytes"])
        emit(f"dist/collective_bytes_{method}", comm[method])

    rec = {
        "cell": "dist_scaling",
        "host_cpu_count": os.cpu_count(),
        "forced_host_devices": MAX_DEVICES,
        "global_batch": GLOBAL_BATCH,
        "fanouts": FANOUTS,
        "per_mesh": per_mesh,
        "parity_4dev": {"param_maxdiff": param_diff,
                        "loss_maxdiff": loss_diff, "tolerance": 1e-5,
                        "pass": bool(param_diff <= 1e-5
                                     and loss_diff <= 1e-5)},
        "collective_bytes_per_step": comm,
        "compression_saving_int8":
            1.0 - comm["int8"] / max(comm["raw"], 1),
        "note": ("forced host devices timeshare host_cpu_count cores; "
                 "wall-clock efficiency on this box measures dispatch "
                 "overhead, not parallel speedup"),
    }
    append_cell(out_path, rec)


def run(out_path: str = "BENCH_dist.json") -> None:
    """Entry point for run.py: re-exec with forced devices if needed."""
    import jax

    from repro.launch.mesh import HOST_DEVICE_FLAG, host_device_flag

    if len(jax.devices()) >= MAX_DEVICES:
        _inner(out_path)
        return
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    if HOST_DEVICE_FLAG not in flags:
        env["XLA_FLAGS"] = f"{flags} {host_device_flag(MAX_DEVICES)}".strip()
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    src = os.path.join(root, "src")
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (src, env.get("PYTHONPATH")) if p)
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.dist_scaling", "--inner",
         out_path], cwd=root, env=env)
    if proc.returncode != 0:
        raise RuntimeError(
            f"dist_scaling re-exec failed (rc={proc.returncode})")


if __name__ == "__main__":
    if "--inner" in sys.argv:
        args = [a for a in sys.argv[1:] if a != "--inner"]
        _inner(*args)
    else:
        run(*sys.argv[1:])
