"""Sampler throughput (paper §2.3 'Efficient Subgraph Sampling' +
cuGraph 2-8x loading-speedup claim shape).

Measures: naive per-node Python sampling vs the vectorised budgeted sampler,
with/without the prefetch thread; homogeneous and temporal variants.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, synthetic_graph
from repro.data.data import Data
from repro.data.loader import NeighborLoader
from repro.data.sampler import NeighborSampler


def naive_sample(indptr, indices, seeds, fanouts, rng):
    """Per-node Python-loop sampler (the paper's 'pure Python' baseline)."""
    nodes = list(seeds)
    frontier = list(seeds)
    for f in fanouts:
        nxt = []
        for v in frontier:
            nbrs = indices[indptr[v]:indptr[v + 1]]
            if len(nbrs) == 0:
                continue
            pick = rng.choice(nbrs, size=min(f, len(nbrs)), replace=False)
            nxt.extend(int(u) for u in pick)
        nodes.extend(nxt)
        frontier = nxt
    return nodes


def run(iters: int = 3):
    ei, x, y = synthetic_graph(100_000, 16, 64, seed=2)
    data = Data(x=x, edge_index=ei, y=y)
    csr = data.get_rev_csr()
    rng = np.random.default_rng(0)
    seeds = rng.integers(0, 100_000, 512)
    fanouts = [10, 10]

    t0 = time.perf_counter()
    for _ in range(iters):
        naive_sample(csr.indptr, csr.indices, seeds, fanouts, rng)
    naive_us = (time.perf_counter() - t0) / iters * 1e6

    sampler = NeighborSampler(data, fanouts)
    t0 = time.perf_counter()
    for _ in range(iters):
        sampler.sample(seeds)
    vec_us = (time.perf_counter() - t0) / iters * 1e6
    emit("sampler/naive_python_us", naive_us)
    emit("sampler/vectorized_us", vec_us,
         f"speedup={naive_us / vec_us:.2f}x")

    # end-to-end loader epoch (sampling + feature fetch), +prefetch overlap
    for prefetch in (0, 2):
        loader = NeighborLoader(data, data, num_neighbors=fanouts,
                                batch_size=512,
                                input_nodes=np.arange(8192),
                                prefetch=prefetch)
        t0 = time.perf_counter()
        n = 0
        for b in loader:
            n += 1
        dt = (time.perf_counter() - t0) / max(n, 1) * 1e6
        emit(f"loader/batch_us_prefetch{prefetch}", dt,
             f"batches={n}")

    # temporal sampling overhead
    t_edge = rng.integers(0, 1000, ei.shape[1])
    data_t = Data(x=x, edge_index=ei, y=y, time=t_edge)
    st = NeighborSampler(data_t, fanouts, temporal_strategy="recent")
    seed_time = rng.integers(100, 900, 512)
    t0 = time.perf_counter()
    for _ in range(iters):
        st.sample(seeds, seed_time)
    emit("sampler/temporal_recent_us",
         (time.perf_counter() - t0) / iters * 1e6)


if __name__ == "__main__":
    run()
