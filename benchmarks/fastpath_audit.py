"""Fast-path audit cell: static proof the bench steps ride Pallas (PR 7).

Re-derives the jit'd step cells of ``spmm_bench`` at small shapes and,
instead of timing them, *audits* them: each step's closed jaxpr is walked by
``repro.analysis.dispatch`` (zero ``repro_oracle:*`` eqns, the expected
kernels launched), costed by ``repro.launch.jaxpr_stats`` (pallas FLOPs),
and its loader batches are fingerprinted by a ``RetraceSentinel`` (one
abstract signature across batches == one compilation). Everything is an
abstract trace — no compilation, no execution — so the cell is cheap enough
to run on every bench invocation. Appends a ``fastpath_audit`` record
(per-cell audit summaries + worst-case SMEM/VMEM budget headroom) to
``BENCH_spmm.json``.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import append_cell, emit
from repro.analysis.budgets import budget_headroom_summary
from repro.analysis.dispatch import audit_jaxpr
from repro.analysis.retrace import RetraceSentinel
from repro.launch.jaxpr_stats import analyze_jaxpr


def _audit_cell(name, step, params, batches, expect_kernels):
    """One trace -> dispatch audit + FLOP cost + batch-signature count."""
    jaxpr = jax.make_jaxpr(step)(params, batches[0])
    report = audit_jaxpr(jaxpr)
    report.assert_fused(expect_kernels=expect_kernels)
    stats = analyze_jaxpr(jaxpr)

    sentinel = RetraceSentinel(budget=1)
    probe = sentinel.wrap(lambda p, b: None, name=name)
    for b in batches:
        probe(params, b)  # raises if any batch has a fresh signature
    summary = report.summary()
    summary["trace_count"] = sentinel.count(name)
    summary["pallas_flops"] = int(stats["pallas_flops"])
    emit(f"spmm/fastpath_audit/{name}",
         float(report.total_kernel_launches),
         f"fallbacks={report.oracle_fallbacks} "
         f"trace_count={summary['trace_count']}")
    return summary


def _forced_env(value: str):
    """Context manager flipping REPRO_USE_PALLAS around an abstract trace."""
    import contextlib

    @contextlib.contextmanager
    def cm():
        prev = os.environ.get("REPRO_USE_PALLAS")
        os.environ["REPRO_USE_PALLAS"] = value
        try:
            yield
        finally:
            if prev is None:
                os.environ.pop("REPRO_USE_PALLAS", None)
            else:
                os.environ["REPRO_USE_PALLAS"] = prev

    return cm()


def run(out_path: str = "BENCH_spmm.json") -> None:
    from repro.core.edge_index import EdgeIndex
    from repro.core.hetero import to_hetero
    from repro.data.data import Data, HeteroData
    from repro.data.hetero_sampler import HeteroNeighborLoader
    from repro.data.loader import NeighborLoader
    from repro.nn.gnn.conv import GATConv, SAGEConv, gcn_norm

    on_tpu = jax.default_backend() == "tpu"
    interpret = not on_tpu
    rng = np.random.default_rng(23)
    n, e, feat, hidden = 512, 4096, 32, 16
    data = Data(x=rng.standard_normal((n, feat)).astype(np.float32),
                edge_index=np.stack([rng.integers(0, n, e),
                                     rng.integers(0, n, e)]),
                y=rng.integers(0, 4, n))
    loader = NeighborLoader(data, data, num_neighbors=[4, 2], batch_size=8,
                            shuffle=True, prefill_ell=True,
                            pipeline_depth=2, prefetch=2, seed=0)
    it = iter(loader)
    batches = [next(it) for _ in range(3)]
    audits = {}

    # -- loader_step: plain 2-layer aggregation, value_and_grad ------------
    params = {
        "w1": jnp.asarray(rng.standard_normal((feat, hidden)) * 0.1,
                          jnp.float32),
        "w2": jnp.asarray(rng.standard_normal((hidden, 4)) * 0.1,
                          jnp.float32),
    }

    def loader_step(p, batch):
        def loss_fn(p):
            h = jax.nn.relu(batch.edge_index.matmul(
                batch.x @ p["w1"], force_pallas=True, interpret=interpret))
            out = batch.edge_index.matmul(
                h @ p["w2"], force_pallas=True, interpret=interpret)
            return (out[batch.seed_slots] ** 2).mean()

        return jax.value_and_grad(loss_fn)(p)

    audits["loader_step"] = _audit_cell(
        "loader_step", loader_step, params, batches,
        expect_kernels=("_spmm_ell_kernel",))

    # -- train_step: gcn-normalised (weighted) aggregation -----------------
    def train_step(p, batch):
        def loss_fn(p):
            ew, _ = gcn_norm(batch.edge_index, batch.num_nodes,
                             add_self_loops=False)
            h = jax.nn.relu(batch.edge_index.matmul(
                batch.x @ p["w1"], edge_weight=ew, force_pallas=True,
                interpret=interpret))
            out = batch.edge_index.matmul(
                h @ p["w2"], edge_weight=ew, force_pallas=True,
                interpret=interpret)
            return (out[batch.seed_slots] ** 2).mean()

        return jax.value_and_grad(loss_fn)(p)

    audits["train_step"] = _audit_cell(
        "train_step", train_step, params, batches,
        expect_kernels=("_spmm_ell_kernel",))

    # -- gat_step: fused flash-GAT attention kernel ------------------------
    conv = GATConv(feat, hidden, heads=4)
    gat_params = conv.init(jax.random.PRNGKey(0))

    def gat_step(p, batch):
        def loss_fn(p):
            out = conv.apply(p, batch.x, batch.edge_index)
            return (out[batch.seed_slots] ** 2).mean()

        return jax.value_and_grad(loss_fn)(p)

    with _forced_env("1"):
        audits["gat_step"] = _audit_cell(
            "gat_step", gat_step, gat_params, batches,
            expect_kernels=("_gat_ell_kernel",))

    # -- hetero_step: grouped per-type projections + per-relation SpMM -----
    n_user, n_item, he = 256, 512, 2048
    fan = {("user", "buys", "item"): [4, 2],
           ("item", "rev_buys", "user"): [4, 2]}
    hd = HeteroData()
    hd.add_nodes("user", rng.standard_normal((n_user, feat)).astype(
        np.float32))
    hd.add_nodes("item", rng.standard_normal((n_item, feat)).astype(
        np.float32))
    ub = np.stack([rng.integers(0, n_user, he), rng.integers(0, n_item, he)])
    hd.add_edges(("user", "buys", "item"), ub)
    hd.add_edges(("item", "rev_buys", "user"), ub[::-1])
    hloader = HeteroNeighborLoader(
        hd, hd, num_neighbors=fan, input_type="item",
        input_nodes=np.arange(n_item), batch_size=8, shuffle=True,
        prefill_ell=True, pipeline_depth=2, prefetch=2, seed=0)
    hit = iter(hloader)
    hbatches = [next(hit) for _ in range(3)]
    net = to_hetero(lambda i, o: SAGEConv(i, o), (["user", "item"],
                                                  list(fan)),
                    [feat, hidden, 4], grouped=True)
    hparams = net.init(jax.random.PRNGKey(0))

    def hetero_step(p, batch):
        def loss_fn(p):
            out = net.apply(p, batch.x_dict, batch.edge_index_dict,
                            batch.num_nodes_dict)
            return (batch.seed_output(out) ** 2).mean()

        return jax.value_and_grad(loss_fn)(p)

    with _forced_env("1"):
        audits["hetero_step"] = _audit_cell(
            "hetero_step", hetero_step, hparams, hbatches,
            expect_kernels=("_spmm_ell_kernel", "_gmm_kernel"))

    # -- hgt_step: typed carry-mode attention + grouped K/Q/V --------------
    from repro.core.hetero import hgt

    hgt_net = hgt((["user", "item"], list(fan)), [feat, hidden, hidden],
                  heads=4)
    hgt_params = hgt_net.init(jax.random.PRNGKey(0))

    def hgt_step(p, batch):
        def loss_fn(p):
            out = hgt_net.apply(p, batch.x_dict, batch.edge_index_dict,
                                batch.num_nodes_dict)
            return (batch.seed_output(out) ** 2).mean()

        return jax.value_and_grad(loss_fn)(p)

    with _forced_env("1"):
        audits["hgt_step"] = _audit_cell(
            "hgt_step", hgt_step, hgt_params, hbatches,
            expect_kernels=("_attn_ell_kernel", "_gmm_kernel"))

    headroom = budget_headroom_summary(feat=feat)
    rec = {
        "cell": "fastpath_audit",
        "backend": jax.default_backend(),
        "audits": audits,
        "budget_headroom": headroom,
    }
    emit("spmm/fastpath_audit/min_smem_headroom_bytes",
         float(headroom["min_smem_headroom_bytes"]),
         f"launches_audited={headroom['launches_audited']}")
    append_cell(out_path, rec)


if __name__ == "__main__":
    run()
