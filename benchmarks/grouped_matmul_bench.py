"""Heterogeneous grouped matmul {H_T W_T} vs a per-type Python loop
(paper §2.2 'grouped and segmented matrix multiplications ... CUTLASS').

Compares per-type sequential matmuls against the single grouped-GEMM
dispatch (XLA ragged_dot path on CPU; the Pallas kernel is the TPU target,
validated in interpret mode by tests).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.kernels.grouped_matmul import ops as gmm_ops


def run():
    rng = np.random.default_rng(4)
    for g, sizes in ((8, None), (32, None)):
        sizes = rng.integers(64, 512, g).astype(np.int32)
        k = n = 256
        m = int(sizes.sum())
        x = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
        w = jnp.asarray(rng.standard_normal((g, k, n)).astype(np.float32))
        gs = jnp.asarray(sizes)
        offs = np.concatenate([[0], np.cumsum(sizes)])

        def loop(x, w):
            outs = []
            for i in range(g):
                outs.append(x[offs[i]:offs[i + 1]] @ w[i])
            return jnp.concatenate(outs)

        loop_j = jax.jit(loop)
        grouped_j = jax.jit(
            lambda x, w, gs: gmm_ops.grouped_matmul(x, w, gs))
        a = loop_j(x, w)
        b = grouped_j(x, w, gs)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-3)
        t_loop = time_fn(loop_j, x, w)
        t_grp = time_fn(grouped_j, x, w, gs)
        emit(f"gmm/types{g}/loop_us", t_loop)
        emit(f"gmm/types{g}/grouped_us", t_grp,
             f"speedup={t_loop / t_grp:.2f}x")


if __name__ == "__main__":
    run()
