"""Explainability walkthrough (paper §2.4): train a GCN, explain a node with
three algorithms, report fidelity metrics and top edges.

Run:  PYTHONPATH=src python examples/explain_gnn.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.edge_index import EdgeIndex
from repro.core.explain import Explainer
from repro.nn.gnn.models import make_model


def main():
    rng = np.random.default_rng(3)
    n, f = 80, 8
    # two clusters; labels = cluster; inter-cluster edges are the
    # "irrelevant" structure a good explainer should down-weight
    comm = (np.arange(n) >= n // 2).astype(np.int64)
    src, dst = [], []
    for _ in range(600):
        a = rng.integers(0, n)
        b = rng.integers(0, n)
        if comm[a] == comm[b] or rng.random() < 0.15:
            src.append(a), dst.append(b)
    src, dst = np.array(src), np.array(dst)
    x = rng.standard_normal((n, f)).astype(np.float32)
    x[comm == 1] += 1.0
    ei = EdgeIndex.from_coo(src, dst, n, n)

    model = make_model("gcn", f, 32, 2, 2)
    params = model.init(jax.random.PRNGKey(0))
    xj, yj = jnp.asarray(x), jnp.asarray(comm)

    @jax.jit
    def step(p):
        def loss(p):
            lp = jax.nn.log_softmax(model.apply(p, xj, ei))
            return -jnp.take_along_axis(lp, yj[:, None], 1).mean()

        l, g = jax.value_and_grad(loss)(p)
        return jax.tree_util.tree_map(lambda a, b: a - 0.1 * b, p, g), l

    for i in range(80):
        params, l = step(params)
    acc = float((model.apply(params, xj, ei).argmax(-1) == yj).mean())
    print(f"trained GCN acc={acc * 100:.1f}%")

    node = 5
    for algo in ("saliency", "integrated_gradients", "gnn_explainer"):
        expl = Explainer(model, params, algorithm=algo, epochs=100)(
            xj, ei, node_idx=node)
        top = expl.top_edges(5)
        same = np.mean([comm[src[e]] == comm[dst[e]] for e in top])
        print(f"{algo:22s} fid+={expl.metrics['fidelity_plus']:+.3f} "
              f"fid-={expl.metrics['fidelity_minus']:+.3f} "
              f"unfaith={expl.metrics['unfaithfulness']:.3f} "
              f"top5_intra_cluster={same * 100:.0f}%")


if __name__ == "__main__":
    main()
