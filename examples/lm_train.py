"""End-to-end LM training driver (deliverable b): train a small decoder for
a few hundred steps on the synthetic pipeline, with checkpoints + resume.

Default is a ~19M-param model x 200 steps (CPU-friendly). ``--big`` switches
to a ~110M-param model (same code path; slower on this container). On TPU
the identical driver runs the full assigned configs under the production
mesh (see repro.launch.train / repro.launch.dryrun).

Run:  PYTHONPATH=src python examples/lm_train.py [--steps 200] [--big]
"""

import argparse
import dataclasses

import jax

from repro.nn.lm.config import ModelConfig
from repro.nn.lm import model as model_lib
from repro.train import data_pipeline, optimizer as opt_lib, steps
from repro.train.loop import train_loop

SMALL = ModelConfig(
    name="repro-19m", family="dense", n_layers=4, d_model=256, n_heads=4,
    n_kv_heads=2, head_dim=64, d_ff=1024, vocab_size=32000, act="silu",
    qk_norm=True, dtype="float32", tie_embeddings=True)

BIG = dataclasses.replace(SMALL, name="repro-110m", n_layers=8, d_model=640,
                          n_heads=10, n_kv_heads=2, d_ff=2560)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--big", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    cfg = BIG if args.big else SMALL
    ocfg = opt_lib.OptConfig(lr=3e-3, warmup_steps=20,
                             total_steps=args.steps)
    params = model_lib.init_model(jax.random.PRNGKey(0), cfg)
    n = sum(l.size for l in jax.tree_util.tree_leaves(params))
    print(f"model={cfg.name} params={n / 1e6:.1f}M "
          f"tokens/step={args.batch * args.seq}")
    state = opt_lib.init_state(params, ocfg)
    step = jax.jit(steps.make_train_step(cfg, ocfg), donate_argnums=(0,))
    batches = data_pipeline.synthetic_batches(cfg, args.batch, args.seq)
    out = train_loop(state, step, batches, num_steps=args.steps,
                     ckpt_dir=args.ckpt_dir, ckpt_every=100, log_every=20)
    hist = out["history"]
    print(f"loss: {hist[0][1]:.3f} -> {hist[-1][1]:.3f} "
          f"({'improved' if hist[-1][1] < hist[0][1] else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()
