"""Relational Deep Learning blueprint (paper §3.1) on synthetic tables.

Simulates a two-table relational database (users, transactions) as a
*genuinely heterogeneous* temporal graph — one node type per table, one
edge type per primary-foreign-key link (plus its reverse) — and runs the
full RDL loop on the jit-ready hetero stack:

  training table (seed entity, seed timestamp, label)
    -> HeteroNeighborLoader (typed <= t sampling, no leakage; per-relation
       host-prefilled EdgeIndex caches, registered-pytree HeteroBatch)
    -> jit'd to_hetero(GraphSAGE) train step — ONE compilation across
       batches, *on the kernel path*: with Pallas dispatch on (TPU backend
       or REPRO_USE_PALLAS=1) every relation's aggregation runs the
       bucketed ELL kernel and all per-type projections one grouped matmul
       per layer, in the backward pass too — the kernels' custom VJPs
       (scatter-add over the same ELL buckets; two grouped GEMMs over the
       same tile->group table) make jax.grad ride the same kernels the
       serving pass uses
    -> per-seed prediction of a future quantity (churn-style label)
    -> jit'd forward serving pass on the identical dispatch path

Run:  PYTHONPATH=src python examples/rdl_hetero_temporal.py
      REPRO_USE_PALLAS=1 PYTHONPATH=src python examples/rdl_hetero_temporal.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hetero import to_hetero
from repro.data.data import HeteroData
from repro.data.hetero_sampler import HeteroNeighborLoader
from repro.nn.gnn.conv import SAGEConv

ET_OF = ("txn", "of", "user")      # txn -> the user who made it
ET_MADE = ("user", "made", "txn")  # reverse, so txns receive messages too


def make_relational_db(rng, n_users=500, n_txn=5000, feat=16):
    """users(id, features); txns(id, user_fk, amount, timestamp)."""
    user_x = rng.standard_normal((n_users, feat)).astype(np.float32)
    txn_user = rng.integers(0, n_users, n_txn)
    txn_time = np.sort(rng.integers(0, 1000, n_txn))
    txn_amount = rng.exponential(1.0, n_txn).astype(np.float32)
    txn_x = np.zeros((n_txn, feat), np.float32)
    txn_x[:, 0] = txn_amount
    txn_x[:, 1] = np.log1p(txn_amount)
    txn_x[:, 2] = (txn_time / 1000.0).astype(np.float32)
    return user_x, txn_x, txn_user, txn_time, txn_amount


def main(steps=60, lr=0.02):
    rng = np.random.default_rng(0)
    user_x, txn_x, txn_user, txn_time, txn_amount = make_relational_db(rng)
    n_users, n_txn = len(user_x), len(txn_x)
    feat = user_x.shape[1]

    # each table is a node type; the FK link txn->user is an edge type,
    # with the reverse relation added so both types receive messages
    # (paper §3.1 / the PyG ToUndirected idiom)
    hd = HeteroData()
    hd.add_nodes("user", user_x)
    hd.add_nodes("txn", txn_x)
    hd.add_edges(ET_OF, np.stack([np.arange(n_txn), txn_user]),
                 time=txn_time)
    hd.add_edges(ET_MADE, np.stack([txn_user, np.arange(n_txn)]),
                 time=txn_time)

    # training table: (user, seed_time, label = total future spend > 1.0)
    seed_users = rng.integers(0, n_users, 256)
    seed_times = rng.integers(300, 900, 256)
    labels = np.zeros(256, np.int64)
    for i, (u, t) in enumerate(zip(seed_users, seed_times)):
        future = txn_amount[(txn_user == u) & (txn_time > t)].sum()
        labels[i] = int(future > 1.0)

    # iterate the training table in order; row ids via a closure counter —
    # externally-specified labels ride in through the transform hook
    row_ptr = {"i": 0}

    def transform(batch):
        b = len(np.asarray(batch.seed_slots))
        idx = np.arange(row_ptr["i"], row_ptr["i"] + b) % 256
        row_ptr["i"] += b
        batch.extras["label"] = jnp.asarray(labels[idx])
        return batch

    fanouts = {ET_OF: [8, 4], ET_MADE: [8, 4]}

    def make_loader(**kw):
        return HeteroNeighborLoader(
            hd, hd, num_neighbors=fanouts, input_type="user",
            input_nodes=seed_users, input_time=seed_times, batch_size=32,
            temporal_strategy="recent", labels_attr=None, prefetch=2, **kw)

    # training rides the SAME dispatch tree as serving: with Pallas on
    # (TPU / REPRO_USE_PALLAS=1) the loader prefills per-relation static
    # ELL caches and the jit'd grad step runs the bucketed ELL kernel +
    # one grouped projection matmul per layer forward AND backward (the
    # custom VJPs); with Pallas off everything falls to the XLA oracle
    loader = make_loader(transform=transform)
    metadata = (["user", "txn"], [ET_OF, ET_MADE])
    net = to_hetero(lambda i, o: SAGEConv(i, o), metadata, [feat, 32, 2])
    params = net.init(jax.random.PRNGKey(0))
    traces = []

    @jax.jit
    def train_step(params, batch):
        traces.append(1)  # appended only while tracing

        def loss_fn(p):
            out = net.apply(p, batch.x_dict, batch.edge_index_dict,
                            batch.num_nodes_dict)
            logp = jax.nn.log_softmax(batch.seed_output(out))
            y = batch.extras["label"]
            return -jnp.take_along_axis(logp, y[:, None], 1).mean()

        loss, g = jax.value_and_grad(loss_fn)(params)
        return jax.tree_util.tree_map(lambda p, d: p - lr * d, params, g), loss

    step = 0
    while step < steps:
        for batch in loader:
            params, loss = train_step(params, batch)
            step += 1
            if step % 20 == 0:
                print(f"step {step}: loss={float(loss):.4f}")
            if step >= steps:
                break
    print(f"training done: {len(traces)} compilation(s) across "
          f"{steps} steps")

    # serving pass: same network, same dispatch path as training — the
    # train/serve kernel split is gone now that the kernels differentiate
    serve_traces = []

    @jax.jit
    def predict(params, batch):
        serve_traces.append(1)
        out = net.apply(params, batch.x_dict, batch.edge_index_dict,
                        batch.num_nodes_dict)
        return jnp.argmax(batch.seed_output(out), axis=-1)

    row_ptr["i"] = 0
    preds = [np.asarray(predict(params, b))
             for b in make_loader(transform=transform)]
    acc = (np.concatenate(preds) == labels[:len(preds) * 32]).mean()
    print(f"RDL pipeline complete — temporal, hetero, externally-seeded; "
          f"serving accuracy {acc:.1%}, {len(serve_traces)} compilation(s) "
          f"across {len(preds)} batches.")


if __name__ == "__main__":
    main()
