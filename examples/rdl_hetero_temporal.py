"""Relational Deep Learning blueprint (paper §3.1) on synthetic tables.

Simulates a two-table relational database (users, transactions) as a
heterogeneous *temporal* graph, then runs the full RDL loop:

  training table (seed entity, seed timestamp, label)
    -> temporal NeighborLoader (<= t sampling, no leakage)
    -> to_hetero(GraphSAGE) over (user)<-[made]-(txn) edges
    -> per-seed prediction of a future quantity (churn-style label)

Run:  PYTHONPATH=src python examples/rdl_hetero_temporal.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hetero import to_hetero
from repro.data.data import Data
from repro.data.loader import NeighborLoader
from repro.nn.gnn.conv import SAGEConv


def make_relational_db(rng, n_users=500, n_txn=5000, feat=16):
    """users(id, features); txns(id, user_fk, amount, timestamp)."""
    user_x = rng.standard_normal((n_users, feat)).astype(np.float32)
    txn_user = rng.integers(0, n_users, n_txn)
    txn_time = np.sort(rng.integers(0, 1000, n_txn))
    txn_amount = rng.exponential(1.0, n_txn).astype(np.float32)
    txn_x = np.stack([txn_amount,
                      np.log1p(txn_amount),
                      (txn_time / 1000.0).astype(np.float32)],
                     axis=1).astype(np.float32)
    return user_x, txn_x, txn_user, txn_time, txn_amount


def main(steps=60, lr=0.02):
    rng = np.random.default_rng(0)
    user_x, txn_x, txn_user, txn_time, txn_amount = make_relational_db(rng)
    n_users, n_txn = len(user_x), len(txn_x)
    feat = user_x.shape[1]

    # pack the two entity sets into one homogeneous id space for the
    # temporal sampler (users first), with typed features re-fetched below;
    # the primary-foreign-key links txn->user become edges (paper §3.1)
    pad_txn = np.zeros((n_txn, feat), np.float32)
    pad_txn[:, :txn_x.shape[1]] = txn_x
    x_all = np.concatenate([user_x, pad_txn])
    src = n_users + np.arange(n_txn)   # txn -> its user
    dst = txn_user
    data = Data(x=x_all, edge_index=np.stack([src, dst]), time=txn_time,
                num_nodes=n_users + n_txn)

    # training table: (user, seed_time, label = total future spend > median)
    seed_users = rng.integers(0, n_users, 256)
    seed_times = rng.integers(300, 900, 256)
    labels = np.zeros(256, np.int64)
    for i, (u, t) in enumerate(zip(seed_users, seed_times)):
        future = txn_amount[(txn_user == u) & (txn_time > t)].sum()
        labels[i] = int(future > 1.0)

    def attach_labels(batch):
        # externally-specified labels ride in via the transform hook
        idx = batch.extras["row_ids"]
        batch.extras["label"] = jnp.asarray(labels[idx])
        return batch

    # iterate the training table in order; row ids via a closure counter
    row_ptr = {"i": 0}

    def transform(batch):
        b = len(np.asarray(batch.seed_slots))
        idx = np.arange(row_ptr["i"], row_ptr["i"] + b) % 256
        row_ptr["i"] += b
        batch.extras["row_ids"] = idx
        return attach_labels(batch)

    loader = NeighborLoader(
        data, data, num_neighbors=[8, 4], batch_size=32,
        input_nodes=seed_users, input_time=seed_times,
        temporal_strategy="recent", labels_attr=None, transform=transform)

    model = (lambda i, o: SAGEConv(i, o))
    net = to_hetero(model, (["n"], [("n", "e", "n")]), [feat, 32, 2])
    params = net.init(jax.random.PRNGKey(0))

    @jax.jit
    def train_step(params, x, ei, seeds, y):
        def loss_fn(p):
            out = net.apply(p, {"n": x}, {("n", "e", "n"): ei})["n"]
            logp = jax.nn.log_softmax(out[seeds])
            return -jnp.take_along_axis(logp, y[:, None], 1).mean()

        loss, g = jax.value_and_grad(loss_fn)(params)
        return jax.tree_util.tree_map(lambda p, d: p - lr * d, params, g), loss

    step = 0
    while step < steps:
        for batch in loader:
            params, loss = train_step(params, batch.x,
                                      batch.edge_index.data,
                                      batch.seed_slots,
                                      batch.extras["label"])
            step += 1
            if step % 20 == 0:
                print(f"step {step}: loss={float(loss):.4f}")
            if step >= steps:
                break
    print("RDL pipeline complete — temporal, hetero, externally-seeded.")


if __name__ == "__main__":
    main()
