"""GraphRAG retrieval workflow (paper §3.2, Figure 4) — toy end-to-end.

A 'knowledge graph' lives in FeatureStore/GraphStore; a query embedding
retrieves anchor entities (inner-product search), the NeighborLoader pulls
their contextual subgraph, a GNN encodes it, and pooled node embeddings form
the context vector that would condition an LLM. The LLM itself is out of
scope — the retrieval/encode pipeline is the paper's contribution.

Run:  PYTHONPATH=src python examples/graph_rag.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.data import Data
from repro.data.loader import NeighborLoader
from repro.nn.gnn.models import make_model


def mips(query: np.ndarray, keys: np.ndarray, k: int) -> np.ndarray:
    """Maximum inner product search (the FAISS role, §3.1/§3.2)."""
    return np.argsort(-(keys @ query))[:k]


def main():
    rng = np.random.default_rng(1)
    n, f = 1000, 32
    # entity embeddings with 10 latent topics
    topics = rng.integers(0, 10, n)
    topic_vecs = rng.standard_normal((10, f)).astype(np.float32)
    x = (topic_vecs[topics]
         + 0.3 * rng.standard_normal((n, f)).astype(np.float32))
    # KG edges: mostly intra-topic
    src = rng.integers(0, n, 8000)
    sames = rng.random(8000) < 0.8
    dst = np.where(sames,
                   rng.permutation(n)[topics[src] * 0 + rng.integers(0, n, 8000)],
                   rng.integers(0, n, 8000))
    # bias dst to same topic
    same_pool = {t: np.where(topics == t)[0] for t in range(10)}
    dst = np.array([rng.choice(same_pool[topics[s]]) if ss else d
                    for s, d, ss in zip(src, dst, sames)])
    kg = Data(x=x, edge_index=np.stack([src, dst]))

    gnn = make_model("sage", f, 64, f, 2)
    params = gnn.init(jax.random.PRNGKey(0))

    def answer(query_vec: np.ndarray, k_anchors=8):
        anchors = mips(query_vec, x, k_anchors)           # retrieve
        loader = NeighborLoader(kg, kg, num_neighbors=[6, 4],
                                batch_size=k_anchors, input_nodes=anchors,
                                labels_attr=None)
        batch = next(iter(loader))                        # subgraph
        enc = gnn.apply(params, batch.x, batch.edge_index.data,
                        num_nodes=batch.num_nodes)        # encode
        valid = np.asarray(batch.n_id) >= 0
        context = np.asarray(enc)[valid].mean(0)          # pool -> LLM ctx
        retrieved_topics = topics[np.asarray(batch.n_id)[valid]]
        return context, retrieved_topics

    # a query about topic 3
    q = topic_vecs[3] + 0.1 * rng.standard_normal(f).astype(np.float32)
    ctx, retrieved = answer(q)
    frac = (retrieved == 3).mean()
    print(f"context vector dim={ctx.shape[0]}, retrieved nodes={len(retrieved)}")
    print(f"topic purity of retrieved subgraph: {frac * 100:.0f}% "
          f"(chance=10%)")
    assert frac > 0.3


if __name__ == "__main__":
    main()
