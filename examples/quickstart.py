"""Quickstart: end-to-end mini-batch GNN training with the PyG 2.0 pipeline.

Builds a synthetic community graph (labels = community id), then runs the
full paper blueprint: Data (FeatureStore+GraphStore) -> NeighborLoader
(budgeted sampler) -> GraphSAGE -> jit'd train step with layer-wise
trimming. Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.data import Data
from repro.data.loader import NeighborLoader
from repro.nn.gnn.models import make_model


def make_community_graph(rng, n=2000, communities=4, feat=32,
                         p_in=0.02, p_out=0.002):
    """Stochastic block model + community-informative features."""
    comm = rng.integers(0, communities, n)
    src, dst = [], []
    n_edges = n * 10
    while len(src) < n_edges:
        a = rng.integers(0, n, n_edges)
        b = rng.integers(0, n, n_edges)
        same = comm[a] == comm[b]
        keep = rng.random(n_edges) < np.where(same, p_in * 50, p_out * 50)
        src.extend(a[keep].tolist())
        dst.extend(b[keep].tolist())
    src, dst = np.array(src[:n_edges]), np.array(dst[:n_edges])
    x = rng.standard_normal((n, feat)).astype(np.float32)
    x += np.eye(communities)[comm] @ rng.standard_normal(
        (communities, feat)).astype(np.float32) * 1.5
    return Data(x=x, edge_index=np.stack([src, dst]), y=comm), comm


def main(epochs=3, batch_size=128, lr=0.01):
    rng = np.random.default_rng(0)
    data, labels = make_community_graph(rng)
    n = len(labels)
    train_nodes = rng.permutation(n)[: n // 2]
    test_nodes = np.setdiff1d(np.arange(n), train_nodes)[:500]

    loader = NeighborLoader(data, data, num_neighbors=[10, 5],
                            batch_size=batch_size, input_nodes=train_nodes,
                            shuffle=True, prefetch=2)
    model = make_model("sage", 32, 64, 4, num_layers=2)
    params = model.init(jax.random.PRNGKey(0))

    import functools

    @functools.partial(jax.jit, static_argnums=(5, 6))
    def train_step(params, x, edge_index, seed_slots, y,
                   nodes_per_hop, edges_per_hop):
        def loss_fn(p):
            out = model.apply(p, x, edge_index,
                              num_sampled_nodes_per_hop=nodes_per_hop,
                              num_sampled_edges_per_hop=edges_per_hop,
                              trim=True)
            logp = jax.nn.log_softmax(out[seed_slots])
            return -jnp.take_along_axis(logp, y[:, None], 1).mean()

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params = jax.tree_util.tree_map(lambda p, g: p - lr * g, params,
                                        grads)
        return params, loss

    for epoch in range(epochs):
        losses = []
        for batch in loader:
            params, loss = train_step(
                params, batch.x, batch.edge_index.data, batch.seed_slots,
                batch.y, tuple(batch.num_sampled_nodes),
                tuple(batch.num_sampled_edges))
            losses.append(float(loss))
        print(f"epoch {epoch}: loss={np.mean(losses):.4f}")

    # full-batch evaluation (same model code — the paper's seamless
    # mini-batch <-> full-batch transition)
    from repro.core.edge_index import EdgeIndex
    csr = data.get_csr()
    full_ei = EdgeIndex.from_coo(
        np.repeat(np.arange(n), np.diff(csr.indptr)), csr.indices, n, n)
    out = model.apply(params, jnp.asarray(data.x), full_ei)
    acc = float((np.asarray(out.argmax(-1))[test_nodes]
                 == labels[test_nodes]).mean())
    print(f"test accuracy: {acc * 100:.1f}% (4 communities, chance=25%)")
    return acc


if __name__ == "__main__":
    main()
