"""MessagePassing (paper C2): path equivalence, flows, explainer callback."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.edge_index import EdgeIndex
from repro.core.message_passing import MessagePassing


class PlainSum(MessagePassing):
    pass  # default message + sum -> eligible for the fused SpMM path


class CustomMsg(MessagePassing):
    def message(self, params, x_j, x_i, edge_attr):
        return x_j * 2.0 + (0.0 if x_i is None else x_i * 0.5)


def test_fused_equals_materialized(rng):
    """The metadata-driven fast path must agree with edge materialisation."""
    n, e, f = 40, 150, 8
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    x = jnp.asarray(rng.standard_normal((n, f)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal(e).astype(np.float32))
    ei = EdgeIndex.from_coo(src, dst, n, n).fill_cache()
    mp = PlainSum(aggr="sum")
    fused = mp.propagate({}, ei, x, edge_weight=w)
    # force materialised path via raw array edge_index
    raw = mp.propagate({}, ei.data, x, edge_weight=w, num_nodes=n)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(raw),
                               rtol=1e-4, atol=1e-4)


def test_mean_fused_path(rng):
    n, e, f = 30, 100, 4
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    x = jnp.asarray(rng.standard_normal((n, f)).astype(np.float32))
    ei = EdgeIndex.from_coo(src, dst, n, n)
    mp = PlainSum(aggr="mean")
    out = mp.propagate({}, ei, x)
    ref = np.zeros((n, f), np.float32)
    cnt = np.zeros(n)
    for s, d in zip(src, dst):
        ref[d] += np.asarray(x)[s]
        cnt[d] += 1
    ref /= np.maximum(cnt, 1)[:, None]
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-4, atol=1e-4)


def test_flow_target_to_source(rng):
    n, e, f = 20, 60, 4
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    x = jnp.asarray(rng.standard_normal((n, f)).astype(np.float32))
    ei = EdgeIndex.from_coo(src, dst, n, n)
    rev = MessagePassing(aggr="sum", flow="target_to_source")
    out = rev.propagate({}, ei, x, num_nodes=n)
    ref = np.zeros((n, f), np.float32)
    for s, d in zip(src, dst):
        ref[s] += np.asarray(x)[d]
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-4, atol=1e-4)


def test_message_callback_masks_edges(rng):
    """The explainability hook c(.) must modulate messages per edge."""
    n, e, f = 15, 40, 4
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    x = jnp.asarray(rng.standard_normal((n, f)).astype(np.float32))
    ei = EdgeIndex.from_coo(src, dst, n, n)
    mp = CustomMsg(aggr="sum")
    full = mp.propagate({}, ei, x, num_nodes=n)
    zeroed = mp.propagate({}, ei, x, num_nodes=n,
                          message_callback=lambda m: m * 0.0)
    assert float(jnp.abs(zeroed).sum()) == 0.0
    half = mp.propagate({}, ei, x, num_nodes=n,
                        message_callback=lambda m: m * 0.5)
    np.testing.assert_allclose(np.asarray(half), np.asarray(full) * 0.5,
                               rtol=1e-4, atol=1e-5)


def test_bipartite(rng):
    ns, nd, e, f = 12, 9, 40, 4
    src = rng.integers(0, ns, e).astype(np.int32)
    dst = rng.integers(0, nd, e).astype(np.int32)
    xs = jnp.asarray(rng.standard_normal((ns, f)).astype(np.float32))
    xd = jnp.asarray(rng.standard_normal((nd, f)).astype(np.float32))
    ei = EdgeIndex.from_coo(src, dst, ns, nd)
    out = PlainSum(aggr="sum").propagate({}, ei, (xs, xd))
    assert out.shape == (nd, f)
    ref = np.zeros((nd, f), np.float32)
    for s, d in zip(src, dst):
        ref[d] += np.asarray(xs)[s]
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-4, atol=1e-4)
