"""PR 10: data-parallel mesh scale-out.

Covers the ``shard_map`` train step end to end on forced host-platform
devices (conftest sets ``--xla_force_host_platform_device_count=8``):

  * ``launch/mesh.py`` helpers on the modern ``jax.sharding.Mesh`` API;
  * loader shard splitting with -1 tail padding (non-dividing batches);
  * the sampler's masked-seed handling;
  * compression round-trips, error feedback, and compressed-psum parity;
  * ``MeshTrainer`` grad/loss parity vs the single-device accumulation
    oracle, single-trace behaviour, and the golden dispatch audit;
  * checkpointed elastic resize (4 -> 2 devices, bit-identical params).
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.data.data import Data
from repro.data.loader import (Batch, NeighborLoader, split_seed_shards,
                               stack_batches)
from repro.data.sampler import NeighborSampler
from repro.distributed import compression as comp_lib
from repro.launch.mesh import (HOST_DEVICE_FLAG, data_parallel_mesh,
                               host_device_flag, make_mesh)
from repro.launch.train import MeshTrainer
from repro.train import optimizer as opt_lib

FEAT, HIDDEN = 32, 16


def _graph(n=256, e=2048, seed=0):
    rng = np.random.default_rng(seed)
    return Data(x=rng.standard_normal((n, FEAT)).astype(np.float32),
                edge_index=np.stack([rng.integers(0, n, e),
                                     rng.integers(0, n, e)]),
                y=rng.standard_normal(n).astype(np.float32))


def _params(seed=1):
    rng = np.random.default_rng(seed)
    return {"w1": jnp.asarray(rng.standard_normal((FEAT, HIDDEN)) * 0.1,
                              jnp.float32),
            "w2": jnp.asarray(rng.standard_normal((HIDDEN, 1)) * 0.1,
                              jnp.float32)}


def _loss_fn(force_pallas=False):
    from repro.nn.gnn.conv import gcn_norm
    interpret = True if force_pallas else None

    def loss_fn(params, batch):
        ew, _ = gcn_norm(batch.edge_index, batch.num_nodes,
                         add_self_loops=False)
        h = jax.nn.relu(batch.edge_index.matmul(
            batch.x @ params["w1"], edge_weight=ew,
            force_pallas=force_pallas, interpret=interpret))
        out = batch.edge_index.matmul(h @ params["w2"], edge_weight=ew,
                                      force_pallas=force_pallas,
                                      interpret=interpret)
        err = ((out[batch.seed_slots] - batch.y[:, None]) ** 2).sum(axis=-1)
        mask = batch.seed_mask.astype(jnp.float32)
        return (err * mask).sum(), mask.sum()

    return loss_fn


def _loader(data, shards, *, n_seeds=24, batch_size=8, **kw):
    kw.setdefault("prefill_ell", False)
    return NeighborLoader(data, data, num_neighbors=[4, 2],
                          batch_size=batch_size,
                          input_nodes=np.arange(n_seeds), drop_last=False,
                          shards=shards, seed=0, **kw)


def _oracle_step(loss_fn, cfg, d):
    """Single-device gradient accumulation over the same shards."""

    @jax.jit
    def step(state, stacked):
        def total(p):
            ls = ws = 0.0
            for i in range(d):
                shard = jax.tree_util.tree_map(lambda l, i=i: l[i], stacked)
                l, w = loss_fn(p, shard)
                ls, ws = ls + l, ws + w
            return ls, ws
        (loss_sum, weight), grads = jax.value_and_grad(
            total, has_aux=True)(state.params)
        w = jnp.maximum(weight, 1e-12)
        grads = jax.tree_util.tree_map(lambda g: g / w, grads)
        state, metrics = opt_lib.apply_updates(state, grads, cfg)
        metrics["loss"] = loss_sum / w
        return state, metrics

    return step


def _max_param_diff(a, b):
    return max(float(jnp.max(jnp.abs(x - y))) for x, y in zip(
        jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)))


# --------------------------------------------------------------- mesh.py
class TestMeshHelpers:
    def test_forced_host_devices_visible(self):
        # conftest must have forced 8 devices before jax initialised
        assert len(jax.devices()) >= 8

    def test_make_mesh_shape_and_axes(self):
        mesh = make_mesh((2, 2), ("data", "model"))
        assert mesh.shape == {"data": 2, "model": 2}
        assert mesh.axis_names == ("data", "model")

    def test_make_mesh_sub_mesh_over_prefix(self):
        # a 2-device mesh inside an 8-device process: the scaling sweep's
        # core requirement the stale all-device helpers couldn't express
        mesh = make_mesh((2,), ("data",))
        assert mesh.devices.size == 2
        assert list(mesh.devices.ravel()) == list(jax.devices()[:2])

    def test_make_mesh_shape_axes_mismatch(self):
        with pytest.raises(ValueError, match="disagree"):
            make_mesh((2, 2), ("data",))

    def test_make_mesh_too_few_devices_names_flag(self):
        with pytest.raises(ValueError) as ei:
            make_mesh((1024,), ("data",))
        msg = str(ei.value)
        assert HOST_DEVICE_FLAG in msg and "1024" in msg

    def test_host_device_flag(self):
        assert host_device_flag(4) == f"{HOST_DEVICE_FLAG}=4"

    def test_data_parallel_mesh(self):
        mesh = data_parallel_mesh(4, axis_name="data")
        assert mesh.axis_names == ("data",)
        assert mesh.devices.size == 4


# ------------------------------------------------------- loader sharding
class TestLoaderSharding:
    def test_split_even(self):
        parts = split_seed_shards(np.arange(8), None, 4)
        assert len(parts) == 4
        assert all(len(s) == 2 for s, _ in parts)
        np.testing.assert_array_equal(
            np.concatenate([s for s, _ in parts]), np.arange(8))

    def test_split_non_dividing_pads_minus_one(self):
        parts = split_seed_shards(np.arange(6), None, 4)
        seeds = np.concatenate([s for s, _ in parts])
        assert len(seeds) == 8
        np.testing.assert_array_equal(seeds[:6], np.arange(6))
        np.testing.assert_array_equal(seeds[6:], [-1, -1])

    def test_split_pads_time_with_zero(self):
        t = np.arange(5, dtype=np.int64) + 100
        parts = split_seed_shards(np.arange(5), t, 2)
        times = np.concatenate([tt for _, tt in parts])
        assert len(times) == 6
        assert times[-1] == 0

    def test_split_invalid_shards(self):
        with pytest.raises(ValueError, match="shards"):
            split_seed_shards(np.arange(4), None, 0)

    def test_stacked_batch_shapes(self):
        data = _graph()
        batches = list(_loader(data, shards=4))
        assert len(batches) == 3
        for b in batches:
            assert isinstance(b, Batch)
            for leaf in jax.tree_util.tree_leaves(b):
                assert leaf.shape[0] == 4

    def test_tail_batch_padded_not_dropped(self):
        # 20 seeds / batch 8 -> last batch has 4 seeds over 4 shards:
        # 1 per shard, no padding; 18 seeds -> last batch 2 over 4 shards,
        # 2 shards get a -1 pad seed each. Neither crashes nor drops seeds.
        data = _graph()
        batches = list(_loader(data, shards=4, n_seeds=18))
        assert len(batches) == 3
        tail = batches[-1]
        seed_ids = np.asarray(tail.n_id)[
            np.arange(4)[:, None], np.asarray(tail.seed_slots)]
        real = seed_ids[seed_ids >= 0]
        assert sorted(real.tolist()) == [16, 17]
        assert (seed_ids < 0).sum() == 2  # the two pad seeds

    def test_seed_mask_and_label_padding(self):
        data = _graph()
        batches = list(_loader(data, shards=4, n_seeds=18))
        tail = batches[-1]
        for i in range(4):
            shard = jax.tree_util.tree_map(lambda l, i=i: l[i], tail)
            mask = np.asarray(shard.seed_mask)
            y = np.asarray(shard.y)
            # padded seeds contribute zero labels and a False mask
            assert (y[~mask] == 0).all()
            sid = np.asarray(shard.n_id)[np.asarray(shard.seed_slots)]
            np.testing.assert_array_equal(mask, sid >= 0)

    def test_health_counts_global_batches(self):
        data = _graph()
        plain = _loader(data, shards=1)
        list(plain)
        sharded = _loader(data, shards=4)
        list(sharded)
        assert plain.health == sharded.health
        assert sharded.health["batches"] == 3
        assert sharded.health["skipped_batches"] == 0

    def test_sharded_equals_concat_of_plain_shards(self):
        # shard i of the stacked batch == a plain loader run over the same
        # seed slice (same sampler seed): sharding only regroups seeds
        data = _graph()
        stacked = next(iter(_loader(data, shards=2, n_seeds=8)))
        shard0 = jax.tree_util.tree_map(lambda l: l[0], stacked)
        plain = next(iter(_loader(data, shards=1, n_seeds=4, batch_size=4)))
        np.testing.assert_array_equal(np.asarray(shard0.n_id),
                                      np.asarray(plain.n_id))
        np.testing.assert_allclose(np.asarray(shard0.x),
                                   np.asarray(plain.x))

    def test_stack_batches_roundtrip(self):
        data = _graph()
        plain = list(_loader(data, shards=1, n_seeds=8, batch_size=4))
        stacked = stack_batches(plain)
        assert stacked.x.shape == (2,) + plain[0].x.shape
        back = jax.tree_util.tree_map(lambda l: l[1], stacked)
        np.testing.assert_array_equal(np.asarray(back.n_id),
                                      np.asarray(plain[1].n_id))


# ------------------------------------------------------- sampler pad seeds
class TestSamplerPadSeeds:
    def test_minus_one_seed_keeps_layout(self):
        data = _graph()
        sampler = NeighborSampler(data, [3, 2], seed=0)
        out = sampler.sample(np.array([5, -1, 7]))
        assert out.node[0] == -1                      # null sink
        np.testing.assert_array_equal(out.node[1:4], [5, -1, 7])
        np.testing.assert_array_equal(out.seed_slots, [1, 2, 3])

    def test_minus_one_seed_expands_nothing(self):
        data = _graph()
        sampler = NeighborSampler(data, [4], seed=0)
        out = sampler.sample(np.array([-1]))
        assert (out.edge < 0).all()                   # all edges padding
        assert (out.node[2:] == -1).all()             # no neighbors found

    def test_no_dedup_corruption_from_pad(self):
        # the old slot_of[seeds] wrote slot ids through index -1 onto the
        # LAST global node; sampling that node afterwards must still work
        data = _graph()
        sampler = NeighborSampler(data, [2], seed=0)
        sampler.sample(np.array([3, -1]))
        n_last = sampler.csr.num_rows - 1
        out = sampler.sample(np.array([n_last, 3]))
        np.testing.assert_array_equal(out.node[1:3], [n_last, 3])
        assert (sampler._slot_of == -1).all()         # lookup fully reset


# ------------------------------------------------------------ compression
class TestCompression:
    def test_int8_roundtrip_bound(self, rng):
        x = jnp.asarray(rng.standard_normal(257).astype(np.float32))
        q, scale = comp_lib.quantize_int8(x)
        err = jnp.abs(comp_lib.dequantize_int8(q, scale) - x)
        assert float(err.max()) <= float(scale) * 0.5001 + 1e-7

    def test_topk_ratio_one_lossless(self, rng):
        x = jnp.asarray(rng.standard_normal((13, 7)).astype(np.float32))
        v, i = comp_lib.topk_compress(x, x.size)
        back = comp_lib.topk_decompress(v, i, x.shape)
        np.testing.assert_allclose(np.asarray(back), np.asarray(x),
                                   rtol=0, atol=0)

    def test_topk_partial_keeps_largest(self, rng):
        x = jnp.asarray(np.array([0.1, -5.0, 0.2, 3.0], np.float32))
        v, i = comp_lib.topk_compress(x, 2)
        back = np.asarray(comp_lib.topk_decompress(v, i, x.shape))
        np.testing.assert_allclose(back, [0.0, -5.0, 0.0, 3.0])

    @pytest.mark.parametrize("method,ratio", [("int8", 1.0), ("topk", 0.25)])
    def test_error_feedback_telescopes(self, rng, method, ratio):
        # sum of dequantised payloads + final residual == sum of raw grads
        grads = [
            {"w": jnp.asarray(rng.standard_normal((6, 5)), jnp.float32)}
            for _ in range(5)]
        residual = comp_lib.init_residual(grads[0])
        applied = jnp.zeros((6, 5))
        for g in grads:
            payload, residual = comp_lib.compress_grads(
                g, residual, method=method, ratio=ratio)
            applied = applied + comp_lib.decompress_grads(
                payload, g, method=method)["w"]
        total = sum(g["w"] for g in grads)
        np.testing.assert_allclose(np.asarray(applied + residual["w"]),
                                   np.asarray(total), rtol=1e-5, atol=1e-5)

    def test_compressed_allreduce_matches_psum(self, rng):
        # topk at ratio 1.0 is lossless: the all_gather+decompress-sum path
        # must agree with a plain psum to <= 1e-5
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        mesh = data_parallel_mesh(4)
        g = jnp.asarray(rng.standard_normal((4, 8, 3)), jnp.float32)
        r = jnp.zeros((4, 8, 3), jnp.float32)

        def body(g, r):
            lg = {"w": g[0]}
            summed, _ = comp_lib.compressed_allreduce(
                lg, {"w": r[0]}, axis_name="data", method="topk", ratio=1.0)
            return summed["w"]

        got = jax.jit(shard_map(
            body, mesh, in_specs=(P("data"), P("data")),
            out_specs=P(), check_rep=False))(g, r)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(g.sum(axis=0)),
                                   rtol=1e-5, atol=1e-5)

    def test_unknown_method_raises(self):
        with pytest.raises(ValueError, match="method"):
            comp_lib.compress_grads({"w": jnp.zeros(3)},
                                    {"w": jnp.zeros(3)}, method="fft")

    def test_payload_nbytes_orders(self):
        like = {"w": jnp.zeros((100, 100))}
        raw = 100 * 100 * 4
        assert comp_lib.payload_nbytes(like, method="int8") < raw
        assert comp_lib.payload_nbytes(
            like, method="topk", ratio=0.01) < raw // 10


# ------------------------------------------------------------ mesh trainer
@pytest.fixture(scope="module")
def trained_pair():
    """(mesh_state, oracle_state, trainer, batches, state0, cfg): one
    4-device epoch stepped by both the sharded and the oracle step."""
    data = _graph()
    loss_fn = _loss_fn()
    cfg = opt_lib.OptConfig(lr=1e-2, warmup_steps=1, total_steps=50)
    state0 = opt_lib.init_state(_params(), cfg)
    mesh = data_parallel_mesh(4)
    trainer = MeshTrainer(loss_fn, cfg, mesh=mesh)
    batches = list(_loader(data, shards=4, n_seeds=22))  # tail: 6 seeds
    oracle = _oracle_step(loss_fn, cfg, 4)
    s_mesh = s_orc = state0
    losses = []
    for b in batches:
        s_mesh, m = trainer.step(s_mesh, b)
        s_orc, mo = oracle(s_orc, b)
        losses.append((float(m["loss"]), float(mo["loss"])))
    return s_mesh, s_orc, trainer, batches, state0, cfg, losses


class TestMeshTrainer:
    def test_grad_parity_4dev(self, trained_pair):
        s_mesh, s_orc = trained_pair[0], trained_pair[1]
        assert _max_param_diff(s_mesh.params, s_orc.params) <= 1e-5
        assert _max_param_diff(s_mesh.mu, s_orc.mu) <= 1e-5

    def test_loss_parity(self, trained_pair):
        losses = trained_pair[6]
        assert all(abs(a - b) <= 1e-5 for a, b in losses)

    def test_single_trace_across_batches(self, trained_pair):
        assert trained_pair[2].trace_count == 1

    def test_wrong_leading_dim_rejected(self, trained_pair):
        trainer, batches, state0 = (trained_pair[2], trained_pair[3],
                                    trained_pair[4])
        shard = jax.tree_util.tree_map(lambda l: l[:2], batches[0])
        with pytest.raises(ValueError, match="shards=4"):
            trainer.step(state0, shard)

    def test_golden_dispatch_audit(self):
        # forced-Pallas loss: the sharded step must show the same kernel
        # set as the single-device step, exactly one fused psum, zero
        # oracle fallbacks. Abstract trace only (no interpret execution).
        from repro.analysis.dispatch import audit_report
        data = _graph()
        cfg = opt_lib.OptConfig(lr=1e-2, warmup_steps=1, total_steps=50)
        state0 = opt_lib.init_state(_params(), cfg)
        trainer = MeshTrainer(_loss_fn(force_pallas=True), cfg,
                              mesh=data_parallel_mesh(4))
        batch = next(iter(_loader(data, shards=4, prefill_ell=True)))
        rep = audit_report(trainer._step.__wrapped__, state0, batch)
        rep.assert_fused(expect_kernels=("_spmm_ell_kernel",),
                         min_launches=2,
                         expect_collectives={"psum": 1})
        assert rep.oracle_fallbacks == 0

    def test_compressed_topk_full_ratio_parity(self, trained_pair):
        # the compressed all-reduce machinery at ratio=1.0 must reproduce
        # the raw-psum step to <= 1e-5 (mechanism parity)
        batches, state0, cfg = (trained_pair[3], trained_pair[4],
                                trained_pair[5])
        s_orc = trained_pair[1]
        tr = MeshTrainer(_loss_fn(), cfg, mesh=data_parallel_mesh(4),
                         compression="topk", compression_ratio=1.0)
        s = state0
        for b in batches:
            s, _ = tr.step(s, b)
        assert _max_param_diff(s.params, s_orc.params) <= 1e-5
        assert tr.trace_count == 1

    def test_compressed_int8_steps_and_converges(self, trained_pair):
        batches, state0, cfg = (trained_pair[3], trained_pair[4],
                                trained_pair[5])
        tr = MeshTrainer(_loss_fn(), cfg, mesh=data_parallel_mesh(4),
                         compression="int8")
        s = state0
        first = last = None
        for _ in range(3):
            for b in batches:
                s, m = tr.step(s, b)
                first = first if first is not None else float(m["loss"])
                last = float(m["loss"])
        assert np.isfinite(last) and last < first

    def test_collective_bytes_compressed_below_raw(self, trained_pair):
        from repro.launch import jaxpr_stats
        batches, state0, cfg = (trained_pair[3], trained_pair[4],
                                trained_pair[5])
        raw_tr = trained_pair[2]
        raw = jaxpr_stats.analyze_jaxpr(
            raw_tr.step_jaxpr(state0, batches[0]))
        int8_tr = MeshTrainer(_loss_fn(), cfg, mesh=data_parallel_mesh(4),
                              compression="int8")
        int8 = jaxpr_stats.analyze_jaxpr(
            int8_tr.step_jaxpr(state0, batches[0]))
        assert raw["collective_bytes"] > 0
        assert int8["collective_bytes"] < raw["collective_bytes"]

    def test_invalid_compression_rejected(self, trained_pair):
        cfg = trained_pair[5]
        with pytest.raises(ValueError, match="compression"):
            MeshTrainer(_loss_fn(), cfg, mesh=data_parallel_mesh(2),
                        compression="zip")

    def test_needs_1d_mesh(self, trained_pair):
        cfg = trained_pair[5]
        with pytest.raises(ValueError, match="1-D"):
            MeshTrainer(_loss_fn(), cfg, mesh=make_mesh((2, 2),
                                                        ("data", "model")))


# ------------------------------------------------- checkpoint + elastic
class TestElasticResize:
    def test_resize_4_to_2_bit_identical(self, tmp_path, trained_pair):
        s_mesh, trainer, state0 = (trained_pair[0], trained_pair[2],
                                   trained_pair[4])
        trainer.save(str(tmp_path), 7, s_mesh)
        small = MeshTrainer(_loss_fn(), trained_pair[5],
                            mesh=data_parallel_mesh(2))
        restored, step = small.restore(str(tmp_path), state0)
        assert step == 7
        for a, b in zip(jax.tree_util.tree_leaves(s_mesh),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_resized_trainer_continues(self, tmp_path, trained_pair):
        s_mesh, state0, cfg = (trained_pair[0], trained_pair[4],
                               trained_pair[5])
        trained_pair[2].save(str(tmp_path), 3, s_mesh)
        small = MeshTrainer(_loss_fn(), cfg, mesh=data_parallel_mesh(2),
                            compression="topk", compression_ratio=1.0)
        restored, _ = small.restore(str(tmp_path), state0)
        assert small._residual is None  # error feedback restarts on resize
        data = _graph()
        batch = next(iter(_loader(data, shards=2)))
        s, m = small.step(restored, batch)
        assert np.isfinite(float(m["loss"]))
        assert int(s.step) == int(restored.step) + 1
