"""Jit-ready loader batches: host-side cache pre-fill, static ELL layout,
single-trace Pallas dispatch, and the satellite bugfixes.

Covers the PR-2 chain:

    NeighborLoader._make_batch (producer thread)
      -> EdgeIndex.from_coo_prefilled (CSC/CSR + static ELL, host numpy)
        -> jit'd step(batch) -> EdgeIndex.matmul -> spmm_ell_pallas
           (one trace across batches; capacity-padded buckets)
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.edge_index import EdgeIndex
from repro.core.message_passing import MessagePassing
from repro.data.data import Data
from repro.data.loader import Batch, NeighborLoader
from repro.data.sampler import static_slot_bounds
from repro.kernels.spmm import ops as spmm_ops, ref as spmm_ref


def _data(rng, n=200, e=1200, feat=16):
    return Data(x=rng.standard_normal((n, feat)).astype(np.float32),
                edge_index=np.stack([rng.integers(0, n, e),
                                     rng.integers(0, n, e)]),
                y=rng.integers(0, 4, n))


# --------------------------------------------------------- static ELL packing
def test_static_slot_bounds_layout():
    bounds = static_slot_bounds(8, [4, 3])
    # seeds [1,9) bounded by fanout 4; hop-1 block [9,41) bounded by 3;
    # hop-2 block receives nothing and is absent.
    assert bounds == [(1, 9, 4), (9, 41, 3)]
    layout = spmm_ops.ell_layout_from_bounds(bounds)
    assert len(layout) == 1  # both ranges share the K=4 rung
    rows, k = layout[0]
    assert k == 4 and len(rows) % 8 == 0
    assert set(rows[rows >= 0].tolist()) == set(range(1, 41))


def test_csr_to_ell_static_matches_oracle(rng):
    """Static-layout packing must aggregate identically to the CSR oracle
    on the rows it covers, for every reduce mode."""
    n_rows, n_cols = 23, 17
    deg = rng.integers(0, 5, n_rows)
    indptr = np.concatenate([[0], np.cumsum(deg)]).astype(np.int64)
    indices = rng.integers(0, n_cols, int(indptr[-1])).astype(np.int32)
    layout = spmm_ops.ell_layout_from_bounds([(0, n_rows, 6)])
    buckets = spmm_ops.csr_to_ell_static(indptr, indices, layout)
    (row_ids, ell_idx, pos), = buckets
    assert len(row_ids) == len(ell_idx) == -(-n_rows // 8) * 8
    assert (row_ids < 0).sum() == len(row_ids) - n_rows  # capacity pads
    x = jnp.asarray(rng.standard_normal((n_cols, 128)).astype(np.float32))
    w = rng.standard_normal(len(indices)).astype(np.float32)
    for reduce in ("sum", "mean", "max", "min"):
        a = spmm_ref.spmm_csr(jnp.asarray(indptr), jnp.asarray(indices), x,
                              jnp.asarray(w), num_rows=n_rows, reduce=reduce)
        b = spmm_ops.spmm_ell_bucketed(buckets, x, jnp.asarray(w),
                                       num_rows=n_rows, reduce=reduce,
                                       force_pallas=False)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-5)


def test_csr_to_ell_static_shapes_fixed_across_inputs(rng):
    """Two different degree realisations against one layout -> identical
    bucket shapes (the no-recompile invariant)."""
    layout = spmm_ops.ell_layout_from_bounds([(1, 9, 4), (9, 41, 3)])

    def pack(seed):
        r = np.random.default_rng(seed)
        deg = np.concatenate([[0], r.integers(0, 4, 40), np.zeros(24, int)])
        indptr = np.concatenate([[0], np.cumsum(deg)])
        indices = r.integers(0, 65, int(indptr[-1])).astype(np.int32)
        return spmm_ops.csr_to_ell_static(indptr, indices, layout)

    a, b = pack(1), pack(2)
    assert [(r.shape, i.shape, p.shape) for r, i, p in a] == \
           [(r.shape, i.shape, p.shape) for r, i, p in b]


def test_csr_to_ell_static_overflow_raises(rng):
    indptr = np.array([0, 9])  # one row, degree 9
    indices = np.zeros(9, np.int32)
    layout = spmm_ops.ell_layout_from_bounds([(0, 1, 4)])  # K=4 < 9
    with pytest.raises(ValueError, match="static ELL layout violated"):
        spmm_ops.csr_to_ell_static(indptr, indices, layout)


# ------------------------------------------------------- loader cache pre-fill
def test_loader_prefills_caches_host_side(rng):
    loader = NeighborLoader(_data(rng), _data(rng), num_neighbors=[4, 3],
                            batch_size=8, prefill_ell=True)
    it = iter(loader)
    b1, b2 = next(it), next(it)
    for b in (b1, b2):
        ei = b.edge_index
        assert ei._csr is not None and ei._csc is not None
        assert ei._ell is not None and len(ei._ell) >= 1
        # CSC is destination-sorted with a consistent permutation
        colptr, row, perm = (np.asarray(t) for t in ei._csc)
        np.testing.assert_array_equal(
            np.asarray(ei.dst)[perm], np.sort(np.asarray(ei.dst)))
        assert colptr[-1] == ei.num_edges
    # identical pytree structure + shapes across batches
    assert (jax.tree_util.tree_structure(b1)
            == jax.tree_util.tree_structure(b2))
    assert ([l.shape for l in jax.tree_util.tree_leaves(b1)]
            == [l.shape for l in jax.tree_util.tree_leaves(b2)])


def test_loader_prefill_off_by_default_on_cpu(rng, monkeypatch):
    monkeypatch.setenv("REPRO_USE_PALLAS", "0")
    b = next(iter(NeighborLoader(_data(rng), _data(rng), num_neighbors=[3],
                                 batch_size=8)))
    assert b.edge_index._csc is not None  # CSR/CSC always host-filled
    assert b.edge_index._ell is None      # no ELL packing cost off-Pallas
    monkeypatch.setenv("REPRO_USE_PALLAS", "1")
    b = next(iter(NeighborLoader(_data(rng), _data(rng), num_neighbors=[3],
                                 batch_size=8)))
    assert b.edge_index._ell is not None  # env-driven default follows dispatch


def test_loader_batch_matmul_parity(rng):
    """Prefilled-cache matmul == oracle on the raw COO, all reduce modes."""
    loader = NeighborLoader(_data(rng), _data(rng), num_neighbors=[4, 3],
                            batch_size=8, prefill_ell=True)
    b = next(iter(loader))
    raw = EdgeIndex(b.edge_index.data, b.num_nodes, b.num_nodes)
    for reduce in ("sum", "mean", "max", "min"):
        fast = b.edge_index.matmul(b.x, reduce=reduce, force_pallas=True)
        ref = raw.matmul(b.x, reduce=reduce, force_pallas=False)
        np.testing.assert_allclose(np.asarray(fast), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)


def test_loader_batch_hits_pallas_single_trace(rng):
    """The acceptance path: prefetch-producer batches dispatch to the Pallas
    ELL kernel inside jit, with ONE trace across two different batches —
    proven statically by the jaxpr dispatch auditor (zero oracle-scope eqns,
    a `_spmm_ell_kernel` launch) plus a RetraceSentinel over the batches,
    instead of a monkey-patched kernel spy."""
    from repro.analysis import RetraceSentinel, audit_report

    loader = NeighborLoader(_data(rng), _data(rng), num_neighbors=[4, 3],
                            batch_size=8, prefetch=2, prefill_ell=True)

    sentinel = RetraceSentinel(budget=1)

    @jax.jit
    def step(batch):
        return batch.edge_index.matmul(batch.x, force_pallas=True)

    step = sentinel.wrap(step, name="loader_step")
    it = iter(loader)
    b1, b2 = next(it), next(it)
    report = audit_report(step, b1)
    report.assert_fused(expect_kernels=("_spmm_ell_kernel",))
    assert report.oracle_fallbacks == 0
    o1, o2 = step(b1), step(b2)
    assert sentinel.count("loader_step") == 1, \
        "second batch retraced: pytree not static"
    for b, o in ((b1, o1), (b2, o2)):
        raw = EdgeIndex(b.edge_index.data, b.num_nodes, b.num_nodes)
        np.testing.assert_allclose(
            np.asarray(o), np.asarray(raw.matmul(b.x, force_pallas=False)),
            rtol=1e-4, atol=1e-4)


def test_disjoint_loader_batches_jit_ready(rng):
    loader = NeighborLoader(_data(rng, n=60, e=400), _data(rng, n=60, e=400),
                            num_neighbors=[3, 2], batch_size=6,
                            disjoint=True, prefill_ell=True)
    b = next(iter(loader))
    assert b.edge_index._ell is not None
    fast = b.edge_index.matmul(b.x, force_pallas=True)
    raw = EdgeIndex(b.edge_index.data, b.num_nodes, b.num_nodes)
    np.testing.assert_allclose(np.asarray(fast),
                               np.asarray(raw.matmul(b.x)),
                               rtol=1e-4, atol=1e-4)


def test_batch_is_pytree_roundtrip(rng):
    b = next(iter(NeighborLoader(_data(rng), _data(rng), num_neighbors=[3],
                                 batch_size=8)))
    leaves, treedef = jax.tree_util.tree_flatten(b)
    b2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(b2, Batch)
    assert b2.num_sampled_nodes == b.num_sampled_nodes
    np.testing.assert_array_equal(np.asarray(b2.n_id), np.asarray(b.n_id))


# -------------------------------------------------------- satellite bugfixes
def test_from_coo_tracer_needs_node_counts(rng):
    src = jnp.asarray(rng.integers(0, 10, 30), jnp.int32)
    dst = jnp.asarray(rng.integers(0, 10, 30), jnp.int32)

    @jax.jit
    def f(s, d):
        return EdgeIndex.from_coo(s, d).data

    with pytest.raises(ValueError, match="num_src_nodes/num_dst_nodes"):
        f(src, dst)
    # explicit counts still work under tracing
    @jax.jit
    def g(s, d):
        return EdgeIndex.from_coo(s, d, 10, 10).data

    np.testing.assert_array_equal(np.asarray(g(src, dst)),
                                  np.stack([np.asarray(src),
                                            np.asarray(dst)]))


def test_target_to_source_uses_fused_transpose(rng, monkeypatch):
    """t2s flow must dispatch to matmul(transpose=True), not edge-level
    materialisation, and agree with it numerically."""
    seen = []
    real = EdgeIndex.matmul
    monkeypatch.setattr(
        EdgeIndex, "matmul",
        lambda self, x, **kw: (seen.append(kw.get("transpose", False)),
                               real(self, x, **kw))[1])
    n, e = 30, 110
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    x = jnp.asarray(rng.standard_normal((n, 8)).astype(np.float32))
    ei = EdgeIndex.from_coo(src, dst, n, n)
    for aggr in ("sum", "mean", "max", "min"):
        mp = MessagePassing(aggr=aggr, flow="target_to_source")
        fused = mp.propagate({}, ei, x)
        raw = mp.propagate({}, ei.data, x, num_nodes=n)
        np.testing.assert_allclose(np.asarray(fused), np.asarray(raw),
                                   rtol=1e-5, atol=1e-5)
    assert seen and all(seen), "t2s did not take the transpose SpMM path"
