"""EdgeIndex (paper C1): metadata, caches, SpMM path, undirected sharing."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.edge_index import EdgeIndex, coalesce


def _random_graph(rng, n=50, e=200):
    return (rng.integers(0, n, e).astype(np.int32),
            rng.integers(0, n, e).astype(np.int32))


def test_sort_and_csr_csc(rng):
    src, dst = _random_graph(rng)
    ei = EdgeIndex.from_coo(src, dst, 50, 50)
    sorted_row, perm = ei.sort_by("row")
    assert sorted_row.sort_order == "row"
    assert bool(np.all(np.diff(np.asarray(sorted_row.src)) >= 0))
    np.testing.assert_array_equal(np.asarray(ei.data[:, perm]),
                                  np.asarray(sorted_row.data))
    rowptr, col, perm_r = ei.get_csr()
    assert ei._csr is not None, "cache must be demand-filled"
    # rowptr consistency: count of edges per row
    counts = np.bincount(src, minlength=50)
    np.testing.assert_array_equal(np.diff(np.asarray(rowptr)), counts)
    # CSC = transpose
    colptr, row, perm_c = ei.get_csc()
    counts_c = np.bincount(dst, minlength=50)
    np.testing.assert_array_equal(np.diff(np.asarray(colptr)), counts_c)


def test_matmul_vs_dense(rng):
    src, dst = _random_graph(rng, 30, 120)
    ei = EdgeIndex.from_coo(src, dst, 30, 30).fill_cache()
    x = rng.standard_normal((30, 8)).astype(np.float32)
    w = rng.standard_normal(120).astype(np.float32)
    dense = np.zeros((30, 30), np.float32)
    for s, d, ww in zip(src, dst, w):
        dense[d, s] += ww
    out = ei.matmul(jnp.asarray(x), edge_weight=jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(out), dense @ x, rtol=1e-4,
                               atol=1e-4)
    # transpose path (the cached backward adjacency)
    out_t = ei.matmul(jnp.asarray(x), edge_weight=jnp.asarray(w),
                      transpose=True)
    np.testing.assert_allclose(np.asarray(out_t), dense.T @ x, rtol=1e-4,
                               atol=1e-4)


def test_undirected_cache_shared(rng):
    src, dst = _random_graph(rng, 20, 60)
    ei = EdgeIndex.from_coo(src, dst, 20, 20).to_undirected()
    assert ei.is_undirected
    ei.get_csc()
    assert ei._csr is None
    ei.get_csr()  # must reuse the CSC cache (A == A^T)
    assert ei._csr is ei._csc or np.shares_memory(
        np.asarray(ei._csr[0]), np.asarray(ei._csc[0]))


def test_cache_never_memoizes_tracers(rng):
    """First use inside jit must not leak tracers into later traces."""
    import jax
    src, dst = _random_graph(rng, 20, 60)
    ei = EdgeIndex.from_coo(src, dst, 20, 20)
    x = jnp.asarray(rng.standard_normal((20, 4)).astype(np.float32))

    @jax.jit
    def f(x):
        return ei.matmul(x)

    out1 = f(x)                    # fills nothing (tracer guard)
    assert ei._csc is None
    out2 = ei.matmul(x)            # eager: memoises concrete arrays
    assert ei._csc is not None
    out3 = f(x * 2)                # re-jit uses the concrete cache — no leak
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(out3), np.asarray(out2) * 2,
                               rtol=1e-5)


def test_coalesce(rng):
    src = np.array([0, 1, 0, 1, 2], np.int32)
    dst = np.array([1, 2, 1, 2, 0], np.int32)
    ei = coalesce(EdgeIndex.from_coo(src, dst, 3, 3))
    assert ei.num_edges == 3


def test_validate_catches_out_of_range():
    ei = EdgeIndex.from_coo([0, 5], [1, 1], num_src_nodes=3,
                            num_dst_nodes=3)
    with pytest.raises(AssertionError):
        ei.validate()


@settings(max_examples=20, deadline=None)
@given(st.integers(5, 40), st.integers(1, 100), st.integers(0, 2 ** 31 - 1))
def test_matmul_property(n, e, seed):
    """SpMM over random graphs == dense reference (property-based)."""
    r = np.random.default_rng(seed)
    src = r.integers(0, n, e).astype(np.int32)
    dst = r.integers(0, n, e).astype(np.int32)
    x = r.standard_normal((n, 4)).astype(np.float32)
    ei = EdgeIndex.from_coo(src, dst, n, n)
    dense = np.zeros((n, n), np.float32)
    for s, d in zip(src, dst):
        dense[d, s] += 1
    np.testing.assert_allclose(np.asarray(ei.matmul(jnp.asarray(x))),
                               dense @ x, rtol=2e-4, atol=2e-4)
