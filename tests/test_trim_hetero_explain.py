"""Trimming (C8), heterogeneous MP (C4), explainability (C11)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.edge_index import EdgeIndex
from repro.core.explain import Explainer
from repro.core.hetero import GroupedLinear, HeteroConv, to_hetero
from repro.core.trim import trim_sizes, trim_to_layer
from repro.data.data import Data
from repro.data.loader import NeighborLoader
from repro.nn.gnn.conv import GATConv, SAGEConv
from repro.nn.gnn.models import make_model


# ------------------------------------------------------------------ trimming
def test_trim_sizes_monotone():
    nodes, edges = [9, 40, 80], [40, 80]
    n0, e0 = trim_sizes(nodes, edges, 0)
    n1, e1 = trim_sizes(nodes, edges, 1)
    assert (n0, e0) == (129, 120)
    assert (n1, e1) == (49, 40)
    assert n1 < n0 and e1 < e0


@pytest.mark.parametrize("model_name", ["gcn", "sage", "gin", "gat",
                                        "edgecnn"])
def test_trim_preserves_seed_outputs(rng, model_name):
    """The paper's invariant: trimming never changes seed representations."""
    n = 300
    ei = np.stack([rng.integers(0, n, 1500), rng.integers(0, n, 1500)])
    data = Data(x=rng.standard_normal((n, 16)).astype(np.float32),
                edge_index=ei, y=rng.integers(0, 3, n))
    loader = NeighborLoader(data, data, num_neighbors=[4, 3, 2],
                            batch_size=6)
    batch = next(iter(loader))
    model = make_model(model_name, 16, 32, 4, 3)
    params = model.init(jax.random.PRNGKey(0))
    full = model.apply(params, batch.x, batch.edge_index.data,
                       num_nodes=batch.num_nodes)
    trim = model.apply(params, batch.x, batch.edge_index.data,
                       num_sampled_nodes_per_hop=batch.num_sampled_nodes,
                       num_sampled_edges_per_hop=batch.num_sampled_edges,
                       trim=True)
    np.testing.assert_allclose(
        np.asarray(full[batch.seed_slots]),
        np.asarray(trim[batch.seed_slots]), rtol=1e-3, atol=1e-4)


def test_trim_reduces_flops(rng):
    """Trimmed execution must do strictly less dot work (jaxpr-counted)."""
    from repro.launch import jaxpr_stats
    n = 300
    ei = np.stack([rng.integers(0, n, 1500), rng.integers(0, n, 1500)])
    data = Data(x=rng.standard_normal((n, 16)).astype(np.float32),
                edge_index=ei)
    loader = NeighborLoader(data, data, num_neighbors=[4, 3, 2],
                            batch_size=6, labels_attr=None)
    batch = next(iter(loader))
    model = make_model("sage", 16, 32, 4, 3)
    params = model.init(jax.random.PRNGKey(0))
    f_full = jaxpr_stats.step_stats(
        lambda p: model.apply(p, batch.x, batch.edge_index.data,
                              num_nodes=batch.num_nodes), params)
    f_trim = jaxpr_stats.step_stats(
        lambda p: model.apply(
            p, batch.x, batch.edge_index.data,
            num_sampled_nodes_per_hop=batch.num_sampled_nodes,
            num_sampled_edges_per_hop=batch.num_sampled_edges, trim=True),
        params)
    assert f_trim["dot_flops"] < f_full["dot_flops"] * 0.8


# -------------------------------------------------------------------- hetero
def _hetero_fixture(rng):
    nt = ["a", "b"]
    et = [("a", "ab", "b"), ("b", "ba", "a")]
    x = {"a": jnp.asarray(rng.standard_normal((12, 8)).astype(np.float32)),
         "b": jnp.asarray(rng.standard_normal((9, 8)).astype(np.float32))}
    ei = {("a", "ab", "b"): jnp.asarray(np.stack(
        [rng.integers(0, 12, 30), rng.integers(0, 9, 30)]).astype(np.int32)),
        ("b", "ba", "a"): jnp.asarray(np.stack(
            [rng.integers(0, 9, 30), rng.integers(0, 12, 30)]).astype(
            np.int32))}
    return nt, et, x, ei


def test_hetero_conv_matches_manual(rng):
    nt, et, x, ei = _hetero_fixture(rng)
    convs = {t: SAGEConv(8, 16) for t in et}
    hc = HeteroConv(convs, aggr="sum")
    params = hc.init(jax.random.PRNGKey(0))
    out = hc.apply(params, x, ei, {"a": 12, "b": 9})
    manual_b = convs[et[0]].apply(params["a__ab__b"], (x["a"], x["b"]),
                                  ei[et[0]], num_nodes=9)
    np.testing.assert_allclose(np.asarray(out["b"]), np.asarray(manual_b),
                               rtol=1e-4, atol=1e-5)


def test_to_hetero_replicates_per_edge_type(rng):
    nt, et, x, ei = _hetero_fixture(rng)
    model = to_hetero(lambda i, o: SAGEConv(i, o), (nt, et), [8, 16, 4])
    params = model.init(jax.random.PRNGKey(0))
    # param structure: one conv per edge type per layer
    assert set(params["layer0"].keys()) == {"a__ab__b", "b__ba__a"}
    out = model.apply(params, x, ei)
    assert out["a"].shape == (12, 4) and out["b"].shape == (9, 4)
    g = jax.grad(lambda p: sum(
        (v ** 2).sum() for v in model.apply(p, x, ei).values()))(params)
    assert all(np.isfinite(np.asarray(l)).all()
               for l in jax.tree_util.tree_leaves(g))


def test_grouped_linear_matches_loop(rng):
    types = ["t0", "t1", "t2"]
    x = {t: jnp.asarray(rng.standard_normal((5 + i, 12)).astype(np.float32))
         for i, t in enumerate(types)}
    gl = GroupedLinear(types, 12, 20)
    p = gl.init(jax.random.PRNGKey(0))
    out = gl.apply(p, x)
    for i, t in enumerate(types):
        np.testing.assert_allclose(np.asarray(out[t]),
                                   np.asarray(x[t] @ p["w"][i]), rtol=2e-4,
                                   atol=2e-4)


# ------------------------------------------------------------- explainability
def test_explainer_algorithms_produce_masks(rng):
    n, e, f = 30, 100, 8
    ei = EdgeIndex.from_coo(rng.integers(0, n, e).astype(np.int32),
                            rng.integers(0, n, e).astype(np.int32), n, n)
    x = jnp.asarray(rng.standard_normal((n, f)).astype(np.float32))
    model = make_model("gcn", f, 16, 3, 2)
    params = model.init(jax.random.PRNGKey(0))
    for algo in ("saliency", "integrated_gradients", "gnn_explainer"):
        expl = Explainer(model, params, algorithm=algo, epochs=10)(
            x, ei, node_idx=5)
        assert expl.edge_mask.shape == (e,)
        assert np.isfinite(np.asarray(expl.edge_mask)).all()
        assert set(expl.metrics) == {"fidelity_plus", "fidelity_minus",
                                     "unfaithfulness"}


def test_attention_explainer_uses_gat(rng):
    n, e, f = 25, 80, 8
    ei = EdgeIndex.from_coo(rng.integers(0, n, e).astype(np.int32),
                            rng.integers(0, n, e).astype(np.int32), n, n)
    x = jnp.asarray(rng.standard_normal((n, f)).astype(np.float32))
    model = make_model("gat", f, 16, 3, 2)
    params = model.init(jax.random.PRNGKey(0))
    expl = Explainer(model, params, algorithm="attention")(x, ei, node_idx=2)
    assert expl.edge_mask.shape == (e,)


def test_gnn_explainer_finds_planted_edge(rng):
    """A label fully determined by one edge must rank that edge top-3."""
    n, f = 12, 4
    # node 0's representation driven by node 1 through edge (1 -> 0)
    src = np.concatenate([[1], rng.integers(2, n, 20)]).astype(np.int32)
    dst = np.concatenate([[0], rng.integers(2, n, 20)]).astype(np.int32)
    ei = EdgeIndex.from_coo(src, dst, n, n)
    x = np.zeros((n, f), np.float32)
    x[1] = 10.0  # only node 1 carries signal
    model = make_model("sage", f, 8, 2, 1)
    params = model.init(jax.random.PRNGKey(1))
    expl = Explainer(model, params, algorithm="gnn_explainer", epochs=80)(
        jnp.asarray(x), ei, node_idx=0)
    assert 0 in expl.top_edges(3), "planted edge not in top-3"
