"""Typed-attention fast path: HGT rides the generalised flash kernel (PR 9).

The acceptance chain for the typed-attention tentpole:

    loader-prefilled hetero batch
      -> jit'd HGT value_and_grad train step, Pallas dispatch on
        -> ONE grouped matmul for all K/Q/V projections (3·|T| groups)
        -> one carry-mode `_attn_ell_kernel` launch per relation
           (scaled dot logits x the typed prior mu[rel])
        -> per-destination-type `merge_carries`: the cross-type softmax
           over ALL incoming edges, no cross-relation materialisation
      == COO-oracle AND hand-rolled dense-softmax outputs/grads,
         ONE trace across batches

plus the merged `return_attention` round trip (alphas sum to 1 *across*
relations), hetero layer trimming keeping seed outputs, the carry
merge/finalize unit contract, and the regression that GAT's additive path
stayed bit-identical through the refactor.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.edge_index import EdgeIndex
from repro.core.hetero import HGTConv, hgt
from repro.data.data import HeteroData
from repro.data.hetero_sampler import HeteroNeighborLoader
from repro.kernels.attention import ops as attn_ops
from repro.kernels.attention import ref as attn_ref

ET_UB = ("user", "buys", "item")
ET_RU = ("item", "rev_buys", "user")
FANOUTS = {ET_UB: [3, 2], ET_RU: [3, 2]}


def _spy(monkeypatch, module, name):
    calls = []
    real = getattr(module, name)
    monkeypatch.setattr(module, name,
                        lambda *a, **k: (calls.append(1), real(*a, **k))[1])
    return calls


def _hetero_inputs(rng, n_user=30, n_item=40, e=180, feat=12):
    x = {"user": jnp.asarray(rng.standard_normal((n_user, feat)),
                             jnp.float32),
         "item": jnp.asarray(rng.standard_normal((n_item, feat)),
                             jnp.float32)}
    ub = np.stack([rng.integers(0, n_user, e).astype(np.int32),
                   rng.integers(0, n_item, e).astype(np.int32)])
    edges = {ET_UB: ub, ET_RU: ub[::-1]}
    nn = {"user": n_user, "item": n_item}
    return x, edges, nn


def _cached_ei(edges, nn):
    out = {}
    for (src_t, _, dst_t), arr in edges.items():
        ei = EdgeIndex.from_coo(arr[0], arr[1], nn[src_t], nn[dst_t])
        out[(src_t, _, dst_t)] = ei.fill_cache()
    return out


def _raw_ei(edges, nn):
    return {et: EdgeIndex(jnp.asarray(np.ascontiguousarray(arr)),
                          nn[et[0]], nn[et[2]])
            for et, arr in edges.items()}


def _dense_hgt(conv, params, x_dict, edges, nn, edge_mask=None):
    """Hand-rolled materialised HGT forward: per-node cross-type softmax
    over the explicit (E, H) logits of the union of relations."""
    T = len(conv.node_types)
    H, D = conv.heads, conv.head_dim
    ti = {t: i for i, t in enumerate(conv.node_types)}
    k, q, v = {}, {}, {}
    for t, x in x_dict.items():
        k[t] = (x @ params["w_kqv"][ti[t]]
                + params["b_kqv"][ti[t]]).reshape(-1, H, D)
        q[t] = (x @ params["w_kqv"][T + ti[t]]
                + params["b_kqv"][T + ti[t]]).reshape(-1, H, D)
        v[t] = (x @ params["w_kqv"][2 * T + ti[t]]
                + params["b_kqv"][2 * T + ti[t]]).reshape(-1, H, D)
    scale = float(D) ** -0.5
    per_dst = {}
    for r, et in enumerate(conv.edge_types):
        if et not in edges:
            continue
        src_t, _, dst_t = et
        src, dst = jnp.asarray(edges[et][0]), jnp.asarray(edges[et][1])
        k_rel = jnp.einsum("nhd,hde->nhe", k[src_t], params["a_rel"][r])
        v_rel = jnp.einsum("nhd,hde->nhe", v[src_t], params["m_rel"][r])
        logits = ((k_rel[src] * q[dst_t][dst]).sum(-1) * scale
                  * params["mu"][r][None, :])
        w = (None if edge_mask is None else edge_mask.get(et))
        per_dst.setdefault(dst_t, []).append((logits, dst, v_rel[src], w))
    out = {}
    for t, chunks in per_dst.items():
        logits = jnp.concatenate([c[0] for c in chunks])
        dst = jnp.concatenate([c[1] for c in chunks])
        msg = jnp.concatenate([c[2] for c in chunks])
        n = nn[t]
        mx = jax.lax.stop_gradient(
            jax.ops.segment_max(logits, dst, num_segments=n))
        mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
        ex = jnp.exp(logits - mx[dst])
        den = jax.ops.segment_sum(ex, dst, num_segments=n)
        alpha = ex / jnp.maximum(den[dst], 1e-16)
        if any(c[3] is not None for c in chunks):
            w = jnp.concatenate([
                c[3] if c[3] is not None else jnp.ones(c[0].shape[0])
                for c in chunks])
            alpha = alpha * w[:, None]
        agg = jax.ops.segment_sum(msg * alpha[..., None], dst,
                                  num_segments=n)
        h = jax.nn.gelu(agg.reshape(n, H * D))
        o = h @ params["w_out"][ti[t]] + params["b_out"][ti[t]]
        x = x_dict[t]
        if conv.in_features == conv.out_features:
            gate = jax.nn.sigmoid(params["skip"][ti[t]])
            o = gate * o.astype(x.dtype) + (1.0 - gate) * x
        out[t] = o
    for t in x_dict:
        out.setdefault(t, x_dict[t])
    return out


# ----------------------------------------------------------- forward parity
@pytest.mark.parametrize("heads", [1, 2, 4])
def test_hgt_fused_matches_dense_and_oracle(rng, monkeypatch, heads):
    """Fused HGT == hand-rolled dense cross-type softmax == COO oracle."""
    feat = 12
    x, edges, nn = _hetero_inputs(rng, feat=feat)
    conv = HGTConv(feat, 8 * heads, (["user", "item"], [ET_UB, ET_RU]),
                   heads=heads)
    params = conv.init(jax.random.PRNGKey(0))
    want = _dense_hgt(conv, params, x, edges, nn)

    monkeypatch.setenv("REPRO_USE_PALLAS", "1")
    calls = _spy(monkeypatch, attn_ops, "attn_ell_pallas")
    got = conv.apply(params, x, _cached_ei(edges, nn), nn)
    assert len(calls) >= len(edges), \
        "not every relation's typed attention hit the fused kernel"
    monkeypatch.setenv("REPRO_USE_PALLAS", "0")
    oracle = conv.apply(params, x, _raw_ei(edges, nn), nn)
    for t in want:
        np.testing.assert_allclose(np.asarray(got[t]), np.asarray(want[t]),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(oracle[t]),
                                   np.asarray(want[t]), rtol=1e-4,
                                   atol=1e-5)


def test_hgt_skip_gate_residual_active(rng, monkeypatch):
    """in==out dims engage the sigmoid(skip)-gated residual; forcing the
    gate towards 0 must pull outputs towards the inputs."""
    monkeypatch.setenv("REPRO_USE_PALLAS", "0")
    feat = 16
    x, edges, nn = _hetero_inputs(rng, feat=feat)
    conv = HGTConv(feat, feat, (["user", "item"], [ET_UB, ET_RU]), heads=4)
    params = conv.init(jax.random.PRNGKey(1))
    closed = dict(params, skip=jnp.full((2,), -30.0))
    out = conv.apply(closed, x, _raw_ei(edges, nn), nn)
    for t in x:
        np.testing.assert_allclose(np.asarray(out[t]), np.asarray(x[t]),
                                   rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------- grad parity
@pytest.mark.parametrize("masked", [False, True])
def test_hgt_grad_parity_fused_vs_oracle(rng, monkeypatch, masked):
    """jax.grad through the carry kernel's custom VJP == autodiff through
    the COO oracle, for params, features, and the per-relation mask."""
    feat = 12
    x, edges, nn = _hetero_inputs(rng, feat=feat)
    mask = ({et: jnp.asarray(rng.random(arr.shape[1]), jnp.float32)
             for et, arr in edges.items()} if masked else None)
    conv = HGTConv(feat, 16, (["user", "item"], [ET_UB, ET_RU]), heads=2)
    params = conv.init(jax.random.PRNGKey(2))

    def loss(p, x_, ei):
        out = conv.apply(p, x_, ei, nn, edge_mask_dict=mask)
        return sum((o ** 2).mean() for o in out.values())

    monkeypatch.setenv("REPRO_USE_PALLAS", "1")
    calls = _spy(monkeypatch, attn_ops, "attn_ell_pallas")
    bwd = _spy(monkeypatch, attn_ref, "attn_carry_panels")
    gk = jax.grad(loss, argnums=(0, 1))(params, x, _cached_ei(edges, nn))
    assert calls, "grad step never reached the fused typed-attention kernel"
    assert bwd, "grad step never ran the carry-panel backward"

    monkeypatch.setenv("REPRO_USE_PALLAS", "0")
    go = jax.grad(loss, argnums=(0, 1))(params, x, _raw_ei(edges, nn))
    diffs = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a - b).max()), gk, go)
    max_diff = max(jax.tree_util.tree_leaves(diffs))
    assert max_diff <= 1e-5, f"kernel-grad != oracle-grad: {max_diff}"


# ---------------------------------------------------------- return_attention
def test_hgt_return_attention_cross_relation_simplex(rng, monkeypatch):
    """Merged alphas: each destination node's coefficients sum to 1
    *jointly across relations*, and fused == oracle coefficients."""
    feat = 12
    x, edges, nn = _hetero_inputs(rng, feat=feat)
    conv = HGTConv(feat, 16, (["user", "item"], [ET_UB, ET_RU]), heads=2)
    params = conv.init(jax.random.PRNGKey(3))

    monkeypatch.setenv("REPRO_USE_PALLAS", "1")
    out_k, alpha_k = conv.apply(params, x, _cached_ei(edges, nn), nn,
                                return_attention=True)
    monkeypatch.setenv("REPRO_USE_PALLAS", "0")
    out_o, alpha_o = conv.apply(params, x, _raw_ei(edges, nn), nn,
                                return_attention=True)
    for et in edges:
        np.testing.assert_allclose(np.asarray(alpha_k[et]),
                                   np.asarray(alpha_o[et]), rtol=1e-4,
                                   atol=1e-6)
    for t in out_k:
        np.testing.assert_allclose(np.asarray(out_k[t]),
                                   np.asarray(out_o[t]), rtol=1e-4,
                                   atol=1e-5)
    # per-node row sums ACROSS relations == 1 (the cross-type softmax)
    for t, n in nn.items():
        tot = jnp.zeros((n, conv.heads))
        for et, arr in edges.items():
            if et[2] != t:
                continue
            dst = jnp.asarray(arr[1])
            tot = tot.at[dst].add(alpha_k[et])
        deg = np.zeros(n)
        for et, arr in edges.items():
            if et[2] == t:
                np.add.at(deg, arr[1], 1)
        rows = np.asarray(tot)[deg > 0]
        np.testing.assert_allclose(rows, np.ones_like(rows), rtol=1e-4,
                                   atol=1e-5)


# ------------------------------------------------- carry merge unit contract
def test_merge_carries_is_union_softmax(rng):
    """Merging per-relation carries == one softmax over the edge union;
    all-empty rows finalize to exact zeros (no NaN from -inf maxima)."""
    n, h, f = 10, 2, 4
    logits1 = jnp.asarray(rng.standard_normal((n, h)), jnp.float32) * 3
    logits2 = jnp.asarray(rng.standard_normal((n, h)), jnp.float32) * 3
    z1 = jnp.asarray(rng.standard_normal((n, h, f)), jnp.float32)
    z2 = jnp.asarray(rng.standard_normal((n, h, f)), jnp.float32)

    # honest single-edge carries: m = logit, l = exp(0) = 1, acc = z
    c1 = attn_ops.SoftmaxCarry(logits1, jnp.ones_like(logits1), z1)
    c2 = attn_ops.SoftmaxCarry(logits2, jnp.ones_like(logits2), z2)
    merged = attn_ops.merge_carries([c1, c2])
    got = attn_ops.finalize_carry(merged)
    w1 = jax.nn.softmax(jnp.stack([logits1, logits2]), axis=0)
    want = w1[0][..., None] * z1 + w1[1][..., None] * z2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                               atol=1e-6)
    # empty rows: m = -inf, l = 0, acc = 0 — merge + finalize stay finite
    neg = jnp.full((n, h), -jnp.inf)
    empty = attn_ops.SoftmaxCarry(neg, jnp.zeros_like(neg),
                                  jnp.zeros_like(z1))
    still = attn_ops.finalize_carry(attn_ops.merge_carries([empty, c1]))
    np.testing.assert_allclose(np.asarray(still), np.asarray(z1), rtol=1e-5,
                               atol=1e-6)
    both = attn_ops.finalize_carry(attn_ops.merge_carries([empty, empty]))
    assert np.isfinite(np.asarray(both)).all()
    np.testing.assert_array_equal(np.asarray(both),
                                  np.zeros_like(np.asarray(both)))


# ------------------------------------------------- loader single-trace step
def test_hgt_loader_step_single_trace_grad_parity(rng, monkeypatch):
    """The acceptance criterion: a jit'd 2-layer HGT train step over
    HeteroNeighborLoader batches runs the fused kernel forward and backward
    with ONE trace across batches, gradients == COO oracle <= 1e-5."""
    monkeypatch.setenv("REPRO_USE_PALLAS", "1")
    calls = _spy(monkeypatch, attn_ops, "attn_ell_pallas")
    bwd = _spy(monkeypatch, attn_ref, "attn_carry_panels")
    n_user, n_item, e, feat, hidden = 80, 120, 600, 8, 8
    hd = HeteroData()
    hd.add_nodes("user",
                 rng.standard_normal((n_user, feat)).astype(np.float32))
    hd.add_nodes("item",
                 rng.standard_normal((n_item, feat)).astype(np.float32))
    ub = np.stack([rng.integers(0, n_user, e), rng.integers(0, n_item, e)])
    hd.add_edges(ET_UB, ub)
    hd.add_edges(ET_RU, ub[::-1])
    loader = HeteroNeighborLoader(
        hd, hd, num_neighbors=FANOUTS, input_type="item",
        input_nodes=np.arange(n_item), batch_size=6, prefill_ell=True,
        seed=0)
    net = hgt((["user", "item"], list(FANOUTS)), [feat, hidden, hidden],
              heads=2)
    params = net.init(jax.random.PRNGKey(4))
    traces = []

    def loss_fn(p, ei_dict, batch):
        out = net.apply(p, batch.x_dict, ei_dict, batch.num_nodes_dict)
        return (batch.seed_output(out) ** 2).mean()

    @jax.jit
    def step(p, batch):
        traces.append(1)
        return jax.value_and_grad(loss_fn)(p, batch.edge_index_dict, batch)

    it = iter(loader)
    b1, b2 = next(it), next(it)
    for b in (b1, b2):
        loss_k, grad_k = step(params, b)
        assert calls, "train step never reached the typed-attention kernel"
        assert bwd, "train step never ran the carry-panel backward"
        monkeypatch.setenv("REPRO_USE_PALLAS", "0")
        raw = {et: EdgeIndex(ei.data, ei.num_src_nodes, ei.num_dst_nodes)
               for et, ei in b.edge_index_dict.items()}
        loss_o, grad_o = jax.value_and_grad(loss_fn)(params, raw, b)
        monkeypatch.setenv("REPRO_USE_PALLAS", "1")
        np.testing.assert_allclose(float(loss_k), float(loss_o), rtol=1e-5)
        diffs = jax.tree_util.tree_map(
            lambda a, b_: float(jnp.abs(a - b_).max()), grad_k, grad_o)
        max_diff = max(jax.tree_util.tree_leaves(diffs))
        assert max_diff <= 1e-5, f"kernel-grad != oracle-grad: {max_diff}"
    assert len(traces) == 1, "second batch retraced the HGT grad step"


# -------------------------------------------------------------------- trim
def test_hgt_trim_preserves_seed_outputs(rng, monkeypatch):
    """Layer-wise hetero trimming of the HGT stack: inner hops keep the
    fused typed kernel and seed representations are unchanged."""
    monkeypatch.setenv("REPRO_USE_PALLAS", "1")
    n_user, n_item, e, feat = 120, 160, 900, 8
    hd = HeteroData()
    hd.add_nodes("user",
                 rng.standard_normal((n_user, feat)).astype(np.float32))
    hd.add_nodes("item",
                 rng.standard_normal((n_item, feat)).astype(np.float32))
    ub = np.stack([rng.integers(0, n_user, e), rng.integers(0, n_item, e)])
    hd.add_edges(ET_UB, ub)
    hd.add_edges(ET_RU, ub[::-1])
    b = next(iter(HeteroNeighborLoader(
        hd, hd, num_neighbors=FANOUTS, input_type="item",
        input_nodes=np.arange(24), batch_size=8, prefill_ell=True, seed=0)))
    net = hgt((["user", "item"], list(FANOUTS)), [feat, 8, 8], heads=2)
    params = net.init(jax.random.PRNGKey(5))
    calls = _spy(monkeypatch, attn_ops, "attn_ell_pallas")
    full = net.apply(params, b.x_dict, b.edge_index_dict, b.num_nodes_dict)
    full_calls = len(calls)
    assert full_calls, "untrimmed HGT batch missed the fused kernel"
    del calls[:]
    trim = net.apply(params, b.x_dict, b.edge_index_dict,
                     num_sampled_nodes_dict=b.num_sampled_nodes_dict,
                     num_sampled_edges_dict=b.num_sampled_edges_dict,
                     trim=True)
    assert calls, "trimmed inner HGT layers fell off the fused kernel path"
    np.testing.assert_allclose(np.asarray(b.seed_output(full)),
                               np.asarray(b.seed_output(trim)), rtol=1e-3,
                               atol=1e-4)


# ------------------------------------------------------ GAT bit-identity
def test_gat_attend_bit_identical_through_typed_refactor(rng, monkeypatch):
    """Regression: the typed-logit hooks must not perturb GAT. The default
    attend, the explicit AdditiveLogit attend, and the direct
    gat_attend_ell call produce BIT-IDENTICAL arrays."""
    monkeypatch.setenv("REPRO_USE_PALLAS", "1")
    n, e, h, f = 40, 200, 2, 8
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    z = jnp.asarray(rng.standard_normal((n, h, f)), jnp.float32)
    a_src = jnp.asarray(rng.standard_normal((n, h)), jnp.float32)
    a_dst = jnp.asarray(rng.standard_normal((n, h)), jnp.float32)
    ei = EdgeIndex.from_coo(src, dst, n, n).fill_cache()

    default = ei.attend(z, a_src, a_dst)
    typed = ei.attend(z, a_src, a_dst,
                      logit=attn_ops.AdditiveLogit(negative_slope=0.2))
    direct = attn_ops.gat_attend_ell(ei.get_ell(), a_src, a_dst, z,
                                     num_rows=n)
    assert np.array_equal(np.asarray(default), np.asarray(typed)), \
        "AdditiveLogit attend diverged from the default GAT path"
    assert np.array_equal(np.asarray(default), np.asarray(direct)), \
        "EdgeIndex.attend diverged from the raw gat_attend_ell entry"
    # ... and the COO route too (no packed cache, oracle dispatch)
    monkeypatch.setenv("REPRO_USE_PALLAS", "0")
    raw = EdgeIndex(ei.data, n, n)
    d0 = raw.attend(z, a_src, a_dst)
    t0 = raw.attend(z, a_src, a_dst,
                    logit=attn_ops.AdditiveLogit(negative_slope=0.2))
    assert np.array_equal(np.asarray(d0), np.asarray(t0)), \
        "AdditiveLogit diverged from the default path on the COO oracle"
