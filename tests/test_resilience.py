"""Fault-tolerant store-backed loading: retries, deadlines, breakers,
stale-cache degradation, chaos injection, loader/train/serve policies.

All chaos here is DETERMINISTIC (seeded per-partition schedules, injectable
sleeps/clocks): no assertion depends on wall time.
"""

import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.data import Data
from repro.data.feature_store import InMemoryFeatureStore
from repro.data.graph_store import InMemoryGraphStore
from repro.data.loader import NeighborLoader
from repro.data.partition import build_partitioned_stores
from repro.data.resilience import (ChaosFeatureStore, ChaosGraphStore,
                                   CircuitBreaker, FailureSchedule,
                                   FetchTimeoutError,
                                   PartitionUnavailableError,
                                   ResilientFeatureStore,
                                   ResilientGraphStore, RetryPolicy,
                                   StoreError, TransientStoreError)


def _no_sleep(_):  # injectable sleep: tests never block on backoff
    pass


def _policy(**kw):
    kw.setdefault("sleep", _no_sleep)
    return RetryPolicy(**kw)


def _stores(rng, n=120, e=600, parts=4, feat=8):
    ei = np.stack([rng.integers(0, n, e), rng.integers(0, n, e)])
    x = rng.standard_normal((n, feat)).astype(np.float32)
    y = rng.integers(0, 3, n)
    fs, gs, part = build_partitioned_stores(x, ei, parts, y=y)
    return fs, gs, part, x, y


# ---------------------------------------------------------------------------
# RetryPolicy
# ---------------------------------------------------------------------------

def test_retry_policy_retries_then_succeeds():
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise TransientStoreError("flaky")
        return "ok"

    assert _policy(max_attempts=3).call(flaky) == "ok"
    assert len(calls) == 3


def test_retry_policy_exhaustion_raises_last():
    with pytest.raises(TransientStoreError, match="always"):
        _policy(max_attempts=2).call(
            lambda: (_ for _ in ()).throw(TransientStoreError("always")))


def test_retry_policy_non_retryable_propagates_immediately():
    calls = []

    def bug():
        calls.append(1)
        raise ValueError("a bug, not a fault")

    with pytest.raises(ValueError):
        _policy(max_attempts=5).call(bug)
    assert len(calls) == 1


def test_retry_policy_deterministic_jitter():
    a = RetryPolicy(seed=42, sleep=_no_sleep)
    b = RetryPolicy(seed=42, sleep=_no_sleep)
    da = [a.delay(i) for i in range(6)]
    db = [b.delay(i) for i in range(6)]
    assert da == db
    assert all(d <= a.max_delay for d in da)
    # backoff grows until the cap
    assert da[1] > da[0] * 1.2


def test_retry_policy_abort_hook_bounds_the_loop():
    calls = []

    def failing():
        calls.append(1)
        raise TransientStoreError("down")

    with pytest.raises(TransientStoreError):
        _policy(max_attempts=100).call(failing,
                                       abort=lambda: len(calls) >= 3)
    assert len(calls) == 3


# ---------------------------------------------------------------------------
# CircuitBreaker
# ---------------------------------------------------------------------------

def test_breaker_trips_after_consecutive_failures():
    clock = [0.0]
    b = CircuitBreaker(failure_threshold=3, recovery_time=10.0,
                       clock=lambda: clock[0])
    for _ in range(2):
        b.record_failure()
    assert b.state == "closed" and b.allow()
    b.record_failure()
    assert b.state == "open" and b.trips == 1
    assert not b.allow()  # cooling down


def test_breaker_half_open_probe_then_close():
    clock = [0.0]
    b = CircuitBreaker(failure_threshold=1, recovery_time=5.0,
                       clock=lambda: clock[0])
    b.record_failure()
    assert not b.allow()
    clock[0] = 6.0  # cooldown elapsed -> exactly one probe
    assert b.allow()
    assert not b.allow()  # a probe is already in flight
    b.record_success()
    assert b.state == "closed" and b.recoveries == 1
    assert b.allow()


def test_breaker_half_open_failure_reopens():
    clock = [0.0]
    b = CircuitBreaker(failure_threshold=2, recovery_time=1.0,
                       clock=lambda: clock[0])
    b.record_failure()
    b.record_failure()
    clock[0] = 2.0
    assert b.allow()       # probe
    b.record_failure()     # probe fails
    assert b.state == "open" and b.trips == 2
    assert not b.allow()   # cooldown restarted at t=2


def test_breaker_success_resets_consecutive_count():
    b = CircuitBreaker(failure_threshold=2)
    b.record_failure()
    b.record_success()
    b.record_failure()
    assert b.state == "closed"  # failures were not consecutive


# ---------------------------------------------------------------------------
# Chaos determinism
# ---------------------------------------------------------------------------

@pytest.mark.chaos
def test_failure_schedule_is_deterministic():
    def drive(schedule):
        outcomes = []
        for p in (0, 1, 0, 1, 0, 1, 2, 2, 0, 1) * 10:
            try:
                schedule.check(p)
                outcomes.append((p, "ok"))
            except PartitionUnavailableError:
                outcomes.append((p, "blackout"))
            except TransientStoreError:
                outcomes.append((p, "error"))
        return outcomes

    mk = lambda: FailureSchedule(seed=9, error_rate=0.3,
                                 blackout={1: [(5, 15)]}, sleep=_no_sleep)
    a, b = drive(mk()), drive(mk())
    assert a == b
    assert ("1", "blackout") not in a  # sanity: keys are ints
    assert any(o == "blackout" for _, o in a)
    assert any(o == "error" for _, o in a)
    # reset rewinds the stream
    s = mk()
    first = drive(s)
    s.reset()
    assert drive(s) == first


@pytest.mark.chaos
def test_chaos_streams_independent_across_partitions():
    """Partition 0's fault sequence must not depend on how many calls
    partition 1 received (concurrent fan-out safety)."""
    mk = lambda: FailureSchedule(seed=3, error_rate=0.5, sleep=_no_sleep)

    def seq(schedule, part, n=40):
        out = []
        for _ in range(n):
            try:
                schedule.check(part)
                out.append("ok")
            except TransientStoreError:
                out.append("err")
        return out

    s1 = mk()
    a = seq(s1, 0)
    s2 = mk()
    seq(s2, 1, n=17)  # interleave extra partition-1 traffic
    b = seq(s2, 0)
    assert a == b


# ---------------------------------------------------------------------------
# ResilientFeatureStore
# ---------------------------------------------------------------------------

def test_resilient_store_transparent_without_faults(rng):
    fs, _, _, x, _ = _stores(rng)
    res = ResilientFeatureStore(fs, retry=_policy())
    idx = rng.integers(0, len(x), 30)
    np.testing.assert_allclose(res.get_tensor(index=idx), x[idx])
    out, degraded = res.get_padded_resilient(
        np.array([3, -1, 7]), group="node", attr="x")
    np.testing.assert_allclose(out[0], x[3])
    assert (out[1] == 0).all() and not degraded.any()
    assert res.health["degraded_rows"] == 0
    assert res.get_tensor_size(group="node", attr="x") == x.shape


@pytest.mark.chaos
def test_resilient_store_retries_transient_faults(rng):
    fs, _, _, x, _ = _stores(rng)
    schedule = FailureSchedule(seed=1, error_rate=0.4, sleep=_no_sleep)
    res = ResilientFeatureStore(ChaosFeatureStore(fs, schedule),
                                retry=_policy(max_attempts=8),
                                failure_threshold=100)
    for _ in range(20):
        idx = rng.integers(0, len(x), 25)
        out, degraded = res.get_padded_resilient(idx)
        np.testing.assert_allclose(out, x[idx])
        assert not degraded.any()
    assert res.health["retries"] > 0
    assert schedule.injected["errors"] == res.health["retries"]


@pytest.mark.chaos
def test_resilient_store_degrades_to_stale_cache(rng):
    """Rows homed on a blacked-out partition come from the last-known-good
    cache, flagged degraded, instead of crashing."""
    fs, _, part, x, _ = _stores(rng, parts=4)
    dead = 2
    schedule = FailureSchedule(seed=0, blackout={dead: [(1, 10_000)]},
                               sleep=_no_sleep)
    res = ResilientFeatureStore(ChaosFeatureStore(fs, schedule),
                                retry=_policy(max_attempts=2),
                                failure_threshold=3, recovery_time=0.0)
    idx = np.arange(len(x))
    warm, dmask = res.get_padded_resilient(idx)  # call 0: everything fresh
    assert not dmask.any()
    np.testing.assert_allclose(warm, x)
    out, degraded = res.get_padded_resilient(idx)  # partition `dead` down
    np.testing.assert_allclose(out, x)  # stale == original (nothing moved)
    np.testing.assert_array_equal(degraded, part[idx] == dead)
    assert res.health["degraded_rows"] == int((part == dead).sum())
    assert res.health["stale_rows"] == res.health["degraded_rows"]
    # keep hammering: the breaker trips and later probes keep degrading
    for _ in range(6):
        out, _ = res.get_padded_resilient(idx)
        np.testing.assert_allclose(out, x)
    assert res.health["breaker_trips"] >= 1
    assert res.breaker_states()[dead] in ("open", "half_open")


@pytest.mark.chaos
def test_resilient_store_uncached_rows_degrade_to_zero(rng):
    fs, _, part, x, _ = _stores(rng, parts=2)
    dead = 1
    schedule = FailureSchedule(seed=0, blackout={dead: [(0, 10_000)]},
                               sleep=_no_sleep)
    res = ResilientFeatureStore(ChaosFeatureStore(fs, schedule),
                                retry=_policy(max_attempts=2),
                                recovery_time=0.0)
    idx = np.arange(len(x))
    out, degraded = res.get_padded_resilient(idx)  # dead from the start
    alive = part[idx] != dead
    np.testing.assert_allclose(out[alive], x[alive])
    assert (out[~alive] == 0).all()  # never cached -> zero rows
    np.testing.assert_array_equal(degraded, ~alive)
    assert res.health["stale_rows"] == 0


@pytest.mark.chaos
def test_resilient_store_recovers_after_blackout(rng):
    fs, _, part, x, _ = _stores(rng, parts=2)
    dead = 0
    schedule = FailureSchedule(seed=0, blackout={dead: [(1, 6)]},
                               sleep=_no_sleep)
    res = ResilientFeatureStore(ChaosFeatureStore(fs, schedule),
                                retry=_policy(max_attempts=1),
                                failure_threshold=2, recovery_time=0.0)
    idx = np.arange(len(x))
    res.get_padded_resilient(idx)  # warm (call 0 per partition)
    seen_degraded = False
    for _ in range(12):  # rides through the window: probes advance calls
        out, dmask = res.get_padded_resilient(idx)
        np.testing.assert_allclose(out, x)
        seen_degraded |= bool(dmask.any())
    assert seen_degraded
    assert res.health["breaker_trips"] >= 1
    assert res.health["breaker_recoveries"] >= 1
    assert res.breaker_states()[dead] == "closed"
    out, dmask = res.get_padded_resilient(idx)
    assert not dmask.any()  # fully fresh again


def test_resilient_store_first_fetch_total_failure_raises(rng):
    fs, _, _, x, _ = _stores(rng, parts=2)
    schedule = FailureSchedule(
        seed=0, blackout={0: [(0, 100)], 1: [(0, 100)]}, sleep=_no_sleep)
    res = ResilientFeatureStore(ChaosFeatureStore(fs, schedule),
                                retry=_policy(max_attempts=2))
    with pytest.raises(TransientStoreError, match="no last-known-good"):
        res.get_padded_resilient(np.arange(10))


@pytest.mark.chaos
def test_resilient_store_deadline_degrades_slow_fetch(rng):
    """A latency-spiked backend misses the per-fetch deadline: rows degrade
    (stale) instead of stalling the producer."""
    fs, _, _, x, _ = _stores(rng, parts=2)
    schedule = FailureSchedule(seed=0, latency_rate=1.0, latency_s=0.25)
    chaos = ChaosFeatureStore(fs, schedule)
    res = ResilientFeatureStore(chaos, retry=_policy(max_attempts=1),
                                recovery_time=0.0)
    idx = np.arange(40)
    res.get_padded_resilient(idx)  # warm the cache (slow but unbounded)
    out, degraded = res.get_padded_resilient(idx, deadline=0.01)
    assert degraded.all()
    np.testing.assert_allclose(out, x[idx])  # all stale hits
    assert res.health["timeouts"] >= 1


def test_resilient_store_nonstore_errors_propagate(rng):
    fs, _, _, _, _ = _stores(rng)
    res = ResilientFeatureStore(fs, retry=_policy())
    with pytest.raises(KeyError):
        res.get_tensor(group="node", attr="nope", index=np.arange(3))


# ---------------------------------------------------------------------------
# ResilientGraphStore
# ---------------------------------------------------------------------------

@pytest.mark.chaos
def test_resilient_graph_store_retries_and_serves_stale(rng):
    n = 50
    ei = np.stack([rng.integers(0, n, 200), rng.integers(0, n, 200)])
    gs0 = InMemoryGraphStore()
    gs0.put_edge_index(ei, num_nodes=n)
    schedule = FailureSchedule(seed=2, error_rate=0.5, sleep=_no_sleep)
    res = ResilientGraphStore(ChaosGraphStore(gs0, schedule),
                              retry=_policy(max_attempts=10),
                              failure_threshold=100)
    csr = res.get_csr()
    assert csr.num_edges == 200
    # total blackout now: the cached CSR plus stale COO keep serving
    schedule.error_rate = 1.0
    assert res.get_rev_csr().num_edges == 200  # fresh fetch -> stale COO
    assert res.health["stale_topology"] >= 1


def test_resilient_graph_store_no_stale_raises(rng):
    gs0 = InMemoryGraphStore()
    gs0.put_edge_index(np.zeros((2, 0), np.int64), num_nodes=3)
    schedule = FailureSchedule(seed=0, error_rate=1.0, sleep=_no_sleep)
    res = ResilientGraphStore(ChaosGraphStore(gs0, schedule),
                              retry=_policy(max_attempts=2))
    with pytest.raises(TransientStoreError):
        res.get_csr()


# ---------------------------------------------------------------------------
# Loader policy: on_batch_error + health counters
# ---------------------------------------------------------------------------

class _FlakyStore(InMemoryFeatureStore):
    """Raises TransientStoreError on chosen _get calls (deterministic)."""

    def __init__(self, fail_calls):
        super().__init__()
        self.fail_calls = set(fail_calls)
        self.calls = 0

    def _get(self, key, index):
        c = self.calls
        self.calls += 1
        if c in self.fail_calls:
            raise TransientStoreError(f"injected at call {c}")
        return super()._get(key, index)


def _flaky_loader(rng, fail_calls, **kw):
    n = 64
    ei = np.stack([rng.integers(0, n, 300), rng.integers(0, n, 300)])
    x = rng.standard_normal((n, 8)).astype(np.float32)
    fs = _FlakyStore(fail_calls)
    fs.put_tensor(x)
    gs = InMemoryGraphStore()
    gs.put_edge_index(ei, num_nodes=n)
    return NeighborLoader(fs, gs, num_neighbors=[3], batch_size=16,
                          labels_attr=None, seed=0, **kw)


@pytest.mark.parametrize("prefetch", [0, 2])
def test_loader_on_batch_error_skip(rng, prefetch):
    # 4 seed batches -> calls 0..3; fail call 1 persistently within retries
    loader = _flaky_loader(rng, {1, 2}, on_batch_error="skip",
                           batch_retries=1, prefetch=prefetch)
    batches = list(loader)
    assert len(batches) == 3  # one batch dropped
    assert loader.health["skipped_batches"] == 1
    assert loader.health["batch_retries"] == 1
    assert loader.health["batches"] == 3


@pytest.mark.parametrize("prefetch", [0, 2])
def test_loader_on_batch_error_retry_succeeds(rng, prefetch):
    loader = _flaky_loader(rng, {1}, on_batch_error="retry",
                           batch_retries=2, prefetch=prefetch)
    batches = list(loader)
    assert len(batches) == 4  # retry re-fetches the failed batch
    assert loader.health["batch_retries"] == 1
    assert loader.health["skipped_batches"] == 0


def test_loader_on_batch_error_retry_exhaustion_raises(rng):
    loader = _flaky_loader(rng, set(range(1, 50)), on_batch_error="retry",
                           batch_retries=2)
    with pytest.raises(TransientStoreError):
        list(loader)


def test_loader_on_batch_error_raise_default(rng):
    loader = _flaky_loader(rng, {1})
    assert loader.on_batch_error == "raise"
    with pytest.raises(TransientStoreError):
        list(loader)


def test_loader_rejects_unknown_policy(rng):
    with pytest.raises(ValueError, match="on_batch_error"):
        _flaky_loader(rng, set(), on_batch_error="explode")


def test_loader_nonstore_error_never_skipped(rng):
    """skip policy is for storage faults only — bugs must still raise."""
    data = Data(x=np.zeros((20, 4), np.float32),
                edge_index=np.stack([np.arange(10), np.arange(10) + 1]))

    def boom(batch):
        raise RuntimeError("a bug in transform")

    loader = NeighborLoader(data, data, num_neighbors=[2], batch_size=4,
                            labels_attr=None, transform=boom,
                            on_batch_error="skip")
    with pytest.raises(RuntimeError, match="a bug"):
        list(loader)


# ---------------------------------------------------------------------------
# Producer-thread lifecycle under failure (satellite)
# ---------------------------------------------------------------------------

def test_prefetch_first_batch_exception_propagates(rng):
    """An exception on the VERY FIRST batch with prefetch>0 must surface in
    the consumer, not deadlock the bounded queue."""
    loader = _flaky_loader(rng, {0}, prefetch=2)  # default raise policy
    with pytest.raises(TransientStoreError, match="call 0"):
        next(iter(loader))


def test_prefetch_consumer_abandonment_mid_retry(rng):
    """Closing the iterator while the producer is inside a long batch-retry
    loop must reap the thread promptly (the abort hook)."""
    import time

    n = 64
    ei = np.stack([rng.integers(0, n, 300), rng.integers(0, n, 300)])
    x = rng.standard_normal((n, 8)).astype(np.float32)

    retrying = threading.Event()

    class _Stuck(InMemoryFeatureStore):
        def __init__(self):
            super().__init__()
            self.calls = 0

        def _get(self, key, index):
            self.calls += 1
            if self.calls > 1:  # first batch fine, then permanently down
                retrying.set()
                raise TransientStoreError("down for good")
            return super()._get(key, index)

    fs = _Stuck()
    fs.put_tensor(x)
    gs = InMemoryGraphStore()
    gs.put_edge_index(ei, num_nodes=n)
    loader = NeighborLoader(fs, gs, num_neighbors=[3], batch_size=16,
                            labels_attr=None, prefetch=1,
                            on_batch_error="retry", batch_retries=100_000,
                            seed=0)
    before = set(threading.enumerate())
    it = iter(loader)
    next(it)
    assert retrying.wait(timeout=5.0)  # producer is mid-retry on batch 2
    it.close()
    deadline = time.time() + 5.0
    extra = [t for t in threading.enumerate() if t not in before]
    while extra and time.time() < deadline:
        time.sleep(0.01)
        extra = [t for t in threading.enumerate() if t not in before]
    assert not extra, f"producer thread leaked mid-retry: {extra}"
    # far fewer than 100k attempts: the abort hook cut the loop short
    assert fs.calls < 50_000


# ---------------------------------------------------------------------------
# Degradation surfaces: Batch.extras + loader health + hetero
# ---------------------------------------------------------------------------

@pytest.mark.chaos
def test_loader_batch_extras_degraded_mask(rng):
    fs, gs, part, x, y = _stores(rng, n=200, e=1200)
    dead = 1
    schedule = FailureSchedule(seed=0, blackout={dead: [(1, 10_000)]},
                               sleep=_no_sleep)
    res = ResilientFeatureStore(ChaosFeatureStore(fs, schedule),
                                retry=_policy(max_attempts=1),
                                recovery_time=0.0)
    res.get_padded_resilient(np.arange(len(x)))  # warm last-known-good
    loader = NeighborLoader(res, gs, num_neighbors=[4], batch_size=16,
                            labels_attr=None, on_batch_error="skip", seed=1)
    batches = list(loader)
    assert batches, "epoch must survive the blackout"
    total_degraded = 0
    for b in batches:
        mask = np.asarray(b.extras["degraded"])
        nid = np.asarray(b.n_id)
        valid = nid >= 0
        # degraded rows are exactly the valid rows homed on the dead part
        np.testing.assert_array_equal(
            mask[valid], part[nid[valid]] == dead)
        assert not mask[~valid].any()
        total_degraded += int(mask.sum())
        # stale cache means features still equal the originals
        np.testing.assert_allclose(
            np.asarray(b.x)[valid], x[nid[valid]], rtol=1e-6)
    assert loader.health["degraded_rows"] == total_degraded > 0


@pytest.mark.chaos
def test_hetero_loader_degraded_extras(rng):
    from repro.data.data import HeteroData
    from repro.data.hetero_sampler import HeteroNeighborLoader

    hd = HeteroData()
    hd.add_nodes("user", rng.standard_normal((30, 4)).astype(np.float32))
    hd.add_nodes("item", rng.standard_normal((50, 4)).astype(np.float32))
    hd.add_edges(("user", "buys", "item"),
                 np.stack([rng.integers(0, 30, 200),
                           rng.integers(0, 50, 200)]))
    schedule = FailureSchedule(seed=4, error_rate=0.3, sleep=_no_sleep)
    res = ResilientFeatureStore(ChaosFeatureStore(hd, schedule),
                                retry=_policy(max_attempts=6),
                                failure_threshold=100)
    loader = HeteroNeighborLoader(
        res, hd, num_neighbors={("user", "buys", "item"): [3]},
        input_type="item", input_nodes=np.arange(50), batch_size=10,
        labels_attr=None, on_batch_error="skip", batch_retries=2, seed=0)
    batches = list(loader)
    assert batches
    for b in batches:
        assert set(b.extras["degraded"]) == {"user", "item"}
    assert loader.health["batches"] == len(batches)
    assert res.health["retries"] > 0


# ---------------------------------------------------------------------------
# train_loop: skipped batches + health snapshot
# ---------------------------------------------------------------------------

def test_train_loop_survives_exhausted_iterator(rng):
    from repro.train.loop import train_loop

    class _FakeLoader:
        health = {"skipped_batches": 2, "degraded_rows": 7, "batches": 3,
                  "batch_retries": 1}

    def step(state, batch):
        return state, {"loss": jnp.asarray(batch, jnp.float32)}

    batches = iter([1.0, 2.0, 3.0])  # exhausts before num_steps=10
    logs = []
    out = train_loop({"w": 0}, step, batches, num_steps=10, log_every=1,
                     loader=_FakeLoader(), log_fn=logs.append)
    assert len(out["history"]) == 3
    assert out["loader_health"]["skipped_batches"] == 2
    assert any("exhausted" in m for m in logs)
    assert any("health=" in m for m in logs)


# ---------------------------------------------------------------------------
# THE chaos proof: jit'd training rides through faults + a blackout
# ---------------------------------------------------------------------------

@pytest.mark.chaos
def test_chaos_train_epochs_complete_one_trace(rng):
    """>=10% transient faults + a full partition blackout: every epoch
    completes with zero crashes, ONE trace, and degraded/skipped counts
    reported in loader health (the ISSUE acceptance gate)."""
    fs, gs, part, x, y = _stores(rng, n=400, e=2400, parts=4, feat=16)
    dead = 1
    schedule = FailureSchedule(seed=13, error_rate=0.10,
                               blackout={dead: [(8, 40)]}, sleep=_no_sleep)
    res = ResilientFeatureStore(ChaosFeatureStore(fs, schedule),
                                retry=_policy(max_attempts=3, seed=13),
                                failure_threshold=3, recovery_time=0.0)
    loader = NeighborLoader(res, gs, num_neighbors=[4, 3], batch_size=32,
                            input_nodes=np.arange(256), shuffle=True,
                            prefetch=2, on_batch_error="skip",
                            batch_retries=2, seed=5)
    rngp = np.random.default_rng(0)
    params = {"w1": jnp.asarray(rngp.standard_normal((16, 8)) * 0.1,
                                jnp.float32),
              "w2": jnp.asarray(rngp.standard_normal((8, 3)) * 0.1,
                                jnp.float32)}
    traces = []

    @jax.jit
    def step(params, batch):
        traces.append(1)

        def loss_fn(p):
            h = jax.nn.relu(batch.edge_index.matmul(batch.x @ p["w1"]))
            out = batch.edge_index.matmul(h @ p["w2"])
            logits = out[batch.seed_slots]
            onehot = jax.nn.one_hot(batch.y, 3)
            return -(jax.nn.log_softmax(logits) * onehot).sum(-1).mean()

        loss, grads = jax.value_and_grad(loss_fn)(params)
        return (jax.tree_util.tree_map(lambda a, g: a - 1e-2 * g, params,
                                       grads), loss)

    losses = []
    for _ in range(3):  # 3 epochs x 8 seed batches
        for b in loader:
            params, loss = step(params, b)
            losses.append(float(loss))
    assert len(traces) == 1, "chaos must not change batch structure"
    assert np.isfinite(losses).all()
    assert schedule.injected["errors"] > 0
    assert schedule.injected["blackout"] > 0
    h = loader.health
    assert h["batches"] == len(losses)
    assert h["degraded_rows"] > 0  # blackout rows served stale
    assert h["batches"] + h["skipped_batches"] >= 3 * len(loader)
    assert res.health["breaker_trips"] >= 1


# ---------------------------------------------------------------------------
# Serving: deadline-bounded degraded answers
# ---------------------------------------------------------------------------

@pytest.mark.chaos
def test_graph_server_degrades_under_blackout(rng):
    from repro.launch.serve import GraphServer

    fs, gs, part, x, _ = _stores(rng, n=300, e=1800, parts=4, feat=16)
    dead = 0
    schedule = FailureSchedule(seed=6, blackout={dead: [(1, 10_000)]},
                               sleep=_no_sleep)
    res = ResilientFeatureStore(ChaosFeatureStore(fs, schedule),
                                retry=_policy(max_attempts=1),
                                recovery_time=0.0)
    res.get_padded_resilient(np.arange(len(x)))  # warm
    w = jnp.asarray(np.random.default_rng(0).standard_normal((16, 4)) * 0.1,
                    jnp.float32)
    server = GraphServer(res, gs,
                         lambda x_, ei_, s: (ei_.matmul(x_) @ w)[s],
                         num_neighbors=[4, 2], batch_size=8,
                         deadline_s=0.5, seed=0)
    degraded_total = 0
    for _ in range(6):
        r = server.answer(rng.integers(0, 300, 5))
        assert r["pred"].shape == (5, 4)
        assert np.isfinite(r["pred"]).all()
        degraded_total += r["degraded"]
    assert degraded_total > 0  # partition `dead` rows served stale
    assert server.trace_count == 1


@pytest.mark.chaos
def test_graph_smoke_cli_runs():
    from repro.launch import serve

    stats = serve.main(["--graph-smoke"])
    assert stats["requests"] == 24
    assert stats["trace_count"] == 1
    assert stats["store_health"]["requests"] >= 24
