import os

# Tests run on the single real CPU device (the dry-run, and only the
# dry-run, forces 512 host devices — per its own module header).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
