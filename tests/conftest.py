import os

# Tests run on the CPU backend (the dry-run, and only the dry-run, forces
# 512 host devices — per its own module header).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# The data-parallel mesh tests (tests/test_mesh_scaleout.py) need several
# host devices; the flag must be in place before jax initialises its
# backends, i.e. before the first jax import anywhere in the suite. Eight
# covers every mesh size the tests build (1/2/4/8). A pre-existing
# force-count in the environment wins.
from repro.launch.mesh import HOST_DEVICE_FLAG, host_device_flag  # noqa: E402

_flags = os.environ.get("XLA_FLAGS", "")
if HOST_DEVICE_FLAG not in _flags:
    os.environ["XLA_FLAGS"] = f"{_flags} {host_device_flag(8)}".strip()

import numpy as np
import pytest

# ---------------------------------------------------------------------------
# `hypothesis` fallback: the container may not ship hypothesis; rather than
# losing the whole suite to a collection error, install a minimal
# deterministic stand-in covering exactly the API our tests use
# (given / settings / st.integers / st.sampled_from). Real hypothesis, when
# present, is always preferred.
# ---------------------------------------------------------------------------
try:  # pragma: no cover - trivial import probe
    import hypothesis  # noqa: F401
except ModuleNotFoundError:  # pragma: no cover - exercised only without dep
    import sys
    import types

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def _integers(min_value, max_value):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    def _sampled_from(options):
        opts = list(options)
        return _Strategy(lambda rng: opts[int(rng.integers(0, len(opts)))])

    def _given(*strategies):
        def deco(fn):
            # Zero-arg wrapper: drawn arguments must not look like pytest
            # fixtures, so the original signature is deliberately hidden.
            def runner():
                n = getattr(runner, "_max_examples", 10)
                rng = np.random.default_rng(0xC0FFEE)
                for _ in range(n):
                    fn(*(s.draw(rng) for s in strategies))

            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            return runner

        return deco

    def _settings(max_examples=10, deadline=None, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    _hyp = types.ModuleType("hypothesis")
    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.sampled_from = _sampled_from
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


@pytest.fixture
def rng():
    return np.random.default_rng(0)
