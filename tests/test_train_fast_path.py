"""Differentiable fast path: jax.grad rides the Pallas kernels (this PR).

The acceptance chain for the custom-VJP tentpole:

    loader-prefilled batch (homogeneous or hetero)
      -> jit'd value_and_grad train step, Pallas dispatch FORCED
        -> forward: bucketed ELL kernel (+ grouped matmul for hetero
           projections), spy-counted
        -> backward: the custom VJPs (masked scatter-add over the same
           buckets; two grouped GEMMs over the same tile->group table)
      == oracle gradients, with ONE trace across batches

plus the explainer regression (gradient-based explainers under
``REPRO_USE_PALLAS=1`` ride the fused path through the VJPs) and a
slow-marked gradient-parity sweep across K ladders, capacity padding,
weighted and transpose flows.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.edge_index import EdgeIndex
from repro.core.explain import Explainer
from repro.core.hetero import to_hetero
from repro.core.message_passing import MessagePassing
from repro.data.data import Data, HeteroData
from repro.data.hetero_sampler import HeteroNeighborLoader
from repro.data.loader import NeighborLoader
from repro.kernels.grouped_matmul import ops as gmm_ops
from repro.kernels.spmm import ops as spmm_ops
from repro.nn.gnn.conv import SAGEConv, gcn_norm
from repro.nn.gnn.models import make_model

ET_UB = ("user", "buys", "item")
ET_RU = ("item", "rev_buys", "user")
FANOUTS = {ET_UB: [3, 2], ET_RU: [3, 2]}


def _spy(monkeypatch, module, name):
    calls = []
    real = getattr(module, name)
    monkeypatch.setattr(module, name,
                        lambda *a, **k: (calls.append(1), real(*a, **k))[1])
    return calls


def _grad_leaves_close(got, want, rtol=1e-3, atol=1e-4):
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=rtol, atol=atol), got, want)


# --------------------------------------------------------- homogeneous step
@pytest.mark.parametrize("weighted", [False, True])
def test_homogeneous_kernel_grad_matches_oracle(rng, monkeypatch, weighted):
    """jax.grad of a jit'd loss through a forced-Pallas train step over
    loader-prefilled batches == oracle gradients, with one trace."""
    calls = _spy(monkeypatch, spmm_ops, "spmm_ell_pallas")
    n, e, feat, hidden = 200, 1200, 16, 8
    data = Data(x=rng.standard_normal((n, feat)).astype(np.float32),
                edge_index=np.stack([rng.integers(0, n, e),
                                     rng.integers(0, n, e)]))
    loader = NeighborLoader(data, data, num_neighbors=[4, 2], batch_size=8,
                            prefill_ell=True, labels_attr=None, seed=0)
    params = {"w1": jnp.asarray(rng.standard_normal((feat, hidden)) * 0.1,
                                jnp.float32),
              "w2": jnp.asarray(rng.standard_normal((hidden, 4)) * 0.1,
                                jnp.float32)}
    traces = []

    def loss_fn(p, ei, batch, force):
        ew = None
        if weighted:
            ew, _ = gcn_norm(ei, batch.num_nodes, add_self_loops=False)
        interpret = True if force else None
        h = jax.nn.relu(ei.matmul(batch.x @ p["w1"], edge_weight=ew,
                                  force_pallas=force, interpret=interpret))
        out = ei.matmul(h @ p["w2"], edge_weight=ew, force_pallas=force,
                        interpret=interpret)
        return (out[batch.seed_slots] ** 2).mean()

    @jax.jit
    def step(p, batch):
        traces.append(1)
        return jax.value_and_grad(loss_fn)(p, batch.edge_index, batch, True)

    it = iter(loader)
    b1, b2 = next(it), next(it)
    for b in (b1, b2):
        loss_k, grad_k = step(params, b)
        # oracle reference on a cache-less EdgeIndex: no Pallas anywhere
        raw = EdgeIndex(b.edge_index.data, b.num_nodes, b.num_nodes)
        loss_o, grad_o = jax.value_and_grad(loss_fn)(params, raw, b, False)
        np.testing.assert_allclose(float(loss_k), float(loss_o), rtol=1e-4)
        _grad_leaves_close(grad_k, grad_o)
    assert len(traces) == 1, "second batch retraced the grad step"
    assert calls, "train step never reached the Pallas ELL kernel"


def test_transpose_flow_grad_matches_oracle(rng, monkeypatch):
    """target_to_source flow (matmul(transpose=True)) differentiates on the
    kernel path via the eagerly-filled transpose ELL cache."""
    monkeypatch.setenv("REPRO_USE_PALLAS", "1")
    calls = _spy(monkeypatch, spmm_ops, "spmm_ell_pallas")
    n, e, feat = 30, 120, 8
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    ei = EdgeIndex.from_coo(src, dst, n, n).fill_cache()
    x = jnp.asarray(rng.standard_normal((n, feat)).astype(np.float32))
    mp = MessagePassing(aggr="sum", flow="target_to_source")
    raw = EdgeIndex(ei.data, n, n)

    gk = jax.grad(lambda x_: (mp.propagate({}, ei, x_) ** 2).sum())(x)
    assert calls, "transpose flow missed the Pallas kernel"
    monkeypatch.setenv("REPRO_USE_PALLAS", "0")
    go = jax.grad(lambda x_: (raw.matmul(
        x_, transpose=True, force_pallas=False) ** 2).sum())(x)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(go), rtol=1e-4,
                               atol=1e-4)


# ------------------------------------------------------------- hetero step
def test_hetero_kernel_grad_matches_oracle(rng, monkeypatch):
    """The typed acceptance path: a jit'd grad step over HeteroBatches with
    per-relation Pallas ELL aggregation AND grouped projections matches the
    per-conv oracle gradients, one trace across batches."""
    monkeypatch.setenv("REPRO_USE_PALLAS", "1")
    ell_calls = _spy(monkeypatch, spmm_ops, "spmm_ell_pallas")
    gmm_calls = _spy(monkeypatch, gmm_ops, "grouped_matmul_pallas")
    hd = HeteroData()
    hd.add_nodes("user", rng.standard_normal((40, 8)).astype(np.float32))
    hd.add_nodes("item", rng.standard_normal((60, 8)).astype(np.float32))
    ub = np.stack([rng.integers(0, 40, 200), rng.integers(0, 60, 200)])
    hd.add_edges(ET_UB, ub)
    hd.add_edges(ET_RU, ub[::-1])
    loader = HeteroNeighborLoader(
        hd, hd, num_neighbors=FANOUTS, input_type="item",
        input_nodes=np.arange(16), batch_size=4, prefill_ell=True, seed=0)
    metadata = (["user", "item"], list(FANOUTS))
    net = to_hetero(lambda i, o: SAGEConv(i, o), metadata, [8, 16, 4])
    params = net.init(jax.random.PRNGKey(0))
    traces = []

    @jax.jit
    def step(p, batch):
        traces.append(1)

        def loss_fn(p):
            out = net.apply(p, batch.x_dict, batch.edge_index_dict,
                            batch.num_nodes_dict)
            return (batch.seed_output(out) ** 2).mean()

        return jax.value_and_grad(loss_fn)(p)

    it = iter(loader)
    b1, b2 = next(it), next(it)
    results = [(b, step(params, b)) for b in (b1, b2)]
    assert len(traces) == 1, "second typed batch retraced the grad step"
    assert len(ell_calls) >= 2 * len(FANOUTS), \
        "not every relation's aggregation hit the Pallas ELL kernel"
    assert gmm_calls, "projections did not run the grouped matmul kernel"

    # oracle reference: per-conv (ungrouped) path on cache-less EdgeIndexes
    monkeypatch.setenv("REPRO_USE_PALLAS", "0")
    ref_net = to_hetero(lambda i, o: SAGEConv(i, o), metadata, [8, 16, 4],
                        grouped=False)
    for b, (loss_k, grad_k) in results:
        raw = {et: EdgeIndex(ei.data, ei.num_src_nodes, ei.num_dst_nodes)
               for et, ei in b.edge_index_dict.items()}

        def ref_loss(p):
            out = ref_net.apply(p, b.x_dict, raw, b.num_nodes_dict)
            return (b.seed_output(out) ** 2).mean()

        loss_o, grad_o = jax.value_and_grad(ref_loss)(params)
        np.testing.assert_allclose(float(loss_k), float(loss_o), rtol=1e-4)
        _grad_leaves_close(grad_k, grad_o, rtol=2e-3, atol=2e-4)


# ------------------------------------------------------ explainer regression
@pytest.mark.parametrize("model_name", ["gcn", "sage"])
def test_explainer_gradients_ride_pallas(rng, monkeypatch, model_name):
    """Gradient-based explainers under REPRO_USE_PALLAS=1 must run (through
    the custom VJPs, on the fused path) and agree with the oracle-path
    attributions."""
    n, e, f = 30, 100, 8
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    x = jnp.asarray(rng.standard_normal((n, f)).astype(np.float32))
    model = make_model(model_name, f, 16, 3, 2)
    params = model.init(jax.random.PRNGKey(0))

    monkeypatch.setenv("REPRO_USE_PALLAS", "1")
    calls = _spy(monkeypatch, spmm_ops, "spmm_ell_pallas")
    ei = EdgeIndex.from_coo(src, dst, n, n)
    fast = Explainer(model, params, algorithm="saliency")(x, ei, node_idx=5)
    assert calls, "explainer gradients bypassed the Pallas kernel"
    assert np.isfinite(np.asarray(fast.edge_mask)).all()
    assert np.isfinite(np.asarray(fast.node_mask)).all()

    monkeypatch.setenv("REPRO_USE_PALLAS", "0")
    ref = Explainer(model, params, algorithm="saliency")(
        x, EdgeIndex.from_coo(src, dst, n, n), node_idx=5)
    np.testing.assert_allclose(np.asarray(fast.edge_mask),
                               np.asarray(ref.edge_mask), rtol=1e-3,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(fast.node_mask),
                               np.asarray(ref.node_mask), rtol=1e-3,
                               atol=1e-4)


def test_gnn_explainer_trains_masks_under_pallas(rng, monkeypatch):
    """The mask-optimisation loop (jit'd jax.grad at explain.py) runs under
    forced Pallas and still finds a planted edge."""
    monkeypatch.setenv("REPRO_USE_PALLAS", "1")
    n, f = 12, 4
    src = np.concatenate([[1], rng.integers(2, n, 20)]).astype(np.int32)
    dst = np.concatenate([[0], rng.integers(2, n, 20)]).astype(np.int32)
    ei = EdgeIndex.from_coo(src, dst, n, n)
    x = np.zeros((n, f), np.float32)
    x[1] = 10.0
    model = make_model("sage", f, 8, 2, 1)
    params = model.init(jax.random.PRNGKey(1))
    expl = Explainer(model, params, algorithm="gnn_explainer", epochs=80)(
        jnp.asarray(x), ei, node_idx=0)
    assert 0 in expl.top_edges(3), "planted edge not in top-3 under Pallas"


# ---------------------------------------------------------- slow grad sweep
def _skewed_csr(rng, n_rows=37, n_cols=29):
    deg = np.concatenate([rng.integers(0, 4, n_rows - 17),
                          rng.integers(5, 17, 15), [0, 53]])
    rng.shuffle(deg)
    indptr = np.concatenate([[0], np.cumsum(deg)]).astype(np.int64)
    indices = rng.integers(0, n_cols, int(indptr[-1])).astype(np.int32)
    return indptr, indices


@pytest.mark.slow
@pytest.mark.parametrize("reduce", ["sum", "mean", "max", "min"])
@pytest.mark.parametrize("weighted", [False, True])
@pytest.mark.parametrize("layout", ["bucketed", "static"])
def test_grad_parity_sweep_buckets(rng, reduce, weighted, layout):
    """Oracle vs kernel-VJP gradients across the K ladder (bucketed) and a
    capacity-padded static layout (-1 row ids), weighted and unweighted."""
    indptr, indices = _skewed_csr(rng)
    n_rows, n_cols = len(indptr) - 1, 29
    if layout == "bucketed":
        buckets = spmm_ops.csr_to_ell_bucketed(indptr, indices)
    else:
        deg = np.diff(indptr)
        # static layout from loose per-range bounds -> capacity padding
        bounds = [(0, 12, int(deg[:12].max(initial=1)) + 3),
                  (12, n_rows, int(deg[12:].max(initial=1)) + 5)]
        static = spmm_ops.ell_layout_from_bounds(bounds)
        buckets = spmm_ops.csr_to_ell_static(indptr, indices, static)
        assert any((np.asarray(r) < 0).any() for r, _, _ in buckets), \
            "static layout produced no capacity padding - sweep is vacuous"
    x = jnp.asarray(rng.standard_normal((n_cols, 128)).astype(np.float32))
    w = (jnp.asarray(rng.standard_normal(len(indices)).astype(np.float32))
         if weighted else None)

    def loss(x_, w_, force):
        out = spmm_ops.spmm_ell_bucketed(
            buckets, x_, w_, num_rows=n_rows, reduce=reduce,
            force_pallas=force, interpret=force or None)
        return (out * jnp.cos(jnp.arange(out.size).reshape(out.shape))).sum()

    if weighted:
        gk = jax.grad(loss, argnums=(0, 1))(x, w, True)
        go = jax.grad(loss, argnums=(0, 1))(x, w, False)
        for a, b in zip(gk, go):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-4)
    else:
        gk = jax.grad(lambda x_: loss(x_, None, True))(x)
        go = jax.grad(lambda x_: loss(x_, None, False))(x)
        np.testing.assert_allclose(np.asarray(gk), np.asarray(go),
                                   rtol=1e-4, atol=1e-4)


@pytest.mark.slow
@pytest.mark.parametrize("reduce", ["sum", "mean", "max", "min"])
@pytest.mark.parametrize("transpose", [False, True])
def test_grad_parity_sweep_edge_index(rng, reduce, transpose):
    """EdgeIndex.matmul gradient parity, forward and transpose flows,
    weighted, through the demand-filled ELL caches."""
    n, e, f = 26, 140, 128
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    ei = EdgeIndex.from_coo(src, dst, n, n).fill_cache(ell=True)
    raw = EdgeIndex(ei.data, n, n)
    x = jnp.asarray(rng.standard_normal((n, f)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal(e).astype(np.float32))

    def loss(target, x_, w_, force):
        out = target.matmul(x_, edge_weight=w_, transpose=transpose,
                            reduce=reduce, force_pallas=force,
                            interpret=True if force else None)
        return (out ** 2).sum()

    gk = jax.grad(loss, argnums=(1, 2))(ei, x, w, True)
    go = jax.grad(loss, argnums=(1, 2))(raw, x, w, False)
    for a, b in zip(gk, go):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-4)


@pytest.mark.slow
def test_grad_parity_sweep_hetero_grouped(rng, monkeypatch):
    """Hetero: grouped-projection grad step (Pallas ELL per relation + one
    grouped GEMM per layer, both on their custom VJPs) vs the per-conv
    oracle, across seeds."""
    metadata = (["user", "item"], [ET_UB, ET_RU])
    for seed in range(3):
        r = np.random.default_rng(seed)
        x = {"user": jnp.asarray(r.standard_normal((12, 8)), jnp.float32),
             "item": jnp.asarray(r.standard_normal((9, 8)), jnp.float32)}

        def make_ei():
            rr = np.random.default_rng(seed + 100)
            return {ET_UB: EdgeIndex.from_coo(
                        rr.integers(0, 12, 30).astype(np.int32),
                        rr.integers(0, 9, 30).astype(np.int32), 12, 9),
                    ET_RU: EdgeIndex.from_coo(
                        rr.integers(0, 9, 30).astype(np.int32),
                        rr.integers(0, 12, 30).astype(np.int32), 9, 12)}

        net_g = to_hetero(lambda i, o: SAGEConv(i, o), metadata, [8, 16, 4])
        net_s = to_hetero(lambda i, o: SAGEConv(i, o), metadata, [8, 16, 4],
                          grouped=False)
        params = net_g.init(jax.random.PRNGKey(seed))

        monkeypatch.setenv("REPRO_USE_PALLAS", "1")
        ei = make_ei()
        for e_ in ei.values():
            e_.fill_cache()
        gg = jax.grad(lambda p: sum(
            (v ** 2).sum()
            for v in net_g.apply(p, x, ei).values()))(params)

        monkeypatch.setenv("REPRO_USE_PALLAS", "0")
        ei_raw = make_ei()
        gs = jax.grad(lambda p: sum(
            (v ** 2).sum()
            for v in net_s.apply(p, x, ei_raw).values()))(params)
        _grad_leaves_close(gg, gs, rtol=2e-3, atol=2e-4)
