"""Jit-ready heterogeneous fast path: typed batches, per-edge-type static
ELL prefill, grouped projections, and hetero-aware trimming.

Covers the PR-3 chain:

    HeteroNeighborSampler (vectorised, static per-(hop, edge-type) bounds)
      -> HeteroNeighborLoader._make_batch (producer thread)
        -> EdgeIndex.from_coo_prefilled per relation (CSC/CSR + static ELL)
          -> jit'd HeteroGNN step (ONE trace across batches)
             -> per-relation propagate -> spmm_ell_pallas
             -> all per-type projections -> ONE grouped matmul per layer
      -> trim_to_layer_hetero keeps the masked ELL fast path on inner hops
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.edge_index import EdgeIndex
from repro.core.hetero import HeteroConv, to_hetero
from repro.core.trim import trim_to_layer
from repro.data.data import Data, HeteroData
from repro.data.graph_store import DEFAULT_ETYPE
from repro.data.hetero_sampler import (HeteroBatch, HeteroNeighborLoader,
                                       HeteroNeighborSampler,
                                       hetero_static_slot_bounds)
from repro.data.loader import NeighborLoader
from repro.data.sampler import NeighborSampler
from repro.kernels.grouped_matmul import ops as gmm_ops
from repro.kernels.spmm import ops as spmm_ops
from repro.nn.gnn.conv import SAGEConv

ET_UB = ("user", "buys", "item")
ET_RU = ("item", "rev_buys", "user")
FANOUTS = {ET_UB: [3, 2], ET_RU: [3, 2]}


def _hetero_graph(rng, n_user=40, n_item=60, e=200):
    hd = HeteroData()
    hd.add_nodes("user", rng.standard_normal((n_user, 8)).astype(np.float32))
    hd.add_nodes("item", rng.standard_normal((n_item, 8)).astype(np.float32))
    ub = np.stack([rng.integers(0, n_user, e), rng.integers(0, n_item, e)])
    hd.add_edges(ET_UB, ub)
    hd.add_edges(ET_RU, ub[::-1])
    return hd


def _loader(hd, **kw):
    kw.setdefault("num_neighbors", FANOUTS)
    kw.setdefault("input_type", "item")
    kw.setdefault("input_nodes", np.arange(16))
    kw.setdefault("batch_size", 4)
    return HeteroNeighborLoader(hd, hd, **kw)


# ------------------------------------------------------- static slot bounds
def test_hetero_static_slot_bounds_layout():
    fan = {("u", "b", "i"): [2, 3], ("i", "r", "u"): [2, 2]}
    bounds = hetero_static_slot_bounds(4, fan, "i")
    # hop 0: only the seed type's frontier (slots [1,5)) receives edges —
    # via ("u","b","i") with fanout 2; that discovers 4*2=8 "u" slots
    # [1,9), which hop-1 ("i","r","u") expansion hits with fanout 2.
    assert bounds[("u", "b", "i")] == [(1, 5, 2)]
    assert bounds[("i", "r", "u")] == [(1, 9, 2)]


def test_bounds_match_realised_degrees(rng):
    """Realised per-slot in-degrees never exceed the static bounds (the
    invariant csr_to_ell_static enforces at pack time)."""
    hd = _hetero_graph(rng)
    s = HeteroNeighborSampler(hd, FANOUTS)
    bounds = s.slot_degree_bounds("item", 6)
    out = s.sample("item", np.arange(6))
    for et, bl in bounds.items():
        col = out.col[et][out.edge[et] >= 0]
        deg = np.bincount(col, minlength=len(out.node[et[2]]))
        for lo, hi, k in bl:
            assert deg[lo:hi].max(initial=0) <= k, (et, lo, hi, k)
        # every real edge lands inside a bounded range
        covered = np.zeros(len(out.node[et[2]]), bool)
        for lo, hi, _ in bl:
            covered[lo:hi] = True
        assert covered[col].all(), et


# ------------------------------------------------- hetero vs homogeneous
def test_hetero_sampler_matches_homogeneous_on_single_type(rng):
    """On a single-node-type graph the vectorised hetero sampler must be
    bit-identical to the homogeneous one (same rng stream, same dedup)."""
    n, e = 50, 300
    d = Data(x=rng.standard_normal((n, 8)).astype(np.float32),
             edge_index=np.stack([rng.integers(0, n, e),
                                  rng.integers(0, n, e)]))
    hs = HeteroNeighborSampler(d, {DEFAULT_ETYPE: [4, 3]}, seed=3)
    s = NeighborSampler(d, [4, 3], seed=3)
    seeds = np.arange(6)
    oh, o = hs.sample("node", seeds), s.sample(seeds)
    np.testing.assert_array_equal(oh.node["node"], o.node)
    np.testing.assert_array_equal(oh.row[DEFAULT_ETYPE], o.row)
    np.testing.assert_array_equal(oh.col[DEFAULT_ETYPE], o.col)
    np.testing.assert_array_equal(oh.edge[DEFAULT_ETYPE], o.edge)
    assert oh.num_sampled_nodes["node"] == o.num_sampled_nodes
    assert oh.num_sampled_edges[DEFAULT_ETYPE] == o.num_sampled_edges


def test_hetero_loader_matches_homogeneous_on_single_type(rng):
    """Loader-level parity: same seeds -> same features and aggregation."""
    n, e = 50, 300
    d = Data(x=rng.standard_normal((n, 8)).astype(np.float32),
             edge_index=np.stack([rng.integers(0, n, e),
                                  rng.integers(0, n, e)]))
    hb = next(iter(HeteroNeighborLoader(
        d, d, num_neighbors={DEFAULT_ETYPE: [4, 3]}, input_type="node",
        input_nodes=np.arange(8), batch_size=8, prefill_ell=True, seed=1)))
    b = next(iter(NeighborLoader(d, d, num_neighbors=[4, 3], batch_size=8,
                                 input_nodes=np.arange(8), prefill_ell=True,
                                 seed=1)))
    np.testing.assert_array_equal(np.asarray(hb.x_dict["node"]),
                                  np.asarray(b.x))
    fast = hb.edge_index_dict[DEFAULT_ETYPE].matmul(
        hb.x_dict["node"], force_pallas=True)
    ref = b.edge_index.matmul(b.x, force_pallas=False)
    np.testing.assert_allclose(np.asarray(fast), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


# ------------------------------------------------------- loader jit readiness
def test_hetero_loader_prefills_per_edge_type(rng):
    it = iter(_loader(_hetero_graph(rng), prefill_ell=True))
    b1, b2 = next(it), next(it)
    for b in (b1, b2):
        assert isinstance(b, HeteroBatch)
        for et, ei in b.edge_index_dict.items():
            assert ei._csr is not None and ei._csc is not None, et
            assert ei._ell is not None, et
            colptr, row, perm = (np.asarray(t) for t in ei._csc)
            np.testing.assert_array_equal(
                np.asarray(ei.dst)[perm], np.sort(np.asarray(ei.dst)))
            assert colptr[-1] == ei.num_edges
    # identical pytree structure + shapes across batches (no-recompile)
    assert (jax.tree_util.tree_structure(b1)
            == jax.tree_util.tree_structure(b2))
    assert ([l.shape for l in jax.tree_util.tree_leaves(b1)]
            == [l.shape for l in jax.tree_util.tree_leaves(b2)])


def test_hetero_loader_tail_batch(rng):
    """The silent-tail-drop bug: 10 seeds / batch 4 must yield the 2-seed
    tail with drop_last=False (its own cached-by-size static layout) and
    drop it only when asked."""
    hd = _hetero_graph(rng)
    kept = list(_loader(hd, input_nodes=np.arange(10), drop_last=False,
                        prefill_ell=True))
    dropped = list(_loader(hd, input_nodes=np.arange(10), drop_last=True))
    assert len(kept) == 3 and len(dropped) == 2
    assert len(_loader(hd, input_nodes=np.arange(10), drop_last=False)) == 3
    assert len(_loader(hd, input_nodes=np.arange(10), drop_last=True)) == 2
    tail = kept[-1]
    assert tail.seed_slots.shape == (2,)
    for et, ei in tail.edge_index_dict.items():
        assert ei._ell is not None, et
        fast = ei.matmul(tail.x_dict[et[0]], force_pallas=True)
        raw = EdgeIndex(ei.data, ei.num_src_nodes, ei.num_dst_nodes)
        np.testing.assert_allclose(
            np.asarray(fast),
            np.asarray(raw.matmul(tail.x_dict[et[0]], force_pallas=False)),
            rtol=1e-4, atol=1e-4)


def test_hetero_loader_single_trace_all_relations_pallas(rng, monkeypatch):
    """The acceptance path: prefetch-producer typed batches drive a jit'd
    HeteroGNN with ONE trace across batches, every edge type's aggregation
    dispatching to the Pallas ELL kernel and all per-type projections
    funnelling through one grouped matmul per layer."""
    monkeypatch.setenv("REPRO_USE_PALLAS", "1")
    pallas_calls, gmm_calls, traces = [], [], []
    real_p = spmm_ops.spmm_ell_pallas
    monkeypatch.setattr(spmm_ops, "spmm_ell_pallas",
                        lambda *a, **k: (pallas_calls.append(1),
                                         real_p(*a, **k))[1])
    real_g = gmm_ops.grouped_matmul_pallas
    monkeypatch.setattr(gmm_ops, "grouped_matmul_pallas",
                        lambda *a, **k: (gmm_calls.append(1),
                                         real_g(*a, **k))[1])
    hd = _hetero_graph(rng)
    loader = _loader(hd, prefetch=2)
    net = to_hetero(lambda i, o: SAGEConv(i, o),
                    (["user", "item"], list(FANOUTS)), [8, 16, 4])
    params = net.init(jax.random.PRNGKey(0))

    @jax.jit
    def step(params, batch):
        traces.append(1)  # runs only while tracing
        out = net.apply(params, batch.x_dict, batch.edge_index_dict,
                        batch.num_nodes_dict)
        return batch.seed_output(out)

    it = iter(loader)
    b1, b2 = next(it), next(it)
    o1, o2 = step(params, b1), step(params, b2)
    assert len(traces) == 1, "second batch retraced: pytree not static"
    # 2 layers x 2 relations, each with >= 1 ELL bucket
    assert len(pallas_calls) >= 2 * len(FANOUTS), \
        "not every relation reached the Pallas ELL kernel"
    assert len(gmm_calls) == 2, \
        "per-type projections did not group into one matmul per layer"
    # numerics: per-conv (ungrouped) oracle path on cache-less EdgeIndex
    monkeypatch.setenv("REPRO_USE_PALLAS", "0")
    ref_net = to_hetero(lambda i, o: SAGEConv(i, o),
                        (["user", "item"], list(FANOUTS)), [8, 16, 4],
                        grouped=False)
    for b, o in ((b1, o1), (b2, o2)):
        raw = {et: EdgeIndex(ei.data, ei.num_src_nodes, ei.num_dst_nodes)
               for et, ei in b.edge_index_dict.items()}
        ref = ref_net.apply(params, b.x_dict, raw, b.num_nodes_dict)
        np.testing.assert_allclose(np.asarray(o),
                                   np.asarray(b.seed_output(ref)),
                                   rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------- model layer
def test_hetero_conv_aggr_validation():
    convs = {ET_UB: SAGEConv(8, 16), ET_RU: SAGEConv(8, 16)}
    with pytest.raises(ValueError, match="unknown cross-type aggr"):
        HeteroConv(dict(convs), aggr="median")
    with pytest.raises(ValueError, match="unknown cross-type aggr"):
        to_hetero(lambda i, o: SAGEConv(i, o),
                  (["user", "item"], list(FANOUTS)), [8, 4], aggr="concat")
    assert HeteroConv(dict(convs), aggr="cat").aggr == "cat"


@pytest.mark.parametrize("aggr", ["sum", "mean", "max", "min", "cat"])
def test_grouped_projection_matches_per_conv(rng, aggr):
    """grouped=True (one grouped GEMM) == grouped=False (|E| separate convs)
    for every cross-type aggregation mode."""
    x = {"user": jnp.asarray(rng.standard_normal((12, 8)),
                             dtype=jnp.float32),
         "item": jnp.asarray(rng.standard_normal((9, 8)),
                             dtype=jnp.float32)}
    ei = {ET_UB: EdgeIndex.from_coo(rng.integers(0, 12, 30).astype(np.int32),
                                    rng.integers(0, 9, 30).astype(np.int32),
                                    12, 9),
          ET_RU: EdgeIndex.from_coo(rng.integers(0, 9, 30).astype(np.int32),
                                    rng.integers(0, 12, 30).astype(np.int32),
                                    9, 12)}
    convs = {et: SAGEConv(8, 16) for et in (ET_UB, ET_RU)}
    hc_g = HeteroConv(dict(convs), aggr=aggr, grouped=True)
    hc_s = HeteroConv(dict(convs), aggr=aggr, grouped=False)
    params = hc_g.init(jax.random.PRNGKey(0))
    out_g = hc_g.apply(params, x, ei)
    out_s = hc_s.apply(params, x, ei)
    assert set(out_g) == set(out_s)
    for t in out_g:
        np.testing.assert_allclose(np.asarray(out_g[t]),
                                   np.asarray(out_s[t]),
                                   rtol=2e-4, atol=2e-4)


def test_grouped_auto_off_for_raw_edge_arrays(rng, monkeypatch):
    """Raw (2, E) arrays can't take the grouped path; auto-detect must fall
    back to the per-conv path instead of crashing (even with Pallas
    dispatch on, which otherwise auto-enables grouping)."""
    monkeypatch.setenv("REPRO_USE_PALLAS", "1")
    seen = []
    real = gmm_ops.grouped_matmul
    monkeypatch.setattr(gmm_ops, "grouped_matmul",
                        lambda *a, **k: (seen.append(1), real(*a, **k))[1])
    x = {"user": jnp.asarray(rng.standard_normal((12, 8)), jnp.float32),
         "item": jnp.asarray(rng.standard_normal((9, 8)), jnp.float32)}
    ei = {ET_UB: jnp.asarray(np.stack([rng.integers(0, 12, 30),
                                       rng.integers(0, 9, 30)]), jnp.int32),
          ET_RU: jnp.asarray(np.stack([rng.integers(0, 9, 30),
                                       rng.integers(0, 12, 30)]), jnp.int32)}
    hc = HeteroConv({et: SAGEConv(8, 16) for et in (ET_UB, ET_RU)})
    out = hc.apply(hc.init(jax.random.PRNGKey(0)), x, ei,
                   {"user": 12, "item": 9})
    assert not seen and out["item"].shape == (9, 16)


# ------------------------------------------------------------------ trimming
def test_hetero_trim_preserves_seed_outputs(rng):
    """The paper's invariant, hetero edition: layer-wise trimming never
    changes seed representations."""
    b = next(iter(_loader(_hetero_graph(rng), batch_size=8,
                          input_nodes=np.arange(24), prefill_ell=True)))
    net = to_hetero(lambda i, o: SAGEConv(i, o),
                    (["user", "item"], list(FANOUTS)), [8, 16, 4])
    params = net.init(jax.random.PRNGKey(0))
    full = net.apply(params, b.x_dict, b.edge_index_dict, b.num_nodes_dict)
    trim = net.apply(params, b.x_dict, b.edge_index_dict,
                     num_sampled_nodes_dict=b.num_sampled_nodes_dict,
                     num_sampled_edges_dict=b.num_sampled_edges_dict,
                     trim=True)
    np.testing.assert_allclose(np.asarray(b.seed_output(full)),
                               np.asarray(b.seed_output(trim)),
                               rtol=1e-3, atol=1e-4)
    # trimmed inner shapes actually shrink
    assert trim["item"].shape[0] < full["item"].shape[0] or \
        trim["user"].shape[0] < full["user"].shape[0]
    # trim without the edge budgets is a hard error, not an obscure crash
    with pytest.raises(ValueError, match="num_sampled_edges_dict"):
        net.apply(params, b.x_dict, b.edge_index_dict,
                  num_sampled_nodes_dict=b.num_sampled_nodes_dict,
                  trim=True)


def test_trim_keeps_ell_fast_path(rng, monkeypatch):
    """trim_to_layer must carry a masked static-layout ELL (not drop it) and
    the masked cache must agree with the oracle on the trimmed graph —
    including *weighted* matmuls, whose per-edge weights gather through the
    COO-keyed ``ell_pos`` instead of detouring to the oracle."""
    d = Data(x=rng.standard_normal((200, 16)).astype(np.float32),
             edge_index=np.stack([rng.integers(0, 200, 1200),
                                  rng.integers(0, 200, 1200)]))
    b = next(iter(NeighborLoader(d, d, num_neighbors=[4, 3], batch_size=8,
                                 prefill_ell=True)))
    x_t, ei_t, _ = trim_to_layer(1, b.num_sampled_nodes,
                                 b.num_sampled_edges, b.x, b.edge_index)
    assert ei_t._ell is not None
    # identical shapes to the parent's cache (jit-stable across layers)
    assert [tuple(a.shape for a in bk) for bk in ei_t._ell] == \
           [tuple(a.shape for a in bk) for bk in b.edge_index._ell]
    raw = EdgeIndex(ei_t.data, x_t.shape[0], x_t.shape[0])
    for reduce in ("sum", "mean", "max", "min"):
        fast = ei_t.matmul(x_t, reduce=reduce, force_pallas=True)
        ref = raw.matmul(x_t, reduce=reduce, force_pallas=False)
        np.testing.assert_allclose(np.asarray(fast), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)
    # weighted matmul on the inherited (masked) ELL rides the Pallas kernel
    # — no oracle fallback — and still matches the oracle numerically
    calls = []
    real = spmm_ops.spmm_ell_pallas
    monkeypatch.setattr(spmm_ops, "spmm_ell_pallas",
                        lambda *a, **k: (calls.append(1), real(*a, **k))[1])
    w = jnp.asarray(rng.standard_normal(ei_t.num_edges).astype(np.float32))
    got = ei_t.matmul(x_t, edge_weight=w, force_pallas=True)
    assert calls, "weighted trimmed matmul fell back off the Pallas path"
    ref = raw.matmul(x_t, edge_weight=w, force_pallas=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
