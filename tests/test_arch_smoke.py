"""Per-architecture smoke tests (deliverable f): reduced same-family configs,
one forward/train step on CPU, output shapes + no NaNs; plus the
prefill==forward and decode==forward consistency checks on representative
families (dense / GQA / MoE / SSM / hybrid / enc-dec / VLM).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.nn.lm import model as M
from repro.train import optimizer as opt_lib, steps as steps_lib


def _batch(cfg, rng, b=2, s=16):
    out = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)))}
    if cfg.n_prefix_embeds:
        out["prefix_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.n_prefix_embeds, cfg.d_model)),
            cfg.jnp_dtype)
    if cfg.arch_type == "encdec":
        out["enc_in"] = jnp.asarray(rng.standard_normal((b, 8, cfg.d_model)),
                                    cfg.jnp_dtype)
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch, rng):
    cfg = get_config(arch, smoke=True)
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, rng)
    logits, aux = M.forward_train(
        params, cfg, batch["tokens"],
        prefix_embeds=batch.get("prefix_embeds"),
        enc_in=batch.get("enc_in"))
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # one full train step (grads + optimizer)
    ocfg = opt_lib.OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    state = opt_lib.init_state(params, ocfg)
    step = steps_lib.make_train_step(cfg, ocfg)
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(new_state.step) == 1
    # params actually changed
    delta = sum(float(jnp.abs(a - b).sum()) for a, b in zip(
        jax.tree_util.tree_leaves(state.params),
        jax.tree_util.tree_leaves(new_state.params)))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_count_analytic_matches_actual(arch):
    cfg = get_config(arch, smoke=True)
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    actual = sum(l.size for l in jax.tree_util.tree_leaves(params))
    assert actual == cfg.param_count(), (actual, cfg.param_count())


@pytest.mark.parametrize("arch", ["qwen3_14b", "gemma_2b", "falcon_mamba_7b",
                                  "jamba_1_5_large_398b",
                                  "deepseek_moe_16b",
                                  "seamless_m4t_large_v2", "internvl2_76b"])
def test_prefill_then_decode_matches_forward(arch, rng):
    """Strong consistency: teacher-forced logits at position t must equal
    prefill(t tokens) / decode-by-decode logits (KV/SSM cache correctness)."""
    cfg = get_config(arch, smoke=True)
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    b, s = 1, 12
    batch = _batch(cfg, rng, b=b, s=s)
    toks = batch["tokens"]
    full_logits, _ = M.forward_train(
        params, cfg, toks, prefix_embeds=batch.get("prefix_embeds"),
        enc_in=batch.get("enc_in"), remat=False)

    prefix = cfg.n_prefix_embeds
    total = s + prefix
    cache = M.make_cache(cfg, b, total, enc_len=8)
    # prefill on the first s-2 tokens, then decode 2 tokens
    cut = s - 2
    pre_logits, cache = M.prefill(
        params, cfg, toks[:, :cut], cache_slice(cache, cut + prefix),
        prefix_embeds=batch.get("prefix_embeds"),
        enc_in=batch.get("enc_in"))
    np.testing.assert_allclose(
        np.asarray(pre_logits[:, -1], np.float32),
        np.asarray(full_logits[:, cut - 1], np.float32),
        rtol=2e-3, atol=2e-3)
    # grow the cache to full length for decode
    cache = pad_cache(cfg, cache, b, total, enc_len=8)
    pos = cut + prefix
    for t in range(cut, s):
        logits_d, cache = M.decode_step(
            params, cfg, toks[:, t:t + 1], cache, jnp.asarray(pos, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0], np.float32),
            np.asarray(full_logits[:, t], np.float32),
            rtol=2e-3, atol=2e-3)
        pos += 1


def cache_slice(cache, length):
    """Shrink KV time axes to `length` for a short prefill."""

    def f(path, a):
        names = [getattr(p, "key", getattr(p, "name", None)) for p in path]
        if names[-1] in ("k", "v") and "cross" not in names:
            return a[..., :length, :, :] if a.ndim == 4 else \
                a[:, :, :length, :, :]
        return a

    return jax.tree_util.tree_map_with_path(f, cache)


def pad_cache(cfg, cache, b, total, enc_len):
    def f(path, a):
        names = [getattr(p, "key", getattr(p, "name", None)) for p in path]
        if names[-1] in ("k", "v") and "cross" not in names:
            time_ax = a.ndim - 3
            pad = total - a.shape[time_ax]
            if pad > 0:
                width = [(0, 0)] * a.ndim
                width[time_ax] = (0, pad)
                return jnp.pad(a, width)
        return a

    return jax.tree_util.tree_map_with_path(f, cache)


def test_decode_32k_shape_contract():
    """decode lowers serve_step (one token vs seq_len cache), not train."""
    cfg = get_config("qwen3_4b", smoke=True)
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    cache = M.make_cache(cfg, 2, 64)
    logits, new_cache = M.decode_step(
        params, cfg, jnp.zeros((2, 1), jnp.int32), cache,
        jnp.asarray(5, jnp.int32))
    assert logits.shape == (2, 1, cfg.vocab_size)
    # cache shapes preserved
    for a, b in zip(jax.tree_util.tree_leaves(cache),
                    jax.tree_util.tree_leaves(new_cache)):
        assert a.shape == b.shape
