"""Sampler + loader (paper C7/C9): validity, budgets, temporal, disjoint."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data.data import Data
from repro.data.loader import NeighborLoader
from repro.data.sampler import NeighborSampler


def _graph(rng, n=200, e=1200, with_time=False):
    src = rng.integers(0, n, e)
    dst = rng.integers(0, n, e)
    x = rng.standard_normal((n, 16)).astype(np.float32)
    t = rng.integers(0, 100, e) if with_time else None
    return Data(x=x, edge_index=np.stack([src, dst]),
                y=rng.integers(0, 4, n), time=t), src, dst, t


def test_sampled_edges_exist(rng):
    data, src, dst, _ = _graph(rng)
    sampler = NeighborSampler(data, [4, 3])
    out = sampler.sample(np.arange(10))
    edge_set = set(zip(src.tolist(), dst.tolist()))
    for i in range(len(out.row)):
        if out.edge[i] < 0:
            assert out.row[i] == 0 and out.col[i] == 0
            continue
        gs, gd = out.node[out.row[i]], out.node[out.col[i]]
        assert (int(gs), int(gd)) in edge_set
        assert src[out.edge[i]] == gs and dst[out.edge[i]] == gd


def test_budgets_are_static(rng):
    """Two different seed sets must produce identical output shapes."""
    data, *_ = _graph(rng)
    sampler = NeighborSampler(data, [5, 2])
    a = sampler.sample(np.arange(8))
    b = sampler.sample(np.arange(100, 108))
    assert len(a.node) == len(b.node)
    assert len(a.row) == len(b.row)
    assert a.num_sampled_nodes == b.num_sampled_nodes == [9, 40, 80]
    assert a.num_sampled_edges == b.num_sampled_edges == [40, 80]


def test_dedup_no_duplicate_slots(rng):
    data, *_ = _graph(rng, n=30)  # small graph -> heavy overlap
    out = NeighborSampler(data, [8, 8]).sample(np.arange(6))
    val = out.node[out.node >= 0]
    assert len(val) == len(set(val.tolist()))


def test_temporal_constraint(rng):
    data, src, dst, t = _graph(rng, with_time=True)
    for strat in ("uniform", "recent", "anneal"):
        s = NeighborSampler(data, [6], temporal_strategy=strat)
        seed_time = np.full(10, 40)
        out = s.sample(np.arange(10), seed_time)
        eids = out.edge[out.edge >= 0]
        assert (t[eids] <= 40).all(), strat


def test_recent_picks_most_recent(rng):
    # star graph: node 0 <- nodes 1..20 at times 1..20
    n = 21
    src = np.arange(1, n)
    dst = np.zeros(n - 1, np.int64)
    t = np.arange(1, n)
    data = Data(x=np.zeros((n, 4), np.float32),
                edge_index=np.stack([src, dst]), time=t)
    s = NeighborSampler(data, [3], temporal_strategy="recent")
    out = s.sample(np.array([0]), np.array([15]))
    eids = out.edge[out.edge >= 0]
    assert sorted(t[eids].tolist()) == [13, 14, 15]  # 3 most recent <= 15


def test_disjoint_subgraphs(rng):
    data, *_ = _graph(rng, n=50)
    s = NeighborSampler(data, [3, 2], disjoint=True)
    out = s.sample(np.arange(4))
    assert out.metadata.get("disjoint")
    # seeds occupy slots 1..4; every edge path must stay within one sample
    assert len(out.seed_slots) == 4
    # a global node may appear in MULTIPLE samples (slots differ)
    val = out.node[out.node >= 0]
    assert len(val) >= len(set(val.tolist()))


def test_loader_yields_model_ready_batches(rng):
    data, *_ = _graph(rng)
    loader = NeighborLoader(data, data, num_neighbors=[4, 2], batch_size=16)
    n_batches = 0
    for b in loader:
        n_batches += 1
        assert b.x.shape[0] == b.num_nodes
        assert b.y is not None and b.y.shape[0] == 16
        assert (np.asarray(b.x)[0] == 0).all()  # null sink zero features
    assert n_batches == len(loader)


def test_loader_transform_hook(rng):
    """RDL-style: attach external labels via transform (paper §3.1)."""
    data, *_ = _graph(rng)

    def attach(batch):
        batch.extras["table_label"] = np.asarray(batch.n_id)[
            np.asarray(batch.seed_slots)] % 3
        return batch

    loader = NeighborLoader(data, data, num_neighbors=[3], batch_size=8,
                            transform=attach)
    b = next(iter(loader))
    assert "table_label" in b.extras and len(b.extras["table_label"]) == 8


def test_disjoint_merge_matches_per_seed_aggregation(rng):
    """Disjoint-merge slot maps must preserve subgraph structure: a 2-hop
    sum aggregation over the merged batch equals the same aggregation run
    on each per-seed shared sample (identical rng streams)."""
    data, *_ = _graph(rng, n=40, e=300)
    fan = [3, 2]
    seeds = np.arange(6)
    merged = NeighborSampler(data, fan, disjoint=True, seed=7).sample(seeds)
    per_seed_sampler = NeighborSampler(data, fan, seed=7)
    per = [per_seed_sampler.sample(seeds[i:i + 1])
           for i in range(len(seeds))]

    def seed_values(out, seed_slot):
        # features = f(global node id); 2 rounds of masked scatter-add
        h = np.where(out.node >= 0, out.node + 1, 0).astype(np.float64)
        for _ in fan:
            nh = np.zeros_like(h)
            real = out.edge >= 0
            np.add.at(nh, out.col[real], h[out.row[real]])
            h = nh
        return h[seed_slot]

    for i in range(len(seeds)):
        got = seed_values(merged, int(merged.seed_slots[i]))
        want = seed_values(per[i], int(per[i].seed_slots[0]))
        assert got == want, (i, got, want)


def test_prefetch_parity_and_ordering(rng):
    """prefetch>0 must yield the same batches in the same order as
    prefetch=0 (same seed -> same sampler rng stream)."""
    data, *_ = _graph(rng)
    mk = lambda p: NeighborLoader(data, data, num_neighbors=[4, 2],
                                  batch_size=16, shuffle=True, seed=3,
                                  prefetch=p)
    batches0 = list(mk(0))
    batches2 = list(mk(2))
    assert len(batches0) == len(batches2) > 1
    for a, b in zip(batches0, batches2):
        np.testing.assert_array_equal(np.asarray(a.n_id), np.asarray(b.n_id))
        np.testing.assert_array_equal(np.asarray(a.e_id), np.asarray(b.e_id))
        np.testing.assert_array_equal(np.asarray(a.x), np.asarray(b.x))


def test_prefetch_abandoned_iterator_reaps_producer(rng):
    """Breaking out of iteration early must not leave the producer thread
    blocked on the bounded queue forever."""
    import threading
    import time

    data, *_ = _graph(rng)
    loader = NeighborLoader(data, data, num_neighbors=[4], batch_size=8,
                            prefetch=1)
    before = set(threading.enumerate())
    it = iter(loader)
    next(it)
    it.close()  # GeneratorExit: the finally block must reap the producer
    deadline = time.time() + 5.0
    extra = [t for t in threading.enumerate() if t not in before]
    while extra and time.time() < deadline:
        time.sleep(0.01)
        extra = [t for t in threading.enumerate() if t not in before]
    assert not extra, f"producer thread leaked: {extra}"


def test_partial_tail_batch_prefills_ell(rng):
    """drop_last=False: the smaller tail batch gets its own static layout
    instead of crashing the packer (full-batch row ids out of range)."""
    data, *_ = _graph(rng)
    loader = NeighborLoader(data, data, num_neighbors=[4, 3], batch_size=16,
                            input_nodes=np.arange(40), drop_last=False,
                            prefill_ell=True)
    batches = list(loader)
    assert [len(b.seed_slots) for b in batches] == [16, 16, 8]
    for b in batches:
        assert b.edge_index._ell is not None
        # packed batch aggregates identically to the oracle on the raw COO
        from repro.core.edge_index import EdgeIndex
        import jax.numpy as jnp
        fast = b.edge_index.matmul(jnp.asarray(b.x), force_pallas=True)
        ref = EdgeIndex(b.edge_index.data, b.num_nodes,
                        b.num_nodes).matmul(jnp.asarray(b.x),
                                            force_pallas=False)
        np.testing.assert_allclose(np.asarray(fast), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)


def test_prefetch_producer_exception_propagates(rng):
    """A raising _make_batch must surface in the consumer instead of
    deadlocking the queue (the swallowed-sentinel bug)."""
    data, *_ = _graph(rng)

    def boom(batch):
        raise RuntimeError("transform failed")

    loader = NeighborLoader(data, data, num_neighbors=[3], batch_size=8,
                            prefetch=2, transform=boom)
    with pytest.raises(RuntimeError, match="transform failed"):
        list(loader)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 6), st.integers(1, 5))
def test_sampler_shapes_property(seed, f1, f2):
    r = np.random.default_rng(seed)
    data, *_ = _graph(r, n=60, e=300)
    s = NeighborSampler(data, [f1, f2])
    out = s.sample(np.arange(5))
    assert len(out.node) == 1 + 5 + 5 * f1 + 5 * f1 * f2
    assert len(out.row) == 5 * f1 + 5 * f1 * f2
    # all slots referenced by edges are in range
    assert (out.row < len(out.node)).all() and (out.row >= 0).all()
    assert (out.col < len(out.node)).all() and (out.col >= 0).all()
