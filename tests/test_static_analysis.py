"""PR 7 static-verification gate: lint, dispatch audits, budgets, retrace.

Tier-1 anchors: ``test_lint_clean`` (the ``python -m repro.analysis`` exit-0
contract over ``src/``), the golden dispatch audits proving the four bench
step cells ride Pallas with zero oracle fallbacks and one trace, and the
pack-time rejection of an over-budget ELL ladder.
"""

import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (BudgetError, RetraceError, RetraceSentinel,
                            audit_report, budget_headroom_summary,
                            ell_layout_report, lint_source)
from repro.analysis import lint as lint_mod
from repro.analysis.__main__ import default_root, main as analysis_main
from repro.kernels import budgets as hw
from repro.kernels.spmm import ops as spmm_ops

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture
def rng():
    return np.random.default_rng(0)


# ------------------------------------------------------------------ lint
def test_lint_clean():
    """``python -m repro.analysis`` must exit 0 over the src/ tree."""
    assert analysis_main([]) == 0


def test_lint_default_root_is_src_tree():
    assert default_root().endswith("src")
    assert os.path.isdir(os.path.join(default_root(), "repro"))


def test_lint_flags_raw_kernel_entry_outside_package():
    src = "def f(t, x):\n    return spmm_ell_pallas(t, x)\n"
    bad = lint_source("src/repro/nn/gnn/conv.py", src)
    assert [f.rule for f in bad] == ["raw-kernel-entry"]
    # the same call inside the kernel package is the wrapper's job: clean
    assert not lint_source("src/repro/kernels/spmm/ops.py", src)


def test_lint_flags_registered_attn_entry_outside_package():
    """`attn_ell_pallas` joined the registered raw-entry table (PR 9)."""
    src = "def f(t, z, a, b):\n    return attn_ell_pallas(t, z, a, b)\n"
    bad = lint_source("src/repro/core/edge_index.py", src)
    assert [f.rule for f in bad] == ["raw-kernel-entry"]
    assert not lint_source("src/repro/kernels/attention/ops.py", src)


def test_lint_flags_unregistered_pallas_entry_outside_package():
    """Any `*_pallas` call outside repro/kernels/ is package-private —
    even ones the registry has never heard of (generic rule, PR 9)."""
    src = "def f(t, x):\n    return frobnicate_ell_pallas(t, x)\n"
    bad = lint_source("src/repro/nn/gnn/conv.py", src)
    assert [f.rule for f in bad] == ["raw-kernel-entry"]
    # inside the kernel package: the wrapper's job, clean
    assert not lint_source("src/repro/kernels/attention/ops.py", src)
    # dispatch-control kwargs are not kernel entries: allowlisted
    ok = ("def f(t, x, force_pallas=None):\n"
          "    return g(t, x, use_pallas(force_pallas))\n")
    assert not lint_source("src/repro/nn/gnn/conv.py", ok)


def test_lint_flags_clock_and_rng_in_resilience():
    src = ("import time\nimport random\nimport numpy as np\n"
           "def jitter():\n"
           "    t = time.time()\n"
           "    r = np.random.random()\n"
           "    g = np.random.default_rng()\n"
           "    return t + r + g.random()\n")
    bad = lint_source("src/repro/data/resilience.py", src)
    rules = [f.rule for f in bad]
    assert rules.count("injectable-clock-rng") == 4  # import + 3 calls
    # the whole deterministic-host set is in scope: loader pipeline,
    # cache eviction, partitioner region growing
    for path in ("src/repro/data/loader.py",
                 "src/repro/data/feature_store.py",
                 "src/repro/data/partition.py"):
        assert [f.rule for f in lint_source(path, src)].count(
            "injectable-clock-rng") == 4
    # identical source anywhere else is out of the rule's scope
    assert not lint_source("src/repro/nn/gnn/conv.py", src)


def test_lint_flags_jnp_in_pipeline_stages():
    src = ("import jax.numpy as jnp\n"
           "def _stage_gather(self, sample):\n"
           "    return jnp.asarray(sample)\n")
    bad = lint_source("src/repro/data/loader.py", src)
    assert [f.rule for f in bad] == ["host-packing-purity"]
    # _stage_pack is the device-put stage: jnp allowed there by design
    ok = src.replace("_stage_gather", "_stage_pack")
    assert not lint_source("src/repro/data/loader.py", ok)
    # cache eviction is on the same contract
    evict = src.replace("_stage_gather", "_evict")
    assert [f.rule for f in lint_source(
        "src/repro/data/feature_store.py", evict)] == ["host-packing-purity"]


def test_lint_flags_jnp_in_host_packing():
    src = ("import jax.numpy as jnp\n"
           "def csr_to_ell(indptr, indices):\n"
           "    return jnp.asarray(indices)\n")
    bad = lint_source("src/repro/kernels/spmm/ops.py", src)
    assert [f.rule for f in bad] == ["host-packing-purity"]
    # a function not on the producer-thread list may use jnp freely
    ok = src.replace("csr_to_ell", "spmm_ell_weighted")
    assert not lint_source("src/repro/kernels/spmm/ops.py", ok)


def test_lint_flags_host_sync_in_shard_step_body():
    """PR 10: the shard_map'd step bodies must stay on-device — a
    device_get or host callback inside them serialises the mesh."""
    src = ("def _shard_body(state, stacked):\n"
           "    g = jax.device_get(state.params)\n"
           "    return g\n")
    bad = lint_source("src/repro/launch/train.py", src)
    assert [f.rule for f in bad] == ["shard-step-purity"]
    cb = ("def _shard_body_compressed(state, stacked, residual):\n"
          "    jax.debug.debug_print('loss={l}', l=state.step)\n"
          "    return jax.pure_callback(f, shape, state)\n")
    rules = [f.rule for f in lint_source("src/repro/launch/train.py", cb)]
    assert rules == ["shard-step-purity"] * 2


def test_lint_shard_step_rule_scoped_to_step_bodies():
    # other functions in train.py may device_get freely (host-side driver)
    src = ("def step(self, state, batch):\n"
           "    return jax.device_get(self._step(state, batch))\n")
    assert not lint_source("src/repro/launch/train.py", src)
    # identical body outside train.py is out of scope
    bad = ("def _shard_body(state, stacked):\n"
           "    return jax.device_get(state)\n")
    assert not lint_source("src/repro/train/loop.py", bad)


def test_lint_real_mesh_step_bodies_clean():
    with open(os.path.join(REPO_ROOT, "src", "repro", "launch",
                           "train.py")) as f:
        src = f.read()
    assert not [f_ for f_ in lint_source("src/repro/launch/train.py", src)
                if f_.rule == "shard-step-purity"]


def test_pytree_roundtrips_clean():
    assert lint_mod.check_pytree_roundtrips() == []


# --------------------------------------------------------------- budgets
def test_over_budget_ell_layout_rejected_at_pack_time():
    """A degree bound whose K rung needs more than the SMEM prefetch
    budget must be rejected when the layout is built, not at launch."""
    max_k = hw.MAX_PREFETCH_ELEMS // hw.DEFAULT_BR
    with pytest.raises(BudgetError, match="prefetch"):
        spmm_ops.ell_layout_from_bounds([(0, 8, max_k + 1)])
    # the largest servable rung is fine
    layout = spmm_ops.ell_layout_from_bounds([(0, 8, max_k)])
    assert layout and layout[0][1] == max_k


def test_over_budget_static_pack_rejected(rng):
    indptr = np.arange(9, dtype=np.int64) * 2
    indices = rng.integers(0, 8, 16).astype(np.int32)
    rows = np.arange(8, dtype=np.int32)
    bad_layout = [(rows, 2 * (hw.MAX_PREFETCH_ELEMS // hw.DEFAULT_BR))]
    with pytest.raises(BudgetError, match="K="):
        spmm_ops.csr_to_ell_static(indptr, indices, bad_layout)


def test_budget_error_message_is_actionable():
    with pytest.raises(BudgetError) as exc:
        hw.check_ell_rung(hw.MAX_PREFETCH_ELEMS, block_rows=hw.DEFAULT_BR,
                          context="unit test")
    msg = str(exc.value)
    assert "unit test" in msg and "MAX_PREFETCH_ELEMS" in msg
    assert str(hw.MAX_PREFETCH_ELEMS // hw.DEFAULT_BR) in msg  # the remedy


def test_ell_layout_report_and_headroom(rng):
    layout = spmm_ops.ell_layout_from_bounds([(0, 16, 4), (16, 48, 12)])
    recs = ell_layout_report(layout, feat=64)
    assert len(recs) == len(layout)
    assert all(not r["over_budget"] for r in recs)
    assert all(0 <= r["smem_frac"] <= 1 for r in recs)
    summary = budget_headroom_summary([layout], feat=64)
    assert summary["min_smem_headroom_bytes"] > 0
    assert summary["launches_audited"] >= len(layout) + 2


def test_typed_attention_budget_accounting():
    """The typed carry launch ships more SMEM than GAT's: `(1, H)` prior
    row plus two `BR x d`-per-head m/l carry blocks, and head-dim-wide
    logit halves instead of scalar ones. A shape the GAT checker accepts
    must therefore be rejectable by the typed checker."""
    shape = dict(rows=8, k=4, heads=4, feat=16)
    # GAT accounting (logit_dim=1, no carry) passes at this shape...
    hw.check_gat_bucket(**shape)
    # ...and the typed checker agrees when given the same launch shape
    hw.check_attn_bucket(**shape, logit_dim=1, carry=False)
    usage_gat = hw.gat_launch_usage(8, 4, 4, 16)
    usage_typed = hw.attn_launch_usage(8, 4, 4, 16, logit_dim=1,
                                       carry=False)
    assert usage_gat == usage_typed
    # ...but wide typed logit halves blow the VMEM budget
    with pytest.raises(BudgetError, match="attention"):
        hw.check_attn_bucket(**shape, logit_dim=50000, carry=True)


def test_attn_grid_report_servable_shape():
    from repro.analysis import attn_grid_report

    rec = attn_grid_report(64, 8, 4, 32, logit_dim=8, carry=True)
    assert rec["logit_dim"] == 8 and rec["carry"]
    assert rec["vmem_headroom_bytes"] > 0
    assert rec["smem_headroom_bytes"] > 0


# --------------------------------------------------- dispatch golden audits
def _loader_batches(rng, count=2, **loader_kw):
    from repro.data.data import Data
    from repro.data.loader import NeighborLoader

    n, e, feat = 256, 2048, 32
    data = Data(x=rng.standard_normal((n, feat)).astype(np.float32),
                edge_index=np.stack([rng.integers(0, n, e),
                                     rng.integers(0, n, e)]),
                y=rng.integers(0, 4, n))
    loader = NeighborLoader(data, data, num_neighbors=[4, 2], batch_size=8,
                            shuffle=True, prefill_ell=True, seed=0,
                            **loader_kw)
    it = iter(loader)
    try:
        return [next(it) for _ in range(count)]
    finally:
        it.close()


def test_golden_audit_loader_step(rng):
    """The loader_step cell: forced-Pallas grad step == zero oracle eqns,
    `_spmm_ell_kernel` launched, one signature across batches."""
    batches = _loader_batches(rng)
    feat, hidden = batches[0].x.shape[1], 16
    params = {"w1": jnp.zeros((feat, hidden)), "w2": jnp.zeros((hidden, 4))}

    def step(p, batch):
        def loss_fn(p):
            h = jax.nn.relu(batch.edge_index.matmul(
                batch.x @ p["w1"], force_pallas=True, interpret=True))
            out = batch.edge_index.matmul(
                h @ p["w2"], force_pallas=True, interpret=True)
            return (out[batch.seed_slots] ** 2).mean()

        return jax.value_and_grad(loss_fn)(p)

    report = audit_report(step, params, batches[0])
    report.assert_fused(expect_kernels=("_spmm_ell_kernel",))
    assert report.oracle_fallbacks == 0
    sentinel = RetraceSentinel(budget=1)
    probe = sentinel.wrap(lambda p, b: None, name="loader_step")
    for b in batches:
        probe(params, b)
    assert sentinel.count("loader_step") == 1


def test_golden_audit_pipelined_loader_single_signature(rng):
    """The stage-pipelined producer feeds the same one-trace fast path:
    batches from a depth-3 pipeline share one jit signature (no retrace)
    and the grad step stays fully fused with zero oracle fallbacks."""
    batches = _loader_batches(rng, count=4, pipeline_depth=3, prefetch=2)
    feat, hidden = batches[0].x.shape[1], 16
    params = {"w1": jnp.zeros((feat, hidden)), "w2": jnp.zeros((hidden, 4))}

    def step(p, batch):
        def loss_fn(p):
            h = jax.nn.relu(batch.edge_index.matmul(
                batch.x @ p["w1"], force_pallas=True, interpret=True))
            out = batch.edge_index.matmul(
                h @ p["w2"], force_pallas=True, interpret=True)
            return (out[batch.seed_slots] ** 2).mean()

        return jax.value_and_grad(loss_fn)(p)

    report = audit_report(step, params, batches[0])
    report.assert_fused(expect_kernels=("_spmm_ell_kernel",))
    assert report.oracle_fallbacks == 0
    sentinel = RetraceSentinel(budget=1)
    probe = sentinel.wrap(lambda p, b: None, name="pipelined_step")
    for b in batches:
        probe(params, b)
    assert sentinel.count("pipelined_step") == 1


def test_golden_audit_train_step_weighted(rng):
    """The train_step cell (gcn-normalised weighted aggregation)."""
    from repro.nn.gnn.conv import gcn_norm

    batches = _loader_batches(rng)
    feat, hidden = batches[0].x.shape[1], 16
    params = {"w1": jnp.zeros((feat, hidden)), "w2": jnp.zeros((hidden, 4))}

    def step(p, batch):
        def loss_fn(p):
            ew, _ = gcn_norm(batch.edge_index, batch.num_nodes,
                             add_self_loops=False)
            h = jax.nn.relu(batch.edge_index.matmul(
                batch.x @ p["w1"], edge_weight=ew, force_pallas=True,
                interpret=True))
            out = batch.edge_index.matmul(
                h @ p["w2"], edge_weight=ew, force_pallas=True,
                interpret=True)
            return (out[batch.seed_slots] ** 2).mean()

        return jax.value_and_grad(loss_fn)(p)

    report = audit_report(step, params, batches[0])
    report.assert_fused(expect_kernels=("_spmm_ell_kernel",))
    # the ops-level custom-VJP backward is attributed, not misread as oracle
    assert report.kernel_vjp_eqns.get("spmm_ell", 0) > 0


def test_golden_audit_gat_step(rng, monkeypatch):
    """The gat_step cell: fused flash-GAT attention, zero fallbacks."""
    from repro.nn.gnn.conv import GATConv

    monkeypatch.setenv("REPRO_USE_PALLAS", "1")
    batches = _loader_batches(rng)
    feat = batches[0].x.shape[1]
    conv = GATConv(feat, 16, heads=4)
    params = conv.init(jax.random.PRNGKey(0))

    def step(p, batch):
        def loss_fn(p):
            out = conv.apply(p, batch.x, batch.edge_index)
            return (out[batch.seed_slots] ** 2).mean()

        return jax.value_and_grad(loss_fn)(p)

    report = audit_report(step, params, batches[0])
    report.assert_fused(expect_kernels=("_gat_ell_kernel",))
    assert report.oracle_fallbacks == 0
    sentinel = RetraceSentinel(budget=1)
    probe = sentinel.wrap(lambda p, b: None, name="gat_step")
    for b in batches:
        probe(params, b)
    assert sentinel.count("gat_step") == 1


def test_golden_audit_hetero_step(rng, monkeypatch):
    """The hetero_step cell: grouped projections (`_gmm_kernel`) plus
    per-relation ELL aggregation, zero oracle fallbacks."""
    from repro.core.hetero import to_hetero
    from repro.data.data import HeteroData
    from repro.data.hetero_sampler import HeteroNeighborLoader
    from repro.nn.gnn.conv import SAGEConv

    monkeypatch.setenv("REPRO_USE_PALLAS", "1")
    n_user, n_item, e, feat = 128, 256, 1024, 16
    fan = {("user", "buys", "item"): [4, 2],
           ("item", "rev_buys", "user"): [4, 2]}
    hd = HeteroData()
    hd.add_nodes("user", rng.standard_normal((n_user, feat)).astype(
        np.float32))
    hd.add_nodes("item", rng.standard_normal((n_item, feat)).astype(
        np.float32))
    ub = np.stack([rng.integers(0, n_user, e), rng.integers(0, n_item, e)])
    hd.add_edges(("user", "buys", "item"), ub)
    hd.add_edges(("item", "rev_buys", "user"), ub[::-1])
    loader = HeteroNeighborLoader(
        hd, hd, num_neighbors=fan, input_type="item",
        input_nodes=np.arange(n_item), batch_size=8, prefill_ell=True,
        seed=0)
    it = iter(loader)
    batches = [next(it) for _ in range(2)]
    net = to_hetero(lambda i, o: SAGEConv(i, o), (["user", "item"],
                                                  list(fan)),
                    [feat, 8, 4], grouped=True)
    params = net.init(jax.random.PRNGKey(0))

    def step(p, batch):
        def loss_fn(p):
            out = net.apply(p, batch.x_dict, batch.edge_index_dict,
                            batch.num_nodes_dict)
            return (batch.seed_output(out) ** 2).mean()

        return jax.value_and_grad(loss_fn)(p)

    report = audit_report(step, params, batches[0])
    report.assert_fused(expect_kernels=("_spmm_ell_kernel", "_gmm_kernel"))
    sentinel = RetraceSentinel(budget=1)
    probe = sentinel.wrap(lambda p, b: None, name="hetero_step")
    for b in batches:
        probe(params, b)
    assert sentinel.count("hetero_step") == 1


def test_golden_audit_hgt_step(rng, monkeypatch):
    """The hgt_step cell: one grouped K/Q/V matmul (`_gmm_kernel`) plus
    typed carry-mode attention (`_attn_ell_kernel`), zero fallbacks."""
    from repro.core.hetero import hgt
    from repro.data.data import HeteroData
    from repro.data.hetero_sampler import HeteroNeighborLoader

    monkeypatch.setenv("REPRO_USE_PALLAS", "1")
    n_user, n_item, e, feat = 128, 256, 1024, 16
    fan = {("user", "buys", "item"): [4, 2],
           ("item", "rev_buys", "user"): [4, 2]}
    hd = HeteroData()
    hd.add_nodes("user", rng.standard_normal((n_user, feat)).astype(
        np.float32))
    hd.add_nodes("item", rng.standard_normal((n_item, feat)).astype(
        np.float32))
    ub = np.stack([rng.integers(0, n_user, e), rng.integers(0, n_item, e)])
    hd.add_edges(("user", "buys", "item"), ub)
    hd.add_edges(("item", "rev_buys", "user"), ub[::-1])
    loader = HeteroNeighborLoader(
        hd, hd, num_neighbors=fan, input_type="item",
        input_nodes=np.arange(n_item), batch_size=8, prefill_ell=True,
        seed=0)
    it = iter(loader)
    batches = [next(it) for _ in range(2)]
    net = hgt((["user", "item"], list(fan)), [feat, 8, 8], heads=4)
    params = net.init(jax.random.PRNGKey(0))

    def step(p, batch):
        def loss_fn(p):
            out = net.apply(p, batch.x_dict, batch.edge_index_dict,
                            batch.num_nodes_dict)
            return (batch.seed_output(out) ** 2).mean()

        return jax.value_and_grad(loss_fn)(p)

    report = audit_report(step, params, batches[0])
    report.assert_fused(expect_kernels=("_attn_ell_kernel", "_gmm_kernel"))
    assert report.oracle_fallbacks == 0
    # the typed-attention custom VJP is attributed, not misread as oracle
    assert report.kernel_vjp_eqns.get("attn_ell", 0) > 0
    sentinel = RetraceSentinel(budget=1)
    probe = sentinel.wrap(lambda p, b: None, name="hgt_step")
    for b in batches:
        probe(params, b)
    assert sentinel.count("hgt_step") == 1


def test_audit_flags_oracle_path(rng):
    """The auditor must *reject* the XLA oracle branch (negative control)."""
    batch = _loader_batches(rng, count=1)[0]

    def fwd(x):
        return batch.edge_index.matmul(x, force_pallas=False)

    report = audit_report(fwd, jnp.zeros_like(batch.x))
    assert report.oracle_fallbacks > 0
    assert "spmm" in " ".join(report.oracle_eqns)
    with pytest.raises(AssertionError, match="oracle fallback"):
        report.assert_fused()


def test_bench_fastpath_audit_cell(tmp_path):
    """The registered bench cell writes the audit record end to end."""
    if REPO_ROOT not in sys.path:
        sys.path.insert(0, REPO_ROOT)
    import json

    from benchmarks import fastpath_audit

    out = str(tmp_path / "BENCH_audit.json")
    fastpath_audit.run(out)
    rec = [r for r in json.load(open(out)) if r["cell"] == "fastpath_audit"]
    assert len(rec) == 1
    audits = rec[0]["audits"]
    assert set(audits) == {"loader_step", "train_step", "hetero_step",
                           "gat_step", "hgt_step"}
    for name, a in audits.items():
        assert a["oracle_fallbacks"] == 0, (name, a)
        assert a["trace_count"] == 1, (name, a)
        assert a["kernel_launches"], (name, a)
    assert rec[0]["budget_headroom"]["min_smem_headroom_bytes"] > 0


# ---------------------------------------------------------------- retrace
def test_retrace_sentinel_diff_on_shape_change():
    sentinel = RetraceSentinel(budget=1)
    f = sentinel.wrap(lambda x: x, name="f")
    f(jnp.zeros((4, 8)))
    f(jnp.zeros((4, 8)))  # same signature: free
    with pytest.raises(RetraceError) as exc:
        f(jnp.zeros((5, 8)))
    msg = str(exc.value)
    assert "2 distinct" in msg and "(4, 8)" in msg and "(5, 8)" in msg


def test_retrace_sentinel_static_aux_diff():
    sentinel = RetraceSentinel(budget=1)
    f = sentinel.wrap(lambda x, flag: x, name="f")
    f(jnp.zeros(3), True)
    with pytest.raises(RetraceError, match="static"):
        f(jnp.zeros(3), False)


def test_retrace_sentinel_record_only_mode():
    sentinel = RetraceSentinel(budget=None)
    f = sentinel.wrap(lambda x: x, name="f")
    for n in (1, 2, 3):
        f(jnp.zeros(n))
    assert sentinel.count("f") == 3
    sentinel.check()  # no budget -> never raises


def test_retrace_sentinel_context_manager_checks_on_exit():
    with pytest.raises(RetraceError):
        with RetraceSentinel(budget=0) as sentinel:
            sentinel.wrap(lambda: None, name="g")()


def test_train_loop_reports_trace_signatures():
    from repro.train.loop import train_loop

    class _State:
        pass

    def step(state, batch):
        return state, {"loss": jnp.asarray(float(batch["x"].sum()))}

    batches = iter([{"x": jnp.ones((2, 4))} for _ in range(3)])
    out = train_loop(_State(), step, batches, num_steps=3, log_every=100,
                     log_fn=lambda *a: None)
    assert out["trace_signatures"] == 1

    bad = iter([{"x": jnp.ones((2, 4))}, {"x": jnp.ones((3, 4))}])
    with pytest.raises(RetraceError):
        train_loop(_State(), step, bad, num_steps=2, retrace_budget=1,
                   log_every=100, log_fn=lambda *a: None)
