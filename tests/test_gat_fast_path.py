"""Fused attention fast path: GAT rides the flash-GAT Pallas kernel (this PR).

The acceptance chain for the attention tentpole:

    loader-prefilled batch (homogeneous or hetero)
      -> jit'd GATConv value_and_grad train step, Pallas dispatch on
        -> forward: the fused flash-GAT ELL kernel (spy-counted), no
           (E, H, F) edge-message materialisation
        -> backward: the ops-level custom VJP (softmax backward over the
           same panels, spy-counted)
      == materialised-oracle outputs and gradients, ONE trace across batches

plus `return_attention` recovering per-edge alpha through the COO-keyed
``ell_pos``, the explainer's ``edge_mask`` staying fused on GAT, the
``flow="target_to_source"`` transpose dispatch, hetero per-relation
dispatch, trimmed deep GATs, and a slow-marked parity sweep across the
bucketed K ladder.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.edge_index import EdgeIndex
from repro.core.explain import Explainer
from repro.core.hetero import to_hetero
from repro.data.data import Data, HeteroData
from repro.data.hetero_sampler import HeteroNeighborLoader
from repro.data.loader import NeighborLoader
from repro.kernels.attention import ops as attn_ops
from repro.kernels.segment_softmax import ref as sm_ref
from repro.nn.gnn.conv import GATConv
from repro.nn.gnn.models import make_model

ET_UB = ("user", "buys", "item")
ET_RU = ("item", "rev_buys", "user")
FANOUTS = {ET_UB: [3, 2], ET_RU: [3, 2]}


def _spy(monkeypatch, module, name):
    calls = []
    real = getattr(module, name)
    monkeypatch.setattr(module, name,
                        lambda *a, **k: (calls.append(1), real(*a, **k))[1])
    return calls


def _random_graph(rng, n, e):
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    return src, dst


def _materialised_gat(params, x, src, dst, n, heads, f_head, concat=True,
                      negative_slope=0.2, edge_weight=None):
    """The pre-refactor (E, H, F)-materialising GAT forward, as oracle."""
    z = (x @ params["lin"]["w"]).reshape(-1, heads, f_head)
    a_src = (z * params["att_src"]).sum(-1)
    a_dst = (z * params["att_dst"]).sum(-1)
    logits = jax.nn.leaky_relu(a_src[src] + a_dst[dst], negative_slope)
    alpha = sm_ref.segment_softmax(logits, dst, n)
    msg = z[src] * alpha[..., None]
    if edge_weight is not None:
        msg = msg * edge_weight[:, None, None]
    out = jax.ops.segment_sum(msg, dst, num_segments=n)
    out = out.reshape(n, heads * f_head) if concat else out.mean(1)
    return out + params["bias"], alpha


# ----------------------------------------------------------- forward parity
@pytest.mark.parametrize("heads,concat", [(1, True), (4, True), (2, False)])
def test_gat_fused_forward_matches_materialised(rng, monkeypatch, heads,
                                                concat):
    monkeypatch.setenv("REPRO_USE_PALLAS", "1")
    calls = _spy(monkeypatch, attn_ops, "gat_ell_pallas")
    n, e, f_in, f_out = 40, 220, 12, 8
    src, dst = _random_graph(rng, n, e)
    x = jnp.asarray(rng.standard_normal((n, f_in)).astype(np.float32))
    conv = GATConv(f_in, f_out, heads=heads, concat=concat)
    params = conv.init(jax.random.PRNGKey(0))
    ei = EdgeIndex.from_coo(src, dst, n, n).fill_cache()
    got = conv.apply(params, x, ei)
    assert calls, "fused GAT forward never reached the Pallas kernel"
    want, _ = _materialised_gat(params, x, src, dst, n, heads,
                                conv.out_per_head, concat=concat)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4,
                               atol=1e-5)


def test_gat_return_attention_roundtrip_ell_pos(rng, monkeypatch):
    """Per-edge alpha recovered through the COO-keyed ell_pos == the
    materialised softmax coefficients, in COO edge order."""
    monkeypatch.setenv("REPRO_USE_PALLAS", "1")
    calls = _spy(monkeypatch, attn_ops, "gat_ell_pallas")
    n, e = 30, 150
    src, dst = _random_graph(rng, n, e)
    x = jnp.asarray(rng.standard_normal((n, 10)).astype(np.float32))
    conv = GATConv(10, 8, heads=2)
    params = conv.init(jax.random.PRNGKey(1))
    ei = EdgeIndex.from_coo(src, dst, n, n).fill_cache()
    got, alpha = conv.apply(params, x, ei, return_attention=True)
    assert calls, "return_attention dropped off the fused path"
    want, want_alpha = _materialised_gat(params, x, src, dst, n, 2, 4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(alpha), np.asarray(want_alpha),
                               rtol=1e-4, atol=1e-6)


# ------------------------------------------------------------- grad parity
@pytest.mark.parametrize("weighted,concat", [(False, True), (True, True),
                                             (True, False)])
def test_gat_grad_parity_fused_vs_materialised(rng, monkeypatch, weighted,
                                               concat):
    """jax.grad through the fused kernel's custom VJP == autodiff through
    the materialised oracle, for params, features and the edge mask."""
    n, e, f_in, f_out = 35, 180, 10, 8
    src, dst = _random_graph(rng, n, e)
    x = jnp.asarray(rng.standard_normal((n, f_in)).astype(np.float32))
    mask = (jnp.asarray(rng.random(e).astype(np.float32)) if weighted
            else None)
    conv = GATConv(f_in, f_out, heads=2, concat=concat)
    params = conv.init(jax.random.PRNGKey(2))

    def loss(p, x_, m_, ei):
        out = conv.apply(p, x_, ei, edge_mask=m_)
        return (out ** 2).mean()

    monkeypatch.setenv("REPRO_USE_PALLAS", "1")
    calls = _spy(monkeypatch, attn_ops, "gat_ell_pallas")
    bwd = _spy(monkeypatch, attn_ops, "_gat_panels_backward")
    ei = EdgeIndex.from_coo(src, dst, n, n).fill_cache()
    argnums = (0, 1, 2) if weighted else (0, 1)
    gk = jax.grad(loss, argnums=argnums)(params, x, mask, ei)
    assert calls, "grad step never reached the fused kernel forward"
    assert bwd, "grad step never ran the panel softmax backward"

    monkeypatch.setenv("REPRO_USE_PALLAS", "0")
    raw = EdgeIndex(ei.data, n, n)
    go = jax.grad(loss, argnums=argnums)(params, x, mask, raw)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5), gk, go)


def test_gat_flow_target_to_source(rng, monkeypatch):
    """Regression: flow="target_to_source" used to be silently ignored. It
    now aggregates along reversed edges (transpose dispatch), on both the
    materialised and the fused path."""
    n, e, f = 28, 140, 10
    src, dst = _random_graph(rng, n, e)
    x = jnp.asarray(rng.standard_normal((n, f)).astype(np.float32))
    conv = GATConv(f, 8, heads=2, flow="target_to_source")
    params = conv.init(jax.random.PRNGKey(3))
    # oracle: the forward-flow conv on the reversed edge list
    monkeypatch.setenv("REPRO_USE_PALLAS", "0")
    want, _ = _materialised_gat(params, x, dst, src, n, 2, 4)
    got_raw = conv.apply(params, x, np.stack([src, dst]), num_nodes=n)
    np.testing.assert_allclose(np.asarray(got_raw), np.asarray(want),
                               rtol=1e-4, atol=1e-5)
    monkeypatch.setenv("REPRO_USE_PALLAS", "1")
    calls = _spy(monkeypatch, attn_ops, "gat_ell_pallas")
    ei = EdgeIndex.from_coo(src, dst, n, n).fill_cache()
    got = conv.apply(params, x, ei)
    assert calls, "reversed flow missed the fused kernel (transpose table)"
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4,
                               atol=1e-5)


# ------------------------------------------------- loader single-trace step
def test_gat_loader_step_single_trace_grad_parity(rng, monkeypatch):
    """The acceptance criterion: a jit'd GATConv train step over
    NeighborLoader batches runs the fused kernel forward and backward with
    ONE trace across batches, gradients == materialised oracle <= 1e-5."""
    monkeypatch.setenv("REPRO_USE_PALLAS", "1")
    calls = _spy(monkeypatch, attn_ops, "gat_ell_pallas")
    bwd = _spy(monkeypatch, attn_ops, "_gat_panels_backward")
    n, e, feat, hidden = 200, 1200, 16, 8
    data = Data(x=rng.standard_normal((n, feat)).astype(np.float32),
                edge_index=np.stack(_random_graph(rng, n, e)))
    loader = NeighborLoader(data, data, num_neighbors=[4, 2], batch_size=8,
                            prefill_ell=True, labels_attr=None, seed=0)
    conv = GATConv(feat, hidden, heads=2)
    params = conv.init(jax.random.PRNGKey(4))
    traces = []

    def loss_fn(p, ei, batch):
        out = conv.apply(p, batch.x, ei)
        return (out[batch.seed_slots] ** 2).mean()

    @jax.jit
    def step(p, batch):
        traces.append(1)
        return jax.value_and_grad(loss_fn)(p, batch.edge_index, batch)

    it = iter(loader)
    b1, b2 = next(it), next(it)
    for b in (b1, b2):
        loss_k, grad_k = step(params, b)
        assert calls, "train step never reached the fused attention kernel"
        assert bwd, "train step never ran the fused attention backward"
        # materialised oracle on a cache-less EdgeIndex: no Pallas anywhere
        monkeypatch.setenv("REPRO_USE_PALLAS", "0")
        raw = EdgeIndex(b.edge_index.data, b.num_nodes, b.num_nodes)
        loss_o, grad_o = jax.value_and_grad(loss_fn)(params, raw, b)
        monkeypatch.setenv("REPRO_USE_PALLAS", "1")
        np.testing.assert_allclose(float(loss_k), float(loss_o), rtol=1e-5)
        diffs = jax.tree_util.tree_map(
            lambda a, b_: float(jnp.abs(a - b_).max()), grad_k, grad_o)
        max_diff = max(jax.tree_util.tree_leaves(diffs))
        assert max_diff <= 1e-5, f"kernel-grad != oracle-grad: {max_diff}"
    assert len(traces) == 1, "second batch retraced the GAT grad step"


# ------------------------------------------------------ explainer edge_mask
def test_explainer_edge_mask_gat_stays_fused(rng, monkeypatch):
    """Gradient-based explainers on GAT under REPRO_USE_PALLAS=1 send their
    soft mask down the fused path (spy-counted — the mask folds into the
    post-softmax weight, no (E, H, F) materialisation) and agree with the
    oracle-path attributions."""
    n, e, f = 30, 100, 8
    src, dst = _random_graph(rng, n, e)
    x = jnp.asarray(rng.standard_normal((n, f)).astype(np.float32))
    model = make_model("gat", f, 16, 3, 2)
    params = model.init(jax.random.PRNGKey(0))

    monkeypatch.setenv("REPRO_USE_PALLAS", "1")
    calls = _spy(monkeypatch, attn_ops, "gat_ell_pallas")
    ei = EdgeIndex.from_coo(src, dst, n, n)
    fast = Explainer(model, params, algorithm="saliency")(x, ei, node_idx=5)
    assert calls, "GAT explainer gradients bypassed the fused kernel"
    assert np.isfinite(np.asarray(fast.edge_mask)).all()

    monkeypatch.setenv("REPRO_USE_PALLAS", "0")
    ref = Explainer(model, params, algorithm="saliency")(
        x, EdgeIndex.from_coo(src, dst, n, n), node_idx=5)
    np.testing.assert_allclose(np.asarray(fast.edge_mask),
                               np.asarray(ref.edge_mask), rtol=1e-3,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(fast.node_mask),
                               np.asarray(ref.node_mask), rtol=1e-3,
                               atol=1e-4)


def test_attention_explainer_roundtrip_fused(rng, monkeypatch):
    """The 'attention' explanation algorithm (GAT coefficient capture) uses
    return_attention — on the fused path the coefficients come back through
    ell_pos and must match the oracle's."""
    n, e, f = 24, 90, 6
    src, dst = _random_graph(rng, n, e)
    x = jnp.asarray(rng.standard_normal((n, f)).astype(np.float32))
    model = make_model("gat", f, 8, 2, 2)
    params = model.init(jax.random.PRNGKey(1))
    monkeypatch.setenv("REPRO_USE_PALLAS", "1")
    ei = EdgeIndex.from_coo(src, dst, n, n).fill_cache()
    fast = Explainer(model, params, algorithm="attention")(x, ei, node_idx=3)
    monkeypatch.setenv("REPRO_USE_PALLAS", "0")
    ref = Explainer(model, params, algorithm="attention")(
        x, EdgeIndex.from_coo(src, dst, n, n), node_idx=3)
    np.testing.assert_allclose(np.asarray(fast.edge_mask),
                               np.asarray(ref.edge_mask), rtol=1e-4,
                               atol=1e-6)


# ------------------------------------------------------------------ hetero
def test_hetero_gat_per_relation_fused(rng, monkeypatch):
    """Every relation of a hetero GAT dispatches the fused attention kernel
    (typed loader batches, one trace) and matches the per-conv oracle."""
    monkeypatch.setenv("REPRO_USE_PALLAS", "1")
    calls = _spy(monkeypatch, attn_ops, "gat_ell_pallas")
    hd = HeteroData()
    hd.add_nodes("user", rng.standard_normal((40, 8)).astype(np.float32))
    hd.add_nodes("item", rng.standard_normal((60, 8)).astype(np.float32))
    ub = np.stack([rng.integers(0, 40, 200), rng.integers(0, 60, 200)])
    hd.add_edges(ET_UB, ub)
    hd.add_edges(ET_RU, ub[::-1])
    loader = HeteroNeighborLoader(
        hd, hd, num_neighbors=FANOUTS, input_type="item",
        input_nodes=np.arange(16), batch_size=4, prefill_ell=True, seed=0)
    metadata = (["user", "item"], list(FANOUTS))
    net = to_hetero(lambda i, o: GATConv(i, o, heads=2), metadata,
                    [8, 16, 4])
    params = net.init(jax.random.PRNGKey(0))
    traces = []

    @jax.jit
    def step(p, batch):
        traces.append(1)

        def loss_fn(p):
            out = net.apply(p, batch.x_dict, batch.edge_index_dict,
                            batch.num_nodes_dict)
            return (batch.seed_output(out) ** 2).mean()

        return jax.value_and_grad(loss_fn)(p)

    it = iter(loader)
    b1, b2 = next(it), next(it)
    results = [(b, step(params, b)) for b in (b1, b2)]
    assert len(traces) == 1, "second typed batch retraced the grad step"
    assert len(calls) >= 2 * len(FANOUTS), \
        "not every relation's attention hit the fused kernel"

    monkeypatch.setenv("REPRO_USE_PALLAS", "0")
    for b, (loss_k, grad_k) in results:
        raw = {et: EdgeIndex(ei.data, ei.num_src_nodes, ei.num_dst_nodes)
               for et, ei in b.edge_index_dict.items()}

        def ref_loss(p):
            out = net.apply(p, b.x_dict, raw, b.num_nodes_dict)
            return (b.seed_output(out) ** 2).mean()

        loss_o, grad_o = jax.value_and_grad(ref_loss)(params)
        np.testing.assert_allclose(float(loss_k), float(loss_o), rtol=1e-4)
        jax.tree_util.tree_map(
            lambda a, b_: np.testing.assert_allclose(
                np.asarray(a), np.asarray(b_), rtol=2e-3, atol=2e-4),
            grad_k, grad_o)


# -------------------------------------------------------------------- trim
def test_deep_gat_trim_keeps_kernel_and_seed_outputs(rng, monkeypatch):
    """Layer-wise trimming of a deep GAT: inner hops keep the fused kernel
    (masked static-layout ELL) and seed representations are unchanged."""
    monkeypatch.setenv("REPRO_USE_PALLAS", "1")
    n, e, feat = 300, 2400, 12
    data = Data(x=rng.standard_normal((n, feat)).astype(np.float32),
                edge_index=np.stack(_random_graph(rng, n, e)))
    loader = NeighborLoader(data, data, num_neighbors=[4, 3, 2],
                            batch_size=6, prefill_ell=True,
                            labels_attr=None, seed=0)
    batch = next(iter(loader))
    model = make_model("gat", feat, 8, 3, 3)
    params = model.init(jax.random.PRNGKey(5))
    calls = _spy(monkeypatch, attn_ops, "gat_ell_pallas")
    full = model.apply(params, batch.x, batch.edge_index)
    full_calls = len(calls)
    assert full_calls, "untrimmed GAT batch missed the fused kernel"
    del calls[:]
    trim = model.apply(params, batch.x, batch.edge_index,
                       num_sampled_nodes_per_hop=batch.num_sampled_nodes,
                       num_sampled_edges_per_hop=batch.num_sampled_edges,
                       trim=True)
    assert len(calls) == full_calls, \
        "trimmed inner GAT layers fell off the fused kernel path"
    np.testing.assert_allclose(
        np.asarray(full[batch.seed_slots]),
        np.asarray(trim[batch.seed_slots]), rtol=1e-3, atol=1e-4)


def test_trimmed_transpose_ell_serves_reversed_flow(rng, monkeypatch):
    """The transpose (CSR-derived) ELL now survives a layer trim as a
    per-slot masked cache: reversed-flow GAT attend AND transpose matmul
    on a trimmed EdgeIndex stay on the kernel and match the COO oracle of
    the trimmed graph."""
    from repro.core.trim import trim_to_layer
    monkeypatch.setenv("REPRO_USE_PALLAS", "1")
    n, e, feat = 300, 2400, 12
    data = Data(x=rng.standard_normal((n, feat)).astype(np.float32),
                edge_index=np.stack(_random_graph(rng, n, e)))
    loader = NeighborLoader(data, data, num_neighbors=[4, 3, 2],
                            batch_size=6, prefill_ell=True,
                            labels_attr=None, seed=0)
    batch = next(iter(loader))
    batch.edge_index.fill_cache()  # packs the transpose ELL (host CSR)
    x, ei_t, _ = trim_to_layer(1, batch.num_sampled_nodes,
                               batch.num_sampled_edges, batch.x,
                               batch.edge_index)
    assert ei_t._ell_t is not None, "trim dropped the transpose ELL"
    conv = GATConv(feat, 8, heads=2, flow="target_to_source")
    params = conv.init(jax.random.PRNGKey(6))
    calls = _spy(monkeypatch, attn_ops, "gat_ell_pallas")
    got = conv.apply(params, x, ei_t)
    assert calls, "trimmed reversed-flow GAT fell off the fused kernel"
    got_mm = ei_t.matmul(x, transpose=True, force_pallas=True,
                         interpret=True)

    monkeypatch.setenv("REPRO_USE_PALLAS", "0")
    raw = EdgeIndex(ei_t.data, ei_t.num_src_nodes, ei_t.num_dst_nodes)
    want = conv.apply(params, x, raw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4,
                               atol=1e-5)
    want_mm = raw.matmul(x, transpose=True, force_pallas=False)
    np.testing.assert_allclose(np.asarray(got_mm), np.asarray(want_mm),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------- slow grad sweep
@pytest.mark.slow
@pytest.mark.parametrize("heads,concat,weighted", [
    (1, True, False), (2, True, True), (4, False, True), (3, False, False)])
def test_gat_parity_sweep_k_ladder(rng, monkeypatch, heads, concat,
                                   weighted):
    """Fused-vs-materialised forward AND grad parity on a skewed-degree
    graph whose demand-filled ELL spans several K-ladder buckets."""
    n = 64
    deg = np.concatenate([rng.integers(0, 4, 40), rng.integers(5, 17, 20),
                          [0, 1, 29, 53]])
    rng.shuffle(deg)
    dst = np.repeat(np.arange(n), deg).astype(np.int32)
    e = len(dst)
    src = rng.integers(0, n, e).astype(np.int32)
    x = jnp.asarray(rng.standard_normal((n, 12)).astype(np.float32))
    mask = (jnp.asarray(rng.random(e).astype(np.float32)) if weighted
            else None)
    conv = GATConv(12, 8 * heads if concat else 8, heads=heads,
                   concat=concat)
    params = conv.init(jax.random.PRNGKey(heads))

    def loss(p, x_, m_, ei):
        return (conv.apply(p, x_, ei, edge_mask=m_) ** 2).mean()

    monkeypatch.setenv("REPRO_USE_PALLAS", "1")
    calls = _spy(monkeypatch, attn_ops, "gat_ell_pallas")
    ei = EdgeIndex.from_coo(src, dst, n, n).fill_cache()
    assert len(ei.get_ell()) >= 3, "degree skew produced too few buckets"
    out_k = conv.apply(params, x, ei, edge_mask=mask)
    gk = jax.grad(loss, argnums=(0, 1))(params, x, mask, ei)
    assert len(calls) >= 3, "not every K bucket launched the kernel"

    monkeypatch.setenv("REPRO_USE_PALLAS", "0")
    raw = EdgeIndex(ei.data, n, n)
    out_o = conv.apply(params, x, raw, edge_mask=mask)
    go = jax.grad(loss, argnums=(0, 1))(params, x, mask, raw)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_o),
                               rtol=1e-4, atol=1e-5)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5), gk, go)
