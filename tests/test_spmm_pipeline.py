"""The blocked-ELL SpMM pipeline: packing, buckets, kernel, dispatch.

Covers the full chain the TPU fast path takes:

    EdgeIndex.get_ell (cached, degree-bucketed packing)
      -> spmm_ell_bucketed (one launch per power-of-two-K bucket)
        -> spmm_ell_pallas (pipelined DMA kernel; interpret mode on CPU)

plus the vectorised host-side packing, the widened max/min fused
MessagePassing path, and the vectorised temporal sampler search.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.edge_index import EdgeIndex
from repro.core.message_passing import MessagePassing
from repro.data.sampler import _temporal_prefix
from repro.kernels.spmm import ops as spmm_ops, ref as spmm_ref
from repro.kernels.spmm.spmm import spmm_ell_pallas

REDUCES = ["sum", "mean", "max", "min"]


def _skewed_csr(rng, n_rows=37, n_cols=29):
    """Real-world-ish degrees: many small rows, a few hubs, some zeros."""
    deg = np.concatenate([rng.integers(0, 4, n_rows - 17),
                          rng.integers(5, 17, 15), [0, 53]])
    rng.shuffle(deg)
    indptr = np.concatenate([[0], np.cumsum(deg)]).astype(np.int64)
    indices = rng.integers(0, n_cols, int(indptr[-1])).astype(np.int32)
    return indptr, indices


# ------------------------------------------------------------------- packing
def test_csr_to_ell_vectorized_matches_loop(rng):
    indptr, indices = _skewed_csr(rng)
    w = rng.standard_normal(len(indices)).astype(np.float32)
    ell_idx, ell_w = spmm_ops.csr_to_ell(indptr, indices, w)
    rows_pad, k = ell_idx.shape
    ref_idx = np.full((rows_pad, k), -1, np.int32)
    ref_w = np.zeros((rows_pad, k), np.float32)
    for r in range(len(indptr) - 1):  # the old per-row reference semantics
        lo, hi = int(indptr[r]), int(indptr[r + 1])
        take = min(hi - lo, k)
        ref_idx[r, :take] = indices[lo:lo + take]
        ref_w[r, :take] = w[lo:lo + take]
    np.testing.assert_array_equal(ell_idx, ref_idx)
    np.testing.assert_array_equal(ell_w, ref_w)


def test_csr_to_ell_truncates_to_k(rng):
    indptr, indices = _skewed_csr(rng)
    ell_idx, _ = spmm_ops.csr_to_ell(indptr, indices, k=3)
    assert ell_idx.shape[1] == 3
    deg = np.minimum(np.diff(indptr), 3)
    np.testing.assert_array_equal(
        (ell_idx[:len(deg)] >= 0).sum(1), deg)


def test_bucketed_packing_partitions_edges(rng):
    """Every edge in exactly one bucket; every row in at most one; K ladder
    is power-of-two multiples of min_k with <=2x padding waste per row."""
    indptr, indices = _skewed_csr(rng)
    buckets = spmm_ops.csr_to_ell_bucketed(indptr, indices, min_k=4)
    all_pos = np.concatenate([p[p >= 0] for _, _, p in buckets])
    assert sorted(all_pos.tolist()) == list(range(len(indices)))
    all_rows = np.concatenate([r for r, _, _ in buckets])
    assert len(set(all_rows.tolist())) == len(all_rows)
    deg = np.diff(indptr)
    for row_ids, ell_idx, pos in buckets:
        k = ell_idx.shape[1]
        assert k % 4 == 0 and (k // 4) & (k // 4 - 1) == 0  # 4 * 2^j
        assert ell_idx.shape[0] % 8 == 0  # block_rows padded
        np.testing.assert_array_equal(
            (ell_idx[:len(row_ids)] >= 0).sum(1), deg[row_ids])
        # degree fits the bucket: (k/2, k], except the first bucket (1..min_k)
        assert deg[row_ids].max() <= k
        if k > 4:
            assert deg[row_ids].min() > k // 2


def test_bucketed_empty_graph():
    assert spmm_ops.csr_to_ell_bucketed(np.zeros(5, np.int64),
                                        np.zeros(0, np.int32)) == []


# -------------------------------------------------------------------- kernel
@pytest.mark.parametrize("reduce", REDUCES)
def test_kernel_weighted_parity_all_reduces(rng, reduce):
    """Pallas (interpret) == ELL oracle with weights, incl. max/min."""
    rows, k, n, f = 16, 5, 23, 128
    ell = rng.integers(-1, n, (rows, k)).astype(np.int32)
    w = jnp.asarray(rng.standard_normal((rows, k)).astype(np.float32))
    x = jnp.asarray(rng.standard_normal((n, f)).astype(np.float32))
    a = spmm_ref.spmm_ell(jnp.asarray(ell), w, x, reduce=reduce)
    b = spmm_ell_pallas(jnp.asarray(ell), w, x, reduce=reduce,
                        interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


def test_kernel_zero_degree_rows(rng):
    """All-padding rows produce the 0 fill in every reduce mode."""
    rows, k, n, f = 8, 4, 10, 128
    ell = rng.integers(0, n, (rows, k)).astype(np.int32)
    ell[2] = -1
    ell[5] = -1
    x = jnp.asarray(rng.standard_normal((n, f)).astype(np.float32))
    for reduce in REDUCES:
        out = np.asarray(spmm_ell_pallas(jnp.asarray(ell), None, x,
                                         reduce=reduce, interpret=True))
        np.testing.assert_array_equal(out[2], 0.0)
        np.testing.assert_array_equal(out[5], 0.0)


@pytest.mark.slow
@pytest.mark.parametrize("reduce", REDUCES)
@pytest.mark.parametrize("shape", [(32, 9, 40, 256), (64, 33, 100, 128),
                                   (8, 2, 300, 384)])
def test_kernel_sweep_slow(rng, reduce, shape):
    """Wider (rows, K, N, F) sweep — excluded from tier-1 via `slow`."""
    rows, k, n, f = shape
    ell = rng.integers(-1, n, (rows, k)).astype(np.int32)
    w = jnp.asarray(rng.standard_normal((rows, k)).astype(np.float32))
    x = jnp.asarray(rng.standard_normal((n, f)).astype(np.float32))
    bf = 128 if f % 128 == 0 else f
    a = spmm_ref.spmm_ell(jnp.asarray(ell), w, x, reduce=reduce)
    b = spmm_ell_pallas(jnp.asarray(ell), w, x, reduce=reduce,
                        block_feat=bf, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


# ---------------------------------------------------------- bucketed dispatch
@pytest.mark.parametrize("reduce", REDUCES)
@pytest.mark.parametrize("weighted", [False, True])
def test_bucketed_spmm_matches_csr_oracle(rng, reduce, weighted):
    indptr, indices = _skewed_csr(rng)
    n_rows, n_cols = len(indptr) - 1, 29
    w = rng.standard_normal(len(indices)).astype(np.float32)
    x = jnp.asarray(rng.standard_normal((n_cols, 128)).astype(np.float32))
    buckets = spmm_ops.csr_to_ell_bucketed(indptr, indices)
    wj = jnp.asarray(w) if weighted else None
    a = spmm_ref.spmm_csr(jnp.asarray(indptr), jnp.asarray(indices), x, wj,
                          num_rows=n_rows, reduce=reduce)
    b = spmm_ops.spmm_ell_bucketed(buckets, x, wj, num_rows=n_rows,
                                   reduce=reduce, force_pallas=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


@pytest.mark.parametrize("reduce", ["sum", "max"])
def test_bucketed_spmm_pallas_interpret(rng, reduce):
    indptr, indices = _skewed_csr(rng)
    n_rows, n_cols = len(indptr) - 1, 29
    w = jnp.asarray(rng.standard_normal(len(indices)).astype(np.float32))
    x = jnp.asarray(rng.standard_normal((n_cols, 128)).astype(np.float32))
    buckets = spmm_ops.csr_to_ell_bucketed(indptr, indices)
    a = spmm_ref.spmm_csr(jnp.asarray(indptr), jnp.asarray(indices), x, w,
                          num_rows=n_rows, reduce=reduce)
    b = spmm_ops.spmm_ell_bucketed(buckets, x, w, num_rows=n_rows,
                                   reduce=reduce, force_pallas=True,
                                   interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


def test_spmm_ell_row_chunking(rng, monkeypatch):
    """Tables above the SMEM prefetch budget split into multiple launches
    along rows — results must be identical to a single launch."""
    monkeypatch.setattr(spmm_ops, "MAX_PREFETCH_ELEMS", 64)  # force chunking
    rows, k, n, f = 40, 5, 23, 128  # 40*5 > 64 -> 4 launches of 8+ rows
    ell = rng.integers(-1, n, (rows, k)).astype(np.int32)
    w = jnp.asarray(rng.standard_normal((rows, k)).astype(np.float32))
    x = jnp.asarray(rng.standard_normal((n, f)).astype(np.float32))
    a = spmm_ref.spmm_ell(jnp.asarray(ell), w, x, reduce="sum")
    b = spmm_ops.spmm_ell(jnp.asarray(ell), w, x, reduce="sum",
                          force_pallas=True, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


# ------------------------------------------------------------ EdgeIndex + MP
def test_edge_index_ell_cache_demand_filled(rng):
    src = rng.integers(0, 20, 60).astype(np.int32)
    dst = rng.integers(0, 20, 60).astype(np.int32)
    ei = EdgeIndex.from_coo(src, dst, 20, 20)
    assert ei._ell is None
    ell = ei.get_ell()
    assert ell is not None and ei._ell is ell
    assert ei.get_ell() is ell  # memoised
    x = jnp.asarray(rng.standard_normal((20, 8)).astype(np.float32))
    out = ei.matmul(x, force_pallas=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ei.matmul(x, force_pallas=False)),
                               rtol=1e-4, atol=1e-4)


def test_undirected_ell_cache_shared(rng):
    """A == A^T: the transpose ELL request must reuse the forward packing."""
    src = rng.integers(0, 20, 50).astype(np.int32)
    dst = rng.integers(0, 20, 50).astype(np.int32)
    ei = EdgeIndex.from_coo(src, dst, 20, 20).to_undirected()
    fwd = ei.get_ell()
    assert ei.get_ell(transpose=True) is fwd
    assert ei._ell_t is None  # no second packing stored


def test_fill_cache_packs_ell_when_pallas_on(rng, monkeypatch):
    src = rng.integers(0, 12, 30).astype(np.int32)
    dst = rng.integers(0, 12, 30).astype(np.int32)
    monkeypatch.setenv("REPRO_USE_PALLAS", "1")
    ei = EdgeIndex.from_coo(src, dst, 12, 12).fill_cache()
    assert ei._ell is not None and ei._ell_t is not None
    monkeypatch.setenv("REPRO_USE_PALLAS", "0")
    ei2 = EdgeIndex.from_coo(src, dst, 12, 12).fill_cache()
    assert ei2._ell is None  # oracle backend: no eager packing cost


def test_edge_index_ell_not_filled_under_jit(rng):
    """Tracing without a cache must fall back to the oracle, not crash."""
    src = rng.integers(0, 15, 40).astype(np.int32)
    dst = rng.integers(0, 15, 40).astype(np.int32)
    ei = EdgeIndex.from_coo(src, dst, 15, 15)
    x = jnp.asarray(rng.standard_normal((15, 4)).astype(np.float32))

    @jax.jit
    def f(x):
        return ei.matmul(x, force_pallas=True)

    out = f(x)
    assert ei._ell is None  # tracer guard held
    np.testing.assert_allclose(np.asarray(out), np.asarray(ei.matmul(x)),
                               rtol=1e-5, atol=1e-5)


def test_propagate_dispatches_to_pallas_ell(rng, monkeypatch):
    """MessagePassing.propagate with a sorted EdgeIndex must reach the
    Pallas ELL kernel (not the XLA oracle) when the Pallas path is forced —
    proven statically by the jaxpr dispatch auditor instead of a
    monkey-patched kernel spy."""
    from repro.analysis import audit_report

    monkeypatch.setenv("REPRO_USE_PALLAS", "1")
    n, e, f = 26, 90, 128
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    x = jnp.asarray(rng.standard_normal((n, f)).astype(np.float32))
    ei, _ = EdgeIndex.from_coo(src, dst, n, n).sort_by("col")
    mp = MessagePassing(aggr="sum")
    out = mp.propagate({}, ei, x)  # eager warm call packs the ELL cache
    # steady state (the jit-cached trace): fused kernel, zero oracle eqns
    report = audit_report(lambda x_: mp.propagate({}, ei, x_), x)
    report.assert_fused(expect_kernels=("_spmm_ell_kernel",))
    assert report.oracle_fallbacks == 0
    monkeypatch.delenv("REPRO_USE_PALLAS")
    ref_out = MessagePassing(aggr="sum").propagate({}, ei.data, x,
                                                   num_nodes=n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("aggr", ["max", "min"])
def test_fused_path_max_min(rng, aggr):
    """The widened fused predicate: max/min aggr == materialised path."""
    n, e, f = 30, 110, 8
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    x = jnp.asarray(rng.standard_normal((n, f)).astype(np.float32))
    ei = EdgeIndex.from_coo(src, dst, n, n)
    mp = MessagePassing(aggr=aggr)
    fused = mp.propagate({}, ei, x)
    raw = mp.propagate({}, ei.data, x, num_nodes=n)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(raw),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------------- sampler
def test_temporal_prefix_matches_searchsorted(rng):
    """Vectorised binary search == per-row np.searchsorted (the old loop)."""
    for _ in range(50):
        n_edges = int(rng.integers(0, 200))
        n_rows = int(rng.integers(1, 20))
        cuts = np.sort(rng.integers(0, n_edges + 1, n_rows + 1))
        lo, hi = cuts[:-1], cuts[1:]
        t = np.zeros(n_edges, np.int64)
        for a, b in zip(lo, hi):
            t[a:b] = np.sort(rng.integers(0, 40, b - a))
        bound = rng.integers(-5, 45, n_rows)
        got = _temporal_prefix(t, lo.copy(), hi.copy(), bound)
        want = np.array(
            [a + np.searchsorted(t[a:b], bb, side="right")
             for a, b, bb in zip(lo, hi, bound)], np.int64)
        np.testing.assert_array_equal(got, want)
